"""Render EXPERIMENTS.md tables from reports/dryrun.json + roofline model.

    PYTHONPATH=src:. python benchmarks/make_experiments.py > /tmp/tables.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402

REPORT = os.path.join(os.path.dirname(__file__), "..", "reports",
                      "dryrun.json")
MESHES = {"16x16": {"data": 16, "model": 16},
          "2x16x16": {"pod": 2, "data": 16, "model": 16}}


def n_micro_for(shape, data_shards):
    if shape.kind != "train":
        return 1
    tokens = shape.global_batch * shape.seq_len // data_shards
    m = max(1, tokens // 4096)
    while shape.global_batch % m or (shape.global_batch // m) % 16:
        m -= 1
    return max(m, 1)


def fmt_s(x):
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def main():
    with open(REPORT) as f:
        dry = json.load(f)

    print("### Dry-run table (compile status, per-device memory)\n")
    print("| arch | shape | 16x16 | 2x16x16 | temp/dev | args/dev | "
          "compile | #colls |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for sname in SHAPES:
            r1 = dry.get(f"{arch}|{sname}|16x16", {})
            r2 = dry.get(f"{arch}|{sname}|2x16x16", {})
            if r1.get("status") == "SKIP":
                print(f"| {arch} | {sname} | SKIP | SKIP | — | — | — | — |"
                      f" <!-- {r1.get('reason','')[:60]} -->")
                continue
            pd = r1.get("per_device", {})
            print(f"| {arch} | {sname} | {r1.get('status','?')} | "
                  f"{r2.get('status','?')} | "
                  f"{pd.get('temp_bytes',0)/2**30:.2f} GiB | "
                  f"{(pd.get('argument_bytes',0)+pd.get('alias_bytes',0))/2**30:.2f} GiB | "
                  f"{r1.get('compile_s','?')}s | "
                  f"{r1.get('n_collectives','?')} |")

    print("\n### Roofline table (16x16; per-device, per step)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    mesh = MESHES["16x16"]
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            rec = dry.get(f"{arch}|{sname}|16x16", {})
            if rec.get("status") == "SKIP":
                continue
            r = rl.cell_roofline(cfg, shape, mesh,
                                 n_micro=n_micro_for(shape, 16))
            print(f"| {arch} | {sname} | {fmt_s(r.compute_s)} | "
                  f"{fmt_s(r.memory_s)} | {fmt_s(r.collective_s)} | "
                  f"**{r.dominant}** | {r.useful_ratio:.2f} | "
                  f"{r.roofline_fraction:.3f} |")

    print("\n### Perf-variant cells (hillclimb log source)\n")
    print("| key | temp/dev | link bytes/dev | #colls | flops/dev |")
    print("|---|---|---|---|---|")
    for k, v in sorted(dry.items()):
        if k.count("|") >= 3 and v.get("status") == "OK":
            pd = v["per_device"]
            print(f"| {k} | {pd['temp_bytes']/2**30:.2f} GiB | "
                  f"{pd['link_bytes']/2**30:.3f} GiB | "
                  f"{v['n_collectives']} | {pd['flops']:.3g} |")


if __name__ == "__main__":
    main()
