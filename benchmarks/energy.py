"""Fig. 9 (middle) + Fig. 10b — energy/power breakdown."""
from repro.core import costmodel as cm


def rows():
    out = []
    for mr, tag in ((12.5e3, "12.5k"), (25e3, "25k"), (50e3, "50k")):
        est = cm.dart_pim_system(max_reads=mr)
        out.append((f"dartpim_{tag}_energy_kJ", round(est.energy_J / 1e3, 1),
                    f"avg_power={est.avg_power_W:.0f}W "
                    f"(paper: 20.8..34.9kJ, 201..482W)"))
    st = cm.speedup_table(25e3)
    for name, v in st.items():
        out.append((f"energy_eff_vs_{name}", round(v["energy_eff"], 1),
                    "paper: minimap2/parabricks=90.6x genasm=3.6x "
                    "segram=20.7x"))
    return out
