"""Roofline table from reports/dryrun.json + the analytic model.

Produces the EXPERIMENTS.md §Roofline rows: three terms, dominant
bottleneck, MODEL_FLOPS/HLO ratio, per (arch x shape x mesh).
"""
import json
import os

from repro.configs import ARCHS, SHAPES
from repro.launch import roofline as rl

REPORT = os.path.join(os.path.dirname(__file__), "..", "reports",
                      "dryrun.json")

MESHES = {"16x16": {"data": 16, "model": 16},
          "2x16x16": {"pod": 2, "data": 16, "model": 16}}


def n_micro_for(shape, data_shards=16):
    if shape.kind != "train":
        return 1
    tokens = shape.global_batch * shape.seq_len // data_shards
    m = max(1, tokens // 4096)
    while shape.global_batch % m:
        m -= 1
    return m


def table(mesh_tag="16x16"):
    try:
        with open(REPORT) as f:
            dry = json.load(f)
    except FileNotFoundError:
        dry = {}
    mesh = MESHES[mesh_tag]
    rows = []
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            key = f"{arch}|{sname}|{mesh_tag}"
            rec = dry.get(key, {})
            if rec.get("status") == "SKIP":
                rows.append({"arch": arch, "shape": sname, "status": "SKIP",
                             "reason": rec.get("reason", "")})
                continue
            r = rl.cell_roofline(cfg, shape, mesh,
                                 n_micro=n_micro_for(shape))
            rows.append({
                "arch": arch, "shape": sname,
                "status": rec.get("status", "PENDING"),
                "compute_s": r.compute_s, "memory_s": r.memory_s,
                "collective_s": r.collective_s, "dominant": r.dominant,
                "useful_ratio": r.useful_ratio,
                "roofline_fraction": r.roofline_fraction,
                "temp_gib": (rec.get("per_device", {}).get("temp_bytes", 0)
                             / 2 ** 30),
                "hlo_flops_flat": rec.get("per_device", {}).get("flops", 0),
                "n_collectives": rec.get("n_collectives", 0),
            })
    return rows


def rows():
    out = []
    for r in table("16x16"):
        if r["status"] == "SKIP":
            out.append((f"roofline_{r['arch']}_{r['shape']}", 0,
                        f"SKIP({r['reason'][:40]})"))
        else:
            out.append((
                f"roofline_{r['arch']}_{r['shape']}",
                round(r["roofline_fraction"], 3),
                f"dom={r['dominant']} c={r['compute_s']:.3g}s "
                f"m={r['memory_s']:.3g}s x={r['collective_s']:.3g}s "
                f"useful={r['useful_ratio']:.2f} {r['status']}"))
    return out
