"""End-to-end mapping pipeline wall time on CPU: padded reference vs the
candidate-compacted engine (jnp and Pallas backends; synchronous vs async
double-buffered streaming), plus full-system iteration counts feeding
Eq. 6 (the full-system-simulator analog).

``bench_pipeline`` is the machine-readable entry (``benchmarks/run.py
--pipeline-json`` writes its output to BENCH_pipeline.json); ``rows``
keeps the CSV harness fast with a smaller read batch.

``python -m benchmarks.pipeline_bench --chunk-sweep`` sweeps chunk sizes:
for each, the fully synchronous engine (stream=False) reports per-stage
wall time (host prep / transfer / per-stage compute / fetch) and the
streamed engine reports its reads/s next to it, so the double-buffering
win is measured, not asserted.
"""
import time

from repro.core import costmodel as cm
from repro.core.index import build_index, minimizer_frequencies
from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.data.genome import make_reference, sample_reads


def _timed_map(idx, reads, cfg, iters=1, **mapper_kw):
    # session: index placed once, plans cached
    mapper = Mapper(idx, cfg, **mapper_kw)
    mapper.map(reads)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        res = mapper.map(reads)
    dt = (time.perf_counter() - t0) / iters
    return res, dt


def _make_world(genome: int):
    ref = make_reference(genome, seed=0, repeat_frac=0.03)
    return ref, build_index(ref)


def bench_pipeline(R: int = 4096, genome: int = 30_000,
                   chunk_reads: int | None = 1024,
                   include_pallas: bool = True, include_padded: bool = True,
                   world=None) -> dict:
    """Compare the execution engines at batch size R (``chunk_reads``-sized
    streaming chunks for the compacted engines).  Returns a dict with
    per-engine wall time / per-read time, the measured candidate-pruning
    ratio, the affine instance counts (padded vs compacted), and the
    streamed-vs-synchronous speedup of the Pallas engine."""
    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=2)
    if chunk_reads and chunk_reads >= R:
        chunk_reads = None  # single chunk: stream/sync distinction is moot

    engines = {}
    if include_padded:
        engines["padded_jnp"] = MapperConfig(engine="padded",
                                             wf_backend="jnp")
    engines["compacted_jnp"] = MapperConfig(
        engine="compacted", wf_backend="jnp", chunk_reads=chunk_reads)
    if include_pallas:
        engines["compacted_pallas_sync"] = MapperConfig(
            engine="compacted", wf_backend="pallas", chunk_reads=chunk_reads,
            stream=False)
        engines["compacted_pallas"] = MapperConfig(
            engine="compacted", wf_backend="pallas", chunk_reads=chunk_reads)
    # the single-dispatch engine: seed->filter->linear->affine->traceback
    # in one jit per chunk, no post-filter host sync
    engines["fused_jnp"] = MapperConfig(
        engine="fused", wf_backend="jnp", chunk_reads=chunk_reads)
    if include_pallas:
        engines["fused_pallas_sync"] = MapperConfig(
            engine="fused", wf_backend="pallas", chunk_reads=chunk_reads,
            stream=False)
        engines["fused_pallas"] = MapperConfig(
            engine="fused", wf_backend="pallas", chunk_reads=chunk_reads)

    out = {"R": R, "genome": genome, "chunk_reads": chunk_reads,
           "engines": {}}
    baseline = base_dt = None
    sync_dts = {}
    for name, cfg in engines.items():
        try:
            res, dt = _timed_map(idx, rs.reads, cfg)
        except Exception as e:  # noqa: BLE001 — report, keep the others
            out["engines"][name] = {"error": f"{type(e).__name__}: {e}"}
            continue
        entry = {
            "wall_s": round(dt, 4),
            "per_read_us": round(dt / R * 1e6, 2),
            "reads_per_s": round(R / dt, 1),
            "mapped_frac": round(float(res.mapped.mean()), 4),
        }
        if name == "padded_jnp":
            baseline, base_dt = res, dt
            entry["speedup_vs_padded"] = 1.0
        elif baseline is not None:  # only meaningful vs a live padded run
            entry["speedup_vs_padded"] = round(base_dt / dt, 2)
            entry["matches_padded"] = bool(
                (res.position == baseline.position).all()
                and (res.distance == baseline.distance).all())
        if name.endswith("_sync"):
            sync_dts[name[: -len("_sync")]] = dt
        elif name in sync_dts:
            entry["speedup_vs_sync"] = round(sync_dts[name] / dt, 2)
        if res.stats:
            st = dict(res.stats)
            st.pop("stream", None)
            if "stage_times_s" in st:  # full precision lives in the stats
                st["stage_times_s"] = {k: round(v, 4) for k, v
                                       in st["stage_times_s"].items()}
            entry.update(st)
        out["engines"][name] = entry
    # the real-data boundary: same mapping work fed through FASTQ/SAM
    out["fastq_path"] = bench_fastq_path(R=min(R, 2048), genome=genome,
                                         chunk_reads=chunk_reads,
                                         world=(ref, idx))
    # and the paired-end path: gzip R1/R2 in, resolved pairs + MAPQ out
    out["paired_path"] = bench_paired_path(n_pairs=min(R, 2048) // 2,
                                           genome=genome,
                                           chunk_reads=chunk_reads,
                                           world=(ref, idx))
    # the always-on hardening tax: armed-but-idle injector + watchdog +
    # retry wrapper vs the plain session (gated < 5% in perf-trend)
    try:
        out["resilience_overhead"] = bench_resilience_overhead(
            R=min(R, 2048), genome=genome, chunk_reads=chunk_reads,
            world=(ref, idx))
    except Exception as e:  # noqa: BLE001 — report, keep the others
        out["resilience_overhead"] = {
            "error": f"{type(e).__name__}: {e}"}
    # the always-on instrumentation tax: armed-but-idle metrics registry
    # + span tracer vs both disarmed (gated < 5% in perf-trend)
    try:
        out["obs_overhead"] = bench_obs_overhead(
            R=min(R, 2048), genome=genome, chunk_reads=chunk_reads,
            world=(ref, idx))
    except Exception as e:  # noqa: BLE001 — report, keep the others
        out["obs_overhead"] = {"error": f"{type(e).__name__}: {e}"}
    # the out-of-core index path: streamed sharded build + mmap reload
    try:
        out["index_build"] = bench_index_build()
    except Exception as e:  # noqa: BLE001 — report, keep the others
        out["index_build"] = {"error": f"{type(e).__name__}: {e}"}
    return out


def bench_index_build(genome: int = 400_000, num_partitions: int = 4,
                      tile_bp: int = 1 << 16, R: int = 1024) -> dict:
    """Sharded out-of-core index path: streamed build throughput
    (bases/s over FASTA -> on-disk CSR, the ``--tile-bp``-bounded scan),
    mmap reload latency (``open_index``: manifest + memmap handles, no
    bulk reads), and routed-mapping reads/s through the reloaded index
    next to the flat in-memory session on identical reads.
    ``build_bases_per_s`` is the perf-trend gate's ``index_build``
    metric."""
    import os
    import tempfile

    from repro.data.genome import write_fasta
    from repro.index import build_sharded_index, open_index

    ref = make_reference(genome, seed=0, repeat_frac=0.03)
    rs = sample_reads(ref, R, seed=2)
    with tempfile.TemporaryDirectory() as d:
        fa = os.path.join(d, "ref.fa")
        write_fasta(fa, ref)
        t0 = time.perf_counter()
        built = build_sharded_index(fa, os.path.join(d, "idx"),
                                    num_partitions=num_partitions,
                                    tile_bp=tile_bp)
        build_dt = time.perf_counter() - t0
        stor = built.storage_bytes()
        t0 = time.perf_counter()
        sidx = open_index(os.path.join(d, "idx"))
        reload_dt = time.perf_counter() - t0

        flat = build_index(ref, read_len=sidx.read_len, k=sidx.k,
                           w=sidx.w, eth=sidx.eth)
        cfg = MapperConfig.from_index(flat, chunk_reads=min(R, 512))
        _, flat_dt = _timed_map(flat, rs.reads, cfg)
        res, routed_dt = _timed_map(sidx, rs.reads, cfg)
        # prefetch-overlapped routed mapping: next chunk's partition
        # uploads staged on a background worker (bit-identical results)
        _, pf_dt = _timed_map(sidx, rs.reads, cfg, prefetch=True)
    bstats = (built.manifest or {}).get("build", {})
    return {
        "genome": genome, "num_partitions": num_partitions,
        "tile_bp": tile_bp,
        "build_wall_s": round(build_dt, 4),
        "build_bases_per_s": round(genome / build_dt, 1),
        "spill_bytes": bstats.get("spill_bytes", 0),
        "spill_writes": bstats.get("spill_writes", 0),
        "reload_ms": round(reload_dt * 1e3, 3),
        "on_disk_bytes": stor["total_bytes"],
        "blowup": stor["blowup"],
        "flat_reads_per_s": round(R / flat_dt, 1),
        "routed_reads_per_s": round(R / routed_dt, 1),
        "routed_prefetch_reads_per_s": round(R / pf_dt, 1),
        "routed_overhead_frac": round(
            max(routed_dt - flat_dt, 0.0) / routed_dt, 4),
        "prefetch_overhead_frac": round(
            max(pf_dt - flat_dt, 0.0) / pf_dt, 4),
        "mapped_frac": round(float(res.mapped.mean()), 4),
    }


def bench_fastq_path(R: int = 2048, genome: int = 30_000,
                     chunk_reads: int | None = 1024,
                     world=None) -> dict:
    """FASTQ-path reads/s next to the in-memory path: the same dual-strand
    mapping work, once fed from arrays and once through the full
    write-FASTQ -> stream-parse -> map -> SAM-emit loop, so
    BENCH_pipeline.json records the I/O boundary's overhead."""
    import os
    import tempfile

    from repro.data.genome import write_fasta, write_fastq
    from repro.io.fasta import ReferenceMap, load_reference
    from repro.io.fastq import FastqStream
    from repro.io.sam import emit_alignments, sam_header, write_sam

    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=2, both_strands=True)
    chunk = min(chunk_reads or R, R)
    cfg = MapperConfig.from_index(idx, wf_backend="jnp", chunk_reads=chunk,
                                  both_strands=True)
    mapper = Mapper(idx, cfg)
    mapper.map(rs.reads)  # compile both strands' chunk shapes
    t0 = time.perf_counter()
    res = mapper.map(rs.reads)
    mem_dt = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        fa, fq = os.path.join(d, "ref.fa"), os.path.join(d, "reads.fq")
        sam = os.path.join(d, "out.sam")
        write_fasta(fa, ref)
        write_fastq(fq, rs)
        t0 = time.perf_counter()
        _, contigs = load_reference(fa, spacer=cfg.read_len + 2 * cfg.eth)
        refmap = ReferenceMap(contigs)
        stream = FastqStream(fq, chunk_reads=chunk)
        n = 0
        with open(sam, "w") as out:
            write_sam(out, sam_header(contigs), ())
            for c in stream:
                r = mapper.map(c.reads)
                for rec in emit_alignments(r, c.names, c.reads, c.quals,
                                           refmap, seqs=c.seqs):
                    out.write(rec + "\n")
                n += len(c)
        io_dt = time.perf_counter() - t0
    return {
        "R": R, "chunk_reads": chunk,
        "in_memory_reads_per_s": round(R / mem_dt, 1),
        "fastq_sam_reads_per_s": round(n / io_dt, 1),
        "io_overhead_frac": round(max(io_dt - mem_dt, 0.0) / io_dt, 4),
        "mapped_frac": round(float(res.mapped.mean()), 4),
        "reverse_best_frac": round(res.stats.reverse_best / R, 4),
    }


def bench_paired_path(n_pairs: int = 1024, genome: int = 30_000,
                      chunk_reads: int | None = 1024,
                      world=None) -> dict:
    """Paired-end reads/s through the full gzip pipeline: write .fastq.gz
    R1/R2, stream-parse pairs, map both mates per chunk as one stacked
    dual-strand batch, resolve proper pairs + MAPQ host-side, emit
    paired SAM.  The ``reads_per_s`` here is the perf-trend gate's
    ``paired_path`` metric (reads = 2 * pairs)."""
    import os
    import tempfile

    from repro.core.pairing import InsertSizeTracker, resolve_pairs
    from repro.data.genome import sample_pairs, write_fasta, write_fastq_pair
    from repro.io.fasta import ReferenceMap, load_reference
    from repro.io.fastq import PairedFastqStream
    from repro.io.sam import emit_paired_alignments, sam_header, write_sam

    ref, idx = world or _make_world(genome)
    pp = sample_pairs(ref, n_pairs, seed=4)
    chunk = min(chunk_reads or n_pairs, n_pairs)
    cfg = MapperConfig.from_index(idx, wf_backend="jnp", chunk_reads=chunk,
                                  both_strands=True)
    mapper = Mapper(idx, cfg)
    mapper.map_pairs(pp.reads1[:chunk], pp.reads2[:chunk])  # compile

    with tempfile.TemporaryDirectory() as d:
        fa = os.path.join(d, "ref.fa")
        r1, r2 = (os.path.join(d, "r1.fastq.gz"),
                  os.path.join(d, "r2.fastq.gz"))
        sam = os.path.join(d, "out.sam")
        write_fasta(fa, ref)
        write_fastq_pair(r1, r2, pp)
        t0 = time.perf_counter()
        _, contigs = load_reference(fa, spacer=cfg.read_len + 2 * cfg.eth)
        refmap = ReferenceMap(contigs)
        stream = PairedFastqStream(r1, r2, chunk_reads=chunk)
        tracker = InsertSizeTracker()
        n = n_proper = n_rescued = 0
        with open(sam, "w") as out:
            write_sam(out, sam_header(contigs), ())
            for c1, c2 in stream:
                res1, res2 = mapper.map_pairs(c1.reads, c2.reads)
                pr = resolve_pairs(res1, res2, cfg=cfg, tracker=tracker,
                                   ref=ref, reads1=c1.reads,
                                   reads2=c2.reads)
                for rec in emit_paired_alignments(
                        pr, c1.names, c1.reads, c1.quals, c2.reads,
                        c2.quals, refmap, seqs1=c1.seqs, seqs2=c2.seqs):
                    out.write(rec + "\n")
                n += 2 * len(c1)
                n_proper += pr.stats["n_proper"]
                n_rescued += pr.stats["n_rescued"]
        dt = time.perf_counter() - t0
    return {
        "n_pairs": n_pairs, "chunk_reads": chunk,
        "reads_per_s": round(n / dt, 1),
        "pairs_per_s": round(n_pairs / dt, 1),
        "proper_frac": round(n_proper / max(n_pairs, 1), 4),
        "rescued": n_rescued,
        "insert_median": tracker.median,
    }


def bench_resilience_overhead(R: int = 2048, genome: int = 30_000,
                              chunk_reads: int | None = 1024,
                              iters: int = 3, world=None) -> dict:
    """Armed-but-idle fault-tolerance tax on the streamed Pallas engine.

    The resilience stack is always-on in a hardened deployment, so its
    idle cost is a first-class metric: the same streamed run once through
    a plain ``Mapper`` session and once through the full armed stack —
    ``FaultInjector`` threaded into the fetch thread (zero rates: every
    site checks, nothing fires), fetch watchdog armed, ``ResilientMapper``
    retry/bisect wrapper around every block.  ``overhead_frac`` is the
    perf-trend gate's ``resilience_overhead`` metric (< 5% = pass); it is
    self-relative (armed vs plain on the same runner), so it carries no
    hardware variance.  Plain and armed iterations are interleaved and
    each side takes its best-of-``iters`` wall time, so machine drift
    during the benchmark lands on both sides instead of masquerading as
    overhead.
    """
    from repro.core.resilience import FaultInjector, ResilientMapper

    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=3)
    chunk = min(chunk_reads or R, R)
    cfg = MapperConfig(engine="compacted", wf_backend="pallas",
                       chunk_reads=chunk)

    plain = Mapper(idx, cfg)
    plain.map(rs.reads)  # compile
    inj = FaultInjector(seed=0, rates={"bucket": 0.0, "fetch_stall": 0.0,
                                       "fetch_error": 0.0})
    armed = ResilientMapper(Mapper(idx, cfg, injector=inj, watchdog_s=60.0))
    armed.map(rs.reads)  # compile

    plain_ts, armed_ts = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        plain.map(rs.reads)
        plain_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        res, mask, _ = armed.map(rs.reads)
        armed_ts.append(time.perf_counter() - t0)
    plain_dt, armed_dt = min(plain_ts), min(armed_ts)
    assert not mask.any() and res is not None  # idle means idle

    return {
        "R": R, "chunk_reads": chunk,
        "plain_reads_per_s": round(R / plain_dt, 1),
        "armed_reads_per_s": round(R / armed_dt, 1),
        "overhead_frac": round(max(armed_dt - plain_dt, 0.0) / armed_dt, 4),
    }


def bench_obs_overhead(R: int = 2048, genome: int = 30_000,
                       chunk_reads: int | None = 1024,
                       iters: int = 3, world=None) -> dict:
    """Armed-but-idle observability tax on the streamed Pallas engine.

    The metrics registry and the span tracer are always-on in an
    instrumented deployment, so their enabled cost is a first-class
    metric: the same streamed run once with both disarmed (the default:
    every hook is one attribute load + an ``is None`` branch) and once
    with a live registry + tracer installed — counters increment per
    run/chunk, spans record wherever stage times flow.  ``overhead_frac``
    is the perf-trend gate's ``obs_overhead`` metric (< 5% = pass); like
    ``resilience_overhead`` it is self-relative and interleaved
    best-of-``iters``, so machine drift lands on both sides instead of
    masquerading as overhead.
    """
    from repro.obs import registry as obs_registry
    from repro.obs import tracing as obs_tracing

    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=3)
    chunk = min(chunk_reads or R, R)
    cfg = MapperConfig(engine="compacted", wf_backend="pallas",
                       chunk_reads=chunk)
    mapper = Mapper(idx, cfg)
    mapper.map(rs.reads)  # compile

    reg = obs_registry.MetricsRegistry()
    tr = obs_tracing.Tracer()
    plain_ts, armed_ts = [], []
    try:
        for _ in range(iters):
            obs_tracing.disable_tracing()
            obs_registry.disable_metrics()
            t0 = time.perf_counter()
            mapper.map(rs.reads)
            plain_ts.append(time.perf_counter() - t0)
            obs_registry.enable_metrics(reg)
            obs_tracing.enable_tracing(tracer_=tr)
            t0 = time.perf_counter()
            mapper.map(rs.reads)
            armed_ts.append(time.perf_counter() - t0)
    finally:
        obs_tracing.disable_tracing()
        obs_registry.disable_metrics()
    plain_dt, armed_dt = min(plain_ts), min(armed_ts)

    return {
        "R": R, "chunk_reads": chunk,
        "plain_reads_per_s": round(R / plain_dt, 1),
        "armed_reads_per_s": round(R / armed_dt, 1),
        "overhead_frac": round(max(armed_dt - plain_dt, 0.0) / armed_dt, 4),
        "spans_recorded": len(tr),
        "counter_series": len(reg.snapshot()["counters"]),
    }


def chunk_sweep(R: int = 4096, genome: int = 30_000,
                sizes=(512, 1024, 2048), wf_backend: str = "pallas",
                world=None) -> list[dict]:
    """reads/s across chunk sizes, streamed vs synchronous, with the sync
    run's per-stage wall-time breakdown."""
    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=2)
    usable = [s for s in sizes if s < R]
    if len(usable) < len(sizes):
        print(f"chunk-sweep: dropping sizes >= R={R} "
              f"({sorted(set(sizes) - set(usable))}): a single-chunk run "
              f"has nothing to double-buffer")
    out = []
    for chunk in usable:
        row = {"chunk_reads": chunk}
        for stream in (False, True):
            cfg = MapperConfig(engine="compacted", wf_backend=wf_backend,
                               chunk_reads=chunk, stream=stream)
            res, dt = _timed_map(idx, rs.reads, cfg)
            key = "stream" if stream else "sync"
            row[f"{key}_reads_per_s"] = round(R / dt, 1)
            row[f"{key}_wall_s"] = round(dt, 4)
            if not stream:  # rounded for display; stats keep full precision
                row["stage_times_s"] = {
                    k: round(v, 4)
                    for k, v in res.stats["stage_times_s"].items()}
        row["stream_speedup"] = round(row["sync_wall_s"]
                                      / row["stream_wall_s"], 2)
        out.append(row)
    return out


def rows():
    world = _make_world(30_000)
    bench = bench_pipeline(R=128, chunk_reads=None, include_pallas=False,
                           world=world)
    pad = bench["engines"]["padded_jnp"]
    cmp_ = bench["engines"]["compacted_jnp"]

    # full-system simulation: reads/PLs per minimizer -> Eq. 6 iteration
    # counts -> DP-memory execution time at DART-PIM scale
    freqs = minimizer_frequencies(world[1])
    # synthetic read load per minimizer proportional to its PL count
    read_load = freqs * 128.0 / max(freqs.sum(), 1)
    k_l, k_a, j_l, j_a = cm.full_system_simulation(read_load * 1000, freqs)
    t_dp = (k_l * cm.linear_wf_cycles()["total_cycles"]
            + k_a * cm.affine_wf_cycles()["total_cycles"]) * cm.T_CLK
    return [
        ("pipeline_padded_cpu_128reads_ms", round(pad["wall_s"] * 1e3, 1),
         f"{pad['reads_per_s']:.0f} reads/s CPU-jnp; "
         f"mapped={pad['mapped_frac']:.3f}"),
        ("pipeline_compacted_cpu_128reads_ms", round(cmp_["wall_s"] * 1e3, 1),
         f"speedup={cmp_['speedup_vs_padded']}x; "
         f"affine {cmp_['affine_dist_instances']} of "
         f"{cmp_['padded_affine_instances']} padded; "
         f"pruning={cmp_['pruning_ratio']:.3f}"),
        ("fullsys_eq6_dpmem_s", round(t_dp, 4),
         f"K_L={k_l:.0f} K_A={k_a:.0f} J_L={j_l:.3g} J_A={j_a:.3g}"),
    ]


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--chunk-sweep", action="store_true",
                    help="sweep chunk sizes: streamed vs sync reads/s + "
                         "per-stage wall times")
    ap.add_argument("--reads", type=int, default=4096)
    ap.add_argument("--genome", type=int, default=30_000)
    ap.add_argument("--sizes", type=int, nargs="+", default=[512, 1024, 2048])
    ap.add_argument("--wf-backend", default="pallas",
                    choices=("jnp", "pallas"))
    args = ap.parse_args()
    if not args.chunk_sweep:
        ap.error("use benchmarks/run.py for the CSV/JSON harness; this "
                 "entry point only serves --chunk-sweep")
    for row in chunk_sweep(R=args.reads, genome=args.genome,
                           sizes=tuple(args.sizes),
                           wf_backend=args.wf_backend):
        st = row.pop("stage_times_s")
        breakdown = " ".join(f"{k}={v:.3f}" for k, v in st.items())
        print(f"chunk={row['chunk_reads']:>5}: "
              f"sync={row['sync_reads_per_s']:>8.1f} r/s "
              f"stream={row['stream_reads_per_s']:>8.1f} r/s "
              f"speedup={row['stream_speedup']:.2f}x\n"
              f"             sync stages [s]: {breakdown}")


if __name__ == "__main__":
    main()
