"""End-to-end mapping pipeline wall time on CPU (jnp path) + full-system
iteration counts feeding Eq. 6 (the full-system-simulator analog)."""
import time

import numpy as np

from repro.core import costmodel as cm
from repro.core.index import build_index, minimizer_frequencies
from repro.core.pipeline import map_reads
from repro.data.genome import make_reference, sample_reads


def rows():
    ref = make_reference(30_000, seed=0, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 128, seed=2)
    map_reads(idx, rs.reads)  # compile
    t0 = time.perf_counter()
    res = map_reads(idx, rs.reads)
    dt = time.perf_counter() - t0

    # full-system simulation: reads/PLs per minimizer -> Eq. 6 iteration
    # counts -> DP-memory execution time at DART-PIM scale
    freqs = minimizer_frequencies(idx)
    # synthetic read load per minimizer proportional to its PL count
    read_load = freqs * float(len(rs.reads)) / max(freqs.sum(), 1)
    k_l, k_a, j_l, j_a = cm.full_system_simulation(read_load * 1000, freqs)
    t_dp = (k_l * cm.linear_wf_cycles()["total_cycles"]
            + k_a * cm.affine_wf_cycles()["total_cycles"]) * cm.T_CLK
    return [
        ("pipeline_cpu_128reads_ms", round(dt * 1e3, 1),
         f"{len(rs.reads)/dt:.0f} reads/s CPU-jnp; "
         f"mapped={res.mapped.mean():.3f}"),
        ("fullsys_eq6_dpmem_s", round(t_dp, 4),
         f"K_L={k_l:.0f} K_A={k_a:.0f} J_L={j_l:.3g} J_A={j_a:.3g}"),
    ]
