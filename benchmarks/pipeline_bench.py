"""End-to-end mapping pipeline wall time on CPU: padded reference vs the
candidate-compacted engine (jnp and Pallas backends), plus full-system
iteration counts feeding Eq. 6 (the full-system-simulator analog).

``bench_pipeline`` is the machine-readable entry (``benchmarks/run.py
--pipeline-json`` writes its output to BENCH_pipeline.json); ``rows`` keeps
the CSV harness fast with a smaller read batch.
"""
import time

from repro.core import costmodel as cm
from repro.core.index import build_index, minimizer_frequencies
from repro.core.pipeline import MapperConfig, map_reads
from repro.data.genome import make_reference, sample_reads


def _timed_map(idx, reads, cfg, iters=1):
    map_reads(idx, reads, cfg)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        res = map_reads(idx, reads, cfg)
    dt = (time.perf_counter() - t0) / iters
    return res, dt


def _make_world(genome: int):
    ref = make_reference(genome, seed=0, repeat_frac=0.03)
    return ref, build_index(ref)


def bench_pipeline(R: int = 1024, genome: int = 30_000,
                   include_pallas: bool = True, world=None) -> dict:
    """Compare the execution engines at batch size R.  Returns a dict with
    per-engine wall time / per-read time, the measured candidate-pruning
    ratio, and the affine instance counts (padded vs compacted)."""
    ref, idx = world or _make_world(genome)
    rs = sample_reads(ref, R, seed=2)

    engines = {
        "padded_jnp": MapperConfig(engine="padded", wf_backend="jnp"),
        "compacted_jnp": MapperConfig(engine="compacted", wf_backend="jnp"),
    }
    if include_pallas:
        engines["compacted_pallas"] = MapperConfig(engine="compacted",
                                                   wf_backend="pallas")

    out = {"R": R, "genome": genome, "engines": {}}
    baseline = None
    for name, cfg in engines.items():
        try:
            res, dt = _timed_map(idx, rs.reads, cfg)
        except Exception as e:  # noqa: BLE001 — report, keep the others
            out["engines"][name] = {"error": f"{type(e).__name__}: {e}"}
            continue
        entry = {
            "wall_s": round(dt, 4),
            "per_read_us": round(dt / R * 1e6, 2),
            "reads_per_s": round(R / dt, 1),
            "mapped_frac": round(float(res.mapped.mean()), 4),
        }
        if name == "padded_jnp":
            baseline, base_dt = res, dt
            entry["speedup_vs_padded"] = 1.0
        elif baseline is not None:  # only meaningful vs a live padded run
            entry["speedup_vs_padded"] = round(base_dt / dt, 2)
            entry["matches_padded"] = bool(
                (res.position == baseline.position).all()
                and (res.distance == baseline.distance).all())
        if res.stats:
            entry.update(res.stats)
        out["engines"][name] = entry
    return out


def rows():
    world = _make_world(30_000)
    bench = bench_pipeline(R=128, include_pallas=False, world=world)
    pad = bench["engines"]["padded_jnp"]
    cmp_ = bench["engines"]["compacted_jnp"]

    # full-system simulation: reads/PLs per minimizer -> Eq. 6 iteration
    # counts -> DP-memory execution time at DART-PIM scale
    freqs = minimizer_frequencies(world[1])
    # synthetic read load per minimizer proportional to its PL count
    read_load = freqs * 128.0 / max(freqs.sum(), 1)
    k_l, k_a, j_l, j_a = cm.full_system_simulation(read_load * 1000, freqs)
    t_dp = (k_l * cm.linear_wf_cycles()["total_cycles"]
            + k_a * cm.affine_wf_cycles()["total_cycles"]) * cm.T_CLK
    return [
        ("pipeline_padded_cpu_128reads_ms", round(pad["wall_s"] * 1e3, 1),
         f"{pad['reads_per_s']:.0f} reads/s CPU-jnp; "
         f"mapped={pad['mapped_frac']:.3f}"),
        ("pipeline_compacted_cpu_128reads_ms", round(cmp_["wall_s"] * 1e3, 1),
         f"speedup={cmp_['speedup_vs_padded']}x; "
         f"affine {cmp_['affine_dist_instances']} of "
         f"{cmp_['padded_affine_instances']} padded; "
         f"pruning={cmp_['pruning_ratio']:.3f}"),
        ("fullsys_eq6_dpmem_s", round(t_dp, 4),
         f"K_L={k_l:.0f} K_A={k_a:.0f} J_L={j_l:.3g} J_A={j_a:.3g}"),
    ]
