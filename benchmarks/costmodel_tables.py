"""Paper Table I / Table IV / Sec. IV-B reproduction (analytic cost model)."""
from repro.core import costmodel as cm


def rows():
    lin = cm.linear_wf_cycles()
    aff = cm.affine_wf_cycles()
    out = [
        ("tableIV_linear_magic_cycles", lin["magic_cycles"], 254_585),
        ("tableIV_linear_total_cycles", lin["total_cycles"], 258_620),
        ("tableIV_linear_energy_nJ", round(lin["energy_J"] * 1e9, 2), 45.9),
        ("tableIV_affine_total_cycles", aff["total_cycles"], 1_308_699),
        ("tableIV_affine_energy_nJ", round(aff["energy_J"] * 1e9, 1), 229),
        ("alg1_ops_per_cell_b3", cm.linear_wf_cell_ops_closed(3), 130),
        ("secIVB_sw_vs_wf_latency", round(cm.sw_vs_wf_latency_ratio(), 2),
         2.8),
    ]
    return [(name, value, f"paper={ref}") for name, value, ref in out]
