"""Genomics-kernel roofline: the paper-faithful WF pipeline on TPU v5e.

This is the §Perf track for the paper's own technique.  All numbers are
derived from the kernel definitions (ops/bytes per instance — exact, the
kernels are ours) against v5e VPU/HBM ceilings, with the DART-PIM cost
model (Table IV) as the hardware-baseline comparison.

Per linear-WF instance (rl=150, eth=6, band=13):
  int8 VPU ops : 150 rows x [band compare/min/add chain ~ 6 vector ops
                 + 13-step unrolled left-scan x 2 ops] ~= 150 x 32 lane-ops
  HBM traffic  : read (150 + 162) B + write 8 B  (band lives in VMEM)
Arithmetic intensity ~ 15 ops/byte -> VPU-bound, not HBM-bound.
"""
from repro.core import costmodel as cm

# v5e: 8 MXU-independent VPU lanes x 128 x ~940 MHz x 4 int8 ALUs (approx.)
VPU_INT8_OPS = 49e12
HBM_BW = 819e9

RL, ETH = 150, 6
BAND = 2 * ETH + 1


def linear_instance_cost():
    ops = RL * (6 * BAND + 2 * BAND)      # vector ops across the band
    bytes_ = RL + (RL + 2 * ETH) + 8
    return ops, bytes_


def affine_instance_cost():
    # three matrices + direction emission; dirs written to HBM
    ops = RL * (16 * BAND + 4 * BAND)
    bytes_ = RL + (RL + 2 * ETH) + RL * BAND + 8
    return ops, bytes_


def rows():
    out = []
    lo, lb = linear_instance_cost()
    ao, ab = affine_instance_cost()
    t_lin = max(lo / VPU_INT8_OPS, lb / HBM_BW)
    t_aff = max(ao / VPU_INT8_OPS, ab / HBM_BW)
    # DART-PIM: one instance = 258,620 cycles x 2ns, but 8M crossbars deep
    dp_lin = cm.linear_wf_cycles()["total_cycles"] * cm.T_CLK
    out.append(("linear_wf_tpu_inst_ns", round(t_lin * 1e9, 2),
                f"VPU-bound ({lo} ops; {lb} B); DART-PIM xbar-row "
                f"{dp_lin*1e6:.0f}us but 8M-way parallel"))
    out.append(("affine_wf_tpu_inst_ns", round(t_aff * 1e9, 2),
                f"{ao} ops; dirs write {RL*BAND}B dominates bytes"))
    # chip-level throughput: instances/s/chip at VPU roofline
    out.append(("linear_wf_inst_per_s_per_chip", f"{1/t_lin:.3g}",
                "x256 chips/pod"))
    # end-to-end: paper workload (389M reads x 930 PLs) on one v5e pod
    insts = 389e6 * cm.AVG_PLS_PER_READ
    pod_s = insts * t_lin / 256 + 389e6 * cm.AVG_MINIS_PER_READ * t_aff / 256
    dart = cm.dart_pim_system(max_reads=25e3).exec_time_s
    out.append(("pod_v5e_endtoend_s", round(pod_s, 1),
                f"DART-PIM 25k={dart:.1f}s -> v5e pod {dart/pod_s:.1f}x "
                "faster at equal accuracy (collective seeding excluded; "
                "see EXPERIMENTS.md)"))
    return out
