"""Fig. 8 / Sec. VII-A analog — mapping accuracy vs. capacity caps.

Synthetic genome with repeats + Illumina-like errors; ground truth attached
by the simulator.  The maxReads trade-off is exercised through the
distributed mapper's send-buffer capacity (the Reads-FIFO stand-in).
"""
import numpy as np

from repro.core.index import build_index
from repro.core.mapper import Mapper
from repro.data.genome import make_reference, sample_reads


def rows():
    ref = make_reference(30_000, seed=0, repeat_frac=0.03)
    idx = build_index(ref)
    mapper = Mapper(idx)
    out = []
    for sub in (0.0, 0.002, 0.01):
        rs = sample_reads(ref, 96, sub_rate=sub, ins_rate=sub / 4,
                          del_rate=sub / 4, seed=11)
        res = mapper.map(rs.reads)
        exact = float((res.position == rs.true_pos).mean())
        close = float((np.abs(res.position - rs.true_pos) <= 6).mean())
        out.append((f"accuracy_sub{sub}", round(close, 4),
                    f"exact={exact:.4f} mapped={res.mapped.mean():.3f} "
                    "(paper: 99.7-99.8% vs BWA-MEM)"))
    # capacity cap accuracy trade (maxReads analog): cap PLs per minimizer
    for cap in (4, 32):
        idx_c = build_index(ref, max_pls_per_minimizer=cap)
        rs = sample_reads(ref, 96, seed=11)
        res = Mapper(idx_c).map(rs.reads)
        close = float((np.abs(res.position - rs.true_pos) <= 6).mean())
        out.append((f"accuracy_plcap{cap}", round(close, 4),
                    "capacity/accuracy trade (paper Fig. 8)"))

    # dual-strand accuracy (real read sets are ~50% reverse-strand):
    # correctness requires position AND strand to match ground truth.
    # The forward-only mapper on the same set shows what the pipeline
    # lost before strand-awareness existed.
    from repro.core.pipeline import MapperConfig
    rs_f = sample_reads(ref, 96, seed=11)
    base_close = float((np.abs(mapper.map(rs_f.reads).position
                               - rs_f.true_pos) <= 6).mean())
    rs_b = sample_reads(ref, 96, seed=11, both_strands=True)
    cfg_b = MapperConfig.from_index(idx, both_strands=True)
    mapper_b = Mapper(idx, cfg_b)  # reused by the paired row below
    res_b = mapper_b.map(rs_b.reads)
    dual_close = float(((np.abs(res_b.position - rs_b.true_pos) <= 6)
                        & (res_b.strand == rs_b.strand)).mean())
    fwd_on_dual = float((np.abs(mapper.map(rs_b.reads).position
                                - rs_b.true_pos) <= 6).mean())
    out.append(("accuracy_dualstrand_strand_aware", round(dual_close, 4),
                f"fwd-only baseline on fwd set={base_close:.4f}; fwd-only "
                f"on this {rs_b.strand.mean():.0%}-reverse set="
                f"{fwd_on_dual:.4f} (position AND strand must match)"))

    # paired-end accuracy: both mates' position AND strand AND the
    # proper-pair call must match ground truth (the concordance metric
    # mappers are judged on — Alser et al.; single-mate position accuracy
    # shown alongside for the gap pairing closes)
    from repro.core.pairing import resolve_pairs
    from repro.data.genome import sample_pairs
    pp = sample_pairs(ref, 96, seed=11)
    pres1, pres2 = mapper_b.map_pairs(pp.reads1, pp.reads2)
    pr = resolve_pairs(pres1, pres2, cfg=cfg_b, ref=ref,
                       reads1=pp.reads1, reads2=pp.reads2)
    pair_ok = float((((np.abs(pr.res1.position - pp.pos1) <= 6)
                      & (np.abs(pr.res2.position - pp.pos2) <= 6)
                      & (pr.res1.strand == pp.strand1)
                      & (pr.res2.strand == pp.strand2)
                      & pr.proper)).mean())
    mate_ok = float(np.concatenate(
        [(np.abs(pr.res1.position - pp.pos1) <= 6),
         (np.abs(pr.res2.position - pp.pos2) <= 6)]).mean())
    out.append(("accuracy_paired_proper", round(pair_ok, 4),
                f"pos+strand+proper both mates; per-mate pos acc="
                f"{mate_ok:.4f}; proper={pr.stats['n_proper']}/96 "
                f"rescued={pr.stats['n_rescued']} insert_median="
                f"{pr.stats['insert_median']}"))

    # filter elimination rates: linear WF (paper's mechanism) vs base-count
    # (the cited baseline; paper: ~68% eliminated)
    rs = sample_reads(ref, 96, seed=11)
    res = mapper.map(rs.reads)
    sat = 7
    valid = res.linear_dist < 10 ** 9
    n_valid = int((res.linear_dist <= sat).sum())  # all seeded candidates
    n_pass = int((res.linear_dist <= 6).sum())
    out.append(("linearWF_filter_elimination", round(1 - n_pass / max(
        n_valid, 1), 4), "fraction of PLs discarded (paper base-count ~68%)"))

    # lowTh split (paper Sec. V-A: rare minimizers -> RISC-V/residual batch)
    from repro.core.index import low_th_split
    s = low_th_split(idx, low_th=3)
    out.append(("lowth_rare_minimizer_frac",
                round(s["rare_minimizer_fraction"], 4),
                f"rare PL work fraction={s['rare_pl_fraction']:.4f} "
                "(paper: 0.16% of affine instances on RISC-V)"))
    out.extend(accuracy_comparison_rows())
    return out


def accuracy_comparison_rows():
    """Fig. 8 comparison points (reported accuracies from the paper)."""
    from repro.core.costmodel import ACCURACY
    return [(f"paper_accuracy_{k}", v, "Sec. VII-A") for k, v in
            ACCURACY.items()]
