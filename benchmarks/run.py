"""Benchmark harness: one module per paper table/figure.

Default mode prints ``name,value,derived`` CSV (value is us_per_call for
timed rows, the modelled/papers' metric otherwise).

``--pipeline-json [PATH]`` instead runs the end-to-end engine comparison
(padded reference vs candidate-compacted, jnp vs Pallas backends,
synchronous vs streamed chunk execution) at ``--reads`` / ``--chunk-reads``
and writes the result to PATH (default BENCH_pipeline.json), so the perf
trajectory is tracked across PRs.  ``--check-against BASELINE.json`` then
compares the fresh run to a committed baseline and exits non-zero when
the streamed Pallas engine's reads/s regressed more than ``--tolerance``
(the CI perf-trend gate).
"""
import argparse
import json
import sys
import time

REGRESSION_ENGINE = "compacted_pallas"
REGRESSION_METRIC = "reads_per_s"
# synchronous runs carry per-stage wall times; each stage is gated
# independently so a regression hiding inside an improved total still fails
STAGE_ENGINES = ("compacted_pallas_sync", "fused_pallas_sync")
STAGE_NOISE_FLOOR_S = 0.005  # sub-5ms stages are runner noise, not signal
# armed-but-idle fault-tolerance tax ceiling: the resilience stack
# (injector in the fetch thread + watchdog + retry wrapper) may cost at
# most this fraction of the plain streamed engine's reads/s.  The metric
# is self-relative (armed vs plain in the *same* fresh run), so it needs
# no hardware-variance tolerance on top.
RESILIENCE_OVERHEAD_MAX = 0.05
# armed-but-idle observability tax ceiling: a live metrics registry +
# span tracer may cost at most this fraction of the disarmed streamed
# engine's reads/s.  Self-relative like the resilience gate.
OBS_OVERHEAD_MAX = 0.05


def emit_pipeline_json(path: str, reads: int, chunk_reads: int | None,
                       include_padded: bool) -> dict:
    from benchmarks.pipeline_bench import bench_pipeline
    bench = bench_pipeline(R=reads, chunk_reads=chunk_reads,
                           include_padded=include_padded)
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    for name, e in bench["engines"].items():
        if "error" in e:
            print(f"{name}: ERROR {e['error']}")
        else:
            extra = ""
            if "survivors" in e:
                extra = (f" affine={e['affine_dist_instances']}"
                         f"/{e['padded_affine_instances']}padded"
                         f" survivors={e['survivors']}"
                         f" pruning={e['pruning_ratio']:.3f}")
            if "speedup_vs_sync" in e:
                extra += f" stream_speedup={e['speedup_vs_sync']}x"
            print(f"{name}: {e['wall_s']:.3f}s "
                  f"{e['per_read_us']:.1f}us/read "
                  f"speedup={e.get('speedup_vs_padded', 1.0)}x{extra}")
    fp = bench.get("fastq_path")
    if fp:
        print(f"fastq_path (dual-strand): "
              f"{fp['fastq_sam_reads_per_s']:.1f} reads/s through "
              f"FASTQ->SAM vs {fp['in_memory_reads_per_s']:.1f} in-memory "
              f"(I/O overhead {fp['io_overhead_frac']:.1%})")
    pp = bench.get("paired_path")
    if pp:
        print(f"paired_path (gzip R1/R2 -> paired SAM): "
              f"{pp['reads_per_s']:.1f} reads/s "
              f"({pp['pairs_per_s']:.1f} pairs/s, proper "
              f"{pp['proper_frac']:.1%}, {pp['rescued']} rescued)")
    ib = bench.get("index_build")
    if ib:
        if "error" in ib:
            print(f"index_build: ERROR {ib['error']}")
        else:
            print(f"index_build (out-of-core sharded build -> mmap "
                  f"reload -> routed mapping): "
                  f"{ib['build_bases_per_s']:.0f} bases/s build "
                  f"({ib.get('spill_bytes', 0)} spill B), "
                  f"{ib['reload_ms']:.1f}ms reload, "
                  f"{ib['routed_reads_per_s']:.1f} routed vs "
                  f"{ib['flat_reads_per_s']:.1f} flat reads/s "
                  f"({ib['routed_overhead_frac']:.1%} overhead)")
            pf = ib.get("routed_prefetch_reads_per_s")
            if pf is not None:
                print(f"index_build prefetch: {pf:.1f} prefetch-on vs "
                      f"{ib['routed_reads_per_s']:.1f} prefetch-off "
                      f"routed reads/s "
                      f"({ib.get('prefetch_overhead_frac', 0):.1%} vs "
                      f"flat)")
    ro = bench.get("resilience_overhead")
    if ro:
        if "error" in ro:
            print(f"resilience_overhead: ERROR {ro['error']}")
        else:
            print(f"resilience_overhead (armed-but-idle injector + "
                  f"watchdog + retry wrapper): "
                  f"{ro['armed_reads_per_s']:.1f} vs "
                  f"{ro['plain_reads_per_s']:.1f} plain reads/s "
                  f"({ro['overhead_frac']:.1%} overhead)")
    oo = bench.get("obs_overhead")
    if oo:
        if "error" in oo:
            print(f"obs_overhead: ERROR {oo['error']}")
        else:
            print(f"obs_overhead (armed-but-idle metrics registry + "
                  f"span tracer): {oo['armed_reads_per_s']:.1f} vs "
                  f"{oo['plain_reads_per_s']:.1f} plain reads/s "
                  f"({oo['overhead_frac']:.1%} overhead, "
                  f"{oo['spans_recorded']} spans, "
                  f"{oo['counter_series']} counter series)")
    print(f"wrote {path}")
    return bench


def _gate_metric(name: str, fresh_val, base_val, tolerance: float,
                 missing_reason: str | None = None) -> int:
    if fresh_val is None:
        why = f": {missing_reason}" if missing_reason else ""
        print(f"perf-trend: FAIL — fresh run has no {name}{why}")
        return 1
    floor = (1.0 - tolerance) * base_val
    verdict = "OK" if fresh_val >= floor else "FAIL"
    print(f"perf-trend: {verdict} — {name} "
          f"fresh={fresh_val:.1f} baseline={base_val:.1f} "
          f"floor={floor:.1f} (tolerance {tolerance:.0%})")
    return 0 if fresh_val >= floor else 1


def _gate_stages(fresh: dict, base: dict, engine: str,
                 tolerance: float) -> int:
    """Per-stage gate: any stage of ``engine``'s synchronous breakdown
    that takes > (1 + tolerance) x its baseline wall time fails, even
    when the total improved — that is what catches a stage-level
    regression smuggled in under a bigger win elsewhere."""
    bst = base.get("engines", {}).get(engine, {}).get("stage_times_s")
    if not bst:
        print(f"perf-trend: baseline lacks {engine}.stage_times_s; "
              f"skipping per-stage check")
        return 0
    fe = fresh.get("engines", {}).get(engine, {})
    fst = fe.get("stage_times_s")
    if not fst:
        why = fe.get("error", "engine missing from fresh run")
        print(f"perf-trend: FAIL — fresh run has no "
              f"{engine}.stage_times_s ({why})")
        return 1
    rc = 0
    for stage, bval in sorted(bst.items()):
        fval = fst.get(stage)
        if fval is None or bval < STAGE_NOISE_FLOOR_S:
            continue
        ceil = (1.0 + tolerance) * bval
        verdict = "OK" if fval <= ceil else "FAIL"
        print(f"perf-trend: {verdict} — {engine}.{stage} "
              f"fresh={fval:.4f}s baseline={bval:.4f}s "
              f"ceiling={ceil:.4f}s (tolerance {tolerance:.0%})")
        rc |= fval > ceil
    return rc


def check_regression(fresh: dict, baseline_path: str, tolerance: float,
                     stage_tolerance: float = 0.25) -> int:
    """Non-zero when the streamed Pallas engine — or the paired-end
    path's reads/s — regressed > tolerance vs the committed baseline,
    or any synchronous per-stage wall time grew > stage_tolerance
    (the CI perf-trend gate).  Metrics the baseline lacks are skipped,
    so the gate never blocks the PR that introduces a new section."""
    with open(baseline_path) as f:
        base = json.load(f)
    rc = 0
    try:
        b = base["engines"][REGRESSION_ENGINE][REGRESSION_METRIC]
    except KeyError:
        print(f"perf-trend: baseline {baseline_path} lacks "
              f"{REGRESSION_ENGINE}.{REGRESSION_METRIC}; skipping check")
        b = None
    if b is not None:
        e = fresh["engines"].get(REGRESSION_ENGINE, {})
        fresh_val = (None if "error" in e else e.get(REGRESSION_METRIC))
        rc |= _gate_metric(f"{REGRESSION_ENGINE}.{REGRESSION_METRIC}",
                           fresh_val, b, tolerance,
                           missing_reason=e.get("error"))
    bp = base.get("paired_path", {}).get("reads_per_s")
    if bp is None:
        print(f"perf-trend: baseline {baseline_path} lacks "
              f"paired_path.reads_per_s; skipping check")
    else:
        rc |= _gate_metric("paired_path.reads_per_s",
                           fresh.get("paired_path", {}).get("reads_per_s"),
                           bp, tolerance)
    ro = fresh.get("resilience_overhead")
    if base.get("resilience_overhead") is None:
        print(f"perf-trend: baseline {baseline_path} lacks "
              f"resilience_overhead; skipping check")
    elif ro is None or "error" in (ro or {}):
        why = (ro or {}).get("error", "section missing from fresh run")
        print(f"perf-trend: FAIL — fresh run has no resilience_overhead "
              f"({why})")
        rc |= 1
    else:
        of = ro["overhead_frac"]
        verdict = "OK" if of <= RESILIENCE_OVERHEAD_MAX else "FAIL"
        print(f"perf-trend: {verdict} — resilience_overhead "
              f"armed={ro['armed_reads_per_s']:.1f} "
              f"plain={ro['plain_reads_per_s']:.1f} reads/s "
              f"overhead={of:.1%} "
              f"(ceiling {RESILIENCE_OVERHEAD_MAX:.0%})")
        rc |= of > RESILIENCE_OVERHEAD_MAX
    oo = fresh.get("obs_overhead")
    if base.get("obs_overhead") is None:
        print(f"perf-trend: baseline {baseline_path} lacks "
              f"obs_overhead; skipping check")
    elif oo is None or "error" in (oo or {}):
        why = (oo or {}).get("error", "section missing from fresh run")
        print(f"perf-trend: FAIL — fresh run has no obs_overhead ({why})")
        rc |= 1
    else:
        of = oo["overhead_frac"]
        verdict = "OK" if of <= OBS_OVERHEAD_MAX else "FAIL"
        print(f"perf-trend: {verdict} — obs_overhead "
              f"armed={oo['armed_reads_per_s']:.1f} "
              f"plain={oo['plain_reads_per_s']:.1f} reads/s "
              f"overhead={of:.1%} (ceiling {OBS_OVERHEAD_MAX:.0%})")
        rc |= of > OBS_OVERHEAD_MAX
    bi = base.get("index_build", {})
    if bi.get("build_bases_per_s") is None:
        print(f"perf-trend: baseline {baseline_path} lacks "
              f"index_build.build_bases_per_s; skipping check")
    else:
        fi = fresh.get("index_build") or {}
        fresh_val = (None if "error" in fi
                     else fi.get("build_bases_per_s"))
        rc |= _gate_metric("index_build.build_bases_per_s", fresh_val,
                           bi["build_bases_per_s"], tolerance,
                           missing_reason=fi.get("error"))
    # routed-mapping throughput, prefetch off and on — each skipped
    # until a baseline records it, so the introducing run stays green
    for key in ("routed_reads_per_s", "routed_prefetch_reads_per_s"):
        if bi.get(key) is None:
            print(f"perf-trend: baseline {baseline_path} lacks "
                  f"index_build.{key}; skipping check")
            continue
        fi = fresh.get("index_build") or {}
        fresh_val = None if "error" in fi else fi.get(key)
        rc |= _gate_metric(f"index_build.{key}", fresh_val, bi[key],
                           tolerance, missing_reason=fi.get("error"))
    for engine in STAGE_ENGINES:
        rc |= _gate_stages(fresh, base, engine, stage_tolerance)
    return rc


def run_csv() -> None:
    from benchmarks import (accuracy, area, costmodel_tables, energy,
                            pipeline_bench, roofline_report, throughput,
                            wf_kernel_bench, wf_roofline)
    modules = [
        ("costmodel_tables", costmodel_tables),
        ("throughput", throughput),
        ("energy", energy),
        ("area", area),
        ("accuracy", accuracy),
        ("wf_kernel_bench", wf_kernel_bench),
        ("wf_roofline", wf_roofline),
        ("pipeline_bench", pipeline_bench),
        ("roofline", roofline_report),
    ]
    print("name,value,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for row in mod.rows():
                n, v, d = row
                print(f"{n},{v},{str(d).replace(',', ';')}")
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pipeline-json", nargs="?", const="BENCH_pipeline.json",
                    default=None, metavar="PATH",
                    help="write the end-to-end engine comparison JSON "
                         "instead of the CSV sweep")
    ap.add_argument("--reads", type=int, default=4096,
                    help="batch size for --pipeline-json (default 4096)")
    ap.add_argument("--chunk-reads", type=int, default=1024,
                    help="streaming chunk size (0 = unchunked; default 1024)")
    ap.add_argument("--no-padded", action="store_true",
                    help="skip the slow padded-jnp reference (CI perf job)")
    ap.add_argument("--check-against", metavar="BASELINE", default=None,
                    help="compare the fresh --pipeline-json run to this "
                         "baseline JSON; exit 1 on >tolerance regression")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed reads/s regression fraction (default .15)")
    ap.add_argument("--stage-tolerance", type=float, default=0.25,
                    help="allowed per-stage wall-time growth fraction for "
                         "the synchronous engines (default .25)")
    args = ap.parse_args()
    if args.check_against and not args.pipeline_json:
        ap.error("--check-against requires --pipeline-json (the gate "
                 "compares a fresh pipeline run)")
    if args.pipeline_json:
        bench = emit_pipeline_json(args.pipeline_json, args.reads,
                                   args.chunk_reads or None,
                                   include_padded=not args.no_padded)
        if args.check_against:
            raise SystemExit(check_regression(bench, args.check_against,
                                              args.tolerance,
                                              args.stage_tolerance))
    else:
        run_csv()


if __name__ == "__main__":
    main()
