"""Benchmark harness: one module per paper table/figure.

Default mode prints ``name,value,derived`` CSV (value is us_per_call for
timed rows, the modelled/papers' metric otherwise).

``--pipeline-json [PATH]`` instead runs the end-to-end engine comparison
(padded reference vs candidate-compacted, jnp vs Pallas backends) at
R=1024 and writes the result to PATH (default BENCH_pipeline.json), so the
perf trajectory is tracked across PRs.
"""
import argparse
import json
import sys
import time


def emit_pipeline_json(path: str, reads: int) -> None:
    from benchmarks.pipeline_bench import bench_pipeline
    bench = bench_pipeline(R=reads)
    with open(path, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
        f.write("\n")
    for name, e in bench["engines"].items():
        if "error" in e:
            print(f"{name}: ERROR {e['error']}")
        else:
            extra = ""
            if "survivors" in e:
                extra = (f" affine={e['affine_dist_instances']}"
                         f"/{e['padded_affine_instances']}padded"
                         f" survivors={e['survivors']}"
                         f" pruning={e['pruning_ratio']:.3f}")
            print(f"{name}: {e['wall_s']:.3f}s "
                  f"{e['per_read_us']:.1f}us/read "
                  f"speedup={e.get('speedup_vs_padded', 1.0)}x{extra}")
    print(f"wrote {path}")


def run_csv() -> None:
    from benchmarks import (accuracy, area, costmodel_tables, energy,
                            pipeline_bench, roofline_report, throughput,
                            wf_kernel_bench, wf_roofline)
    modules = [
        ("costmodel_tables", costmodel_tables),
        ("throughput", throughput),
        ("energy", energy),
        ("area", area),
        ("accuracy", accuracy),
        ("wf_kernel_bench", wf_kernel_bench),
        ("wf_roofline", wf_roofline),
        ("pipeline_bench", pipeline_bench),
        ("roofline", roofline_report),
    ]
    print("name,value,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for row in mod.rows():
                n, v, d = row
                print(f"{n},{v},{str(d).replace(',', ';')}")
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pipeline-json", nargs="?", const="BENCH_pipeline.json",
                    default=None, metavar="PATH",
                    help="write the end-to-end engine comparison JSON "
                         "instead of the CSV sweep")
    ap.add_argument("--reads", type=int, default=1024,
                    help="batch size for --pipeline-json (default 1024)")
    args = ap.parse_args()
    if args.pipeline_json:
        emit_pipeline_json(args.pipeline_json, args.reads)
    else:
        run_csv()


if __name__ == "__main__":
    main()
