"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV (value is us_per_call for timed rows,
the modelled/papers' metric otherwise).
"""
import sys
import time


def main() -> None:
    from benchmarks import (accuracy, area, costmodel_tables, energy,
                            pipeline_bench, roofline_report, throughput,
                            wf_kernel_bench, wf_roofline)
    modules = [
        ("costmodel_tables", costmodel_tables),
        ("throughput", throughput),
        ("energy", energy),
        ("area", area),
        ("accuracy", accuracy),
        ("wf_kernel_bench", wf_kernel_bench),
        ("wf_roofline", wf_roofline),
        ("pipeline_bench", pipeline_bench),
        ("roofline", roofline_report),
    ]
    print("name,value,derived")
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for row in mod.rows():
                n, v, d = row
                print(f"{n},{v},{str(d).replace(',', ';')}")
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
