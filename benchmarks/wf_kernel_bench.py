"""WF kernel micro-benchmarks: measured CPU (jnp reference path) wall time +
derived TPU projections from the roofline byte/op model.

On this CPU container the Pallas kernels run in interpret mode (correctness
only), so wall-clock here times the pure-jnp batched reference; the
``derived`` column reports the TPU-side projection used in EXPERIMENTS.md
(int8 VPU ops at 4 ops/byte-lane, 197 TFLOP/s bf16 chip -> ~49 Tint8op/s
effective on the VPU 8x128 lanes).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affine_wf import banded_affine, banded_affine_dist
from repro.core.linear_wf import banded_wf
from repro.kernels import ops

VPU_INT8_OPS = 49e12  # conservative: 1/4 of bf16 MXU peak as scalar int8 VPU


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def rows():
    rng = np.random.default_rng(0)
    R, n, eth = 1024, 150, 6
    s1 = jnp.asarray(rng.integers(0, 4, (R, n)), jnp.uint8)
    s2 = jnp.asarray(rng.integers(0, 4, (R, n + 2 * eth)), jnp.uint8)

    t_lin = _time(jax.jit(lambda a, b: banded_wf(a, b, eth=eth)), s1, s2)
    t_aff = _time(jax.jit(lambda a, b: banded_affine(a, b, eth=eth, sat=32)),
                  s1, s2)
    t_affd = _time(
        jax.jit(lambda a, b: banded_affine_dist(a, b, eth=eth, sat=32)),
        s1, s2)
    # Pallas kernels (interpret mode on CPU: correctness-path timing only;
    # compiled-mode numbers require a TPU)
    t_plin = _time(lambda a, b: ops.linear_wf(a, b, eth=eth), s1, s2)
    t_paffd = _time(lambda a, b: ops.affine_wf_dist(a, b, eth=eth, sat=32),
                    s1, s2)

    # TPU projection: ops per instance ~= rows x band x ~12 int8 VPU ops
    ops_lin = n * (2 * eth + 1) * 12
    ops_aff = n * (2 * eth + 1) * 40
    tpu_lin_inst_s = ops_lin / VPU_INT8_OPS * 1.5  # 1.5x scheduling slack
    tpu_aff_inst_s = ops_aff / VPU_INT8_OPS * 1.5
    return [
        ("linear_wf_cpu_batch1024", round(t_lin * 1e6, 1),
         f"cpu_inst_us={t_lin/R*1e6:.2f}"),
        ("affine_wf_cpu_batch1024", round(t_aff * 1e6, 1),
         f"cpu_inst_us={t_aff/R*1e6:.2f}"),
        ("affine_wf_dist_cpu_batch1024", round(t_affd * 1e6, 1),
         f"cpu_inst_us={t_affd/R*1e6:.2f}; no direction planes"),
        ("linear_wf_pallas_interp_batch1024", round(t_plin * 1e6, 1),
         f"cpu_inst_us={t_plin/R*1e6:.2f}; interpret mode"),
        ("affine_wf_dist_pallas_interp_batch1024", round(t_paffd * 1e6, 1),
         f"cpu_inst_us={t_paffd/R*1e6:.2f}; interpret mode"),
        ("linear_wf_tpu_proj_inst_ns", round(tpu_lin_inst_s * 1e9, 2),
         f"~{1/tpu_lin_inst_s:.3g} inst/s/core (DART-PIM xbar: "
         "258620cyc*2ns=517us/inst, x8M xbars)"),
        ("affine_wf_tpu_proj_inst_ns", round(tpu_aff_inst_s * 1e9, 2),
         f"~{1/tpu_aff_inst_s:.3g} inst/s/core"),
    ]
