"""Fig. 9 (right) + Fig. 10c — area and area efficiency."""
from repro.core import costmodel as cm


def rows():
    out = [("dartpim_area_mm2", round(sum(cm.AREA_MM2.values()), 0),
            "paper=8170 (crossbars 96.9%)")]
    for comp, a in cm.AREA_MM2.items():
        out.append((f"area_{comp}_mm2", a, ""))
    for mr, tag in ((12.5e3, "12.5k"), (25e3, "25k"), (50e3, "50k")):
        est = cm.dart_pim_system(max_reads=mr)
        out.append((f"area_eff_{tag}", round(est.area_eff, 0),
                    "paper: 1086 (12.5k) .. 273 (50k) reads/mm^2/s"))
    return out
