"""Fig. 9 (left) — end-to-end throughput vs. the five baselines."""
from repro.core import costmodel as cm


def rows():
    out = []
    for mr, tag in ((12.5e3, "12.5k"), (25e3, "25k"), (50e3, "50k")):
        est = cm.dart_pim_system(max_reads=mr)
        out.append((f"dartpim_{tag}_exec_s", round(est.exec_time_s, 1),
                    f"throughput={est.throughput_reads_s:.3g}reads/s"))
    st = cm.speedup_table(25e3)
    for name, v in st.items():
        out.append((f"speedup_vs_{name}", round(v["speedup"], 1),
                    "paper: minimap2=227x parabricks=5.7x genasm=334x "
                    "segram=257x"))
    return out
