"""Build a persistent sharded genome index from a FASTA, out of core.

    PYTHONPATH=src python -m repro.launch.build_index ref.fa -o ref.idx \
        --partitions 8 --tile-bp 1048576
    PYTHONPATH=src python -m repro.launch.map_fastq --index-dir ref.idx \
        reads.fq -o out.sam

One pass over the FASTA in ``--tile-bp`` tiles (peak memory is bounded
by the tile, not the genome), partitioned by the crossbar rule
``hash32(kmer) % partitions``; the output directory holds a versioned
JSON manifest, per-partition memmap CSR files with 2-bit packed
segments, and the 2-bit packed reference — everything ``map_fastq
--index-dir`` needs, on both topologies (``--partitions`` must equal
the mesh device count for ``--topology mesh``).
"""
from __future__ import annotations

import argparse
import sys
import time


def run(args) -> int:
    from repro.index import build_sharded_index, verify_index
    from repro.launch.map_fastq import _metrics_snapshot
    from repro.obs import logjson
    from repro.obs import registry as _metrics
    from repro.obs import tracing as _tracing

    trace_out = getattr(args, "trace_out", None)
    metrics_out = getattr(args, "metrics_out", None)
    log_on = getattr(args, "log_json", False) and not logjson.enabled()
    metrics_on = metrics_out is not None and _metrics.ACTIVE is None
    tracing_on = trace_out is not None and _tracing.ACTIVE is None
    if log_on:
        logjson.enable("build_index")
    if metrics_on:
        _metrics.enable_metrics()
    if tracing_on:
        _tracing.enable_tracing()
    try:
        t0 = time.perf_counter()
        say = (lambda msg: logjson.say(f"build_index: {msg}",
                                       event="progress"))
        idx = build_sharded_index(
            args.reference, args.output, num_partitions=args.partitions,
            tile_bp=args.tile_bp, read_len=args.read_len, k=args.k,
            w=args.w, eth=args.eth, max_pls_per_minimizer=args.max_pls,
            overwrite=args.force, origin=args.origin, progress=say)
        if args.verify:
            verify_index(args.output)
            say("full integrity check passed")
        stor = idx.storage_bytes()
        bstats = (idx.manifest or {}).get("build", {})
        dt = time.perf_counter() - t0
        logjson.say(
            f"build_index: {args.output}: {idx.num_partitions} "
            f"partitions, {len(idx.contigs)} contig(s), {idx.ref_len} "
            f"bases, {idx.n_occurrences} occurrences, "
            f"{stor['total_bytes']} B on disk ({stor['blowup']:.1f}x "
            f"segment blowup), {bstats.get('spill_bytes', 0)} spill B "
            f"in {dt:.1f}s",
            event="done", partitions=idx.num_partitions,
            ref_len=idx.ref_len, occurrences=idx.n_occurrences,
            bytes_on_disk=stor["total_bytes"],
            spill_bytes=bstats.get("spill_bytes", 0), wall_s=round(dt, 3))
        return 0
    finally:
        if metrics_out is not None and _metrics.ACTIVE is not None:
            open(metrics_out, "w").close()
            _metrics_snapshot(metrics_out, seq=0)
        if trace_out is not None and _tracing.ACTIVE is not None:
            _tracing.ACTIVE.export(trace_out)
        if tracing_on:
            _tracing.disable_tracing()
        if metrics_on:
            _metrics.disable_metrics()
        if log_on:
            logjson.disable()


def main():
    ap = argparse.ArgumentParser(
        prog="repro.launch.build_index",
        description="Build a sharded on-disk genome index from a FASTA "
                    "(streamed; bounded memory).")
    ap.add_argument("reference", help="FASTA reference (multi-contig ok; "
                                      "N -> never-matching sentinel)")
    ap.add_argument("-o", "--output", required=True,
                    help="output index directory")
    ap.add_argument("--partitions", type=int, default=4,
                    help="partition count (power of two; use the mesh "
                         "device count for --topology mesh mapping)")
    ap.add_argument("--tile-bp", type=int, default=1 << 20,
                    help="scan tile size in bases — the peak-memory knob")
    ap.add_argument("--read-len", type=int, default=150,
                    help="read length the segment geometry is sized for")
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--w", type=int, default=30)
    ap.add_argument("--eth", type=int, default=6)
    ap.add_argument("--max-pls", type=int, default=256,
                    help="occurrence cap per hyper-repetitive minimizer")
    ap.add_argument("--origin", type=int, default=0,
                    help="global position of the reference's first base "
                         "(format v2): occurrence positions are recorded "
                         "at origin + offset, so multi-host builds can "
                         "split one coordinate space")
    ap.add_argument("--force", action="store_true",
                    help="rebuild over an existing index directory")
    ap.add_argument("--verify", action="store_true",
                    help="re-read and digest-check every file after the "
                         "build")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the build as Chrome trace-event JSON "
                         "(scan + per-partition finalize spans)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a final JSONL metrics snapshot (schema: "
                         "schemas/metrics_snapshot.schema.json)")
    ap.add_argument("--log-json", action="store_true",
                    help="structured one-object-per-line JSON progress "
                         "on stderr")
    return run(ap.parse_args())


if __name__ == "__main__":
    raise SystemExit(main())
