# Launchers: mesh construction, multi-pod dry-run, train/serve drivers,
# and the FASTA+FASTQ -> SAM end-to-end mapper (map_fastq).
