"""Roofline terms per (arch x shape x mesh) cell.

Hardware model (TPU v5e targets, per chip):
  peak bf16        197 TFLOP/s
  HBM bandwidth    819 GB/s
  ICI link         ~50 GB/s/link

Methodology.  XLA's ``cost_analysis`` on the compiled module counts every
while-loop body ONCE (verified experimentally — scan trip counts are not
multiplied), so the compiled counts are per-layer/per-chunk lower bounds,
not per-step totals.  The roofline therefore combines:
  * an exact analytic matmul/op count derived from the model definitions
    (we own every einsum — the formulas are exact, and they are VALIDATED
    against cost_analysis on configs whose loops are fully unrolled, see
    tests/test_roofline.py);
  * compiled-artifact facts that are loop-independent: per-device buffer
    sizes (memory_analysis) and the collective schedule (op kinds/shapes
    parsed from the post-SPMD HLO), scaled by the known trip counts.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

BYTES_BF16 = 2
BYTES_F32 = 4


# --------------------------------------------------------------- FLOPs model
def _attn_proj_flops(cfg):
    """Per token: q/k/v/o projections (2*m*n*k per matmul)."""
    d, hd = cfg.d_model, cfg.head_dim
    return 2 * d * hd * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)


def _attn_score_flops(cfg, s_ctx):
    """Per token, attending over s_ctx keys: QK^T + PV."""
    return 2 * 2 * cfg.n_heads * cfg.head_dim * s_ctx


def _mlp_flops(cfg):
    return 2 * 3 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg, capacity_factor=1.25):
    """Per token: router + top_k experts (x capacity padding)."""
    router = 2 * cfg.d_model * cfg.n_experts
    experts = 2 * 3 * cfg.d_model * cfg.d_ff * cfg.top_k * capacity_factor
    return router + experts


def _mamba1_flops(cfg):
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    proj = 2 * d * 2 * di + 2 * di * (cfg.ssm_dt_rank + 2 * N) \
        + 2 * cfg.ssm_dt_rank * di + 2 * di * d
    conv = 2 * cfg.ssm_conv * di
    # associative scan: log2(C) combine steps, 3 mul/add per (di, N) element
    import math
    scan = 3 * di * N * (math.ceil(math.log2(max(cfg.ssm_chunk, 2))) + 2)
    y = 2 * di * N
    return proj + conv + scan + y


def _mamba2_flops(cfg):
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H, Pd = cfg.ssm_heads, cfg.ssm_d_inner // cfg.ssm_heads
    C = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * N + H) + 2 * di * d
    conv = 2 * cfg.ssm_conv * (di + 2 * N)
    # SSD per token: CB^T (C*N) + att@x (C*H*P) + state update (N*H*P) etc.
    ssd = 2 * C * N + 2 * C * H * Pd + 4 * N * H * Pd
    return proj + conv + ssd


def _layer_flops(cfg, s_ctx, decode=False):
    """Per token forward flops for one layer (s_ctx = attention context)."""
    if cfg.family in ("dense", "encoder"):
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx) \
            + _mlp_flops(cfg)
    if cfg.family == "moe":
        cf = cfg.n_experts / cfg.top_k if decode else 1.25
        return _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx) \
            + _moe_flops(cfg, cf)
    if cfg.family == "ssm":
        return _mamba1_flops(cfg)
    if cfg.family == "hybrid":
        f = _mamba2_flops(cfg)
        if cfg.attn_every:
            shared = (_attn_proj_flops(cfg)
                      + _attn_score_flops(cfg, s_ctx)) / cfg.attn_every
            f += shared
        return f
    raise ValueError(cfg.family)


def forward_flops(cfg, n_tokens, s_ctx, decode=False, with_unembed=True,
                  unembed_tokens=None):
    """Global forward FLOPs for n_tokens (each attending s_ctx)."""
    per_tok = _layer_flops(cfg, s_ctx, decode) * cfg.n_layers
    un = 2 * cfg.d_model * cfg.vocab_size * (
        unembed_tokens if unembed_tokens is not None else n_tokens)
    return per_tok * n_tokens + (un if with_unembed else 0)


def cell_flops(cfg, shape) -> dict:
    """Global FLOPs per step + the 'useful' 6*N*D (2*N*D serve) number."""
    B, S = shape.global_batch, shape.seq_len
    n_tok = B * S
    if shape.kind == "train":
        # bwd = 2x fwd; full remat recomputes fwd once more
        fwd = forward_flops(cfg, n_tok, s_ctx=S / 2)  # causal avg context
        # chunked attention computes the full rectangle (masked): the causal
        # waste is part of HLO flops, so count s_ctx=S for hlo-comparable.
        fwd_hlo = forward_flops(cfg, n_tok, s_ctx=S)
        total = 4 * fwd_hlo
        useful = 6 * cfg.active_params() * n_tok
    elif shape.kind == "prefill":
        fwd_hlo = forward_flops(cfg, n_tok, s_ctx=S, with_unembed=True,
                                unembed_tokens=B)
        total = fwd_hlo
        useful = 2 * cfg.active_params() * n_tok
    else:  # decode: B new tokens, context S
        total = forward_flops(cfg, B, s_ctx=S, decode=True)
        useful = 2 * cfg.active_params() * B
    return {"hlo_like_total": total, "useful": useful}


# --------------------------------------------------------------- bytes model
def param_bytes(cfg) -> int:
    return cfg.n_params() * BYTES_F32


def cell_hbm_bytes(cfg, shape, n_dev, n_micro=1) -> float:
    """Per-device HBM traffic per step (analytic, documented assumptions).

    train: each microbatch reads all (gathered) weights fwd + bwd + recompute
           (3 passes, bf16 compute reads) + optimizer read/write f32 x3;
    prefill/decode: one weight pass; decode additionally reads the KV cache
    (or SSM state) once per token.
    """
    pb = param_bytes(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        w = 3 * n_micro * pb / 2 * BYTES_BF16 / BYTES_F32  # bf16 reads
        opt = 3 * pb  # adam m,v read+write + param update
        act = B * S * cfg.d_model * BYTES_BF16 * cfg.n_layers * 4 / n_dev
        return (w + opt) / n_dev + act
    if shape.kind == "prefill":
        w = pb / 2
        act = B * S * cfg.d_model * BYTES_BF16 * cfg.n_layers * 2 / n_dev
        return w / n_dev + act
    # decode
    w = pb / 2
    kv = 0.0
    if cfg.family in ("dense", "moe", "encoder"):
        kv = (cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim
              * 2 * BYTES_BF16)
    elif cfg.family == "hybrid" and cfg.attn_every:
        sites = cfg.n_layers // cfg.attn_every
        kv = sites * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * BYTES_BF16
        kv += (cfg.n_layers * B * cfg.ssm_d_inner * cfg.ssm_state
               * BYTES_F32)
    elif cfg.family == "ssm":
        kv = cfg.n_layers * B * cfg.ssm_d_inner * cfg.ssm_state * BYTES_F32
    return (w + kv) / n_dev


def _tp_allreduces_per_layer(cfg) -> float:
    """TP activation all-reduces per layer (Megatron accounting).

    dense/encoder: attn-out + mlp-out = 2.  moe: attn-out + expert combine
    = 2 (dispatch from batch-replicated activations is a local slice —
    GSPMD moves no bytes; only the combine reduces over the expert/model
    axis).  ssm: out_proj only = 1.  hybrid: mamba out_proj + shared attn
    amortized = 1 + 1/attn_every.
    """
    if cfg.family in ("dense", "encoder", "moe"):
        return 2.0
    if cfg.family == "ssm":
        return 1.0
    if cfg.family == "hybrid":
        return 1.0 + (1.0 / cfg.attn_every if cfg.attn_every else 0.0)
    raise ValueError(cfg.family)


def cell_collective_bytes(cfg, shape, mesh_shape: dict, n_micro=1) -> float:
    """Per-device ICI link bytes per step (ring formulas, analytic).

    Counted: FSDP weight all-gather (per microbatch) + gradient
    reduce-scatter/all-gather over data(+pod) + TP activation all-reduces
    (expert combine included, see _tp_allreduces_per_layer).
    """
    d_ax = mesh_shape.get("data", 1)
    p_ax = mesh_shape.get("pod", 1)
    m_ax = mesh_shape.get("model", 1)
    pb_bf16 = cfg.n_params() * BYTES_BF16
    B, S = shape.global_batch, shape.seq_len
    tok_dev = B * S / max(d_ax * p_ax, 1)
    n_ar = _tp_allreduces_per_layer(cfg)

    total = 0.0
    if shape.kind == "train":
        # FSDP gather: each device receives its missing (d-1)/d of the
        # model-shard slice, fwd + bwd + remat recompute, per microbatch
        total += 3 * n_micro * (pb_bf16 / m_ax) * (d_ax - 1) / d_ax
        # grad reduce over data x pod (two-level ring all-reduce, f32)
        gb = cfg.n_params() * BYTES_F32 / m_ax
        total += 2 * gb * (d_ax - 1) / d_ax
        total += 2 * (gb / d_ax) * (p_ax - 1) / max(p_ax, 1)
        # TP all-reduce of layer outputs, fwd + bwd + recompute
        act = tok_dev * cfg.d_model * BYTES_BF16
        total += 3 * n_ar * cfg.n_layers * 2 * act * (m_ax - 1) / m_ax
    elif shape.kind == "prefill":
        total += (pb_bf16 / m_ax) * (d_ax - 1) / d_ax
        act = tok_dev * cfg.d_model * BYTES_BF16
        total += n_ar * cfg.n_layers * 2 * act * (m_ax - 1) / m_ax
    else:  # decode: bf16 weights resident (no per-token FSDP gather);
        # MoE keeps the fsdp axis for its expert tables
        if cfg.family == "moe" or cfg.n_params() >= 32e9:
            total += (pb_bf16 / m_ax) * (d_ax - 1) / d_ax
        act = (B / max(d_ax * p_ax, 1)) * cfg.d_model * BYTES_BF16
        total += n_ar * cfg.n_layers * 2 * act * (m_ax - 1) / m_ax
    return total


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    hbm_bytes_per_dev: float
    link_bytes_per_dev: float
    useful_flops: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (the score)."""
        useful_s = self.useful_flops / PEAK_FLOPS
        return useful_s / max(self.bound_s, 1e-30)


def cell_roofline(cfg, shape, mesh_shape: dict, n_micro: int = 1) -> Roofline:
    n_dev = 1
    for v in mesh_shape.values():
        n_dev *= v
    fl = cell_flops(cfg, shape)
    flops_dev = fl["hlo_like_total"] / n_dev
    hbm = cell_hbm_bytes(cfg, shape, n_dev, n_micro)
    link = cell_collective_bytes(cfg, shape, mesh_shape, n_micro)
    useful_dev = fl["useful"] / n_dev
    return Roofline(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=link / ICI_BW,
        flops_per_dev=flops_dev,
        hbm_bytes_per_dev=hbm,
        link_bytes_per_dev=link,
        useful_flops=useful_dev,
        useful_ratio=fl["useful"] / max(fl["hlo_like_total"], 1e-30),
    )
