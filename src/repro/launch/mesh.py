"""Mesh construction (production + genomics service).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# single home of the jax-version mesh-construction shim
from repro.core.mapper import make_mesh_compat as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_genomics_mesh(n_shards: int | None = None):
    """Flat shard mesh for the distributed read mapper (one axis)."""
    n = n_shards or len(jax.devices())
    return _make_mesh((n,), ("shards",))


def batch_axes(mesh) -> tuple:
    """Mesh axes carrying data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def named(mesh, spec_tree):
    """Map a PartitionSpec pytree to NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
