"""Production train launcher: mesh + sharded state + checkpointed loop.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --batch 8 --seq 128 [--reduced] [--mesh 2x2] \
        [--microbatches 2] [--ckpt /tmp/ck]

On a real TPU pod slice, run one process per host (jax.distributed
initializes from the TPU environment) with --mesh data x model matching the
slice topology; on CPU it runs single-device (or virtual devices via
XLA_FLAGS) for development.  The step function, shardings, microbatching
and checkpoint/restore are exactly the dry-run configuration — what
compiles there runs here.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.data import tokens as token_data
from repro.launch import mesh as mesh_lib
from repro.models import lm, transformer
from repro.models.layers import Shardings
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default=None,
                    help="DxM (e.g. 16x16); default 1 x n_devices")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for CPU development")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)

    n_dev = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = 1, n_dev
    mesh = jax.make_mesh((d, m), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    sh = Shardings(batch=("data",), model=("model",), fsdp=("data",),
                   model_size=m)
    print(f"mesh {d}x{m}; arch {cfg.arch} ({cfg.n_params()/1e6:.1f}M params)")

    opt = adamw(lr=args.lr, warmup=min(20, args.steps // 10),
                total_steps=args.steps)
    pspecs = transformer.param_specs(cfg, sh)
    ns = lambda t: mesh_lib.named(mesh, t)
    with mesh:
        params = jax.jit(
            lambda k: transformer.init_params(cfg, k),
            out_shardings=ns(pspecs))(jax.random.key(0))
        opt_state = jax.jit(opt.init,
                            out_shardings=ns({"m": pspecs,
                                              "v": pspecs}))(params)
        state = (params, opt_state, jnp.int32(0))
        start = 0
        if args.ckpt and (s := ckpt_lib.latest_step(args.ckpt)) is not None:
            state, extra = ckpt_lib.restore(args.ckpt, s, state,
                                            sharding_tree=None)
            start = int(extra.get("next_step", s))
            print(f"restored checkpoint @ step {start}")

        step_fn = jax.jit(lm.make_train_step(
            cfg, opt, sh, num_microbatches=args.microbatches),
            donate_argnums=(0,))
        dspec = NamedSharding(mesh, P("data", None))
        for step in range(start, args.steps):
            toks, labels = token_data.batch_for_step(
                step, global_batch=args.batch, seq_len=args.seq,
                vocab_size=cfg.vocab_size)
            batch = {
                "tokens": jax.device_put(toks % cfg.vocab_size, dspec),
                "labels": jax.device_put(labels % cfg.vocab_size, dspec)}
            if cfg.input_kind == "embeds":
                rng = np.random.default_rng(step)
                emb = rng.standard_normal(
                    (args.batch, args.seq, cfg.d_model)).astype("f") * 0.02
                batch = {"embeds": jax.device_put(
                    jnp.asarray(emb, jnp.bfloat16),
                    NamedSharding(mesh, P("data", None, None))),
                    "labels": batch["labels"]}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
                print(f"step {step:>5} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms/step)")
            if args.ckpt and (step + 1) % 50 == 0:
                ckpt_lib.save(args.ckpt, step + 1, state,
                              extra={"next_step": step + 1})
    print("done")


if __name__ == "__main__":
    main()
