import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Per cell this records, into reports/dryrun.json:
  * memory_analysis()  — per-device argument/temp/output bytes (fits-check)
  * cost_analysis()    — per-device HLO FLOPs and bytes accessed
  * parsed collective schedule from the post-SPMD compiled HLO (op kind,
    per-device bytes, group size) with ring-model link-byte accounting
  * derived roofline terms (see repro/launch/roofline.py)
The two XLA_FLAGS lines above MUST stay the first statements — jax locks
the device count at first init, and only the dry-run wants 512 devices.
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_applicable, input_specs
from repro.launch import mesh as mesh_lib
from repro.models import lm, transformer
from repro.models.layers import Shardings
from repro.train.optimizer import adafactor, adafactor_state_specs, adamw

REPORT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "reports", "dryrun.json")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|u64|s32|u32|s16|u16|s8|"
                       r"u8|pred|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        key = "f8" if dt.startswith("f8") else dt
        total += n * _DTYPE_BYTES.get(key, 4)
    return total


def parse_collectives(hlo_text: str, default_group: int) -> list[dict]:
    """Extract (kind, per-device result bytes, group size) per collective."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        g = default_group
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACES_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        out.append({"kind": kind, "result_bytes": nbytes, "group": g})
    return out


def link_bytes(colls: list[dict]) -> float:
    """Ring-model per-chip link bytes for the parsed collective schedule."""
    total = 0.0
    for c in colls:
        b, g = c["result_bytes"], max(c["group"], 1)
        f = (g - 1) / g
        if c["kind"] == "all-reduce":
            total += 2 * b * f
        elif c["kind"] == "all-gather":
            total += b * f            # result is the gathered (large) buffer
        elif c["kind"] == "reduce-scatter":
            total += b * (g - 1)      # result is the scattered (small) buffer
        elif c["kind"] == "all-to-all":
            total += b * f
        elif c["kind"] == "collective-permute":
            total += b
    return total


def pick_microbatches(cfg, shape, data_shards: int,
                      target_tokens_per_dev: int = 4096) -> int:
    """Gradient-accumulation factor: keep live tokens/device ~target."""
    tokens_per_dev = shape.global_batch * shape.seq_len // max(data_shards, 1)
    m = max(1, tokens_per_dev // target_tokens_per_dev)
    while shape.global_batch % m or (shape.global_batch // m) % data_shards:
        m -= 1
    return max(m, 1)


def build_cell(cfg, shape, mesh, variant=None):
    """Returns (jitted fn, abstract args) for one cell.

    ``variant`` (perf hillclimbing): dict with optional keys
      micro_target : int  — tokens/device per microbatch (default 4096)
      kv_quant     : bool — int8 KV cache for decode cells
      seq_parallel : bool — shard activation carries on (model) over seq
    """
    variant = variant or {}
    multi = "pod" in mesh.axis_names
    baxes = mesh_lib.batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    shard_batch = shape.global_batch % bsize == 0 and shape.global_batch >= bsize
    bspec = baxes if shard_batch else None
    n_dev = bsize * mesh.shape["model"]
    if (variant or {}).get("flat_dp") and shape.global_batch % n_dev == 0:
        # repurpose the model axis as extra data parallelism (small archs:
        # TP collectives dominate at model=16 — see EXPERIMENTS.md §Perf)
        bspec = tuple(baxes) + ("model",)
        sh = Shardings(batch=bspec, model=(), fsdp=("data",), model_size=1)
    else:
        sh = Shardings(batch=bspec if shard_batch else (), model=("model",),
                       fsdp=("data",), model_size=mesh.shape["model"],
                       seq=("model",) if variant.get("seq_parallel") else ())

    pspecs = transformer.param_specs(cfg, sh)
    params_abs = transformer.abstract_params(cfg)
    ns = lambda tree: mesh_lib.named(mesh, tree)

    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        # >100B params: factored 2nd moment (Adafactor) — full f32 Adam
        # state would not leave workspace on 16 GiB chips at 256-way
        # sharding (see EXPERIMENTS.md §Perf).
        if cfg.n_params() > 1.0e11:
            opt = adafactor()
            opt_specs = adafactor_state_specs(pspecs)
        else:
            opt = adamw()
            opt_specs = {"m": pspecs, "v": pspecs}
        data_like = (n_dev if (variant or {}).get("flat_dp")
                     and shape.global_batch % n_dev == 0 else bsize)
        n_micro = pick_microbatches(
            cfg, shape, data_like,
            target_tokens_per_dev=variant.get("micro_target", 4096))
        # >100B params: bf16 grad accumulator by default (hillclimbed —
        # the f32 accumulator alone is 3.4 GiB/device at 235B)
        acc = (jnp.bfloat16 if (variant.get("grad_acc_bf16")
                                or cfg.n_params() > 1.0e11) else jnp.float32)
        step = lm.make_train_step(cfg, opt, sh, num_microbatches=n_micro,
                                  acc_dtype=acc)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        state_abs = (params_abs, opt_abs, jax.ShapeDtypeStruct((), jnp.int32))
        state_specs = (pspecs, opt_specs, P())
        dspec = {k: P(bspec, *([None] * (len(v.shape) - 1)))
                 for k, v in specs.items()}
        fn = jax.jit(step, in_shardings=(ns(state_specs), ns(dspec)),
                     donate_argnums=(0,))
        return fn, (state_abs, specs)
    if shape.kind == "prefill":
        step = lm.make_prefill_step(cfg, sh)
        dspec = {k: P(bspec, *([None] * (len(v.shape) - 1)))
                 for k, v in specs.items()}
        fn = jax.jit(step, in_shardings=(ns(pspecs), ns(dspec)))
        return fn, (params_abs, specs)
    # decode: serving holds bf16 weights RESIDENT (no per-token FSDP
    # gathers) — params bf16 shard on `model` alone for every family except
    # MoE, whose expert tables exceed a single model-axis shard (they keep
    # the fsdp axis; ragged expert-parallel serving is logged future work).
    if cfg.family != "moe" and cfg.n_params() < 32e9:
        sh = Shardings(batch=sh.batch, model=sh.model, fsdp=(),
                       model_size=mesh.shape["model"])
        pspecs = transformer.param_specs(cfg, sh)
    params_abs = jax.eval_shape(transformer.cast_params, params_abs)
    seq_axes = () if shard_batch else tuple(baxes)  # long_500k: shard cache S
    kv_quant = bool(variant.get("kv_quant"))
    step = lm.make_serve_step(cfg, sh)
    cache_abs = transformer.init_cache(cfg, shape.global_batch, shape.seq_len,
                                       abstract=True, kv_quant=kv_quant)
    cspecs = transformer.cache_specs(cfg, sh, seq_shard_axes=seq_axes,
                                     kv_quant=kv_quant)
    tok_abs = specs["token"]
    if cfg.input_kind == "embeds":
        tok_abs = jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.d_model), jnp.bfloat16)
        tspec = P(bspec, None, None)
    else:
        tspec = P(bspec, None)
    fn = jax.jit(step, in_shardings=(ns(pspecs), ns(cspecs), ns(tspec),
                                     NamedSharding(mesh, P())),
                 donate_argnums=(1,))
    return fn, (params_abs, cache_abs, tok_abs, specs["pos"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant=None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"status": "SKIP", "reason": reason}
    if (variant or {}).get("mesh_override"):
        import jax as _jax
        from jax.sharding import AxisType as _AT
        d, m = (int(x) for x in variant["mesh_override"].split("x"))
        mesh = _jax.make_mesh((d, m), ("data", "model"),
                              axis_types=(_AT.Auto,) * 2)
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape, mesh, variant=variant)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
    n_dev = len(jax.devices())
    colls = parse_collectives(hlo, default_group=n_dev)
    coll_summary = {}
    for c in colls:
        k = c["kind"]
        coll_summary.setdefault(k, {"count": 0, "bytes": 0})
        coll_summary[k]["count"] += 1
        coll_summary[k]["bytes"] += c["result_bytes"]
    return {
        "status": "OK",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            # live peak: args + temps + non-aliased outputs (donated state
            # aliases its argument buffers)
            "peak_bytes": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                           + ma.output_size_in_bytes
                           - ma.alias_size_in_bytes),
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "link_bytes": link_bytes(colls),
        },
        "collectives": coll_summary,
        "n_collectives": len(colls),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--report", default=None)
    ap.add_argument("--tag", default="", help="variant suffix for the key")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--micro-target", type=int, default=4096)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--grad-acc-bf16", action="store_true")
    ap.add_argument("--flat-dp", action="store_true")
    ap.add_argument("--mesh-override", default=None,
                    help="DxM re-aim of the 256 chips (perf variant)")
    args = ap.parse_args()
    variant = {"kv_quant": args.kv_quant, "micro_target": args.micro_target,
               "seq_parallel": args.seq_parallel,
               "grad_acc_bf16": args.grad_acc_bf16, "flat_dp": args.flat_dp,
               "mesh_override": args.mesh_override}

    report_path = args.report or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "../../..",
                     "reports/dryrun.json"))
    os.makedirs(os.path.dirname(report_path), exist_ok=True)
    results = {}
    if os.path.exists(report_path):
        with open(report_path) as f:
            results = json.load(f)

    cells = []
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        key = f"{a}|{s}|{'2x16x16' if mp else '16x16'}" + (
            f"|{args.tag}" if args.tag else "")
        if results.get(key, {}).get("status") in ("OK", "SKIP"):
            print(f"[cached] {key}: {results[key]['status']}")
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            res = run_cell(a, s, mp, variant=variant)
        except Exception as e:  # noqa: BLE001 — record and continue
            res = {"status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        results[key] = res
        with open(report_path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  -> {res['status']} "
              + (f"compile={res.get('compile_s')}s "
                 f"flops/dev={res['per_device']['flops']:.3g} "
                 f"temp/dev={res['per_device']['temp_bytes']/2**30:.2f}GiB"
                 if res["status"] == "OK" else res.get("reason",
                                                       res.get("error", ""))),
              flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "OK")
    n_skip = sum(1 for r in results.values() if r["status"] == "SKIP")
    n_fail = sum(1 for r in results.values() if r["status"] == "FAIL")
    print(f"dry-run cells: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
