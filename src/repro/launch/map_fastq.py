"""FASTA + FASTQ -> SAM, end-to-end over the ``Mapper`` session.

    PYTHONPATH=src python -m repro.launch.map_fastq ref.fa reads.fq \
        -o out.sam
    PYTHONPATH=src python -m repro.launch.map_fastq ref.fa \
        --r1 reads_R1.fastq.gz --r2 reads_R2.fastq.gz -o out.sam
    PYTHONPATH=src python -m repro.launch.map_fastq ref.fa pairs.fq \
        --interleaved -o out.sam --topology mesh --shards 4

The real-data boundary of the reproduction: a (multi-contig) FASTA
reference is indexed, FASTQ reads stream through the session in
``--chunk-reads`` batches — each chunk mapped on **both strands**
(forward + reverse complement; ``--single-strand`` disables) — and
spec-valid SAM comes out.  Plain and ``.gz`` FASTQ parse identically.

Single-end input (one positional FASTQ) emits FLAG 0x4/0x10 records
with MAPQ 255 (no quality model on this path — unchanged output).
Paired-end input (``--r1``/``--r2`` or ``--interleaved``) maps both
mates of every pair in one stacked batch, resolves proper pairs
host-side (FR orientation, insert window from a running median, mate
rescue — see ``repro.core.pairing``) and emits the full pairing FLAGs
(0x1/0x2/0x8/0x20/0x40/0x80), RNEXT/PNEXT/TLEN, and calibrated MAPQ.
``--topology mesh`` routes chunks onto the distributed all_to_all
mapper; its stage B computes distances/positions only, so mesh records
carry CIGAR ``*`` (strand/POS/NM/pairing still present).

Progress and the closing unified-stats lines go to stderr, so ``-o -``
pipes clean SAM to stdout.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time


def _open_stream(args, injector=None):
    """Build the FASTQ stream per input layout -> (stream, paired)."""
    from repro.io.fastq import FastqStream, PairedFastqStream

    kw = dict(read_len=args.read_len, chunk_reads=args.chunk_reads,
              on_error=args.on_error, rejects=args.rejects,
              injector=injector)
    if args.r2 is not None and args.r1 is None:
        raise SystemExit("map_fastq: --r2 needs --r1")
    if args.r1 is not None:
        if args.reads is not None:
            raise SystemExit("map_fastq: pass either a positional FASTQ or "
                             "--r1/--r2, not both")
        if args.r2 is None:
            raise SystemExit("map_fastq: --r1 needs --r2 (or use "
                             "--interleaved with a single file)")
        if args.interleaved:
            raise SystemExit("map_fastq: --interleaved takes a single "
                             "positional FASTQ, not --r1/--r2")
        return PairedFastqStream(args.r1, args.r2, **kw), True
    if args.reads is None:
        raise SystemExit("map_fastq: no reads given (positional FASTQ or "
                         "--r1/--r2)")
    if args.interleaved:
        return PairedFastqStream(args.reads, interleaved=True, **kw), True
    return FastqStream(args.reads, **kw), False


def _ingest(stream):
    """Enumerate FASTQ chunks, stamping the span context with the chunk
    index and recording each chunk's host-side parse as an ``ingest``
    span when tracing is armed."""
    from repro.obs import tracing as _tracing
    it = iter(stream)
    i = 0
    while True:
        if _tracing.ACTIVE is not None:
            _tracing.set_ctx(chunk=i)
        t0 = time.perf_counter()
        try:
            chunk = next(it)
        except StopIteration:
            return
        tr = _tracing.ACTIVE
        if tr is not None:
            tr.add("ingest", t0, time.perf_counter())
        yield i, chunk
        i += 1


def _span(name):
    from repro.obs import tracing as _tracing
    tr = _tracing.ACTIVE
    return tr.span(name) if tr is not None else contextlib.nullcontext()


def _metrics_snapshot(path, seq: int) -> None:
    """Append one registry snapshot line to the ``--metrics-out`` JSONL
    (schema: ``schemas/metrics_snapshot.schema.json``)."""
    from repro.obs import registry as _metrics
    reg = _metrics.ACTIVE
    if path is None or reg is None:
        return
    rec = dict(kind="metrics_snapshot", seq=seq, ts_unix_s=time.time())
    rec.update(reg.snapshot())
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def run(args) -> int:
    """Entry point: arms the ``--log-json``/``--metrics-out``/
    ``--trace-out`` surfaces around the mapping run and always tears
    them down — the trace is exported even when the run fails, so a
    crash still leaves an inspectable timeline."""
    from repro.obs import logjson
    from repro.obs import registry as _metrics
    from repro.obs import tracing as _tracing

    trace_out = getattr(args, "trace_out", None)
    metrics_out = getattr(args, "metrics_out", None)
    log_on = getattr(args, "log_json", False) and not logjson.enabled()
    metrics_on = metrics_out is not None and _metrics.ACTIVE is None
    tracing_on = trace_out is not None and _tracing.ACTIVE is None
    if log_on:
        logjson.enable("map_fastq")
    if metrics_on:
        _metrics.enable_metrics()
    if tracing_on:
        _tracing.enable_tracing()
    # the closing stats are re-derived from the registry only when this
    # run owns a fresh one (counters from an inherited registry would
    # include earlier runs)
    args.obs_fresh_registry = metrics_on
    if metrics_out is not None:
        open(metrics_out, "w").close()   # truncate; snapshots append
    try:
        return _run(args)
    finally:
        if trace_out is not None and _tracing.ACTIVE is not None:
            _tracing.ACTIVE.export(trace_out)
        if tracing_on:
            _tracing.disable_tracing()
        if metrics_on:
            _metrics.disable_metrics()
        if log_on:
            logjson.disable()


def _run(args) -> int:
    from repro.core.index import build_index
    from repro.core.mapper import (Mapper, accumulate_partition_stats,
                                   accumulate_stats, totals_from_registry)
    from repro.obs import logjson
    from repro.core.pairing import InsertSizeTracker, resolve_pairs
    from repro.core.pipeline import MapperConfig
    from repro.core.resilience import FaultInjector, ResilientMapper
    from repro.io.fasta import ReferenceMap, load_reference

    from repro.io.sam import (emit_alignments, emit_paired_alignments,
                              sam_header)

    t0 = time.perf_counter()
    injector = (FaultInjector.from_spec(args.inject)
                if args.inject is not None else None)
    sharded = None
    if args.index_dir is not None:
        from repro.index import open_index
        sharded = open_index(args.index_dir)
        if args.read_len is not None and args.read_len != sharded.read_len:
            raise SystemExit(
                f"map_fastq: --read-len {args.read_len} conflicts with the "
                f"index's read_len={sharded.read_len} — segment geometry "
                f"is fixed at build time; rebuild with "
                f"repro.launch.build_index --read-len {args.read_len}")
        args.read_len = sharded.read_len
        for name in ("k", "w", "eth"):
            if getattr(args, name) != getattr(sharded, name):
                print(f"map_fastq: --{name} {getattr(args, name)} ignored; "
                      f"index manifest has {name}="
                      f"{getattr(sharded, name)}", file=sys.stderr)
                setattr(args, name, getattr(sharded, name))
    stream, paired = _open_stream(args, injector)
    rl = stream.read_len
    if sharded is not None:
        contigs = sharded.contigs
        refmap = sharded.reference_map()
        # only the paired-end mate-rescue scan needs the genome itself;
        # single-end runs stay on the mmap'd packed reference
        ref = sharded.reference_codes() if paired else None
        n_indexed = sharded.ref_len
        idx = sharded
    else:
        # spacer >= one alignment window: no read can map across a boundary
        rejected_contigs: list = []
        ref, contigs = load_reference(args.reference,
                                      spacer=rl + 2 * args.eth,
                                      on_error=args.on_error,
                                      rejected=rejected_contigs)
        for cname, why in rejected_contigs:
            print(f"map_fastq: skipped contig {cname!r}: {why}",
                  file=sys.stderr)
        refmap = ReferenceMap(contigs)
        n_indexed = len(ref)
        idx = build_index(ref, read_len=rl, k=args.k, w=args.w,
                          eth=args.eth)
    cfg = MapperConfig.from_index(
        idx, engine=args.engine, wf_backend=args.wf_backend,
        chunk_reads=args.chunk_reads, stream=not args.no_stream,
        both_strands=not args.single_strand,
        # --trace-out needs per-stage times on the streamed path: spans
        # are emitted from the same perf_counter reads that build
        # stage_times_s, so the trace and the stats agree by construction
        profile=getattr(args, "trace_out", None) is not None)
    budget = (int(args.index_budget_mb * (1 << 20))
              if args.index_budget_mb is not None else None)
    if args.prefetch and (sharded is None or args.topology != "single"):
        raise SystemExit(
            "map_fastq: --prefetch needs --index-dir with --topology "
            "single — only the shard-routed arena path has per-chunk "
            "partition uploads to overlap")
    mapper = Mapper(idx, cfg, topology=args.topology, n_shards=args.shards,
                    injector=injector, watchdog_s=args.watchdog,
                    memory_budget_bytes=budget, prefetch=args.prefetch)
    # fault containment (retry/bisect/degrade) is armed alongside the
    # injector or a permissive run; a plain strict run keeps today's
    # fail-fast behaviour with zero wrapping
    resilient = (ResilientMapper(mapper, injector=injector)
                 if injector is not None or args.on_error == "permissive"
                 else None)
    src = (f"index {args.index_dir} ({sharded.num_partitions} partitions)"
           if sharded is not None else "in-memory index")
    logjson.say(
        f"map_fastq: {len(contigs)} contig(s), {n_indexed} indexed bases "
        f"({src}), read_len={rl}, topology={mapper.topology}, "
        f"paired={paired}, both_strands={cfg.both_strands}, "
        f"engine={cfg.engine}, wf_backend={cfg.wf_backend}",
        event="start", contigs=len(contigs), indexed_bases=n_indexed,
        read_len=rl, topology=mapper.topology, paired=paired,
        engine=cfg.engine, wf_backend=cfg.wf_backend)

    # resume-safe atomic output: SAM accumulates in a .partial segment
    # and lands on the final path in one os.replace only after a clean
    # finish — an interrupted run can never leave a truncated file that
    # looks complete
    partial = None if args.output == "-" else args.output + ".partial"
    out = sys.stdout if partial is None else open(partial, "w")
    totals = dict(reads=0, mapped=0, reverse_best=0, survivors=0,
                  affine_instances=0, padded_affine_instances=0,
                  dropped_send=0, dropped_affine=0,
                  pairs=0, proper=0, rescued=0)
    saw_stats = False
    tracker = InsertSizeTracker()
    contig_starts = [c.offset for c in contigs]
    try:
        for line in sam_header(contigs,
                               command_line=" ".join(sys.argv)):
            out.write(line + "\n")
        t_map = time.perf_counter()
        n_chunks = 0
        for i, chunk in _ingest(stream):
            n_chunks = i + 1
            if paired:
                c1, c2 = chunk
                if resilient is not None:
                    res1, res2, _ = resilient.map_pairs(c1.reads, c2.reads)
                    if res1 is None:  # every block failed after retries
                        print(f"chunk {i}: all {2 * len(c1)} reads failed "
                              f"after retries; chunk quarantined",
                              file=sys.stderr)
                        totals["reads"] += 2 * len(c1)
                        continue
                else:
                    res1, res2 = mapper.map_pairs(c1.reads, c2.reads)
                pr = resolve_pairs(res1, res2, cfg=cfg, tracker=tracker,
                                   ref=ref, reads1=c1.reads,
                                   reads2=c2.reads,
                                   contig_starts=contig_starts)
                with _span("sam_emit"):
                    for rec in emit_paired_alignments(
                            pr, c1.names, c1.reads, c1.quals, c2.reads,
                            c2.quals, refmap, seqs1=c1.seqs, seqs2=c2.seqs):
                        out.write(rec + "\n")
                n_new = 2 * len(c1)
                n_mapped = int(pr.res1.mapped.sum() + pr.res2.mapped.sum())
                res = res1  # stats object is shared by both halves
                for r in (pr.res1, pr.res2):
                    if r.strand is not None:
                        totals["reverse_best"] += int((r.strand
                                                       & r.mapped).sum())
                totals["pairs"] += pr.stats["n_pairs"]
                totals["proper"] += pr.stats["n_proper"]
                totals["rescued"] += pr.stats["n_rescued"]
                extra = (f", proper {pr.stats['n_proper']}/"
                         f"{pr.stats['n_pairs']} "
                         f"(insert median {pr.stats['insert_median']})")
            else:
                if resilient is not None:
                    res, mask, _ = resilient.map(chunk.reads)
                    if res is None:  # every block failed after retries
                        print(f"chunk {i}: all {len(chunk)} reads failed "
                              f"after retries; chunk quarantined",
                              file=sys.stderr)
                        totals["reads"] += len(chunk)
                        continue
                else:
                    res = mapper.map(chunk.reads)
                with _span("sam_emit"):
                    for rec in emit_alignments(res, chunk.names,
                                               chunk.reads, chunk.quals,
                                               refmap, seqs=chunk.seqs):
                        out.write(rec + "\n")
                n_new = len(chunk)
                n_mapped = int(res.mapped.sum())
                if res.strand is not None:  # from the result, not stats:
                    #                         the padded engine has stats=None
                    totals["reverse_best"] += int((res.strand
                                                   & res.mapped).sum())
                extra = ""
            totals["reads"] += n_new
            totals["mapped"] += n_mapped
            if res.stats is not None:
                saw_stats = True
                accumulate_stats(totals, res.stats, fields=(
                    "survivors", "affine_instances",
                    "padded_affine_instances", "dropped_send",
                    "dropped_affine"))
                accumulate_partition_stats(totals, res.stats)
            out.flush()  # each chunk's records land in the .partial segment
            _metrics_snapshot(getattr(args, "metrics_out", None), seq=i)
            rate = totals["reads"] / max(time.perf_counter() - t_map, 1e-9)
            logjson.say(
                f"chunk {i}: {n_new} reads, "
                f"mapped {n_mapped / max(n_new, 1):.3f} "
                f"(cumulative {totals['reads']} reads, {rate:.0f} reads/s)"
                f"{extra}",
                event="chunk", chunk=i, reads=n_new, mapped=n_mapped,
                cumulative_reads=totals["reads"],
                reads_per_s=round(rate, 1))
        complete = True
    except BaseException:
        complete = False
        raise
    finally:
        if out is not sys.stdout:
            out.close()
        if partial is not None:
            if complete:  # atomic landing: complete output or none
                os.replace(partial, args.output)
            else:
                print(f"map_fastq: run did not complete; partial SAM "
                      f"left at {partial}", file=sys.stderr)

    dt = time.perf_counter() - t0
    skipped = (f", skipped {stream.n_skipped} short" if stream.n_skipped
               else "") + (f", truncated {stream.n_truncated} long"
                           if stream.n_truncated else "")
    logjson.say(
        f"done: {totals['reads']} reads in {dt:.1f}s "
        f"({totals['reads']/max(dt, 1e-9):.0f} reads/s incl. index build), "
        f"mapped {totals['mapped']} "
        f"({totals['reverse_best']} reverse-strand){skipped}",
        event="done", reads=totals["reads"], mapped=totals["mapped"],
        wall_s=round(dt, 3))
    if stream.n_rejected:
        reasons = dict(getattr(stream, "reject_reasons", {}))
        subs = {id(s): s for s in (getattr(stream, "_s1", None),
                                   getattr(stream, "_s2", None))
                if s is not None}
        for s in subs.values():  # paired: fold in both mates' counts once
            for k, v in s.reject_reasons.items():
                reasons[k] = reasons.get(k, 0) + v
        where = f" -> {args.rejects}" if args.rejects else ""
        print(f"quarantined: {stream.n_rejected} malformed record(s) "
              f"{reasons}{where}", file=sys.stderr)
    if resilient is not None:
        rc = resilient.counters
        if any(rc.values()) or resilient.ladder.degraded:
            print(f"resilience: {rc['retries']} retries, "
                  f"{rc['failed_reads']} quarantined reads in "
                  f"{rc['failed_blocks']} block(s), engine ladder "
                  f"{resilient.ladder.describe()}", file=sys.stderr)
    if paired:
        lo, hi = tracker.window()
        print(f"pairing: {totals['proper']}/{totals['pairs']} proper, "
              f"{totals['rescued']} rescued, insert median "
              f"{tracker.median} window [{lo}, {hi}]", file=sys.stderr)
    if saw_stats:
        if getattr(args, "obs_fresh_registry", False):
            # re-derive the engine counters from the metrics registry so
            # the closing lines and the exported snapshots can never
            # disagree (the registry counts every engine run)
            derived = totals_from_registry(mapper.topology)
            if derived is not None:
                for k in ("survivors", "affine_instances",
                          "padded_affine_instances", "dropped_send",
                          "dropped_affine"):
                    totals[k] = derived[k]
        from repro.launch.serve import _print_mapper_stats
        _print_mapper_stats(mapper, totals, file=sys.stderr)
    else:  # padded reference engine: no instance accounting to report
        print(f"plan cache: {mapper.plan_cache_hits} hits / "
              f"{mapper.plan_cache_misses} misses", file=sys.stderr)
    _metrics_snapshot(getattr(args, "metrics_out", None), seq=n_chunks)
    return 0


def main():
    ap = argparse.ArgumentParser(
        prog="repro.launch.map_fastq",
        description="Map a FASTQ read set against a FASTA reference; "
                    "emit SAM.")
    ap.add_argument("reference", nargs="?", default=None,
                    help="FASTA reference (multi-contig ok; N -> "
                         "never-matching sentinel); omit when mapping "
                         "against a prebuilt --index-dir")
    ap.add_argument("reads", nargs="?", default=None,
                    help="FASTQ reads (4-line records; .gz ok) — "
                         "single-end, or interleaved pairs with "
                         "--interleaved")
    ap.add_argument("--index-dir", default=None, metavar="DIR",
                    help="prebuilt sharded index directory "
                         "(repro.launch.build_index) instead of indexing "
                         "a FASTA at startup; geometry comes from the "
                         "manifest")
    ap.add_argument("--index-budget-mb", type=float, default=None,
                    metavar="MB",
                    help="--index-dir + single topology: device budget "
                         "for the partition arena; partitions load "
                         "lazily and LRU-evict under this bound")
    ap.add_argument("--prefetch", action="store_true",
                    help="--index-dir + single topology: stage the next "
                         "chunk's partition uploads on a background "
                         "worker while the current chunk computes "
                         "(bit-identical results)")
    ap.add_argument("--r1", default=None,
                    help="paired-end R1 FASTQ (.gz ok); requires --r2")
    ap.add_argument("--r2", default=None,
                    help="paired-end R2 FASTQ (.gz ok)")
    ap.add_argument("--interleaved", action="store_true",
                    help="the positional FASTQ holds interleaved R1/R2 "
                         "records")
    ap.add_argument("-o", "--output", default="-",
                    help="output SAM path ('-' = stdout; progress goes to "
                         "stderr either way)")
    ap.add_argument("--topology", default="single",
                    choices=("single", "mesh"))
    ap.add_argument("--shards", type=int, default=None,
                    help="mesh topology: shard count (default: all devices)")
    ap.add_argument("--chunk-reads", type=int, default=1024,
                    help="FASTQ batch size == engine streaming chunk")
    ap.add_argument("--read-len", type=int, default=None,
                    help="fixed read length (default: first FASTQ record)")
    ap.add_argument("--single-strand", action="store_true",
                    help="forward strand only (reverse-strand reads will "
                         "not map)")
    ap.add_argument("--engine", default="compacted",
                    choices=("compacted", "fused", "padded"))
    ap.add_argument("--wf-backend", default="jnp", choices=("jnp", "pallas"))
    ap.add_argument("--no-stream", action="store_true",
                    help="synchronous debug path (per-stage timings)")
    ap.add_argument("--on-error", default="strict",
                    choices=("strict", "permissive"),
                    help="malformed-input policy: strict raises with "
                         "file:line context; permissive quarantines bad "
                         "records (counted; see --rejects) and keeps "
                         "mapping")
    ap.add_argument("--rejects", default=None,
                    help="permissive mode: write quarantined raw FASTQ "
                         "records to this file (.gz ok)")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. "
                         "'bucket=0.125,record=0.005,seed=3' (sites: "
                         "bucket, record, stall, error, flush; plus "
                         "seed=, stall_s=, poison=r1;r2, "
                         "engines=fused;pallas) — chaos testing")
    ap.add_argument("--watchdog", type=float, default=None, metavar="S",
                    help="streaming fetch watchdog seconds: a stalled "
                         "chunk fetch fails (and is retried/quarantined) "
                         "instead of hanging the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run as Chrome trace-event JSON "
                         "(loadable in Perfetto / chrome://tracing); "
                         "implies per-stage profiling, so the span "
                         "durations equal stage_times_s")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write JSONL metrics snapshots (one per chunk "
                         "plus a final one; schema: "
                         "schemas/metrics_snapshot.schema.json)")
    ap.add_argument("--log-json", action="store_true",
                    help="structured one-object-per-line JSON progress "
                         "on stderr instead of human-readable lines")
    ap.add_argument("--k", type=int, default=12)
    ap.add_argument("--w", type=int, default=30)
    ap.add_argument("--eth", type=int, default=6)
    args = ap.parse_args()
    if args.index_dir is not None:
        if args.reference is not None and args.reads is None:
            # `map_fastq --index-dir DIR reads.fq`: the sole positional
            # is the FASTQ — no FASTA on this path
            args.reference, args.reads = None, args.reference
        if args.reference is not None:
            raise SystemExit("map_fastq: pass either a FASTA reference or "
                             "--index-dir, not both")
    elif args.reference is None:
        raise SystemExit("map_fastq: a FASTA reference (positional) or "
                         "--index-dir is required")
    if args.topology == "mesh" and args.shards and \
            "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}")
    try:
        return run(args)
    except BrokenPipeError:
        # `map_fastq ... -o - | head` closing the pipe is not an error;
        # detach stdout so interpreter shutdown doesn't re-raise
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        return 141  # 128 + SIGPIPE, the conventional exit status


if __name__ == "__main__":
    raise SystemExit(main())
