"""Genomics mapping service launcher (the paper's system kind).

    PYTHONPATH=src python -m repro.launch.serve --shards 8 --reads 256
    PYTHONPATH=src python -m repro.launch.serve --service --batches 16
    PYTHONPATH=src python -m repro.launch.serve --service --topology mesh \
        --shards 4 --batches 16

Both modes drive the unified ``repro.core.mapper.Mapper`` session API:

  * distributed (default) — ``Mapper(topology="mesh")`` batch loop: one
    process per host on a real pod (mesh from the TPU environment); on
    CPU it runs over virtual devices.  Stage B runs affine WF only on
    compacted filter survivors; the unified ``MapperStats`` reports the
    instance accounting.
  * ``--service`` — the request-batching path: variable-sized request
    batches are coalesced by the pow-2 ``ReadBatcher`` into static bucket
    shapes (``repro.core.serving``).  ``--topology single`` (default)
    streams buckets through the async double-buffered engine;
    ``--topology mesh`` routes every bucket onto the distributed mapper,
    where repeated same-size buckets hit the session plan cache (the
    compiled shard_map program) with zero recompiles after warm-up —
    watch the plan-cache counters in the closing stats lines.
"""
from __future__ import annotations

import argparse
import contextlib
import os
import sys
import time


@contextlib.contextmanager
def _obs(args, component: str):
    """Arm the observability surfaces a launcher asked for and always
    tear them down: ``--log-json`` structured logging, ``--metrics-out``
    (final JSONL snapshot), ``--trace-out`` (Chrome trace export, even
    on failure), ``--metrics-port`` (Prometheus exposition thread) and
    ``--profiler-port`` (jax profiler server for on-demand device
    timelines)."""
    from repro.launch.map_fastq import _metrics_snapshot
    from repro.obs import logjson
    from repro.obs import registry as _metrics
    from repro.obs import server as obs_server
    from repro.obs import tracing as _tracing

    trace_out = getattr(args, "trace_out", None)
    metrics_out = getattr(args, "metrics_out", None)
    metrics_port = getattr(args, "metrics_port", None)
    profiler_port = getattr(args, "profiler_port", None)
    log_on = getattr(args, "log_json", False) and not logjson.enabled()
    need_metrics = metrics_out is not None or metrics_port is not None
    metrics_on = need_metrics and _metrics.ACTIVE is None
    tracing_on = trace_out is not None and _tracing.ACTIVE is None
    if log_on:
        logjson.enable(component)
    if metrics_on:
        _metrics.enable_metrics()
    if tracing_on:
        _tracing.enable_tracing()
    srv = None
    if metrics_port is not None and _metrics.ACTIVE is not None:
        srv = obs_server.start_metrics_server(_metrics.ACTIVE,
                                              port=metrics_port)
        logjson.say(f"serve: metrics exposition on "
                    f"http://{srv.host}:{srv.port}/metrics",
                    event="metrics_server", port=srv.port)
    if profiler_port is not None:
        prof = obs_server.start_profiler_server(profiler_port)
        if prof is not None:
            logjson.say(f"serve: jax profiler server on port "
                        f"{profiler_port}", event="profiler_server",
                        port=profiler_port)
        else:
            logjson.say("serve: jax profiler server unavailable on this "
                        "jax build; continuing without it",
                        event="profiler_server", port=None)
    try:
        yield
    finally:
        if srv is not None:
            srv.stop()
        if metrics_out is not None and _metrics.ACTIVE is not None:
            open(metrics_out, "w").close()
            _metrics_snapshot(metrics_out, seq=0)
        if trace_out is not None and _tracing.ACTIVE is not None:
            _tracing.ACTIVE.export(trace_out)
        if tracing_on:
            _tracing.disable_tracing()
        if metrics_on:
            _metrics.disable_metrics()
        if log_on:
            logjson.disable()


def _print_mapper_stats(mapper, totals: dict, file=None) -> None:
    """Closing stats lines shared by every launcher (``map_fastq`` uses
    it too, with ``file=sys.stderr``): the unified MapperStats accounting
    and the session plan-cache counters.  The counter label names the
    stage that actually ran them: the mesh topology's stage B (filter +
    compacted affine on the index-owner shards) vs the single topology's
    filter/affine stages — so `--topology mesh` output is comparable
    across modes without guessing which path produced it."""
    label = ("stage B [mesh]" if mapper.topology == "mesh"
             else "filter/affine [single]")
    print(f"{label}: {totals['survivors']} "
          f"survivors -> {totals['affine_instances']} affine instances "
          f"(of {totals['padded_affine_instances']} padded), dropped "
          f"send={totals['dropped_send']} affine={totals['dropped_affine']}",
          file=file)
    print(f"plan cache: {mapper.plan_cache_hits} hits / "
          f"{mapper.plan_cache_misses} misses "
          f"(same-size batches reuse compiled executables after warm-up)",
          file=file)
    part = totals.get("partitions")
    if part:
        if "minis_routed_per_partition" in part:  # shard-routed single
            print(f"partitions: routed "
                  f"{part['minis_routed_per_partition']} minimizers "
                  f"(found {part['minis_found_per_partition']}) over "
                  f"{part['chunks_routed']} chunk(s); arena "
                  f"{part['arena_bytes']} B, {part['partition_loads']} "
                  f"load(s), {part['partition_evictions']} eviction(s), "
                  f"{part['h2d_bytes']} B h2d", file=file)
        else:  # mesh: partition i on shard i
            print(f"partitions: {part['num_partitions']} mesh-placed, "
                  f"occurrences {part['occurrences_per_partition']}, "
                  f"stage-B survivors {part['survivors_per_partition']}",
                  file=file)
    stor = mapper.index_storage()
    if stor is not None:
        per = stor.get("per_partition")
        breakdown = (" (" + ", ".join(
            f"p{d['partition']}: "
            f"{d['hash_table_bytes'] + d['segments_bytes']}"
            for d in per) + ")" if per else "")
        print(f"index storage: {stor['total_bytes']} B "
              f"(hash {stor['hash_table_bytes']} B + segments "
              f"{stor['materialized_segments_bytes']} B, blowup "
              f"{stor['blowup']:.1f}x){breakdown}", file=file)


def run_service(args) -> int:
    import numpy as np

    from repro.core.index import build_index
    from repro.core.mapper import Mapper
    from repro.core.pipeline import MapperConfig
    from repro.core.serving import BatcherConfig
    from repro.data.genome import make_reference, sample_reads
    from repro.obs import logjson

    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    cfg = MapperConfig.from_index(idx, wf_backend=args.wf_backend,
                                  stream=not args.no_stream)
    mapper = Mapper(idx, cfg, topology=args.topology, n_shards=args.shards)
    svc = mapper.serve(BatcherConfig(bucket_min=args.bucket_min,
                                     bucket_max=args.bucket_max))
    rng = np.random.default_rng(7)
    logjson.say(f"service: genome {len(ref)} bases, buckets "
                f"[{args.bucket_min}..{args.bucket_max}], "
                f"topology={mapper.topology}, stream={cfg.stream}, "
                f"wf_backend={cfg.wf_backend}",
                event="start", file=sys.stdout,
                genome=len(ref), topology=mapper.topology)
    total = correct = 0
    t0 = time.perf_counter()
    truth = {}
    for b in range(args.batches):
        # a burst of variable-sized client requests, then one flush
        for _ in range(int(rng.integers(1, 5))):
            n = int(rng.integers(1, args.reads + 1))
            rs = sample_reads(ref, n, seed=int(rng.integers(1 << 30)))
            truth[svc.submit(rs.reads)] = rs.true_pos
        for rid, res in svc.flush().items():
            total += len(res.position)
            correct += int((np.abs(res.position - truth.pop(rid)) <= 6).sum())
    dt = time.perf_counter() - t0
    st = svc.batcher.stats
    waste = st["padded_reads"] / max(st["padded_reads"] + st["reads"], 1)
    logjson.say(f"{total} reads / {st['requests']} requests in {dt:.1f}s "
                f"({total/dt:.0f} reads/s), accuracy "
                f"{correct/max(total,1):.4f}",
                event="done", file=sys.stdout, reads=total,
                requests=st["requests"], wall_s=round(dt, 3),
                accuracy=round(correct / max(total, 1), 4))
    print(f"bucket hist {st['bucket_hist']}, lane padding waste {waste:.3f}")
    _print_mapper_stats(mapper, svc.totals)
    return 0


def run_distributed(args) -> int:
    import numpy as np

    from repro.core.index import build_index
    from repro.core.mapper import Mapper, accumulate_stats
    from repro.core.pipeline import MapperConfig
    from repro.data.genome import make_reference, sample_reads
    from repro.launch.mesh import make_genomics_mesh

    mesh = make_genomics_mesh(args.shards)
    n_shards = mesh.devices.size
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    cfg = MapperConfig.from_index(idx, wf_backend=args.wf_backend)
    mapper = Mapper(idx, cfg, topology="mesh", mesh=mesh,
                    send_cap=args.send_cap)
    print(f"serving: {n_shards} shards, {len(idx.uniq_kmers)} minimizers, "
          f"{len(ref)} bases")
    totals = dict(survivors=0, affine_instances=0,
                  padded_affine_instances=0, dropped_send=0,
                  dropped_affine=0, reverse_best=0)
    total = correct = 0
    t0 = time.perf_counter()
    for b in range(args.batches):
        rs = sample_reads(ref, args.reads, seed=1000 + b)
        res = mapper.map(rs.reads)
        total += len(res.position)
        correct += int((np.abs(res.position - rs.true_pos) <= 6).sum())
        accumulate_stats(totals, res.stats)
    dt = time.perf_counter() - t0
    print(f"{total} reads in {dt:.1f}s ({total/dt:.0f} reads/s), "
          f"accuracy {correct/total:.4f}, dropped {totals['dropped_send']}")
    _print_mapper_stats(mapper, totals)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", action="store_true",
                    help="request batcher + Mapper session service mode")
    ap.add_argument("--topology", default="single",
                    choices=("single", "mesh"),
                    help="service mode only: execute buckets on the "
                         "single-shard streaming engine or route them onto "
                         "the distributed mesh mapper")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--genome", type=int, default=50_000)
    ap.add_argument("--reads", type=int, default=128,
                    help="reads per batch (distributed) / max request size "
                         "(service)")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--send-cap", type=int, default=None)
    ap.add_argument("--bucket-min", type=int, default=64)
    ap.add_argument("--bucket-max", type=int, default=1024)
    ap.add_argument("--wf-backend", default="jnp",
                    choices=("jnp", "pallas"))
    ap.add_argument("--no-stream", action="store_true",
                    help="service mode only: synchronous debug path "
                         "(per-stage timings)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run as Chrome trace-event JSON "
                         "(Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a final JSONL metrics snapshot (schema: "
                         "schemas/metrics_snapshot.schema.json)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="expose the live metrics registry over HTTP "
                         "(Prometheus text on /metrics, JSON on "
                         "/metrics.json; 0 = ephemeral port)")
    ap.add_argument("--profiler-port", type=int, default=None,
                    metavar="PORT",
                    help="start the jax profiler server so TensorBoard / "
                         "jax.profiler.trace clients can capture device "
                         "timelines from the live process")
    ap.add_argument("--log-json", action="store_true",
                    help="structured one-object-per-line JSON progress "
                         "on stderr")
    args, _ = ap.parse_known_args()
    if args.shards and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}")
    fn = run_service if args.service else run_distributed
    with _obs(args, "serve"):
        return fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
