"""Genomics mapping service launcher (the paper's system kind).

    PYTHONPATH=src python -m repro.launch.serve --shards 8 --reads 256
    PYTHONPATH=src python -m repro.launch.serve --service --batches 16

Two modes:

  * distributed (default) — the mesh mapper: one process per host on a real
    pod (mesh from the TPU environment); on CPU it runs over virtual
    devices.  Stage B now runs affine WF only on compacted filter
    survivors (``--stats`` prints the instance accounting).
  * ``--service`` — the single-device serving path: variable-sized request
    batches are coalesced by the pow-2 ``ReadBatcher`` into the streaming
    engine's static chunk shapes (``repro.core.serving``), exercising the
    async double-buffered ``map_reads`` engine end to end.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def run_service(args) -> int:
    import numpy as np

    from repro.core.index import build_index
    from repro.core.pipeline import MapperConfig
    from repro.core.serving import BatcherConfig, MappingService
    from repro.data.genome import make_reference, sample_reads

    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    cfg = MapperConfig(read_len=idx.read_len, k=idx.k, w=idx.w, eth=idx.eth,
                       wf_backend=args.wf_backend, stream=not args.no_stream)
    svc = MappingService(idx, cfg,
                         BatcherConfig(bucket_min=args.bucket_min,
                                       bucket_max=args.bucket_max))
    rng = np.random.default_rng(7)
    print(f"service: genome {len(ref)} bases, buckets "
          f"[{args.bucket_min}..{args.bucket_max}], "
          f"stream={cfg.stream}, wf_backend={cfg.wf_backend}")
    total = correct = 0
    t0 = time.perf_counter()
    truth = {}
    for b in range(args.batches):
        # a burst of variable-sized client requests, then one flush
        for _ in range(int(rng.integers(1, 5))):
            n = int(rng.integers(1, args.reads + 1))
            rs = sample_reads(ref, n, seed=int(rng.integers(1 << 30)))
            truth[svc.submit(rs.reads)] = rs.true_pos
        for rid, res in svc.flush().items():
            total += len(res.position)
            correct += int((np.abs(res.position - truth.pop(rid)) <= 6).sum())
    dt = time.perf_counter() - t0
    st = svc.batcher.stats
    waste = st["padded_reads"] / max(st["padded_reads"] + st["reads"], 1)
    print(f"{total} reads / {st['requests']} requests in {dt:.1f}s "
          f"({total/dt:.0f} reads/s), accuracy {correct/max(total,1):.4f}")
    print(f"bucket hist {st['bucket_hist']}, lane padding waste {waste:.3f}")
    return 0


def run_distributed(args) -> int:
    import numpy as np

    from repro.core.distributed import distributed_map_reads, shard_index
    from repro.core.index import build_index
    from repro.core.pipeline import MapperConfig
    from repro.data.genome import make_reference, sample_reads
    from repro.launch.mesh import make_genomics_mesh

    mesh = make_genomics_mesh(args.shards)
    n_shards = mesh.devices.size
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    sidx = shard_index(idx, n_shards)
    cfg = MapperConfig(read_len=idx.read_len, k=idx.k, w=idx.w, eth=idx.eth,
                       wf_backend=args.wf_backend)
    print(f"serving: {n_shards} shards, {len(idx.uniq_kmers)} minimizers, "
          f"{len(ref)} bases")
    total = correct = dropped = surv = aff_inst = aff_drop = 0
    t0 = time.perf_counter()
    for b in range(args.batches):
        rs = sample_reads(ref, args.reads, seed=1000 + b)
        pos, dist, drop, stats = distributed_map_reads(
            mesh, sidx, rs.reads, cfg=cfg, send_cap=args.send_cap,
            with_stats=True)
        total += len(pos)
        correct += int((np.abs(pos - rs.true_pos) <= 6).sum())
        dropped += int(drop.sum())
        surv += stats["stage_b_survivors"]
        aff_inst += stats["stage_b_affine_instances"]
        aff_drop += stats["stage_b_affine_dropped"]
    dt = time.perf_counter() - t0
    print(f"{total} reads in {dt:.1f}s ({total/dt:.0f} reads/s), "
          f"accuracy {correct/total:.4f}, dropped {dropped}")
    print(f"stage B: {surv} survivors -> {aff_inst} affine instances "
          f"(compacted), {aff_drop} dropped on overflow")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", action="store_true",
                    help="single-device batcher+streaming service mode")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--genome", type=int, default=50_000)
    ap.add_argument("--reads", type=int, default=128,
                    help="reads per batch (distributed) / max request size "
                         "(service)")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--send-cap", type=int, default=None)
    ap.add_argument("--bucket-min", type=int, default=64)
    ap.add_argument("--bucket-max", type=int, default=1024)
    ap.add_argument("--wf-backend", default="jnp",
                    choices=("jnp", "pallas"))
    ap.add_argument("--no-stream", action="store_true",
                    help="service mode only: synchronous debug path "
                         "(per-stage timings)")
    args, _ = ap.parse_known_args()
    if args.shards and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}")
    return run_service(args) if args.service else run_distributed(args)


if __name__ == "__main__":
    raise SystemExit(main())
