"""Genomics mapping service launcher (the paper's system kind).

    PYTHONPATH=src python -m repro.launch.serve --shards 8 --reads 256

One process per host on a real pod (mesh from the TPU environment); on CPU
it runs over virtual devices.  Wraps the distributed mapper with request
batching, capacity accounting (Reads-FIFO analog) and throughput stats.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--genome", type=int, default=50_000)
    ap.add_argument("--reads", type=int, default=128)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--send-cap", type=int, default=None)
    args, _ = ap.parse_known_args()
    if args.shards and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}")

    import numpy as np

    from repro.core.distributed import distributed_map_reads, shard_index
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    from repro.launch.mesh import make_genomics_mesh

    mesh = make_genomics_mesh(args.shards)
    n_shards = mesh.devices.size
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    sidx = shard_index(idx, n_shards)
    print(f"serving: {n_shards} shards, {len(idx.uniq_kmers)} minimizers, "
          f"{len(ref)} bases")
    total = correct = dropped = 0
    t0 = time.perf_counter()
    for b in range(args.batches):
        rs = sample_reads(ref, args.reads, seed=1000 + b)
        pos, dist, drop = distributed_map_reads(
            mesh, sidx, rs.reads, send_cap=args.send_cap)
        total += len(pos)
        correct += int((np.abs(pos - rs.true_pos) <= 6).sum())
        dropped += int(drop.sum())
    dt = time.perf_counter() - t0
    print(f"{total} reads in {dt:.1f}s ({total/dt:.0f} reads/s), "
          f"accuracy {correct/total:.4f}, dropped {dropped}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
