"""Genomics mapping service launcher (the paper's system kind).

    PYTHONPATH=src python -m repro.launch.serve --shards 8 --reads 256
    PYTHONPATH=src python -m repro.launch.serve --service --batches 16
    PYTHONPATH=src python -m repro.launch.serve --service --topology mesh \
        --shards 4 --batches 16

Both modes drive the unified ``repro.core.mapper.Mapper`` session API:

  * distributed (default) — ``Mapper(topology="mesh")`` batch loop: one
    process per host on a real pod (mesh from the TPU environment); on
    CPU it runs over virtual devices.  Stage B runs affine WF only on
    compacted filter survivors; the unified ``MapperStats`` reports the
    instance accounting.
  * ``--service`` — the request-batching path: variable-sized request
    batches are coalesced by the pow-2 ``ReadBatcher`` into static bucket
    shapes (``repro.core.serving``).  ``--topology single`` (default)
    streams buckets through the async double-buffered engine;
    ``--topology mesh`` routes every bucket onto the distributed mapper,
    where repeated same-size buckets hit the session plan cache (the
    compiled shard_map program) with zero recompiles after warm-up —
    watch the plan-cache counters in the closing stats lines.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _print_mapper_stats(mapper, totals: dict, file=None) -> None:
    """Closing stats lines shared by every launcher (``map_fastq`` uses
    it too, with ``file=sys.stderr``): the unified MapperStats accounting
    and the session plan-cache counters.  The counter label names the
    stage that actually ran them: the mesh topology's stage B (filter +
    compacted affine on the index-owner shards) vs the single topology's
    filter/affine stages — so `--topology mesh` output is comparable
    across modes without guessing which path produced it."""
    label = ("stage B [mesh]" if mapper.topology == "mesh"
             else "filter/affine [single]")
    print(f"{label}: {totals['survivors']} "
          f"survivors -> {totals['affine_instances']} affine instances "
          f"(of {totals['padded_affine_instances']} padded), dropped "
          f"send={totals['dropped_send']} affine={totals['dropped_affine']}",
          file=file)
    print(f"plan cache: {mapper.plan_cache_hits} hits / "
          f"{mapper.plan_cache_misses} misses "
          f"(same-size batches reuse compiled executables after warm-up)",
          file=file)
    part = totals.get("partitions")
    if part:
        if "minis_routed_per_partition" in part:  # shard-routed single
            print(f"partitions: routed "
                  f"{part['minis_routed_per_partition']} minimizers "
                  f"(found {part['minis_found_per_partition']}) over "
                  f"{part['chunks_routed']} chunk(s); arena "
                  f"{part['arena_bytes']} B, {part['partition_loads']} "
                  f"load(s), {part['partition_evictions']} eviction(s), "
                  f"{part['h2d_bytes']} B h2d", file=file)
        else:  # mesh: partition i on shard i
            print(f"partitions: {part['num_partitions']} mesh-placed, "
                  f"occurrences {part['occurrences_per_partition']}, "
                  f"stage-B survivors {part['survivors_per_partition']}",
                  file=file)
    stor = mapper.index_storage()
    if stor is not None:
        per = stor.get("per_partition")
        breakdown = (" (" + ", ".join(
            f"p{d['partition']}: "
            f"{d['hash_table_bytes'] + d['segments_bytes']}"
            for d in per) + ")" if per else "")
        print(f"index storage: {stor['total_bytes']} B "
              f"(hash {stor['hash_table_bytes']} B + segments "
              f"{stor['materialized_segments_bytes']} B, blowup "
              f"{stor['blowup']:.1f}x){breakdown}", file=file)


def run_service(args) -> int:
    import numpy as np

    from repro.core.index import build_index
    from repro.core.mapper import Mapper
    from repro.core.pipeline import MapperConfig
    from repro.core.serving import BatcherConfig
    from repro.data.genome import make_reference, sample_reads

    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    cfg = MapperConfig.from_index(idx, wf_backend=args.wf_backend,
                                  stream=not args.no_stream)
    mapper = Mapper(idx, cfg, topology=args.topology, n_shards=args.shards)
    svc = mapper.serve(BatcherConfig(bucket_min=args.bucket_min,
                                     bucket_max=args.bucket_max))
    rng = np.random.default_rng(7)
    print(f"service: genome {len(ref)} bases, buckets "
          f"[{args.bucket_min}..{args.bucket_max}], "
          f"topology={mapper.topology}, stream={cfg.stream}, "
          f"wf_backend={cfg.wf_backend}")
    total = correct = 0
    t0 = time.perf_counter()
    truth = {}
    for b in range(args.batches):
        # a burst of variable-sized client requests, then one flush
        for _ in range(int(rng.integers(1, 5))):
            n = int(rng.integers(1, args.reads + 1))
            rs = sample_reads(ref, n, seed=int(rng.integers(1 << 30)))
            truth[svc.submit(rs.reads)] = rs.true_pos
        for rid, res in svc.flush().items():
            total += len(res.position)
            correct += int((np.abs(res.position - truth.pop(rid)) <= 6).sum())
    dt = time.perf_counter() - t0
    st = svc.batcher.stats
    waste = st["padded_reads"] / max(st["padded_reads"] + st["reads"], 1)
    print(f"{total} reads / {st['requests']} requests in {dt:.1f}s "
          f"({total/dt:.0f} reads/s), accuracy {correct/max(total,1):.4f}")
    print(f"bucket hist {st['bucket_hist']}, lane padding waste {waste:.3f}")
    _print_mapper_stats(mapper, svc.totals)
    return 0


def run_distributed(args) -> int:
    import numpy as np

    from repro.core.index import build_index
    from repro.core.mapper import Mapper, accumulate_stats
    from repro.core.pipeline import MapperConfig
    from repro.data.genome import make_reference, sample_reads
    from repro.launch.mesh import make_genomics_mesh

    mesh = make_genomics_mesh(args.shards)
    n_shards = mesh.devices.size
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    cfg = MapperConfig.from_index(idx, wf_backend=args.wf_backend)
    mapper = Mapper(idx, cfg, topology="mesh", mesh=mesh,
                    send_cap=args.send_cap)
    print(f"serving: {n_shards} shards, {len(idx.uniq_kmers)} minimizers, "
          f"{len(ref)} bases")
    totals = dict(survivors=0, affine_instances=0,
                  padded_affine_instances=0, dropped_send=0,
                  dropped_affine=0, reverse_best=0)
    total = correct = 0
    t0 = time.perf_counter()
    for b in range(args.batches):
        rs = sample_reads(ref, args.reads, seed=1000 + b)
        res = mapper.map(rs.reads)
        total += len(res.position)
        correct += int((np.abs(res.position - rs.true_pos) <= 6).sum())
        accumulate_stats(totals, res.stats)
    dt = time.perf_counter() - t0
    print(f"{total} reads in {dt:.1f}s ({total/dt:.0f} reads/s), "
          f"accuracy {correct/total:.4f}, dropped {totals['dropped_send']}")
    _print_mapper_stats(mapper, totals)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service", action="store_true",
                    help="request batcher + Mapper session service mode")
    ap.add_argument("--topology", default="single",
                    choices=("single", "mesh"),
                    help="service mode only: execute buckets on the "
                         "single-shard streaming engine or route them onto "
                         "the distributed mesh mapper")
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--genome", type=int, default=50_000)
    ap.add_argument("--reads", type=int, default=128,
                    help="reads per batch (distributed) / max request size "
                         "(service)")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--send-cap", type=int, default=None)
    ap.add_argument("--bucket-min", type=int, default=64)
    ap.add_argument("--bucket-max", type=int, default=1024)
    ap.add_argument("--wf-backend", default="jnp",
                    choices=("jnp", "pallas"))
    ap.add_argument("--no-stream", action="store_true",
                    help="service mode only: synchronous debug path "
                         "(per-stage timings)")
    args, _ = ap.parse_known_args()
    if args.shards and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}")
    return run_service(args) if args.service else run_distributed(args)


if __name__ == "__main__":
    raise SystemExit(main())
