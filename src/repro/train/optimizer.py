"""AdamW with global-norm clipping and cosine schedule — pure JAX.

Optimizer state lives in fp32 (params too); sharding of the state follows
the param specs 1:1 (the launcher maps param_specs over (m, v)).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    global_norm: Callable


def adafactor(lr=1e-2, decay_pow=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, warmup=100,
              total_steps=10_000) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), momentum-free, factored 2nd moment.

    Optimizer state is O(rows + cols) per matrix instead of O(rows * cols) —
    the difference between a 235B-param config fitting a 16 GiB chip
    (~3.5 GiB param+state/device at 256-way sharding) and not (~10.3 GiB
    with Adam's full m, v).  State leaves per param: (vr, vc); for <2-D
    params vr holds the full second moment and vc is a scalar dummy.
    """
    sched = cosine_schedule(lr, warmup, total_steps)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"vr": jnp.zeros_like(p, dtype=jnp.float32),
                    "vc": jnp.zeros((), jnp.float32)}
        return jax.tree.map(per, params)

    def global_norm(tree):
        leaves = jax.tree.leaves(tree)
        return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                            for l in leaves))

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1
        beta2 = 1.0 - t ** (-decay_pow)
        lr_t = sched(step)

        def per(g, st, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                  eps)[..., None])
                u = g / jnp.maximum(denom, eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                vr = beta2 * st["vr"] + (1 - beta2) * g2
                u = g / jnp.sqrt(vr + eps)
                new_st = {"vr": vr, "vc": st["vc"]}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            upd = -lr_t * u - lr_t * weight_decay * p
            return upd.astype(p.dtype), new_st

        flat_u, flat_s = [], []
        g_l, s_l, p_l = (jax.tree.leaves(grads),
                         jax.tree.leaves(state,
                                         is_leaf=lambda x: isinstance(x, dict)
                                         and "vr" in x),
                         jax.tree.leaves(params))
        for g, st, p in zip(g_l, s_l, p_l):
            u, ns = per(g, st, p)
            flat_u.append(u)
            flat_s.append(ns)
        treedef = jax.tree.structure(params)
        return (jax.tree.unflatten(treedef, flat_u),
                jax.tree.unflatten(treedef, flat_s))

    return Optimizer(init=init, update=update, global_norm=global_norm)


def adafactor_state_specs(pspecs):
    """PartitionSpecs for adafactor state given the param spec tree."""
    from jax.sharding import PartitionSpec as P

    def per(s):
        s = tuple(s)
        vr = P(*s[:-1]) if len(s) >= 2 else P(*s)
        vc = P(*(s[:-2] + s[-1:])) if len(s) >= 2 else P()
        return {"vr": vr, "vc": vc}

    return jax.tree.map(per, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm=1.0, warmup=100, total_steps=10_000) -> Optimizer:
    sched = cosine_schedule(lr, warmup, total_steps)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def global_norm(tree):
        leaves = jax.tree.leaves(tree)
        return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                            for l in leaves))

    def update(grads, state, params, step):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        t = step.astype(jnp.float32) + 1
        mhat_s = 1.0 / (1 - b1 ** t)
        vhat_s = 1.0 / (1 - b2 ** t)
        lr_t = sched(step)
        updates = jax.tree.map(
            lambda mu, nu, p: -lr_t * (mu * mhat_s /
                                       (jnp.sqrt(nu * vhat_s) + eps)
                                       + weight_decay * p),
            m, v, params)
        return updates, {"m": m, "v": v}

    return Optimizer(init=init, update=update, global_norm=global_norm)
