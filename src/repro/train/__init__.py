from . import checkpoint, optimizer, trainer  # noqa: F401
