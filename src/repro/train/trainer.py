"""Training loop with checkpoint/restart, failure retry, straggler posture.

Scale design notes (how this runs on 1000+ nodes):
  * the step function is fully shape-static (no host-dependent shapes), so
    one compilation serves the whole run — no recompilation stragglers;
  * data is generated per-shard deterministically from (seed, step), so a
    replacement node reconstructs its shard without a data service;
  * transient step failures (preempted host, flaky interconnect) are
    retried ``max_retries`` times by replaying the SAME step — safe because
    the step is pure (params only advance on success);
  * restarts resume from the atomic checkpoint (see checkpoint.py), onto a
    possibly different mesh (elastic re-shard);
  * ``FaultInjector`` simulates node failures for tests/examples — this is
    how the fault path is exercised in CI without real hardware.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import checkpoint as ckpt_lib
from .optimizer import Optimizer, adamw
from ..data import tokens as token_data
from ..models import lm, transformer


class FaultInjector:
    """Deterministically raises on configured steps (simulated node loss)."""

    def __init__(self, fail_steps=(), exc=RuntimeError):
        self.fail_steps = set(fail_steps)
        self.exc = exc
        self.tripped = set()

    def check(self, step: int):
        if step in self.fail_steps and step not in self.tripped:
            self.tripped.add(step)
            raise self.exc(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    max_retries: int = 2
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, model_cfg, tcfg: TrainerConfig,
                 optimizer: Optimizer | None = None,
                 train_step_fn: Callable | None = None,
                 fault_injector: FaultInjector | None = None):
        self.cfg = model_cfg
        self.tcfg = tcfg
        self.optimizer = optimizer or adamw(total_steps=tcfg.total_steps)
        self.train_step = train_step_fn or jax.jit(
            lm.make_train_step(model_cfg, self.optimizer))
        self.faults = fault_injector
        self.metrics_log: list[dict] = []

    # ---- state management -------------------------------------------------
    def init_state(self, key=None):
        params = transformer.init_params(self.cfg,
                                         key or jax.random.key(self.tcfg.seed))
        return (params, self.optimizer.init(params), jnp.int32(0))

    def maybe_restore(self, state):
        d = self.tcfg.ckpt_dir
        if not d:
            return state, 0
        step = ckpt_lib.latest_step(d)
        if step is None:
            return state, 0
        state, extra = ckpt_lib.restore(d, step, state)
        return state, int(extra.get("next_step", step))

    # ---- data -------------------------------------------------------------
    def batch_for(self, step: int):
        toks, labels = token_data.batch_for_step(
            step, global_batch=self.tcfg.global_batch,
            seq_len=self.tcfg.seq_len, vocab_size=self.cfg.vocab_size,
            seed=self.tcfg.seed)
        if self.cfg.input_kind == "embeds":
            # modality-stub training: deterministic pseudo-embeddings
            rng = np.random.default_rng(step + self.tcfg.seed)
            emb = rng.standard_normal(
                (self.tcfg.global_batch, self.tcfg.seq_len,
                 self.cfg.d_model)).astype(np.float32) * 0.02
            return {"embeds": jnp.asarray(emb, jnp.bfloat16),
                    "labels": jnp.asarray(labels % self.cfg.vocab_size)}
        return {"tokens": jnp.asarray(toks % self.cfg.vocab_size),
                "labels": jnp.asarray(labels % self.cfg.vocab_size)}

    # ---- loop -------------------------------------------------------------
    def run(self, state=None) -> tuple:
        state = state if state is not None else self.init_state()
        state, start = self.maybe_restore(state)
        for step in range(start, self.tcfg.total_steps):
            batch = self.batch_for(step)
            for attempt in range(self.tcfg.max_retries + 1):
                try:
                    if self.faults is not None:
                        self.faults.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.train_step(state, batch)
                    dt = time.perf_counter() - t0
                    break
                except RuntimeError:
                    if attempt >= self.tcfg.max_retries:
                        raise
                    continue  # replay the same (pure) step
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                rec = {k: float(v) for k, v in metrics.items()}
                rec.update(step=step, step_time_s=dt)
                self.metrics_log.append(rec)
            if (self.tcfg.ckpt_dir and self.tcfg.ckpt_every
                    and (step + 1) % self.tcfg.ckpt_every == 0):
                ckpt_lib.save(self.tcfg.ckpt_dir, step + 1, state,
                              extra={"next_step": step + 1})
        if self.tcfg.ckpt_dir:
            ckpt_lib.save(self.tcfg.ckpt_dir, self.tcfg.total_steps, state,
                          extra={"next_step": self.tcfg.total_steps})
        return state
