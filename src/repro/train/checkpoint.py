"""Checkpointing with atomic writes and elastic restore.

Fault-tolerance contract (1000+-node posture):
  * **Atomic**: write to ``<dir>/tmp.<step>``, fsync, rename to
    ``<dir>/step_<step>`` — a crash mid-write never corrupts the latest
    checkpoint; ``latest_step`` only sees fully-renamed directories.
  * **Complete state**: params + optimizer state + step + data-pipeline
    cursor + RNG key.  Together with the deterministic-by-(seed, step)
    data pipeline this makes restart *exact* (replayed batches identical).
  * **Elastic**: arrays are stored fully-replicated as host numpy plus the
    logical PartitionSpec metadata; ``restore`` re-shards onto whatever
    mesh is active — the restart mesh may differ from the save mesh
    (node loss -> smaller mesh; scale-up -> larger), which is what
    "elastic scaling" means operationally.
  * **Retention**: ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state_tree, extra: dict | None = None,
         keep: int = 3) -> str:
    """state_tree: arbitrary pytree of arrays. extra: JSON-serializable."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(state_tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": np.asarray(jax.device_get(l))
                for i, l in enumerate(leaves)})
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, sharding_tree=None):
    """Restore into the structure of ``like_tree``; optionally placing each
    leaf with the given sharding (elastic re-shard onto the active mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like_tree)
    assert meta["n_leaves"] == len(leaves), \
        f"checkpoint has {meta['n_leaves']} leaves, expected {len(leaves)}"
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        assert tuple(old.shape) == tuple(new.shape), (old.shape, new.shape)
    if sharding_tree is not None:
        shard_leaves = jax.tree.flatten(sharding_tree)[0]
        new_leaves = [jax.device_put(l, s)
                      for l, s in zip(new_leaves, shard_leaves)]
    return jax.tree.unflatten(treedef, new_leaves), meta["extra"]
