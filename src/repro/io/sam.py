"""Spec-valid SAM emission + a dependency-free validator.

Only what the mapper actually produces is emitted, precisely:

* FLAG uses 0x4 (unmapped) and 0x10 (reverse strand) — single-end, so
  no pairing bits;
* POS is the 1-based, contig-local leftmost position (the mapper's
  global concatenated position goes through ``fasta.ReferenceMap``);
* CIGAR comes from the affine-WF traceback via ``cigar.cigar_from_ops``
  (``"*"`` on the mesh topology, whose stage B never tracebacks, and on
  the ``max_ops`` truncation path);
* SEQ/QUAL are stored in *alignment* orientation per the SAM spec:
  reverse-strand hits store the reverse-complemented read and reversed
  qualities (exactly the orientation the engine aligned);
* NM:i carries the affine-WF distance — the paper's alignment cost
  (gap-open + gap-extend weighted), deliberately *not* the SAM spec's
  literal mismatch+gap-base count, and computed over the full traceback
  (including any edge deletions the CIGAR normalization trims).

``validate_sam`` is the boundary's test oracle: a small, dependency-free
checker (header shape, mandatory columns, FLAG/CIGAR/SEQ consistency)
that CI runs against the ``map_fastq`` output of both topologies.
"""
from __future__ import annotations

from typing import Iterable, Iterator

import numpy as np

from ..core.encoding import decode_to_str, revcomp
from .cigar import (cigar_from_ops, cigar_query_len, cigar_ref_len,
                    parse_cigar, trim_edge_deletions, unparse_cigar)
from .fasta import Contig, ReferenceMap

FLAG_PAIRED = 0x1
FLAG_PROPER = 0x2
FLAG_UNMAPPED = 0x4
FLAG_MATE_UNMAPPED = 0x8
FLAG_REVERSE = 0x10
FLAG_MATE_REVERSE = 0x20
FLAG_READ1 = 0x40
FLAG_READ2 = 0x80
MAPQ_UNAVAILABLE = 255   # single-end path: no mapping-quality model

# FLAG bits that are only meaningful on paired templates (spec 1.4)
_PAIRED_ONLY_FLAGS = (FLAG_PROPER | FLAG_MATE_UNMAPPED | FLAG_MATE_REVERSE
                      | FLAG_READ1 | FLAG_READ2)


def sam_header(contigs: list[Contig], *, program_id: str = "repro",
               program_name: str = "repro.launch.map_fastq",
               command_line: str | None = None) -> list[str]:
    """@HD/@SQ/@PG header lines (unsorted output)."""
    lines = ["@HD\tVN:1.6\tSO:unsorted"]
    lines += [f"@SQ\tSN:{c.name}\tLN:{c.length}" for c in contigs]
    pg = f"@PG\tID:{program_id}\tPN:{program_name}"
    if command_line:
        pg += f"\tCL:{command_line}"
    return lines + [pg]


def sam_record(qname: str, flag: int, rname: str, pos: int, mapq: int,
               cigar: str, seq: str, qual: str, *, rnext: str = "*",
               pnext: int = 0, tlen: int = 0,
               nm: int | None = None) -> str:
    """One alignment line.  The single-end defaults keep RNEXT/PNEXT/TLEN
    at ``*``/0/0; the paired emitter passes real mate fields."""
    fields = [qname, str(flag), rname, str(pos), str(mapq), cigar,
              rnext, str(pnext), str(tlen), seq, qual]
    if nm is not None:
        fields.append(f"NM:i:{nm}")
    return "\t".join(fields)


def _qual_str(q: np.ndarray) -> str:
    return q.tobytes().decode("ascii")


# complement for raw sequence text; non-ACGT (N, IUPAC codes) self-map so
# the emitted SEQ never invents bases the input didn't have
_COMP_TABLE = str.maketrans("ACGTacgt", "TGCAtgca")


def _revcomp_str(seq: str) -> str:
    return seq.translate(_COMP_TABLE)[::-1]


def _mapped_fields(result, i: int, reads, quals, seqs,
                   refmap: ReferenceMap):
    """Placement + sequence fields of one *mapped* record: ``(contig,
    local_pos0, cigar, seq, qual_str, rev)``.  The single place where the
    edge-deletion CIGAR normalization, the post-shift contig resolution,
    and the alignment-orientation SEQ/QUAL flips happen — shared by the
    single-end and paired emitters so their records cannot drift."""
    strand = result.strand
    rev = bool(strand[i]) if strand is not None else False
    cig, shift = "*", 0
    if result.ops is not None:
        cig = cigar_from_ops(result.ops[i], int(result.op_count[i]))
        if cig != "*":
            trimmed, shift = trim_edge_deletions(parse_cigar(cig))
            cig = unparse_cigar(trimmed)
    # locate AFTER the edge-deletion shift: a leading-deletion
    # alignment seeded just inside the inter-contig spacer belongs to
    # the contig its first aligned base lands in, not its neighbour
    contig, local = refmap.locate(int(result.position[i]) + shift)
    if seqs is not None:
        seq = _revcomp_str(seqs[i]) if rev else seqs[i]
    else:
        seq = decode_to_str(revcomp(reads[i]) if rev else reads[i])
    qual = quals[i][::-1] if rev else quals[i]
    return contig, local, cig, seq, _qual_str(qual), rev


def emit_alignments(result, names: list[str], reads: np.ndarray,
                    quals: np.ndarray, refmap: ReferenceMap, *,
                    seqs: list[str] | None = None) -> Iterator[str]:
    """MappingResult batch -> SAM record lines (single-end).

    ``reads``/``quals`` are in *as-sequenced* orientation; reverse-strand
    hits (``result.strand == 1``) are flipped here.  ``result.ops`` may
    be None (mesh topology) — those records carry CIGAR ``"*"``.

    Pass ``seqs`` (the raw FASTQ sequence text, e.g. ``ReadChunk.seqs``)
    to emit SEQ verbatim — the engine's codes rewrite N to A for k-mer
    seeding, and SAM output must not present those as real A bases.
    """
    for i, name in enumerate(names):
        if not result.mapped[i]:
            seq = seqs[i] if seqs is not None else decode_to_str(reads[i])
            yield sam_record(name, FLAG_UNMAPPED, "*", 0, 0, "*",
                             seq, _qual_str(quals[i]))
            continue
        contig, local, cig, seq, qual, rev = _mapped_fields(
            result, i, reads, quals, seqs, refmap)
        yield sam_record(name, FLAG_REVERSE if rev else 0, contig.name,
                         local + 1, MAPQ_UNAVAILABLE, cig, seq,
                         qual, nm=int(result.distance[i]))


def emit_paired_alignments(pairs, names: list[str],
                           reads1, quals1, reads2, quals2,
                           refmap: ReferenceMap, *,
                           seqs1: list[str] | None = None,
                           seqs2: list[str] | None = None) -> Iterator[str]:
    """PairResolution batch -> interleaved R1/R2 SAM record lines.

    ``pairs`` is a ``repro.core.pairing.PairResolution``; ``names`` are
    the shared template QNAMEs (``PairedFastqStream`` chunk names).  Per
    pair the two records carry the full FLAG pairing algebra (0x1
    always; 0x40/0x80 mate identity; 0x2 on proper pairs; 0x8/0x20
    mirroring the mate's state), RNEXT ``=``/contig/``*``, PNEXT, and
    symmetric TLEN (leftmost mate positive; ties broken toward R1), plus
    the calibrated MAPQ from the pair resolution.  Unmapped mates keep
    the validator's unmapped shape (RNAME ``*``, POS 0, CIGAR ``*``) but
    still point RNEXT/PNEXT at a mapped mate's locus.
    """
    res = (pairs.res1, pairs.res2)
    reads = (reads1, reads2)
    quals = (quals1, quals2)
    seqs = (seqs1, seqs2)
    mapqs = (pairs.mapq1, pairs.mapq2)
    mate_flag = (FLAG_READ1, FLAG_READ2)
    for i, name in enumerate(names):
        mapped = [bool(res[m].mapped[i]) for m in (0, 1)]
        fields = [
            _mapped_fields(res[m], i, reads[m], quals[m], seqs[m], refmap)
            if mapped[m] else None
            for m in (0, 1)]
        proper = bool(pairs.proper[i])
        # reference footprint per mate (for TLEN): CIGAR when present,
        # read length otherwise (the mesh path's CIGAR-less records)
        span = [None, None]
        for m in (0, 1):
            if mapped[m]:
                contig, local, cig, _, _, _ = fields[m]
                ref_len = (cigar_ref_len(cig) if cig != "*"
                           else np.asarray(reads[m]).shape[1])
                span[m] = (contig, local, local + ref_len)
        tlen = [0, 0]
        if mapped[0] and mapped[1] and span[0][0] is span[1][0]:
            lo = min(span[0][1], span[1][1])
            hi = max(span[0][2], span[1][2])
            if (span[0][1], 0) <= (span[1][1], 1):  # ties: R1 leftmost
                tlen = [hi - lo, lo - hi]
            else:
                tlen = [lo - hi, hi - lo]
        for m in (0, 1):
            o = 1 - m
            flag = FLAG_PAIRED | mate_flag[m]
            if proper:
                flag |= FLAG_PROPER
            if not mapped[m]:
                flag |= FLAG_UNMAPPED
            if not mapped[o]:
                flag |= FLAG_MATE_UNMAPPED
            if mapped[o] and fields[o][5]:
                flag |= FLAG_MATE_REVERSE
            if not mapped[m]:
                seq = (seqs[m][i] if seqs[m] is not None
                       else decode_to_str(reads[m][i]))
                rnext, pnext = "*", 0
                if mapped[o]:  # point at the mate so the pair stays
                    #            co-locatable in sorted output
                    rnext = fields[o][0].name
                    pnext = fields[o][1] + 1
                yield sam_record(name, flag, "*", 0, 0, "*", seq,
                                 _qual_str(quals[m][i]), rnext=rnext,
                                 pnext=pnext, tlen=0)
                continue
            contig, local, cig, seq, qual, rev = fields[m]
            if rev:
                flag |= FLAG_REVERSE
            rnext, pnext = "*", 0
            if mapped[o]:
                o_contig, o_local = fields[o][0], fields[o][1]
                rnext = "=" if o_contig is contig else o_contig.name
                pnext = o_local + 1
            yield sam_record(name, flag, contig.name, local + 1,
                             int(mapqs[m][i]), cig, seq, qual,
                             rnext=rnext, pnext=pnext, tlen=tlen[m],
                             nm=int(res[m].distance[i]))


def write_sam(handle, header_lines: Iterable[str],
              records: Iterable[str]) -> int:
    """Write header + records; returns the record count."""
    for line in header_lines:
        handle.write(line + "\n")
    n = 0
    for rec in records:
        handle.write(rec + "\n")
        n += 1
    return n


# --------------------------------------------------------------------------
# Dependency-free validator (the tests/CI oracle for this boundary)
# --------------------------------------------------------------------------

def _check(cond: bool, msg: str) -> None:
    """Explicit raise instead of ``assert``: the validator must keep
    validating under ``python -O`` (asserts are stripped there)."""
    if not cond:
        raise AssertionError(msg)


def validate_sam(text: str, *, expect_reads: int | None = None,
                 require_mapq: bool = False) -> dict:
    """Check a SAM document's structural invariants; raise on violation.

    Record checks: @HD first with a VN; at least one @SQ with SN/LN;
    every record has >= 11 tab-separated mandatory columns with
    well-typed FLAG/POS/MAPQ; unmapped records (FLAG 0x4) carry */0/*;
    mapped records name a known @SQ contig, sit inside [1, LN], and any
    non-``*`` CIGAR consumes exactly ``len(SEQ)`` query bases; QUAL
    length matches SEQ; RNEXT is ``*``, ``=`` or a known contig, with
    ``=`` only legal on a mapped record (an RNAME to equal), PNEXT
    inside the mate contig, and ``*`` implying PNEXT/TLEN 0; the
    paired-only FLAG bits (0x2/0x8/0x20/0x40/0x80) appear only with 0x1.

    Pair checks (templates whose records set 0x1): exactly two primary
    records per QNAME, one 0x40 and one 0x80; the 0x2/proper bit equal
    on both mates and only set when both are mapped; each record's 0x8
    mirrors its mate's 0x4 and its 0x20 mirrors its mate's 0x10;
    TLEN(R1) == -TLEN(R2); RNEXT/PNEXT resolve to the mate's RNAME/POS.

    ``require_mapq=True`` additionally demands a *computed* mapping
    quality on every mapped record — MAPQ in [0, 254], rejecting the 255
    "unavailable" placeholder (the paired path always computes one).

    Returns summary counts.
    """
    lines = [ln for ln in text.split("\n") if ln != ""]
    _check(bool(lines) and lines[0].startswith("@HD\t"),
           "missing @HD header")
    _check("VN:" in lines[0], "@HD lacks VN")
    sq = {}
    n_header = 0
    for ln in lines:
        if not ln.startswith("@"):
            break
        n_header += 1
        if ln.startswith("@SQ"):
            tags = dict(t.split(":", 1) for t in ln.split("\t")[1:])
            _check("SN" in tags and "LN" in tags, f"bad @SQ line: {ln!r}")
            sq[tags["SN"]] = int(tags["LN"])
    _check(bool(sq), "no @SQ lines")
    n = n_mapped = n_reverse = n_paired = n_proper = 0
    templates: dict[str, list] = {}
    for ln in lines[n_header:]:
        _check(not ln.startswith("@"), "header line after records")
        f = ln.split("\t")
        _check(len(f) >= 11, f"record has {len(f)} < 11 columns: {ln!r}")
        qname, flag, rname, pos, mapq, cig, rnext, pnext, tlen, seq, \
            qual = f[:11]
        flag, pos, mapq = int(flag), int(pos), int(mapq)
        pnext, tlen = int(pnext), int(tlen)
        _check(bool(qname) and 0 <= mapq <= 255, f"bad QNAME/MAPQ: {ln!r}")
        _check(len(qual) == len(seq), f"QUAL/SEQ length mismatch: {ln!r}")
        mapped = not (flag & FLAG_UNMAPPED)
        if require_mapq and mapped:
            _check(mapq <= 254, f"mapped record with MAPQ {mapq} outside "
                                f"[0, 254] (255 = 'unavailable'): {ln!r}")
        # mate placement fields are checked on every record, paired or not
        _check(rnext == "*" or rnext == "=" or rnext in sq,
               f"RNEXT {rnext!r} is neither *, = nor an @SQ contig: {ln!r}")
        _check(rnext != "=" or rname != "*",
               f"RNEXT '=' but RNAME is '*' (no contig to equal): {ln!r}")
        if rnext == "*":
            _check(pnext == 0 and tlen == 0,
                   f"RNEXT '*' with PNEXT/TLEN set: {ln!r}")
        else:
            mate_contig = rname if rnext == "=" else rnext
            _check(0 <= pnext <= sq[mate_contig],
                   f"PNEXT {pnext} outside [0, {sq[mate_contig]}]: {ln!r}")
        if not (flag & FLAG_PAIRED):
            _check(not (flag & _PAIRED_ONLY_FLAGS),
                   f"paired-only FLAG bits without 0x1: {ln!r}")
        else:
            n_paired += 1
            templates.setdefault(qname, []).append(
                (flag, rname, pos, rnext, pnext, tlen, ln))
        n += 1
        if not mapped:
            _check(rname == "*" and pos == 0 and cig == "*",
                   f"unmapped record with placement fields: {ln!r}")
            continue
        n_mapped += 1
        n_reverse += bool(flag & FLAG_REVERSE)
        _check(rname in sq, f"RNAME {rname!r} not in @SQ")
        _check(1 <= pos <= sq[rname], f"POS {pos} outside [1, {sq[rname]}]")
        if cig != "*":
            _check(cigar_query_len(cig) == len(seq),
                   f"CIGAR consumes {cigar_query_len(cig)} query bases "
                   f"but SEQ has {len(seq)}: {ln!r}")
            parsed = parse_cigar(cig)
            _check(parsed[0][1] != "D" and parsed[-1][1] != "D",
                   f"CIGAR begins/ends with a deletion: {ln!r}")
            end = pos + cigar_ref_len(cig) - 1
            _check(end <= sq[rname],
                   f"alignment footprint [{pos}, {end}] extends past "
                   f"{rname}'s LN {sq[rname]}: {ln!r}")
    for qname, recs in templates.items():
        n_proper += _check_pair(qname, recs)
    if expect_reads is not None:
        _check(n == expect_reads, f"{n} records != {expect_reads} reads")
    return dict(n_records=n, n_mapped=n_mapped, n_reverse=n_reverse,
                n_paired=n_paired, n_proper=n_proper, contigs=sq)


def _check_pair(qname: str, recs: list) -> int:
    """Cross-record consistency of one paired template; returns 1 when
    the pair is proper (0x2) so the caller can count them."""
    _check(len(recs) == 2,
           f"template {qname!r} has {len(recs)} paired records, not 2")
    a, b = recs
    for (flag, _, _, _, _, _, ln) in recs:
        _check(bool(flag & FLAG_READ1) != bool(flag & FLAG_READ2),
               f"paired record needs exactly one of 0x40/0x80: {ln!r}")
    _check(bool(a[0] & FLAG_READ1) != bool(b[0] & FLAG_READ1),
           f"template {qname!r}: both records claim the same mate slot")
    for (flag, rname, _, rnext, pnext, _, ln), \
            (oflag, orname, opos, _, _, _, _) in ((a, b), (b, a)):
        mate_unmapped = bool(oflag & FLAG_UNMAPPED)
        _check(bool(flag & FLAG_MATE_UNMAPPED) == mate_unmapped,
               f"0x8 does not mirror the mate's 0x4: {ln!r}")
        _check(bool(flag & FLAG_MATE_REVERSE)
               == (not mate_unmapped and bool(oflag & FLAG_REVERSE)),
               f"0x20 does not mirror the mate's 0x10: {ln!r}")
        _check(bool(flag & FLAG_PROPER) == bool(oflag & FLAG_PROPER),
               f"0x2 differs between mates: {ln!r}")
        if flag & FLAG_PROPER:
            _check(not (flag & FLAG_UNMAPPED) and not mate_unmapped,
                   f"proper pair (0x2) with an unmapped mate: {ln!r}")
        if not mate_unmapped:
            resolved = rname if rnext == "=" else rnext
            _check(resolved == orname and pnext == opos,
                   f"RNEXT/PNEXT ({resolved!r}, {pnext}) do not point at "
                   f"the mate's RNAME/POS ({orname!r}, {opos}): {ln!r}")
    _check(a[5] == -b[5],
           f"TLEN not symmetric for {qname!r}: {a[5]} vs {b[5]}")
    return int(bool(a[0] & FLAG_PROPER))
