"""Spec-valid SAM emission + a dependency-free validator.

Only what the mapper actually produces is emitted, precisely:

* FLAG uses 0x4 (unmapped) and 0x10 (reverse strand) — single-end, so
  no pairing bits;
* POS is the 1-based, contig-local leftmost position (the mapper's
  global concatenated position goes through ``fasta.ReferenceMap``);
* CIGAR comes from the affine-WF traceback via ``cigar.cigar_from_ops``
  (``"*"`` on the mesh topology, whose stage B never tracebacks, and on
  the ``max_ops`` truncation path);
* SEQ/QUAL are stored in *alignment* orientation per the SAM spec:
  reverse-strand hits store the reverse-complemented read and reversed
  qualities (exactly the orientation the engine aligned);
* NM:i carries the affine-WF distance — the paper's alignment cost
  (gap-open + gap-extend weighted), deliberately *not* the SAM spec's
  literal mismatch+gap-base count, and computed over the full traceback
  (including any edge deletions the CIGAR normalization trims).

``validate_sam`` is the boundary's test oracle: a small, dependency-free
checker (header shape, mandatory columns, FLAG/CIGAR/SEQ consistency)
that CI runs against the ``map_fastq`` output of both topologies.
"""
from __future__ import annotations

from typing import Iterable, Iterator

import numpy as np

from ..core.encoding import decode_to_str, revcomp
from .cigar import (cigar_from_ops, cigar_query_len, cigar_ref_len,
                    parse_cigar, trim_edge_deletions, unparse_cigar)
from .fasta import Contig, ReferenceMap

FLAG_UNMAPPED = 0x4
FLAG_REVERSE = 0x10
MAPQ_UNAVAILABLE = 255   # the mapper computes no mapping-quality model


def sam_header(contigs: list[Contig], *, program_id: str = "repro",
               program_name: str = "repro.launch.map_fastq",
               command_line: str | None = None) -> list[str]:
    """@HD/@SQ/@PG header lines (unsorted single-end output)."""
    lines = ["@HD\tVN:1.6\tSO:unsorted"]
    lines += [f"@SQ\tSN:{c.name}\tLN:{c.length}" for c in contigs]
    pg = f"@PG\tID:{program_id}\tPN:{program_name}"
    if command_line:
        pg += f"\tCL:{command_line}"
    return lines + [pg]


def sam_record(qname: str, flag: int, rname: str, pos: int, mapq: int,
               cigar: str, seq: str, qual: str, *,
               nm: int | None = None) -> str:
    """One alignment line (RNEXT/PNEXT/TLEN are */0/0: single-end)."""
    fields = [qname, str(flag), rname, str(pos), str(mapq), cigar,
              "*", "0", "0", seq, qual]
    if nm is not None:
        fields.append(f"NM:i:{nm}")
    return "\t".join(fields)


def _qual_str(q: np.ndarray) -> str:
    return q.tobytes().decode("ascii")


# complement for raw sequence text; non-ACGT (N, IUPAC codes) self-map so
# the emitted SEQ never invents bases the input didn't have
_COMP_TABLE = str.maketrans("ACGTacgt", "TGCAtgca")


def _revcomp_str(seq: str) -> str:
    return seq.translate(_COMP_TABLE)[::-1]


def emit_alignments(result, names: list[str], reads: np.ndarray,
                    quals: np.ndarray, refmap: ReferenceMap, *,
                    seqs: list[str] | None = None) -> Iterator[str]:
    """MappingResult batch -> SAM record lines.

    ``reads``/``quals`` are in *as-sequenced* orientation; reverse-strand
    hits (``result.strand == 1``) are flipped here.  ``result.ops`` may
    be None (mesh topology) — those records carry CIGAR ``"*"``.

    Pass ``seqs`` (the raw FASTQ sequence text, e.g. ``ReadChunk.seqs``)
    to emit SEQ verbatim — the engine's codes rewrite N to A for k-mer
    seeding, and SAM output must not present those as real A bases.
    """
    strand = result.strand
    for i, name in enumerate(names):
        if not result.mapped[i]:
            seq = seqs[i] if seqs is not None else decode_to_str(reads[i])
            yield sam_record(name, FLAG_UNMAPPED, "*", 0, 0, "*",
                             seq, _qual_str(quals[i]))
            continue
        rev = bool(strand[i]) if strand is not None else False
        cig, shift = "*", 0
        if result.ops is not None:
            cig = cigar_from_ops(result.ops[i], int(result.op_count[i]))
            if cig != "*":
                trimmed, shift = trim_edge_deletions(parse_cigar(cig))
                cig = unparse_cigar(trimmed)
        # locate AFTER the edge-deletion shift: a leading-deletion
        # alignment seeded just inside the inter-contig spacer belongs to
        # the contig its first aligned base lands in, not its neighbour
        contig, local = refmap.locate(int(result.position[i]) + shift)
        if seqs is not None:
            seq = _revcomp_str(seqs[i]) if rev else seqs[i]
        else:
            seq = decode_to_str(revcomp(reads[i]) if rev else reads[i])
        qual = quals[i][::-1] if rev else quals[i]
        yield sam_record(name, FLAG_REVERSE if rev else 0, contig.name,
                         local + 1, MAPQ_UNAVAILABLE, cig, seq,
                         _qual_str(qual), nm=int(result.distance[i]))


def write_sam(handle, header_lines: Iterable[str],
              records: Iterable[str]) -> int:
    """Write header + records; returns the record count."""
    for line in header_lines:
        handle.write(line + "\n")
    n = 0
    for rec in records:
        handle.write(rec + "\n")
        n += 1
    return n


# --------------------------------------------------------------------------
# Dependency-free validator (the tests/CI oracle for this boundary)
# --------------------------------------------------------------------------

def _check(cond: bool, msg: str) -> None:
    """Explicit raise instead of ``assert``: the validator must keep
    validating under ``python -O`` (asserts are stripped there)."""
    if not cond:
        raise AssertionError(msg)


def validate_sam(text: str, *, expect_reads: int | None = None) -> dict:
    """Check a SAM document's structural invariants; raise on violation.

    Checks: @HD first with a VN; at least one @SQ with SN/LN; every
    record has >= 11 tab-separated mandatory columns with well-typed
    FLAG/POS/MAPQ; unmapped records (FLAG 0x4) carry */0/*; mapped
    records name a known @SQ contig, sit inside [1, LN], and any
    non-``*`` CIGAR consumes exactly ``len(SEQ)`` query bases; QUAL
    length matches SEQ.  Returns summary counts.
    """
    lines = [ln for ln in text.split("\n") if ln != ""]
    _check(bool(lines) and lines[0].startswith("@HD\t"),
           "missing @HD header")
    _check("VN:" in lines[0], "@HD lacks VN")
    sq = {}
    n_header = 0
    for ln in lines:
        if not ln.startswith("@"):
            break
        n_header += 1
        if ln.startswith("@SQ"):
            tags = dict(t.split(":", 1) for t in ln.split("\t")[1:])
            _check("SN" in tags and "LN" in tags, f"bad @SQ line: {ln!r}")
            sq[tags["SN"]] = int(tags["LN"])
    _check(bool(sq), "no @SQ lines")
    n = n_mapped = n_reverse = 0
    for ln in lines[n_header:]:
        _check(not ln.startswith("@"), "header line after records")
        f = ln.split("\t")
        _check(len(f) >= 11, f"record has {len(f)} < 11 columns: {ln!r}")
        qname, flag, rname, pos, mapq, cig, _, _, _, seq, qual = f[:11]
        flag, pos, mapq = int(flag), int(pos), int(mapq)
        _check(bool(qname) and 0 <= mapq <= 255, f"bad QNAME/MAPQ: {ln!r}")
        _check(len(qual) == len(seq), f"QUAL/SEQ length mismatch: {ln!r}")
        n += 1
        if flag & FLAG_UNMAPPED:
            _check(rname == "*" and pos == 0 and cig == "*",
                   f"unmapped record with placement fields: {ln!r}")
            continue
        n_mapped += 1
        n_reverse += bool(flag & FLAG_REVERSE)
        _check(rname in sq, f"RNAME {rname!r} not in @SQ")
        _check(1 <= pos <= sq[rname], f"POS {pos} outside [1, {sq[rname]}]")
        if cig != "*":
            _check(cigar_query_len(cig) == len(seq),
                   f"CIGAR consumes {cigar_query_len(cig)} query bases "
                   f"but SEQ has {len(seq)}: {ln!r}")
            parsed = parse_cigar(cig)
            _check(parsed[0][1] != "D" and parsed[-1][1] != "D",
                   f"CIGAR begins/ends with a deletion: {ln!r}")
            end = pos + cigar_ref_len(cig) - 1
            _check(end <= sq[rname],
                   f"alignment footprint [{pos}, {end}] extends past "
                   f"{rname}'s LN {sq[rname]}: {ln!r}")
    if expect_reads is not None:
        _check(n == expect_reads, f"{n} records != {expect_reads} reads")
    return dict(n_records=n, n_mapped=n_mapped, n_reverse=n_reverse,
                contigs=sq)
