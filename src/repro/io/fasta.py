"""Streaming multi-record FASTA parsing (reference ingestion).

Real references are multi-contig and carry ambiguity codes; the mapping
core works on one flat ``uint8`` array.  The bridge is deliberate:

* every non-ACGT base (N and the rarer IUPAC codes) maps to the index's
  ``SENTINEL`` (4), which never equals a read base — a candidate window
  overlapping an N run pays one edit per N, so mapping *near* ambiguity
  is allowed and mapping *onto* it is rejected by the linear-WF filter,
  with no special casing downstream;
* contigs are concatenated with a run of ``spacer`` sentinel bases
  between them, so no read can align across a contig boundary (the
  spacer is sized >= one full alignment window);
* the ``Contig`` table remembers each contig's name/length/offset, and
  ``ReferenceMap`` converts the mapper's global positions back to
  SAM-style (contig, 1-based local) coordinates.

Parsing streams the file line by line (no whole-file string), so a
reference is held once as codes, never twice as text.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, TextIO

import numpy as np

from ..core.index import SENTINEL

# non-ACGT -> SENTINEL (index.SENTINEL never matches a read base)
_REF_LUT = np.full(256, SENTINEL, dtype=np.uint8)
for _i, _c in enumerate("ACGT"):
    _REF_LUT[ord(_c)] = _i
    _REF_LUT[ord(_c.lower())] = _i


def _open(path_or_handle, mode="r"):
    """Open a path (gzip-transparent) or pass a handle through.

    Returns ``(handle, owned)``.  Paths ending in ``.gz`` open through
    ``gzip`` in text mode, so every reader and writer built on this —
    FASTA/FASTQ parsing, the simulator's ``write_fasta``/``write_fastq``
    — handles ``.fastq.gz`` files with zero caller changes.  Compression
    is detected by extension, not magic bytes: a misnamed file fails fast
    in the parser instead of silently streaming gzip framing as bases.
    """
    if hasattr(path_or_handle, "read") or hasattr(path_or_handle, "write"):
        return path_or_handle, False
    if str(path_or_handle).endswith(".gz"):
        import gzip
        return gzip.open(path_or_handle, mode + "t"), True
    return open(path_or_handle, mode), True


def encode_ref_line(line: str) -> np.ndarray:
    """ASCII reference bases -> uint8 codes, non-ACGT -> SENTINEL."""
    return _REF_LUT[np.frombuffer(line.encode("ascii"), dtype=np.uint8)]


@dataclasses.dataclass(frozen=True)
class Contig:
    """One reference sequence and where it landed in the flat array."""
    name: str
    length: int
    offset: int       # start in the concatenated reference


def parse_fasta(path_or_handle) -> Iterator[tuple[str, np.ndarray]]:
    """Yield ``(name, codes)`` per record, streaming line by line.

    ``name`` is the first whitespace-delimited token of the header (the
    SAM ``SN`` convention); ``codes`` is uint8 with non-ACGT -> SENTINEL.
    """
    f, owned = _open(path_or_handle)
    try:
        name, parts = None, []
        for raw in f:
            line = raw.strip()
            if not line:
                continue
            if line.startswith(">"):
                if name is not None:
                    yield name, (np.concatenate(parts) if parts else
                                 np.zeros(0, np.uint8))
                name, parts = line[1:].split()[0] if len(line) > 1 else "", []
                if not name:
                    raise ValueError("FASTA record with empty header name")
            else:
                if name is None:
                    raise ValueError("FASTA sequence data before any "
                                     "'>' header line")
                parts.append(encode_ref_line(line))
        if name is not None:
            yield name, (np.concatenate(parts) if parts else
                         np.zeros(0, np.uint8))
    finally:
        if owned:
            f.close()


def stream_fasta(path_or_handle, *,
                 max_chunk: int = 1 << 20,
                 ) -> Iterator[tuple[str, np.ndarray, bool]]:
    """Yield ``(name, codes_chunk, is_last)`` streaming each contig in
    bounded pieces, never holding a whole contig.

    Unlike :func:`parse_fasta` (which concatenates a record before
    yielding it), this caps resident sequence at ~``max_chunk`` bases —
    the ingestion contract the out-of-core index builder
    (``repro.index.build``) needs so a chromosome-sized contig costs
    tile-sized memory.  ``is_last`` marks the final chunk of a record;
    a record with no sequence lines yields one empty last chunk so
    callers can reject it by name.
    """
    f, owned = _open(path_or_handle)
    try:
        name, parts, buffered = None, [], 0

        def flush(last: bool):
            nonlocal parts, buffered
            chunk = (np.concatenate(parts) if parts else
                     np.zeros(0, np.uint8))
            parts, buffered = [], 0
            return name, chunk, last

        for raw in f:
            line = raw.strip()
            if not line:
                continue
            if line.startswith(">"):
                if name is not None:
                    yield flush(True)
                name = line[1:].split()[0] if len(line) > 1 else ""
                if not name:
                    raise ValueError("FASTA record with empty header name")
            else:
                if name is None:
                    raise ValueError("FASTA sequence data before any "
                                     "'>' header line")
                codes = encode_ref_line(line)
                parts.append(codes)
                buffered += len(codes)
                if buffered >= max_chunk:
                    yield flush(False)
        if name is not None:
            yield flush(True)
    finally:
        if owned:
            f.close()


class ReferenceMap:
    """Global (concatenated) position <-> per-contig coordinates."""

    def __init__(self, contigs: list[Contig]):
        if not contigs:
            raise ValueError("empty reference: no contigs")
        self.contigs = contigs
        self._starts = np.array([c.offset for c in contigs], dtype=np.int64)

    def locate(self, pos: int) -> tuple[Contig, int]:
        """Global position -> ``(contig, 0-based local position)``.

        The mapper's band allows an alignment start a few bases off the
        seeded position, so a global position inside a spacer is
        attributed to the *nearest* contig edge — a start just before
        contig ``i+1`` belongs to ``i+1``'s first base, not ``i``'s last
        — and clamped into it.
        """
        i = int(np.searchsorted(self._starts, pos, side="right")) - 1
        i = max(i, 0)
        c = self.contigs[i]
        if pos >= c.offset + c.length and i + 1 < len(self.contigs):
            nxt = self.contigs[i + 1]
            if nxt.offset - pos <= pos - (c.offset + c.length - 1):
                c = nxt
        return c, int(np.clip(pos - c.offset, 0, max(c.length - 1, 0)))


def load_reference(path_or_handle, *, spacer: int, on_error: str = "strict",
                   rejected: list | None = None,
                   ) -> tuple[np.ndarray, list[Contig]]:
    """Multi-record FASTA -> (flat uint8 reference, contig table).

    Contigs are joined by ``spacer`` SENTINEL bases (size it >= one
    alignment window, ``read_len + 2*eth``, so no read maps across a
    boundary).  Degenerate records — empty sequence, or *only* non-ACGT
    bases (an all-SENTINEL contig is indistinguishable from its spacer
    and can never be mapped onto) — are rejected: ``on_error="strict"``
    raises naming the contig; ``on_error="permissive"`` skips the contig
    and appends ``(name, reason)`` to ``rejected`` (when given), so a
    draft assembly full of N-only scaffolds still loads.
    """
    if on_error not in ("strict", "permissive"):
        raise ValueError(f"on_error={on_error!r}; expected 'strict' or "
                         f"'permissive'")
    parts, contigs, off = [], [], 0
    for name, codes in parse_fasta(path_or_handle):
        reason = ("no sequence" if len(codes) == 0 else
                  "only non-ACGT (sentinel) bases"
                  if (codes == SENTINEL).all() else None)
        if reason is not None:
            if on_error == "strict":
                raise ValueError(f"FASTA contig {name!r} has {reason}")
            if rejected is not None:
                rejected.append((name, reason))
            continue
        if contigs:
            parts.append(np.full(spacer, SENTINEL, dtype=np.uint8))
            off += spacer
        contigs.append(Contig(name=name, length=len(codes), offset=off))
        parts.append(codes)
        off += len(codes)
    if not contigs:
        raise ValueError("empty FASTA: no records (or none usable)")
    return np.concatenate(parts), contigs
