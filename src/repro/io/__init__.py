"""Genomics I/O boundary: standard formats in, standard formats out.

DART-PIM's headline evaluation is end-to-end on real data (HG38 +
HiSeq-X reads); the comparability bar for any reproduction is therefore
the standard-format boundary — FASTA references and FASTQ read sets in,
SAM alignments out (Alser et al., arXiv:2008.00961; Diab et al.,
arXiv:2208.01243 treat exactly this as the accelerator-framework
contract).  This package is that boundary:

  ``fasta``  — streaming multi-record FASTA parsing (N -> sentinel) and
               the concatenated-reference + contig-table view the index
               builder consumes.
  ``fastq``  — streaming FASTQ parsing into fixed-shape, ``chunk_reads``
               sized batches that feed the async streaming engine
               without materializing the file.
  ``cigar``  — END-aligned traceback ops -> CIGAR strings (and back).
  ``sam``    — spec-valid SAM emission (header, FLAG strand bits, NM
               tags) plus a dependency-free validator used by tests/CI.

The end-to-end driver is ``repro.launch.map_fastq``.
"""
from .cigar import (cigar_from_ops, cigar_query_len, cigar_ref_len,
                    parse_cigar)  # noqa: F401
from .fasta import (Contig, ReferenceMap, load_reference,
                    parse_fasta)  # noqa: F401
from .fastq import FastqStream, ReadChunk, parse_fastq  # noqa: F401
from .sam import (FLAG_REVERSE, FLAG_UNMAPPED, emit_alignments, sam_header,
                  sam_record, validate_sam)  # noqa: F401
