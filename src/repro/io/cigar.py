"""END-aligned traceback ops -> CIGAR strings (and back).

The affine-WF traceback (``repro.core.affine_wf.traceback``) emits op
codes right-aligned in a fixed ``(R, max_ops)`` buffer, left-padded with
``OP_NONE`` — the device-friendly layout.  SAM wants run-length encoded
CIGAR text.  We emit the exact alignment alphabet (``=`` match, ``X``
substitution, ``I`` insertion-to-reference, ``D`` deletion) rather than
collapsing to ``M``: it is spec-valid and loss-free w.r.t. the
traceback, so the alignment (not just its span) is reconstructible.

Truncation: with a caller-set ``max_ops`` smaller than the walk length,
``op_count`` exceeds the buffer and the stored ops are incomplete —
those alignments degrade to CIGAR ``"*"`` (spec: "CIGAR unavailable")
instead of emitting a string that cannot re-sum to the read length.
"""
from __future__ import annotations

import re

import numpy as np

from ..core.affine_wf import OP_DEL, OP_INS, OP_MATCH, OP_NONE, OP_SUB

_OP_CHAR = {OP_MATCH: "=", OP_SUB: "X", OP_INS: "I", OP_DEL: "D"}
_CIGAR_RE = re.compile(r"(\d+)([MIDNSHP=X])")

# which CIGAR ops consume query (read) vs reference bases (SAM spec 1.6)
QUERY_OPS = set("MIS=X")
REF_OPS = set("MDN=X")


def cigar_from_ops(ops: np.ndarray, op_count: int) -> str:
    """One END-aligned op row + its count -> CIGAR string.

    ``op_count == 0`` (unmapped) and ``op_count > len(ops)`` (the
    ``max_ops`` truncation path — the buffer holds only the tail of the
    walk) both return ``"*"``.
    """
    ops = np.asarray(ops)
    k = int(op_count)
    if k <= 0 or k > ops.shape[-1]:
        return "*"
    tail = ops[ops.shape[-1] - k:]
    if np.any(tail == OP_NONE):  # padding inside the walk: corrupt row
        return "*"
    # run-length encode
    flips = np.flatnonzero(np.diff(tail)) + 1
    bounds = np.concatenate([[0], flips, [k]])
    return "".join(f"{bounds[i + 1] - bounds[i]}{_OP_CHAR[int(tail[bounds[i]])]}"
                   for i in range(len(bounds) - 1))


def cigars_from_result(ops: np.ndarray, op_count: np.ndarray) -> list[str]:
    """Batched ``cigar_from_ops`` over ``(R, max_ops)`` / ``(R,)``."""
    return [cigar_from_ops(ops[r], int(op_count[r]))
            for r in range(len(op_count))]


def parse_cigar(cigar: str) -> list[tuple[int, str]]:
    """CIGAR -> [(length, op)], validating the whole string matches."""
    if cigar == "*":
        return []
    parts = _CIGAR_RE.findall(cigar)
    if "".join(f"{n}{c}" for n, c in parts) != cigar or not parts:
        raise ValueError(f"malformed CIGAR {cigar!r}")
    out = [(int(n), c) for n, c in parts]
    if any(n < 1 for n, _ in out):
        raise ValueError(f"zero-length CIGAR op in {cigar!r}")
    return out


def unparse_cigar(parsed: list[tuple[int, str]]) -> str:
    return "".join(f"{n}{c}" for n, c in parsed) if parsed else "*"


def trim_edge_deletions(parsed: list[tuple[int, str]],
                        ) -> tuple[list[tuple[int, str]], int]:
    """SAM-normalize an op list: an alignment may not begin or end with a
    deletion (no read base is involved in those ref positions — real
    aligners shrink the footprint instead).  The banded-WF traceback can
    emit them when the band's best path enters via the gap matrices;
    drop them and return ``(ops, pos_shift)`` where ``pos_shift`` is the
    number of leading deleted reference bases POS must advance by.
    """
    lo, hi = 0, len(parsed)
    shift = 0
    while lo < hi and parsed[lo][1] == "D":
        shift += parsed[lo][0]
        lo += 1
    while hi > lo and parsed[hi - 1][1] == "D":
        hi -= 1
    return parsed[lo:hi], shift


def cigar_query_len(cigar: str) -> int:
    """Read bases the CIGAR consumes (must equal the SEQ length)."""
    return sum(n for n, c in parse_cigar(cigar) if c in QUERY_OPS)


def cigar_ref_len(cigar: str) -> int:
    """Reference bases the CIGAR consumes (the alignment footprint)."""
    return sum(n for n, c in parse_cigar(cigar) if c in REF_OPS)
