"""Streaming FASTQ ingestion in engine-shaped batches.

The mapping engine wants fixed ``(chunk, read_len)`` uint8 blocks (the
static jit shapes of ``repro.core.pipeline``); a FASTQ file is a
variable-length record stream.  ``FastqStream`` bridges them without
ever materializing the file: records are parsed 4 lines at a time and
accumulated into ``chunk_reads``-sized ``ReadChunk`` batches, so a
389M-read HiSeq run and a 32-read smoke test walk the same code path.

Length policy (the pipeline is fixed-``read_len``, like DART-PIM's
crossbar rows): the first record sets ``read_len`` unless the caller
pins it; longer reads are truncated to it, shorter reads are skipped.
Both are counted (``n_truncated`` / ``n_skipped``) so silent data loss
is impossible.  Read bases outside ACGT encode to A (the 2-bit k-mer
alphabet has no N slot — same policy as ``core.encoding.encode_str``);
qualities ride along as raw phred+33 bytes for SAM emission.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from ..core.encoding import encode_str

DEFAULT_CHUNK_READS = 1024


@dataclasses.dataclass
class ReadChunk:
    """One engine-shaped batch of FASTQ records."""
    names: list[str]         # per-read QNAMEs (header token before space)
    reads: np.ndarray        # (n, read_len) uint8 base codes
    quals: np.ndarray        # (n, read_len) uint8 phred+33 ASCII
    seqs: list[str] | None = None  # raw sequence text (read_len chars):
    #                        codes rewrite N->A for seeding, SAM SEQ must
    #                        not — pass this to sam.emit_alignments

    def __len__(self) -> int:
        return len(self.names)


def _encode_read(seq: str, read_len: int) -> np.ndarray:
    # one home for the base-encoding policy (unknown -> A): core.encoding
    return encode_str(seq)[:read_len]


class FastqStream:
    """Iterate a FASTQ file as ``ReadChunk`` batches.

    Parameters
    ----------
    path : str | file-like
        FASTQ source (4-line records).
    read_len : int, optional
        Fixed read length; inferred from the first record when None
        (the first record is read eagerly at construction so callers can
        size the index before iterating).
    chunk_reads : int
        Batch size; the last chunk may be shorter.  Match this to
        ``MapperConfig.chunk_reads`` so each chunk feeds the streaming
        engine as one unit.
    """

    def __init__(self, path_or_handle, read_len: int | None = None,
                 chunk_reads: int = DEFAULT_CHUNK_READS):
        if chunk_reads < 1:
            raise ValueError(f"chunk_reads={chunk_reads!r} must be >= 1")
        from .fasta import _open
        self._f, self._owned = _open(path_or_handle)
        self.chunk_reads = chunk_reads
        self.n_reads = 0       # records emitted (post length policy)
        self.n_skipped = 0     # records shorter than read_len
        self.n_truncated = 0   # records longer than read_len
        self._peeked = None
        try:
            first = self._next_record()
            if first is None:
                raise ValueError("empty FASTQ: no records")
            self.read_len = (read_len if read_len is not None
                             else len(first[1]))
            if self.read_len < 1:
                raise ValueError(f"read_len={self.read_len!r} must be >= 1")
        except Exception:
            if self._owned:  # don't leak the fd when the peek fails
                self._f.close()
            raise
        self._peeked = first

    def _next_record(self):
        """Next raw ``(name, seq, qual)`` or None at EOF."""
        if self._peeked is not None:
            rec, self._peeked = self._peeked, None
            return rec
        head = self._f.readline()
        while head is not None and head.strip() == "" and head != "":
            head = self._f.readline()
        if not head:
            return None
        head = head.strip()
        if not head.startswith("@"):
            raise ValueError(f"malformed FASTQ: expected '@' header, "
                             f"got {head[:40]!r}")
        seq = self._f.readline().strip()
        plus = self._f.readline().strip()
        qual = self._f.readline().strip()
        if not plus.startswith("+"):
            raise ValueError(f"malformed FASTQ record {head[:40]!r}: "
                             f"missing '+' separator line")
        if len(qual) != len(seq):
            raise ValueError(f"malformed FASTQ record {head[:40]!r}: "
                             f"{len(seq)} bases but {len(qual)} qualities")
        return head[1:].split()[0] if len(head) > 1 else "*", seq, qual

    def __iter__(self) -> Iterator[ReadChunk]:
        rl = self.read_len
        names, reads, quals, seqs = [], [], [], []
        try:
            while True:
                rec = self._next_record()
                if rec is None:
                    break
                name, seq, qual = rec
                if len(seq) < rl:
                    self.n_skipped += 1
                    continue
                if len(seq) > rl:
                    self.n_truncated += 1
                names.append(name)
                reads.append(_encode_read(seq, rl))
                quals.append(np.frombuffer(qual[:rl].encode("ascii"),
                                           dtype=np.uint8))
                seqs.append(seq[:rl])
                if len(names) == self.chunk_reads:
                    self.n_reads += len(names)
                    yield ReadChunk(names, np.stack(reads),
                                    np.stack(quals), seqs)
                    names, reads, quals, seqs = [], [], [], []
            if names:
                self.n_reads += len(names)
                yield ReadChunk(names, np.stack(reads), np.stack(quals),
                                seqs)
        finally:
            # close the owned handle even on early break / parse error
            # (generator finalization triggers this via GeneratorExit)
            if self._owned:
                self._f.close()


def parse_fastq(path_or_handle, read_len: int | None = None,
                chunk_reads: int = DEFAULT_CHUNK_READS,
                ) -> Iterator[ReadChunk]:
    """Functional spelling of ``FastqStream`` (counts live on the
    stream object; use the class when you need them)."""
    return iter(FastqStream(path_or_handle, read_len=read_len,
                            chunk_reads=chunk_reads))
