"""Streaming FASTQ ingestion in engine-shaped batches.

The mapping engine wants fixed ``(chunk, read_len)`` uint8 blocks (the
static jit shapes of ``repro.core.pipeline``); a FASTQ file is a
variable-length record stream.  ``FastqStream`` bridges them without
ever materializing the file: records are parsed 4 lines at a time and
accumulated into ``chunk_reads``-sized ``ReadChunk`` batches, so a
389M-read HiSeq run and a 32-read smoke test walk the same code path.

Length policy (the pipeline is fixed-``read_len``, like DART-PIM's
crossbar rows): the first record sets ``read_len`` unless the caller
pins it; longer reads are truncated to it, shorter reads are skipped.
Both are counted (``n_truncated`` / ``n_skipped``) so silent data loss
is impossible.  Read bases outside ACGT encode to A (the 2-bit k-mer
alphabet has no N slot — same policy as ``core.encoding.encode_str``);
qualities ride along as raw phred+33 bytes for SAM emission.

Malformed-record policy (``on_error``): real-world FASTQ carries bad
records — quality strings of the wrong length, missing ``+`` separators,
truncated final records, corrupt gzip members.  ``on_error="strict"``
(default) raises ``FastqParseError`` with ``file:line`` context at the
first bad record.  ``on_error="permissive"`` *quarantines* instead: the
raw record is written to the ``rejects`` FASTQ (when given), counted in
``n_rejected`` / ``reject_reasons``, its name recorded in
``rejected_names``, and parsing resynchronizes at the next ``@`` header
— corruption costs the records it touched, never the run.

``.fastq.gz`` paths stream through gzip transparently (``fasta._open``)
and parse bit-identically to the plain file; a truncated gzip stream
raises a ``ValueError`` naming the failure (strict) or ends the stream
as a counted rejection (permissive) instead of ending the read set
silently as if the file were complete.

``PairedFastqStream`` is the paired-end entry: two R1/R2 files (or one
interleaved file) iterated in lockstep as ``(chunk1, chunk2)`` pairs,
with mate names cross-checked (``/1``/``/2`` suffixes stripped) and the
length policy applied *per pair* — if either mate is too short the whole
pair is skipped, so the two chunks stay index-aligned mate-for-mate.
Under ``permissive`` a mid-stream mate-name desync re-pairs via a
one-record lookahead (the orphaned mate is quarantined) and an unpaired
tail becomes a counted rejection instead of an exception.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator

import numpy as np

from ..core.encoding import encode_str

DEFAULT_CHUNK_READS = 1024

ON_ERROR = ("strict", "permissive")

# trailing mate designator: read7/1, read7/2.  ONLY the '/1'-'/2'
# convention is stripped — '.1'/'_1' are real name parts in the wild
# (SRA spot names are 'SRR123.1', 'SRR123.2', ... for *different*
# templates; stripping those would conflate them into one QNAME)
_MATE_SUFFIX_RE = re.compile(r"/[12]$")


def mate_base_name(name: str) -> str:
    """QNAME with a trailing ``/1``/``/2`` mate designator stripped —
    the canonical template name both mates must share (and the QNAME the
    SAM spec wants: identical for both records of a pair)."""
    return _MATE_SUFFIX_RE.sub("", name)


class FastqParseError(ValueError):
    """A malformed FASTQ record, located: ``source:lineno: reason``.

    ``reason`` is the bare diagnosis, ``slug`` its stable key in
    ``reject_reasons``, ``lines`` the raw text consumed for the record
    (what a permissive stream writes to the rejects file), ``name`` the
    record's QNAME when the header was parseable.
    """

    def __init__(self, reason: str, source: str, lineno: int,
                 lines=(), name: str | None = None,
                 slug: str = "malformed"):
        super().__init__(f"{source}:{lineno}: {reason}")
        self.reason = reason
        self.slug = slug
        self.source = source
        self.lineno = lineno
        self.lines = list(lines)
        self.name = name


class _RejectSink:
    """Lazily-opened rejects FASTQ shared by the streams of a paired
    source (one file, one writer — the two mates must not truncate each
    other's rejects)."""

    def __init__(self, spec):
        self.spec = spec
        self._f = None
        self._owned = False

    def write(self, lines) -> None:
        if self.spec is None or not lines:
            return
        if self._f is None:
            from .fasta import _open
            self._f, self._owned = _open(self.spec, "w")
        self._f.write("".join(lines))

    def close(self) -> None:
        if self._f is not None and self._owned:
            self._f.close()
        self._f = None


@dataclasses.dataclass
class ReadChunk:
    """One engine-shaped batch of FASTQ records."""
    names: list[str]         # per-read QNAMEs (header token before space)
    reads: np.ndarray        # (n, read_len) uint8 base codes
    quals: np.ndarray        # (n, read_len) uint8 phred+33 ASCII
    seqs: list[str] | None = None  # raw sequence text (read_len chars):
    #                        codes rewrite N->A for seeding, SAM SEQ must
    #                        not — pass this to sam.emit_alignments

    def __len__(self) -> int:
        return len(self.names)


def _encode_read(seq: str, read_len: int) -> np.ndarray:
    # one home for the base-encoding policy (unknown -> A): core.encoding
    return encode_str(seq)[:read_len]


class FastqStream:
    """Iterate a FASTQ file as ``ReadChunk`` batches.

    Parameters
    ----------
    path : str | file-like
        FASTQ source (4-line records).
    read_len : int, optional
        Fixed read length; inferred from the first record when None
        (the first record is read eagerly at construction so callers can
        size the index before iterating).
    chunk_reads : int
        Batch size; the last chunk may be shorter.  Match this to
        ``MapperConfig.chunk_reads`` so each chunk feeds the streaming
        engine as one unit.
    on_error : "strict" | "permissive"
        Malformed-record policy (module docstring).  Strict raises
        ``FastqParseError`` with file:line context; permissive counts,
        quarantines and resynchronizes.
    rejects : str | file-like | _RejectSink, optional
        Where permissive mode writes quarantined raw records (a FASTQ-
        shaped rejects file; ``.gz`` spelled paths compress).  Opened
        lazily on the first rejection.
    injector : FaultInjector, optional
        Chaos hook: a fired ``"fastq_record"`` site marks the cleanly
        parsed record corrupt (rejected/raised per ``on_error``) —
        deterministic corruption for the chaos suite.
    """

    def __init__(self, path_or_handle, read_len: int | None = None,
                 chunk_reads: int = DEFAULT_CHUNK_READS, *,
                 on_error: str = "strict", rejects=None, injector=None):
        if chunk_reads < 1:
            raise ValueError(f"chunk_reads={chunk_reads!r} must be >= 1")
        if on_error not in ON_ERROR:
            raise ValueError(f"on_error={on_error!r}; expected one of "
                             f"{ON_ERROR}")
        from .fasta import _open
        self._f, self._owned = _open(path_or_handle)
        self.source = (path_or_handle if isinstance(path_or_handle, str)
                       else getattr(self._f, "name", "<stream>"))
        self.chunk_reads = chunk_reads
        self.on_error = on_error
        self.injector = injector
        self._sink = (rejects if isinstance(rejects, _RejectSink)
                      else _RejectSink(rejects))
        self.n_reads = 0       # records emitted (post length policy)
        self.n_skipped = 0     # records shorter than read_len
        self.n_truncated = 0   # records longer than read_len
        self.n_rejected = 0    # malformed records quarantined (permissive)
        self.reject_reasons: dict[str, int] = {}
        self.rejected_names: list[str] = []
        self._lineno = 0
        self._line_at = 0       # lineno of the line _readline last gave
        self._pushback: tuple[str, int] | None = None
        self._rec_lines: list[str] = []
        self._peeked = None     # (record, raw lines) | None
        try:
            first = self._next_record()
            if first is None:
                raise ValueError(f"{self.source}: empty FASTQ: no records")
            self.read_len = (read_len if read_len is not None
                             else len(first[1]))
            if self.read_len < 1:
                raise ValueError(f"read_len={self.read_len!r} must be >= 1")
        except Exception:
            if self._owned:  # don't leak the fd when the peek fails
                self._f.close()
            raise
        self._peeked = (first, list(self._rec_lines))

    # ------------------------------------------------------ line plumbing

    def _readline(self) -> str:
        if self._pushback is not None:
            line, self._line_at = self._pushback
            self._pushback = None
        else:
            line = self._f.readline()
            self._lineno += 1
            self._line_at = self._lineno
        self._rec_lines.append(line)
        return line

    def _push_back(self, line: str, lineno: int) -> None:
        self._pushback = (line, lineno)
        if self._rec_lines and self._rec_lines[-1] is line:
            self._rec_lines.pop()

    def push_back_record(self, rec, lines) -> None:
        """Un-consume a record (the paired stream's desync lookahead)."""
        if self._peeked is not None:
            raise RuntimeError("only one record of pushback is supported")
        self._peeked = (rec, list(lines))

    # ----------------------------------------------------------- parsing

    def _next_record(self):
        """Next raw ``(name, seq, qual)`` or None at EOF.

        Strict mode raises ``FastqParseError`` (or ``ValueError`` for a
        truncated gzip stream) at the first malformed record; permissive
        mode quarantines it (``_reject``), resynchronizes at the next
        ``@`` header, and keeps going.  ``self._rec_lines`` holds the raw
        text of the returned record.
        """
        if self._peeked is not None:
            (rec, lines), self._peeked = self._peeked, None
            self._rec_lines = lines
            return rec
        while True:
            try:
                rec = self._parse_record()
            except EOFError as e:  # gzip: stream ends before EOF marker
                if self.on_error == "permissive":
                    self._reject("truncated_gzip", None, [])
                    return None
                raise ValueError(
                    f"{self.source}: truncated gzip FASTQ stream "
                    f"(compressed file ended mid-record): {e}") from e
            except FastqParseError as e:
                if self.on_error == "strict":
                    raise
                self._reject(e.slug, e.name, e.lines)
                self._resync()
                continue
            if (rec is not None and self.injector is not None
                    and self.injector.fire("fastq_record")):
                err = FastqParseError("injected record corruption",
                                      self.source, self._line_at,
                                      self._rec_lines, rec[0],
                                      slug="injected")
                if self.on_error == "strict":
                    raise err
                self._reject(err.slug, err.name, err.lines)
                continue  # a clean record was consumed: no resync needed
            return rec

    def _parse_record(self):
        self._rec_lines = []
        head = self._readline()
        while head is not None and head.strip() == "" and head != "":
            self._rec_lines = []
            head = self._readline()
        if not head:
            return None
        start = self._line_at
        head = head.strip()
        if not head.startswith("@"):
            raise FastqParseError(f"malformed FASTQ: expected '@' header, "
                                  f"got {head[:40]!r}", self.source, start,
                                  self._rec_lines, slug="bad_header")
        name = head[1:].split()[0] if len(head) > 1 else "*"
        seq = self._readline().strip()
        plus = self._readline().strip()
        qual = self._readline().strip()
        if not plus.startswith("+"):
            raise FastqParseError(f"malformed FASTQ record {head[:40]!r}: "
                                  f"missing '+' separator line",
                                  self.source, start, self._rec_lines, name,
                                  slug="missing_separator")
        if len(qual) != len(seq):
            raise FastqParseError(f"malformed FASTQ record {head[:40]!r}: "
                                  f"{len(seq)} bases but {len(qual)} "
                                  f"qualities", self.source, start,
                                  self._rec_lines, name,
                                  slug="qual_len_mismatch")
        return name, seq, qual

    def _reject(self, slug: str, name: str | None, lines) -> None:
        self.n_rejected += 1
        self.reject_reasons[slug] = self.reject_reasons.get(slug, 0) + 1
        if name is not None:
            self.rejected_names.append(name)
        self._sink.write(lines)

    def _resync(self) -> None:
        """Skip forward to the next plausible record header so one bad
        record costs itself, not the rest of the file."""
        while True:
            line = self._f.readline()
            if not line:
                return
            self._lineno += 1
            if line.startswith("@"):
                self._pushback = (line, self._lineno)
                return

    def __iter__(self) -> Iterator[ReadChunk]:
        rl = self.read_len
        names, reads, quals, seqs = [], [], [], []
        try:
            while True:
                rec = self._next_record()
                if rec is None:
                    break
                name, seq, qual = rec
                if len(seq) < rl:
                    self.n_skipped += 1
                    continue
                if len(seq) > rl:
                    self.n_truncated += 1
                names.append(name)
                reads.append(_encode_read(seq, rl))
                quals.append(np.frombuffer(qual[:rl].encode("ascii"),
                                           dtype=np.uint8))
                seqs.append(seq[:rl])
                if len(names) == self.chunk_reads:
                    self.n_reads += len(names)
                    yield ReadChunk(names, np.stack(reads),
                                    np.stack(quals), seqs)
                    names, reads, quals, seqs = [], [], [], []
            if names:
                self.n_reads += len(names)
                yield ReadChunk(names, np.stack(reads), np.stack(quals),
                                seqs)
        finally:
            # close the owned handles even on early break / parse error
            # (generator finalization triggers this via GeneratorExit)
            if self._owned:
                self._f.close()
            self._sink.close()


def parse_fastq(path_or_handle, read_len: int | None = None,
                chunk_reads: int = DEFAULT_CHUNK_READS,
                ) -> Iterator[ReadChunk]:
    """Functional spelling of ``FastqStream`` (counts live on the
    stream object; use the class when you need them)."""
    return iter(FastqStream(path_or_handle, read_len=read_len,
                            chunk_reads=chunk_reads))


class _ChunkBuilder:
    """Accumulates records into one ReadChunk (shared by the two mates
    of ``PairedFastqStream`` so their policy cannot drift)."""

    def __init__(self, read_len: int):
        self.rl = read_len
        self.names, self.reads, self.quals, self.seqs = [], [], [], []

    def add(self, name: str, seq: str, qual: str) -> None:
        rl = self.rl
        self.names.append(name)
        self.reads.append(_encode_read(seq, rl))
        self.quals.append(np.frombuffer(qual[:rl].encode("ascii"),
                                        dtype=np.uint8))
        self.seqs.append(seq[:rl])

    def __len__(self) -> int:
        return len(self.names)

    def emit(self) -> ReadChunk:
        chunk = ReadChunk(self.names, np.stack(self.reads),
                          np.stack(self.quals), self.seqs)
        self.names, self.reads, self.quals, self.seqs = [], [], [], []
        return chunk


class PairedFastqStream:
    """Iterate paired-end FASTQ as lockstep ``(chunk1, chunk2)`` batches.

    Two source layouts:

    * two files — ``PairedFastqStream(r1_path, r2_path)``: record *i* of
      R1 pairs with record *i* of R2;
    * interleaved — ``PairedFastqStream(path, interleaved=True)``:
      records ``2i``/``2i+1`` are the R1/R2 mates of pair *i*.

    Both mates must share a template name once the ``/1``/``/2``-style
    suffix is stripped (``mate_base_name``); a mismatch or a mate count
    imbalance raises instead of silently re-pairing.  The fixed-length
    policy is applied per *pair*: if either mate is shorter than
    ``read_len`` the whole pair is skipped (``n_skipped`` counts pairs),
    so ``chunk1[i]`` and ``chunk2[i]`` are always mates.  ``names`` on
    the emitted chunks carry the shared template name — exactly the SAM
    QNAME both records of the pair must use.

    ``on_error="permissive"`` extends the per-record quarantine policy
    (see ``FastqStream``) with pair-level recovery: on a mate-name
    desync, a one-record lookahead on each side re-pairs the streams and
    quarantines the orphaned mate (reason ``mate_desync``); when it
    cannot re-pair, both records are quarantined and lockstep continues.
    An unpaired tail quarantines the surviving record (reason
    ``unpaired_tail``) and ends the stream.  Both substreams share one
    ``rejects`` sink.

    ``.gz`` paths stream through gzip transparently on either layout.
    """

    def __init__(self, r1, r2=None, *, interleaved: bool = False,
                 read_len: int | None = None,
                 chunk_reads: int = DEFAULT_CHUNK_READS,
                 on_error: str = "strict", rejects=None, injector=None):
        if interleaved and r2 is not None:
            raise ValueError("interleaved=True takes a single source; "
                             "r2 must be None")
        if not interleaved and r2 is None:
            raise ValueError("paired input needs r2 (or interleaved=True)")
        if chunk_reads < 1:
            raise ValueError(f"chunk_reads={chunk_reads!r} must be >= 1")
        if on_error not in ON_ERROR:
            raise ValueError(f"on_error={on_error!r}; expected one of "
                             f"{ON_ERROR}")
        self.interleaved = interleaved
        self.chunk_reads = chunk_reads
        self.on_error = on_error
        self._sink = _RejectSink(rejects)
        self._s1 = FastqStream(r1, read_len=read_len, chunk_reads=chunk_reads,
                               on_error=on_error, rejects=self._sink,
                               injector=injector)
        self.read_len = self._s1.read_len
        self._s2 = (self._s1 if interleaved else
                    FastqStream(r2, read_len=self.read_len,
                                chunk_reads=chunk_reads, on_error=on_error,
                                rejects=self._sink, injector=injector))
        self.n_pairs = 0      # pairs emitted (post length policy)
        self.n_skipped = 0    # pairs dropped because a mate was short
        self.n_truncated = 0  # mates longer than read_len (counted singly)
        self.n_rejected_pairs = 0  # pair-level quarantines (permissive)
        self.reject_reasons: dict[str, int] = {}

    @property
    def n_rejected(self) -> int:
        """All quarantined records: per-record parse rejections on either
        substream plus the pair-level desync/tail quarantines."""
        n = self._s1.n_rejected + self.n_rejected_pairs
        if not self.interleaved:
            n += self._s2.n_rejected
        return n

    @property
    def rejected_names(self) -> list[str]:
        names = list(self._s1.rejected_names)
        if not self.interleaved:
            names += self._s2.rejected_names
        return names

    def _reject_pair(self, reason: str, *recs) -> None:
        """Quarantine record(s) at the pair level: ``recs`` are
        ``(stream, record, raw_lines)`` triples."""
        self.n_rejected_pairs += 1
        self.reject_reasons[reason] = \
            self.reject_reasons.get(reason, 0) + 1
        for stream, rec, lines in recs:
            if rec is not None:
                stream.rejected_names.append(rec[0])
                self._sink.write(lines)

    def _next_pair(self):
        while True:
            r1 = self._s1._next_record()
            l1 = list(self._s1._rec_lines)
            r2 = self._s2._next_record()
            l2 = list(self._s2._rec_lines)
            if r1 is None and r2 is None:
                return None
            if (r1 is None) != (r2 is None):
                which = "R1" if r1 is None else "R2"
                if self.on_error == "permissive":
                    # quarantine the survivor; the stream is over
                    alive = ((self._s2, r2, l2) if r1 is None
                             else (self._s1, r1, l1))
                    self._reject_pair("unpaired_tail", alive)
                    return None
                raise ValueError(f"unpaired FASTQ input: {which} ended "
                                 f"before its mate stream")
            b1, b2 = mate_base_name(r1[0]), mate_base_name(r2[0])
            if b1 == b2:
                return b1, r1, r2
            if self.on_error == "strict":
                raise ValueError(f"mate name mismatch: {r1[0]!r} vs "
                                 f"{r2[0]!r} (template {b1!r} != {b2!r})")
            # permissive desync recovery: one-record lookahead per side —
            # if the *next* R1 pairs with this R2, the current R1 is an
            # orphan (and vice versa); otherwise drop both and move on
            n1 = self._s1._next_record()
            ln1 = list(self._s1._rec_lines)
            if n1 is not None and mate_base_name(n1[0]) == b2:
                self._reject_pair("mate_desync", (self._s1, r1, l1))
                return b2, n1, r2
            if n1 is not None:
                self._s1.push_back_record(n1, ln1)
            n2 = self._s2._next_record()
            ln2 = list(self._s2._rec_lines)
            if n2 is not None and mate_base_name(n2[0]) == b1:
                self._reject_pair("mate_desync", (self._s2, r2, l2))
                return b1, r1, n2
            if n2 is not None:
                self._s2.push_back_record(n2, ln2)
            self._reject_pair("mate_desync", (self._s1, r1, l1),
                              (self._s2, r2, l2))

    def __iter__(self) -> Iterator[tuple[ReadChunk, ReadChunk]]:
        rl = self.read_len
        c1, c2 = _ChunkBuilder(rl), _ChunkBuilder(rl)
        try:
            while True:
                pair = self._next_pair()
                if pair is None:
                    break
                base, (_, s1, q1), (_, s2, q2) = pair
                if len(s1) < rl or len(s2) < rl:
                    self.n_skipped += 1  # pair integrity: drop both mates
                    continue
                self.n_truncated += (len(s1) > rl) + (len(s2) > rl)
                c1.add(base, s1, q1)
                c2.add(base, s2, q2)
                if len(c1) == self.chunk_reads:
                    self.n_pairs += len(c1)
                    yield c1.emit(), c2.emit()
            if len(c1):
                self.n_pairs += len(c1)
                yield c1.emit(), c2.emit()
        finally:
            if self._s1._owned:
                self._s1._f.close()
            if not self.interleaved and self._s2._owned:
                self._s2._f.close()
            self._sink.close()
