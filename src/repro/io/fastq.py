"""Streaming FASTQ ingestion in engine-shaped batches.

The mapping engine wants fixed ``(chunk, read_len)`` uint8 blocks (the
static jit shapes of ``repro.core.pipeline``); a FASTQ file is a
variable-length record stream.  ``FastqStream`` bridges them without
ever materializing the file: records are parsed 4 lines at a time and
accumulated into ``chunk_reads``-sized ``ReadChunk`` batches, so a
389M-read HiSeq run and a 32-read smoke test walk the same code path.

Length policy (the pipeline is fixed-``read_len``, like DART-PIM's
crossbar rows): the first record sets ``read_len`` unless the caller
pins it; longer reads are truncated to it, shorter reads are skipped.
Both are counted (``n_truncated`` / ``n_skipped``) so silent data loss
is impossible.  Read bases outside ACGT encode to A (the 2-bit k-mer
alphabet has no N slot — same policy as ``core.encoding.encode_str``);
qualities ride along as raw phred+33 bytes for SAM emission.

``.fastq.gz`` paths stream through gzip transparently (``fasta._open``)
and parse bit-identically to the plain file; a truncated gzip stream
raises a ``ValueError`` naming the failure instead of ending the read
set early as if the file were complete.

``PairedFastqStream`` is the paired-end entry: two R1/R2 files (or one
interleaved file) iterated in lockstep as ``(chunk1, chunk2)`` pairs,
with mate names cross-checked (``/1``/``/2`` suffixes stripped) and the
length policy applied *per pair* — if either mate is too short the whole
pair is skipped, so the two chunks stay index-aligned mate-for-mate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator

import numpy as np

from ..core.encoding import encode_str

DEFAULT_CHUNK_READS = 1024

# trailing mate designator: read7/1, read7/2.  ONLY the '/1'-'/2'
# convention is stripped — '.1'/'_1' are real name parts in the wild
# (SRA spot names are 'SRR123.1', 'SRR123.2', ... for *different*
# templates; stripping those would conflate them into one QNAME)
_MATE_SUFFIX_RE = re.compile(r"/[12]$")


def mate_base_name(name: str) -> str:
    """QNAME with a trailing ``/1``/``/2`` mate designator stripped —
    the canonical template name both mates must share (and the QNAME the
    SAM spec wants: identical for both records of a pair)."""
    return _MATE_SUFFIX_RE.sub("", name)


@dataclasses.dataclass
class ReadChunk:
    """One engine-shaped batch of FASTQ records."""
    names: list[str]         # per-read QNAMEs (header token before space)
    reads: np.ndarray        # (n, read_len) uint8 base codes
    quals: np.ndarray        # (n, read_len) uint8 phred+33 ASCII
    seqs: list[str] | None = None  # raw sequence text (read_len chars):
    #                        codes rewrite N->A for seeding, SAM SEQ must
    #                        not — pass this to sam.emit_alignments

    def __len__(self) -> int:
        return len(self.names)


def _encode_read(seq: str, read_len: int) -> np.ndarray:
    # one home for the base-encoding policy (unknown -> A): core.encoding
    return encode_str(seq)[:read_len]


class FastqStream:
    """Iterate a FASTQ file as ``ReadChunk`` batches.

    Parameters
    ----------
    path : str | file-like
        FASTQ source (4-line records).
    read_len : int, optional
        Fixed read length; inferred from the first record when None
        (the first record is read eagerly at construction so callers can
        size the index before iterating).
    chunk_reads : int
        Batch size; the last chunk may be shorter.  Match this to
        ``MapperConfig.chunk_reads`` so each chunk feeds the streaming
        engine as one unit.
    """

    def __init__(self, path_or_handle, read_len: int | None = None,
                 chunk_reads: int = DEFAULT_CHUNK_READS):
        if chunk_reads < 1:
            raise ValueError(f"chunk_reads={chunk_reads!r} must be >= 1")
        from .fasta import _open
        self._f, self._owned = _open(path_or_handle)
        self.chunk_reads = chunk_reads
        self.n_reads = 0       # records emitted (post length policy)
        self.n_skipped = 0     # records shorter than read_len
        self.n_truncated = 0   # records longer than read_len
        self._peeked = None
        try:
            first = self._next_record()
            if first is None:
                raise ValueError("empty FASTQ: no records")
            self.read_len = (read_len if read_len is not None
                             else len(first[1]))
            if self.read_len < 1:
                raise ValueError(f"read_len={self.read_len!r} must be >= 1")
        except Exception:
            if self._owned:  # don't leak the fd when the peek fails
                self._f.close()
            raise
        self._peeked = first

    def _next_record(self):
        """Next raw ``(name, seq, qual)`` or None at EOF."""
        if self._peeked is not None:
            rec, self._peeked = self._peeked, None
            return rec
        try:
            return self._parse_record()
        except EOFError as e:  # gzip: stream ends before the EOF marker
            raise ValueError(
                "truncated gzip FASTQ stream (compressed file ended "
                f"mid-record): {e}") from e

    def _parse_record(self):
        head = self._f.readline()
        while head is not None and head.strip() == "" and head != "":
            head = self._f.readline()
        if not head:
            return None
        head = head.strip()
        if not head.startswith("@"):
            raise ValueError(f"malformed FASTQ: expected '@' header, "
                             f"got {head[:40]!r}")
        seq = self._f.readline().strip()
        plus = self._f.readline().strip()
        qual = self._f.readline().strip()
        if not plus.startswith("+"):
            raise ValueError(f"malformed FASTQ record {head[:40]!r}: "
                             f"missing '+' separator line")
        if len(qual) != len(seq):
            raise ValueError(f"malformed FASTQ record {head[:40]!r}: "
                             f"{len(seq)} bases but {len(qual)} qualities")
        return head[1:].split()[0] if len(head) > 1 else "*", seq, qual

    def __iter__(self) -> Iterator[ReadChunk]:
        rl = self.read_len
        names, reads, quals, seqs = [], [], [], []
        try:
            while True:
                rec = self._next_record()
                if rec is None:
                    break
                name, seq, qual = rec
                if len(seq) < rl:
                    self.n_skipped += 1
                    continue
                if len(seq) > rl:
                    self.n_truncated += 1
                names.append(name)
                reads.append(_encode_read(seq, rl))
                quals.append(np.frombuffer(qual[:rl].encode("ascii"),
                                           dtype=np.uint8))
                seqs.append(seq[:rl])
                if len(names) == self.chunk_reads:
                    self.n_reads += len(names)
                    yield ReadChunk(names, np.stack(reads),
                                    np.stack(quals), seqs)
                    names, reads, quals, seqs = [], [], [], []
            if names:
                self.n_reads += len(names)
                yield ReadChunk(names, np.stack(reads), np.stack(quals),
                                seqs)
        finally:
            # close the owned handle even on early break / parse error
            # (generator finalization triggers this via GeneratorExit)
            if self._owned:
                self._f.close()


def parse_fastq(path_or_handle, read_len: int | None = None,
                chunk_reads: int = DEFAULT_CHUNK_READS,
                ) -> Iterator[ReadChunk]:
    """Functional spelling of ``FastqStream`` (counts live on the
    stream object; use the class when you need them)."""
    return iter(FastqStream(path_or_handle, read_len=read_len,
                            chunk_reads=chunk_reads))


class _ChunkBuilder:
    """Accumulates records into one ReadChunk (shared by the two mates
    of ``PairedFastqStream`` so their policy cannot drift)."""

    def __init__(self, read_len: int):
        self.rl = read_len
        self.names, self.reads, self.quals, self.seqs = [], [], [], []

    def add(self, name: str, seq: str, qual: str) -> None:
        rl = self.rl
        self.names.append(name)
        self.reads.append(_encode_read(seq, rl))
        self.quals.append(np.frombuffer(qual[:rl].encode("ascii"),
                                        dtype=np.uint8))
        self.seqs.append(seq[:rl])

    def __len__(self) -> int:
        return len(self.names)

    def emit(self) -> ReadChunk:
        chunk = ReadChunk(self.names, np.stack(self.reads),
                          np.stack(self.quals), self.seqs)
        self.names, self.reads, self.quals, self.seqs = [], [], [], []
        return chunk


class PairedFastqStream:
    """Iterate paired-end FASTQ as lockstep ``(chunk1, chunk2)`` batches.

    Two source layouts:

    * two files — ``PairedFastqStream(r1_path, r2_path)``: record *i* of
      R1 pairs with record *i* of R2;
    * interleaved — ``PairedFastqStream(path, interleaved=True)``:
      records ``2i``/``2i+1`` are the R1/R2 mates of pair *i*.

    Both mates must share a template name once the ``/1``/``/2``-style
    suffix is stripped (``mate_base_name``); a mismatch or a mate count
    imbalance raises instead of silently re-pairing.  The fixed-length
    policy is applied per *pair*: if either mate is shorter than
    ``read_len`` the whole pair is skipped (``n_skipped`` counts pairs),
    so ``chunk1[i]`` and ``chunk2[i]`` are always mates.  ``names`` on
    the emitted chunks carry the shared template name — exactly the SAM
    QNAME both records of the pair must use.

    ``.gz`` paths stream through gzip transparently on either layout.
    """

    def __init__(self, r1, r2=None, *, interleaved: bool = False,
                 read_len: int | None = None,
                 chunk_reads: int = DEFAULT_CHUNK_READS):
        if interleaved and r2 is not None:
            raise ValueError("interleaved=True takes a single source; "
                             "r2 must be None")
        if not interleaved and r2 is None:
            raise ValueError("paired input needs r2 (or interleaved=True)")
        if chunk_reads < 1:
            raise ValueError(f"chunk_reads={chunk_reads!r} must be >= 1")
        self.interleaved = interleaved
        self.chunk_reads = chunk_reads
        self._s1 = FastqStream(r1, read_len=read_len, chunk_reads=chunk_reads)
        self.read_len = self._s1.read_len
        self._s2 = (self._s1 if interleaved else
                    FastqStream(r2, read_len=self.read_len,
                                chunk_reads=chunk_reads))
        self.n_pairs = 0      # pairs emitted (post length policy)
        self.n_skipped = 0    # pairs dropped because a mate was short
        self.n_truncated = 0  # mates longer than read_len (counted singly)

    def _next_pair(self):
        r1 = self._s1._next_record()
        r2 = self._s2._next_record()
        if r1 is None and r2 is None:
            return None
        if (r1 is None) != (r2 is None):
            which = "R2" if r1 is None else "R1"
            raise ValueError(f"unpaired FASTQ input: {which} ended before "
                             f"its mate stream")
        b1, b2 = mate_base_name(r1[0]), mate_base_name(r2[0])
        if b1 != b2:
            raise ValueError(f"mate name mismatch: {r1[0]!r} vs {r2[0]!r} "
                             f"(template {b1!r} != {b2!r})")
        return b1, r1, r2

    def __iter__(self) -> Iterator[tuple[ReadChunk, ReadChunk]]:
        rl = self.read_len
        c1, c2 = _ChunkBuilder(rl), _ChunkBuilder(rl)
        try:
            while True:
                pair = self._next_pair()
                if pair is None:
                    break
                base, (_, s1, q1), (_, s2, q2) = pair
                if len(s1) < rl or len(s2) < rl:
                    self.n_skipped += 1  # pair integrity: drop both mates
                    continue
                self.n_truncated += (len(s1) > rl) + (len(s2) > rl)
                c1.add(base, s1, q1)
                c2.add(base, s2, q2)
                if len(c1) == self.chunk_reads:
                    self.n_pairs += len(c1)
                    yield c1.emit(), c2.emit()
            if len(c1):
                self.n_pairs += len(c1)
                yield c1.emit(), c2.emit()
        finally:
            if self._s1._owned:
                self._s1._f.close()
            if not self.interleaved and self._s2._owned:
                self._s2._f.close()
