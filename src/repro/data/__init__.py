from . import genome, tokens  # noqa: F401
