"""Deterministic, shardable synthetic LM token pipeline.

Design rules for 1000+ node runs:
  * **Deterministic by (seed, step, shard)** — any host can regenerate any
    batch shard independently; a restarted/replaced node needs only the step
    counter from the checkpoint (no data-server state), which is what makes
    elastic restart exact.
  * **Static shapes** — batches never ragged, so steps are replayable and
    stragglers cannot arise from shape-dependent recompilation.
"""
from __future__ import annotations

import numpy as np


def batch_for_step(step: int, *, global_batch: int, seq_len: int,
                   vocab_size: int, seed: int = 0,
                   shard_index: int = 0, num_shards: int = 1):
    """Return (tokens, labels) for this host's shard of global batch ``step``.

    tokens/labels are int32 (global_batch // num_shards, seq_len).  Labels
    are next-token shifted with a structured pattern (token ~ mix of zipf-ish
    ids) so loss curves are non-degenerate in the examples.
    """
    assert global_batch % num_shards == 0
    local = global_batch // num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard_index]))
    # zipf-ish marginal over vocab, cheap to sample: square a uniform
    u = rng.random((local, seq_len + 1))
    toks = (u * u * (vocab_size - 1)).astype(np.int32)
    return toks[:, :-1], toks[:, 1:]
