"""Synthetic genome + Illumina-like read simulator (ground truth attached).

The container has no genomic datasets; the paper's HG38 + 389M HiSeq-X reads
are replaced by a controlled simulator: a uniform-random reference (optionally
with repeated segments, to exercise high-frequency minimizers / the maxReads
cap) and reads sampled with substitution/insertion/deletion errors at
Illumina-like rates.  Every read carries its true origin so mapping accuracy
(paper Sec. VII-A) is measured against exact ground truth rather than a
surrogate mapper.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReadSet:
    reads: np.ndarray        # (R, rl) uint8 base codes
    true_pos: np.ndarray     # (R,) int32 origin position in the reference
    n_errors: np.ndarray     # (R,) int32 number of simulated edits


def make_reference(length: int, seed: int = 0, repeat_frac: float = 0.05,
                   repeat_len: int = 500) -> np.ndarray:
    """Random reference with a fraction of duplicated segments.

    Duplications create repetitive minimizers — the workload feature that
    motivates DART-PIM's Reads-FIFO caps and the RISC-V lowTh offload.
    """
    rng = np.random.default_rng(seed)
    ref = rng.integers(0, 4, length).astype(np.uint8)
    n_rep = int(length * repeat_frac / max(repeat_len, 1))
    for _ in range(n_rep):
        src = int(rng.integers(0, length - repeat_len))
        dst = int(rng.integers(0, length - repeat_len))
        ref[dst : dst + repeat_len] = ref[src : src + repeat_len]
    return ref


def sample_reads(ref: np.ndarray, n_reads: int, read_len: int = 150,
                 sub_rate: float = 0.002, ins_rate: float = 0.0005,
                 del_rate: float = 0.0005, seed: int = 1) -> ReadSet:
    """Sample reads uniformly; apply per-base edit errors.

    Rates default to Illumina-like (~0.3% total), well inside eth=6 for
    rl=150 so the banded WF is exact for typical reads.
    """
    rng = np.random.default_rng(seed)
    G = len(ref)
    margin = read_len + 16  # room for deletions consuming extra ref bases
    pos = rng.integers(0, G - margin, n_reads).astype(np.int32)
    reads = np.empty((n_reads, read_len), dtype=np.uint8)
    n_err = np.zeros(n_reads, dtype=np.int32)
    for r in range(n_reads):
        out, p, errs = [], int(pos[r]), 0
        while len(out) < read_len:
            u = rng.random()
            if u < sub_rate:
                out.append((ref[p] + int(rng.integers(1, 4))) % 4)
                p += 1
                errs += 1
            elif u < sub_rate + ins_rate:
                out.append(int(rng.integers(0, 4)))
                errs += 1
            elif u < sub_rate + ins_rate + del_rate:
                p += 1
                errs += 1
            else:
                out.append(ref[p])
                p += 1
        reads[r] = np.array(out[:read_len], dtype=np.uint8)
        n_err[r] = errs
    return ReadSet(reads=reads, true_pos=pos, n_errors=n_err)
