"""Synthetic genome + Illumina-like read simulator (ground truth attached).

The container has no genomic datasets; the paper's HG38 + 389M HiSeq-X reads
are replaced by a controlled simulator: a uniform-random reference (optionally
with repeated segments, to exercise high-frequency minimizers / the maxReads
cap) and reads sampled with substitution/insertion/deletion errors at
Illumina-like rates.  Every read carries its true origin so mapping accuracy
(paper Sec. VII-A) is measured against exact ground truth rather than a
surrogate mapper.

Real read sets are ~50% reverse-strand: ``sample_reads(both_strands=True)``
reverse-complements a coin-flip subset *after* sampling, so the forward
loci (and the forward-only RNG stream — ``both_strands=False`` stays
bit-identical to the historical behavior) are untouched and ``strand``
labels the ground truth.  ``true_pos`` is always the forward-reference
leftmost position — exactly what the mapper reports for either strand.

``write_fasta``/``write_fastq`` round-trip simulated worlds through the
real parsers of ``repro.io``, so I/O tests and the FASTQ-path benchmarks
run on the same ground-truthed data as the in-memory ones.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.encoding import decode_to_str, revcomp


@dataclasses.dataclass(frozen=True)
class ReadSet:
    reads: np.ndarray        # (R, rl) uint8 base codes (as sequenced)
    true_pos: np.ndarray     # (R,) int32 forward-ref origin position
    n_errors: np.ndarray     # (R,) int32 number of simulated edits
    strand: np.ndarray | None = None  # (R,) int8 0=fwd 1=revcomp sampled
    quals: np.ndarray | None = None   # (R, rl) uint8 phred+33 ASCII


def make_reference(length: int, seed: int = 0, repeat_frac: float = 0.05,
                   repeat_len: int = 500) -> np.ndarray:
    """Random reference with a fraction of duplicated segments.

    Duplications create repetitive minimizers — the workload feature that
    motivates DART-PIM's Reads-FIFO caps and the RISC-V lowTh offload.
    """
    rng = np.random.default_rng(seed)
    ref = rng.integers(0, 4, length).astype(np.uint8)
    n_rep = int(length * repeat_frac / max(repeat_len, 1))
    for _ in range(n_rep):
        src = int(rng.integers(0, length - repeat_len))
        dst = int(rng.integers(0, length - repeat_len))
        ref[dst : dst + repeat_len] = ref[src : src + repeat_len]
    return ref


def sample_reads(ref: np.ndarray, n_reads: int, read_len: int = 150,
                 sub_rate: float = 0.002, ins_rate: float = 0.0005,
                 del_rate: float = 0.0005, seed: int = 1,
                 both_strands: bool = False) -> ReadSet:
    """Sample reads uniformly; apply per-base edit errors.

    Rates default to Illumina-like (~0.3% total), well inside eth=6 for
    rl=150 so the banded WF is exact for typical reads.

    ``both_strands=True`` reverse-complements a ~50% coin-flip subset
    (separate RNG stream: the sampled loci and errors are identical to
    the forward-only run, only the sequenced orientation flips).
    Simulated phred+33 qualities are attached either way.
    """
    rng = np.random.default_rng(seed)
    G = len(ref)
    margin = read_len + 16  # room for deletions consuming extra ref bases
    pos = rng.integers(0, G - margin, n_reads).astype(np.int32)
    reads = np.empty((n_reads, read_len), dtype=np.uint8)
    n_err = np.zeros(n_reads, dtype=np.int32)
    for r in range(n_reads):
        out, p, errs = [], int(pos[r]), 0
        while len(out) < read_len:
            u = rng.random()
            if u < sub_rate:
                out.append((ref[p] + int(rng.integers(1, 4))) % 4)
                p += 1
                errs += 1
            elif u < sub_rate + ins_rate:
                out.append(int(rng.integers(0, 4)))
                errs += 1
            elif u < sub_rate + ins_rate + del_rate:
                p += 1
                errs += 1
            else:
                out.append(ref[p])
                p += 1
        reads[r] = np.array(out[:read_len], dtype=np.uint8)
        n_err[r] = errs
    strand = np.zeros(n_reads, dtype=np.int8)
    if both_strands:
        srng = np.random.default_rng(seed + 0x5A5A)
        strand = (srng.random(n_reads) < 0.5).astype(np.int8)
        flip = strand == 1
        reads[flip] = revcomp(reads[flip])
    qrng = np.random.default_rng(seed + 0x9E37)
    quals = (qrng.integers(30, 41, (n_reads, read_len)) + 33).astype(np.uint8)
    return ReadSet(reads=reads, true_pos=pos, n_errors=n_err, strand=strand,
                   quals=quals)


@dataclasses.dataclass(frozen=True)
class PairedReadSet:
    """Simulated paired-end reads with full ground truth attached.

    FR library geometry: each fragment of length ``isize`` yields an R1
    from one end and an R2 from the other, facing inward; ``flip`` says
    which physical end became R1 (coin flip, like a real prep), so R1 is
    forward for ~half the pairs and reverse for the rest.  ``pos1``/
    ``pos2`` are forward-reference leftmost positions — exactly what the
    mapper reports for either strand — and ``isize`` is the true
    fragment length TLEN should recover.
    """
    reads1: np.ndarray       # (N, rl) uint8 as-sequenced R1 codes
    reads2: np.ndarray       # (N, rl) uint8 as-sequenced R2 codes
    pos1: np.ndarray         # (N,) int32 forward-ref leftmost of R1
    pos2: np.ndarray         # (N,) int32 forward-ref leftmost of R2
    strand1: np.ndarray      # (N,) int8 0=fwd 1=revcomp
    strand2: np.ndarray      # (N,) int8
    isize: np.ndarray        # (N,) int32 true fragment length
    n_errors1: np.ndarray    # (N,) int32
    n_errors2: np.ndarray    # (N,) int32
    quals1: np.ndarray       # (N, rl) uint8 phred+33 ASCII
    quals2: np.ndarray       # (N, rl) uint8


def _read_with_errors(rng, ref, start: int, read_len: int, sub_rate: float,
                      ins_rate: float, del_rate: float):
    """One error-laden read sampled forward from ``ref[start:]`` — the
    same per-base edit process as ``sample_reads`` (kept separate so the
    single-end RNG stream stays bit-identical to the historical one)."""
    out, p, errs = [], int(start), 0
    while len(out) < read_len:
        u = rng.random()
        if u < sub_rate:
            out.append((ref[p] + int(rng.integers(1, 4))) % 4)
            p += 1
            errs += 1
        elif u < sub_rate + ins_rate:
            out.append(int(rng.integers(0, 4)))
            errs += 1
        elif u < sub_rate + ins_rate + del_rate:
            p += 1
            errs += 1
        else:
            out.append(ref[p])
            p += 1
    return np.array(out[:read_len], dtype=np.uint8), errs


def sample_pairs(ref: np.ndarray, n_pairs: int, read_len: int = 150,
                 insert_mean: float = 350.0, insert_sd: float = 30.0,
                 sub_rate: float = 0.002, ins_rate: float = 0.0005,
                 del_rate: float = 0.0005, unmappable_frac: float = 0.0,
                 seed: int = 1) -> PairedReadSet:
    """Sample FR paired-end fragments with ground-truth insert sizes.

    Fragment starts are uniform; lengths are normal
    (``insert_mean``/``insert_sd``), clipped to ``[read_len, 2*mean]``.
    The upstream mate is sequenced forward, the downstream mate
    reverse-complement (facing inward), and a coin flip decides which is
    R1 — so both ``(strand1, strand2)`` orientations occur, as in a real
    library.  ``unmappable_frac`` replaces that fraction of R2 mates
    with random sequence (simulated adapter/contaminant), the workload
    for mate rescue and the 0x8 FLAG path.
    """
    rng = np.random.default_rng(seed)
    G = len(ref)
    margin = read_len + 16
    isize = np.clip(np.round(rng.normal(insert_mean, insert_sd, n_pairs)),
                    read_len, 2 * insert_mean).astype(np.int32)
    starts = np.array([rng.integers(0, max(G - int(sz) - margin, 1))
                       for sz in isize], dtype=np.int32)
    r1 = np.empty((n_pairs, read_len), dtype=np.uint8)
    r2 = np.empty((n_pairs, read_len), dtype=np.uint8)
    e1 = np.zeros(n_pairs, dtype=np.int32)
    e2 = np.zeros(n_pairs, dtype=np.int32)
    pos1 = np.empty(n_pairs, dtype=np.int32)
    pos2 = np.empty(n_pairs, dtype=np.int32)
    s1 = np.empty(n_pairs, dtype=np.int8)
    s2 = np.empty(n_pairs, dtype=np.int8)
    for i in range(n_pairs):
        frag_lo = int(starts[i])
        frag_hi = frag_lo + int(isize[i]) - read_len  # downstream mate start
        up, ne_up = _read_with_errors(rng, ref, frag_lo, read_len,
                                      sub_rate, ins_rate, del_rate)
        dn_f, ne_dn = _read_with_errors(rng, ref, frag_hi, read_len,
                                        sub_rate, ins_rate, del_rate)
        dn = revcomp(dn_f)  # downstream mate is sequenced inward
        if rng.random() < 0.5:  # R1 = upstream (forward) mate
            r1[i], r2[i] = up, dn
            pos1[i], pos2[i] = frag_lo, frag_hi
            s1[i], s2[i] = 0, 1
            e1[i], e2[i] = ne_up, ne_dn
        else:                   # R1 = downstream (reverse) mate
            r1[i], r2[i] = dn, up
            pos1[i], pos2[i] = frag_hi, frag_lo
            s1[i], s2[i] = 1, 0
            e1[i], e2[i] = ne_dn, ne_up
    if unmappable_frac > 0:
        urng = np.random.default_rng(seed + 0x7777)
        junk = urng.random(n_pairs) < unmappable_frac
        r2[junk] = urng.integers(0, 4, (int(junk.sum()),
                                        read_len)).astype(np.uint8)
    qrng = np.random.default_rng(seed + 0x9E37)
    quals1 = (qrng.integers(30, 41, (n_pairs, read_len)) + 33
              ).astype(np.uint8)
    quals2 = (qrng.integers(30, 41, (n_pairs, read_len)) + 33
              ).astype(np.uint8)
    return PairedReadSet(reads1=r1, reads2=r2, pos1=pos1, pos2=pos2,
                         strand1=s1, strand2=s2, isize=isize,
                         n_errors1=e1, n_errors2=e2,
                         quals1=quals1, quals2=quals2)


# --------------------------------------------------------------------------
# Standard-format writers (round-trip partners of repro.io's parsers)
# --------------------------------------------------------------------------

def write_fasta(path_or_handle, contigs, width: int = 70) -> None:
    """Write contigs as FASTA.

    ``contigs`` is a single codes array (one record named ``ref``) or a
    list of ``(name, codes)`` pairs.  Lines wrap at ``width`` bases.
    """
    from ..io.fasta import _open
    if isinstance(contigs, np.ndarray):
        contigs = [("ref", contigs)]
    f, owned = _open(path_or_handle, "w")
    try:
        for name, codes in contigs:
            f.write(f">{name}\n")
            line = decode_to_str(codes)
            for i in range(0, len(line), width):
                f.write(line[i : i + width] + "\n")
    finally:
        if owned:
            f.close()


def write_fastq_pair(path1, path2, pairs: "PairedReadSet",
                     names: list[str] | None = None,
                     interleaved_path=None) -> None:
    """Write a ``PairedReadSet`` as R1/R2 FASTQ files (gzip when the
    paths end in ``.gz``), mate names suffixed ``/1``/``/2``.  Pass
    ``interleaved_path`` instead of ``path1``/``path2`` (set those to
    None) for the single-file interleaved layout."""
    base = (names if names is not None
            else [f"pair{i}" for i in range(len(pairs.reads1))])
    n1 = [f"{b}/1" for b in base]
    n2 = [f"{b}/2" for b in base]
    if interleaved_path is not None:
        from ..io.fasta import _open
        f, owned = _open(interleaved_path, "w")
        try:
            for i in range(len(base)):
                for nm, rd, ql in ((n1[i], pairs.reads1[i], pairs.quals1[i]),
                                   (n2[i], pairs.reads2[i],
                                    pairs.quals2[i])):
                    f.write(f"@{nm}\n{decode_to_str(rd)}\n+\n"
                            f"{np.asarray(ql).tobytes().decode('ascii')}\n")
        finally:
            if owned:
                f.close()
        return
    write_fastq(path1, pairs.reads1, pairs.quals1, n1)
    write_fastq(path2, pairs.reads2, pairs.quals2, n2)


def write_fastq(path_or_handle, reads, quals: np.ndarray | None = None,
                names: list[str] | None = None) -> None:
    """Write reads as 4-line FASTQ records (gzip-transparent: a path
    ending in ``.gz`` writes a compressed stream).

    ``reads`` is a ``ReadSet`` (qualities taken from it) or an
    ``(R, rl)`` codes array.  Missing qualities default to ``I``
    (phred 40); missing names to ``read<i>``.
    """
    from ..io.fasta import _open
    if isinstance(reads, ReadSet):
        quals = reads.quals if quals is None else quals
        reads = reads.reads
    reads = np.asarray(reads)
    if quals is None:
        quals = np.full(reads.shape, ord("I"), dtype=np.uint8)
    f, owned = _open(path_or_handle, "w")
    try:
        for i in range(len(reads)):
            name = names[i] if names is not None else f"read{i}"
            f.write(f"@{name}\n{decode_to_str(reads[i])}\n+\n"
                    f"{np.asarray(quals[i]).tobytes().decode('ascii')}\n")
    finally:
        if owned:
            f.close()
