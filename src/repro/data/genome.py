"""Synthetic genome + Illumina-like read simulator (ground truth attached).

The container has no genomic datasets; the paper's HG38 + 389M HiSeq-X reads
are replaced by a controlled simulator: a uniform-random reference (optionally
with repeated segments, to exercise high-frequency minimizers / the maxReads
cap) and reads sampled with substitution/insertion/deletion errors at
Illumina-like rates.  Every read carries its true origin so mapping accuracy
(paper Sec. VII-A) is measured against exact ground truth rather than a
surrogate mapper.

Real read sets are ~50% reverse-strand: ``sample_reads(both_strands=True)``
reverse-complements a coin-flip subset *after* sampling, so the forward
loci (and the forward-only RNG stream — ``both_strands=False`` stays
bit-identical to the historical behavior) are untouched and ``strand``
labels the ground truth.  ``true_pos`` is always the forward-reference
leftmost position — exactly what the mapper reports for either strand.

``write_fasta``/``write_fastq`` round-trip simulated worlds through the
real parsers of ``repro.io``, so I/O tests and the FASTQ-path benchmarks
run on the same ground-truthed data as the in-memory ones.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.encoding import decode_to_str, revcomp


@dataclasses.dataclass(frozen=True)
class ReadSet:
    reads: np.ndarray        # (R, rl) uint8 base codes (as sequenced)
    true_pos: np.ndarray     # (R,) int32 forward-ref origin position
    n_errors: np.ndarray     # (R,) int32 number of simulated edits
    strand: np.ndarray | None = None  # (R,) int8 0=fwd 1=revcomp sampled
    quals: np.ndarray | None = None   # (R, rl) uint8 phred+33 ASCII


def make_reference(length: int, seed: int = 0, repeat_frac: float = 0.05,
                   repeat_len: int = 500) -> np.ndarray:
    """Random reference with a fraction of duplicated segments.

    Duplications create repetitive minimizers — the workload feature that
    motivates DART-PIM's Reads-FIFO caps and the RISC-V lowTh offload.
    """
    rng = np.random.default_rng(seed)
    ref = rng.integers(0, 4, length).astype(np.uint8)
    n_rep = int(length * repeat_frac / max(repeat_len, 1))
    for _ in range(n_rep):
        src = int(rng.integers(0, length - repeat_len))
        dst = int(rng.integers(0, length - repeat_len))
        ref[dst : dst + repeat_len] = ref[src : src + repeat_len]
    return ref


def sample_reads(ref: np.ndarray, n_reads: int, read_len: int = 150,
                 sub_rate: float = 0.002, ins_rate: float = 0.0005,
                 del_rate: float = 0.0005, seed: int = 1,
                 both_strands: bool = False) -> ReadSet:
    """Sample reads uniformly; apply per-base edit errors.

    Rates default to Illumina-like (~0.3% total), well inside eth=6 for
    rl=150 so the banded WF is exact for typical reads.

    ``both_strands=True`` reverse-complements a ~50% coin-flip subset
    (separate RNG stream: the sampled loci and errors are identical to
    the forward-only run, only the sequenced orientation flips).
    Simulated phred+33 qualities are attached either way.
    """
    rng = np.random.default_rng(seed)
    G = len(ref)
    margin = read_len + 16  # room for deletions consuming extra ref bases
    pos = rng.integers(0, G - margin, n_reads).astype(np.int32)
    reads = np.empty((n_reads, read_len), dtype=np.uint8)
    n_err = np.zeros(n_reads, dtype=np.int32)
    for r in range(n_reads):
        out, p, errs = [], int(pos[r]), 0
        while len(out) < read_len:
            u = rng.random()
            if u < sub_rate:
                out.append((ref[p] + int(rng.integers(1, 4))) % 4)
                p += 1
                errs += 1
            elif u < sub_rate + ins_rate:
                out.append(int(rng.integers(0, 4)))
                errs += 1
            elif u < sub_rate + ins_rate + del_rate:
                p += 1
                errs += 1
            else:
                out.append(ref[p])
                p += 1
        reads[r] = np.array(out[:read_len], dtype=np.uint8)
        n_err[r] = errs
    strand = np.zeros(n_reads, dtype=np.int8)
    if both_strands:
        srng = np.random.default_rng(seed + 0x5A5A)
        strand = (srng.random(n_reads) < 0.5).astype(np.int8)
        flip = strand == 1
        reads[flip] = revcomp(reads[flip])
    qrng = np.random.default_rng(seed + 0x9E37)
    quals = (qrng.integers(30, 41, (n_reads, read_len)) + 33).astype(np.uint8)
    return ReadSet(reads=reads, true_pos=pos, n_errors=n_err, strand=strand,
                   quals=quals)


# --------------------------------------------------------------------------
# Standard-format writers (round-trip partners of repro.io's parsers)
# --------------------------------------------------------------------------

def write_fasta(path_or_handle, contigs, width: int = 70) -> None:
    """Write contigs as FASTA.

    ``contigs`` is a single codes array (one record named ``ref``) or a
    list of ``(name, codes)`` pairs.  Lines wrap at ``width`` bases.
    """
    from ..io.fasta import _open
    if isinstance(contigs, np.ndarray):
        contigs = [("ref", contigs)]
    f, owned = _open(path_or_handle, "w")
    try:
        for name, codes in contigs:
            f.write(f">{name}\n")
            line = decode_to_str(codes)
            for i in range(0, len(line), width):
                f.write(line[i : i + width] + "\n")
    finally:
        if owned:
            f.close()


def write_fastq(path_or_handle, reads, quals: np.ndarray | None = None,
                names: list[str] | None = None) -> None:
    """Write reads as 4-line FASTQ records.

    ``reads`` is a ``ReadSet`` (qualities taken from it) or an
    ``(R, rl)`` codes array.  Missing qualities default to ``I``
    (phred 40); missing names to ``read<i>``.
    """
    from ..io.fasta import _open
    if isinstance(reads, ReadSet):
        quals = reads.quals if quals is None else quals
        reads = reads.reads
    reads = np.asarray(reads)
    if quals is None:
        quals = np.full(reads.shape, ord("I"), dtype=np.uint8)
    f, owned = _open(path_or_handle, "w")
    try:
        for i in range(len(reads)):
            name = names[i] if names is not None else f"read{i}"
            f.write(f"@{name}\n{decode_to_str(reads[i])}\n+\n"
                    f"{np.asarray(quals[i]).tobytes().decode('ascii')}\n")
    finally:
        if owned:
            f.close()
