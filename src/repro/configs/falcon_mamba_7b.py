"""falcon-mamba-7b [ssm]: attention-free Mamba-1, state 16.
[arXiv:2410.05355; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=65024,
    ssm_state=16, mamba_version=1, norm="rms", use_rope=False, head_dim=1)
