"""The paper's own configuration (Table III) — read-mapping parameters."""
from repro.core.pipeline import MapperConfig

MAPPER = MapperConfig(read_len=150, k=12, w=30, eth=6, sat_affine=32,
                      max_minis=16, max_pls=32, filter_threshold=6)

# DART-PIM system parameters (Tables II/III)
MAX_READS = {"12.5k": 12_500, "25k": 25_000, "50k": 50_000}
LOW_TH = 3
READS_FIFO_ROWS = 160
LINEAR_BUF_ROWS = 32
AFFINE_BUF_ROWS = 64
