"""Model/arch configuration schema + input shape cells.

Every assigned architecture is a ``ModelConfig``; the four assignment shapes
are ``ShapeCell``s.  ``input_specs`` builds ShapeDtypeStruct stand-ins for
the dry-run (never allocates).  Modality frontends ([audio]/[vlm]) are stubs:
``input_kind='embeds'`` feeds precomputed frame/patch embeddings straight to
the backbone.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                     # dense | moe | ssm | hybrid | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                       # per-expert width for MoE
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    norm: str = "rms"               # rms | ln | ln_nonparam
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10_000.0
    causal: bool = True
    input_kind: str = "tokens"      # tokens | embeds
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    mamba_version: int = 0
    # hybrid (zamba-style): one SHARED attention block applied every N layers
    attn_every: int = 0
    # training
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived SSM dims
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def ssm_heads(self) -> int:
        return max(1, self.ssm_d_inner // 64)

    # ---- capabilities
    @property
    def supports_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.family == "moe":
            ffn = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
        elif self.family in ("ssm", "hybrid"):
            di, N = self.ssm_d_inner, self.ssm_state
            if self.mamba_version == 1:
                ffn = (d * 2 * di + di * (self.ssm_dt_rank + 2 * N)
                       + self.ssm_dt_rank * di + di * N + di * d)
            else:
                ffn = d * (2 * di + 2 * N + self.ssm_heads) + di * d
        else:
            ffn = 3 * d * self.d_ff
        per_layer = ffn if self.family == "ssm" else attn + ffn
        if self.family == "hybrid":
            per_layer = ffn  # mamba layers; one shared attn added below
        total = L * per_layer + 2 * self.vocab_size * d
        if self.family == "hybrid":
            total += attn
        if self.family == "ssm":
            total = L * ffn + 2 * self.vocab_size * d
        return total

    def active_params(self) -> int:
        """Active-per-token params (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        attn = d * self.head_dim * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = 3 * d * self.d_ff * self.top_k
        return L * (attn + ffn) + 2 * self.vocab_size * d


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per DESIGN.md §Arch-applicability."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full attention is O(L^2); 500k context needs " \
                      "sub-quadratic (SSM/hybrid) sequence mixing"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCell,
                batch_override: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.input_kind == "embeds":
            return {"embeds": f((B, S, cfg.d_model), jnp.bfloat16),
                    "labels": f((B, S), jnp.int32)}
        return {"tokens": f((B, S), jnp.int32),
                "labels": f((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.input_kind == "embeds":
            return {"embeds": f((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": f((B, S), jnp.int32)}
    # decode: one new token against a cache of length S
    return {"token": f((B, 1), jnp.int32),
            "pos": f((), jnp.int32)}
