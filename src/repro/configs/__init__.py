"""Config registry: one module per assigned architecture (+ the paper's own).

``get_config(arch_id)`` resolves --arch flags; ``reduced(cfg)`` shrinks any
config to a CPU-smoke-test size preserving its family wiring.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig, ShapeCell, SHAPES, cell_applicable, input_specs

from . import (falcon_mamba_7b, hubert_xlarge, moonshot_16b, olmo_1b,
               qwen2_vl_72b, qwen3_0p6b, qwen3_moe_235b, smollm_135m,
               stablelm_3b, zamba2_2p7b)

ARCHS = {
    m.CONFIG.arch: m.CONFIG
    for m in (zamba2_2p7b, olmo_1b, stablelm_3b, qwen3_0p6b, smollm_135m,
              qwen2_vl_72b, hubert_xlarge, falcon_mamba_7b, qwen3_moe_235b,
              moonshot_16b)
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return ARCHS[arch]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.family != "hybrid" else 4,
        d_model=128, d_ff=256 if cfg.d_ff else 0, vocab_size=512,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 1,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_chunk=16,
        attn_every=2 if cfg.attn_every else 0,
        remat=False,
    )
    return dataclasses.replace(cfg, **changes)


__all__ = ["ARCHS", "get_config", "reduced", "ModelConfig", "ShapeCell",
           "SHAPES", "cell_applicable", "input_specs"]
