"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, per-expert d_ff=1536,
GQA kv=4, qk-norm, head_dim=128. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, head_dim=128, d_ff=1536, vocab_size=151936,
    n_experts=128, top_k=8, qk_norm=True, norm="rms", rope_theta=1e6)
