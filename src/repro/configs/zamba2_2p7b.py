"""zamba2-2.7b [hybrid]: Mamba2 backbone + ONE shared attention block applied
every 6 layers (zamba-style weight sharing). [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm_state=64, mamba_version=2, attn_every=6, norm="rms")
