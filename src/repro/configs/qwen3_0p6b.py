"""qwen3-0.6b [dense]: qk-norm, GQA, head_dim=128. [hf:Qwen/Qwen3-8B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-0.6b", family="dense", n_layers=28, d_model=1024,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=3072, vocab_size=151936,
    qk_norm=True, norm="rms", rope_theta=1e6)
