"""hubert-xlarge [audio]: encoder-only (bidirectional), frame-embedding
frontend is a STUB; classifier over 504 cluster units.  No decode step
(encoder) — decode cells are SKIP by design. [arXiv:2106.07447; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504,
    causal=False, input_kind="embeds", norm="ln", use_rope=False)
