"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.
(Shared-expert path of Moonlight is omitted — noted in DESIGN.md.)
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840,
    n_experts=64, top_k=6, norm="rms")
