"""qwen2-vl-72b [vlm]: text backbone exact; vision frontend is a STUB —
input_specs feeds precomputed patch embeddings (B, S, d_model).  M-RoPE
reduces to 1-D RoPE for the text-only dry-run cells (see DESIGN.md).
[arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2-vl-72b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064,
    input_kind="embeds", norm="rms", rope_theta=1e6)
