"""olmo-1b [dense]: non-parametric LayerNorm (no scale/bias).
[arXiv:2402.00838; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=50304,
    norm="ln_nonparam")
