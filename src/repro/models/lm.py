"""Loss, train_step, prefill/serve_step factories.

``make_train_step``/``make_serve_step`` return jit-ready pure functions; the
launcher (repro/launch) attaches meshes and in/out shardings.  Cross-entropy
is computed against vocab-sharded logits (softmax stats reduce over the
sharded axis under GSPMD — no full logits replica ever materializes).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import transformer
from .layers import NO_SHARD, Shardings

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits, labels):
    """logits (B,S,V) any float dtype; labels (B,S) int32 -> scalar f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg, sh: Shardings = NO_SHARD):
    def loss_fn(params, batch):
        logits, aux = transformer.forward(params, batch, cfg, sh)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + MOE_AUX_WEIGHT * aux / max(cfg.n_layers, 1)
        return loss, {"ce": ce, "moe_aux": aux}
    return loss_fn


def make_train_step(cfg, optimizer, sh: Shardings = NO_SHARD,
                    num_microbatches: int = 1,
                    acc_dtype=jnp.float32):
    """optimizer: repro.train.optimizer.Optimizer (init/update pair).

    ``num_microbatches`` > 1 accumulates gradients over a lax.scan of
    microbatches — live activation/remat memory scales 1/M while the math
    is identical (mean of per-microbatch grads).  Microbatches interleave
    batch rows (stride M) so every data shard contributes rows to every
    microbatch — no resharding inside the scan.
    """
    loss_fn = make_loss_fn(cfg, sh)
    pspecs = transformer.param_specs(cfg, sh) if sh.enabled else None

    def constrain_like_params(tree):
        """Pin gradient shardings to the param specs — without this the
        scan-backward grad stacks come out replicated along the fsdp axis
        (multi-GiB per device at 72B scale)."""
        if pspecs is None:
            return tree
        try:
            return jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                tree, pspecs,
                is_leaf=lambda x: not isinstance(x, dict))
        except ValueError:
            return tree  # no mesh context

    def grads_of(params, batch):
        """Differentiate w.r.t. the bf16-cast tree: per-layer grad slices
        stay bf16 inside the scan backward (half the transient footprint);
        they are widened to f32 only at the (sharded) accumulation."""
        pc = transformer.cast_params(params)
        (loss, metrics), gb = jax.value_and_grad(loss_fn, has_aux=True)(
            pc, batch)
        gb = constrain_like_params(gb)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), gb)
        grads = constrain_like_params(grads)
        return (loss, metrics), grads

    def train_step(state, batch):
        params, opt_state, step = state
        if num_microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            M = num_microbatches

            def split(a):
                B = a.shape[0]
                assert B % M == 0, (B, M)
                a = a.reshape((B // M, M) + a.shape[1:])
                return jnp.swapaxes(a, 0, 1)  # (M, B/M, ...) strided rows

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                gacc, lacc, aacc = carry
                (l, m), g = grads_of(params, mb)
                gacc = jax.tree.map(
                    lambda x, y: (x + y.astype(acc_dtype)).astype(acc_dtype),
                    gacc, g)
                return (gacc, lacc + m["ce"], aacc + m["moe_aux"]), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (gsum, ce_sum, aux_sum), _ = jax.lax.scan(
                body, (g0, jnp.float32(0), jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / M, gsum)
            loss = ce_sum / M + MOE_AUX_WEIGHT * (aux_sum / M) / max(
                cfg.n_layers, 1)
            metrics = {"ce": ce_sum / M, "moe_aux": aux_sum / M}
        updates, new_opt = optimizer.update(grads, opt_state, params, step)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        gnorm = optimizer.global_norm(grads)
        return (new_params, new_opt, step + 1), {
            "loss": loss, "grad_norm": gnorm, **metrics}

    return train_step


def make_eval_step(cfg, sh: Shardings = NO_SHARD):
    loss_fn = make_loss_fn(cfg, sh)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step


def make_prefill_step(cfg, sh: Shardings = NO_SHARD):
    """Full-sequence forward (the prefill_* cells). Returns last logits."""
    def prefill(params, batch):
        logits, _ = transformer.forward(params, batch, cfg, sh,
                                        last_only=True)
        return logits[:, -1]
    return prefill


def make_serve_step(cfg, sh: Shardings = NO_SHARD,
                    seq_shard_axes: Sequence[str] = ()):
    """One-token decode (the decode_* / long_* cells)."""
    def serve_step(params, cache, token, pos):
        logits, new_cache = transformer.decode_step(
            params, cache, token, pos, cfg, sh,
            seq_shard_axes=seq_shard_axes)
        return logits[:, -1], new_cache
    return serve_step


def greedy_generate(params, cfg, prompt_tokens, n_new: int,
                    max_seq: int | None = None, sh: Shardings = NO_SHARD):
    """Small-scale generation helper for the examples (prefill+decode)."""
    B, S0 = prompt_tokens.shape
    max_seq = max_seq or (S0 + n_new)
    cache = transformer.init_cache(cfg, B, max_seq)
    serve = jax.jit(make_serve_step(cfg, sh))

    # prefill by stepping (simple + exact; fine for example scale)
    tok = prompt_tokens[:, :1]
    out = [prompt_tokens]
    logits = None
    for t in range(S0 + n_new - 1):
        logits, cache = serve(params, cache, tok, jnp.int32(t))
        if t + 1 < S0:
            tok = prompt_tokens[:, t + 1 : t + 2]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)
