"""Transformer building blocks (pure JAX, sharding-annotated).

Conventions:
  * params are plain dicts of jnp arrays; fp32 storage, bf16 compute.
  * every function takes a ``Shardings`` helper so activation constraints
    follow whatever mesh (('data','model') or ('pod','data','model')) is
    active; with no mesh the constraints are no-ops.
  * attention supports GQA, RoPE (with position offset for decode), optional
    qk-norm (Qwen3), causal/bidirectional, and a KV-cache decode path with
    optional *sequence-sharded* cache (distributed flash-decode: local
    softmax stats + psum combine) for the long-context cells.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Shardings:
    """Logical->mesh axis mapping. Empty tuples mean 'replicated'.

    ``fsdp`` names the mesh axes that additionally shard *parameters*
    (ZeRO-3: weights gathered at use, optimizer state stays sharded) —
    typically the data axis.  ``model_size`` is the model-axis extent, used
    to drop head-axis constraints when head counts don't divide it.
    """
    batch: tuple = ("data",)     # ('pod','data') on the multi-pod mesh
    model: tuple = ("model",)
    fsdp: tuple = ()
    seq: tuple = ()              # sequence-parallel carries (perf variant)
    model_size: int = 1
    enabled: bool = True

    def spec(self, *axes) -> P:
        return P(*[a if a else None for a in axes])

    def maybe_model(self, n: int) -> tuple:
        """Model axes only if ``n`` divides evenly (e.g. few KV heads)."""
        if self.model and self.model_size > 1 and n % self.model_size != 0:
            return ()
        return self.model

    def constrain(self, x, *axes):
        if not self.enabled:
            return x
        try:
            return jax.lax.with_sharding_constraint(x, self.spec(*axes))
        except ValueError:
            return x  # no mesh in context (e.g. plain CPU tests)


NO_SHARD = Shardings(batch=(), model=(), enabled=False)


def compute_dtype(x):
    return x.astype(jnp.bfloat16)


# ----------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale=None, bias=None, eps: float = 1e-5):
    """LayerNorm; scale/bias may be None (OLMo's non-parametric LN)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    if kind == "ln":
        return layer_norm(x, p.get("scale"), p.get("bias"))
    if kind == "ln_nonparam":
        return layer_norm(x, None, None)
    raise ValueError(kind)


def init_norm(key, d, kind: str):
    if kind == "ln_nonparam":
        return {}
    return {"scale": jnp.ones((d,), jnp.float32)}


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                    # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- attention
def init_attention(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (d, nh * hd), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, nkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, nkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(k4, (nh * hd, d), jnp.float32) * s,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(x, p, cfg, positions, sh: Shardings):
    B, S, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ compute_dtype(p["wq"])).reshape(B, S, nh, hd)
    k = (x @ compute_dtype(p["wk"])).reshape(B, S, nkv, hd)
    v = (x @ compute_dtype(p["wv"])).reshape(B, S, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = sh.constrain(q, sh.batch, None, sh.maybe_model(nh), None)
    k = sh.constrain(k, sh.batch, None, sh.maybe_model(nkv), None)
    v = sh.constrain(v, sh.batch, None, sh.maybe_model(nkv), None)
    return q, k, v


def _sdpa(q, k, v, causal: bool, q_offset=None):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> (B,Sq,H,hd).

    GQA via grouped einsum — the KV tensors are never replicated across the
    query-head group (a ``repeat`` would copy the whole KV cache rep times
    in the decode path)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    logits = logits * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (0 if q_offset is None else q_offset)
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where((qi >= ki)[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q, k, v, causal: bool, q_chunk: int = 1024,
                  kv_chunk: int = 1024):
    """Flash-style online-softmax attention: O(q_chunk * kv_chunk) live
    memory instead of O(S^2).  q (B,S,H,hd); k/v (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    nq, nk = S // qc, S // kc
    scale = 1.0 / math.sqrt(hd)
    # grouped GQA: KV never replicated across the rep query heads
    qr = jnp.moveaxis(q.reshape(B, nq, qc, KV, rep, hd), 1, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, KV, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, KV, hd), 1, 0)

    def q_block(_, qin):
        qb, qi = qin                                       # (B,qc,KV,rep,hd)
        m0 = jnp.full((B, KV, rep, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, qc), jnp.float32)
        a0 = jnp.zeros((B, qc, KV, rep, hd), jnp.float32)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_block(carry, kin):
            m, l, acc = carry
            kb, vb, ki = kin
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb).astype(
                jnp.float32) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)[:, None]
                kpos = ki * kc + jnp.arange(kc)[None, :]
                s = jnp.where((qpos >= kpos)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))     # (B,KV,rep,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * jnp.moveaxis(corr, 3, 1)[..., None] + jnp.einsum(
                "bgrqk,bkgd->bqgrd", p.astype(qb.dtype), vb).astype(
                    jnp.float32)
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (kr, vr, jnp.arange(nk)))
        # l (B,KV,rep,qc) -> (B,qc,KV,rep,1) to divide acc
        out = acc / jnp.maximum(jnp.transpose(l, (0, 3, 1, 2)),
                                1e-30)[..., None]
        return None, out.astype(q.dtype)

    # remat per chunk: backward recomputes score blocks instead of saving
    # every (B, H, qc, kc) probability tile (flash-attention memory shape).
    q_block = jax.checkpoint(
        q_block, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_block, None, (qr, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


ATTN_CHUNK_THRESHOLD = 2048


def attention(x, p, cfg, sh: Shardings, positions=None, causal=True):
    """Full (training / prefill) attention. x (B, S, D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _qkv(x, p, cfg, positions, sh)
    if S > ATTN_CHUNK_THRESHOLD:
        o = _sdpa_chunked(q, k, v, causal)
    else:
        o = _sdpa(q, k, v, causal)
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = o @ compute_dtype(p["wo"])
    return sh.constrain(out, sh.batch, None, None)


def decode_attention(x, p, cfg, sh: Shardings, cache, pos, *,
                     seq_shard_axes: Sequence[str] = ()):
    """One-token decode with KV cache.

    x (B, 1, D); cache dict {k,v: (B, S_max, KV, hd), len: scalar int32}.
    ``seq_shard_axes``: mesh axes the cache sequence dim is sharded over —
    softmax stats are psum-combined across them (distributed flash-decode),
    enabling long_500k where one device cannot hold the cache.
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k_new, v_new = _qkv(x, p, cfg, positions, sh)
    if seq_shard_axes:
        # each shard owns rows [flat*S_local, (flat+1)*S_local) of the cache
        flat = jnp.int32(0)
        for a in seq_shard_axes:
            flat = flat * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        S_local = cache["k"].shape[1]
        local_pos = pos - flat * S_local
        in_range = (local_pos >= 0) & (local_pos < S_local)
        up = jnp.clip(local_pos, 0, S_local - 1)
        k_cache = jnp.where(
            in_range,
            jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, up, 1),
            cache["k"])
        v_cache = jnp.where(
            in_range,
            jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, up, 1),
            cache["v"])
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        rep = H // KV
        qg = q.reshape(B, 1, KV, rep, hd)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg,
                            k_cache).astype(jnp.float32)
        logits = logits / math.sqrt(hd)
        kidx = jnp.arange(S_local)[None, None, None, None, :] + flat * S_local
        logits = jnp.where(kidx <= pos, logits, -1e30)
        m = jnp.max(logits, axis=-1, keepdims=True)
        for a in seq_shard_axes:
            m = jax.lax.pmax(m, a)
        ew = jnp.exp(logits - m)                         # (B,KV,rep,1,S)
        num = jnp.einsum("bgrqk,bkgd->bqgrd", ew.astype(q.dtype), v_cache)
        den = jnp.sum(ew, axis=-1).astype(jnp.float32)   # (B,KV,rep,1)
        num = num.astype(jnp.float32)
        for a in seq_shard_axes:
            num = jax.lax.psum(num, a)
            den = jax.lax.psum(den, a)
        den_q = jnp.transpose(den, (0, 3, 1, 2))[..., None]  # (B,1,KV,rep,1)
        o = (num / jnp.maximum(den_q, 1e-30)).astype(x.dtype)
        o = o.reshape(B, 1, H, hd)
        new_cache = {"k": k_cache, "v": v_cache}
    elif cache["k"].dtype == jnp.int8:
        # int8-quantized KV cache (beyond-paper §Perf optimization):
        # halves the decode memory-roofline term.  Per-(token, kv-head)
        # symmetric scales; dequantization is folded into the attention
        # einsums — the cache is never materialized in bf16.
        B1, _, KV, hd = k_new.shape
        H = cfg.n_heads
        rep = H // KV

        def quant(x):
            s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0 + 1e-12
            return jnp.round(x.astype(jnp.float32) / s).astype(jnp.int8), \
                s.astype(jnp.float32)

        k_q, k_s = quant(k_new)
        v_q, v_s = quant(v_new)
        upd = lambda c, n: jax.lax.dynamic_update_slice_in_dim(c, n, pos, 1)
        k_cache = upd(cache["k"], k_q)
        v_cache = upd(cache["v"], v_q)
        ks_cache = upd(cache["k_scale"], k_s)
        vs_cache = upd(cache["v_scale"], v_s)
        qg = q.reshape(B, 1, KV, rep, hd)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg,
                            k_cache.astype(jnp.bfloat16)).astype(jnp.float32)
        # fold in the per-(token, head) scale: (B,S,KV,1)->(B,KV,1,1,S)
        ksT = jnp.transpose(ks_cache, (0, 2, 3, 1))[:, :, :, None, :]
        logits = logits * ksT / math.sqrt(hd)
        kidx = jnp.arange(k_cache.shape[1])[None, None, None, None, :]
        logits = jnp.where(kidx <= pos, logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        vsT = jnp.transpose(vs_cache, (0, 2, 3, 1))[:, :, :, None, :]
        o = jnp.einsum("bgrqk,bkgd->bqgrd", (w * vsT).astype(jnp.bfloat16),
                       v_cache.astype(jnp.bfloat16))
        o = o.reshape(B, 1, H, hd).astype(x.dtype)
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks_cache,
                     "v_scale": vs_cache}
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new,
                                                      pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new,
                                                      pos, 1)
        o = _sdpa(q, k_cache, v_cache, causal=True, q_offset=pos)
        new_cache = {"k": k_cache, "v": v_cache}
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    out = o @ compute_dtype(p["wo"])
    return sh.constrain(out, sh.batch, None, None), new_cache


# ----------------------------------------------------------------- mlp
def init_mlp(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    s = 1.0 / math.sqrt(d)
    return {
        "wi": jax.random.normal(k1, (d, f), jnp.float32) * s,
        "wg": jax.random.normal(k2, (d, f), jnp.float32) * s,
        "wo": jax.random.normal(k3, (f, d), jnp.float32) / math.sqrt(f),
    }


def mlp(x, p, sh: Shardings):
    h = jax.nn.silu(x @ compute_dtype(p["wg"])) * (x @ compute_dtype(p["wi"]))
    h = sh.constrain(h, sh.batch, None, sh.model)
    return sh.constrain(h @ compute_dtype(p["wo"]), sh.batch, None, None)


# ----------------------------------------------------------------- MoE
def init_moe(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = 1.0 / math.sqrt(d)
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s,
        "wi": jax.random.normal(k2, (e, d, f), jnp.float32) * s,
        "wg": jax.random.normal(k3, (e, d, f), jnp.float32) * s,
        "wo": jax.random.normal(k4, (e, f, d), jnp.float32) / math.sqrt(f),
    }


MOE_SEQ_CHUNK = 4096


def moe(x, p, cfg, sh: Shardings, capacity_factor: float = 1.25):
    """Sequence-chunked wrapper over ``_moe_chunk``: long sequences are
    dispatched in <=MOE_SEQ_CHUNK slices via lax.scan so the (B, E*cap, D)
    dispatch buffer stays bounded (prefill_32k would otherwise need a
    ~5 GiB/device buffer).  Capacity is per chunk — slightly *more*
    load-balanced than global capacity."""
    B, S, D = x.shape
    C = MOE_SEQ_CHUNK
    if S <= C:
        return _moe_chunk(x, p, cfg, sh, capacity_factor)
    assert S % C == 0
    xc = jnp.moveaxis(x.reshape(B, S // C, C, D), 1, 0)

    def body(aux, xi):
        y, a = _moe_chunk(xi, p, cfg, sh, capacity_factor)
        return aux + a, y

    aux, ys = jax.lax.scan(body, jnp.float32(0.0), xc)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    return y, aux / (S // C)


def _moe_chunk(x, p, cfg, sh: Shardings, capacity_factor: float = 1.25):
    """Token-choice top-k MoE with per-batch-row capacity, EP over ``model``.

    Dispatch is scatter-based (sort-free ranking via a cumsum over one-hot
    expert assignments), computed independently per batch row so every
    tensor keeps a leading batch axis — the dispatch buffer shards as
    (batch, expert, ...) over (data, model), i.e. DP x EP, and the
    scatter/gather reshard is GSPMD's all_to_all.  This is the paper's
    owner-computes pattern (minimizer-sharded segments) applied to experts;
    overflow tokens are dropped (residual passthrough), the same bounded-
    capacity trade as DART-PIM's Reads-FIFO.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    cap = max(int(capacity_factor * S * K / E), 1)
    logits = (x @ compute_dtype(p["router"])).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (B, S, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # rank of each (token, k) within its expert, per batch row — sort-based:
    # O(S*K log) and O(S*K) memory (a one-hot cumsum would materialize a
    # (B, S*K, E) int32 tensor: hundreds of GiB at prefill_32k scale).
    flat_e = top_e.reshape(B, S * K)
    order = jnp.argsort(flat_e, axis=1, stable=True)          # (B, S*K)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(jnp.searchsorted)(sorted_e, sorted_e)    # leftmost equal
    rank_sorted = (jnp.arange(S * K, dtype=jnp.int32)[None, :]
                   - first.astype(jnp.int32))
    rank = jnp.zeros((B, S * K), jnp.int32)
    rank = rank.at[jnp.arange(B)[:, None], order].set(rank_sorted)
    rank = rank.reshape(B, S, K)
    keep = rank < cap
    slot = jnp.where(keep, top_e * cap + rank, E * cap)       # (B, S, K)

    # dispatch/combine as vmapped per-row scatter/gather: the batching dim
    # stays a real batch dim in the HLO, so GSPMD keeps everything sharded
    # on (data) — explicit b_idx index arrays defeat that and replicate.
    x_rep = jnp.broadcast_to(x[:, :, None, :], (B, S, K, D))
    flat_slot = slot.reshape(B, S * K)

    def scatter_row(xr, sl):
        return jnp.zeros((E * cap + 1, D), x.dtype).at[sl].set(xr)

    buf = jax.vmap(scatter_row)(x_rep.reshape(B, S * K, D), flat_slot)
    hidden = buf[:, :-1].reshape(B, E, cap, D)
    hidden = sh.constrain(hidden, sh.batch, sh.model, None, None)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", hidden,
                               compute_dtype(p["wg"])))
    h = h * jnp.einsum("becd,edf->becf", hidden, compute_dtype(p["wi"]))
    out = jnp.einsum("becf,efd->becd", h, compute_dtype(p["wo"]))
    out = sh.constrain(out, sh.batch, sh.model, None, None)
    outflat = jnp.concatenate(
        [out.reshape(B, E * cap, D), jnp.zeros((B, 1, D), out.dtype)], axis=1)
    gathered = jnp.take_along_axis(outflat, flat_slot[..., None], axis=1)
    gathered = gathered.reshape(B, S, K, D)
    combined = jnp.sum(gathered * top_p[..., None].astype(out.dtype), axis=2)
    # aux load-balancing loss (Switch-style), returned for the trainer
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return sh.constrain(combined, sh.batch, None, None), aux
