from . import layers, lm, ssm, transformer  # noqa: F401
