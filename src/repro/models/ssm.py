"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 / SSD (zamba2).

Training path uses **chunked scans**: the sequence is cut into static chunks;
within a chunk Mamba-1 uses a numerically-stable associative scan over
(decay, input) pairs and Mamba-2 uses the SSD matmul formulation (decay-
masked (C·B^T) attention-like GEMMs — MXU-friendly); chunks are chained with
a lax.scan carrying the (B, heads/channels, state) SSM state.  This bounds
live memory to one chunk's expanded tensors instead of O(S * d_inner * N).

Decode path carries (ssm_state, conv_state) per layer — O(1) per token, the
reason the long_500k cell is runnable for these families at all.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Shardings, compute_dtype


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv, window K.  x (B, S, C), w (K, C), b (C,).

    If conv_state (B, K-1, C) is given (decode), it prefixes x and the new
    state is returned."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * compute_dtype(w)[i] for i in range(K))
    y = y + compute_dtype(b)
    new_state = xp[:, -(K - 1) :] if K > 1 else None
    return y, new_state


# ===================================================================== Mamba-1
def init_mamba1(key, cfg):
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    dtr = cfg.ssm_dt_rank
    K = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (K, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * N),
                                    jnp.float32) / math.sqrt(di),
        "dt_proj": jax.random.normal(ks[3], (dtr, di),
                                     jnp.float32) / math.sqrt(dtr),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d),
                                      jnp.float32) / math.sqrt(di),
    }


def _mamba1_scan_chunk(h_in, a, bx):
    """Associative scan within a chunk.  a, bx (B, C, di, N); h_in (B, di, N).

    h_t = a_t * h_{t-1} + bx_t.  Returns (h_all (B,C,di,N), h_out)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_all = a_c * h_in[:, None] + b_c
    return h_all, h_all[:, -1]


def mamba1_block(x, p, cfg, sh: Shardings):
    """Training/prefill forward.  x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    di, N, dtr = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    C = min(cfg.ssm_chunk, S)
    assert S % C == 0
    xz = x @ compute_dtype(p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = sh.constrain(xin, sh.batch, None, sh.model)
    xin, _ = _causal_conv(xin, p["conv_w"], p["conv_b"])
    xin = jax.nn.silu(xin)
    dbc = xin @ compute_dtype(p["x_proj"])
    dt_in, Bm, Cm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ compute_dtype(p["dt_proj"])).astype(jnp.float32)
        + p["dt_bias"])                                   # (B,S,di) f32
    A = -jnp.exp(p["A_log"])                              # (di, N)

    nc = S // C
    xin_c = xin.reshape(B, nc, C, di)
    dt_c = dt.reshape(B, nc, C, di)
    B_c = Bm.reshape(B, nc, C, N)
    C_c = Cm.reshape(B, nc, C, N)

    def chunk_step(h, inputs):
        xc, dtc, bc, cc = inputs                          # (B,C,...)
        a = jnp.exp(dtc[..., None] * A).astype(jnp.float32)   # (B,C,di,N)
        bx = (dtc * xc.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[:, :, None, :]          # (B,C,di,N)
        h_all, h_out = _mamba1_scan_chunk(h, a, bx)
        y = jnp.einsum("bcdn,bcn->bcd", h_all,
                       cc.astype(jnp.float32))               # (B,C,di)
        return h_out, y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    xs = (jnp.moveaxis(xin_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
          jnp.moveaxis(B_c, 1, 0), jnp.moveaxis(C_c, 1, 0))
    _, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = y + xin * compute_dtype(p["D"])
    y = y * jax.nn.silu(z)
    y = sh.constrain(y, sh.batch, None, sh.model)
    out = y @ compute_dtype(p["out_proj"])
    return sh.constrain(out, sh.batch, None, None)


def mamba1_decode(x, p, cfg, sh: Shardings, state):
    """x (B, 1, D); state {"h": (B,di,N) f32, "conv": (B,K-1,di)}."""
    B = x.shape[0]
    di, N, dtr = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    xz = x @ compute_dtype(p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xin = jax.nn.silu(xin)
    dbc = xin @ compute_dtype(p["x_proj"])
    dt_in, Bm, Cm = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ compute_dtype(p["dt_proj"])).astype(jnp.float32)
        + p["dt_bias"])[:, 0]                             # (B, di)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                        # (B,di,N)
    bx = (dt * xin[:, 0].astype(jnp.float32))[..., None] \
        * Bm[:, 0].astype(jnp.float32)[:, None, :]
    h = a * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y[:, None].astype(x.dtype) + xin * compute_dtype(p["D"])
    y = y * jax.nn.silu(z)
    out = y @ compute_dtype(p["out_proj"])
    return sh.constrain(out, sh.batch, None, None), \
        {"h": h, "conv": conv_state}


def init_mamba1_state(cfg, batch: int):
    return {"h": jnp.zeros((batch, cfg.ssm_d_inner, cfg.ssm_state),
                           jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner),
                              jnp.bfloat16)}


# ===================================================================== Mamba-2
def init_mamba2(key, cfg):
    d, di, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        # [x, z, B, C, dt]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * N + H), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[1], (K, di + 2 * N),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di + 2 * N,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d),
                                      jnp.float32) / math.sqrt(di),
    }


def mamba2_block(x, p, cfg, sh: Shardings):
    """SSD chunked forward.  x (B, S, D) -> (B, S, D)."""
    from .layers import rms_norm
    B, S, D = x.shape
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    C = min(cfg.ssm_chunk, S)
    assert S % C == 0
    proj = x @ compute_dtype(p["in_proj"])
    xin, z, Bm, Cm, dt_in = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xin = sh.constrain(xin, sh.batch, None, sh.model)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                        # (H,)
    la = dt * A                                                     # log-decay

    nc = S // C
    xh = xin.reshape(B, nc, C, H, P)
    dtc = dt.reshape(B, nc, C, H)
    lac = la.reshape(B, nc, C, H)
    Bc = Bm.reshape(B, nc, C, N)
    Cc = Cm.reshape(B, nc, C, N)

    tri = jnp.tril(jnp.ones((C, C), jnp.float32))

    def chunk_step(h, inputs):
        xc, dtk, lak, bk, ck = inputs   # (B,C,H,P) (B,C,H) (B,C,H) (B,C,N) x2
        cum = jnp.cumsum(lak, axis=1)                       # (B,C,H)
        # intra-chunk: att[t,s] = (C_t . B_s) exp(cum_t - cum_s) dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", ck.astype(jnp.float32),
                        bk.astype(jnp.float32))             # (B,C,C)
        decay = jnp.exp(cum[:, :, None] - cum[:, None])     # (B,t,s,H)
        att = cb[..., None] * decay * dtk[:, None]          # (B,t,s,H)
        att = att * tri[None, :, :, None]
        y = jnp.einsum("btsh,bshp->bthp", att,
                       xc.astype(jnp.float32))              # (B,C,H,P)
        # inter-chunk: y_t += C_t . (exp(cum_t) h_in)
        y = y + jnp.einsum("btn,bhpn,bth->bthp", ck.astype(jnp.float32),
                           h, jnp.exp(cum))
        # state update
        tot = cum[:, -1]                                    # (B,H)
        hb = jnp.einsum("bsh,bsn,bshp->bhpn",
                        jnp.exp(tot[:, None] - cum) * dtk,
                        bk.astype(jnp.float32), xc.astype(jnp.float32))
        h_out = jnp.exp(tot)[:, :, None, None] * h + hb
        return h_out, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = tuple(jnp.moveaxis(v, 1, 0) for v in (xh, dtc, lac, Bc, Cc))
    _, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = y + xin * jnp.repeat(compute_dtype(p["D"]), P)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ compute_dtype(p["out_proj"])
    return sh.constrain(out, sh.batch, None, None)


def mamba2_decode(x, p, cfg, sh: Shardings, state):
    """x (B,1,D); state {"h": (B,H,P,N) f32, "conv": (B,K-1,di+2N)}."""
    from .layers import rms_norm
    B = x.shape[0]
    di, N, H = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    proj = x @ compute_dtype(p["in_proj"])
    xin, z, Bm, Cm, dt_in = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_in[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                   # (B,H)
    xh = xin[:, 0].reshape(B, H, P).astype(jnp.float32)
    hb = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0].astype(jnp.float32), xh)
    h = a[:, :, None, None] * state["h"] + hb
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y + xin * jnp.repeat(compute_dtype(p["D"]), P)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ compute_dtype(p["out_proj"])
    return sh.constrain(out, sh.batch, None, None), \
        {"h": h, "conv": conv_state}


def init_mamba2_state(cfg, batch: int):
    H, P = cfg.ssm_heads, cfg.ssm_d_inner // cfg.ssm_heads
    return {"h": jnp.zeros((batch, H, P, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros(
                (batch, cfg.ssm_conv - 1, cfg.ssm_d_inner + 2 * cfg.ssm_state),
                jnp.bfloat16)}
