"""Model assembly: init, param sharding specs, forward, prefill, decode.

All families share one pytree layout:
  params = {
    "embed"      : (V, D)  [tokens archs]           — sharded (None, model)
    "blocks"     : stacked per-layer dicts (L, ...) — scanned
    "shared_attn": {"ln", "attn"}                   [hybrid only, ONE copy]
    "final_norm" : norm params
    "lm_head"    : (D, V)                           — sharded (None, model)
  }

scan-over-layers keeps the HLO O(1) in depth (essential for 80-94-layer
configs compiling on one CPU host); ``cfg.remat`` wraps the block body in
jax.checkpoint with a dots-saveable policy for training memory.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from . import layers, ssm
from .layers import Shardings, compute_dtype
from jax.sharding import PartitionSpec as P


# ------------------------------------------------------------------ init
def _init_block(key, cfg):
    ks = jax.random.split(key, 4)
    if cfg.family in ("dense", "encoder"):
        return {"ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm),
                "attn": layers.init_attention(ks[1], cfg),
                "ln2": layers.init_norm(ks[2], cfg.d_model, cfg.norm),
                "mlp": layers.init_mlp(ks[3], cfg)}
    if cfg.family == "moe":
        return {"ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm),
                "attn": layers.init_attention(ks[1], cfg),
                "ln2": layers.init_norm(ks[2], cfg.d_model, cfg.norm),
                "moe": layers.init_moe(ks[3], cfg)}
    if cfg.family == "ssm":
        return {"ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm),
                "mamba": ssm.init_mamba1(ks[1], cfg)}
    if cfg.family == "hybrid":
        return {"ln1": layers.init_norm(ks[0], cfg.d_model, cfg.norm),
                "mamba": ssm.init_mamba2(ks[1], cfg)}
    raise ValueError(cfg.family)


def init_params(cfg, key):
    k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    params = {
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(block_keys),
        "final_norm": layers.init_norm(k_head, cfg.d_model, cfg.norm),
        "lm_head": jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
        / (cfg.d_model ** 0.5),
    }
    if cfg.input_kind == "tokens":
        params["embed"] = jax.random.normal(
            k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "ln": layers.init_norm(k_shared, cfg.d_model, cfg.norm),
            "attn": layers.init_attention(k_shared, cfg)}
    return params


def abstract_params(cfg, key=None):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg),
        jax.random.key(0) if key is None else key)


# ------------------------------------------------------- sharding specs
# Parameters shard 2-D: TP over ``model`` (output/contract dims) x ZeRO-3
# over ``fsdp`` (the other large dim).  GSPMD all-gathers the fsdp shards at
# use and reduce-scatters gradients back — optimizer state stays fully
# sharded, which is what lets 72B/235B param configs fit 16 GiB chips.
def _attn_specs(cfg, sh: Shardings, prefix=()):
    m, f = sh.model, sh.fsdp
    pre = lambda s: P(*(prefix + tuple(s)))
    out = {"wq": pre((f, m)), "wk": pre((f, m)), "wv": pre((f, m)),
           "wo": pre((m, f))}
    if cfg.qk_norm:
        out["q_norm"] = pre((None,))
        out["k_norm"] = pre((None,))
    return out


def _norm_specs(cfg, prefix=()):
    if cfg.norm == "ln_nonparam":
        return {}
    return {"scale": P(*(prefix + (None,)))}


def _block_specs(cfg, sh: Shardings):
    m, f = sh.model, sh.fsdp
    pre = (None,)  # stacked layer axis
    if cfg.family in ("dense", "encoder"):
        return {"ln1": _norm_specs(cfg, pre),
                "attn": _attn_specs(cfg, sh, pre),
                "ln2": _norm_specs(cfg, pre),
                "mlp": {"wi": P(None, f, m), "wg": P(None, f, m),
                        "wo": P(None, m, f)}}
    if cfg.family == "moe":
        return {"ln1": _norm_specs(cfg, pre),
                "attn": _attn_specs(cfg, sh, pre),
                "ln2": _norm_specs(cfg, pre),
                "moe": {"router": P(None, f, None),
                        "wi": P(None, m, f, None),
                        "wg": P(None, m, f, None),
                        "wo": P(None, m, None, f)}}
    if cfg.family == "ssm":
        return {"ln1": _norm_specs(cfg, pre),
                "mamba": {"in_proj": P(None, f, m),
                          "conv_w": P(None, None, m),
                          "conv_b": P(None, m),
                          "x_proj": P(None, m, f),
                          "dt_proj": P(None, f, m),
                          "dt_bias": P(None, m),
                          "A_log": P(None, m, None),
                          "D": P(None, m),
                          "out_proj": P(None, m, f)}}
    if cfg.family == "hybrid":
        return {"ln1": _norm_specs(cfg, pre),
                "mamba": {"in_proj": P(None, f, m),
                          "conv_w": P(None, None, None),
                          "conv_b": P(None, None),
                          "dt_bias": P(None, None),
                          "A_log": P(None, None),
                          "D": P(None, None),
                          "norm_scale": P(None, m),
                          "out_proj": P(None, m, f)}}
    raise ValueError(cfg.family)


def param_specs(cfg, sh: Shardings):
    m, f = sh.model, sh.fsdp
    vocab_m = sh.maybe_model(cfg.vocab_size)  # hubert's 504 stays unsharded
    specs = {
        "blocks": _block_specs(cfg, sh),
        "final_norm": _norm_specs(cfg),
        "lm_head": P(f, vocab_m if vocab_m else None),
    }
    if cfg.input_kind == "tokens":
        # column-sharded: row gather stays local (no one-hot rewrite / table
        # all-gather); the vocab axis is sharded only at the unembed.
        specs["embed"] = P(f, m)
    if cfg.family == "hybrid":
        specs["shared_attn"] = {"ln": _norm_specs(cfg),
                                "attn": _attn_specs(cfg, sh)}
    return specs


# ------------------------------------------------------------- forward
_KEEP_F32 = {"A_log", "dt_bias", "conv_b", "D", "scale", "norm_scale",
             "q_norm", "k_norm", "router"}


def cast_params(params):
    """bf16-cast the large matrices ONCE, outside the layer scan — FSDP
    all-gathers then move bf16, halving gather traffic and the per-layer
    gathered-weights footprint.  Precision-sensitive leaves stay f32."""
    def cast(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _KEEP_F32 or leaf.dtype != jnp.float32:
            return leaf
        return leaf.astype(jnp.bfloat16)
    return jax.tree_util.tree_map_with_path(cast, params)


def _block_fwd(x, pl, cfg, sh: Shardings):
    """One layer. Returns (x, aux)."""
    aux = jnp.float32(0.0)
    if cfg.family in ("dense", "encoder"):
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        x = x + layers.attention(h, pl["attn"], cfg, sh, causal=cfg.causal)
        h = layers.apply_norm(x, pl["ln2"], cfg.norm)
        x = x + layers.mlp(h, pl["mlp"], sh)
    elif cfg.family == "moe":
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        x = x + layers.attention(h, pl["attn"], cfg, sh, causal=cfg.causal)
        h = layers.apply_norm(x, pl["ln2"], cfg.norm)
        y, aux = layers.moe(h, pl["moe"], cfg, sh)
        x = x + y
    elif cfg.family == "ssm":
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        x = x + ssm.mamba1_block(h, pl["mamba"], cfg, sh)
    elif cfg.family == "hybrid":
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        x = x + ssm.mamba2_block(h, pl["mamba"], cfg, sh)
    return x, aux


def _scan_blocks(x, blocks, cfg, sh: Shardings):
    """Depth scan with sqrt(L) two-level remat.

    Per-layer jax.checkpoint alone still saves the (L, B, S, D) carry stack
    for the backward pass; nesting a second checkpoint around segments of
    ~sqrt(L) layers cuts the saved stack to O(sqrt(L)) segment boundaries
    plus one transient segment during its backward — the classic
    sqrt-remat trade (a few % extra recompute for ~L/(2*sqrt(L)) less
    carry memory).
    """
    fn = functools.partial(_block_fwd, cfg=cfg, sh=sh)
    if cfg.remat:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, pl):
        x, aux = carry
        # barrier: stops XLA hoisting the FSDP weight all-gather out of the
        # loop (LICM would materialize the *full* gathered weight stack —
        # tens of GiB at 72B scale — defeating ZeRO-3).
        pl = jax.lax.optimization_barrier(pl)
        if sh.seq:
            # sequence parallelism: carries live seq-sharded on the model
            # axis; GSPMD all-gathers around attention and reduce-scatters
            # after the projections (perf variant, see EXPERIMENTS.md §Perf)
            x = sh.constrain(x, sh.batch, sh.seq, None)
        x, a = fn(x, pl)
        return (x, aux + a), None

    def seq(x, aux, blks):
        (x, aux), _ = jax.lax.scan(body, (x, aux), blks)
        return x, aux

    L = jax.tree.leaves(blocks)[0].shape[0]
    if not cfg.remat or L < 16:
        return seq(x, jnp.float32(0.0), blocks)

    s = max(int(L ** 0.5 + 0.5), 1)
    k = L // s

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def seg_fn(x, aux, seg):
        return seq(x, aux, seg)

    main = jax.tree.map(
        lambda a: a[: k * s].reshape((k, s) + a.shape[1:]), blocks)

    def outer(carry, seg):
        x, aux = carry
        x, aux = seg_fn(x, aux, seg)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(outer, (x, jnp.float32(0.0)), main)
    if L - k * s:
        rest = jax.tree.map(lambda a: a[k * s :], blocks)
        x, aux = seg_fn(x, aux, rest)
    return x, aux


def _shared_attn(x, p, cfg, sh: Shardings):
    h = layers.apply_norm(x, p["ln"], cfg.norm)
    return x + layers.attention(h, p["attn"], cfg, sh, causal=cfg.causal)


def forward(params, batch, cfg, sh: Shardings = layers.NO_SHARD,
            last_only: bool = False):
    """Training/prefill forward pass -> (logits, aux).

    ``last_only``: unembed only the final position (prefill serving) — the
    (B, S, V) logits tensor is never materialized."""
    params = cast_params(params)
    if cfg.input_kind == "tokens":
        x = compute_dtype(params["embed"])[batch["tokens"]]
    else:
        x = batch["embeds"].astype(jnp.bfloat16)
    x = sh.constrain(x, sh.batch, None, None)

    if cfg.family == "hybrid" and cfg.attn_every:
        n_seg = cfg.n_layers // cfg.attn_every
        seg_blocks = jax.tree.map(
            lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:]),
            params["blocks"])
        aux = jnp.float32(0.0)
        for s in range(n_seg):
            seg = jax.tree.map(lambda a: a[s], seg_blocks)
            x, a = _scan_blocks(x, seg, cfg, sh)
            x = _shared_attn(x, params["shared_attn"], cfg, sh)
            aux = aux + a
    else:
        x, aux = _scan_blocks(x, params["blocks"], cfg, sh)

    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    if last_only:
        x = x[:, -1:]
    logits = x @ compute_dtype(params["lm_head"])
    logits = sh.constrain(logits, sh.batch, None, sh.model)
    return logits, aux


# ------------------------------------------------------------- serving
def init_cache(cfg, batch: int, max_seq: int, abstract: bool = False,
               kv_quant: bool = False):
    """Per-layer decode state, stacked on the layer axis.

    ``kv_quant``: int8 KV cache + per-(token, head) f32 scales (beyond-paper
    decode optimization; see layers.decode_attention)."""
    def mk(shape, dtype):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    L = cfg.n_layers

    def kv():
        shp = (L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        if kv_quant:
            sshp = (L, batch, max_seq, cfg.n_kv_heads, 1)
            return {"k": mk(shp, jnp.int8), "v": mk(shp, jnp.int8),
                    "k_scale": mk(sshp, jnp.float32),
                    "v_scale": mk(sshp, jnp.float32)}
        return {"k": mk(shp, jnp.bfloat16), "v": mk(shp, jnp.bfloat16)}
    if cfg.family in ("dense", "moe", "encoder"):
        return {"attn": kv()}
    if cfg.family == "ssm":
        return {"ssm": {
            "h": mk((L, batch, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32),
            "conv": mk((L, batch, cfg.ssm_conv - 1, cfg.ssm_d_inner),
                       jnp.bfloat16)}}
    if cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        di2 = cfg.ssm_d_inner + 2 * cfg.ssm_state
        c = {"ssm": {
            "h": mk((L, batch, cfg.ssm_heads,
                     cfg.ssm_d_inner // cfg.ssm_heads, cfg.ssm_state),
                    jnp.float32),
            "conv": mk((L, batch, cfg.ssm_conv - 1, di2), jnp.bfloat16)}}
        if n_sites:
            c["attn"] = {
                "k": mk((n_sites, batch, max_seq, cfg.n_kv_heads,
                         cfg.head_dim), jnp.bfloat16),
                "v": mk((n_sites, batch, max_seq, cfg.n_kv_heads,
                         cfg.head_dim), jnp.bfloat16)}
        return c
    raise ValueError(cfg.family)


def cache_specs(cfg, sh: Shardings, seq_shard_axes: Sequence[str] = (),
                kv_quant: bool = False):
    """PartitionSpecs matching init_cache structure.

    KV caches shard on the kv-head axis when the head count divides the
    model axis; otherwise the *sequence* axis takes the model axis
    (distributed flash-decode — GSPMD inserts the softmax-stat reductions).
    ``seq_shard_axes`` (long_500k) forces sequence sharding on those axes.
    """
    seq = tuple(seq_shard_axes) if seq_shard_axes else None
    heads = sh.maybe_model(cfg.n_kv_heads) if cfg.n_kv_heads else ()
    if seq is None and not heads and sh.model:
        seq = sh.model
    kvspec = P(None, sh.batch if not seq_shard_axes else None, seq,
               heads if heads else None, None)
    if cfg.family in ("dense", "moe", "encoder"):
        d = {"attn": {"k": kvspec, "v": kvspec}}
        if kv_quant:
            d["attn"]["k_scale"] = kvspec
            d["attn"]["v_scale"] = kvspec
        return d
    if cfg.family == "ssm":
        return {"ssm": {"h": P(None, sh.batch, sh.model, None),
                        "conv": P(None, sh.batch, None, sh.model)}}
    if cfg.family == "hybrid":
        c = {"ssm": {"h": P(None, sh.batch, None, None, None),
                     "conv": P(None, sh.batch, None, None)}}
        if cfg.attn_every:
            c["attn"] = {"k": kvspec, "v": kvspec}
        return c
    raise ValueError(cfg.family)


def _block_decode(x, pl, cache_l, pos, cfg, sh, seq_shard_axes):
    if cfg.family in ("dense", "moe", "encoder"):
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        a, kv = layers.decode_attention(h, pl["attn"], cfg, sh,
                                        cache_l["attn"], pos,
                                        seq_shard_axes=seq_shard_axes)
        x = x + a
        h = layers.apply_norm(x, pl["ln2"], cfg.norm)
        if cfg.family == "moe":
            # decode batches are tiny: provision full capacity (no drops)
            y, _ = layers.moe(h, pl["moe"], cfg, sh,
                              capacity_factor=cfg.n_experts / cfg.top_k)
        else:
            y = layers.mlp(h, pl["mlp"], sh)
        x = x + y
        return x, {"attn": kv}
    if cfg.family == "ssm":
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        y, st = ssm.mamba1_decode(h, pl["mamba"], cfg, sh, cache_l["ssm"])
        return x + y, {"ssm": st}
    if cfg.family == "hybrid":
        h = layers.apply_norm(x, pl["ln1"], cfg.norm)
        y, st = ssm.mamba2_decode(h, pl["mamba"], cfg, sh, cache_l["ssm"])
        return x + y, {"ssm": st}
    raise ValueError(cfg.family)


def decode_step(params, cache, token, pos, cfg,
                sh: Shardings = layers.NO_SHARD,
                seq_shard_axes: Sequence[str] = ()):
    """One-token decode. token (B, 1) int32 (or embeds (B,1,D)); pos scalar.

    Returns (logits (B, 1, V), new_cache)."""
    params = cast_params(params)
    if cfg.input_kind == "tokens":
        x = compute_dtype(params["embed"])[token]
    else:
        x = token.astype(jnp.bfloat16)
    x = sh.constrain(x, sh.batch, None, None)

    def body(x, inputs):
        pl, cache_l = inputs
        x, new_c = _block_decode(x, pl, cache_l, pos, cfg, sh,
                                 seq_shard_axes)
        return x, new_c

    if cfg.family == "hybrid" and cfg.attn_every:
        n_seg = cfg.n_layers // cfg.attn_every
        seg_blocks = jax.tree.map(
            lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:]),
            params["blocks"])
        seg_ssm = jax.tree.map(
            lambda a: a.reshape((n_seg, cfg.attn_every) + a.shape[1:]),
            cache["ssm"])
        new_ssm, new_k, new_v = [], [], []
        for s in range(n_seg):
            seg = jax.tree.map(lambda a: a[s], seg_blocks)
            seg_c = {"ssm": jax.tree.map(lambda a: a[s], seg_ssm)}
            x, nc = jax.lax.scan(
                lambda xx, ins: body(xx, (ins[0], {"ssm": ins[1]})),
                x, (seg, seg_c["ssm"]))
            new_ssm.append(nc["ssm"])
            h = layers.apply_norm(x, params["shared_attn"]["ln"], cfg.norm)
            site_cache = {"k": cache["attn"]["k"][s],
                          "v": cache["attn"]["v"][s]}
            a, kv = layers.decode_attention(
                h, params["shared_attn"]["attn"], cfg, sh, site_cache, pos,
                seq_shard_axes=seq_shard_axes)
            x = x + a
            new_k.append(kv["k"])
            new_v.append(kv["v"])
        new_cache = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                *new_ssm),
            "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}}
    else:
        x, new_inner = jax.lax.scan(body, x, (params["blocks"], cache))
        new_cache = new_inner

    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = x @ compute_dtype(params["lm_head"])
    logits = sh.constrain(logits, sh.batch, None, sh.model)
    return logits, new_cache
