"""``ShardedGenomeIndex`` — the partitioned index as a session object.

The index lifecycle this package replaces was "one flat array + one
dict-like CSR, rebuilt from FASTA on every run".  Here the unit is the
*partition*: minimizers are assigned to partition ``hash32(kmer) % P``
(the crossbar rule shared with ``core.distributed.shard_index``), each
partition is a self-contained CSR + segment store, and the whole thing
lives either

* **on disk** (``open_index`` / ``load_index`` over the directory format
  of ``repro.index.format``, built by ``repro.index.build``), memmapped
  so cold-start touches only the pages a run needs, or
* **in memory** (``shard_flat_index`` partitions an existing
  ``GenomeIndex``), for tests and small references.

Both spellings plug into ``Mapper``:

* ``topology="mesh"`` consumes ``to_mesh_shards()`` — partition *i*
  lands on shard *i* directly with zero runtime re-hashing;
* ``topology="single"`` routes reads to partitions host-side with
  lazy/LRU device residency under a memory budget
  (``repro.index.residency``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.distributed import ShardedIndex
from ..core.index import GenomeIndex, validate_geometry
from ..io.fasta import Contig, ReferenceMap
from . import format as fmt
from .npscan import np_hash32


@dataclasses.dataclass
class Partition:
    """One partition's CSR + segments (arrays may be memmaps)."""
    kmers: np.ndarray       # (n_kmers,) uint32, sorted
    offsets: np.ndarray     # (n_kmers+1,) int32/int64 CSR
    positions: np.ndarray   # (n_occ,) int32/int64
    seg_len: int
    segments_raw: np.ndarray | None = None    # (n_occ, seg_len) uint8
    seg2bit: np.ndarray | None = None         # packed on-disk form
    segsent: np.ndarray | None = None
    _seg_cache: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n_kmers(self) -> int:
        return len(self.kmers)

    @property
    def n_occurrences(self) -> int:
        return len(self.positions)

    def read_segments(self) -> np.ndarray:
        """Materialize (n_occ, seg_len) uint8 segments, **uncached** —
        the residency layer calls this on partition load and must not
        leave a host copy pinned behind the device budget."""
        if self.segments_raw is not None:
            return np.asarray(self.segments_raw)
        if self.n_occurrences == 0:
            return np.zeros((0, self.seg_len), dtype=np.uint8)
        return fmt.unpack_codes(np.asarray(self.seg2bit),
                                np.asarray(self.segsent), self.seg_len)

    @property
    def segments(self) -> np.ndarray:
        """Cached materialized segments (tests / to_genome_index)."""
        if self.segments_raw is not None:
            return np.asarray(self.segments_raw)
        if self._seg_cache is None:
            self._seg_cache = self.read_segments()
        return self._seg_cache

    def storage_bytes(self) -> dict:
        """True on-disk footprint of this partition (2-bit packed)."""
        seg = (self.n_occurrences
               * (fmt.packed_cols(self.seg_len)
                  + fmt.sentinel_cols(self.seg_len)))
        hash_table = (self.kmers.nbytes + self.offsets.nbytes
                      + self.positions.nbytes)
        return {"hash_table_bytes": int(hash_table),
                "segments_bytes": int(seg),
                "n_kmers": self.n_kmers,
                "n_occurrences": self.n_occurrences}


@dataclasses.dataclass
class ShardedGenomeIndex:
    """Minimizer-partitioned genome index (P partitions, crossbar rule)."""
    parts: list
    read_len: int
    k: int
    w: int
    eth: int
    spacer: int
    ref_len: int
    contigs: list
    max_pls_per_minimizer: int = 256
    path: str | None = None
    manifest: dict | None = None
    packed_ref: fmt.PackedReference | None = None

    def __post_init__(self):
        validate_geometry(read_len=self.read_len, k=self.k, w=self.w,
                          eth=self.eth)

    # -- geometry (mirrors GenomeIndex so MapperConfig.from_index works) --
    @property
    def num_partitions(self) -> int:
        return len(self.parts)

    @property
    def seg_len(self) -> int:
        return 2 * (self.read_len + self.eth) - self.k

    @property
    def pad(self) -> int:
        return self.read_len + self.eth - self.k

    @property
    def n_occurrences(self) -> int:
        return sum(p.n_occurrences for p in self.parts)

    # ------------------------------------------------------------- routing
    def route(self, kmers: np.ndarray) -> np.ndarray:
        """Owning partition id per k-mer code — the crossbar rule."""
        return (np_hash32(np.asarray(kmers, np.uint32))
                % np.uint32(self.num_partitions)).astype(np.int32)

    def lookup(self, kmer: int) -> np.ndarray:
        """All minimizer positions of one k-mer code (host-side; the
        union-over-partitions property tests compare this against the
        flat ``GenomeIndex`` CSR)."""
        part = self.parts[int(self.route(np.array([kmer]))[0])]
        empty = np.zeros(0, dtype=np.asarray(part.positions).dtype)
        if part.n_kmers == 0:
            return empty
        i = int(np.searchsorted(part.kmers, np.uint32(kmer)))
        if i >= part.n_kmers or part.kmers[i] != np.uint32(kmer):
            return empty
        return np.asarray(part.positions[part.offsets[i]:
                                         part.offsets[i + 1]])

    def reference_map(self) -> ReferenceMap:
        return ReferenceMap(self.contigs)

    def reference_codes(self) -> np.ndarray:
        """The full spacer-concatenated reference as uint8 codes.

        Materializes ``ref_len`` bytes (the paired-end mate-rescue path
        needs the flat reference); only available when the index carries
        its packed reference (on-disk indexes always do).
        """
        if self.packed_ref is None:
            raise ValueError(
                "this ShardedGenomeIndex carries no packed reference "
                "(in-memory shard_flat_index without ref=); open an "
                "on-disk index or pass ref= when sharding")
        if self.packed_ref.origin:
            raise ValueError(
                f"this index sits at virtual origin "
                f"{self.packed_ref.origin}: materializing the flat "
                f"reference (paired mate rescue) is not supported on "
                f"origin-shifted indexes — map unpaired, or build the "
                f"index with origin=0")
        return self.packed_ref.codes()

    # -------------------------------------------------------- conversions
    def to_genome_index(self) -> GenomeIndex:
        """Merge partitions back into one flat ``GenomeIndex``.

        Materializes every segment — a test/compat spelling (it is the
        identity inverse of ``shard_flat_index``, which the equivalence
        suite asserts), not the way to map at scale.
        """
        ks = [np.asarray(p.kmers) for p in self.parts]
        all_k = np.concatenate(ks) if ks else np.zeros(0, np.uint32)
        counts = np.concatenate([np.diff(p.offsets) for p in self.parts]) \
            if ks else np.zeros(0, np.int64)
        order = np.argsort(all_k, kind="stable")
        pos_parts, seg_parts = [], []
        part_of = np.concatenate(
            [np.full(p.n_kmers, i, np.int32)
             for i, p in enumerate(self.parts)]) if ks else np.zeros(0)
        within = np.concatenate(
            [np.arange(p.n_kmers, dtype=np.int64) for p in self.parts]) \
            if ks else np.zeros(0, np.int64)
        for oi in order:
            p = self.parts[int(part_of[oi])]
            i = int(within[oi])
            lo, hi = int(p.offsets[i]), int(p.offsets[i + 1])
            pos_parts.append(np.asarray(p.positions[lo:hi]))
            seg_parts.append(p.segments[lo:hi])
        positions = (np.concatenate(pos_parts) if pos_parts
                     else np.zeros(0, np.int32))
        segments = (np.concatenate(seg_parts) if seg_parts
                    else np.zeros((0, self.seg_len), np.uint8))
        # int64-accumulated CSR, narrowed only when safe: an int32 cumsum
        # here wraps silently past 2^31 total occurrences
        offsets = fmt.csr_offsets(counts[order])
        pos_dtype = fmt.position_dtype(max(self.ref_len - 1, 0))
        return GenomeIndex(uniq_kmers=all_k[order].astype(np.uint32),
                           offsets=offsets,
                           positions=positions.astype(pos_dtype),
                           segments=segments.astype(np.uint8),
                           read_len=self.read_len, k=self.k, w=self.w,
                           eth=self.eth)

    def to_mesh_shards(self) -> ShardedIndex:
        """Stack partitions into the mesh's padded per-shard layout —
        partition *i* goes to shard *i*, nothing is re-hashed."""
        if self.ref_len - 1 > fmt.INT32_MAX:
            raise ValueError(
                f"mesh shards hold int32 positions but this index ends at "
                f"global position {self.ref_len - 1} (> {fmt.INT32_MAX}); "
                f"map references past 2^31 bases on topology='single', "
                f"which routes through the int64-clean device arena")
        return ShardedIndex.from_partitions(
            [(np.asarray(p.kmers), np.asarray(p.offsets).astype(np.int32),
              np.asarray(p.positions).astype(np.int32), p.read_segments())
             for p in self.parts],
            read_len=self.read_len, k=self.k, w=self.w, eth=self.eth,
            seg_len=self.seg_len)

    # ----------------------------------------------------------- accounting
    def storage_bytes(self) -> dict:
        """On-disk footprint with the per-partition breakdown."""
        per_part = []
        for i, p in enumerate(self.parts):
            d = p.storage_bytes()
            d["partition"] = i
            per_part.append(d)
        hash_table = sum(d["hash_table_bytes"] for d in per_part)
        seg = sum(d["segments_bytes"] for d in per_part)
        origin = self.packed_ref.origin if self.packed_ref else 0
        phys = self.ref_len - origin  # ref_len is the global end (v2)
        ref = fmt.packed_cols(phys) + fmt.sentinel_cols(phys)
        return {
            "hash_table_bytes": int(hash_table),
            "materialized_segments_bytes": int(seg),
            "reference_bytes": int(ref),
            "total_bytes": int(hash_table + seg + ref),
            "blowup": seg / max(hash_table, 1),
            "num_partitions": self.num_partitions,
            "per_partition": per_part,
        }


def shard_flat_index(index: GenomeIndex, num_partitions: int, *,
                     contigs: list | None = None, spacer: int | None = None,
                     ref: np.ndarray | None = None) -> ShardedGenomeIndex:
    """Partition an in-memory ``GenomeIndex`` by the crossbar rule.

    The in-memory twin of ``build_sharded_index``: same partition
    assignment, same per-partition (kmer, pos) order, no disk.  ``ref``
    (the flat reference codes) is optional and only needed when the
    result must serve ``reference_codes()`` (paired mate rescue).
    """
    from .build import _validate_partitions
    _validate_partitions(num_partitions)
    P = int(num_partitions)
    h = np.asarray(np_hash32(index.uniq_kmers)) % P
    counts = np.diff(index.offsets)
    parts = []
    for p in range(P):
        sel = np.where(h == p)[0]
        kmers = index.uniq_kmers[sel]
        pc = counts[sel]
        # int64 cumsum, narrowed when safe (satellite of the v2 audit:
        # the old int32 cumsum wrapped before the int64 repeat below)
        offsets = fmt.csr_offsets(pc)
        idx = (np.repeat(index.offsets[sel].astype(np.int64), pc)
               + (np.arange(int(pc.sum()), dtype=np.int64)
                  - np.repeat(offsets[:-1].astype(np.int64), pc)))
        parts.append(Partition(
            kmers=kmers.astype(np.uint32), offsets=offsets,
            positions=np.asarray(index.positions)[idx],
            seg_len=index.seg_len,
            segments_raw=index.segments[idx]))
    if contigs is None:
        if ref is not None:
            ref_len = len(ref)
        elif len(index.positions):
            # positions are minimizer k-mer starts; the farthest one can
            # sit up to w+k-2 bases short of the reference end (leftmost
            # k-mer of the final window), so use the geometric upper
            # bound.  Pass ref=/contigs= when exact lengths matter.
            ref_len = int(index.positions.max()) + index.w + index.k - 1
        else:
            ref_len = 0
        contigs = [Contig(name="ref", length=ref_len, offset=0)]
    packed = None
    if ref is not None:
        p2, sb = fmt.pack_codes(np.asarray(ref, np.uint8))
        packed = fmt.PackedReference(p2, sb, len(ref))
    return ShardedGenomeIndex(
        parts=parts, read_len=index.read_len, k=index.k, w=index.w,
        eth=index.eth,
        spacer=spacer if spacer is not None else
        index.read_len + 2 * index.eth,
        ref_len=packed.length if packed else
        max((c.offset + c.length for c in contigs), default=0),
        contigs=contigs, packed_ref=packed)


def open_index(index_dir: str, *, mmap: bool = True,
               verify: str = "size") -> ShardedGenomeIndex:
    """Open a persistent index directory.

    ``mmap=True`` (default) memory-maps every array — cold-start cost is
    the manifest plus file-size checks, and pages fault in as mapping
    touches them.  ``verify``: ``"none"`` trusts the directory,
    ``"size"`` (default) checks every file's byte size against the
    manifest, ``"full"`` additionally streams every file through crc32.
    """
    if verify not in ("none", "size", "full"):
        raise ValueError(f"verify={verify!r}; expected 'none', 'size' or "
                         f"'full'")
    man = fmt.load_manifest(index_dir)
    if verify != "none":
        fmt.check_integrity(index_dir, man, full=verify == "full")
    seg_len = 2 * (man["read_len"] + man["eth"]) - man["k"]
    if man["seg_len"] != seg_len:
        raise fmt.IndexFormatError(
            f"{index_dir}: manifest seg_len={man['seg_len']} does not match "
            f"geometry 2*(read_len+eth)-k={seg_len}; manifest is corrupt")
    parts = []
    for pm in man["partitions"]:
        pf = fmt.load_partition(index_dir, pm["id"], mmap=mmap)
        if (len(pf.kmers) != pm["n_kmers"]
                or len(pf.offsets) != pm["n_kmers"] + 1
                or len(pf.positions) != pm["n_occurrences"]
                or pf.seg2bit.shape != (pm["n_occurrences"],
                                        fmt.packed_cols(seg_len))):
            raise fmt.IndexIntegrityError(
                f"{index_dir}: partition {pm['id']} array shapes disagree "
                f"with the manifest (kmers {len(pf.kmers)}/{pm['n_kmers']}, "
                f"positions {len(pf.positions)}/{pm['n_occurrences']}); "
                f"rebuild the index")
        if str(pf.positions.dtype) != man["position_dtype"]:
            raise fmt.IndexIntegrityError(
                f"{index_dir}: partition {pm['id']} positions are "
                f"{pf.positions.dtype} but the manifest says "
                f"{man['position_dtype']}; rebuild the index")
        parts.append(Partition(kmers=pf.kmers, offsets=pf.offsets,
                               positions=pf.positions, seg_len=seg_len,
                               seg2bit=pf.seg2bit, segsent=pf.segsent))
    contigs = [Contig(name=c["name"], length=c["length"], offset=c["offset"])
               for c in man["contigs"]]
    return ShardedGenomeIndex(
        parts=parts, read_len=man["read_len"], k=man["k"], w=man["w"],
        eth=man["eth"], spacer=man["spacer"], ref_len=man["ref_len"],
        contigs=contigs,
        max_pls_per_minimizer=man["max_pls_per_minimizer"],
        path=index_dir, manifest=man,
        packed_ref=fmt.load_reference(index_dir, man, mmap=mmap))


def load_index(index_dir: str) -> ShardedGenomeIndex:
    """Fully load an index into RAM with full crc32 verification."""
    return open_index(index_dir, mmap=False, verify="full")


def verify_index(index_dir: str) -> dict:
    """Full-integrity check; returns the manifest or raises
    ``IndexIntegrityError`` listing every mismatching file."""
    man = fmt.load_manifest(index_dir)
    fmt.check_integrity(index_dir, man, full=True)
    return man
