"""Streamed out-of-core construction of the sharded genome index.

Two phases, both with peak memory bounded by the tile size (plus one
partition's occurrence list), never by the genome:

**Phase 1 — scan.**  The FASTA streams in bounded chunks
(``io.fasta.stream_fasta``); contigs are virtually concatenated with
``spacer`` SENTINEL bases exactly as ``io.fasta.load_reference`` does,
so minimizer positions and segment contents match the flat in-memory
path bit for bit.  A rolling buffer walks the virtual sequence in
``tile_bp`` tiles with a ``w-1``-base left halo and ``w+k-2``-base
right halo: every window whose minimizer lands in the tile is
evaluated, and occurrences are kept only when their position falls
inside the tile — tiles partition the position axis, so the union over
tiles is exactly the flat occurrence set with no duplicates.  Each
occurrence is routed to partition ``hash32(kmer) % P`` (the crossbar
rule) and appended to that partition's spill file as a packed
``uint64 (kmer << pos_bits) | pos`` key, where ``pos_bits =
64 - (2*k + 1)`` — k-mer codes spanning the sentinel base 4 carry one
bit past 2-bit packing (k <= 16, so at least 31 position bits; k=12
leaves 39 bits ≈ 5*10^11 bases — far past GRCh38).  Spills are
strictly append-only behind a
small bounded per-partition write buffer (``_SpillWriter``), so a tile
flush costs at most one sequential write per touched partition and
total spill I/O is linear in spilled bytes.  The 2-bit-packed
reference is written incrementally alongside.

**Phase 2 — finalize.**  Per partition: read the spill, ``np.unique``
the packed keys (one shot = dedup + (kmer, pos) sort, the same order
``core.index.build_index`` produces), cap hyper-repetitive minimizers
at ``max_pls_per_minimizer`` occurrences (first by position, same rule
as the flat build), emit the CSR, and extract segments in bounded
batches from the packed reference (out-of-range bases read as
SENTINEL, matching the flat build's padded slicing).

The minimizer scan is the pure-numpy ``npscan`` port: no jax in the
loop means no per-tile retracing, and the builder's entire footprint
is visible to ``tracemalloc`` — which is how the bounded-RSS property
is asserted in tests.
"""
from __future__ import annotations

import os
import time

import numpy as np

from ..core.index import SENTINEL, validate_geometry
from ..io.fasta import Contig, stream_fasta
from ..obs import registry as _metrics
from ..obs import tracing as _tracing
from . import format as fmt
from .npscan import np_hash32, np_minimizers

_INT32_MAX = fmt.INT32_MAX


def _validate_partitions(num_partitions: int) -> None:
    p = num_partitions
    if not isinstance(p, (int, np.integer)) or p < 1 or (p & (p - 1)):
        raise ValueError(
            f"num_partitions={p!r}: partition count must be a power of two "
            f">= 1 — partitions map onto mesh shards and pow-2 request "
            f"buckets, and hash32(kmer) % P only spreads hash bits evenly "
            f"for pow-2 P")


class _PackedRefWriter:
    """Incremental 2-bit + sentinel-bit reference writer.

    Accepts arbitrary-length code chunks; packs and flushes in
    8-base-aligned blocks (8 = lcm of the 4-codes/byte and 8-bits/byte
    layouts) with a small carry, so the byte image equals
    ``format.pack_codes`` over the whole sequence.
    """

    def __init__(self, codes_path: str, sent_path: str):
        self._fc = open(codes_path, "wb")
        self._fs = open(sent_path, "wb")
        self._pending = np.zeros(0, np.uint8)
        self.length = 0

    def write(self, codes: np.ndarray) -> None:
        codes = np.asarray(codes, np.uint8)
        self.length += len(codes)
        buf = (np.concatenate([self._pending, codes])
               if len(self._pending) else codes)
        n8 = (len(buf) // 8) * 8
        if n8:
            packed, sent = fmt.pack_codes(buf[:n8])
            self._fc.write(packed.tobytes())
            self._fs.write(sent.tobytes())
        self._pending = buf[n8:].copy()

    def close(self) -> None:
        if len(self._pending):
            packed, sent = fmt.pack_codes(self._pending)
            self._fc.write(packed.tobytes())
            self._fs.write(sent.tobytes())
            self._pending = np.zeros(0, np.uint8)
        self._fc.close()
        self._fs.close()


class _SpillWriter:
    """Append-only partition spill files behind bounded write buffers.

    Payloads accumulate per partition in memory and drain as one
    sequential append once ``flush_bytes`` is buffered (or at close) —
    the files are only ever appended to, so spill I/O cost is linear in
    spilled bytes, not in tiles × partitions.
    """

    def __init__(self, paths: list, flush_bytes: int = 1 << 18):
        self._files = [open(p, "wb") for p in paths]
        self._bufs: list = [[] for _ in paths]
        self._buffered = [0] * len(paths)
        self.flush_bytes = int(flush_bytes)
        self.spill_bytes = 0
        self.spill_writes = 0

    def append(self, p: int, payload: bytes) -> None:
        self._bufs[p].append(payload)
        self._buffered[p] += len(payload)
        if self._buffered[p] >= self.flush_bytes:
            self._drain(p)

    def _drain(self, p: int) -> None:
        if not self._buffered[p]:
            return
        blob = b"".join(self._bufs[p])
        self._files[p].write(blob)
        self.spill_bytes += len(blob)
        self.spill_writes += 1
        self._bufs[p] = []
        self._buffered[p] = 0

    def close(self) -> None:
        for p in range(len(self._files)):
            self._drain(p)
            self._files[p].close()


def _finalize_npy(payload_path: str, out_path: str, dtype,
                  shape: tuple) -> None:
    """Wrap a raw little-endian payload file as a valid ``.npy``."""
    header = {"descr": np.lib.format.dtype_to_descr(np.dtype(dtype)),
              "fortran_order": False, "shape": shape}
    with open(out_path, "wb") as out:
        np.lib.format.write_array_header_1_0(out, header)
        with open(payload_path, "rb") as src:
            while True:
                block = src.read(1 << 20)
                if not block:
                    break
                out.write(block)
    os.remove(payload_path)


class _TileScanner:
    """Rolling-buffer tile walk over the virtual concatenated reference."""

    def __init__(self, *, k: int, w: int, tile_bp: int, emit,
                 origin: int = 0):
        self.k, self.w, self.tile = k, w, tile_bp
        self.emit = emit                      # emit(packed_u64_occurrences)
        # sentinel-spanning k-mers (base code 4) need 2k+1 bits, not 2k
        self.pos_bits = np.uint64(64 - (2 * k + 1))
        self.origin = origin                  # global pos of physical base 0
        self.buf = np.zeros(0, np.uint8)
        self.buf_start = origin               # global pos of buf[0]
        self.t0 = origin                      # next tile start
        self.tiles = 0

    def _buf_end(self) -> int:
        return self.buf_start + len(self.buf)

    def _scan(self, t1: int) -> None:
        k, w = self.k, self.w
        lo = max(self.origin, self.t0 - (w - 1))
        hi = min(self._buf_end(), t1 + w + k - 2)
        window = self.buf[lo - self.buf_start: hi - self.buf_start]
        if len(window) >= w + k - 1:
            _, kmer, pos = np_minimizers(window, k, w)
            pos_g = pos.astype(np.int64) + lo
            keep = (pos_g >= self.t0) & (pos_g < t1)
            packed = ((kmer[keep].astype(np.uint64) << self.pos_bits)
                      | pos_g[keep].astype(np.uint64))
            self.emit(np.unique(packed))
        self.tiles += 1
        self.t0 = t1
        # drop bases the next tile's left halo no longer needs
        keep_from = max(self.origin, self.t0 - (w - 1))
        if keep_from > self.buf_start:
            self.buf = self.buf[keep_from - self.buf_start:].copy()
            self.buf_start = keep_from

    def feed(self, codes: np.ndarray) -> None:
        if len(codes):
            self.buf = (np.concatenate([self.buf, codes])
                        if len(self.buf) else np.asarray(codes, np.uint8))
        # a tile is ready once its right halo is fully buffered
        while self._buf_end() >= self.t0 + self.tile + self.w + self.k - 2:
            self._scan(self.t0 + self.tile)

    def finish(self, total_len: int) -> None:
        while self.t0 < total_len:
            self._scan(min(self.t0 + self.tile, total_len))


def build_sharded_index(fasta, out_dir: str, *, num_partitions: int = 4,
                        tile_bp: int = 1 << 20, read_len: int = 150,
                        k: int = 12, w: int = 30, eth: int = 6,
                        max_pls_per_minimizer: int = 256,
                        spacer: int | None = None, overwrite: bool = False,
                        origin: int = 0, format_version: int = 2,
                        progress=None):
    """Build a persistent sharded index directory from a FASTA, streamed.

    Returns the built index opened via ``repro.index.open_index`` (mmap).
    ``spacer`` defaults to ``read_len + 2*eth``, the same inter-contig
    gap ``launch.map_fastq`` uses, so on-disk and in-memory mappings
    agree byte for byte.

    ``origin`` (format v2 only) places the reference at a virtual global
    base offset: every recorded position and contig offset is
    ``origin + actual``, and ``ref_len`` in the manifest is the global
    end.  This is the seam for splitting one genome across several
    builds — and how tests prove positions past 2^31 without a 3 Gb
    fixture.  ``format_version=1`` writes a strict v1 index (int32
    payloads, the 2^31 refusal, no origin) for compatibility checks.
    """
    validate_geometry(read_len=read_len, k=k, w=w, eth=eth)
    _validate_partitions(num_partitions)
    if format_version not in (1, 2):
        raise ValueError(f"format_version={format_version!r}: this builder "
                         f"writes format v1 or v2")
    if origin < 0:
        raise ValueError(f"origin={origin} must be >= 0")
    if origin and format_version == 1:
        raise ValueError(
            f"origin={origin}: format v1 has no origin field; build with "
            f"format_version=2")
    if tile_bp < w + k - 1:
        raise ValueError(
            f"tile_bp={tile_bp}: a tile must cover at least one minimizer "
            f"window (w + k - 1 = {w + k - 1} bases)")
    if spacer is None:
        spacer = read_len + 2 * eth
    if spacer < 0:
        raise ValueError(f"spacer={spacer} must be >= 0")
    P = int(num_partitions)
    # spill keys pack (kmer, position) into one u64; k-mer codes take
    # 2k+1 bits (sentinel base 4 carries past 2-bit packing), so k <= 16
    # (geometry) guarantees at least 31 position bits
    pos_bits = 64 - (2 * k + 1)
    max_pos = (1 << pos_bits) - 1
    say = progress if progress is not None else (lambda _msg: None)

    os.makedirs(out_dir, exist_ok=True)
    if not overwrite and os.path.isfile(
            os.path.join(out_dir, fmt.MANIFEST_NAME)):
        raise ValueError(
            f"{out_dir!r} already holds an index (manifest.json exists); "
            f"pass overwrite=True / --force to rebuild in place")

    t_start = time.perf_counter()
    spill_paths = [os.path.join(out_dir, f".spill{p:04d}.u64")
                   for p in range(P)]
    spills = _SpillWriter(spill_paths)
    n_spilled = np.zeros(P, dtype=np.int64)
    shift = np.uint64(pos_bits)

    def emit(packed_occ: np.ndarray) -> None:
        if not len(packed_occ):
            return
        part = (np_hash32((packed_occ >> shift).astype(np.uint32))
                % np.uint32(P)).astype(np.int64)
        order = np.argsort(part, kind="stable")
        sorted_occ, sorted_part = packed_occ[order], part[order]
        counts = np.bincount(sorted_part, minlength=P)
        bounds = np.concatenate([[0], np.cumsum(counts)])
        for p in np.nonzero(counts)[0]:
            spills.append(p, sorted_occ[bounds[p]: bounds[p + 1]].tobytes())
        n_spilled[:] += counts   # in-place: n_spilled is closed over

    ref_codes_payload = os.path.join(out_dir, ".reference.2bit.payload")
    ref_sent_payload = os.path.join(out_dir, ".reference.sent.payload")
    writer = _PackedRefWriter(ref_codes_payload, ref_sent_payload)
    scanner = _TileScanner(k=k, w=w, tile_bp=tile_bp, emit=emit,
                           origin=origin)

    def feed(codes: np.ndarray) -> None:
        writer.write(codes)
        scanner.feed(codes)

    # -- phase 1: stream contigs through the scanner ----------------------
    t_scan = time.perf_counter()
    contigs: list[Contig] = []
    cur_name, cur_len, cur_has_acgt = None, 0, False

    def close_contig() -> None:
        nonlocal cur_name, cur_len, cur_has_acgt
        if cur_len == 0:
            raise ValueError(f"FASTA contig {cur_name!r} has no sequence")
        if not cur_has_acgt:
            raise ValueError(f"FASTA contig {cur_name!r} has only non-ACGT "
                             f"(sentinel) bases")
        contigs.append(Contig(name=cur_name, length=cur_len,
                              offset=origin + writer.length - cur_len))
        say(f"contig {cur_name}: {cur_len} bp "
            f"(genome so far {writer.length} bp, {scanner.tiles} tiles)")
        cur_name, cur_len, cur_has_acgt = None, 0, False

    chunk_bp = max(tile_bp, w + k)
    for name, codes, is_last in stream_fasta(fasta, max_chunk=chunk_bp):
        if cur_name is None:
            if contigs:          # inter-contig spacer, as load_reference
                feed(np.full(spacer, SENTINEL, dtype=np.uint8))
            cur_name = name
        cur_len += len(codes)
        cur_has_acgt |= bool((codes != SENTINEL).any())
        feed(codes)
        if is_last:
            close_contig()
    if not contigs:
        raise ValueError("empty FASTA: no records (or none usable)")
    ref_len = origin + writer.length     # global end position
    if format_version == 1 and ref_len > _INT32_MAX:
        raise ValueError(
            f"reference is {ref_len} bases after spacer concatenation; "
            f"index format v1 stores int32 positions (max {_INT32_MAX}). "
            f"Build with format_version=2 (the default) for int64 "
            f"positions.")
    if ref_len - 1 > max_pos:
        raise ValueError(
            f"reference ends at global position {ref_len - 1} but the "
            f"spill keys hold {pos_bits} position bits at k={k} (max "
            f"{max_pos}); lower origin or use a smaller k — smaller "
            f"k-mers leave more position bits")
    scanner.finish(ref_len)
    writer.close()
    spills.close()
    _finalize_npy(ref_codes_payload,
                  os.path.join(out_dir, fmt.REFERENCE_FILES["packed"]),
                  np.uint8, (fmt.packed_cols(writer.length),))
    _finalize_npy(ref_sent_payload,
                  os.path.join(out_dir, fmt.REFERENCE_FILES["sentinel"]),
                  np.uint8, (fmt.sentinel_cols(writer.length),))
    say(f"scan done: {ref_len} bp, {scanner.tiles} tiles, "
        f"{int(n_spilled.sum())} spilled occurrences "
        f"({spills.spill_bytes} spill bytes in {spills.spill_writes} "
        f"writes)")
    tr = _tracing.ACTIVE
    if tr is not None:
        tr.add("index_scan", t_scan, time.perf_counter(),
               {"tiles": int(scanner.tiles), "ref_len": int(ref_len)})
    reg = _metrics.ACTIVE
    if reg is not None:
        reg.counter("repro_index_tiles_total").inc(int(scanner.tiles))
        reg.counter("repro_index_spilled_occurrences_total").inc(
            int(n_spilled.sum()))
        reg.counter("repro_index_spill_bytes_total").inc(
            int(spills.spill_bytes))

    # -- phase 2: finalize partitions from spills --------------------------
    man_ref = {role: fmt.file_digest(os.path.join(out_dir, fname))
               for role, fname in fmt.REFERENCE_FILES.items()}
    packed_ref = fmt.load_reference(
        out_dir, {"ref_len": ref_len, "origin": origin}, mmap=True)
    pos_dtype = fmt.position_dtype(ref_len - 1)
    pad = read_len + eth - k
    seg_len = 2 * (read_len + eth) - k
    seg_batch = max(16, tile_bp // max(seg_len, 1))
    parts_meta = []
    total_occ = 0
    dropped_pls = 0
    for p in range(P):
        t_part = time.perf_counter()
        data = np.fromfile(spill_paths[p], dtype=np.uint64)
        os.remove(spill_paths[p])
        u = np.unique(data)       # dedup (defensive) + (kmer, pos) sort
        del data
        kmers = (u >> shift).astype(np.uint32)
        pos = (u & np.uint64(max_pos)).astype(np.int64)
        del u
        # cap hyper-repetitive minimizers: keep the first
        # max_pls_per_minimizer occurrences by position (flat-build rule)
        uniq, starts, counts = np.unique(kmers, return_index=True,
                                         return_counts=True)
        cap = max_pls_per_minimizer
        keep = np.ones(len(kmers), dtype=bool)
        for s, c in zip(starts[counts > cap], counts[counts > cap]):
            keep[s + cap: s + c] = False
        dropped_pls += int((~keep).sum())
        kmers, pos = kmers[keep], pos[keep]
        uniq, counts = np.unique(kmers, return_counts=True)
        offsets = fmt.csr_offsets(counts)
        n_occ = len(pos)
        total_occ += n_occ

        names = fmt.part_filenames(p)
        np.save(os.path.join(out_dir, names["kmers"]),
                uniq.astype(np.uint32))
        np.save(os.path.join(out_dir, names["offsets"]), offsets)
        np.save(os.path.join(out_dir, names["positions"]),
                pos.astype(pos_dtype))
        seg_shape = (n_occ, fmt.packed_cols(seg_len))
        sent_shape = (n_occ, fmt.sentinel_cols(seg_len))
        seg_path = os.path.join(out_dir, names["seg2bit"])
        sent_path = os.path.join(out_dir, names["segsent"])
        if n_occ == 0:
            np.save(seg_path, np.zeros(seg_shape, np.uint8))
            np.save(sent_path, np.zeros(sent_shape, np.uint8))
        else:
            seg_mm = np.lib.format.open_memmap(
                seg_path, mode="w+", dtype=np.uint8, shape=seg_shape)
            sent_mm = np.lib.format.open_memmap(
                sent_path, mode="w+", dtype=np.uint8, shape=sent_shape)
            span = np.arange(seg_len, dtype=np.int64)[None, :]
            for b0 in range(0, n_occ, seg_batch):
                b1 = min(b0 + seg_batch, n_occ)
                idx = (pos[b0:b1, None] - pad) + span
                codes = packed_ref.gather(idx)
                pk, sb = fmt.pack_codes(codes)
                seg_mm[b0:b1] = pk
                sent_mm[b0:b1] = sb
            seg_mm.flush()
            sent_mm.flush()
            del seg_mm, sent_mm
        parts_meta.append({
            "id": p,
            "n_kmers": int(len(uniq)),
            "n_occurrences": int(n_occ),
            "files": {role: fmt.file_digest(os.path.join(out_dir, fname))
                      for role, fname in names.items()},
        })
        say(f"partition {p}/{P}: {len(uniq)} kmers, {n_occ} occurrences")
        tr = _tracing.ACTIVE
        if tr is not None:
            tr.add("index_partition", t_part, time.perf_counter(),
                   {"partition": p, "occurrences": int(n_occ)})
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_index_partitions_total").inc()
            reg.counter("repro_index_occurrences_total").inc(int(n_occ))

    wall_s = time.perf_counter() - t_start
    manifest = {
        "format": (fmt.FORMAT_VERSION_V1 if format_version == 1
                   else fmt.FORMAT_VERSION_V2),
        "read_len": read_len, "k": k, "w": w, "eth": eth,
        "spacer": spacer,
        "max_pls_per_minimizer": max_pls_per_minimizer,
        "num_partitions": P,
        "ref_len": int(ref_len),
        "seg_len": int(seg_len),
        "contigs": [{"name": c.name, "length": c.length, "offset": c.offset}
                    for c in contigs],
        "reference": man_ref,
        "partitions": parts_meta,
        "build": {
            "tile_bp": int(tile_bp),
            "tiles": int(scanner.tiles),
            "n_occurrences": int(total_occ),
            "spilled_occurrences": int(n_spilled.sum()),
            "spill_bytes": int(spills.spill_bytes),
            "spill_writes": int(spills.spill_writes),
            "dropped_pls": int(dropped_pls),
            "wall_s": wall_s,
        },
    }
    if format_version == 2:
        manifest["origin"] = int(origin)
        manifest["position_dtype"] = str(pos_dtype)
    fmt.write_manifest(out_dir, manifest)
    say(f"wrote {out_dir}: {P} partitions, {total_occ} occurrences, "
        f"{spills.spill_bytes} spill bytes, "
        f"{wall_s:.2f}s ({writer.length / max(wall_s, 1e-9):.0f} bases/s)")
    from .sharded import open_index
    return open_index(out_dir)
