"""repro.index — sharded, out-of-core genome index.

The flat ``repro.core.index.GenomeIndex`` (one array + one CSR) assumes
the whole pre-materialized index fits in host memory during build and on
one device at runtime.  This package drops both assumptions:

* :func:`build_sharded_index` — streamed, tile-by-tile out-of-core
  construction with bounded peak memory, partitioned by the crossbar
  rule ``hash32(kmer) % num_partitions``;
* a persistent on-disk format (versioned JSON manifest + per-partition
  memmap CSR files + 2-bit packed reference) with integrity checking —
  :func:`open_index` / :func:`load_index` / :func:`verify_index`;
* shard-routed execution — :class:`ShardedGenomeIndex` plugs into
  ``Mapper(topology="single")`` under a device-memory budget (lazy/LRU
  partition residency, ``repro.index.residency``) and into
  ``Mapper(topology="mesh")`` with partition *i* placed on shard *i*
  (zero runtime re-hashing).

:func:`shard_flat_index` partitions an in-memory ``GenomeIndex`` without
touching disk — the equivalence bridge used by tests and by callers
migrating incrementally.
"""
from .build import build_sharded_index
from .format import (FORMAT_VERSION, IndexFormatError, IndexIntegrityError,
                     MANIFEST_NAME, PackedReference, load_manifest,
                     pack_codes, unpack_codes)
from .sharded import (Partition, ShardedGenomeIndex, load_index, open_index,
                      shard_flat_index, verify_index)

__all__ = [
    "FORMAT_VERSION",
    "MANIFEST_NAME",
    "IndexFormatError",
    "IndexIntegrityError",
    "PackedReference",
    "Partition",
    "ShardedGenomeIndex",
    "build_sharded_index",
    "load_index",
    "load_manifest",
    "open_index",
    "pack_codes",
    "shard_flat_index",
    "unpack_codes",
    "verify_index",
]
