"""Persistent on-disk format for the sharded genome index.

An index is one directory::

    index_dir/
      manifest.json            versioned metadata + per-file digests
      reference.2bit.npy       spacer-concatenated reference, 2-bit packed
      reference.sent.npy       sentinel bitmask (1 bit / base, little-endian)
      part0000.kmers.npy       sorted unique minimizer k-mer codes (uint32)
      part0000.offsets.npy     CSR offsets into positions (int32, n_kmers+1)
      part0000.positions.npy   global minimizer positions (int32)
      part0000.seg2bit.npy     per-occurrence segments, 2-bit packed
                               (n_occ, ceil(seg_len/4)) uint8
      part0000.segsent.npy     per-occurrence sentinel bitmask
                               (n_occ, ceil(seg_len/8)) uint8
      part0001.* ...

Everything is a raw ``.npy`` (not ``.npz``) so ``np.load(mmap_mode="r")``
gives true memmaps — opening a multi-GB index touches only the manifest
and the pages the run actually reads.  The manifest records crc32 + byte
size per file; ``open_index`` checks sizes (cheap), ``verify_index``
checks digests (full read).

Format v2 (``repro-sharded-index/2``) stores positions and CSR offsets
in the narrowest safe dtype: int32 while every position fits 2^31-1,
int64 beyond that — so GRCh38-scale (3.1 Gb) references build and load.
The ``.npy`` files are self-describing, the manifest records the chosen
``position_dtype``, and v1 indexes (always int32) still load through
the same readers.  v2 manifests additionally record ``origin``, a
virtual base offset applied to the whole reference (positions are
``origin + actual``) — the seam for sharding one genome across several
index builds, and how CI proves >= 2^31 positions without a 3 Gb
fixture.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib

import numpy as np

from ..core.index import SENTINEL

FORMAT_VERSION_V1 = "repro-sharded-index/1"
FORMAT_VERSION_V2 = "repro-sharded-index/2"
FORMAT_VERSION = FORMAT_VERSION_V2          # what new builds write
ACCEPTED_VERSIONS = (FORMAT_VERSION_V1, FORMAT_VERSION_V2)
MANIFEST_NAME = "manifest.json"

INT32_MAX = 2**31 - 1


def position_dtype(max_position: int) -> np.dtype:
    """Narrowest on-disk dtype holding positions up to ``max_position``.

    int32 while the largest position fits (v1-compatible payloads),
    int64 beyond — the v2 dtype-selection rule, applied uniformly to
    positions and CSR offsets so small builds stay compact.
    """
    return np.dtype(np.int32 if max_position <= INT32_MAX else np.int64)


def csr_offsets(counts: np.ndarray) -> np.ndarray:
    """CSR offsets from per-key counts, overflow-safe.

    The cumulative sum runs in int64 and is narrowed to int32 only when
    the total fits — an int32 cumsum wraps silently past 2^31
    occurrences-times-bytes, which is exactly the class of bug format
    v2 audits out.
    """
    offsets = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, dtype=np.int64, out=offsets[1:])
    if offsets[-1] <= INT32_MAX:
        return offsets.astype(np.int32)
    return offsets


class IndexFormatError(ValueError):
    """The directory is not a readable index of this format version."""


class IndexIntegrityError(IndexFormatError):
    """The manifest and the files on disk disagree (size or digest)."""


# ---------------------------------------------------------------------------
# 2-bit packing (byte layout shared with core.encoding.pack_2bit: base j
# occupies bits 2*(j%4) of byte j//4; sentinel mask is np.packbits
# little-endian, bit j%8 of byte j//8)
# ---------------------------------------------------------------------------

def packed_cols(n: int) -> int:
    return (n + 3) // 4


def sentinel_cols(n: int) -> int:
    return (n + 7) // 8


def pack_codes(codes: np.ndarray):
    """Pack base codes {0..4} along the last axis.

    Returns ``(two_bit, sent_bits)`` — sentinel (and any code >= 4)
    positions pack as base 0 in ``two_bit`` and set their bit in
    ``sent_bits``, so unpacking restores the exact code array.
    """
    codes = np.ascontiguousarray(codes, dtype=np.uint8)
    n = codes.shape[-1]
    pad = (-n) % 4
    if pad:
        z = np.zeros(codes.shape[:-1] + (pad,), dtype=np.uint8)
        codes = np.concatenate([codes, z], axis=-1)
    sent = codes >= 4
    two = np.where(sent, np.uint8(0), codes)
    two = two.reshape(two.shape[:-1] + (-1, 4))
    packed = (two[..., 0] | (two[..., 1] << 2) | (two[..., 2] << 4)
              | (two[..., 3] << 6)).astype(np.uint8)
    # packbits zero-pads the tail itself; the 4-alignment pad positions
    # are non-sentinel zeros, so the bit image of the first n bases is
    # exact and the column count matches sentinel_cols(n)
    sent_bits = np.packbits(sent, axis=-1,
                            bitorder="little")[..., : sentinel_cols(n)]
    return packed, sent_bits


def unpack_codes(packed: np.ndarray, sent_bits: np.ndarray,
                 n: int) -> np.ndarray:
    """Inverse of :func:`pack_codes` -> (..., n) uint8 codes {0..4}."""
    packed = np.asarray(packed, dtype=np.uint8)
    shifts = (np.arange(4, dtype=np.uint8) * 2)
    bases = ((packed[..., :, None] >> shifts) & 3)
    bases = bases.reshape(bases.shape[:-2] + (-1,))[..., :n]
    sent = np.unpackbits(np.asarray(sent_bits, dtype=np.uint8), axis=-1,
                         bitorder="little")[..., :n]
    return np.where(sent.astype(bool), np.uint8(SENTINEL),
                    bases).astype(np.uint8)


class PackedReference:
    """Random access into the packed spacer-concatenated reference.

    ``gather`` takes any-shape global base positions and returns codes,
    with out-of-range positions reading as SENTINEL — exactly the
    virtual infinite padding ``build_index`` applies before slicing
    segments, so segment extraction from disk matches the in-memory
    path byte for byte.

    ``origin`` (format v2) shifts the whole reference to a virtual base
    offset: physical byte 0 holds global position ``origin``, and
    ``length`` stays the *global* end (``origin + physical bases``), so
    gathers below ``origin`` or at/after ``length`` read as SENTINEL.
    """

    def __init__(self, packed: np.ndarray, sent_bits: np.ndarray,
                 length: int, origin: int = 0):
        self.packed = packed
        self.sent_bits = sent_bits
        self.origin = int(origin)
        self.length = int(length)

    def gather(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        valid = (idx >= self.origin) & (idx < self.length)
        ci = np.clip(idx - self.origin, 0,
                     max(self.length - self.origin - 1, 0))
        b = np.asarray(self.packed[ci >> 2])
        b = (b >> ((ci & 3) * 2).astype(np.uint8)) & 3
        s = np.asarray(self.sent_bits[ci >> 3])
        s = (s >> (ci & 7).astype(np.uint8)) & 1
        ok = valid & (s == 0)
        return np.where(ok, b, np.uint8(SENTINEL)).astype(np.uint8)

    def codes(self, start: int | None = None,
              stop: int | None = None) -> np.ndarray:
        """Contiguous unpacked slice [start, stop) in global positions
        (``start`` defaults to ``origin``)."""
        start = self.origin if start is None else start
        stop = self.length if stop is None else min(stop, self.length)
        if stop <= start:
            return np.zeros(0, dtype=np.uint8)
        return self.gather(np.arange(start, stop, dtype=np.int64))


# ---------------------------------------------------------------------------
# manifest + files
# ---------------------------------------------------------------------------

def part_filenames(p: int) -> dict:
    stem = f"part{p:04d}"
    return {
        "kmers": f"{stem}.kmers.npy",
        "offsets": f"{stem}.offsets.npy",
        "positions": f"{stem}.positions.npy",
        "seg2bit": f"{stem}.seg2bit.npy",
        "segsent": f"{stem}.segsent.npy",
    }


REFERENCE_FILES = {"packed": "reference.2bit.npy",
                   "sentinel": "reference.sent.npy"}


def file_digest(path: str, chunk: int = 1 << 20) -> dict:
    crc = 0
    nbytes = 0
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            crc = zlib.crc32(b, crc)
            nbytes += len(b)
    return {"crc32": crc & 0xFFFFFFFF, "bytes": nbytes}


def write_manifest(index_dir: str, manifest: dict) -> None:
    path = os.path.join(index_dir, MANIFEST_NAME)
    tmp = path + ".partial"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def load_manifest(index_dir: str) -> dict:
    path = os.path.join(index_dir, MANIFEST_NAME)
    if not os.path.isfile(path):
        raise IndexFormatError(
            f"{index_dir!r} is not a sharded index: no {MANIFEST_NAME} "
            f"(build one with `python -m repro.launch.build_index`)")
    with open(path) as f:
        try:
            man = json.load(f)
        except json.JSONDecodeError as e:
            raise IndexFormatError(
                f"{path} is not valid JSON: {e}") from e
    got = man.get("format")
    if got not in ACCEPTED_VERSIONS:
        raise IndexFormatError(
            f"{path}: format {got!r} is not one of {ACCEPTED_VERSIONS!r}; "
            f"rebuild the index with this version of repro")
    for key in ("read_len", "k", "w", "eth", "spacer", "num_partitions",
                "ref_len", "seg_len", "contigs", "partitions", "reference",
                "max_pls_per_minimizer"):
        if key not in man:
            raise IndexFormatError(f"{path}: manifest missing {key!r}")
    # v1 manifests predate these keys; their values are fixed by v1
    man.setdefault("origin", 0)
    man.setdefault("position_dtype", "int32")
    if man["position_dtype"] not in ("int32", "int64"):
        raise IndexFormatError(
            f"{path}: position_dtype {man['position_dtype']!r} is not "
            f"'int32' or 'int64'")
    if got == FORMAT_VERSION_V1 and man["origin"] != 0:
        raise IndexFormatError(
            f"{path}: format v1 indexes cannot carry a nonzero origin "
            f"({man['origin']})")
    if len(man["partitions"]) != man["num_partitions"]:
        raise IndexFormatError(
            f"{path}: manifest lists {len(man['partitions'])} partitions "
            f"but num_partitions={man['num_partitions']}")
    return man


def _check_size(index_dir: str, fname: str, meta: dict,
                problems: list) -> None:
    path = os.path.join(index_dir, fname)
    if not os.path.isfile(path):
        problems.append(f"{fname}: missing")
    elif os.path.getsize(path) != meta["bytes"]:
        problems.append(f"{fname}: {os.path.getsize(path)} bytes on disk, "
                        f"manifest says {meta['bytes']}")


def _check_crc(index_dir: str, fname: str, meta: dict,
               problems: list) -> None:
    path = os.path.join(index_dir, fname)
    if not os.path.isfile(path):
        problems.append(f"{fname}: missing")
        return
    got = file_digest(path)
    if got["bytes"] != meta["bytes"] or got["crc32"] != meta["crc32"]:
        problems.append(
            f"{fname}: crc32/bytes {got['crc32']:#010x}/{got['bytes']} "
            f"!= manifest {meta['crc32']:#010x}/{meta['bytes']}")


def _iter_files(man: dict):
    for role, fname in REFERENCE_FILES.items():
        yield fname, man["reference"][role]
    for part in man["partitions"]:
        for role, fname in part_filenames(part["id"]).items():
            yield fname, part["files"][role]


def check_integrity(index_dir: str, man: dict, *, full: bool) -> None:
    """Raise IndexIntegrityError listing every size (and, when ``full``,
    crc32) mismatch between the manifest and the files on disk."""
    problems: list = []
    for fname, meta in _iter_files(man):
        (_check_crc if full else _check_size)(index_dir, fname, meta,
                                              problems)
    if problems:
        raise IndexIntegrityError(
            f"index {index_dir!r} fails integrity check "
            f"({'crc32' if full else 'size'}):\n  "
            + "\n  ".join(problems)
            + "\n(rebuild the index or restore the files)")


@dataclasses.dataclass(frozen=True)
class PartitionFiles:
    """Loaded (or memmapped) arrays of one partition."""
    kmers: np.ndarray      # (n_kmers,) uint32, sorted
    offsets: np.ndarray    # (n_kmers+1,) int32/int64 CSR
    positions: np.ndarray  # (n_occ,) int32/int64 global minimizer positions
    seg2bit: np.ndarray    # (n_occ, ceil(seg_len/4)) uint8
    segsent: np.ndarray    # (n_occ, ceil(seg_len/8)) uint8


def _load(path: str, mmap: bool) -> np.ndarray:
    return np.load(path, mmap_mode="r" if mmap else None)


def load_partition(index_dir: str, p: int, *, mmap: bool) -> PartitionFiles:
    names = part_filenames(p)
    return PartitionFiles(
        **{role: _load(os.path.join(index_dir, fname), mmap)
           for role, fname in names.items()})


def load_reference(index_dir: str, man: dict, *,
                   mmap: bool) -> PackedReference:
    packed = _load(os.path.join(index_dir, REFERENCE_FILES["packed"]), mmap)
    sent = _load(os.path.join(index_dir, REFERENCE_FILES["sentinel"]), mmap)
    return PackedReference(packed, sent, man["ref_len"],
                           origin=man.get("origin", 0))
