"""Lazy/LRU device residency + shard-routed single-host execution.

The single-host topology cannot assume the whole partitioned index fits
on the device (that assumption is what the sharded index exists to
drop).  Instead the ``Mapper`` owns a fixed-capacity **device arena** —
one ``(cap_rows, seg_len)`` segments array and one ``(cap_rows,)``
positions array sized by ``memory_budget_bytes`` — and partitions move
in and out of it at chunk granularity:

* ``seed_reads_routed`` (host, numpy) extracts each chunk's minimizers
  and routes them by the crossbar rule, so the set of partitions the
  chunk touches is known *before* any device dispatch;
* ``DeviceResidency.ensure`` makes those partitions resident — cache
  hits just touch the LRU, misses upload the partition's segments +
  positions into a free extent, evicting least-recently-used partitions
  (never ones the current chunk needs) when the budget is tight, and
  compacting the arena when free space is fragmented;
* emitted ``occ_idx`` rows are arena rows, and the chunk carries a
  *snapshot* of the arena device arrays: updates are functional
  (``.at[].set`` builds a new array), so a chunk in flight on the
  streaming engine keeps its own consistent buffers even while the next
  chunk's ``phase1`` evicts and reloads partitions underneath it.

Everything downstream — linear/affine WF, filter, traceback — is the
unmodified flat pipeline: ``_RoutedChunkPipeline`` only replaces where
``occ_idx`` rows come from and which device arrays they point into.

With ``prefetch=True`` (``Mapper(..., prefetch=True)``) a single
background worker stages the *next* chunk's host seeding and partition
uploads while the current chunk computes — the same next-chunk-early
discipline ``core.streaming`` applies to H2D/compute/D2H, moved down
into the arena.  All residency state is guarded by one re-entrant lock,
and routing + snapshot are atomic under it, so a prefetch can never
relocate rows between a chunk's ``ensure`` and the snapshot it pairs
its occurrence rows with; results are bit-identical to synchronous
loading because every chunk still pairs rows with its own snapshot.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor

import jax.numpy as jnp
import numpy as np

from ..core import streaming
from ..obs import registry as _metrics
from ..core.index import device_position_dtype
from ..core.pipeline import MapperConfig, _ChunkPipeline
from ..core.seeding import seed_reads_routed

import time


class DeviceResidency:
    """Partition-granular device arena under a byte budget."""

    def __init__(self, index, memory_budget_bytes: int | None = None):
        self.index = index
        seg_len = index.seg_len
        # positions dtype the device can actually hold for this reference
        # (int32 under 2^31 bases, uint32 to 2^32-1, int64 under x64)
        self.pos_dtype = device_position_dtype(
            getattr(index, "ref_len", 0))
        # one occurrence row = seg_len segment bytes + position bytes
        self.row_bytes = seg_len + self.pos_dtype.itemsize
        rows = [p.n_occurrences for p in index.parts]
        total = sum(rows)
        biggest = max(rows, default=0)
        if memory_budget_bytes is None:
            cap_rows = max(total, 1)
        else:
            cap_rows = max(int(memory_budget_bytes) // self.row_bytes, 0)
            if cap_rows < max(biggest, 1):
                need = max(biggest, 1) * self.row_bytes
                raise ValueError(
                    f"memory_budget_bytes={memory_budget_bytes} holds "
                    f"{cap_rows} occurrence rows ({self.row_bytes} B/row) "
                    f"but the largest partition needs {max(biggest, 1)} "
                    f"rows; raise the budget to >= {need} bytes or rebuild "
                    f"the index with more partitions")
        self.cap_rows = cap_rows
        self.budget_bytes = memory_budget_bytes
        self.segments_dev = jnp.zeros((cap_rows, seg_len), dtype=jnp.uint8)
        self.positions_dev = jnp.zeros((cap_rows,), dtype=self.pos_dtype)
        self._alloc: dict[int, tuple[int, int]] = {}   # p -> (lo, rows)
        self._lru: OrderedDict[int, None] = OrderedDict()
        # one re-entrant lock over all residency state: the prefetch
        # worker and the compute path may ensure() concurrently, and a
        # partition must load exactly once with exactly one allocation
        self._lock = threading.RLock()
        self._prefetched: set[int] = set()
        self.loads = 0
        self.evictions = 0
        self.compactions = 0
        self.h2d_bytes = 0
        self.prefetch_loads = 0
        self.prefetch_hits = 0

    # ------------------------------------------------------------- queries
    @property
    def resident(self) -> list:
        return sorted(self._alloc)

    @property
    def resident_rows(self) -> int:
        return sum(r for _, r in self._alloc.values())

    def snapshot(self):
        """The arena device arrays as of now.  Chunks must pair their
        ``occ_idx`` rows with the snapshot taken at routing time —
        functional updates make later loads produce *new* arrays, so a
        snapshot can never change under an in-flight chunk."""
        return self.positions_dev, self.segments_dev

    # ----------------------------------------------------------- residency
    def ensure(self, parts: list, *, prefetch: bool = False) -> dict:
        """Make ``parts`` resident; returns ``{p: arena_base_row}``.

        ``prefetch=True`` marks this call as coming from the background
        prefetch worker: its loads count as prefetch loads, and the
        partitions it stages are credited as prefetch hits when a later
        ensure finds them still resident.  Thread-safe: the whole
        operation holds the residency lock, so two ensures racing on the
        same partition load it exactly once with one allocation.
        """
        with self._lock:
            pinned = set(parts)
            hits = misses = pf_hits = 0
            for p in parts:
                if p in self._alloc:
                    self._lru.move_to_end(p)
                    hits += 1
                    if p in self._prefetched:
                        pf_hits += 1
                        self._prefetched.discard(p)
            for p in parts:
                if p not in self._alloc:
                    misses += 1
                    self._load(p, pinned, prefetch=prefetch)
            if prefetch:
                self._prefetched.update(parts)
            self.prefetch_hits += pf_hits
            reg = _metrics.ACTIVE
            if reg is not None:
                if hits:
                    reg.counter("repro_partition_hits_total").inc(hits)
                if misses:
                    reg.counter("repro_partition_misses_total").inc(misses)
                if pf_hits:
                    reg.counter(
                        "repro_partition_prefetch_hits_total").inc(pf_hits)
                reg.gauge("repro_partition_resident_rows").set(
                    self.resident_rows)
            # Bases must come from the allocation table only after every
            # load: a late ``_load`` may ``_compact`` and relocate
            # partitions that were already resident when ensure() started.
            return {p: self._alloc[p][0] for p in parts}

    def prefetch(self, parts: list) -> dict | None:
        """Best-effort background staging of ``parts``.

        Same as ``ensure(parts, prefetch=True)`` except a budget
        overflow returns None instead of raising — the authoritative
        ensure on the compute path reports the error with the chunk
        that actually needs the partitions."""
        try:
            return self.ensure(parts, prefetch=True)
        except ValueError:
            return None

    def _free_extents(self):
        used = sorted(self._alloc.values())
        extents, cursor = [], 0
        for lo, rows in used:
            if lo > cursor:
                extents.append((cursor, lo - cursor))
            cursor = lo + rows
        if cursor < self.cap_rows:
            extents.append((cursor, self.cap_rows - cursor))
        return extents

    def _find_gap(self, rows: int):
        for lo, size in self._free_extents():
            if size >= rows:
                return lo
        return None

    def _evict_one(self, pinned: set, incoming_rows: int = 0) -> None:
        victim = next((q for q in self._lru if q not in pinned), None)
        if victim is None:
            # Every unpinned resident has already been evicted: the rows
            # still held all belong to partitions this chunk needs, so
            # the report must count held + incoming, not pretend the
            # whole arena were free.
            held = self.resident_rows
            need = sum(self.index.parts[p].n_occurrences for p in pinned)
            raise ValueError(
                f"one chunk touches partitions needing {need} occurrence "
                f"rows but the arena holds {self.cap_rows}: every "
                f"unpinned resident is already evicted and {held} rows "
                f"stay pinned by this chunk while {incoming_rows} more "
                f"are loading; raise memory_budget_bytes (>= "
                f"{need * self.row_bytes} bytes) or shrink chunk_reads "
                f"so fewer partitions are touched at once")
        del self._alloc[victim]
        del self._lru[victim]
        self._prefetched.discard(victim)
        self.evictions += 1
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_partition_evictions_total").inc()

    def _compact(self) -> None:
        """Repack resident partitions to the arena front (functional
        slice moves; sorted ascending, so every move is leftward into
        space already vacated)."""
        self.compactions += 1
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_partition_compactions_total").inc()
        cursor = 0
        for p, (lo, rows) in sorted(self._alloc.items(),
                                    key=lambda kv: kv[1][0]):
            if lo != cursor:
                self.segments_dev = self.segments_dev.at[
                    cursor:cursor + rows].set(self.segments_dev[lo:lo + rows])
                self.positions_dev = self.positions_dev.at[
                    cursor:cursor + rows].set(
                        self.positions_dev[lo:lo + rows])
                self._alloc[p] = (cursor, rows)
            cursor += rows

    def _load(self, p: int, pinned: set, *, prefetch: bool = False) -> int:
        part = self.index.parts[p]
        rows = part.n_occurrences
        while True:
            lo = self._find_gap(rows)
            if lo is not None:
                break
            if (self.cap_rows - self.resident_rows) >= rows:
                self._compact()     # space exists but is fragmented
                continue
            self._evict_one(pinned, incoming_rows=rows)
        segs = part.read_segments()
        self.segments_dev = self.segments_dev.at[lo:lo + rows].set(
            jnp.asarray(segs))
        self.positions_dev = self.positions_dev.at[lo:lo + rows].set(
            jnp.asarray(np.asarray(part.positions).astype(self.pos_dtype)))
        self._alloc[p] = (lo, rows)
        self._lru[p] = None
        self._lru.move_to_end(p)
        self.loads += 1
        if prefetch:
            self.prefetch_loads += 1
        self.h2d_bytes += rows * self.row_bytes
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_partition_loads_total").inc()
            if prefetch:
                reg.counter("repro_partition_prefetch_loads_total").inc()
            reg.counter("repro_partition_h2d_bytes_total").inc(
                rows * self.row_bytes)
        return lo

    # ------------------------------------------------------------- stats
    def stats_summary(self, *, reset: bool = True) -> dict:
        out = {
            "partition_loads": self.loads,
            "partition_evictions": self.evictions,
            "partition_compactions": self.compactions,
            "h2d_bytes": self.h2d_bytes,
            "prefetch_loads": self.prefetch_loads,
            "prefetch_hits": self.prefetch_hits,
            "resident_partitions": self.resident,
            "resident_rows": self.resident_rows,
            "arena_rows": self.cap_rows,
            "arena_bytes": self.cap_rows * self.row_bytes,
        }
        if reset:
            self.loads = self.evictions = self.compactions = 0
            self.h2d_bytes = 0
            self.prefetch_loads = self.prefetch_hits = 0
        return out


class ShardRouter:
    """Per-session routing front-end: host seeding + residency + stats."""

    def __init__(self, index, residency: DeviceResidency,
                 cfg: MapperConfig):
        self.index = index
        self.residency = residency
        self.cfg = cfg
        P = index.num_partitions
        self._routed = np.zeros(P, dtype=np.int64)
        self._found = np.zeros(P, dtype=np.int64)
        self._chunks = 0

    def seed(self, reads: np.ndarray, *, prefetch: bool = False):
        """Route + seed one (padded, possibly strand-stacked) chunk.
        Returns ``(numpy seeds, arena snapshot)``.

        The whole route→ensure→snapshot sequence holds the residency
        lock: a concurrent prefetch must never relocate arena rows
        between this chunk's ``ensure`` and the snapshot its ``occ_idx``
        rows are paired with.  The lock is re-entrant, so the nested
        ``ensure`` is fine; contention is only ever with the single
        prefetch worker."""
        res = self.residency
        with res._lock:
            seeds, routed, found = seed_reads_routed(
                self.index, reads, self.cfg.seed_params,
                lambda parts: res.ensure(parts, prefetch=prefetch))
            snap = res.snapshot()
            self._routed += routed
            self._found += found
            self._chunks += 1
        return seeds, snap

    def drain_stats(self) -> dict:
        """Per-partition accounting since the last drain (one run)."""
        out = {
            "chunks_routed": self._chunks,
            "minis_routed_per_partition": self._routed.tolist(),
            "minis_found_per_partition": self._found.tolist(),
            **self.residency.stats_summary(),
        }
        self._routed[:] = 0
        self._found[:] = 0
        self._chunks = 0
        return out


class _RoutedChunkPipeline(_ChunkPipeline):
    """``_ChunkPipeline`` with shard-routed host seeding.

    phase1 replaces the device ``seed_reads`` dispatch with the host
    router (minimizer extraction + per-partition CSR lookup + residency)
    and uploads the finished static-shape seed tensors; phase2/fetch are
    inherited unchanged — ``chunk_index`` hands them the arena snapshot
    this chunk's ``occ_idx`` rows were routed against.

    With ``prefetch=True`` a single background worker runs the host
    prep (pad + revcomp + route + seed + partition uploads) for chunk
    i+1 while chunk i's device work is in flight: ``begin_run`` stages
    the first chunk, and each ``phase1`` submits the next item before
    consuming its own future.  Chunks still pair occurrence rows with
    the snapshot their own ``seed`` returned, so results are
    bit-identical to synchronous loading.
    """

    def __init__(self, router: ShardRouter, cfg: MapperConfig,
                 prefetch: bool = False):
        super().__init__(None, cfg)
        self.router = router
        self.prefetch = prefetch
        self._ex = None
        self._pf_items: list = []
        self._pf_futs: list = []
        self._pf_i = 0

    def begin_run(self, items) -> None:
        """Stage the first chunk's host prep on the prefetch worker."""
        if not (self.prefetch and self.cfg.stream and items):
            return
        if self._ex is None:
            self._ex = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="arena-prefetch")
        self._pf_items = list(items)
        self._pf_futs = [None] * len(self._pf_items)
        self._pf_i = 0
        self._pf_futs[0] = self._ex.submit(
            self._prep, self._pf_items[0], prefetch=True)

    def _prep(self, item, *, prefetch: bool, times=None):
        """Host-side chunk prep: pad, strand-stack, route + seed (which
        uploads any missing partitions).  Runs on the prefetch worker or
        inline on the main thread — the residency lock serializes them."""
        sub, chunk = item
        n_real = len(sub)
        t0 = time.perf_counter()
        if n_real < chunk:
            sub = np.concatenate(
                [sub, np.zeros((chunk - n_real, sub.shape[1]), sub.dtype)])
        if self.cfg.both_strands:
            from ..core.encoding import revcomp
            sub = np.concatenate([sub, np.asarray(revcomp(sub))])
        t0 = streaming.timed(times, "host_prep", t0)
        seeds_np, snap = self.router.seed(sub, prefetch=prefetch)
        streaming.timed(times, "seed", t0)
        return sub, seeds_np, snap, n_real

    def phase1(self, item, times=None):
        staged = (times is None and self._pf_futs
                  and self._pf_i < len(self._pf_items)
                  and self._pf_items[self._pf_i] is item)
        if staged:
            i = self._pf_i
            self._pf_i += 1
            # submit the *next* item before blocking on this one: the
            # single worker runs them in order, so i is already done or
            # running and i+1 queues behind it
            if i + 1 < len(self._pf_items):
                self._pf_futs[i + 1] = self._ex.submit(
                    self._prep, self._pf_items[i + 1], prefetch=True)
            sub, seeds_np, snap, n_real = self._pf_futs[i].result()
            self._pf_futs[i] = None
        else:
            sub, seeds_np, snap, n_real = self._prep(
                item, prefetch=False, times=times)
        positions_dev, segments_dev = snap
        t0 = time.perf_counter()
        reads = jnp.asarray(sub)
        seeds = {
            "mini_pos": jnp.asarray(seeds_np["mini_pos"]),
            "occ_idx": jnp.asarray(seeds_np["occ_idx"]),
            "occ_valid": jnp.asarray(seeds_np["occ_valid"]),
            "n_valid": seeds_np["n_valid"],
            "_chunk_positions": positions_dev,
            "_chunk_segments": segments_dev,
        }
        if times is not None:
            reads.block_until_ready()
            seeds["occ_idx"].block_until_ready()
        streaming.timed(times, "h2d", t0)
        return reads, seeds, n_real

    def chunk_index(self, seeds):
        return seeds.pop("_chunk_positions"), seeds.pop("_chunk_segments")