"""Pure-numpy minimizer scan — the out-of-core builder's substrate.

Bit-identical ports of ``repro.core.minimizers`` (``hash32``,
``kmer_codes``, ``sliding_argmin``, ``minimizers``,
``unique_read_minimizers``): every operation is exact integer arithmetic,
so the numpy and jax implementations agree value-for-value (locked by a
parity test in ``tests/test_index_sharded.py``).

Two consumers need the host-side twin:

* ``repro.index.build`` scans reference tiles with **no jax in the
  loop** — no per-tile-shape retracing, no device transfers of tile
  buffers, and the whole builder's peak RSS is visible to
  ``tracemalloc`` (the bounded-memory assertion of the out-of-core
  build);
* the shard-routed single-host mapper extracts read minimizers on the
  host to decide which index partitions a chunk touches *before* any
  device dispatch (``repro.index.residency``).
"""
from __future__ import annotations

import numpy as np


def np_hash32(x: np.ndarray) -> np.ndarray:
    """Invertible 32-bit integer mix — ``core.minimizers.hash32`` twin."""
    x = np.asarray(x, dtype=np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def np_kmer_codes(seq: np.ndarray, k: int) -> np.ndarray:
    """All k-mer integer codes along the last axis -> (..., L-k+1) uint32."""
    assert k <= 16, "k-mer code must fit 32 bits"
    n = seq.shape[-1] - k + 1
    acc = np.zeros(seq.shape[:-1] + (n,), dtype=np.uint32)
    for j in range(k):
        acc |= seq[..., j : j + n].astype(np.uint32) << np.uint32(
            2 * (k - 1 - j))
    return acc


def np_sliding_argmin(values: np.ndarray, window: int):
    """Sliding-window (min, leftmost argmin) by (value, index) doubling —
    the same step schedule as ``core.minimizers.sliding_argmin``, so tie
    resolution is identical, not merely equivalent."""
    n = values.shape[-1] - window + 1
    idx = np.broadcast_to(
        np.arange(values.shape[-1], dtype=np.int32), values.shape)
    val, pos = values, idx
    span = 1
    while span < window:
        step = min(span, window - span)
        a_v, a_p = val[..., : val.shape[-1] - step], \
            pos[..., : pos.shape[-1] - step]
        b_v, b_p = val[..., step:], pos[..., step:]
        take_b = (b_v < a_v) | ((b_v == a_v) & (b_p < a_p))
        val = np.where(take_b, b_v, a_v)
        pos = np.where(take_b, b_p, a_p)
        span += step
    return val[..., :n], pos[..., :n]


def np_minimizers(seq: np.ndarray, k: int, w: int):
    """Window minimizers -> (min_hash, min_kmer, min_pos), each
    (..., n_windows); ``min_pos`` is the k-mer start within ``seq``."""
    codes = np_kmer_codes(seq, k)
    minh, min_pos = np_sliding_argmin(np_hash32(codes), w)
    min_kmer = np.take_along_axis(codes, min_pos, axis=-1)
    return minh, min_kmer, min_pos


def np_unique_read_minimizers(reads: np.ndarray, k: int, w: int,
                              max_uniq: int):
    """Batched unique minimizers per read, static-shape padded.

    reads: (R, rl).  Returns (kmers (R, max_uniq) uint32,
    positions (R, max_uniq) int32, valid (R, max_uniq) bool) — the host
    twin of ``vmap(unique_read_minimizers)``: stable sort by kmer, keep
    the first occurrence of each, compact to the front.
    """
    _, kmer, pos = np_minimizers(reads, k, w)
    R, n_win = kmer.shape
    order = np.argsort(kmer, axis=-1, kind="stable")
    ks = np.take_along_axis(kmer, order, -1)
    ps = np.take_along_axis(pos, order, -1)
    first = np.concatenate(
        [np.ones((R, 1), dtype=bool), ks[:, 1:] != ks[:, :-1]], axis=1)
    rank = np.cumsum(first, axis=-1) - 1
    slots = np.where(first, rank, n_win)
    out_k = np.zeros((R, n_win + 1), dtype=ks.dtype)
    out_p = np.zeros((R, n_win + 1), dtype=np.int32)
    np.put_along_axis(out_k, slots, ks, axis=-1)
    np.put_along_axis(out_p, slots, ps.astype(np.int32), axis=-1)
    n_uniq = first.sum(axis=-1)
    valid = np.arange(max_uniq)[None, :] < np.minimum(n_uniq,
                                                      max_uniq)[:, None]
    m = min(max_uniq, n_win + 1)
    kmers = np.zeros((R, max_uniq), dtype=ks.dtype)
    positions = np.zeros((R, max_uniq), dtype=np.int32)
    kmers[:, :m] = out_k[:, :m]
    positions[:, :m] = out_p[:, :m]
    return kmers, positions, valid
