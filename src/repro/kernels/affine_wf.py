"""Pallas TPU kernel: banded affine Wagner-Fischer + direction emission.

Same lane/sublane mapping as the linear kernel (instances on lanes, band on
sublanes); three live int8 bands (D, M1, M2) and a per-row packed-direction
write-out.  The direction planes are the only O(n * band) output — exactly
DART-PIM's traceback rows (4 bits/cell; we emit one uint8 per cell, packed
dD | dM1<<2 | dM2<<3, into an (n * band, R) plane so traceback runs without
re-computing values).

Two entry points share the row recurrence:
  ``affine_wf_pallas``      — distances + direction planes (traceback pass,
                              runs on the one winner per read);
  ``affine_wf_dist_pallas`` — distances only.  No (n * band, R) plane is
                              allocated or written; this is the kernel the
                              compacted pipeline runs on every filter
                              survivor.

VMEM per block (block_r = 256, n = 150, eth = 6): inputs ~78 KiB, three
bands ~10 KiB, dirs block n*band*block_r = 487 KiB — comfortably resident
(and absent entirely in the dist-only variant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_step(Dp, M1p, M2p, chars, s1c, d_col, i, *, eth: int, sat8,
              block_r: int, emit_dirs: bool):
    """One band-row update shared by both kernels.

    Returns (Dn, M1n, M2n, bytes_or_None); direction-byte work is only
    emitted when ``emit_dirs`` (the dist-only kernel never materializes it).
    """
    band = 2 * eth + 1
    big = (sat8 + 40).astype(jnp.int8)
    match = chars == s1c[None, :]
    j = i + d_col - eth                                   # (band, 1)

    shift = lambda a: jnp.concatenate(
        [a[1:], jnp.full((1, block_r), big, jnp.int8)], axis=0)
    m1_ext = shift(M1p) + 1                               # raw
    m1_open = shift(Dp) + 2                               # raw
    M1n = jnp.minimum(jnp.minimum(m1_ext, m1_open), sat8).astype(jnp.int8)
    dM1 = (m1_open < m1_ext).astype(jnp.uint8) if emit_dirs else None
    M1n = jnp.where(j >= 0, M1n, sat8).astype(jnp.int8)

    d_left = jnp.full((block_r,), big, jnp.int8)
    m2_left = jnp.full((block_r,), big, jnp.int8)
    D_rows, M2_rows, B_rows = [], [], []
    for dd in range(band):
        jj = j[dd, 0]
        m2_ext = m2_left + 1
        m2_open = d_left + 2
        m2n = jnp.minimum(jnp.minimum(m2_ext, m2_open), sat8)
        m2n = jnp.where(jj <= 0, sat8, m2n).astype(jnp.int8)
        sub_raw = Dp[dd] + 1
        m1n = M1n[dd]
        dmin = jnp.minimum(jnp.minimum(sub_raw, m1n), m2n)
        dval = jnp.where(match[dd], Dp[dd], jnp.minimum(dmin, sat8))
        dval = jnp.where(jj == 0, m1n, dval)
        dval = jnp.where(jj < 0, sat8, dval).astype(jnp.int8)
        if emit_dirs:
            dm2 = (m2_open < m2_ext).astype(jnp.uint8)
            ddir = jnp.where(
                match[dd], jnp.uint8(0),
                jnp.where(dmin == sub_raw, jnp.uint8(1),
                          jnp.where(dmin == m1n, jnp.uint8(2), jnp.uint8(3))))
            ddir = jnp.where(jj == 0, jnp.uint8(2), ddir)
            byte = (ddir | (dM1[dd] << 2) | (dm2 << 3)).astype(jnp.uint8)
            byte = jnp.where(jj < 0, jnp.uint8(0), byte)
            B_rows.append(byte)
        D_rows.append(dval)
        M2_rows.append(m2n)
        d_left, m2_left = dval, m2n
    Dn = jnp.stack(D_rows, axis=0)
    M2n = jnp.stack(M2_rows, axis=0)
    bytes_ = jnp.stack(B_rows, axis=0) if emit_dirs else None
    return Dn, M1n, M2n, bytes_


def _init_bands(eth: int, sat: int, block_r: int):
    band = 2 * eth + 1
    sat8 = jnp.int8(sat)
    d_col = jax.lax.broadcasted_iota(jnp.int32, (band, 1), 0)
    j0 = d_col - eth
    D0 = jnp.where(j0 < 0, sat, jnp.minimum(jnp.where(j0 == 0, 0, 1 + j0),
                                            sat)).astype(jnp.int8)
    D0 = jnp.broadcast_to(D0, (band, block_r))
    M10 = jnp.full((band, block_r), sat8)
    M20 = jnp.broadcast_to(jnp.where(j0 > 0, D0[:, :1], sat8), (band, block_r))
    return d_col, sat8, D0, M10, M20


def _kernel(s1_ref, s2_ref, out_ref, dirs_ref, *, eth: int, n: int, sat: int):
    band = 2 * eth + 1
    block_r = s1_ref.shape[1]
    d_col, sat8, D0, M10, M20 = _init_bands(eth, sat, block_r)

    def row(i, carry):
        Dp, M1p, M2p = carry
        chars = s2_ref[pl.ds(i - 1, band), :]
        s1c = s1_ref[i - 1, :]
        Dn, M1n, M2n, bytes_ = _row_step(Dp, M1p, M2p, chars, s1c, d_col, i,
                                         eth=eth, sat8=sat8, block_r=block_r,
                                         emit_dirs=True)
        dirs_ref[pl.ds((i - 1) * band, band), :] = bytes_
        return (Dn, M1n, M2n)

    D, _, _ = jax.lax.fori_loop(1, n + 1, row, (D0, M10, M20))
    out_ref[0, :] = D[eth, :].astype(jnp.int32)
    out_ref[1, :] = jnp.min(D, axis=0).astype(jnp.int32)


def _kernel_dist(s1_ref, s2_ref, out_ref, *, eth: int, n: int, sat: int):
    band = 2 * eth + 1
    block_r = s1_ref.shape[1]
    d_col, sat8, D0, M10, M20 = _init_bands(eth, sat, block_r)

    def row(i, carry):
        Dp, M1p, M2p = carry
        chars = s2_ref[pl.ds(i - 1, band), :]
        s1c = s1_ref[i - 1, :]
        Dn, M1n, M2n, _ = _row_step(Dp, M1p, M2p, chars, s1c, d_col, i,
                                    eth=eth, sat8=sat8, block_r=block_r,
                                    emit_dirs=False)
        return (Dn, M1n, M2n)

    D, _, _ = jax.lax.fori_loop(1, n + 1, row, (D0, M10, M20))
    out_ref[0, :] = D[eth, :].astype(jnp.int32)
    out_ref[1, :] = jnp.min(D, axis=0).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("eth", "sat", "block_r", "interpret"))
def affine_wf_pallas(s1T: jnp.ndarray, s2T: jnp.ndarray, *, eth: int = 6,
                     sat: int = 32, block_r: int = 256,
                     interpret: bool = True):
    """s1T (n, R), s2T (n+2*eth, R) int8; returns (dists (2, R) int32,
    dirs (n*band, R) uint8)."""
    n, R = s1T.shape
    band = 2 * eth + 1
    assert s2T.shape == (n + 2 * eth, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel, eth=eth, n=n, sat=sat),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_r), lambda r: (0, r)),
            pl.BlockSpec((n + 2 * eth, block_r), lambda r: (0, r)),
        ],
        out_specs=[
            pl.BlockSpec((2, block_r), lambda r: (0, r)),
            pl.BlockSpec((n * band, block_r), lambda r: (0, r)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((2, R), jnp.int32),
            jax.ShapeDtypeStruct((n * band, R), jnp.uint8),
        ],
        interpret=interpret,
    )(s1T, s2T)


@functools.partial(jax.jit,
                   static_argnames=("eth", "sat", "block_r", "interpret"))
def affine_wf_dist_pallas(s1T: jnp.ndarray, s2T: jnp.ndarray, *, eth: int = 6,
                          sat: int = 32, block_r: int = 256,
                          interpret: bool = True):
    """Distance-only variant: s1T (n, R), s2T (n+2*eth, R) int8 ->
    (2, R) int32 [dist_end; dist_min].  No direction plane is produced."""
    n, R = s1T.shape
    assert s2T.shape == (n + 2 * eth, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel_dist, eth=eth, n=n, sat=sat),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_r), lambda r: (0, r)),
            pl.BlockSpec((n + 2 * eth, block_r), lambda r: (0, r)),
        ],
        out_specs=pl.BlockSpec((2, block_r), lambda r: (0, r)),
        out_shape=jax.ShapeDtypeStruct((2, R), jnp.int32),
        interpret=interpret,
    )(s1T, s2T)
