"""Pallas TPU kernel: fused banded affine WF + on-device traceback.

The staged pipeline materializes the (n * band, R) packed-direction planes
in HBM (``affine_wf_pallas``), fetches nothing, and then runs a separate
traceback program over them — the planes round-trip through HBM purely to
connect two kernels.  This kernel fuses the two: the forward pass writes
its direction bytes into a VMEM *scratch* buffer, and the traceback walk
consumes them in-place, so the only O(n * band) array never leaves the
core.  Outputs are the END-aligned op rows + per-lane op count + the two
distance rows — exactly the arrays the host needs, nothing else crosses
D2H.  This is DART-PIM's traceback dataflow (Sec. IV-B: direction bits
live in auxiliary crossbar rows next to the values and are walked there)
rather than the paper's CPU-side reconstruction.

Walk layout: the fused-transition step (``repro.core.affine_wf
.traceback_step``) emits exactly one op per active lane per iteration, so
all ``block_r`` lanes stay in lockstep and iteration t writes the single
output row ``(max_ops - 1 - t) % max_ops`` — a masked row update, no
per-lane scatter.  The op rows are carried in registers/VMEM as a loop
value and stored once at the end.

VMEM per block (block_r = 256, n = 150, eth = 6, max_ops = 302): inputs
~78 KiB, three bands ~10 KiB, dirs scratch n*band*block_r = 487 KiB, ops
carry max_ops*block_r*4 = 302 KiB — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.affine_wf import OP_NONE, traceback_step

from .affine_wf import _init_bands, _row_step


def _kernel(s1_ref, s2_ref, out_ref, ops_ref, cnt_ref, dirs_ref, *,
            eth: int, n: int, sat: int, max_ops: int):
    band = 2 * eth + 1
    block_r = s1_ref.shape[1]
    d_col, sat8, D0, M10, M20 = _init_bands(eth, sat, block_r)

    # ---- forward pass: affine band recurrence, dirs -> VMEM scratch
    def row(i, carry):
        Dp, M1p, M2p = carry
        chars = s2_ref[pl.ds(i - 1, band), :]
        s1c = s1_ref[i - 1, :]
        Dn, M1n, M2n, bytes_ = _row_step(Dp, M1p, M2p, chars, s1c, d_col, i,
                                         eth=eth, sat8=sat8, block_r=block_r,
                                         emit_dirs=True)
        dirs_ref[pl.ds((i - 1) * band, band), :] = bytes_
        return (Dn, M1n, M2n)

    D, _, _ = jax.lax.fori_loop(1, n + 1, row, (D0, M10, M20))
    out_ref[0, :] = D[eth, :].astype(jnp.int32)
    out_ref[1, :] = jnp.min(D, axis=0).astype(jnp.int32)

    # ---- traceback walk over the scratch planes (never leave VMEM)
    dirs = dirs_ref[...].astype(jnp.int32)           # (n * band, block_r)

    def cond(c):
        i, d, _, _, t, _ = c
        return ((i > 0) | (i + d - eth > 0)).any()

    def body(c):
        i, d, state, k, t, ops = c
        cell = jnp.maximum(i - 1, 0) * band + d
        byte = jnp.take_along_axis(dirs, cell[None, :], axis=0)[0]
        op, ni, nd, ns, active = traceback_step(i, d, state, byte, eth)
        ni = jnp.where(active, ni, i)
        nd = jnp.where(active, nd, d)
        ns = jnp.where(active, ns, state)
        rr = jnp.remainder(max_ops - 1 - t, max_ops)
        cur = jax.lax.dynamic_slice_in_dim(ops, rr, 1, axis=0)[0]
        ops = jax.lax.dynamic_update_slice_in_dim(
            ops, jnp.where(active, op, cur)[None], rr, axis=0)
        return ni, nd, ns, k + active.astype(jnp.int32), t + 1, ops

    init = (jnp.full((block_r,), n, jnp.int32),
            jnp.full((block_r,), eth, jnp.int32),
            jnp.zeros((block_r,), jnp.int32),
            jnp.zeros((block_r,), jnp.int32), jnp.int32(0),
            jnp.full((max_ops, block_r), OP_NONE, jnp.int32))
    _, _, _, k, _, ops = jax.lax.while_loop(cond, body, init)
    ops_ref[...] = ops
    cnt_ref[0, :] = k


@functools.partial(jax.jit, static_argnames=("eth", "sat", "max_ops",
                                             "block_r", "interpret"))
def affine_traceback_pallas(s1T: jnp.ndarray, s2T: jnp.ndarray, *,
                            eth: int = 6, sat: int = 32, max_ops: int,
                            block_r: int = 256, interpret: bool = True):
    """s1T (n, R), s2T (n+2*eth, R) int8 -> (dists (2, R) int32,
    ops (max_ops, R) int32 END-aligned, count (1, R) int32).

    The direction planes live only in VMEM scratch — nothing O(n * band)
    is allocated in HBM or crosses D2H.
    """
    n, R = s1T.shape
    band = 2 * eth + 1
    assert s2T.shape == (n + 2 * eth, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel, eth=eth, n=n, sat=sat, max_ops=max_ops),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_r), lambda r: (0, r)),
            pl.BlockSpec((n + 2 * eth, block_r), lambda r: (0, r)),
        ],
        out_specs=[
            pl.BlockSpec((2, block_r), lambda r: (0, r)),
            pl.BlockSpec((max_ops, block_r), lambda r: (0, r)),
            pl.BlockSpec((1, block_r), lambda r: (0, r)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((2, R), jnp.int32),
            jax.ShapeDtypeStruct((max_ops, R), jnp.int32),
            jax.ShapeDtypeStruct((1, R), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((n * band, block_r), jnp.uint8)],
        interpret=interpret,
    )(s1T, s2T)
