"""Pallas TPU kernel: k-mer hashing + sliding-window minimizer extraction.

Seeding front-end (paper Sec. V-C).  Reads sit along lanes; the sequence
axis along sublanes.  The kernel fuses three stages that would otherwise
round-trip HBM:
  1. 2-bit k-mer code assembly  (k unrolled shift-or steps)
  2. 32-bit invertible hash     (mul/xor lane ops)
  3. sliding-window argmin      (log2(w) doubling steps on (value, idx) pairs)

Output is (n_windows, R) minimizer positions + hashes; the unique-ification
(variable-length) stays in plain JAX — it is O(windows) scalar work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(seq_ref, hash_ref, pos_ref, *, k: int, w: int, n_win: int):
    L, block_r = seq_ref.shape
    n_kmers = L - k + 1
    seq = seq_ref[...].astype(jnp.uint32)
    acc = jnp.zeros((n_kmers, block_r), jnp.uint32)
    for j in range(k):
        acc = acc | (seq[j : j + n_kmers] << (2 * (k - 1 - j)))
    # hash32 (invertible mix)
    x = acc
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    # sliding argmin via (value, index) doubling
    idx = jax.lax.broadcasted_iota(jnp.int32, (n_kmers, block_r), 0)
    val, pos = x, idx
    span = 1
    while span < w:
        step = min(span, w - span)
        a_v, a_p = val[: val.shape[0] - step], pos[: pos.shape[0] - step]
        b_v, b_p = val[step:], pos[step:]
        take_b = (b_v < a_v) | ((b_v == a_v) & (b_p < a_p))
        val = jnp.where(take_b, b_v, a_v)
        pos = jnp.where(take_b, b_p, a_p)
        span += step
    hash_ref[...] = val[:n_win]
    pos_ref[...] = pos[:n_win]


@functools.partial(jax.jit, static_argnames=("k", "w", "block_r", "interpret"))
def minimizer_pallas(seqT: jnp.ndarray, *, k: int = 12, w: int = 30,
                     block_r: int = 512, interpret: bool = True):
    """seqT (L, R) uint8 base codes -> (hashes (n_win, R) uint32,
    positions (n_win, R) int32), n_win = L - (w + k - 1) + 1."""
    L, R = seqT.shape
    n_win = L - (w + k - 1) + 1
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, w=w, n_win=n_win),
        grid=grid,
        in_specs=[pl.BlockSpec((L, block_r), lambda r: (0, r))],
        out_specs=[
            pl.BlockSpec((n_win, block_r), lambda r: (0, r)),
            pl.BlockSpec((n_win, block_r), lambda r: (0, r)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_win, R), jnp.uint32),
            jax.ShapeDtypeStruct((n_win, R), jnp.int32),
        ],
        interpret=interpret,
    )(seqT)
