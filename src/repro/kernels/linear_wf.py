"""Pallas TPU kernel: banded linear Wagner-Fischer (paper Alg. 2).

TPU mapping of the crossbar-row parallelism: each WF *instance* occupies one
VPU **lane**; the 2*eth+1 band cells live along **sublanes**.  A block of
``block_r`` instances is resident in VMEM; the kernel sweeps the read length
with a fori_loop, updating the (band, block_r) int8 band in registers — the
exact in-row dataflow of DART-PIM's Fig. 3, with MAGIC NOR ops replaced by
8x128-lane int8 min/add/select.

Inputs are pre-transposed to (seq, instances) so the instance axis is the
(128-wide, contiguous) lane axis:
  s1T  (n,          R)  int8   reads
  s2T  (n + 2*eth,  R)  int8   reference windows
  out  (2,          R)  int32  row 0 = D[n][n] (paper), row 1 = min last row

VMEM per block (block_r = 512, n = 150, eth = 6):
  s1 75 KiB + s2 81 KiB + band 6.5 KiB + out 4 KiB  <<  16 MiB VMEM.
The matmul-free kernel is VPU-bound; block_r is a multiple of 128 so every
op is lane-aligned, and the band axis (13) stays within one sublane tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s1_ref, s2_ref, out_ref, *, eth: int, n: int):
    band = 2 * eth + 1
    block_r = s1_ref.shape[1]
    sat = jnp.int8(eth + 1)
    d_col = jax.lax.broadcasted_iota(jnp.int32, (band, 1), 0)

    b0 = jnp.where(d_col < eth, sat,
                   jnp.minimum(d_col - eth, eth + 1)).astype(jnp.int8)
    b0 = jnp.broadcast_to(b0, (band, block_r))

    def row(i, B):
        chars = s2_ref[pl.ds(i - 1, band), :]          # (band, R) int8
        s1c = s1_ref[i - 1, :]                          # (R,)
        sub = (chars != s1c[None, :]).astype(jnp.int8)
        j = i + d_col - eth                             # (band, 1)
        diag = jnp.where(j >= 1, B + sub, sat)
        up_src = jnp.concatenate(
            [B[1:], jnp.full((1, block_r), sat, jnp.int8)], axis=0)
        up = jnp.where(j >= 0, jnp.minimum(up_src + 1, sat), sat)
        cand = jnp.minimum(jnp.minimum(diag, up), sat).astype(jnp.int8)
        # left propagation: (min, +1) running scan, unrolled over the band
        run = jnp.full((block_r,), sat, jnp.int8)
        rows = []
        for dd in range(band):
            run = jnp.minimum(cand[dd], jnp.minimum(run + 1, sat)).astype(
                jnp.int8)
            rows.append(run)
        new = jnp.stack(rows, axis=0)
        return jnp.where(j >= 0, new, sat).astype(jnp.int8)

    B = jax.lax.fori_loop(1, n + 1, row, b0)
    out_ref[0, :] = B[eth, :].astype(jnp.int32)
    out_ref[1, :] = jnp.min(B, axis=0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("eth", "block_r", "interpret"))
def linear_wf_pallas(s1T: jnp.ndarray, s2T: jnp.ndarray, *, eth: int = 6,
                     block_r: int = 512, interpret: bool = True):
    """s1T (n, R) int8, s2T (n+2*eth, R) int8; R divisible by block_r.

    Returns (2, R) int32: [dist_end; dist_min].
    """
    n, R = s1T.shape
    assert s2T.shape == (n + 2 * eth, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel, eth=eth, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_r), lambda r: (0, r)),
            pl.BlockSpec((n + 2 * eth, block_r), lambda r: (0, r)),
        ],
        out_specs=pl.BlockSpec((2, block_r), lambda r: (0, r)),
        out_shape=jax.ShapeDtypeStruct((2, R), jnp.int32),
        interpret=interpret,
    )(s1T, s2T)
