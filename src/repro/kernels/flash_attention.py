"""Pallas TPU kernel: causal/bidirectional flash attention with GQA.

The LM-side perf-critical layer (prefill/train attention).  Grid is
(batch, q_heads, q_blocks); each program holds one (qc, hd) query tile and
its kv-head's full (S, hd) K/V panels in VMEM, sweeping kv chunks with an
online-softmax accumulator — the classic flash schedule, with the GQA
q-head -> kv-head mapping folded into the BlockSpec index_map (no KV
replication in HBM or VMEM).

VMEM per program (S = 8192, hd = 128, qc = 512, bf16):
  K + V panels 2x2 MiB + q/out tiles ~0.25 MiB + f32 stats — fits the
  16 MiB VMEM budget up to S ~ 24k; beyond that the jnp chunked oracle
  (layers._sdpa_chunked) streams from HBM instead (documented fallback).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk: int, causal: bool,
            scale: float):
    qc, hd = q_ref.shape[2], q_ref.shape[3]
    S = k_ref.shape[2]
    nk = S // kv_chunk
    iq = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (qc, hd)

    def body(ik, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(ik * kv_chunk, kv_chunk), :]  # (kc, hd)
        v = v_ref[0, 0, pl.ds(ik * kv_chunk, kv_chunk), :]
        s = jnp.dot(q, k.astype(jnp.float32).T)             # (qc, kc)
        if causal:
            qpos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, 1), 0)
            kpos = ik * kv_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (1, kv_chunk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + jnp.dot(p.astype(v.dtype),
                                       v).astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((qc, 1), NEG, jnp.float32)
    l0 = jnp.zeros((qc, 1), jnp.float32)
    a0 = jnp.zeros((qc, hd), jnp.float32)
    if causal:
        # only kv chunks up to the diagonal contribute; masked-out chunks
        # are skipped entirely (no wasted rectangles, unlike the jnp path)
        nk_eff = jnp.minimum(((iq + 1) * qc + kv_chunk - 1) // kv_chunk, nk)
    else:
        nk_eff = nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "kv_chunk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           q_chunk: int = 512, kv_chunk: int = 512,
                           interpret: bool = True):
    """q (B, H, S, hd); k/v (B, KV, S, hd) -> (B, H, S, hd).

    H must be a multiple of KV (GQA); S divisible by the chunk sizes.
    """
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    assert S % qc == 0 and S % kc == 0
    grid = (B, H, S // qc)
    scale = 1.0 / math.sqrt(hd)
    return pl.pallas_call(
        functools.partial(_kernel, kv_chunk=kc, causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qc, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // rep, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
