"""Pure-jnp oracles for every Pallas kernel (same math, no tiling).

These wrap the reference implementations in ``repro.core`` with the kernels'
transposed calling conventions so tests can assert allclose directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.affine_wf import banded_affine
from repro.core.linear_wf import banded_wf
from repro.core.minimizers import minimizers


def linear_wf_ref(s1T, s2T, *, eth: int = 6):
    """(n, R), (n+2eth, R) -> (2, R) int32 [dist_end; dist_min]."""
    s1 = jnp.asarray(s1T).T.astype(jnp.uint8)
    s2 = jnp.asarray(s2T).T.astype(jnp.uint8)
    de, dm = banded_wf(s1, s2, eth=eth)
    return jnp.stack([de, dm], axis=0)


def affine_wf_ref(s1T, s2T, *, eth: int = 6, sat: int = 32):
    """-> (dists (2, R) int32, dirs (n*band, R) uint8)."""
    s1 = jnp.asarray(s1T).T.astype(jnp.uint8)
    s2 = jnp.asarray(s2T).T.astype(jnp.uint8)
    de, dm, dirs = banded_affine(s1, s2, eth=eth, sat=sat)
    n, band = dirs.shape[-2], dirs.shape[-1]
    dirsT = jnp.moveaxis(dirs.reshape(dirs.shape[0], n * band), 0, -1)
    return jnp.stack([de, dm], axis=0), dirsT


def minimizer_ref(seqT, *, k: int = 12, w: int = 30):
    """(L, R) -> (hashes (n_win, R) uint32, positions (n_win, R) int32)."""
    seq = jnp.asarray(seqT).T
    mh, _, mp = minimizers(seq, k=k, w=w)
    return mh.T, mp.T


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Oracle: the exact-softmax grouped-GQA attention from layers."""
    from repro.models.layers import _sdpa
    return _sdpa(q, k, v, causal=causal)
