"""Pallas TPU kernels for DART-PIM's compute hot-spots.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), a jit wrapper in
ops.py, and a pure-jnp oracle in ref.py validated by tests/test_kernels.py.
"""
from . import ops, ref  # noqa: F401
