"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as traced JAX ops, proving correctness of the exact
code that compiles for TPU.  ``on_tpu()`` flips to compiled mode.

Wrappers handle the (instances-last) transposes and padding to the block
size so callers keep the natural (R, n) layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .affine_wf import affine_wf_dist_pallas, affine_wf_pallas
from .linear_wf import linear_wf_pallas
from .minimizer import minimizer_pallas
from .traceback import affine_traceback_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_r(x, block_r):
    R = x.shape[-1]
    pad = (-R) % block_r
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x, R


@functools.partial(jax.jit, static_argnames=("eth", "block_r"))
def linear_wf(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int = 6,
              block_r: int = 512):
    """Batched banded linear WF via the Pallas kernel.

    s1 (R, n) uint8, s2_window (R, n+2*eth) uint8 ->
    (dist_end (R,), dist_min (R,)) int32.
    """
    s1T, R = _pad_r(s1.astype(jnp.int8).T, block_r)
    s2T, _ = _pad_r(s2_window.astype(jnp.int8).T, block_r)
    out = linear_wf_pallas(s1T, s2T, eth=eth, block_r=block_r,
                           interpret=not on_tpu())
    return out[0, :R], out[1, :R]


@functools.partial(jax.jit, static_argnames=("eth", "sat", "block_r"))
def affine_wf(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int = 6,
              sat: int = 32, block_r: int = 256):
    """Batched banded affine WF via the Pallas kernel.

    s1 (R, n), s2_window (R, n+2*eth) uint8 ->
    (dist_end (R,), dist_min (R,), dirs (R, n, band) uint8).
    """
    n = s1.shape[-1]
    band = 2 * eth + 1
    s1T, R = _pad_r(s1.astype(jnp.int8).T, block_r)
    s2T, _ = _pad_r(s2_window.astype(jnp.int8).T, block_r)
    dists, dirsT = affine_wf_pallas(s1T, s2T, eth=eth, sat=sat,
                                    block_r=block_r, interpret=not on_tpu())
    dirs = dirsT[:, :R].T.reshape(R, n, band)
    return dists[0, :R], dists[1, :R], dirs


@functools.partial(jax.jit,
                   static_argnames=("eth", "sat", "max_ops", "block_r"))
def affine_traceback(s1: jnp.ndarray, s2_window: jnp.ndarray, *,
                     eth: int = 6, sat: int = 32, max_ops: int,
                     block_r: int = 256):
    """Fused banded affine WF + on-device traceback via the Pallas kernel
    (direction planes stay in VMEM scratch — see ``kernels.traceback``).

    s1 (R, n), s2_window (R, n+2*eth) uint8 ->
    (dist_end (R,), dist_min (R,), ops (R, max_ops) int32 END-aligned,
    op_count (R,) int32).
    """
    s1T, R = _pad_r(s1.astype(jnp.int8).T, block_r)
    s2T, _ = _pad_r(s2_window.astype(jnp.int8).T, block_r)
    dists, opsT, cnt = affine_traceback_pallas(
        s1T, s2T, eth=eth, sat=sat, max_ops=max_ops, block_r=block_r,
        interpret=not on_tpu())
    return dists[0, :R], dists[1, :R], opsT[:, :R].T, cnt[0, :R]


@functools.partial(jax.jit, static_argnames=("eth", "sat", "block_r"))
def affine_wf_dist(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int = 6,
                   sat: int = 32, block_r: int = 256):
    """Distance-only banded affine WF via the Pallas kernel (no direction
    planes — the compacted pipeline's survivor pass).

    s1 (R, n), s2_window (R, n+2*eth) uint8 ->
    (dist_end (R,), dist_min (R,)) int32.
    """
    s1T, R = _pad_r(s1.astype(jnp.int8).T, block_r)
    s2T, _ = _pad_r(s2_window.astype(jnp.int8).T, block_r)
    out = affine_wf_dist_pallas(s1T, s2T, eth=eth, sat=sat, block_r=block_r,
                                interpret=not on_tpu())
    return out[0, :R], out[1, :R]


@functools.partial(jax.jit, static_argnames=("k", "w", "block_r"))
def minimizer_scan(seqs: jnp.ndarray, *, k: int = 12, w: int = 30,
                   block_r: int = 512):
    """Batched minimizer extraction via the Pallas kernel.

    seqs (R, L) uint8 -> (hashes (R, n_win) uint32, positions (R, n_win)).
    """
    seqT, R = _pad_r(seqs.T, block_r)
    mh, mp = minimizer_pallas(seqT, k=k, w=w, block_r=block_r,
                              interpret=not on_tpu())
    return mh[:, :R].T, mp[:, :R].T


@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "kv_chunk"))
def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 512):
    """Flash attention via the Pallas kernel (layers layout).

    q (B, S, H, hd); k/v (B, S, KV, hd) -> (B, S, H, hd)."""
    from .flash_attention import flash_attention_pallas
    qT = jnp.transpose(q, (0, 2, 1, 3))
    kT = jnp.transpose(k, (0, 2, 1, 3))
    vT = jnp.transpose(v, (0, 2, 1, 3))
    o = flash_attention_pallas(qT, kT, vT, causal=causal, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, interpret=not on_tpu())
    return jnp.transpose(o, (0, 2, 1, 3))
