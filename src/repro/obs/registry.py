"""Process-wide metrics registry: counters, gauges, bounded histograms.

The observability layer's first pillar (the other two live in
``repro.obs.tracing`` and the launcher surfaces).  Design constraints,
in order:

* **near-zero cost when disabled** — hot paths guard on the module
  global ``ACTIVE`` (one attribute load + ``is None`` branch) and touch
  nothing else;
* **bounded memory always** — histograms use *fixed log-spaced bucket
  edges* (no per-observation storage), and per-name label sets are
  capped at ``MAX_LABEL_SETS`` with an explicit overflow series, so a
  long-lived serving process cannot grow the registry without bound no
  matter what label values (tenant ids, bucket sizes) flow through it;
* **one source of truth** — the launchers re-derive their closing-stats
  lines from these instruments (``mapper.totals_from_registry``), and
  the Prometheus text endpoint / JSONL snapshots read the same objects,
  so the numbers cannot disagree between surfaces.

This module is a **leaf**: it imports nothing from ``repro.core`` /
``repro.index`` so every layer of the stack may instrument itself
without import cycles.
"""
from __future__ import annotations

import bisect
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "enable_metrics", "disable_metrics", "metrics",
           "DEFAULT_BUCKET_EDGES", "MAX_LABEL_SETS"]


def _log_edges(lo: float = 1e-6, hi: float = 1e3,
               per_decade: int = 5) -> tuple:
    """Fixed log-spaced bucket upper edges covering ``[lo, hi]``.

    5 edges/decade over 9 decades = 46 buckets (+1 overflow): enough
    resolution for ~15% relative-error quantiles on latencies from a
    microsecond to a quarter hour, in a few hundred bytes per histogram.
    """
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


DEFAULT_BUCKET_EDGES = _log_edges()

# distinct label-sets allowed per metric name before new label values
# collapse into one overflow series — the bound that keeps per-tenant /
# per-shard labels safe in a long-lived service
MAX_LABEL_SETS = 64
_OVERFLOW_LABELS = (("other", "true"),)


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (queue depth, resident rows)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket log-spaced histogram; memory is O(len(edges)), never
    O(observations).  ``quantile`` returns the upper edge of the bucket
    holding the requested rank (observations above the last edge report
    the last edge — the histogram's bounded-range contract)."""

    __slots__ = ("name", "labels", "edges", "counts", "count", "sum",
                 "_lock")

    def __init__(self, name: str, labels: tuple = (),
                 edges: tuple = DEFAULT_BUCKET_EDGES):
        self.name = name
        self.labels = labels
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return self.edges[min(i, len(self.edges) - 1)]
        return self.edges[-1]

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self.counts)
            n, s = self.count, self.sum
        buckets = {}
        for i, c in enumerate(counts):
            if c:
                le = ("+Inf" if i >= len(self.edges)
                      else f"{self.edges[i]:.6g}")
                buckets[le] = c
        return dict(count=n, sum=s, p50=self.quantile(0.5),
                    p95=self.quantile(0.95), p99=self.quantile(0.99),
                    buckets=buckets)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Name + labels -> instrument, with per-name label-set bounding.

    ``counter("repro_reads_total", topology="single")`` returns the same
    object on every call, creating it on first use.  A metric name is
    permanently bound to one instrument kind (mixing kinds raises).
    """

    def __init__(self, max_label_sets: int = MAX_LABEL_SETS):
        self.max_label_sets = max_label_sets
        self._lock = threading.Lock()
        self._families: dict[str, dict[tuple, object]] = {}
        self._kinds: dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: dict):
        key = tuple(sorted(labels.items())) if labels else ()
        fam = self._families.get(name)
        if fam is not None and self._kinds.get(name) == kind:
            inst = fam.get(key)
            if inst is not None:
                return inst
        with self._lock:
            known = self._kinds.setdefault(name, kind)
            if known != kind:
                raise ValueError(f"metric {name!r} is a {known}, not a "
                                 f"{kind}")
            fam = self._families.setdefault(name, {})
            inst = fam.get(key)
            if inst is None:
                if key and len(fam) >= self.max_label_sets:
                    key = _OVERFLOW_LABELS   # bounded cardinality
                    inst = fam.get(key)
                    if inst is not None:
                        return inst
                inst = fam[key] = _KINDS[kind](name, key)
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # ------------------------------------------------------------ export
    @staticmethod
    def _series(name: str, labels: tuple) -> str:
        if not labels:
            return name
        inner = ",".join(f'{k}="{v}"' for k, v in labels)
        return f"{name}{{{inner}}}"

    def snapshot(self) -> dict:
        """JSON-serializable state: one flat dict per instrument kind,
        keyed by the Prometheus-style series name."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = [(name, self._kinds[name], dict(fam))
                     for name, fam in self._families.items()]
        for name, kind, fam in items:
            for labels, inst in sorted(fam.items()):
                series = self._series(name, labels)
                if kind == "histogram":
                    out["histograms"][series] = inst.snapshot()
                else:
                    v = inst.value
                    out["counters" if kind == "counter"
                        else "gauges"][series] = (
                        int(v) if isinstance(v, int) else float(v))
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4)."""
        lines = []
        with self._lock:
            items = sorted((name, self._kinds[name], dict(fam))
                           for name, fam in self._families.items())
        for name, kind, fam in items:
            lines.append(f"# TYPE {name} {kind}")
            for labels, inst in sorted(fam.items()):
                if kind != "histogram":
                    lines.append(f"{self._series(name, labels)} "
                                 f"{inst.value}")
                    continue
                snap = inst.snapshot()
                cum = 0
                for i, edge in enumerate(inst.edges):
                    cum += inst.counts[i]
                    if inst.counts[i]:
                        ll = labels + (("le", f"{edge:.6g}"),)
                        lines.append(
                            f"{self._series(name + '_bucket', ll)} {cum}")
                ll = labels + (("le", "+Inf"),)
                lines.append(f"{self._series(name + '_bucket', ll)} "
                             f"{snap['count']}")
                lines.append(f"{self._series(name + '_sum', labels)} "
                             f"{snap['sum']}")
                lines.append(f"{self._series(name + '_count', labels)} "
                             f"{snap['count']}")
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------- global
# The process-wide registry.  Hot paths read this module attribute once
# and branch on None — the entire disabled-mode cost.
ACTIVE: MetricsRegistry | None = None


def enable_metrics(registry: MetricsRegistry | None = None,
                   ) -> MetricsRegistry:
    """Arm the process-wide registry (idempotent; pass ``registry`` to
    install a specific instance, e.g. a fresh one in tests)."""
    global ACTIVE
    if registry is not None:
        ACTIVE = registry
    elif ACTIVE is None:
        ACTIVE = MetricsRegistry()
    return ACTIVE


def disable_metrics() -> None:
    global ACTIVE
    ACTIVE = None


def metrics() -> MetricsRegistry | None:
    """The active registry, or None when metrics are disabled."""
    return ACTIVE
