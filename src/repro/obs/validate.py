"""Dependency-free validators for the exported observability artifacts.

Two consumers: the ``obs-smoke`` CI job (which must validate without
installing ``jsonschema``) and the test suite.  ``validate_chrome_trace``
checks the Chrome trace-event contract Perfetto relies on — every
complete ("X") span carries numeric pid/tid/ts/dur, and any duration
("B"/"E") events balance per (pid, tid) track.  ``validate_json`` is a
minimal JSON-Schema-subset checker (type / required / properties /
additionalProperties / items / enum / minimum) — enough to hold the
metrics-JSONL snapshot format to ``schemas/metrics_snapshot.schema.json``
without a schema library.
"""
from __future__ import annotations

import json

__all__ = ["validate_chrome_trace", "validate_json", "validate_jsonl",
           "load_json"]

_NUM = (int, float)


def load_json(path: str):
    with open(path) as f:
        return json.load(f)


def validate_chrome_trace(trace) -> list[str]:
    """-> list of violations (empty = valid).  Accepts the object form
    (``{"traceEvents": [...]}``) or the bare event array."""
    errors: list[str] = []
    events = (trace.get("traceEvents") if isinstance(trace, dict)
              else trace)
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    if not events:
        errors.append("trace holds no events")
    stacks: dict[tuple, list] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph is None:
            errors.append(f"event {i}: missing ph")
            continue
        where = f"event {i} ({ev.get('name', '?')!r}, ph={ph})"
        if ph == "M":
            if "name" not in ev or "pid" not in ev:
                errors.append(f"{where}: metadata needs name and pid")
            continue
        for field in ("name", "pid", "tid", "ts"):
            if field not in ev:
                errors.append(f"{where}: missing {field}")
        for field in ("pid", "tid", "ts"):
            if field in ev and not isinstance(ev[field], _NUM):
                errors.append(f"{where}: {field} is not numeric")
        if ph == "X":
            if "dur" not in ev:
                errors.append(f"{where}: complete event missing dur")
            elif not isinstance(ev["dur"], _NUM):
                errors.append(f"{where}: dur is not numeric")
            elif ev["dur"] < 0:
                errors.append(f"{where}: negative dur")
        elif ph == "B":
            stacks.setdefault((ev.get("pid"), ev.get("tid")),
                              []).append(ev.get("name"))
        elif ph == "E":
            stack = stacks.get((ev.get("pid"), ev.get("tid")))
            if not stack:
                errors.append(f"{where}: E without matching B on its "
                              f"(pid, tid) track")
            else:
                stack.pop()
    for (pid, tid), stack in stacks.items():
        for name in stack:
            errors.append(f"unbalanced B event {name!r} on track "
                          f"(pid={pid}, tid={tid}): no matching E")
    return errors


def validate_json(obj, schema: dict, path: str = "$") -> list[str]:
    """Check ``obj`` against a JSON-Schema subset; -> violations."""
    errors: list[str] = []
    t = schema.get("type")
    if t is not None:
        ok = {"object": lambda o: isinstance(o, dict),
              "array": lambda o: isinstance(o, list),
              "string": lambda o: isinstance(o, str),
              "number": lambda o: isinstance(o, _NUM)
              and not isinstance(o, bool),
              "integer": lambda o: isinstance(o, int)
              and not isinstance(o, bool),
              "boolean": lambda o: isinstance(o, bool),
              "null": lambda o: o is None}
        types = t if isinstance(t, list) else [t]
        if not any(ok[x](obj) for x in types):
            return [f"{path}: expected {t}, got {type(obj).__name__}"]
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in enum {schema['enum']}")
    if "minimum" in schema and isinstance(obj, _NUM) \
            and obj < schema["minimum"]:
        errors.append(f"{path}: {obj} < minimum {schema['minimum']}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                errors.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for k, v in obj.items():
            if k in props:
                errors += validate_json(v, props[k], f"{path}.{k}")
            elif isinstance(extra, dict):
                errors += validate_json(v, extra, f"{path}.{k}")
            elif extra is False:
                errors.append(f"{path}: unexpected key {k!r}")
    if isinstance(obj, list) and "items" in schema:
        for i, v in enumerate(obj):
            errors += validate_json(v, schema["items"], f"{path}[{i}]")
    return errors


def validate_jsonl(path: str, schema: dict) -> list[str]:
    """Validate every line of a JSONL file against ``schema``."""
    errors: list[str] = []
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {lineno}: not JSON ({e})")
                continue
            errors += validate_json(obj, schema, path=f"line {lineno}")
    if n == 0:
        errors.append("no JSONL records")
    return errors
