"""``repro.obs`` — unified observability: metrics, tracing, surfaces.

Three pillars over one design rule (near-zero cost when disabled,
bounded memory when enabled):

* ``repro.obs.registry`` — the process-wide metrics registry
  (counters / gauges / fixed-log-bucket histograms), wired through the
  streaming engine, ``Mapper``, ``DeviceResidency``, ``ResilientMapper``
  and ``MappingService``;
* ``repro.obs.tracing``  — chunk-lifecycle span tracing exported as
  Chrome trace-event JSON (Perfetto-loadable), sharing clock reads with
  ``stage_times_s`` so the two surfaces agree by construction;
* ``repro.obs.logjson`` / ``repro.obs.server`` — structured JSON
  logging, Prometheus text exposition, and the jax profiler server for
  the launchers (``--trace-out`` / ``--metrics-out`` / ``--log-json`` /
  ``--metrics-port`` / ``--profiler-port``).

The package is a **leaf**: nothing here imports ``repro.core`` or
``repro.index``, so every layer may instrument itself without cycles.
"""
from . import logjson, server, validate
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       disable_metrics, enable_metrics, metrics)
from .tracing import (Tracer, annotate, clear_ctx, disable_tracing,
                      enable_tracing, get_ctx, set_ctx, tracer)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "enable_metrics", "disable_metrics", "metrics",
    "Tracer", "enable_tracing", "disable_tracing", "tracer",
    "set_ctx", "get_ctx", "clear_ctx", "annotate",
    "logjson", "server", "validate",
]
