"""Span tracing with Chrome trace-event export (Perfetto-loadable).

Second pillar of ``repro.obs``: every chunk's lifecycle (ingest -> H2D
-> seed -> linear -> affine -> traceback -> D2H -> SAM emit) is recorded
as **complete spans** carrying chunk/shard attribution, and exported as
Chrome trace-event JSON (the ``{"traceEvents": [...]}`` container) that
loads directly in Perfetto / ``chrome://tracing``.

The central integration point is ``repro.core.streaming.timed``: every
per-stage wall-time accumulation *also* emits a span from the **same
two clock reads**, so the exported trace's per-stage durations and the
legacy ``stage_times_s`` dict are identical by construction — the
acceptance property ``tests/test_obs.py`` locks.

Attribution rides a thread-local context (``set_ctx(chunk=i)``): the
streaming engine stamps the in-flight chunk index on whichever thread
(dispatch or fetch) runs each phase, so overlapping chunks untangle in
the viewer.  Memory is bounded by ``max_events`` — a long run drops and
counts excess events rather than growing without limit.

Like the registry, this module is a leaf with a module-global ``ACTIVE``
tracer: disabled cost is one attribute load + ``is None`` branch.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

__all__ = ["Tracer", "enable_tracing", "disable_tracing", "tracer",
           "set_ctx", "get_ctx", "clear_ctx", "annotate"]

_tls = threading.local()


def set_ctx(**kw) -> None:
    """Replace this thread's span-attribution context (e.g. chunk=3)."""
    _tls.ctx = kw


def get_ctx() -> dict | None:
    return getattr(_tls, "ctx", None)


def clear_ctx() -> None:
    _tls.ctx = None


class Tracer:
    """Bounded in-memory span collector with Chrome trace-event export."""

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = max_events
        self.epoch = time.perf_counter()
        self.dropped = 0
        self._events: list[tuple] = []   # (name, tid, t0, t1, args)
        self._lock = threading.Lock()

    def add(self, name: str, t0: float, t1: float,
            args: dict | None = None) -> None:
        """Record a complete span from two ``perf_counter`` reads; the
        calling thread's context (``set_ctx``) merges into ``args``."""
        ctx = getattr(_tls, "ctx", None)
        if ctx:
            args = {**ctx, **args} if args else dict(ctx)
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(
                (name, threading.get_ident(), t0, t1, args))

    @contextlib.contextmanager
    def span(self, name: str, **args):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, t0, time.perf_counter(), args or None)

    def __len__(self) -> int:
        return len(self._events)

    def stage_totals(self) -> dict:
        """Summed span seconds by name — ``stage_times_s``, re-derived
        from the trace (bit-equal where both exist: same clock reads)."""
        out: dict[str, float] = {}
        with self._lock:
            events = list(self._events)
        for name, _tid, t0, t1, _args in events:
            out[name] = out.get(name, 0.0) + (t1 - t0)
        return out

    def chrome(self) -> dict:
        """The trace as a Chrome trace-event JSON object."""
        with self._lock:
            events = list(self._events)
        pid = os.getpid()
        tids: dict[int, int] = {}
        out = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": "repro"}}]
        for name, ident, t0, t1, args in events:
            tid = tids.setdefault(ident, len(tids))
            ev = {"ph": "X", "name": name, "pid": pid, "tid": tid,
                  "ts": (t0 - self.epoch) * 1e6,
                  "dur": (t1 - t0) * 1e6}
            if args:
                ev["args"] = args
            out.append(ev)
        for ident, tid in tids.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": f"thread-{tid}"}})
        meta = {"dropped_events": self.dropped} if self.dropped else {}
        return {"traceEvents": out, "displayTimeUnit": "ms", **meta}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)
            f.write("\n")


# --------------------------------------------------------------- global
ACTIVE: Tracer | None = None


def enable_tracing(max_events: int = 1_000_000,
                   tracer_: Tracer | None = None) -> Tracer:
    """Arm the process-wide tracer (idempotent; pass ``tracer_`` to
    install a specific instance)."""
    global ACTIVE
    if tracer_ is not None:
        ACTIVE = tracer_
    elif ACTIVE is None:
        ACTIVE = Tracer(max_events=max_events)
    return ACTIVE


def disable_tracing() -> Tracer | None:
    """Disarm; returns the tracer that was active (for a final export)."""
    global ACTIVE
    t, ACTIVE = ACTIVE, None
    return t


def tracer() -> Tracer | None:
    return ACTIVE


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` when tracing is armed, else a
    null context — the hook that names engine dispatches inside a
    ``jax.profiler`` trace (profiler server / programmatic traces)
    without taxing un-traced runs."""
    if ACTIVE is None:
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:   # profiler unavailable: spans still work
        return contextlib.nullcontext()
