"""Structured JSON logging for the launchers (``--log-json``).

One JSON object per line on the configured stream (stderr by default),
so launcher progress/closing output becomes machine-parseable without
scraping the human-readable lines.  Disabled by default; the launchers'
``say`` calls fall back to plain ``print`` when not enabled, keeping
the human output byte-identical to before this layer existed.
"""
from __future__ import annotations

import json
import sys
import time

__all__ = ["enable", "disable", "enabled", "emit", "say"]

_state = {"stream": None, "component": None}


def enable(component: str, stream=None) -> None:
    _state["component"] = component
    _state["stream"] = stream if stream is not None else sys.stderr


def disable() -> None:
    _state["stream"] = None
    _state["component"] = None


def enabled() -> bool:
    return _state["stream"] is not None


def emit(event: str, **fields) -> bool:
    """Write one JSON log line; returns False (and writes nothing) when
    JSON logging is not enabled, so callers can fall back to print."""
    stream = _state["stream"]
    if stream is None:
        return False
    rec = {"ts_unix_s": time.time(), "component": _state["component"],
           "event": event}
    rec.update(fields)
    stream.write(json.dumps(rec, default=str) + "\n")
    stream.flush()
    return True


def say(msg: str, *, event: str = "log", file=None, **fields) -> None:
    """JSON log line when enabled, else a plain print to ``file``
    (stderr by default) — the launchers' one-call progress surface."""
    if not emit(event, msg=msg, **fields):
        print(msg, file=file if file is not None else sys.stderr)
