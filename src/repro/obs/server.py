"""Operational endpoints: Prometheus text exposition + jax profiler.

``MetricsServer`` is a daemon-thread HTTP server exposing the active
registry as ``/metrics`` (Prometheus text format 0.0.4) and
``/metrics.json`` (the JSON snapshot) — the scrape surface for service
mode (``serve --service --metrics-port``).

``start_profiler_server`` wraps ``jax.profiler.start_server`` (the
mesh-transformer-jax fleet-debugging pattern): once listening, a
``jax.profiler.trace`` client or TensorBoard can attach to a live
serving process and capture device timelines on demand.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["MetricsServer", "start_metrics_server",
           "start_profiler_server"]


class MetricsServer:
    """Threaded HTTP exposition of one ``MetricsRegistry``."""

    def __init__(self, registry, port: int = 0, host: str = "127.0.0.1"):
        self.registry = registry
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):          # noqa: N802 (http.server API)
                if self.path.startswith("/metrics.json"):
                    body = json.dumps(outer.registry.snapshot()).encode()
                    ctype = "application/json"
                elif self.path.startswith("/metrics"):
                    body = outer.registry.to_prometheus().encode()
                    ctype = "text/plain; version=0.0.4"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # scrapes are not launcher output
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="obs-metrics", daemon=True)

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()


def start_metrics_server(registry, port: int = 0,
                         host: str = "127.0.0.1") -> MetricsServer:
    """Start the exposition thread; ``port=0`` binds an ephemeral port
    (read it back from ``.port``)."""
    return MetricsServer(registry, port=port, host=host).start()


def start_profiler_server(port: int):
    """Start the jax profiler server on ``port``; returns the server
    object, or None when the profiler is unavailable on this jax build
    (the caller reports and continues — observability must never take
    the service down)."""
    try:
        import jax
        return jax.profiler.start_server(port)
    except Exception:
        return None
