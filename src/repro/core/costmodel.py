"""DART-PIM analytic cost model (paper Secs. IV, VI, VII; Tables I-VI).

The memristive gate-level schedule does not transfer to TPU, but the paper's
quantitative claims do — this module reproduces them analytically so the
reproduction can be validated against the paper's own numbers:

  * Table I    — MAGIC-NOR cycle counts per logical operation
  * Alg. 1     — 37*b + 19 ops per linear WF cell
  * Table IV   — cycles/switches per WF instance (258,620 / 1,308,699)
  * Eq. 6      — DP-memory execution time
  * Eq. 7      — crossbar energy
  * Figs. 9-10 — end-to-end throughput / energy / area comparison points

Workload constants (AVG_*) are back-derived from the paper's own reported
end-to-end numbers and cross-checked against our full-system simulation on
synthetic genomes (see tests/test_costmodel.py and benchmarks/).
"""
from __future__ import annotations

import dataclasses
import math

# ----------------------------------------------------------------- Table I
def cycles_and(n): return 3 * n
def cycles_xnor(n): return 4 * n
def cycles_xor(n): return 5 * n
def cycles_copy(n): return 1 + n
def cycles_add(n): return 9 * n
def cycles_add_bit(n): return 5 * n            # N-bit + 1-bit
def cycles_add_const(n): return 5 * n
def cycles_sub(n): return 9 * n
def cycles_mux(n): return 3 * n + 1
def cycles_min(n): return 12 * n + 1


def linear_wf_cell_ops(b: int = 3) -> int:
    """Algorithm 1: MAGIC ops for one linear WF cell with b-bit values.

    2 mins (2*13b) + add-const (5b) + mux1-select (6) + mux1 (3b+1)
    + mux2-select (11) + mux2 (3b+1)  =  37b + 19.
    """
    return (2 * (12 * b + 1) + cycles_add_bit(b) + 6 + cycles_mux(b) + 11
            + cycles_mux(b)) - 2 * 1 + 2  # keep closed form explicit below


def linear_wf_cell_ops_closed(b: int = 3) -> int:
    return 37 * b + 19


# --------------------------------------------------- Table III / IV constants
READ_LEN = 150
ETH = 6
BAND = 2 * ETH + 1          # 13 live cells per row

LINEAR_OVERHEAD = 1_085     # row/col init + step (4) — paper Sec. VII-B
LINEAR_WRITE_CYCLES = 4_035
LINEAR_MAGIC_SWITCHES = 254_384
LINEAR_WRITE_SWITCHES = 255_499

AFFINE_MAGIC_CYCLES = 1_288_281
AFFINE_WRITE_CYCLES = 20_418
AFFINE_MAGIC_SWITCHES = 1_271_921
AFFINE_WRITE_SWITCHES = 1_277_495

# Table V
T_CLK = 2e-9                # 2 ns conservatively-scaled MAGIC/write cycle
E_MAGIC = 90e-15            # 90 fJ/bit
E_WRITE = 90e-15

# Table II / VI
N_CROSSBARS = 8 * 2 ** 20   # 8M crossbars (32 chips x 512 banks x 512 xbars)
LINEAR_BUF_ROWS = 32
AFFINE_INSTANCES_PER_ITER = 8
READS_FIFO_ROWS = 160
STATIC_POWER_W = 86.0 + 6.1 + 5.7   # controllers + RISC-V(+cache) + periphery
RISCV_AFFINE_FRACTION = 0.0016      # 0.16% of affine instances on RISC-V
DATA_TRANSFER_J = 1.1 + 75.4        # reads write-in + results read-out

AREA_MM2 = {"crossbars": 7916.0, "controllers": 191.9, "peripherals": 53.6,
            "riscv_cores": 14.2, "riscv_caches": 6.4}

# Workload constants back-derived from the paper's end-to-end numbers
# (Sec. VII-C/D): T(maxReads) is linear with slope ~3.47 ms/read ->
# ~6 linear iterations/read + 1 affine instance per (read, crossbar)/8.
AVG_LINEAR_ITERS_PER_READ = 6.0     # ceil(avg PLs per (read,minimizer) / 32)
AVG_MINIS_PER_READ = 5.0            # unique minimizers landing per read
AVG_PLS_PER_READ = 930.0            # ~ AVG_MINIS * 186 PLs/(read,mini)


def linear_wf_cycles(read_len: int = READ_LEN, eth: int = ETH,
                     b: int = 3) -> dict:
    """Reproduces Table IV (linear row): 1950 cells x 130 cycles + overhead."""
    cells = (2 * eth + 1) * read_len
    magic = cells * linear_wf_cell_ops_closed(b) + LINEAR_OVERHEAD
    return {"cells": cells, "magic_cycles": magic,
            "write_cycles": LINEAR_WRITE_CYCLES,
            "total_cycles": magic + LINEAR_WRITE_CYCLES,
            "energy_J": (LINEAR_MAGIC_SWITCHES * E_MAGIC
                         + LINEAR_WRITE_SWITCHES * E_WRITE)}


def affine_wf_cycles() -> dict:
    """Table IV (affine row) — taken as measured constants from the paper's
    cycle-accurate single-crossbar simulator."""
    return {"magic_cycles": AFFINE_MAGIC_CYCLES,
            "write_cycles": AFFINE_WRITE_CYCLES,
            "total_cycles": AFFINE_MAGIC_CYCLES + AFFINE_WRITE_CYCLES,
            "energy_J": (AFFINE_MAGIC_SWITCHES * E_MAGIC
                         + AFFINE_WRITE_SWITCHES * E_WRITE)}


@dataclasses.dataclass(frozen=True)
class SystemEstimate:
    exec_time_s: float
    throughput_reads_s: float
    energy_J: float
    avg_power_W: float
    reads_per_J: float
    area_mm2: float
    area_eff: float  # reads / (mm^2 * s)


def dart_pim_system(n_reads: float = 389e6, max_reads: float = 25e3,
                    linear_iters_per_read: float = AVG_LINEAR_ITERS_PER_READ,
                    minis_per_read: float = AVG_MINIS_PER_READ,
                    pls_per_read: float = AVG_PLS_PER_READ) -> SystemEstimate:
    """End-to-end estimate via Eq. 6 (time) and Eq. 7 (energy).

    The bottleneck crossbar processes ``max_reads`` reads; all crossbars run
    in lock-step, so K_L = max_reads * iterations/read and K_A = max_reads /
    8 (one affine instance per read per crossbar, 8 per iteration).
    """
    n_l = linear_wf_cycles()["total_cycles"]
    n_a = affine_wf_cycles()["total_cycles"]
    k_l = max_reads * linear_iters_per_read
    k_a = max_reads / AFFINE_INSTANCES_PER_ITER
    t = (k_l * n_l + k_a * n_a) * T_CLK                      # Eq. 6

    j_l = n_reads * pls_per_read                             # linear instances
    j_a = n_reads * minis_per_read * (1 - RISCV_AFFINE_FRACTION)
    e_xbar = (linear_wf_cycles()["energy_J"] * j_l
              + affine_wf_cycles()["energy_J"] * j_a)        # Eq. 7
    energy = e_xbar + STATIC_POWER_W * t + DATA_TRANSFER_J
    area = sum(AREA_MM2.values())
    return SystemEstimate(exec_time_s=t, throughput_reads_s=n_reads / t,
                          energy_J=energy, avg_power_W=energy / t,
                          reads_per_J=n_reads / energy, area_mm2=area,
                          area_eff=n_reads / (area * t))


# ------------------------------------------------- comparison points (Sec VII)
BASELINES = {
    # name: (exec_time_s, energy_J, area_mm2) for 389M reads
    "minimap2":  (19_785.0, 2.4e6, 2_362.0),
    "parabricks": (495.0, 2.4e6, 46_352.0),
    "genasm":    (29_154.0, 94.2e3, 10.7),
    "segram":    (22_426.0, 543e3, 27.8),
    "genvom":    (39.2, 1.4e3, 298.0),
}
N_READS_PAPER = 389e6

ACCURACY = {  # Sec. VII-A
    "dartpim_12.5k": 0.997, "dartpim_25k": 0.998, "dartpim_50k": 0.998,
    "parabricks": 0.999, "minimap2": 0.999, "genasm": 0.966,
    "segram": 0.966, "genvom": 0.912,
}


def speedup_table(max_reads: float = 25e3) -> dict:
    est = dart_pim_system(max_reads=max_reads)
    out = {}
    for name, (t, e, a) in BASELINES.items():
        out[name] = {
            "speedup": t / est.exec_time_s,
            "energy_eff": (N_READS_PAPER / e) and (est.reads_per_J /
                                                   (N_READS_PAPER / e)),
            "area_eff_ratio": est.area_eff / (N_READS_PAPER / (a * t)),
        }
    return out


def sw_vs_wf_latency_ratio(b_sw: int = 8, b_wf: int = 3) -> float:
    """Sec. IV-B claim: linear WF lowers latency ~2.8x vs in-memory SW.

    Cell cost scales with bit width (37b+19); SW additionally needs ~max
    instead of min and similarity bookkeeping — modelled as the same cell
    structure at b=8 vs b=3 (the paper attributes the gain to bit-width).
    """
    return linear_wf_cell_ops_closed(b_sw) / linear_wf_cell_ops_closed(b_wf)


def full_system_simulation(read_counts_per_minimizer, pls_per_minimizer,
                           max_reads: int = 25_000,
                           linear_rows: int = LINEAR_BUF_ROWS):
    """Full-system iteration counts from a measured workload histogram
    (our stand-in for the paper's C++ full-system simulator).

    read_counts_per_minimizer: reads seeded to each minimizer (array)
    pls_per_minimizer: PLs stored for each minimizer (array)
    Returns (K_L, K_A, J_L, J_A) for Eq. 6/7 with per-crossbar caps applied.
    """
    import numpy as np
    reads = np.minimum(np.asarray(read_counts_per_minimizer), max_reads)
    pls = np.asarray(pls_per_minimizer)
    iters_per_read = np.ceil(pls / linear_rows)
    k_l = float((reads * iters_per_read).max()) if len(reads) else 0.0
    k_a = float(np.ceil(reads / AFFINE_INSTANCES_PER_ITER).max()) if len(reads) \
        else 0.0
    j_l = float((reads * pls).sum())
    j_a = float(reads.sum())
    return k_l, k_a, j_l, j_a
