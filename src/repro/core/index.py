"""Offline indexing (paper Sec. V-B) — DART-PIM's data organization.

The reference genome is scanned for minimizer occurrences; for every
occurrence we **pre-materialize the reference segment** of length
``2*(rl + eth) - k`` centered on the occurrence, exactly as DART-PIM writes
segments into crossbar linear-WF buffers.  The ~17x storage blow-up is the
paper's deliberate trade: all later stages touch only local data.

Layout (CSR over unique minimizer k-mers, sorted for O(log U) lookup):
  uniq_kmers : (U,)   uint32  sorted unique minimizer k-mer codes
  offsets    : (U+1,) int32/int64  CSR offsets into positions/segments
  positions  : (P,)   int32/int64  k-mer start position of each occurrence
  segments   : (P, seg_len) uint8  pre-extracted reference windows
               (sentinel base 4 beyond the reference ends — never matches)

Positions past 2^31-1 (index format v2, GRCh38-scale) are int64 on the
host; :func:`device_position_dtype` picks what the device arena can
actually hold under jax's 32-bit default.

A "crossbar" in the TPU mapping is an index shard: minimizers are assigned
to shards by ``hash(kmer) % num_shards`` (see ``repro.core.distributed``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .minimizers import minimizers
import jax.numpy as jnp

SENTINEL = 4  # "N"-like base, never equal to a read base


def device_position_dtype(ref_len: int) -> np.dtype:
    """Device-side dtype for positions of a reference ending at global
    position ``ref_len - 1``.

    jax defaults to 32-bit (``jnp.asarray`` silently narrows int64 when
    x64 is off), so the choice is explicit: int32 while every position
    fits; int64 when the runtime honors it (``JAX_ENABLE_X64``);
    otherwise uint32 up to 2^32-1 — which covers GRCh38's 3.1 Gb
    spacer-concatenated reference.  Past 2^32-1 without x64 is an
    error, never a silent wrap.
    """
    import jax
    max_pos = int(ref_len) - 1
    # strict <: the dtype max itself is the device winner-reduce
    # sentinel, so the largest representable value must stay unused
    if max_pos < np.iinfo(np.int32).max:
        return np.dtype(np.int32)
    if jax.config.read("jax_enable_x64"):
        return np.dtype(np.int64)
    if max_pos < np.iinfo(np.uint32).max:
        return np.dtype(np.uint32)
    raise ValueError(
        f"reference ends at position {max_pos}, past uint32; device "
        f"arithmetic needs 64-bit ints — set JAX_ENABLE_X64=1 (or "
        f"jax.config.update('jax_enable_x64', True)) before mapping")


def validate_geometry(*, read_len: int, k: int, w: int, eth: int) -> None:
    """Reject impossible index/mapper geometry at construction time.

    The one home of the (read_len, k, w, eth) sanity rules, shared by
    ``MapperConfig``, ``build_index``, ``repro.index.build_sharded_index``
    and ``repro.index.ShardedGenomeIndex`` — so a bad geometry fails here
    with the field named, not deep inside jit tracing with a shape error.
    """
    if read_len < 1:
        raise ValueError(f"read_len={read_len!r}: read length must be >= 1")
    if not 1 <= k <= 16:
        raise ValueError(f"k={k!r}: k-mer length must be within [1, 16] — "
                         f"k-mer codes are 2-bit packed into uint32")
    if k > read_len:
        raise ValueError(f"k={k} exceeds read_len={read_len}: reads "
                         f"shorter than k produce no k-mers to seed")
    if w < 1:
        raise ValueError(f"w={w!r}: minimizer window length must be >= 1")
    if eth < 0:
        raise ValueError(f"eth={eth!r}: band half-width must be >= 0")


@dataclasses.dataclass(frozen=True)
class GenomeIndex:
    uniq_kmers: np.ndarray
    offsets: np.ndarray
    positions: np.ndarray
    segments: np.ndarray
    read_len: int
    k: int
    w: int
    eth: int

    @property
    def seg_len(self) -> int:
        return 2 * (self.read_len + self.eth) - self.k

    @property
    def pad(self) -> int:
        """Segment extent on each side of the minimizer start."""
        return self.read_len + self.eth - self.k

    def storage_bytes(self) -> dict:
        """Footprint accounting, mirroring the paper's 800MB -> 13.3GB note.

        Reports the *true on-disk* bytes of the persistent format
        (``repro.index.format``): segments are 2-bit packed per base —
        ``ceil(seg_len/4)`` bytes per occurrence row, not
        ``nbytes // 4`` (which undercounted rows whose length is not a
        multiple of 4) — plus a 1-bit-per-base sentinel mask, and the
        hash table includes the CSR offsets it is stored with.
        """
        n_occ = len(self.positions)
        seg_bytes = n_occ * ((self.seg_len + 3) // 4
                             + (self.seg_len + 7) // 8)
        hash_table = (self.uniq_kmers.nbytes + self.offsets.nbytes
                      + self.positions.nbytes)
        return {
            "hash_table_bytes": hash_table,
            "materialized_segments_bytes": seg_bytes,
            "total_bytes": hash_table + seg_bytes,
            "blowup": seg_bytes / max(hash_table, 1),
        }


def build_index(ref: np.ndarray, read_len: int = 150, k: int = 12,
                w: int = 30, eth: int = 6, max_pls_per_minimizer: int = 256,
                ) -> GenomeIndex:
    """Scan the reference, collect minimizer occurrences, materialize segments.

    ``max_pls_per_minimizer`` caps hyper-repetitive minimizers (the paper
    bounds these via the Reads-FIFO / lowTh mechanisms; capping PLs is the
    standard minimap2-style guard and keeps shapes static downstream).
    """
    validate_geometry(read_len=read_len, k=k, w=w, eth=eth)
    _, kmers, pos = minimizers(jnp.asarray(ref), k=k, w=w)
    kmers = np.asarray(kmers)
    pos = np.asarray(pos)
    # Dedup (kmer, pos) occurrence pairs (adjacent windows share minimizers).
    occ = np.unique(np.stack([kmers.astype(np.int64), pos.astype(np.int64)], 1),
                    axis=0)
    kmers_u, pos_u = occ[:, 0].astype(np.uint32), occ[:, 1].astype(np.int32)
    # CSR by kmer (occ already sorted by kmer then pos).
    uniq, starts, counts = np.unique(kmers_u, return_index=True,
                                     return_counts=True)
    # Cap PL lists.
    keep = np.ones(len(kmers_u), dtype=bool)
    for s, c in zip(starts[counts > max_pls_per_minimizer],
                    counts[counts > max_pls_per_minimizer]):
        keep[s + max_pls_per_minimizer : s + c] = False
    kmers_u, pos_u = kmers_u[keep], pos_u[keep]
    uniq, counts = np.unique(kmers_u, return_counts=True)
    offsets = np.zeros(len(uniq) + 1, dtype=np.int32)
    offsets[1:] = np.cumsum(counts)

    pad = read_len + eth - k
    seg_len = 2 * (read_len + eth) - k
    padded = np.full(len(ref) + 2 * pad, SENTINEL, dtype=np.uint8)
    padded[pad : pad + len(ref)] = ref
    # segment for occurrence at p spans ref[p - pad : p - pad + seg_len]
    segs = np.stack([padded[p : p + seg_len] for p in pos_u]) if len(pos_u) \
        else np.zeros((0, seg_len), dtype=np.uint8)
    return GenomeIndex(uniq_kmers=uniq.astype(np.uint32), offsets=offsets,
                       positions=pos_u, segments=segs.astype(np.uint8),
                       read_len=read_len, k=k, w=w, eth=eth)


def minimizer_frequencies(index: GenomeIndex) -> np.ndarray:
    """PLs per unique minimizer — drives the lowTh RISC-V/crossbar split."""
    return np.diff(index.offsets)


def low_th_split(index: GenomeIndex, low_th: int = 3) -> dict:
    """Paper Sec. V-A: minimizers with frequency <= lowTh are offloaded
    (RISC-V in DART-PIM; the padded residual batch on TPU).

    Returns masks + the workload split statistics that drive Eq. 6/7.
    """
    freqs = minimizer_frequencies(index)
    rare = freqs <= low_th
    return {
        "rare_mask": rare,
        "n_rare_minimizers": int(rare.sum()),
        "n_minimizers": len(freqs),
        "rare_pl_fraction": float(freqs[rare].sum() / max(freqs.sum(), 1)),
        "rare_minimizer_fraction": float(rare.mean()),
    }
