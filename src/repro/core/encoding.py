"""2-bit DNA base encoding (A=0, C=1, G=2, T=3).

DART-PIM stores reads as 2R bits and reference segments as 4R bits inside a
crossbar row. On TPU we keep bases as uint8 in {0,1,2,3} (the VPU's narrowest
lane type); helpers here pack/unpack to the 2-bit representation used when
computing memory-footprint numbers and k-mer integer codes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BASES = "ACGT"
_LUT = np.full(256, 255, dtype=np.uint8)
for _i, _c in enumerate(BASES):
    _LUT[ord(_c)] = _i
    _LUT[ord(_c.lower())] = _i

A, C, G, T = 0, 1, 2, 3
NUM_BASES = 4
BITS_PER_BASE = 2


def encode_str(s: str) -> np.ndarray:
    """ASCII DNA string -> uint8 codes in {0..3}. Unknown bases map to A."""
    out = _LUT[np.frombuffer(s.encode(), dtype=np.uint8)]
    return np.where(out == 255, 0, out).astype(np.uint8)


# codes -> text: ACGT for 0..3, N for the sentinel and anything above
_DECODE_CHARS = np.frombuffer(b"ACGTN", dtype=np.uint8)


def decode_to_str(codes) -> str:
    codes = np.minimum(np.asarray(codes), NUM_BASES).astype(np.uint8)
    return _DECODE_CHARS[codes].tobytes().decode("ascii")


def revcomp(codes: np.ndarray) -> np.ndarray:
    """Reverse complement along the last axis (A<->T, C<->G).

    Works on single sequences or batches ``(..., L)``.  Sentinel bases
    (code >= 4, the "N" stand-in) are their own complement so reference
    windows keep their never-matching property under strand flips.
    """
    codes = np.asarray(codes)
    comp = np.where(codes < NUM_BASES, (NUM_BASES - 1) - codes, codes)
    return np.ascontiguousarray(comp[..., ::-1]).astype(codes.dtype)


def pack_2bit(codes: np.ndarray) -> np.ndarray:
    """Pack base codes (len multiple of 4 padded) into bytes, 4 bases/byte."""
    codes = np.asarray(codes, dtype=np.uint8)
    pad = (-len(codes)) % 4
    if pad:
        codes = np.concatenate([codes, np.zeros(pad, dtype=np.uint8)])
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(
        np.uint8
    )


def unpack_2bit(packed: np.ndarray, n: int) -> np.ndarray:
    packed = np.asarray(packed, dtype=np.uint8)
    out = np.empty((len(packed), 4), dtype=np.uint8)
    for j in range(4):
        out[:, j] = (packed >> (2 * j)) & 0x3
    return out.reshape(-1)[:n]


def kmer_codes(seq: jnp.ndarray, k: int) -> jnp.ndarray:
    """All k-mer integer codes of ``seq`` (len L) -> (L-k+1,) uint32.

    code = sum_j seq[i+j] << 2*(k-1-j)  (big-endian base order; k <= 16).
    Vectorized as a sum of k shifted views — k is small and static.
    """
    assert k <= 16, "k-mer code must fit 32 bits"
    L = seq.shape[-1]
    n = L - k + 1
    acc = jnp.zeros(seq.shape[:-1] + (n,), dtype=jnp.uint32)
    for j in range(k):
        acc = acc | (
            seq[..., j : j + n].astype(jnp.uint32) << jnp.uint32(2 * (k - 1 - j))
        )
    return acc
