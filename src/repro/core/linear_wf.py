"""Linear Wagner-Fischer: banded edit distance (paper Sec. III-A, Alg. 2).

The band has half-width ``eth`` (paper: 6); all values are saturated at
``eth + 1``.  Only ``2*eth + 1`` cells are live per row — DART-PIM keeps them
in one crossbar row; we keep them in one VPU-lane-resident int8 vector and
sweep the read length.  This module is the pure-jnp reference; the Pallas
kernel in ``repro.kernels.linear_wf`` implements the identical recurrence.

Band coordinates: cell (i, j) of the (n+1) x (m+1) WF matrix is stored at
``d = j - i + eth`` (valid for |i - j| <= eth).  Row ``i`` of the band needs
reference chars ``s2_window[i-1 : i-1 + 2*eth+1]`` — a contiguous slice,
where ``s2_window`` has length ``n + 2*eth`` and position ``p`` holds the
reference base at (expected read start - eth + p).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def full_wf_numpy(s1: np.ndarray, s2: np.ndarray,
                  w_del: int = 1, w_ins: int = 1, w_sub: int = 1) -> np.ndarray:
    """Unbanded Wagner-Fischer distance matrix (oracle). O(n*m) numpy."""
    n, m = len(s1), len(s2)
    D = np.zeros((n + 1, m + 1), dtype=np.int32)
    D[1:, 0] = np.cumsum(np.full(n, w_del))
    D[0, 1:] = np.cumsum(np.full(m, w_ins))
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            if s1[i - 1] == s2[j - 1]:
                D[i, j] = D[i - 1, j - 1]
            else:
                D[i, j] = min(D[i - 1, j] + w_del,
                              D[i, j - 1] + w_ins,
                              D[i - 1, j - 1] + w_sub)
    return D


def banded_wf_numpy(s1: np.ndarray, s2_window: np.ndarray, eth: int = 6):
    """Band-only oracle with saturation, mirroring paper Algorithm 2 exactly.

    ``s2_window`` must have length len(s1) + 2*eth.  Returns the full band
    history (n+1, 2*eth+1) and the final distance D[n][n] (= band[n, eth]).
    """
    n = len(s1)
    assert len(s2_window) == n + 2 * eth
    sat = eth + 1
    B = np.full((n + 1, 2 * eth + 1), sat, dtype=np.int32)
    for d in range(eth, 2 * eth + 1):
        B[0, d] = min(d - eth, sat)
    for i in range(1, n + 1):
        for d in range(2 * eth + 1):
            j = i + d - eth
            if j < 0:
                continue  # stays saturated
            diag = B[i - 1, d]
            up = B[i - 1, d + 1] if d + 1 <= 2 * eth else sat
            left = B[i, d - 1] if d >= 1 else sat
            if j == 0:
                B[i, d] = min(up + 1, sat)
                continue
            sub = int(s1[i - 1] != s2_window[i + d - 1])
            B[i, d] = min(diag + sub, up + 1, left + 1, sat)
    return B, int(B[n, eth])


@partial(jax.jit, static_argnames=("eth",))
def banded_wf(s1: jnp.ndarray, s2_window: jnp.ndarray, eth: int = 6):
    """Batched banded WF distance. s1: (..., n), s2_window: (..., n+2*eth).

    Returns (dist_end, dist_min): the paper-faithful D[n][n] and the
    semi-global min over the last band row.  int8 arithmetic, saturated at
    eth+1 (paper: 3-bit cells for eth=6).
    """
    n = s1.shape[-1]
    band = 2 * eth + 1
    sat = jnp.int8(eth + 1)
    d_idx = jnp.arange(band, dtype=jnp.int32)

    b0 = jnp.where(d_idx < eth, sat, jnp.minimum(d_idx - eth, eth + 1)).astype(
        jnp.int8
    )
    b0 = jnp.broadcast_to(b0, s1.shape[:-1] + (band,))

    def row(carry, i):
        prev = carry  # (..., band) row i-1
        # chars for this row: s2_window[..., i-1 : i-1+band]
        chars = jax.lax.dynamic_slice_in_dim(s2_window, i - 1, band, axis=-1)
        sub = (s1[..., i - 1][..., None] != chars).astype(jnp.int8)
        j = i + d_idx - eth  # (band,)
        diag = jnp.where(j >= 1, prev + sub, sat)
        up_src = jnp.concatenate([prev[..., 1:], jnp.full_like(prev[..., :1], sat)],
                                 axis=-1)
        up = jnp.where(j >= 0, jnp.minimum(up_src + 1, sat), sat)
        cand = jnp.minimum(jnp.minimum(diag, up), sat).astype(jnp.int8)

        # left-propagation: running (min,+1) prefix scan over the band
        def scan_left(run, c):
            v = jnp.minimum(c, jnp.minimum(run + 1, sat)).astype(jnp.int8)
            return v, v

        init = jnp.full(cand.shape[:-1], sat, dtype=jnp.int8)
        _, newT = jax.lax.scan(scan_left, init, jnp.moveaxis(cand, -1, 0))
        new = jnp.moveaxis(newT, 0, -1)
        new = jnp.where(j >= 0, new, sat).astype(jnp.int8)
        return new, None

    last, _ = jax.lax.scan(row, b0, jnp.arange(1, n + 1))
    dist_end = last[..., eth].astype(jnp.int32)
    dist_min = jnp.min(last, axis=-1).astype(jnp.int32)
    return dist_end, dist_min
