"""Fault tolerance for the mapping service (the always-on posture).

DART-PIM is pitched as an always-on end-to-end accelerator; a real
deployment keeps mapping throughput up despite defective ranks, stalled
controllers and malformed real-world reads.  On the JAX side the failure
surface is the same shape — a wedged fetch thread, a device error or
capacity blow-up in one bucket, a poisoned read that reliably kills its
chunk — and the posture is the same: **contain the failure to the work
that caused it** and keep the rest of the stream flowing.  This module is
the one home of that policy layer:

``MappingError``
    The structured per-request failure result.  A bucket that exhausts
    its retries resolves the affected request(s) to one of these instead
    of raising through ``MappingService.flush``.
``RetryPolicy``
    Exponential-backoff retry + chunk bisection: a failed block is
    retried, then split in half and each half mapped independently, so a
    single poisoned read quarantines ``bisect_min`` reads, not the whole
    bucket.
``AdmissionConfig``
    Backpressure at ``MappingService.submit``: a bounded pending-reads
    queue with ``block`` (drain synchronously) or ``shed`` (reject with
    ``ShedError``) overflow policies, plus per-request deadlines.
``DegradeLadder``
    Graceful degradation after repeated failures: ``fused -> compacted``
    engine, then ``pallas -> jnp`` backend.  Sticky by design — a
    session that had to degrade stays degraded until rebuilt.
``FaultInjector``
    The deterministic chaos driver threaded through
    ``streaming.stream_map`` (fetch stalls/errors), the bucket executor
    (transient kills, poisoned rows, engine-targeted failures) and the
    FASTQ parser (record corruption).  Same seed, same faults — every
    chaos test is reproducible.
``ResilientMapper``
    A ``Mapper`` wrapper applying retry/bisect/degrade, returning
    per-read results with a ``failed`` quarantine mask instead of
    raising.

Import discipline: this module may import ``mapper``/``pipeline`` (they
never import it back); ``streaming`` stays below it and defines its own
``FetchStallError``.
"""
from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

from ..obs import registry as _metrics
from . import affine_wf
from .mapper import _PER_READ_FIELDS, Mapper, MapperStats
from .pipeline import LazyTraceback, MapperConfig, MappingResult
from .streaming import FetchStallError  # noqa: F401  (re-export: the
#                       public error taxonomy lives in this module)

__all__ = ["MappingError", "RetryPolicy", "AdmissionConfig",
           "DegradeLadder", "FaultInjector", "ResilientMapper",
           "InjectedFault", "ShedError", "FetchStallError"]


def _obs_inc(name: str, n=1) -> None:
    """Bump a resilience counter in the active metrics registry (no-op
    when metrics are disabled)."""
    reg = _metrics.ACTIVE
    if reg is not None:
        reg.counter(name).inc(n)


class InjectedFault(RuntimeError):
    """A deterministic fault raised by ``FaultInjector`` (chaos tests)."""


class ShedError(RuntimeError):
    """``MappingService.submit`` rejected a request: the pending queue is
    full and the admission policy is ``shed``.  Recoverable — resubmit
    after a ``flush``."""


@dataclasses.dataclass(frozen=True)
class MappingError:
    """Structured per-request failure result.

    ``MappingService.flush`` resolves a request to one of these — instead
    of raising and losing every other request in the drain — when its
    reads could not be mapped: every read failed after retries and
    bisection, the request's deadline expired before mapping, or the
    flush itself hit an unexpected error.  ``error_type`` is the stable
    taxonomy key (``"execution"`` | ``"deadline"`` | ``"internal"``);
    ``message`` carries the underlying cause.
    """
    error_type: str
    message: str
    n_reads: int = 0
    attempts: int = 0

    @property
    def ok(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Error isolation for one mapped block (bucket/chunk).

    A failing block is retried ``max_attempts`` times with exponential
    backoff (``backoff_s * backoff_mult**attempt`` seconds between
    attempts; set ``backoff_s=0`` in tests).  A block that exhausts its
    attempts is split in half and each half mapped independently
    (recursively), so a persistent failure — a poisoned read that
    reliably kills its chunk — is quarantined down to a block of at most
    ``bisect_min`` reads while every healthy read still maps.
    ``degrade_after`` consecutive block-level failures step the
    ``DegradeLadder`` (``fused -> compacted`` engine, ``pallas -> jnp``
    backend).
    """
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    bisect_min: int = 16
    degrade_after: int = 2

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts={self.max_attempts!r} must "
                             f"be >= 1")
        if self.backoff_s < 0 or self.backoff_mult < 1.0:
            raise ValueError("backoff_s must be >= 0 and backoff_mult >= 1")
        if self.bisect_min < 1:
            raise ValueError(f"bisect_min={self.bisect_min!r} must be >= 1")
        if self.degrade_after < 1:
            raise ValueError(f"degrade_after={self.degrade_after!r} must "
                             f"be >= 1")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission control at ``MappingService.submit``.

    ``max_pending_reads`` bounds the batcher queue (None = unbounded).
    When a submit would overflow it, ``policy`` decides:

    * ``"block"`` — drain synchronously: the service flushes the pending
      queue first (results are delivered by the *next* ``flush`` call),
      then accepts the request.  Backpressure, no data loss.
    * ``"shed"``  — reject with ``ShedError`` and count it in
      ``totals["shed_requests"]``.  The caller owns the retry.

    ``deadline_s`` is the default per-request deadline (overridable per
    ``submit``): a request still queued when its deadline passes is
    resolved to a ``MappingError("deadline")`` at the next flush instead
    of being mapped, and counted in ``totals["deadline_misses"]``.
    """
    max_pending_reads: int | None = None
    policy: str = "block"
    deadline_s: float | None = None

    POLICIES = ("block", "shed")

    def __post_init__(self):
        if self.policy not in self.POLICIES:
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             f"expected one of {self.POLICIES}")
        if self.max_pending_reads is not None and self.max_pending_reads < 1:
            raise ValueError(f"max_pending_reads="
                             f"{self.max_pending_reads!r} must be >= 1 "
                             f"(or None for unbounded)")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s={self.deadline_s!r} must be > 0 "
                             f"(or None for no deadline)")


class DegradeLadder:
    """Graceful-degradation state: which config variant is active.

    The ladder is derived from the base config at construction —
    ``fused`` engine steps down to ``compacted`` (same results, one host
    sync more, no single-dispatch fusion), then ``pallas`` backend steps
    down to ``jnp`` (the reference implementation the kernels are parity-
    tested against).  ``fail()`` after ``degrade_after`` consecutive
    block failures advances one rung; ``ok()`` resets the failure streak
    but never climbs back up — a session that had to degrade stays
    degraded (sticky), because the condition that broke the fast path is
    usually still there.
    """

    def __init__(self, cfg: MapperConfig, degrade_after: int = 2):
        rungs = [cfg]
        if cfg.engine == "fused":
            rungs.append(dataclasses.replace(cfg, engine="compacted"))
        if rungs[-1].wf_backend == "pallas":
            rungs.append(dataclasses.replace(rungs[-1], wf_backend="jnp"))
        self.rungs = rungs
        self.degrade_after = degrade_after
        self.level = 0
        self.steps = 0            # total rungs descended (for stats)
        self._streak = 0

    @property
    def cfg(self) -> MapperConfig:
        return self.rungs[self.level]

    @property
    def degraded(self) -> bool:
        return self.level > 0

    def ok(self) -> None:
        self._streak = 0

    def fail(self) -> bool:
        """Record a block-level failure; True when this one degraded."""
        self._streak += 1
        if (self._streak >= self.degrade_after
                and self.level + 1 < len(self.rungs)):
            self.level += 1
            self.steps += 1
            self._streak = 0
            return True
        return False

    def describe(self) -> str:
        c = self.cfg
        return f"{c.engine}/{c.wf_backend} (rung {self.level}/" \
               f"{len(self.rungs) - 1})"


class FaultInjector:
    """Deterministic fault injection, one site vocabulary for the stack.

    Sites (each an independent, seed-derived RNG stream, so arming one
    site never perturbs another's fault sequence):

    * ``"bucket"``       — transient failure of a mapped block
      (``ResilientMapper``); retries draw fresh Bernoulli trials, so a
      transient fault clears on retry with probability ``1 - rate``.
    * ``"fetch_stall"``  — the streaming engine's fetch thread sleeps
      ``stall_s`` (exercises the ``watchdog_s`` timeout).
    * ``"fetch_error"``  — the fetch thread raises (exercises prompt
      exception propagation out of ``stream_map``).
    * ``"fastq_record"`` — a parsed FASTQ record is treated as corrupt
      (quarantined under ``on_error="permissive"``).
    * ``"flush"``        — ``MappingService.flush`` fails before
      assembly (exercises the transactional resolve-everything path).

    Beyond the Bernoulli sites, ``poison_rows`` marks absolute read rows
    whose block *always* fails — the bisection-quarantine scenario — and
    ``fail_engines`` names engines/backends that always fail, which is
    how the degradation ladder is driven in tests (``{"fused"}`` breaks
    the fused rung and forces the compacted fallback).

    Determinism: RNG streams are keyed on ``(seed, crc32(site))`` —
    stable across processes and Python hash randomization.  ``fired``
    counts the faults each site actually raised.
    """

    def __init__(self, seed: int = 0, rates: dict | None = None, *,
                 stall_s: float = 0.0, poison_rows=(), fail_engines=()):
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.stall_s = float(stall_s)
        self.poison_rows = frozenset(int(r) for r in poison_rows)
        self.fail_engines = frozenset(fail_engines)
        self.fired: dict[str, int] = {}
        self.checked: dict[str, int] = {}
        self._rngs: dict[str, np.random.Generator] = {}

    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Parse a CLI spec: ``"bucket=0.125,record=0.005,seed=3"``.

        Keys: any site rate (``record`` aliases ``fastq_record``,
        ``stall``/``error`` alias the fetch sites), ``seed``, ``stall_s``
        (the stall duration), ``poison`` (``;``-separated rows) and
        ``engines`` (``;``-separated ``fail_engines``).
        """
        aliases = {"record": "fastq_record", "stall": "fetch_stall",
                   "error": "fetch_error"}
        seed, stall_s, rates, poison, engines = 0, 0.0, {}, (), ()
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"bad --inject entry {part!r}: "
                                 f"expected key=value")
            k, v = part.split("=", 1)
            if k == "seed":
                seed = int(v)
            elif k == "stall_s":
                stall_s = float(v)
            elif k == "poison":
                poison = [int(r) for r in v.split(";") if r]
            elif k == "engines":
                engines = [e for e in v.split(";") if e]
            else:
                rates[aliases.get(k, k)] = float(v)
        return cls(seed, rates, stall_s=stall_s, poison_rows=poison,
                   fail_engines=engines)

    def _rng(self, site: str) -> np.random.Generator:
        rng = self._rngs.get(site)
        if rng is None:
            rng = np.random.default_rng(
                (self.seed, zlib.crc32(site.encode("ascii"))))
            self._rngs[site] = rng
        return rng

    def fire(self, site: str) -> bool:
        """One Bernoulli trial at ``site``'s rate; advances the stream."""
        self.checked[site] = self.checked.get(site, 0) + 1
        rate = self.rates.get(site, 0.0)
        if rate <= 0.0:
            return False
        hit = bool(self._rng(site).random() < rate)
        if hit:
            self.fired[site] = self.fired.get(site, 0) + 1
        return hit

    def check(self, site: str, detail: str = "") -> None:
        if self.fire(site):
            raise InjectedFault(f"injected {site} fault{detail}")

    def check_block(self, lo: int, hi: int, *, engine: str | None = None,
                    backend: str | None = None) -> None:
        """Bucket-site check for the block covering rows ``[lo, hi)``:
        engine-targeted and poisoned-row faults are persistent (they fire
        on every attempt, at every bisection level, so the failure really
        is quarantined, not retried away); the ``bucket`` rate is the
        transient component."""
        for key in (engine, backend):
            if key is not None and key in self.fail_engines:
                self.fired["engine"] = self.fired.get("engine", 0) + 1
                raise InjectedFault(f"injected engine fault: {key!r} is "
                                    f"marked failing")
        rows = self.poisoned_in(lo, hi)
        if rows:
            self.fired["poison"] = self.fired.get("poison", 0) + 1
            raise InjectedFault(f"injected poisoned read(s) {rows} in "
                                f"rows [{lo}, {hi})")
        self.check("bucket", f" (rows [{lo}, {hi}))")

    def poisoned_in(self, lo: int, hi: int) -> list[int]:
        return sorted(r for r in self.poison_rows if lo <= r < hi)

    def sleep(self, site: str) -> None:
        """Stall the calling thread for ``stall_s`` when ``site`` fires
        (the fetch-thread watchdog scenario)."""
        if self.stall_s > 0 and self.fire(site):
            time.sleep(self.stall_s)

    @property
    def armed(self) -> bool:
        return bool(any(r > 0 for r in self.rates.values())
                    or self.poison_rows or self.fail_engines)


@dataclasses.dataclass(frozen=True)
class BlockFailure:
    """A block that exhausted retries and bisection: its reads are
    quarantined (``MappingResult.failed``), not mapped."""
    message: str
    attempts: int


def _zero_counters() -> dict:
    return dict(retries=0, failed_reads=0, failed_blocks=0,
                degraded_steps=0)


def synthesize_block(n: int, template: MappingResult, cfg: MapperConfig,
                     ) -> dict:
    """Per-field placeholder arrays for ``n`` quarantined reads, shaped
    and typed off a healthy ``template`` result from the same run:
    unmapped (``position=-1``, ``distance=sat``), zero candidates, empty
    traceback.  Raw attribute access keeps a lazy template lazy."""
    sat = cfg.sat_affine

    def raw(f):
        return object.__getattribute__(template, f)

    fill = dict(position=-1, distance=sat, distance2=sat, mapped=False,
                strand=0, ops=affine_wf.OP_NONE, op_count=0,
                linear_dist=cfg.eth + 1, n_candidates=0)
    out = {}
    for f, v in fill.items():
        t = raw(f)
        out[f] = (None if t is None
                  else np.full((n,) + t.shape[1:], v, t.dtype))
    lt = raw("lazy_tb")
    out["lazy_tb"] = None if lt is None else LazyTraceback(
        lt.segments, lt.cfg,
        np.zeros((n,) + lt.reads.shape[1:], lt.reads.dtype),
        np.zeros((n,) + lt.occ.shape[1:], lt.occ.dtype),
        np.zeros((n,) + lt.mpos.shape[1:], lt.mpos.dtype),
        np.zeros(n, bool))
    return out


def merge_stats_list(parts: list, counters: dict | None = None,
                     ) -> MapperStats | None:
    """Sum a run's healthy per-segment ``MapperStats`` into one, folding
    the resilience ``counters`` (retries / quarantined reads / degrade
    steps) into the unified schema.  None when no segment carried stats
    (the padded reference engine)."""
    stats = [s for s in parts if isinstance(s, MapperStats)]
    if not stats:
        return None
    first = stats[0]
    num = {}
    for f in ("reads", "candidates", "survivors", "affine_instances",
              "padded_affine_instances", "dropped_send", "dropped_affine",
              "reverse_best"):
        num[f] = sum(getattr(s, f) for s in stats)
    c = counters or {}
    return MapperStats(
        topology=first.topology, engine=first.engine,
        plan_cache_hits=first.plan_cache_hits,
        plan_cache_misses=first.plan_cache_misses,
        retries=c.get("retries", 0),
        failed_reads=c.get("failed_reads", 0),
        extra={**first.extra, "resilience": dict(c)} if c else
        dict(first.extra), **num)


def assemble_segments(segments: list, cfg: MapperConfig,
                      counters: dict | None = None,
                      ) -> tuple[MappingResult | None, np.ndarray]:
    """Stitch resilient block results back into one ``MappingResult``.

    ``segments`` is the ordered ``[(n_rows, MappingResult|BlockFailure)]``
    cover from ``ResilientMapper.map_segments``.  Failed blocks are
    synthesized as unmapped rows (``synthesize_block``) and flagged in
    the returned quarantine mask / ``MappingResult.failed``; ``stats``
    is the merged healthy accounting.  Returns ``(None, all-True mask)``
    when every block failed — the caller decides the error shape (the
    serving layer resolves each request to a ``MappingError``).
    """
    total = sum(n for n, _ in segments)
    mask = np.zeros(total, dtype=bool)
    healthy = [s for _, s in segments if isinstance(s, MappingResult)]
    off = 0
    for n, s in segments:
        if isinstance(s, BlockFailure):
            mask[off : off + n] = True
        off += n
    if not healthy:
        return None, mask
    if len(segments) == 1 and len(healthy) == 1:
        # fast path (the armed-but-idle case): hand the engine result
        # through untouched apart from folding counters into its stats
        res = segments[0][1]
        object.__setattr__(res, "stats",
                           merge_stats_list([res.stats], counters))
        return res, mask
    template = healthy[0]

    chunks: list[dict] = []
    for n, s in segments:
        if isinstance(s, BlockFailure):
            chunks.append(synthesize_block(n, template, cfg))
        else:
            chunks.append({f: object.__getattribute__(s, f)
                           for f in _PER_READ_FIELDS if f != "failed"}
                          | {"lazy_tb": object.__getattribute__(s,
                                                                "lazy_tb")})

    def cat(f):
        arrs = [c[f] for c in chunks]
        if any(a is None for a in arrs):
            return None
        if f == "lazy_tb":
            return LazyTraceback.concat(arrs)
        return arrs[0] if len(arrs) == 1 else np.concatenate(arrs)

    fields = {f: cat(f) for f in _PER_READ_FIELDS if f != "failed"}
    stats = merge_stats_list([s.stats for s in healthy], counters)
    return MappingResult(**fields, failed=mask if mask.any() else None,
                         stats=stats, lazy_tb=cat("lazy_tb")), mask


class ResilientMapper:
    """Retry / bisect / degrade wrapper around a ``Mapper`` session.

    ``map`` and ``map_pairs`` mirror the ``Mapper`` calls but never
    raise for a contained block failure: quarantined reads come back
    unmapped with ``MappingResult.failed`` set, and the per-call
    ``counters`` (retries, failed reads/blocks, degrade steps) ride in
    ``stats.extra["resilience"]``.  ``map_segments`` is the serving
    layer's lower-level entry — it returns the ordered block cover so
    ``MappingService.flush`` can resolve per-request spans.

    With no injector and no faults the wrapper is one ``try`` per block
    — the armed-but-idle overhead the ``resilience_overhead`` benchmark
    gates at <5%.
    """

    def __init__(self, mapper: Mapper, policy: RetryPolicy = RetryPolicy(),
                 injector: FaultInjector | None = None):
        self.mapper = mapper
        self.policy = policy
        self.injector = injector
        self.ladder = DegradeLadder(mapper.cfg,
                                    degrade_after=policy.degrade_after)
        self.counters = _zero_counters()    # session-cumulative
        self._fallbacks: dict[int, Mapper] = {}

    @property
    def cfg(self) -> MapperConfig:
        return self.ladder.cfg

    def _mapper_at(self, level: int) -> Mapper:
        if level == 0:
            return self.mapper
        m = self._fallbacks.get(level)
        if m is None:
            base = self.mapper
            cfg = self.ladder.rungs[level]
            if base.topology == "mesh":
                m = Mapper(base.sharded_index, cfg, topology="mesh",
                           mesh=base.mesh, send_cap=base.send_cap,
                           injector=base.injector,
                           watchdog_s=base.watchdog_s)
            else:
                m = Mapper(base.index, cfg, injector=base.injector,
                           watchdog_s=base.watchdog_s)
            self._fallbacks[level] = m
        return m

    # ------------------------------------------------------------- mapping

    def map_segments(self, reads: np.ndarray, *, chunk: int | None = None,
                     plan_n: int | None = None, base: int = 0,
                     counters: dict | None = None) -> tuple[list, dict]:
        """Map ``reads`` with containment; -> ``(segments, counters)``.

        ``segments`` is an ordered ``[(n_rows, MappingResult |
        BlockFailure)]`` cover of the input.  ``chunk`` is forwarded to
        the plan (the serving layer's streamed full-bucket runs) and
        ``plan_n`` overrides the planned batch size (the serving layer's
        mesh buckets plan at bucket size so same-size buckets share one
        compiled program); halves created by bisection re-plan at their
        own size.  ``base`` is the absolute row offset of ``reads[0]`` —
        the coordinate the injector's ``poison_rows`` are expressed in.
        """
        counters = counters if counters is not None else _zero_counters()
        n = len(reads)
        if n == 0:
            return [], counters
        pol = self.policy
        last_exc: BaseException | None = None
        attempts = 0
        while attempts < pol.max_attempts:
            m = self._mapper_at(self.ladder.level)
            try:
                if self.injector is not None:
                    self.injector.check_block(base, base + n,
                                              engine=m.cfg.engine,
                                              backend=m.cfg.wf_backend)
                res = m.run(m.plan(plan_n if plan_n is not None else n,
                                   chunk=chunk), reads)
                if len(res.position) != n:
                    raise RuntimeError(
                        f"engine returned {len(res.position)} rows for "
                        f"{n} reads")
                self.ladder.ok()
                return [(n, res)], counters
            except Exception as e:  # noqa: BLE001 — containment boundary
                last_exc = e
                attempts += 1
                if attempts < pol.max_attempts:
                    counters["retries"] += 1
                    self.counters["retries"] += 1
                    _obs_inc("repro_retries_total")
                    if pol.backoff_s > 0:
                        time.sleep(pol.backoff_s
                                   * pol.backoff_mult ** (attempts - 1))
        if self.ladder.fail():
            counters["degraded_steps"] += 1
            self.counters["degraded_steps"] += 1
            _obs_inc("repro_degradations_total")
        if n > max(pol.bisect_min, 1):
            # quarantine by bisection: each half retries independently,
            # so the poisoned half shrinks while the healthy half maps
            _obs_inc("repro_bisections_total")
            mid = n // 2
            left, _ = self.map_segments(reads[:mid], base=base,
                                        counters=counters)
            right, _ = self.map_segments(reads[mid:], base=base + mid,
                                         counters=counters)
            return left + right, counters
        counters["failed_reads"] += n
        counters["failed_blocks"] += 1
        self.counters["failed_reads"] += n
        self.counters["failed_blocks"] += 1
        _obs_inc("repro_quarantined_reads_total", n)
        _obs_inc("repro_failed_blocks_total")
        msg = f"{type(last_exc).__name__}: {last_exc}"
        return [(n, BlockFailure(message=msg, attempts=attempts))], counters

    def map(self, reads: np.ndarray) -> tuple[MappingResult | None,
                                              np.ndarray, dict]:
        """Plan + run with containment -> ``(result, failed_mask,
        counters)``.  ``result`` is None only when *every* block failed
        (the mask is then all-True)."""
        reads = np.asarray(reads)
        segments, counters = self.map_segments(reads)
        res, mask = assemble_segments(segments, self.cfg, counters)
        return res, mask, counters

    def map_pairs(self, reads1: np.ndarray, reads2: np.ndarray):
        """Paired twin of ``Mapper.map_pairs``: one stacked resilient
        batch, split back per mate -> ``(res1, res2, counters)`` (None
        results when everything failed; the ``failed`` masks split with
        the other per-read fields)."""
        from .mapper import split_result
        reads1, reads2 = np.asarray(reads1), np.asarray(reads2)
        if reads1.shape != reads2.shape:
            raise ValueError(f"mate batches must align pairwise: "
                             f"{reads1.shape} vs {reads2.shape}")
        res, mask, counters = self.map(np.concatenate([reads1, reads2]))
        if res is None:
            return None, None, counters
        r1, r2 = split_result(res, len(reads1))
        return r1, r2, counters
