"""Unified ``Mapper`` session API — one planner/executor front-end.

DART-PIM's controller hierarchy (paper Sec. V, Fig. 6) is a *single*
planned dataflow from indexing to the final reduce: the main controller
owns data placement, batch routing, and stage dispatch, and the crossbar
controllers merely execute what was planned.  The repo's execution paths
mirror that split here:

  ``Mapper(index, cfg)``   — the session object.  It owns the device
      placement of the (possibly sharded) index, a **plan cache** of
      pre-built per-bucket/per-chunk executables, and the running
      plan-cache hit/miss counters.
  ``Mapper.plan(spec)``    — the planning layer: returns a ``MappingPlan``
      describing exactly what a ``run`` would execute (chunk sizes, lane
      capacities, shard routing, send/survivor capacities) *before* any
      compute is dispatched.
  ``Mapper.run(plan, r)``  — the executor: runs reads through the plan's
      cached executable.
  ``Mapper.map(reads)``    — plan + run in one call.
  ``Mapper.map_async(r)``  — same, as a ``concurrent.futures.Future``
      (submissions execute in order on a session worker thread, each one
      driving the async double-buffered streaming engine internally).
  ``Mapper.serve()``       — a ``MappingService`` request batcher wired to
      this session.

``topology=`` selects the back-end behind the same result schema:

  ``"single"`` — the single-shard pipeline of ``repro.core.pipeline``
      (padded or candidate-compacted engine, streamed chunks).
  ``"mesh"``   — the distributed all_to_all mapper of
      ``repro.core.distributed`` over a flat device mesh.  Reads are
      zero-padded up to a shard multiple and results trimmed back, so
      arbitrary batch sizes work; stage B never tracebacks, so the
      traceback fields of ``MappingResult`` are ``None`` on this path.

``MapperConfig.both_strands`` makes strand a planning dimension on both
topologies: plans are sized for the forward + reverse-complement
encodings of every read (2n), the engine executes them as one stacked
batch, and the per-read winner — lower affine distance, ties keep
forward — is reduced host-side into ``MappingResult.strand`` /
``MapperStats.reverse_best`` (see ``repro.io`` for the FASTQ/SAM
boundary this feeds).

Every run reports a unified ``MapperStats`` (replacing the old divergent
``stats`` dict vs ``with_stats=True`` tuple shapes).  ``MapperStats`` is
dict-compatible (``stats["survivors"]``) for the legacy per-path keys and
additionally exposes the unified fields as attributes, including the
session's plan-cache hit counters — the observable for "no recompiles
after warm-up" assertions.

The old free functions ``pipeline.map_reads`` and
``distributed.distributed_map_reads`` remain as thin deprecation shims
that forward here and stay bit-identical.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import registry as _metrics
from ..obs import tracing as _tracing
from . import streaming
from .distributed import (AXIS, ShardedIndex, _cached_mapper, shard_index,
                          stage_b_affine_capacity)
from .encoding import revcomp
from .index import GenomeIndex, device_position_dtype
from .pipeline import (LazyTraceback, MapperConfig, MappingResult,
                       _ChunkPipeline, _merge_stats, map_reads_jax)

TOPOLOGIES = ("single", "mesh")

__all__ = ["Mapper", "MapperStats", "MappingPlan", "TOPOLOGIES",
           "accumulate_partition_stats", "split_result",
           "totals_from_registry"]


_PER_READ_FIELDS = ("position", "distance", "distance2", "mapped", "strand",
                    "ops", "op_count", "linear_dist", "n_candidates",
                    "failed")


def split_result(res: MappingResult, n: int,
                 ) -> tuple[MappingResult, MappingResult]:
    """Split one stacked ``MappingResult`` into ``(first n, rest)``.

    The paired-end path maps both mates as one stacked batch (R1 rows
    then R2 rows — one plan, one engine dispatch, shared chunking) and
    splits here.  Both halves share the run's ``stats`` object (its
    ``reads`` counts the full stacked batch).  Raw attribute access keeps
    a ``cigar_mode="lazy"`` result lazy: the pending traceback holder is
    sliced, not materialized.
    """
    lt = object.__getattribute__(res, "lazy_tb")

    def half(lo, hi):
        def raw(f):
            v = object.__getattribute__(res, f)
            return v[lo:hi] if v is not None else None
        return MappingResult(**{f: raw(f) for f in _PER_READ_FIELDS},
                             stats=res.stats,
                             lazy_tb=lt[lo:hi] if lt is not None else None)
    return half(0, n), half(n, len(res.position))


@dataclasses.dataclass
class MapperStats:
    """Unified per-run statistics schema shared by every topology.

    The named fields are the topology-independent accounting (what was
    seeded, what survived the filter, what the affine stage actually
    executed, what fixed-capacity buffers dropped) plus the session's
    cumulative plan-cache counters at the time of the run.  ``extra``
    carries the legacy per-path keys (``candidates_valid`` /
    ``stage_times_s`` on the single-shard path, ``stage_b_*`` /
    ``send_dropped`` on the mesh path) and backs the dict-style access
    (``stats["survivors"]``, ``dict(stats)``) the pre-``Mapper`` API
    exposed.
    """
    topology: str
    engine: str
    reads: int                     # real reads mapped (padding excluded)
    candidates: int                # seeded candidates / stage-B entries
    survivors: int                 # filter survivors admitted to affine
    affine_instances: int          # affine WF instances actually executed
    padded_affine_instances: int   # what the padded reference would run
    dropped_send: int = 0          # mesh: send-FIFO overflow drops
    dropped_affine: int = 0        # mesh: survivor-capacity overflow drops
    reverse_best: int = 0          # dual-strand runs: reads whose best
    #                                alignment used the reverse complement
    plan_cache_hits: int = 0       # session cumulative, sampled at run time
    plan_cache_misses: int = 0
    retries: int = 0               # resilience: block retries this run
    failed_reads: int = 0          # resilience: reads quarantined this run
    extra: dict = dataclasses.field(default_factory=dict)

    # -- dict-compatibility with the legacy stats shapes ------------------
    def __getitem__(self, key):
        return self.extra[key]

    def __contains__(self, key):
        return key in self.extra

    def get(self, key, default=None):
        return self.extra.get(key, default)

    def keys(self):
        return self.extra.keys()

    def as_dict(self) -> dict:
        return dict(self.extra)


_PART_SUM_KEYS = ("chunks_routed", "partition_loads", "partition_evictions",
                  "partition_compactions",
                  "h2d_bytes", "prefetch_loads", "prefetch_hits",
                  "minis_routed_per_partition",
                  "minis_found_per_partition", "survivors_per_partition")


def accumulate_partition_stats(totals: dict, stats) -> dict:
    """Merge a run's per-partition accounting (``stats["partitions"]``,
    present on sharded-index sessions, single and mesh) into
    ``totals["partitions"]``.  Counters and per-partition count vectors
    sum across runs; static descriptors (arena size, occurrence layout,
    current residency) take the latest run's value."""
    if not isinstance(stats, MapperStats):
        return totals
    part = stats.get("partitions")
    if not part:
        return totals
    acc = totals.setdefault("partitions", {})
    for k, v in part.items():
        if k in _PART_SUM_KEYS:
            if isinstance(v, list):
                prev = acc.get(k)
                acc[k] = ([a + b for a, b in zip(prev, v)] if prev
                          else list(v))
            else:
                acc[k] = acc.get(k, 0) + v
        else:
            acc[k] = v
    return totals


def accumulate_stats(totals: dict, stats, fields=None) -> dict:
    """Sum ``MapperStats`` fields into a running ``totals`` dict — the
    one home for the per-batch accumulation loop used by the serving
    layer and the launchers.  ``fields`` defaults to ``totals``'s own
    keys; a non-``MapperStats`` stats (padded engine: None) is a no-op.
    """
    if isinstance(stats, MapperStats):
        for k in (fields if fields is not None else tuple(totals)):
            totals[k] = totals.get(k, 0) + getattr(stats, k)
    return totals


# MapperStats fields mirrored into the metrics registry per run, and the
# fields ``totals_from_registry`` re-derives — keep the two in lockstep
# so registry-sourced closing stats byte-match the legacy accumulation
_METRIC_RUN_FIELDS = ("reads", "candidates", "survivors",
                      "affine_instances", "padded_affine_instances",
                      "dropped_send", "dropped_affine", "reverse_best")


def _record_run_metrics(stats: MapperStats) -> None:
    """Mirror one run's ``MapperStats`` into the active registry (no-op
    when metrics are disabled).  Summing these counters across runs is
    exactly ``accumulate_stats`` over the same fields, which is what
    lets the launchers re-emit their closing stats from the registry."""
    reg = _metrics.ACTIVE
    if reg is None:
        return
    lab = dict(topology=stats.topology)
    reg.counter("repro_runs_total", **lab).inc()
    for f in _METRIC_RUN_FIELDS:
        v = int(getattr(stats, f))
        if v:
            reg.counter(f"repro_{f}_total", **lab).inc(v)


def totals_from_registry(topology: str, reg=None) -> dict | None:
    """The engine-accounting totals dict re-derived from the metrics
    registry (None when metrics are disabled).  With a clean run this
    byte-matches the ``accumulate_stats`` path (property-tested); under
    faults the registry is the truthful one — it counts every engine
    run including retried and bisected blocks."""
    reg = reg if reg is not None else _metrics.ACTIVE
    if reg is None:
        return None
    return {f: reg.counter(f"repro_{f}_total", topology=topology).value
            for f in _METRIC_RUN_FIELDS}


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    """What a ``Mapper.run`` will execute, decided before any dispatch.

    Single topology: ``chunk`` is the static chunk quantum (the jit shape
    every chunk is padded to), ``chunk_sizes`` the real per-chunk read
    counts, and ``lin_cap_max``/``aff_cap_max`` the ceilings the measured
    per-chunk bucket capacities are clamped to (the capacities themselves
    are data-dependent and picked host-side between the jitted stages).

    Mesh topology: ``padded_reads`` is the global batch shape (reads are
    zero-padded up to a multiple of ``n_shards``), ``send_cap`` the
    per-destination send-FIFO capacity of the all_to_all exchange, and
    ``stage_b_affine_cap`` the negotiated per-shard survivor capacity the
    compiled stage B executes.
    """
    topology: str
    engine: str
    n_reads: int                   # batch size the plan was sized for
    chunk: int                     # single: chunk quantum; mesh: padded R
    chunk_sizes: tuple             # single: per-chunk real read counts
    lin_cap_max: int = 0
    aff_cap_max: int = 0
    n_shards: int = 1
    send_cap: int = 0
    stage_b_affine_cap: int = 0
    padded_reads: int = 0
    both_strands: bool = False     # engine executes 2*n_reads encodings
    #                                (forward + reverse complement); results
    #                                are strand-reduced back to n_reads

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_sizes)

    @property
    def key(self) -> tuple:
        """Plan-cache key: plans sharing a key share one executable (and
        therefore its compiled programs — equal keys cannot recompile).
        The mesh key includes the negotiated stage-B survivor capacity:
        static configs derive it deterministically from (batch, send_cap)
        so repeated plans still hit, while ``stage_b_adaptive`` sessions
        recompile exactly when the provisioned capacity moves."""
        if self.topology == "mesh":
            return ("mesh", self.padded_reads, self.send_cap,
                    self.stage_b_affine_cap)
        if self.engine == "padded":
            return ("single", "padded", self.n_reads)
        return ("single", self.engine, self.chunk)


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions: >= 0.5 takes explicit Auto
    axis types; older releases have implicitly-auto axes only.  The single
    home of this shim — ``launch.mesh`` builds its meshes through it."""
    try:
        from jax.sharding import AxisType
    except ImportError:  # jax < 0.5
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def _flat_mesh(n_shards: int | None):
    """Default flat shard mesh (``launch.mesh.make_genomics_mesh`` without
    the core->launch dependency)."""
    n = n_shards or len(jax.devices())
    return make_mesh_compat((n,), (AXIS,))


def _host_positions(pos):
    """Result-boundary position dtype: unsigned device positions (the
    uint32 arena of references past 2^31 without x64) become int64 with
    the all-ones BIG sentinel rewritten to the public -1.  Keyed on the
    sentinel value itself, not ``mapped`` — the two can disagree on
    degenerate candidates and -1 must mean exactly "no position won"."""
    if pos is None or pos.dtype.kind != "u":
        return pos
    big = np.iinfo(pos.dtype).max
    out = pos.astype(np.int64)
    out[pos == big] = -1
    return out


def _reduce_strands(res: MappingResult, n: int) -> MappingResult:
    """Fold a stacked fwd-then-rc result of 2n reads to the per-read best.

    The winner is the strand with the smaller affine-WF distance; ties
    (including both-unmapped) keep the forward strand, so single-strand
    workloads are bit-identical with or without ``both_strands``.  Every
    per-read field (traceback ops included) follows the winner, and the
    stats are re-expressed over the n real reads.
    """
    rev_wins = res.distance[n:] < res.distance[:n]

    def pick(a):
        if a is None:
            return None
        m = rev_wins.reshape((-1,) + (1,) * (a.ndim - 1))
        return np.where(m, a[n:], a[:n])

    mapped = pick(res.mapped)
    stats = res.stats
    if isinstance(stats, MapperStats):
        stats = dataclasses.replace(
            stats, reads=n, reverse_best=int(np.sum(rev_wins & mapped)),
            extra={**stats.extra, "both_strands": True})
    # the runner-up across both strands: the winner strand's own second
    # locus, or the loser strand's best alignment — whichever is closer.
    # (An opposite-strand hit is a genuinely competing alignment even at
    # the same locus, so no distance-to-winner exclusion applies here.)
    d2 = None
    if res.distance2 is not None:
        lose_d1 = np.where(rev_wins, res.distance[:n], res.distance[n:])
        d2 = np.minimum(pick(res.distance2), lose_d1).astype(
            res.distance2.dtype)
    return MappingResult(
        position=pick(res.position), distance=pick(res.distance),
        distance2=d2, mapped=mapped, strand=rev_wins.astype(np.int8),
        ops=pick(res.ops), op_count=pick(res.op_count),
        linear_dist=pick(res.linear_dist),
        n_candidates=pick(res.n_candidates), stats=stats)


class Mapper:
    """Read-mapping session: placed index + plan cache + executor.

    Parameters
    ----------
    index : GenomeIndex | ShardedIndex
        The reference index.  ``topology="mesh"`` accepts either — a
        ``GenomeIndex`` is sharded across the mesh on construction.
    cfg : MapperConfig, optional
        Defaults to ``MapperConfig.from_index(index)``.
    topology : "single" | "mesh"
        Back-end selection; see the module docstring.
    mesh : jax mesh, optional
        Mesh topology only.  Defaults to a flat mesh over ``n_shards``
        devices (all local devices when ``n_shards`` is None).
    n_shards, send_cap : int, optional
        Mesh topology only: shard count for the default mesh, and a fixed
        send-FIFO capacity (default: scaled from each plan's batch size).
    injector : FaultInjector, optional
        Chaos hook threaded into the streaming engine's fetch thread
        (``core.resilience``).  Runtime state, deliberately NOT part of
        ``MapperConfig`` — the config is a static jit argument and must
        stay hashable/value-comparable.
    watchdog_s : float, optional
        Streaming fetch watchdog: a chunk fetch exceeding this wall time
        raises ``streaming.FetchStallError`` instead of hanging the
        session.  None (default) disables the bound.
    memory_budget_bytes : int, optional
        Single topology with a ``repro.index.ShardedGenomeIndex`` only:
        device budget for the partition arena.  Partitions are loaded
        lazily per chunk and LRU-evicted under this bound
        (``repro.index.residency``).  None keeps every partition
        resident (the budget is the full index).
    prefetch : bool, optional
        Shard-routed single topology only: stage the next chunk's host
        seeding and partition uploads on a background worker while the
        current chunk computes (``repro.index.residency``).  Results are
        bit-identical to synchronous loading; only streamed runs
        (``cfg.stream=True``) actually overlap.

    Both topologies also accept a ``repro.index.ShardedGenomeIndex``:
    on ``"single"`` chunks are shard-routed through the residency arena;
    on ``"mesh"`` partition *i* is placed on shard *i* directly — the
    on-disk partitioning IS the mesh placement, no runtime re-hashing.
    """

    def __init__(self, index, cfg: MapperConfig | None = None, *,
                 topology: str = "single", mesh=None,
                 n_shards: int | None = None, send_cap: int | None = None,
                 injector=None, watchdog_s: float | None = None,
                 memory_budget_bytes: int | None = None,
                 prefetch: bool = False):
        if topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {topology!r}; "
                             f"expected one of {TOPOLOGIES}")
        if watchdog_s is not None and watchdog_s <= 0:
            raise ValueError(f"watchdog_s={watchdog_s!r} must be > 0 "
                             f"(or None to disable)")
        self.cfg = cfg or MapperConfig.from_index(index)
        self.topology = topology
        self.send_cap = send_cap
        self.injector = injector
        self.watchdog_s = watchdog_s
        self._plan_cache: dict[tuple, object] = {}
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        self._pool: ThreadPoolExecutor | None = None
        # rolling per-run stage-B survivor fractions (survivors / bucket
        # entries), fed by _run_mesh; drives adaptive capacity planning
        from collections import deque
        self._survivor_hist = deque(maxlen=self.cfg.stage_b_history)

        from ..index.sharded import ShardedGenomeIndex
        self.part_index = index if isinstance(index, ShardedGenomeIndex) \
            else None
        self.router = None
        if memory_budget_bytes is not None and not (
                topology == "single" and self.part_index is not None):
            raise ValueError(
                "memory_budget_bytes only applies to topology=\"single\" "
                "with a repro.index.ShardedGenomeIndex — the mesh topology "
                "places one whole partition per device, and a flat "
                "GenomeIndex is always fully resident")
        self.prefetch = bool(prefetch)
        if self.prefetch and not (topology == "single"
                                  and self.part_index is not None):
            raise ValueError(
                "prefetch=True only applies to topology=\"single\" with a "
                "repro.index.ShardedGenomeIndex — only the shard-routed "
                "arena path has per-chunk partition uploads to overlap")

        if topology == "single":
            if isinstance(index, ShardedIndex):
                raise ValueError('topology="single" needs a GenomeIndex, '
                                 "not a ShardedIndex")
            self.sharded_index = None
            self.mesh = None
            if self.part_index is not None:
                if self.cfg.engine == "padded":
                    raise ValueError(
                        'engine="padded" needs the whole index resident as '
                        "one flat array; use the compacted/fused engines "
                        "with a ShardedGenomeIndex, or "
                        "index.to_genome_index() to flatten it")
                if self.cfg.cigar_mode == "lazy":
                    raise ValueError(
                        'cigar_mode="lazy" defers traceback past the run, '
                        "but the residency arena may evict the segment "
                        "rows a deferred traceback would read; use "
                        'cigar_mode="eager" or "off" with a '
                        "ShardedGenomeIndex")
                from ..index.residency import DeviceResidency, ShardRouter
                self.index = None
                self._dev = None
                self.router = ShardRouter(
                    index, DeviceResidency(index, memory_budget_bytes),
                    self.cfg)
            else:
                self.index = index
                # dtype-explicit uploads: jnp.asarray silently narrows
                # int64 to int32 when x64 is off, which would wrap
                # format-v2 positions past 2^31.  device_position_dtype
                # picks what the device can hold (uint32 covers GRCh38);
                # occ_idx rows are int32 everywhere, so > 2^31 occurrence
                # rows in one flat device index is structurally out.
                pos = np.asarray(index.positions)
                max_pos = int(pos.max()) if len(pos) else 0
                pdt = device_position_dtype(max_pos + 1)
                offs = np.asarray(index.offsets)
                if len(pos) > np.iinfo(np.int32).max:
                    raise ValueError(
                        f"flat index has {len(pos)} occurrence rows, past "
                        f"int32 occ_idx addressing; use a "
                        f"ShardedGenomeIndex (partition-local rows)")
                self._dev = (jnp.asarray(index.uniq_kmers),
                             jnp.asarray(offs.astype(np.int32)),
                             jnp.asarray(pos.astype(pdt)),
                             jnp.asarray(index.segments))
        else:
            self.mesh = mesh if mesh is not None else _flat_mesh(n_shards)
            S = int(self.mesh.devices.size)
            if self.part_index is not None:
                if index.num_partitions != S:
                    raise ValueError(
                        f"sharded index has {index.num_partitions} "
                        f"partitions but the mesh has {S} devices — mesh "
                        f"placement maps partition i onto shard i, so "
                        f"rebuild the index with num_partitions={S} or "
                        f"map over a {index.num_partitions}-device mesh")
                sidx = index.to_mesh_shards()
                self.index = None
            elif isinstance(index, ShardedIndex):
                if index.n_shards != S:
                    raise ValueError(
                        f"ShardedIndex has {index.n_shards} shards but the "
                        f"mesh has {S} devices")
                sidx = index
                self.index = None
            else:
                sidx = shard_index(index, S)
                self.index = index
            self.sharded_index = sidx
            self._dev = sidx.device_arrays()

    # ------------------------------------------------------------- planning

    def plan(self, reads_spec, *, chunk: int | None = None,
             send_cap: int | None = None) -> MappingPlan:
        """Build the execution plan for a batch (no compute dispatched).

        ``reads_spec`` is a read count or a reads array.  ``chunk``
        overrides ``cfg.chunk_reads`` for this plan (single topology);
        ``send_cap`` overrides the session / derived send capacity (mesh).
        Inspect the returned ``MappingPlan`` for the chosen chunking,
        capacities and shard routing; pass it to :meth:`run` to execute.
        """
        n = (int(reads_spec) if isinstance(reads_spec, (int, np.integer))
             else len(reads_spec))
        cfg = self.cfg
        # with both_strands the engine maps forward + reverse-complement
        # encodings of every read: capacities/chunking are sized for the
        # effective 2n batch, the strand reduce trims back to n
        bs = cfg.both_strands
        eff = 2 * n if bs else n
        if self.topology == "mesh":
            S = self.sharded_index.n_shards
            padded = max(-(-eff // S) * S, S)
            sc = send_cap or self.send_cap or \
                max(2 * (padded // S) * cfg.max_minis // S, 8)
            return MappingPlan(
                topology="mesh", engine=cfg.engine, n_reads=n,
                chunk=padded, chunk_sizes=(eff,), n_shards=S, send_cap=sc,
                stage_b_affine_cap=stage_b_affine_capacity(
                    S * sc, cfg, frac=self._stage_b_frac()),
                padded_reads=padded, both_strands=bs)
        if cfg.engine == "padded":
            return MappingPlan(topology="single", engine="padded", n_reads=n,
                               chunk=max(eff, 1), chunk_sizes=(eff,),
                               both_strands=bs)
        # compacted/fused engines chunk over the n *reads*: each chunk
        # carries its own forward + reverse-complement rows and reduces
        # them on device, so capacities are sized for 2*chunk rows while
        # the chunk schedule (and every fetched array) stays per-read
        c = chunk or cfg.chunk_reads or max(n, 1)
        sizes = tuple(min(c, n - i) for i in range(0, n, c))
        rows = 2 * c if bs else c
        return MappingPlan(topology="single", engine=cfg.engine, n_reads=n,
                           chunk=c, chunk_sizes=sizes,
                           lin_cap_max=rows * cfg.max_minis * cfg.max_pls,
                           aff_cap_max=rows * cfg.max_minis, both_strands=bs)

    def _stage_b_frac(self) -> float | None:
        """Adaptive stage-B provisioning fraction, or None for the static
        ``cfg.stage_b_survivor_frac``.  Uses the session's rolling
        quantile of observed survivor fractions with 25% headroom — a
        workload that filters harder than provisioned shrinks the
        compiled affine pass, one that stops filtering grows it instead
        of silently dropping survivors."""
        if not self.cfg.stage_b_adaptive or not self._survivor_hist:
            return None
        q = float(np.quantile(np.asarray(self._survivor_hist),
                              self.cfg.stage_b_quantile))
        return min(q * 1.25, 1.0)

    def _executable(self, plan: MappingPlan):
        """Plan-cache lookup (counting hits/misses), building on miss.

        Cache entries are the per-plan executables: the chunk pipeline of
        the compacted engine, the jitted padded reference, or the
        compiled ``shard_map`` program + negotiated survivor capacity of
        the mesh mapper.  Repeated same-key plans therefore reuse the
        exact compiled programs — a cache hit cannot recompile.
        """
        reg = _metrics.ACTIVE
        entry = self._plan_cache.get(plan.key)
        if entry is not None:
            self.plan_cache_hits += 1
            if reg is not None:
                reg.counter("repro_plan_cache_hits_total",
                            topology=self.topology).inc()
            return entry
        self.plan_cache_misses += 1
        if reg is not None:
            reg.counter("repro_plan_cache_misses_total",
                        topology=self.topology).inc()
        if plan.topology == "mesh":
            entry = _cached_mapper(self.mesh, self.cfg, plan.n_shards,
                                   plan.send_cap, plan.stage_b_affine_cap)
        elif plan.engine == "padded":
            entry = map_reads_jax
        elif self.router is not None:
            from ..index.residency import _RoutedChunkPipeline
            entry = _RoutedChunkPipeline(self.router, self.cfg,
                                         prefetch=self.prefetch)
        else:
            entry = _ChunkPipeline(self._dev, self.cfg)
        self._plan_cache[plan.key] = entry
        return entry

    # ------------------------------------------------------------ execution

    def map(self, reads: np.ndarray) -> MappingResult:
        """Plan + run one read batch; the single public mapping call."""
        reads = np.asarray(reads)
        return self.run(self.plan(len(reads)), reads)

    def map_pairs(self, reads1: np.ndarray, reads2: np.ndarray,
                  ) -> tuple[MappingResult, MappingResult]:
        """Map both mates of a paired batch in ONE stacked engine batch.

        ``reads1[i]`` and ``reads2[i]`` are the R1/R2 mates of pair
        ``i``, each in as-sequenced orientation (both_strands handles
        orientation per mate).  The stack shares a single plan — same
        chunking, same capacities, one strand reduce — and is split back
        into per-mate results, so pairing never forks the execution
        path.  Host-side pair resolution (proper pairs, rescue, MAPQ)
        lives in ``repro.core.pairing``.
        """
        reads1, reads2 = np.asarray(reads1), np.asarray(reads2)
        if reads1.shape != reads2.shape:
            raise ValueError(f"mate batches must align pairwise: "
                             f"{reads1.shape} vs {reads2.shape}")
        res = self.map(np.concatenate([reads1, reads2]))
        return split_result(res, len(reads1))

    def map_async(self, reads: np.ndarray) -> Future:
        """Submit a batch to the session worker thread; returns a Future
        of the ``MappingResult``.  Submissions execute in order, each one
        driving the double-buffered streaming engine internally, so the
        caller overlaps its own work (e.g. preparing the next batch) with
        the full mapping pipeline of this one."""
        reads = np.asarray(reads)
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="mapper-session")
        return self._pool.submit(self.map, reads)

    def serve(self, batcher=None, **kwargs):
        """A ``MappingService`` request batcher wired to this session.
        ``kwargs`` forward to ``MappingService`` (``admission=``,
        ``retry=``, ``injector=``)."""
        from .serving import BatcherConfig, MappingService
        return MappingService(self, batcher=batcher or BatcherConfig(),
                              **kwargs)

    def index_storage(self) -> dict | None:
        """Footprint accounting of the session's index — the flat
        ``storage_bytes`` dict, or the sharded one with its
        ``per_partition`` breakdown (``repro.index``).  None when the
        session holds only pre-placed device shards (a raw
        ``ShardedIndex``) with no host-side source index."""
        src = self.part_index if self.part_index is not None else self.index
        if src is None:
            return None
        return src.storage_bytes()

    def close(self):
        """Shut down the ``map_async`` worker (no-op if never used)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def run(self, plan: MappingPlan, reads: np.ndarray) -> MappingResult:
        """Execute ``reads`` through ``plan``'s cached executable.

        ``len(reads)`` may be smaller than the plan's batch size (the
        serving path reuses one bucket-sized plan for a shorter residue):
        reads are padded to the plan's static shape and results trimmed.

        On a ``both_strands`` plan the engine executes the forward and
        reverse-complement encodings of every read.  The compacted/fused
        engines stack the two encodings *per chunk* and reduce the
        per-read winner on device before anything is fetched (see
        ``pipeline._strand_stage``); the padded reference and the mesh
        topology run one stacked fwd-then-rc batch and reduce host-side
        (``_reduce_strands``).  Either way: lower distance wins, ties
        prefer the forward strand — bit-identical results.
        """
        reads = np.asarray(reads)
        if plan.both_strands and (plan.topology == "mesh"
                                  or plan.engine == "padded"):
            n_real = len(reads)
            reads = np.concatenate([reads, revcomp(reads)])
            res = self._run_strand(plan, reads)
            return _reduce_strands(res, n_real)
        return self._run_strand(plan, reads)

    def _run_strand(self, plan: MappingPlan, reads: np.ndarray,
                    ) -> MappingResult:
        n = len(reads)
        entry = self._executable(plan)
        if plan.topology == "mesh":
            return self._run_mesh(plan, entry, reads, n)
        if plan.engine == "padded":
            out = entry(*self._dev, jnp.asarray(reads), self.cfg)
            return MappingResult(
                position=_host_positions(np.asarray(out["position"])),
                distance=np.asarray(out["distance"]),
                distance2=np.asarray(out["distance2"]),
                mapped=np.asarray(out["mapped"]),
                ops=np.asarray(out["ops"]),
                op_count=np.asarray(out["op_count"]),
                linear_dist=np.asarray(out["linear_dist"]),
                n_candidates=np.asarray(out["n_candidates"]), stats=None)
        return self._run_chunks(plan, entry, reads, n)

    def _run_chunks(self, plan: MappingPlan, pipe: _ChunkPipeline,
                    reads: np.ndarray, n: int) -> MappingResult:
        cfg = self.cfg
        items = [(reads[c0 : c0 + plan.chunk], plan.chunk)
                 for c0 in range(0, n, plan.chunk)]
        pipe.begin_run(items)
        if cfg.stream:
            times = {} if cfg.profile else None
            fetched = streaming.stream_map(items, pipe.phase1, pipe.phase2,
                                           pipe.fetch, times=times,
                                           injector=self.injector,
                                           watchdog_s=self.watchdog_s)
        else:
            times = {}
            fetched = streaming.sync_map(items, pipe.phase1, pipe.phase2,
                                         pipe.fetch, times=times)
        parts = [out for out, _ in fetched]
        raw = _merge_stats([st for _, st in fetched])
        raw["stream"] = cfg.stream
        if cfg.both_strands:
            raw["both_strands"] = True
        if times is not None:
            # full precision: stage times feed a 5 ms-noise-floor CI gate
            # and the trace-agreement check; rounding happens only at
            # display/serialization (benchmarks, logs)
            raw["stage_times_s"] = dict(times)
        if getattr(pipe, "router", None) is not None:
            raw["partitions"] = pipe.router.drain_stats()

        def cat(k):
            if k not in parts[0]:
                return None
            if len(parts) > 1:  # concatenate copies -> always writable
                return np.concatenate([np.asarray(p[k]) for p in parts])
            a = np.asarray(parts[0][k])
            # a single chunk's fetch is a zero-copy read-only view of the
            # device buffer; results are caller-owned, so hand out a
            # writable copy (callers mutate e.g. `mapped` in pair rescue)
            return a if a.flags.writeable else a.copy()

        mapped = cat("mapped")
        lazy = None
        if cfg.cigar_mode == "lazy":
            lazy = LazyTraceback(self._dev[3], cfg, cat("_tb_reads"),
                                 cat("_tb_occ"), cat("_tb_mpos"), mapped)
        stats = MapperStats(
            topology="single", engine=cfg.engine, reads=n,
            candidates=raw["candidates_valid"], survivors=raw["survivors"],
            affine_instances=raw["affine_dist_instances"],
            padded_affine_instances=raw["padded_affine_instances"],
            reverse_best=raw.get("reverse_best", 0),
            plan_cache_hits=self.plan_cache_hits,
            plan_cache_misses=self.plan_cache_misses, extra=raw)
        _record_run_metrics(stats)
        return MappingResult(position=_host_positions(cat("position")),
                             distance=cat("distance"),
                             distance2=cat("distance2"),
                             mapped=mapped, strand=cat("strand"),
                             ops=cat("ops"), op_count=cat("op_count"),
                             linear_dist=cat("linear_dist"),
                             n_candidates=cat("n_candidates"), stats=stats,
                             lazy_tb=lazy)

    def _run_mesh(self, plan: MappingPlan, entry, reads: np.ndarray,
                  n: int) -> MappingResult:
        if n > plan.padded_reads:
            raise ValueError(f"{n} reads exceed the plan's padded batch "
                             f"shape {plan.padded_reads}; re-plan")
        if n < plan.padded_reads:
            pad = np.zeros((plan.padded_reads - n, reads.shape[1]),
                           reads.dtype)
            reads = np.concatenate([reads, pad])
        fn, aff_cap = entry
        # the mesh path has no chunk pipeline, so its stage accounting is
        # the two host-visible boundaries: the async dispatch enqueue and
        # the blocking D2H fetch.  Same ``streaming.timed`` hook as the
        # single topology: when tracing is armed the spans and the
        # ``stage_times_s`` values come from identical clock reads.
        times = ({} if (self.cfg.profile or _tracing.ACTIVE is not None)
                 else None)
        t0 = time.perf_counter()
        with _tracing.annotate("mesh_dispatch"):
            pos, dist, dist2, dropped, n_surv, aff_drop = fn(
                *self._dev, jnp.asarray(reads))
        t0 = streaming.timed(times, "dispatch", t0)
        pos = np.asarray(pos)[:n]
        dist = np.asarray(dist)[:n]
        dist2 = np.asarray(dist2)[:n]
        dropped = np.asarray(dropped)
        streaming.timed(times, "d2h", t0)
        S = plan.n_shards
        surv = int(np.asarray(n_surv).sum())
        n_aff_drop = int(np.asarray(aff_drop).sum())
        entries = S * S * plan.send_cap
        self._survivor_hist.append(surv / max(entries, 1))
        raw = dict(stage_b_entries=entries, stage_b_survivors=surv,
                   stage_b_affine_capacity=aff_cap,
                   stage_b_affine_instances=S * aff_cap,
                   stage_b_padded_affine_instances=entries,
                   stage_b_affine_dropped=n_aff_drop,
                   send_dropped=int(dropped.sum()),
                   send_dropped_per_shard=dropped,
                   stage_b_survivors_per_shard=np.asarray(n_surv),
                   padded_reads=plan.padded_reads)
        if times is not None:
            raw["stage_times_s"] = dict(times)
        if self.part_index is not None:
            # partition i IS shard i: the on-disk partitioning routed the
            # mesh, so per-shard counters are per-partition counters
            raw["partitions"] = dict(
                num_partitions=S,
                occurrences_per_partition=[p.n_occurrences
                                           for p in self.part_index.parts],
                survivors_per_partition=np.asarray(n_surv).tolist())
        stats = MapperStats(
            topology="mesh", engine=self.cfg.engine, reads=n,
            candidates=entries, survivors=surv,
            affine_instances=S * aff_cap, padded_affine_instances=entries,
            dropped_send=int(dropped.sum()), dropped_affine=n_aff_drop,
            plan_cache_hits=self.plan_cache_hits,
            plan_cache_misses=self.plan_cache_misses, extra=raw)
        _record_run_metrics(stats)
        return MappingResult(position=pos, distance=dist, distance2=dist2,
                             mapped=pos >= 0, stats=stats)
