"""Distributed read mapping (the paper's Sec. V architecture on a TPU mesh).

DART-PIM's controller hierarchy routes each read to the crossbars owning its
minimizers; results flow back to the main RISC-V for the final min-reduce.
On a TPU mesh this is:

  stage A (read owner) : minimizer extraction, destination = hash % n_shards,
                         bucket into fixed-capacity send buffers
  all_to_all           : one collective replaces the paper's 1556 GB of
                         CPU<->memory PL traffic
  stage B (index owner): local lookup -> banded linear WF over <=max_pls PLs
                         -> min-extract -> filter -> banded affine WF on the
                         compacted survivors only (static capacity from
                         ``stage_b_affine_capacity``, overflow dropped)
  all_to_all (return)  : (read_id, distance, position) echoes to the owner
  stage C (read owner) : scatter-min per read  (main-RISC-V reduce)

Fixed buffer capacities are the Reads-FIFO/maxReads mechanism: overflow
entries are *dropped*, trading accuracy for bounded latency exactly as the
paper does (measured in benchmarks/accuracy.py).

The index is sharded by minimizer hash (``shard_index``) — DART-PIM's
"crossbar per minimizer" data organization, with the same deliberate
segment duplication.

The public front-end for this path is ``repro.core.mapper.Mapper`` with
``topology="mesh"`` (``distributed_map_reads`` below is its deprecation
shim); ``make_distributed_mapper`` stays the compiled-program builder the
session's plan cache draws from.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import wf_backend as wfb
from .compaction import bucket_capacity, compact_indices, scatter_to
from .filtering import collapse_candidates, gather_windows
from .index import GenomeIndex
from .minimizers import hash32, unique_read_minimizers
from .pipeline import MapperConfig

AXIS = "shards"


def _shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: the top-level API landed after
    0.4.x; older releases carry it in jax.experimental with ``check_rep``
    instead of ``check_vma`` (both disabled — scan carries are created
    fresh inside the body)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def stage_b_affine_capacity(n_entries: int, cfg: MapperConfig,
                            frac: float | None = None) -> int:
    """Static survivor capacity for stage B's affine pass.

    Stage B is inside one jit (no host sync between the filter and the
    affine stage), so the survivor-bucket capacity must be *negotiated*
    up front rather than measured per batch: each of the ``n_entries``
    bucket slots contributes at most one affine candidate (its best of
    ``max_pls`` PLs), and a slot only survives when it is occupied, its
    minimizer is found, and its best linear distance clears the filter
    threshold.  ``frac`` is the provisioned fraction of that bound
    (default ``cfg.stage_b_survivor_frac``; ``stage_b_adaptive`` sessions
    pass the quantile of their observed survivor history instead — see
    ``Mapper._stage_b_frac``).  Drop-on-overflow beyond the capacity is
    the Reads-FIFO semantics; a threshold that cannot reject anything
    (``> eth``) disables the filter, so provisioning falls back to full
    capacity.
    """
    if frac is None:
        frac = cfg.stage_b_survivor_frac
    frac = 1.0 if cfg.filter_threshold > cfg.eth else \
        max(min(frac, 1.0), 0.0)
    want = int(np.ceil(n_entries * frac))
    cap = bucket_capacity(want, align=cfg.aff_block_r, cap_max=n_entries)
    # neither the lane-align floor nor the pow-2 rounding may outgrow the
    # entry count: a "compacted" pass larger than its input would be a
    # pessimization.  A non-pow2/non-aligned cap is safe here — stage B
    # compiles once per program and the kernel ops pad to the lane block
    # internally.
    return min(cap, n_entries)


@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Per-shard padded CSR index arrays (leading axis = shard)."""
    uniq_kmers: np.ndarray   # (S, U) uint32, padded with 0xFFFFFFFF
    offsets: np.ndarray      # (S, U+1) int32
    positions: np.ndarray    # (S, O) int32
    segments: np.ndarray     # (S, O, seg_len) uint8
    n_shards: int
    read_len: int
    k: int
    w: int
    eth: int

    def device_arrays(self):
        return (jnp.asarray(self.uniq_kmers), jnp.asarray(self.offsets),
                jnp.asarray(self.positions), jnp.asarray(self.segments))

    @classmethod
    def from_partitions(cls, parts, *, read_len: int, k: int, w: int,
                        eth: int, seg_len: int) -> "ShardedIndex":
        """Stack pre-partitioned per-shard CSRs into the padded layout.

        ``parts`` is a sequence of ``(kmers, offsets, positions,
        segments)`` tuples, one per shard, already assigned by the
        ``hash32(kmer) % n_shards`` crossbar rule (e.g. the partitions of
        a ``repro.index.ShardedGenomeIndex`` built offline).  This is the
        zero-re-hash path onto the mesh: no flat index is rebuilt and no
        runtime ``shard_index`` scan runs — the padding conventions here
        (uniq padded with 0xFFFFFFFF, offsets padded with the last
        offset) are exactly ``shard_index``'s, so the stacked arrays are
        bit-identical to sharding the equivalent flat index.
        """
        n_shards = len(parts)
        u_cap = max(max((len(p[0]) for p in parts), default=0), 1)
        o_cap = max(max((len(p[2]) for p in parts), default=0), 1)
        uq = np.full((n_shards, u_cap), 0xFFFFFFFF, dtype=np.uint32)
        of = np.zeros((n_shards, u_cap + 1), dtype=np.int32)
        po = np.zeros((n_shards, o_cap), dtype=np.int32)
        sg = np.zeros((n_shards, o_cap, seg_len), dtype=np.uint8)
        for s, (kmers, offsets, positions, segments) in enumerate(parts):
            nu, no = len(kmers), len(positions)
            uq[s, :nu] = kmers
            of[s, : nu + 1] = offsets
            of[s, nu + 1:] = offsets[-1] if nu else 0
            po[s, :no] = positions
            sg[s, :no] = segments
        return cls(uniq_kmers=uq, offsets=of, positions=po, segments=sg,
                   n_shards=n_shards, read_len=read_len, k=k, w=w, eth=eth)


def shard_index(index: GenomeIndex, n_shards: int) -> ShardedIndex:
    """Assign each unique minimizer to shard hash32(kmer) % n_shards."""
    kmers = index.uniq_kmers
    h = np.asarray(hash32(jnp.asarray(kmers))) % n_shards
    counts = np.diff(index.offsets)
    u_cap = max(int(np.bincount(h, minlength=n_shards).max()), 1)
    o_cap = max(int(np.bincount(h, weights=counts,
                                minlength=n_shards).max()), 1) if len(h) else 1
    U = len(kmers)
    uq = np.full((n_shards, u_cap), 0xFFFFFFFF, dtype=np.uint32)
    of = np.zeros((n_shards, u_cap + 1), dtype=np.int32)
    po = np.zeros((n_shards, o_cap), dtype=np.int32)
    sg = np.zeros((n_shards, o_cap, index.seg_len), dtype=np.uint8)
    for s in range(n_shards):
        sel = np.where(h == s)[0]
        nu, off = len(sel), 0
        uq[s, :nu] = kmers[sel]
        for i, ui in enumerate(sel):
            c = int(counts[ui])
            lo = index.offsets[ui]
            po[s, off : off + c] = index.positions[lo : lo + c]
            sg[s, off : off + c] = index.segments[lo : lo + c]
            off += c
            of[s, i + 1] = off
        of[s, nu + 1 :] = off
    return ShardedIndex(uniq_kmers=uq, offsets=of, positions=po, segments=sg,
                        n_shards=n_shards, read_len=index.read_len,
                        k=index.k, w=index.w, eth=index.eth)


def _bucket_by_dst(dst, payload, n_shards: int, cap: int):
    """Scatter entries into (n_shards, cap) buckets; overflow dropped.

    dst: (E,) int32 target shard per entry (n_shards = drop).
    payload: dict of (E, ...) arrays.  Returns dict of (n_shards, cap, ...)
    plus a valid mask and the number of dropped entries.
    """
    E = dst.shape[0]
    order = jnp.argsort(dst, stable=True)
    dsorted = dst[order]
    # rank within group: arange - index of first element of the group
    first = jnp.searchsorted(dsorted, dsorted)  # leftmost equal
    rank = jnp.arange(E, dtype=jnp.int32) - first
    ok = (dsorted < n_shards) & (rank < cap)
    slot = jnp.where(ok, dsorted * cap + rank, n_shards * cap)
    out = {}
    for name, arr in payload.items():
        a = arr[order]
        buf = jnp.zeros((n_shards * cap + 1,) + a.shape[1:], dtype=a.dtype)
        buf = buf.at[slot].set(a)
        out[name] = buf[:-1].reshape((n_shards, cap) + a.shape[1:])
    vmask = jnp.zeros((n_shards * cap + 1,), dtype=bool).at[slot].set(ok)
    out["valid"] = vmask[:-1].reshape(n_shards, cap)
    dropped = jnp.sum(dsorted < n_shards) - jnp.sum(ok)
    return out, dropped


def _stage_b(local, uniq, offsets, positions, segments, cfg: MapperConfig,
             aff_cap: int):
    """Index-owner compute: lookup -> linear WF -> min -> filter ->
    compacted affine WF.

    The affine stage runs only on the filter survivors: the ``passed``
    mask is compacted into a static ``aff_cap``-slot bucket
    (``stage_b_affine_capacity``) and the distance-only affine WF executes
    on those ``aff_cap`` instances instead of every bucket entry.
    Survivors beyond ``aff_cap`` are *dropped* (reported unmapped), the
    same bounded-latency/accuracy trade as the Reads-FIFO overflow.
    Returns per-shard (aff (S, cap), pos (S, cap), co_est (S, cap) —
    the placement-level co-optimal runner-up estimate for the distance2
    reduce, n_survivors, n_affine_dropped).
    """
    S, cap = local["kmer"].shape
    kmers = local["kmer"].reshape(-1)
    minipos = local["minipos"].reshape(-1)
    reads = local["read"].reshape(-1, cfg.read_len)
    valid = local["valid"].reshape(-1)

    idx = jnp.searchsorted(uniq, kmers)
    idx = jnp.minimum(idx, uniq.shape[0] - 1)
    found = (uniq[idx] == kmers) & valid
    start, count = offsets[idx], offsets[idx + 1] - offsets[idx]
    P = cfg.max_pls
    occ = start[:, None] + jnp.arange(P, dtype=jnp.int32)[None, :]
    occ_valid = (jnp.arange(P)[None, :] < count[:, None]) & found[:, None]
    occ = jnp.where(occ_valid, occ, 0)

    windows = gather_windows(segments, occ, minipos[:, None],
                             read_len=cfg.read_len, k=cfg.k, eth=cfg.eth)
    E = kmers.shape[0]
    s1 = jnp.broadcast_to(reads[:, None, :], (E, P, cfg.read_len))
    lin_end, _ = wfb.linear_wf_dist(s1, windows, eth=cfg.eth,
                                    backend=cfg.wf_backend,
                                    block_r=cfg.lin_block_r)
    lin_end = jnp.where(occ_valid, lin_end, cfg.eth + 1)
    best_pl, best_lin, passed = collapse_candidates(lin_end,
                                                    cfg.filter_threshold)
    n_surv = jnp.sum(passed)

    # distance-only affine on the compacted survivors: stage B never
    # tracebacks, so no (E, n, band) direction planes are materialized and
    # only aff_cap of the E bucket entries execute
    slots, slot_ok = compact_indices(passed, aff_cap)
    sel_win = jnp.take_along_axis(windows, best_pl[:, None, None], 1)[:, 0]
    aff_c, _ = wfb.affine_wf_dist(reads[slots], sel_win[slots], eth=cfg.eth,
                                  sat=cfg.sat_affine,
                                  backend=cfg.wf_backend,
                                  block_r=cfg.aff_block_r)
    sat = jnp.int32(cfg.sat_affine)
    aff_c = jnp.where(slot_ok, aff_c, sat).astype(jnp.int32)
    aff_end = scatter_to(E, slots, slot_ok, aff_c, sat)
    kept = scatter_to(E, slots, slot_ok, slot_ok, False)
    sel_occ = jnp.take_along_axis(occ, best_pl[:, None], 1)[:, 0]
    pos = positions[sel_occ] - minipos
    # placement-level co-optimal survey (pipeline._co_optimal_runner_up's
    # mesh analog): a repeat copy whose placements share this entry's
    # minimizer never leaves the per-entry argmin, so survey the full
    # (E, P) linear distances for far-locus placements at least as good
    # as the chosen one; estimate their affine distance as this entry's
    # plus the linear excess.  The estimate rides the return exchange and
    # feeds stage C's runner-up reduce.
    sat_lin = jnp.int32(cfg.eth + 1)
    pos_pl = positions[occ] - minipos[:, None]                 # (E, P)
    far_pl = jnp.abs(pos_pl - pos[:, None]) > cfg.eth
    co = far_pl & occ_valid & (lin_end
                               <= min(cfg.filter_threshold, cfg.eth))
    min_far = jnp.min(jnp.where(co, lin_end, sat_lin), axis=-1)
    co_est = jnp.minimum(aff_end + jnp.maximum(min_far - best_lin, 0), sat)
    co_est = jnp.where((min_far < sat_lin) & kept, co_est, sat)
    pos = jnp.where(kept, pos, -1)
    return (aff_end.reshape(S, cap), pos.reshape(S, cap),
            co_est.reshape(S, cap).astype(jnp.int32), n_surv,
            n_surv - jnp.sum(slot_ok))


def make_distributed_mapper(mesh, cfg: MapperConfig, n_shards: int,
                            send_cap: int, aff_cap: int | None = None):
    """Build the jitted shard_map mapping step.

    Returns ``(fn, stage_b_affine_cap)`` — the negotiated per-shard
    survivor capacity is surfaced so callers report exactly what the
    compiled program executes.  ``aff_cap`` overrides the negotiation
    (the ``Mapper`` session passes its plan's — possibly adaptively
    derived — capacity so the compiled program matches the plan).
    Call signature of ``fn``:
      fn(uniq (S,U), offsets (S,U+1), positions (S,O), segments (S,O,L),
         reads (R_global, rl), read_dst_meta...) ->
         (position (R_global,), distance (R_global,),
          distance2 (R_global,), dropped (S,),
          stage_b_survivors (S,), stage_b_affine_dropped (S,))
    """
    from jax.sharding import PartitionSpec as P

    M = cfg.max_minis
    # survivor capacity is negotiated once per program: every shard's
    # stage B sees n_shards*send_cap bucket entries after the exchange
    if aff_cap is None:
        aff_cap = stage_b_affine_capacity(n_shards * send_cap, cfg)

    def step(uniq, offsets, positions, segments, reads):
        # local shapes: uniq (1, U) ... reads (R_local, rl)
        uniq, offsets = uniq[0], offsets[0]
        positions, segments = positions[0], segments[0]
        R = reads.shape[0]

        # ---- stage A: seeding + bucketing
        kmers, minipos, valid = jax.vmap(
            lambda r: unique_read_minimizers(r, k=cfg.k, w=cfg.w, max_uniq=M)
        )(reads)
        dst = (hash32(kmers) % n_shards).astype(jnp.int32)
        dst = jnp.where(valid, dst, n_shards)  # invalid -> drop bucket
        rid = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32)[:, None],
                               (R, M))
        payload = {
            "kmer": kmers.reshape(-1),
            "minipos": minipos.reshape(-1).astype(jnp.int32),
            "read": jnp.broadcast_to(reads[:, None, :],
                                     (R, M, cfg.read_len)).reshape(
                                         -1, cfg.read_len),
            "rid": rid.reshape(-1),
        }
        buckets, dropped = _bucket_by_dst(dst.reshape(-1), payload,
                                          n_shards, send_cap)

        # ---- exchange: entries travel to their minimizer's home shard
        recv = {k: jax.lax.all_to_all(v, AXIS, 0, 0, tiled=False)
                for k, v in buckets.items()}

        # ---- stage B on the index owner
        aff, pos, co_est, n_surv, aff_drop = _stage_b(
            recv, uniq, offsets, positions, segments, cfg, aff_cap)
        aff = jnp.where(recv["valid"], aff, cfg.sat_affine)
        co_est = jnp.where(recv["valid"], co_est, cfg.sat_affine)

        # ---- return trip
        back_aff = jax.lax.all_to_all(aff, AXIS, 0, 0)
        back_pos = jax.lax.all_to_all(pos, AXIS, 0, 0)
        back_co = jax.lax.all_to_all(co_est, AXIS, 0, 0)
        back_rid = buckets["rid"]  # origin kept its own copy (same order)
        back_val = buckets["valid"]

        # ---- stage C: min-reduce per read (position of the min distance)
        flat_aff = jnp.where(back_val, back_aff, cfg.sat_affine).reshape(-1)
        flat_pos = back_pos.reshape(-1)
        flat_rid = jnp.where(back_val, back_rid, R).reshape(-1)
        best = jnp.full((R + 1,), cfg.sat_affine, dtype=jnp.int32)
        best = best.at[flat_rid].min(flat_aff)
        is_best = (flat_aff == best[flat_rid]) & (flat_rid < R)
        # leftmost position among ties
        bigpos = jnp.where(is_best & (flat_pos >= 0), flat_pos, 2 ** 30)
        posr = jnp.full((R + 1,), 2 ** 30, dtype=jnp.int32)
        posr = posr.at[flat_rid].min(bigpos)
        position = jnp.where((best[:R] < cfg.sat_affine) & (posr[:R] < 2 ** 30),
                             posr[:R], -1)
        # runner-up distance at a different locus (beyond the band from
        # the winner) — same semantics as pipeline._runner_up_distance,
        # expressed as a second scatter-min over the returned entries,
        # plus the per-entry placement-level co-optimal estimates from
        # stage B (the _co_optimal_runner_up analog)
        pos_ext = jnp.concatenate([position, jnp.full((1,), -1, jnp.int32)])
        far = jnp.abs(flat_pos - pos_ext[flat_rid]) > cfg.eth
        d2_key = jnp.where(far & (flat_aff < cfg.sat_affine)
                           & (flat_pos >= 0), flat_aff, cfg.sat_affine)
        best2 = jnp.full((R + 1,), cfg.sat_affine, dtype=jnp.int32)
        best2 = best2.at[flat_rid].min(d2_key)
        flat_co = jnp.where(back_val, back_co, cfg.sat_affine).reshape(-1)
        best2 = best2.at[flat_rid].min(flat_co)
        return (position, best[:R], best2[:R], dropped[None], n_surv[None],
                aff_drop[None])

    pspec = P(AXIS)
    fn = _shard_map(step, mesh,
                    in_specs=(pspec, pspec, pspec, pspec, pspec),
                    out_specs=(pspec,) * 6)
    return jax.jit(fn), aff_cap


# one compiled program per (mesh, cfg, shards, send_cap): repeated serving
# batches hit the jit cache instead of re-tracing the shard_map step
_cached_mapper = functools.lru_cache(maxsize=8)(make_distributed_mapper)


_LEGACY_STATS_KEYS = (
    "stage_b_entries", "stage_b_survivors", "stage_b_affine_capacity",
    "stage_b_affine_instances", "stage_b_padded_affine_instances",
    "stage_b_affine_dropped", "send_dropped")


def distributed_map_reads(mesh, sidx: ShardedIndex, reads: np.ndarray,
                          cfg: MapperConfig | None = None,
                          send_cap: int | None = None,
                          with_stats: bool = False):
    """Host wrapper: returns (positions, distances, dropped_per_shard),
    plus a stage-B stats dict when ``with_stats=True``.

    .. deprecated::
        Use :class:`repro.core.mapper.Mapper` with ``topology="mesh"`` —
        ``Mapper(sidx, cfg, topology="mesh", mesh=mesh).map(reads)``
        returns the same positions/distances bit-identically, as a
        ``MappingResult`` whose ``stats`` (a unified ``MapperStats``)
        always carries the stage-B accounting.  See the README's
        migration table.
    """
    import warnings

    warnings.warn(
        "distributed_map_reads is deprecated; use repro.core.mapper.Mapper "
        'with topology="mesh" — Mapper(sidx, cfg, topology="mesh", '
        "mesh=mesh).map(reads) is the bit-identical replacement",
        DeprecationWarning, stacklevel=2)
    from .mapper import Mapper

    R, S = len(reads), sidx.n_shards
    assert R % S == 0, "pad reads to a multiple of the shard count"
    mapper = Mapper(sidx, cfg, topology="mesh", mesh=mesh,
                    send_cap=send_cap)
    res = mapper.map(reads)
    st = res.stats
    dropped = st["send_dropped_per_shard"]
    if not with_stats:
        if st.dropped_affine:  # bounded-latency drop, never a *silent* one
            warnings.warn(
                f"stage B dropped {st.dropped_affine} filter survivors on "
                f"affine-capacity overflow (capacity "
                f"{st['stage_b_affine_capacity']}/shard); raise "
                f"stage_b_survivor_frac or send_cap, or pass "
                f"with_stats=True to track this", stacklevel=2)
        return res.position, res.distance, dropped
    return (res.position, res.distance, dropped,
            {k: st[k] for k in _LEGACY_STATS_KEYS})
