"""Request batching for the mapping service (the serving front-end).

A mapping service receives read batches of arbitrary size — per-client
FASTQ slices, not the engine's static chunk shape.  Feeding each request
straight to the mapper would trigger one jit bucket per distinct batch
size and waste lanes on tiny batches.  ``ReadBatcher`` is the Reads-FIFO
analog at the request layer: it coalesces pending requests into
**power-of-two bucket shapes** between ``bucket_min`` and ``bucket_max``
(the streaming engine's chunk size), so

  * recompiles are bounded by ``log2(bucket_max / bucket_min) + 1``
    distinct shapes, regardless of request-size distribution;
  * full ``bucket_max`` buckets flow through the double-buffered streaming
    engine back-to-back (one multi-chunk streamed run);
  * the residue pays at most 2x padding on the *last* bucket only.

``MappingService`` wraps the batcher + a ``repro.core.mapper.Mapper``
session with per-request result reassembly and padding/throughput
accounting.  The session's topology decides where buckets execute:

  * ``topology="single"`` — full buckets run as one streamed multi-chunk
    plan, the residue as its own pow-2 chunk shape;
  * ``topology="mesh"``   — every bucket is routed onto the distributed
    all_to_all mapper; same-size buckets share one plan-cache entry, so
    repeated buckets hit the compiled shard_map program with **zero**
    recompiles after warm-up (observable via the plan-cache counters in
    ``MapperStats`` / ``Mapper.plan_cache_hits``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .compaction import bucket_capacity
from .mapper import (_PER_READ_FIELDS, Mapper, MapperStats,
                     accumulate_stats, split_result)
from .pipeline import MapperConfig, MappingResult


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    bucket_min: int = 64     # smallest jit'd batch shape (pow2)
    bucket_max: int = 1024   # largest; == the streaming chunk size (pow2)

    def __post_init__(self):
        for v in (self.bucket_min, self.bucket_max):
            assert v >= 1 and (v & (v - 1)) == 0, "bucket sizes must be pow2"
        assert self.bucket_min <= self.bucket_max


def pow2_buckets(n: int, *, lo: int, hi: int) -> list[int]:
    """Greedy cover of ``n`` reads by pow-2 bucket sizes in ``[lo, hi]``:
    full ``hi`` buckets first, one rounded-up bucket for the residue."""
    out = [hi] * (n // hi)
    rest = n % hi
    if rest:
        out.append(bucket_capacity(rest, align=lo, cap_max=hi))
    return out


class ReadBatcher:
    """Coalesce variable-sized incoming read batches into pow-2 buckets.

    ``submit`` enqueues a request and returns its id; ``drain`` hands back
    everything pending as one concatenated read block plus the bucket
    cover and per-request spans, and resets the queue.
    """

    def __init__(self, read_len: int, cfg: BatcherConfig = BatcherConfig()):
        self.read_len = read_len
        self.cfg = cfg
        self._pending: list[tuple[int, np.ndarray]] = []
        self._next_id = 0
        self.stats = dict(requests=0, reads=0, padded_reads=0,
                          bucket_hist={})

    @property
    def pending_reads(self) -> int:
        return sum(len(r) for _, r in self._pending)

    def submit(self, reads: np.ndarray) -> int:
        reads = np.asarray(reads)
        assert reads.ndim == 2 and reads.shape[1] == self.read_len, \
            f"expected (n, {self.read_len}) reads, got {reads.shape}"
        # empty requests are rejected up front: an all-empty flush would
        # otherwise drain the queue without ever resolving their ids
        assert len(reads) >= 1, "empty read batch"
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, reads))
        self.stats["requests"] += 1
        self.stats["reads"] += len(reads)
        return rid

    def drain(self):
        """-> (reads (N, rl), buckets [sizes], spans {rid: (lo, hi)})."""
        if not self._pending:
            return (np.zeros((0, self.read_len), np.uint8), [], {})
        spans, off = {}, 0
        for rid, r in self._pending:
            spans[rid] = (off, off + len(r))
            off += len(r)
        reads = np.concatenate([r for _, r in self._pending])
        self._pending = []
        buckets = pow2_buckets(len(reads), lo=self.cfg.bucket_min,
                               hi=self.cfg.bucket_max)
        self.stats["padded_reads"] += sum(buckets) - len(reads)
        for b in buckets:
            hist = self.stats["bucket_hist"]
            hist[b] = hist.get(b, 0) + 1
        return reads, buckets, spans


# the per-read MappingResult fields, shared with mapper.split_result so
# reassembly and pair splitting cannot drift apart
_RESULT_FIELDS = _PER_READ_FIELDS

_TOTAL_FIELDS = ("reads", "candidates", "survivors", "affine_instances",
                 "padded_affine_instances", "dropped_send", "dropped_affine",
                 "reverse_best")


class MappingService:
    """Mapping service: request batcher + a ``Mapper`` session.

    Construct from an existing session (``MappingService(mapper)`` /
    ``mapper.serve()``) or from an index + config, which builds a
    single-topology session internally (the pre-``Mapper`` signature).

    ``submit`` queues a request; ``flush`` drains the batcher, routes the
    coalesced buckets through the session (see the module docstring for
    the per-topology routing) and returns ``{request_id: MappingResult}``.
    ``totals`` accumulates the unified ``MapperStats`` accounting across
    flushes — survivors, executed affine instances, drop counters — and
    ``mapper.plan_cache_hits``/``misses`` expose the warm-up behaviour.
    """

    def __init__(self, index_or_mapper, cfg: MapperConfig | None = None,
                 batcher: BatcherConfig = BatcherConfig()):
        if isinstance(index_or_mapper, Mapper):
            assert cfg is None, "pass cfg via the Mapper session"
            self.mapper = index_or_mapper
        else:
            self.mapper = Mapper(index_or_mapper, cfg)
        self.index = self.mapper.index
        self.cfg = self.mapper.cfg
        self.batcher = ReadBatcher(self.cfg.read_len, batcher)
        self.totals = {k: 0 for k in _TOTAL_FIELDS}
        self._paired: set[int] = set()

    def submit(self, reads: np.ndarray) -> int:
        return self.batcher.submit(reads)

    def submit_paired(self, reads1: np.ndarray, reads2: np.ndarray) -> int:
        """Queue a paired-end request: mates ride the bucket pipeline as
        one stacked block (R1 rows then R2 rows), and ``flush`` hands the
        request back as a ``(res1, res2)`` per-mate tuple instead of one
        ``MappingResult`` — the serving-layer face of
        ``Mapper.map_pairs``."""
        reads1, reads2 = np.asarray(reads1), np.asarray(reads2)
        if reads1.shape != reads2.shape:
            raise ValueError(f"mate batches must align pairwise: "
                             f"{reads1.shape} vs {reads2.shape}")
        rid = self.batcher.submit(np.concatenate([reads1, reads2]))
        self._paired.add(rid)
        return rid

    def _accumulate(self, parts: list[MappingResult]) -> None:
        for p in parts:
            accumulate_stats(self.totals, p.stats)

    def flush(self) -> dict[int, MappingResult]:
        reads, buckets, spans = self.batcher.drain()
        if not buckets:
            return {}
        parts = []
        if self.mapper.topology == "mesh":
            # every bucket is one distributed batch; same-size buckets
            # share a plan key -> the compiled shard_map program
            off = 0
            for b in buckets:
                block = reads[off : off + b]  # last block may be short
                off += b
                parts.append(self.mapper.run(self.mapper.plan(b), block))
        else:
            hi = self.batcher.cfg.bucket_max
            n_full = sum(1 for b in buckets if b == hi)
            if n_full:  # full buckets: one streamed multi-chunk plan
                plan = self.mapper.plan(n_full * hi, chunk=hi)
                parts.append(self.mapper.run(plan, reads[: n_full * hi]))
            rest = reads[n_full * hi :]
            if len(rest):  # residue: its own pow-2 chunk shape
                plan = self.mapper.plan(len(rest), chunk=buckets[-1])
                parts.append(self.mapper.run(plan, rest))
        self._accumulate(parts)

        def cat(field):
            # raw access: a cigar_mode="lazy" bucket result must not be
            # materialized just to be reassembled per request
            arrs = [object.__getattribute__(p, field) for p in parts]
            if any(a is None for a in arrs):  # mesh: no traceback fields
                return None
            return arrs[0] if len(arrs) == 1 else np.concatenate(arrs)

        fields = {f: cat(f) for f in _RESULT_FIELDS}
        lts = [object.__getattribute__(p, "lazy_tb") for p in parts]
        lazy = None
        if all(lt is not None for lt in lts):
            from .pipeline import LazyTraceback
            lazy = LazyTraceback.concat(lts)
        out = {}
        for rid, (lo, hi_) in spans.items():
            res = MappingResult(
                **{f: (v[lo:hi_] if v is not None else None)
                   for f, v in fields.items()},
                stats=None,
                lazy_tb=lazy[lo:hi_] if lazy is not None else None)
            if rid in self._paired:
                self._paired.discard(rid)
                res = split_result(res, (hi_ - lo) // 2)
            out[rid] = res
        return out

    @property
    def affine_drop_rate(self) -> float:
        """Fraction of stage-B filter survivors dropped on affine-capacity
        overflow, across all flushes so far (0.0 on the single topology,
        which never drops).  The observable that tells an operator whether
        the provisioned survivor capacity — static or adaptive — is
        actually holding the workload."""
        return self.totals["dropped_affine"] / max(self.totals["survivors"],
                                                   1)
