"""Request batching for the mapping service (the serving front-end).

A mapping service receives read batches of arbitrary size — per-client
FASTQ slices, not the engine's static chunk shape.  Feeding each request
straight to the mapper would trigger one jit bucket per distinct batch
size and waste lanes on tiny batches.  ``ReadBatcher`` is the Reads-FIFO
analog at the request layer: it coalesces pending requests into
**power-of-two bucket shapes** between ``bucket_min`` and ``bucket_max``
(the streaming engine's chunk size), so

  * recompiles are bounded by ``log2(bucket_max / bucket_min) + 1``
    distinct shapes, regardless of request-size distribution;
  * full ``bucket_max`` buckets flow through the double-buffered streaming
    engine back-to-back (one multi-chunk streamed run);
  * the residue pays at most 2x padding on the *last* bucket only.

``MappingService`` wraps the batcher + a ``repro.core.mapper.Mapper``
session with per-request result reassembly and padding/throughput
accounting.  The session's topology decides where buckets execute:

  * ``topology="single"`` — full buckets run as one streamed multi-chunk
    plan, the residue as its own pow-2 chunk shape;
  * ``topology="mesh"``   — every bucket is routed onto the distributed
    all_to_all mapper; same-size buckets share one plan-cache entry, so
    repeated buckets hit the compiled shard_map program with **zero**
    recompiles after warm-up (observable via the plan-cache counters in
    ``MapperStats`` / ``Mapper.plan_cache_hits``).

Fault tolerance (``repro.core.resilience``): admission control bounds the
pending queue at ``submit`` (``AdmissionConfig`` — block or shed, plus
per-request deadlines), and ``flush`` is **transactional**: every drained
request id is resolved exactly once, to its results or to a structured
``MappingError`` — a failed bucket is retried, bisected and quarantined
by the ``ResilientMapper`` so it takes down only the reads that caused
it, never the flush.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..obs import registry as _metrics
from .compaction import bucket_capacity
from .mapper import (_PER_READ_FIELDS, Mapper, MapperStats,
                     accumulate_partition_stats, accumulate_stats,
                     split_result)
from .pipeline import MapperConfig, MappingResult
from .resilience import (AdmissionConfig, MappingError, ResilientMapper,
                         RetryPolicy, ShedError, assemble_segments)


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    bucket_min: int = 64     # smallest jit'd batch shape (pow2)
    bucket_max: int = 1024   # largest; == the streaming chunk size (pow2)

    def __post_init__(self):
        for name in ("bucket_min", "bucket_max"):
            v = getattr(self, name)
            if v < 1 or (v & (v - 1)) != 0:
                raise ValueError(f"{name}={v!r} must be a positive power "
                                 f"of two")
        if self.bucket_min > self.bucket_max:
            raise ValueError(f"bucket_min={self.bucket_min} must be <= "
                             f"bucket_max={self.bucket_max}")


def pow2_buckets(n: int, *, lo: int, hi: int) -> list[int]:
    """Greedy cover of ``n`` reads by pow-2 bucket sizes in ``[lo, hi]``:
    full ``hi`` buckets first, one rounded-up bucket for the residue."""
    out = [hi] * (n // hi)
    rest = n % hi
    if rest:
        out.append(bucket_capacity(rest, align=lo, cap_max=hi))
    return out


class ReadBatcher:
    """Coalesce variable-sized incoming read batches into pow-2 buckets.

    ``submit`` enqueues a request and returns its id; ``drain`` hands back
    everything pending as one concatenated read block plus the bucket
    cover and per-request spans, and resets the queue.

    ``stats`` is safe for long-lived serving: the counters are scalars and
    ``bucket_hist`` is keyed by bucket size — a power of two in
    ``[bucket_min, bucket_max]`` — so it holds at most
    ``log2(bucket_max / bucket_min) + 1`` entries no matter how many
    requests pass through.

    Malformed submissions raise ``ValueError`` (not ``assert`` — service
    callers need recoverable errors, and asserts vanish under
    ``python -O``).
    """

    def __init__(self, read_len: int, cfg: BatcherConfig = BatcherConfig()):
        self.read_len = read_len
        self.cfg = cfg
        self._pending: list[tuple[int, np.ndarray]] = []
        self._next_id = 0
        self.stats = dict(requests=0, reads=0, padded_reads=0,
                          bucket_hist={})

    @property
    def pending_reads(self) -> int:
        return sum(len(r) for _, r in self._pending)

    def submit(self, reads: np.ndarray) -> int:
        reads = np.asarray(reads)
        if reads.ndim != 2 or reads.shape[1] != self.read_len:
            raise ValueError(f"expected (n, {self.read_len}) reads, got "
                             f"{reads.shape}")
        # empty requests are rejected up front: an all-empty flush would
        # otherwise drain the queue without ever resolving their ids
        if len(reads) < 1:
            raise ValueError("empty read batch")
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, reads))
        self.stats["requests"] += 1
        self.stats["reads"] += len(reads)
        return rid

    def drain(self):
        """-> (reads (N, rl), buckets [sizes], spans {rid: (lo, hi)})."""
        if not self._pending:
            return (np.zeros((0, self.read_len), np.uint8), [], {})
        spans, off = {}, 0
        for rid, r in self._pending:
            spans[rid] = (off, off + len(r))
            off += len(r)
        reads = np.concatenate([r for _, r in self._pending])
        self._pending = []
        buckets = pow2_buckets(len(reads), lo=self.cfg.bucket_min,
                               hi=self.cfg.bucket_max)
        self.stats["padded_reads"] += sum(buckets) - len(reads)
        for b in buckets:
            hist = self.stats["bucket_hist"]
            hist[b] = hist.get(b, 0) + 1
        return reads, buckets, spans


# the per-read MappingResult fields, shared with mapper.split_result so
# reassembly and pair splitting cannot drift apart
_RESULT_FIELDS = _PER_READ_FIELDS

# engine accounting accumulated from each flush's merged MapperStats ...
_TOTAL_FIELDS = ("reads", "candidates", "survivors", "affine_instances",
                 "padded_affine_instances", "dropped_send", "dropped_affine",
                 "reverse_best")
# ... plus the service-level failure counters maintained by the service
# itself (these are NOT MapperStats attributes — _accumulate must keep
# passing fields=_TOTAL_FIELDS explicitly)
_SERVICE_FIELDS = ("shed_requests", "deadline_misses", "retries",
                   "failed_reads", "failed_requests")

# distinct tenant label values tracked per service; extra tenants share a
# single "_other" bucket so the depth gauges (and the registry label sets
# behind them) stay bounded under long-lived serving
_MAX_TENANTS = 64


class MappingService:
    """Mapping service: request batcher + a ``Mapper`` session.

    Construct from an existing session (``MappingService(mapper)`` /
    ``mapper.serve()``) or from an index + config, which builds a
    single-topology session internally (the pre-``Mapper`` signature).

    ``submit`` queues a request; ``flush`` drains the batcher, routes the
    coalesced buckets through the session (see the module docstring for
    the per-topology routing) and returns ``{request_id: MappingResult}``.
    ``totals`` accumulates the unified ``MapperStats`` accounting across
    flushes — survivors, executed affine instances, drop counters — and
    ``mapper.plan_cache_hits``/``misses`` expose the warm-up behaviour.

    Fault-tolerance knobs:

    admission : AdmissionConfig
        Bounded pending queue + default deadline.  When a ``submit``
        would push ``pending_reads`` past ``max_pending_reads``:
        ``policy="block"`` flushes the queue synchronously first (those
        results are delivered by the *next* ``flush``) and then accepts;
        ``policy="shed"`` raises ``ShedError`` and counts
        ``totals["shed_requests"]``.  A single request larger than the
        bound is accepted against an empty queue (no livelock).
    retry : RetryPolicy
        Block-level retry/bisection/degradation applied inside ``flush``
        (see ``resilience.ResilientMapper``).
    injector : FaultInjector
        Chaos hook: armed sites fire inside ``flush`` and in the
        session's streaming fetch thread.

    ``flush`` resolves **every** drained request id exactly once — to a
    ``MappingResult`` (possibly carrying a partial ``failed`` quarantine
    mask), a ``(res1, res2)`` pair, or a ``MappingError`` — even when a
    bucket, the injector, or the service itself fails mid-flush.
    """

    def __init__(self, index_or_mapper, cfg: MapperConfig | None = None,
                 batcher: BatcherConfig = BatcherConfig(), *,
                 admission: AdmissionConfig = AdmissionConfig(),
                 retry: RetryPolicy = RetryPolicy(), injector=None):
        if isinstance(index_or_mapper, Mapper):
            if cfg is not None:
                raise ValueError("pass cfg via the Mapper session")
            self.mapper = index_or_mapper
        else:
            self.mapper = Mapper(index_or_mapper, cfg, injector=injector)
        self.index = self.mapper.index
        self.cfg = self.mapper.cfg
        self.batcher = ReadBatcher(self.cfg.read_len, batcher)
        self.admission = admission
        self.injector = injector if injector is not None \
            else self.mapper.injector
        self.resilient = ResilientMapper(self.mapper, retry,
                                         injector=self.injector)
        self.totals = {k: 0 for k in _TOTAL_FIELDS + _SERVICE_FIELDS}
        self._paired: set[int] = set()
        self._deadlines: dict[int, float] = {}
        self._ready: dict[int, object] = {}
        # per-request observability state, drained with the request: both
        # dicts are keyed by pending rids only, so they are bounded by the
        # admission queue, and the tenant label space is capped at
        # _MAX_TENANTS (+ "_other")
        self._submit_ts: dict[int, float] = {}
        self._tenants: dict[int, str] = {}
        self._tenant_pending: dict[str, int] = {}

    # ----------------------------------------------------------- admission

    def _admit(self, n_reads: int) -> None:
        lim = self.admission.max_pending_reads
        if lim is None:
            return
        pending = self.batcher.pending_reads
        if pending + n_reads <= lim or pending == 0:
            return  # fits, or single oversize request against empty queue
        if self.admission.policy == "shed":
            self.totals["shed_requests"] += 1
            reg = _metrics.ACTIVE
            if reg is not None:
                reg.counter("repro_shed_requests_total").inc()
            raise ShedError(
                f"pending queue full ({pending} + {n_reads} > {lim} "
                f"reads); resubmit after a flush")
        # "block": drain synchronously, hold results for the next flush.
        # flush() swaps self._ready for a fresh dict, so the held results
        # must be merged into the *post*-flush dict, not the pre-flush one
        held = self.flush()
        self._ready.update(held)

    def _arm_deadline(self, rid: int, deadline_s: float | None) -> int:
        dl = deadline_s if deadline_s is not None \
            else self.admission.deadline_s
        if dl is not None:
            if dl <= 0:
                raise ValueError(f"deadline_s={dl!r} must be > 0")
            self._deadlines[rid] = time.monotonic() + dl
        return rid

    # ---------------------------------------------------------- submission

    def submit(self, reads: np.ndarray, *,
               deadline_s: float | None = None,
               tenant: str | None = None) -> int:
        reads = np.asarray(reads)
        self._admit(len(reads))
        rid = self._arm_deadline(self.batcher.submit(reads), deadline_s)
        self._track_submit(rid, tenant)
        return rid

    def submit_paired(self, reads1: np.ndarray, reads2: np.ndarray, *,
                      deadline_s: float | None = None,
                      tenant: str | None = None) -> int:
        """Queue a paired-end request: mates ride the bucket pipeline as
        one stacked block (R1 rows then R2 rows), and ``flush`` hands the
        request back as a ``(res1, res2)`` per-mate tuple instead of one
        ``MappingResult`` — the serving-layer face of
        ``Mapper.map_pairs``."""
        reads1, reads2 = np.asarray(reads1), np.asarray(reads2)
        if reads1.shape != reads2.shape:
            raise ValueError(f"mate batches must align pairwise: "
                             f"{reads1.shape} vs {reads2.shape}")
        self._admit(2 * len(reads1))
        rid = self.batcher.submit(np.concatenate([reads1, reads2]))
        self._paired.add(rid)
        rid = self._arm_deadline(rid, deadline_s)
        self._track_submit(rid, tenant)
        return rid

    # ------------------------------------------------- per-request tracking

    def _tenant_key(self, tenant: str | None) -> str:
        t = tenant if tenant is not None else "default"
        if t in self._tenant_pending or len(self._tenant_pending) \
                < _MAX_TENANTS:
            return t
        return "_other"

    def _track_submit(self, rid: int, tenant: str | None) -> None:
        self._submit_ts[rid] = time.perf_counter()
        t = self._tenant_key(tenant)
        self._tenants[rid] = t
        depth = self._tenant_pending.get(t, 0) + 1
        self._tenant_pending[t] = depth
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_requests_total", tenant=t).inc()
            reg.gauge("repro_tenant_queue_depth", tenant=t).set(depth)

    def _drain_tracking(self, spans) -> None:
        """Close out per-request tracking for every drained rid: observe
        queue-wait latency and decrement the owning tenant's depth."""
        now = time.perf_counter()
        reg = _metrics.ACTIVE
        for rid in spans:
            ts = self._submit_ts.pop(rid, None)
            if ts is not None and reg is not None:
                reg.histogram(
                    "repro_request_queue_wait_seconds").observe(now - ts)
            t = self._tenants.pop(rid, None)
            if t is not None:
                depth = max(self._tenant_pending.get(t, 1) - 1, 0)
                self._tenant_pending[t] = depth
                if reg is not None:
                    reg.gauge("repro_tenant_queue_depth",
                              tenant=t).set(depth)

    @property
    def tenant_queue_depth(self) -> dict[str, int]:
        """Pending request count per tenant label (bounded at
        ``_MAX_TENANTS`` distinct tenants plus ``"_other"``)."""
        return {t: d for t, d in self._tenant_pending.items() if d}

    def _accumulate(self, stats) -> None:
        accumulate_stats(self.totals, stats, fields=_TOTAL_FIELDS)
        accumulate_partition_stats(self.totals, stats)

    # --------------------------------------------------------------- flush

    def flush(self) -> dict[int, object]:
        """Drain and map everything pending.

        Returns ``{request_id: MappingResult | (res1, res2) |
        MappingError}`` covering every id drained by this call (plus any
        results held from admission-triggered blocking flushes).  The
        resolve is transactional: ids are removed from the pending state
        *first*, then each is resolved exactly once — a failure anywhere
        in the mapping path turns into per-request ``MappingError``
        values, never a raise that would strand drained ids.
        """
        t0 = time.perf_counter()
        try:
            return self._flush()
        finally:
            reg = _metrics.ACTIVE
            if reg is not None:
                reg.histogram("repro_flush_seconds").observe(
                    time.perf_counter() - t0)

    def _flush(self) -> dict[int, object]:
        out, self._ready = self._ready, {}
        reads, buckets, spans = self.batcher.drain()
        self._drain_tracking(spans)
        if not buckets:
            return out
        paired = {rid for rid in spans if rid in self._paired}
        self._paired -= paired      # moved out of pending state at drain

        # expire deadlines before spending any compute on the batch
        now = time.monotonic()
        live: list[tuple[int, np.ndarray]] = []
        for rid, (lo, hi_) in spans.items():
            dl = self._deadlines.pop(rid, None)
            if dl is not None and now > dl:
                self.totals["deadline_misses"] += 1
                reg = _metrics.ACTIVE
                if reg is not None:
                    reg.counter("repro_deadline_misses_total").inc()
                out[rid] = MappingError(
                    "deadline", f"request {rid} missed its deadline by "
                    f"{now - dl:.3f}s before mapping", n_reads=hi_ - lo)
            else:
                live.append((rid, reads[lo:hi_]))
        if not live:
            return out
        if len(live) < len(spans):  # rebuild the batch without the expired
            spans, off = {}, 0
            for rid, r in live:
                spans[rid] = (off, off + len(r))
                off += len(r)
            reads = np.concatenate([r for _, r in live])
            buckets = pow2_buckets(len(reads), lo=self.batcher.cfg.bucket_min,
                                   hi=self.batcher.cfg.bucket_max)
        else:
            spans = {rid: spans[rid] for rid, _ in live}

        try:
            if self.injector is not None:
                self.injector.check("flush")
            segments, counters = self._map_buckets(reads, buckets)
            res, mask = assemble_segments(segments, self.resilient.cfg,
                                          counters)
            self.totals["retries"] += counters["retries"]
            self.totals["failed_reads"] += counters["failed_reads"]
            if res is not None:
                self._accumulate(res.stats)
            for rid, (lo, hi_) in spans.items():
                out[rid] = self._resolve(res, mask, lo, hi_,
                                         paired=rid in paired)
        except Exception as e:  # noqa: BLE001 — transactional boundary:
            # every drained id must resolve; an unexpected failure here
            # becomes a structured per-request error, not a stranded rid
            for rid, (lo, hi_) in spans.items():
                if rid not in out:
                    self.totals["failed_requests"] += 1
                    reg = _metrics.ACTIVE
                    if reg is not None:
                        reg.counter("repro_failed_requests_total").inc()
                    out[rid] = MappingError(
                        "internal", f"{type(e).__name__}: {e}",
                        n_reads=hi_ - lo)
        return out

    def _map_buckets(self, reads: np.ndarray, buckets: list[int]):
        """Route the bucket cover through the resilient mapper ->
        ``(segments, counters)`` covering ``reads`` in order."""
        counters = None
        segments = []

        def timed_map(*a, **kw):
            t0 = time.perf_counter()
            try:
                return self.resilient.map_segments(*a, **kw)
            finally:
                reg = _metrics.ACTIVE
                if reg is not None:
                    reg.histogram("repro_bucket_execute_seconds").observe(
                        time.perf_counter() - t0)

        if self.mapper.topology == "mesh":
            # every bucket is one distributed batch; same-size buckets
            # share a plan key -> the compiled shard_map program
            off = 0
            for b in buckets:
                block = reads[off : off + b]  # last block may be short
                seg, counters = timed_map(
                    block, plan_n=b, base=off, counters=counters)
                segments += seg
                off += b
        else:
            hi = self.batcher.cfg.bucket_max
            n_full = sum(1 for b in buckets if b == hi)
            if n_full:  # full buckets: one streamed multi-chunk plan
                seg, counters = timed_map(
                    reads[: n_full * hi], chunk=hi, counters=counters)
                segments += seg
            rest = reads[n_full * hi :]
            if len(rest):  # residue: its own pow-2 chunk shape
                seg, counters = timed_map(
                    rest, chunk=buckets[-1], base=n_full * hi,
                    counters=counters)
                segments += seg
        return segments, counters

    def _resolve(self, res, mask, lo, hi_, *, paired: bool):
        """One request's slice of the assembled flush result."""
        n = hi_ - lo
        if res is None or mask[lo:hi_].all():
            self.totals["failed_requests"] += 1
            reg = _metrics.ACTIVE
            if reg is not None:
                reg.counter("repro_failed_requests_total").inc()
            return MappingError("execution",
                                "all reads in this request were "
                                "quarantined after retries", n_reads=n)

        def raw(f):
            # raw access: a cigar_mode="lazy" flush result must not be
            # materialized just to be reassembled per request
            v = object.__getattribute__(res, f)
            return v[lo:hi_] if v is not None else None

        lt = object.__getattribute__(res, "lazy_tb")
        part = MappingResult(**{f: raw(f) for f in _RESULT_FIELDS},
                             stats=None,
                             lazy_tb=lt[lo:hi_] if lt is not None else None)
        if paired:
            return split_result(part, n // 2)
        return part

    @property
    def affine_drop_rate(self) -> float:
        """Fraction of stage-B filter survivors dropped on affine-capacity
        overflow, across all flushes so far (0.0 on the single topology,
        which never drops).  The observable that tells an operator whether
        the provisioned survivor capacity — static or adaptive — is
        actually holding the workload."""
        return self.totals["dropped_affine"] / max(self.totals["survivors"],
                                                   1)
