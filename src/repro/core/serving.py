"""Request batching for the streaming mapper (the serving front-end).

A mapping service receives read batches of arbitrary size — per-client
FASTQ slices, not the engine's static chunk shape.  Feeding each request
straight to ``map_reads`` would trigger one jit bucket per distinct batch
size and waste lanes on tiny batches.  ``ReadBatcher`` is the Reads-FIFO
analog at the request layer: it coalesces pending requests into
**power-of-two bucket shapes** between ``bucket_min`` and ``bucket_max``
(the streaming engine's chunk size), so

  * recompiles are bounded by ``log2(bucket_max / bucket_min) + 1``
    distinct shapes, regardless of request-size distribution;
  * full ``bucket_max`` buckets flow through the double-buffered streaming
    engine back-to-back (one multi-chunk ``map_reads`` call);
  * the residue pays at most 2x padding on the *last* bucket only.

``MappingService`` wraps the batcher + ``map_reads`` with per-request
result reassembly and padding/throughput accounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .compaction import bucket_capacity
from .index import GenomeIndex
from .pipeline import MapperConfig, MappingResult, map_reads


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    bucket_min: int = 64     # smallest jit'd batch shape (pow2)
    bucket_max: int = 1024   # largest; == the streaming chunk size (pow2)

    def __post_init__(self):
        for v in (self.bucket_min, self.bucket_max):
            assert v >= 1 and (v & (v - 1)) == 0, "bucket sizes must be pow2"
        assert self.bucket_min <= self.bucket_max


def pow2_buckets(n: int, *, lo: int, hi: int) -> list[int]:
    """Greedy cover of ``n`` reads by pow-2 bucket sizes in ``[lo, hi]``:
    full ``hi`` buckets first, one rounded-up bucket for the residue."""
    out = [hi] * (n // hi)
    rest = n % hi
    if rest:
        out.append(bucket_capacity(rest, align=lo, cap_max=hi))
    return out


class ReadBatcher:
    """Coalesce variable-sized incoming read batches into pow-2 buckets.

    ``submit`` enqueues a request and returns its id; ``drain`` hands back
    everything pending as one concatenated read block plus the bucket
    cover and per-request spans, and resets the queue.
    """

    def __init__(self, read_len: int, cfg: BatcherConfig = BatcherConfig()):
        self.read_len = read_len
        self.cfg = cfg
        self._pending: list[tuple[int, np.ndarray]] = []
        self._next_id = 0
        self.stats = dict(requests=0, reads=0, padded_reads=0,
                          bucket_hist={})

    @property
    def pending_reads(self) -> int:
        return sum(len(r) for _, r in self._pending)

    def submit(self, reads: np.ndarray) -> int:
        reads = np.asarray(reads)
        assert reads.ndim == 2 and reads.shape[1] == self.read_len, \
            f"expected (n, {self.read_len}) reads, got {reads.shape}"
        # empty requests are rejected up front: an all-empty flush would
        # otherwise drain the queue without ever resolving their ids
        assert len(reads) >= 1, "empty read batch"
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, reads))
        self.stats["requests"] += 1
        self.stats["reads"] += len(reads)
        return rid

    def drain(self):
        """-> (reads (N, rl), buckets [sizes], spans {rid: (lo, hi)})."""
        if not self._pending:
            return (np.zeros((0, self.read_len), np.uint8), [], {})
        spans, off = {}, 0
        for rid, r in self._pending:
            spans[rid] = (off, off + len(r))
            off += len(r)
        reads = np.concatenate([r for _, r in self._pending])
        self._pending = []
        buckets = pow2_buckets(len(reads), lo=self.cfg.bucket_min,
                               hi=self.cfg.bucket_max)
        self.stats["padded_reads"] += sum(buckets) - len(reads)
        for b in buckets:
            hist = self.stats["bucket_hist"]
            hist[b] = hist.get(b, 0) + 1
        return reads, buckets, spans


class MappingService:
    """Single-device mapping service: batcher + streaming engine.

    ``submit`` queues a request; ``flush`` drains the batcher, streams the
    coalesced buckets through ``map_reads`` (full buckets as one
    multi-chunk streamed call, the residue bucket as its own pow-2 shape)
    and returns ``{request_id: MappingResult}``.
    """

    def __init__(self, index: GenomeIndex, cfg: MapperConfig | None = None,
                 batcher: BatcherConfig = BatcherConfig()):
        self.index = index
        self.cfg = cfg or MapperConfig(read_len=index.read_len, k=index.k,
                                       w=index.w, eth=index.eth)
        self.batcher = ReadBatcher(self.cfg.read_len, batcher)

    def submit(self, reads: np.ndarray) -> int:
        return self.batcher.submit(reads)

    def flush(self) -> dict[int, MappingResult]:
        reads, buckets, spans = self.batcher.drain()
        if not buckets:
            return {}
        hi = self.batcher.cfg.bucket_max
        n_full = sum(1 for b in buckets if b == hi)
        parts = []
        if n_full:  # full buckets: one streamed multi-chunk call
            cfg = dataclasses.replace(self.cfg, chunk_reads=hi)
            parts.append(map_reads(self.index, reads[: n_full * hi], cfg))
        rest = reads[n_full * hi :]
        if len(rest):  # residue: its own pow-2 chunk shape (padded inside)
            cfg = dataclasses.replace(self.cfg, chunk_reads=buckets[-1])
            parts.append(map_reads(self.index, rest, cfg))

        def cat(field):
            arrs = [getattr(p, field) for p in parts]
            return arrs[0] if len(arrs) == 1 else np.concatenate(arrs)

        fields = {f: cat(f) for f in ("position", "distance", "mapped",
                                      "ops", "op_count", "linear_dist",
                                      "n_candidates")}
        out = {}
        for rid, (lo, hi_) in spans.items():
            out[rid] = MappingResult(
                **{f: v[lo:hi_] for f, v in fields.items()},
                stats=None)
        return out
