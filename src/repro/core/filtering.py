"""Pre-alignment filtering (paper Sec. V-D) + the base-count baseline.

The paper replaces the popular base-count heuristic with an exact banded
linear WF distance (Sec. III-A).  Both are provided: ``base_count_filter``
is the baseline the paper cites (eliminates ~68% of PLs on average at some
accuracy cost); ``linear_wf_filter`` is DART-PIM's mechanism.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import wf_backend as wfb
from .linear_wf import banded_wf  # noqa: F401 — re-exported for callers


def gather_windows(segments: jnp.ndarray, occ_idx: jnp.ndarray,
                   mini_pos: jnp.ndarray, *, read_len: int, k: int,
                   eth: int, win_eth: int | None = None) -> jnp.ndarray:
    """Slice per-candidate reference windows out of materialized segments.

    segments: (P_total, seg_len); occ_idx: (..., ) rows; mini_pos: (...,)
    minimizer offset within the read (broadcast-compatible with occ_idx).
    Returns windows (..., read_len + 2*win_eth) where window position p holds
    the reference base at (expected read start - win_eth + p).

    Segment row for occurrence at reference pos q spans
    ref[q - pad : q - pad + seg_len], pad = read_len + eth - k.  The read's
    expected start is (q - o) for minimizer offset o, i.e. segment-local
    index (pad - o); the WF window begins win_eth earlier.
    """
    win_eth = eth if win_eth is None else win_eth
    assert win_eth <= eth, "segment slack only covers the indexing eth"
    pad = read_len + eth - k
    wlen = read_len + 2 * win_eth
    starts = pad - mini_pos - win_eth  # (...,) >= eth - win_eth >= 0

    def slice_one(row, start):
        return jax.lax.dynamic_slice_in_dim(segments[row], start, wlen)

    flat_rows = occ_idx.reshape(-1)
    flat_starts = jnp.broadcast_to(starts, occ_idx.shape).reshape(-1)
    wins = jax.vmap(slice_one)(flat_rows, flat_starts)
    return wins.reshape(occ_idx.shape + (wlen,))


@partial(jax.jit, static_argnames=("eth", "backend", "block_r"))
def linear_wf_filter(reads: jnp.ndarray, windows: jnp.ndarray,
                     occ_valid: jnp.ndarray, eth: int = 6,
                     backend: str = "jnp", block_r: int = 512):
    """Banded linear WF distance per candidate; invalid -> saturated.

    reads: (R, rl); windows: (R, M, P, rl + 2*eth); occ_valid: (R, M, P).
    ``backend`` selects the jnp reference or the Pallas kernel (see
    ``repro.core.wf_backend``; ``block_r`` is the kernel lane-block size).
    Returns distances (R, M, P) int32 in [0, eth+1].
    """
    R, M, P, _ = windows.shape
    s1 = jnp.broadcast_to(reads[:, None, None, :], (R, M, P, reads.shape[-1]))
    dist_end, dist_min = wfb.linear_wf_dist(s1, windows, eth=eth,
                                            backend=backend, block_r=block_r)
    sat = eth + 1
    return jnp.where(occ_valid, dist_end, sat), jnp.where(occ_valid, dist_min,
                                                          sat)


def collapse_candidates(lin_end: jnp.ndarray, threshold: int):
    """(4) min extraction + filter: collapse the PL axis to the best
    candidate per (read, minimizer) and apply the filter threshold.

    lin_end (..., P) int32 (invalid slots hold the linear sat value) ->
    (best_pl (...,), best_lin (...,), pass_filter (...,)).  Shared by the
    padded reference, both compacted engines and the distributed stage B
    so the winner/filter semantics cannot drift between paths.
    """
    best_pl = jnp.argmin(lin_end, axis=-1)
    best_lin = jnp.take_along_axis(lin_end, best_pl[..., None],
                                   -1)[..., 0]
    return best_pl, best_lin, best_lin <= threshold


@jax.jit
def base_count_filter(reads: jnp.ndarray, windows: jnp.ndarray,
                      occ_valid: jnp.ndarray, threshold: int = 6):
    """Base-count histogram filter [Alser et al.] — the cited baseline.

    Compares per-base counts of the read vs. the aligned reference window
    (central read_len slice); L1/2 histogram distance lower-bounds the edit
    distance restricted to substitutions+indels, so ``hist > threshold``
    safely discards.
    Returns (keep (R,M,P) bool, hist_dist (R,M,P) int32).
    """
    rl = reads.shape[-1]
    wlen = windows.shape[-1]
    off = (wlen - rl) // 2
    centre = windows[..., off : off + rl]
    dists = []
    for b in range(4):
        h1 = jnp.sum(reads == b, axis=-1).astype(jnp.int32)
        h2 = jnp.sum(centre == b, axis=-1).astype(jnp.int32)
        dists.append(jnp.abs(h1[:, None, None] - h2))
    hist = sum(dists) // 2
    keep = (hist <= threshold) & occ_valid
    return keep, hist
