"""DART-PIM core: the paper's contribution as composable JAX modules.

The public mapping API is the ``Mapper`` session (``repro.core.mapper``);
everything else is the stage library it orchestrates.
"""
from . import (affine_wf, costmodel, distributed, encoding, filtering, index,
               linear_wf, mapper, minimizers, pipeline, resilience, seeding,
               serving)  # noqa: F401
from .index import GenomeIndex, build_index  # noqa: F401
from .mapper import Mapper, MapperStats, MappingPlan  # noqa: F401
from .pipeline import MapperConfig, MappingResult, map_reads  # noqa: F401
from .resilience import (AdmissionConfig, FaultInjector,  # noqa: F401
                         MappingError, ResilientMapper, RetryPolicy,
                         ShedError)
from .serving import BatcherConfig, MappingService  # noqa: F401
