"""DART-PIM core: the paper's contribution as composable JAX modules."""
from . import (affine_wf, costmodel, distributed, encoding, filtering, index,
               linear_wf, minimizers, pipeline, seeding)  # noqa: F401
from .index import GenomeIndex, build_index  # noqa: F401
from .pipeline import MapperConfig, MappingResult, map_reads  # noqa: F401
