"""Candidate compaction: static-capacity valid-only buckets (Sec. V dataflow).

DART-PIM's filtering stage exists so the expensive affine WF only runs on the
few candidates the linear WF admits.  The padded reference pipeline ignores
that: it executes every slot of the static ``(R, M, P)`` candidate tensor,
valid or not.  This module supplies the primitives of the compacted execution
engine:

  * ``bucket_capacity``  — host-side choice of a static lane-aligned
    power-of-two capacity for a measured candidate count, so jit recompiles
    are bounded (one compile per occupied bucket size, not per batch);
  * ``compact_indices``  — inside-jit stable compaction of a boolean mask
    into a ``(cap,)`` slot->flat-index table (cumsum + scatter, no sort);
  * ``scatter_to``       — inverse scatter of per-slot results back to the
    flat candidate tensor, invalid slots filled with a sentinel.

The compacted engine keeps one WF *instance* per lane (the crossbar-row
mapping of the Pallas kernels), so capacities are aligned to the kernel block
size ``block_r`` — a power of two itself, making "power-of-two and
lane-aligned" a single rounding.
"""
from __future__ import annotations

import jax.numpy as jnp


def bucket_capacity(count: int, *, align: int, cap_max: int) -> int:
    """Smallest power-of-two >= count, >= align, <= next_pow2(cap_max).

    ``count`` is a *host* integer (the measured number of valid candidates);
    the result is used as a static shape, so equal buckets reuse the same
    compiled executable.  ``align`` must be a power of two (the Pallas
    ``block_r``); the rounded capacity is then automatically lane-aligned.
    """
    assert align >= 1 and (align & (align - 1)) == 0, "align must be a pow2"
    cap = max(int(count), 1)
    cap = 1 << (cap - 1).bit_length()          # next power of two
    cap = max(cap, align)
    ceil_ = max(cap_max, 1)
    ceil_ = 1 << (ceil_ - 1).bit_length()
    return min(cap, max(ceil_, align))


def compact_indices(valid: jnp.ndarray, cap: int):
    """Compact a flat boolean mask into a static-capacity slot table.

    valid: (N,) bool.  Returns (slots (cap,) int32, slot_valid (cap,) bool)
    where ``slots[s]`` is the flat index of the s-th valid entry (original
    order preserved) and ``slot_valid[s]`` marks occupied slots.  Entries
    beyond ``cap`` valids are dropped (callers pick cap >= count on the
    host, so this only triggers at the cap_max ceiling).
    """
    N = valid.shape[0]
    rank = jnp.cumsum(valid.astype(jnp.int32)) - 1        # (N,)
    slot = jnp.where(valid & (rank < cap), rank, cap)     # overflow -> cap
    slots = jnp.zeros((cap + 1,), jnp.int32).at[slot].set(
        jnp.arange(N, dtype=jnp.int32), mode="drop")[:cap]
    slot_valid = jnp.zeros((cap + 1,), bool).at[slot].set(
        True, mode="drop")[:cap]
    return slots, slot_valid


def scatter_to(n_flat: int, slots: jnp.ndarray, slot_valid: jnp.ndarray,
               values: jnp.ndarray, fill) -> jnp.ndarray:
    """Scatter per-slot ``values`` back to a (n_flat, ...) tensor.

    Unoccupied candidate positions get ``fill``.  Invalid slots write to a
    shadow row that is sliced off, so duplicate slot 0 entries never clobber
    candidate 0.
    """
    dst = jnp.where(slot_valid, slots, n_flat)
    out = jnp.full((n_flat + 1,) + values.shape[1:], fill, values.dtype)
    return out.at[dst].set(values, mode="drop")[:n_flat]
