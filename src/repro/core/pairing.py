"""Host-side paired-end resolution: proper pairs, mate rescue, MAPQ.

DART-PIM (and this reproduction's engine) maps each mate independently;
what makes the output *paired-end* is the host-side reduce that the
paper's main controller would own.  This module is that reduce:

* **proper pairs** — both mates mapped, FR orientation (the upstream
  mate forward, the downstream mate reverse-complement: the standard
  Illumina library geometry), and an observed insert size inside a
  window derived from a **running median** of the batch's own
  concordant pairs (``InsertSizeTracker``) — no insert-size parameter
  to mistune;
* **mate rescue** — a pair with exactly one mapped mate re-aligns the
  unmapped mate with a banded affine WF sweep over the window where the
  library geometry predicts it (anchor position ± the tracked insert
  window), accepting only below a distance threshold: a real alignment,
  not a positional guess;
* **MAPQ** — a calibrated 0..60 score per mate from the engine's
  best-vs-second-best affine distance gap (``MappingResult.distance2``,
  the runner-up at a *different* locus) plus pair concordance: proper
  pairs are promoted, discordant ones demoted, rescued mates are capped
  by their anchor's confidence.  Mapped records therefore always carry
  MAPQ <= 254 (255 stays the single-end path's "unavailable").

Everything here is numpy post-processing over two ``MappingResult``
halves of one stacked engine batch (``Mapper.map_pairs``), so both
topologies — including the mesh path, whose stage B has no traceback —
pair identically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .encoding import revcomp
from .pipeline import MapperConfig, MappingResult

MAPQ_MAX = 60            # score ceiling (BWA/minimap2 convention, << 254)
_GAP_SCALE = 6           # MAPQ points per unit of best-vs-2nd distance gap
_PROPER_BONUS = 8        # concordant-pair promotion
_RESCUE_CAP = 17         # rescued mate: placed by its anchor, capped by it


# --------------------------------------------------------------------------
# Insert-size tracking (the running-median window)
# --------------------------------------------------------------------------

class InsertSizeTracker:
    """Running median + MAD window over observed FR insert sizes.

    ``update`` feeds the insert sizes of orientation-concordant pairs
    (bounded memory: only the most recent ``max_samples`` are kept);
    ``window()`` returns the ``[lo, hi]`` acceptance interval — median
    ± ``window_mads`` scaled-MAD half-widths, floored so a low-variance
    library cannot collapse the window to a point.  Until ``min_samples``
    inserts have been seen it reports the permissive ``default_window``,
    so the first chunk of a stream can bootstrap itself (observe, then
    resolve).
    """

    def __init__(self, *, max_samples: int = 4096, window_mads: float = 8.0,
                 min_samples: int = 32,
                 default_window: tuple[int, int] = (0, 10_000)):
        self.max_samples = max_samples
        self.window_mads = window_mads
        self.min_samples = min_samples
        self.default_window = default_window
        self._samples: list[int] = []
        self.n_observed = 0

    def update(self, inserts) -> None:
        vals = [int(v) for v in np.asarray(inserts).reshape(-1)]
        self.n_observed += len(vals)
        self._samples.extend(vals)
        if len(self._samples) > self.max_samples:
            self._samples = self._samples[-self.max_samples:]

    @property
    def median(self) -> float | None:
        if len(self._samples) < self.min_samples:
            return None
        return float(np.median(self._samples))

    def _mad_window(self) -> tuple[int, int]:
        arr = np.asarray(self._samples, dtype=np.float64)
        med = float(np.median(arr))
        mad = float(np.median(np.abs(arr - med)))
        half = max(self.window_mads * 1.4826 * mad, 0.25 * med, 16.0)
        return max(int(med - half), 0), int(med + half)

    def window(self) -> tuple[int, int]:
        if len(self._samples) < self.min_samples:
            return self.default_window
        return self._mad_window()

    def rescue_window(self, min_samples: int = 4) -> tuple[int, int] | None:
        """Insert window for the mate-rescue sweep, or None when there is
        nothing to calibrate from.  Rescue needs a *bounded* interval (a
        stride-1 WF sweep over it), so it trusts the MAD window as soon
        as a handful of concordant inserts exist — unlike :meth:`window`,
        which stays permissive until ``min_samples`` for judging
        properness."""
        if len(self._samples) < min_samples:
            return None
        return self._mad_window()


# --------------------------------------------------------------------------
# Pair resolution
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PairResolution:
    """Per-pair outcome of ``resolve_pairs`` (all arrays length n_pairs).

    ``res1``/``res2`` are *copies* of the inputs with rescued mates
    filled in (position/strand/mapped/distance); the caller's results
    are never mutated.  ``insert`` is the observed fragment length for
    orientation-concordant pairs (0 otherwise).
    """
    res1: MappingResult
    res2: MappingResult
    proper: np.ndarray       # (n,) bool — FR orientation + insert in window
    mapq1: np.ndarray        # (n,) int32 0..MAPQ_MAX
    mapq2: np.ndarray        # (n,) int32
    rescued1: np.ndarray     # (n,) bool — mate 1 was placed by rescue
    rescued2: np.ndarray     # (n,) bool
    insert: np.ndarray       # (n,) int32 observed FR fragment length
    stats: dict


def _strands(res: MappingResult) -> np.ndarray:
    s = res.strand
    if s is None:  # single-strand runs: everything mapped forward
        return np.zeros(len(res.position), dtype=np.int8)
    return np.asarray(s)


def _fr_geometry(pos1, s1, pos2, s2, read_len: int):
    """FR-orientation mask + fragment length in global flat coordinates.

    A pair is FR-oriented when the mates face each other: opposite
    strands and the forward mate upstream of (or overlapping) the
    reverse mate.  The fragment spans the forward mate's start to the
    reverse mate's end (footprint approximated by ``read_len`` — the
    band keeps true footprints within a few bases of it).
    """
    opposite = s1 != s2
    fwd_pos = np.where(s1 == 0, pos1, pos2)
    rev_pos = np.where(s1 == 0, pos2, pos1)
    facing = fwd_pos <= rev_pos
    insert = rev_pos + read_len - fwd_pos
    return opposite & facing, insert.astype(np.int32)


def _copy_result(res: MappingResult) -> MappingResult:
    fields = {f.name: getattr(res, f.name)
              for f in dataclasses.fields(MappingResult)}
    for name in ("position", "distance", "distance2", "mapped", "strand"):
        if fields[name] is not None:
            fields[name] = np.array(fields[name], copy=True)
    return MappingResult(**fields)


def _rescue_candidates(anchor_pos, anchor_strand, window, read_len,
                       max_windows: int):
    """Candidate start positions for the unmapped mate, from the anchor's
    locus and the insert window.  Stride 1 — a start offset *into* the
    band costs gap penalties (the band is end-anchored), so skipping
    starts would misprice in-between placements; when the interval
    exceeds ``max_windows`` the sweep coarsens just enough to fit."""
    lo_ins, hi_ins = window
    if anchor_strand == 0:
        # forward anchor at p: reverse mate starts in
        # [p + lo - rl, p + hi - rl]
        lo = anchor_pos + lo_ins - read_len
        hi = anchor_pos + hi_ins - read_len
    else:
        # reverse anchor ending at p + rl: forward mate starts in
        # [p + rl - hi, p + rl - lo]
        lo = anchor_pos + read_len - hi_ins
        hi = anchor_pos + read_len - lo_ins
    step = max(1, -(-(hi - lo + 1) // max_windows))
    return np.arange(lo, hi + 1, step, dtype=np.int64)


def _rescue(res_un, res_an, idx, reads_un, ref, cfg: MapperConfig,
            window, max_dist: int, max_windows: int, rescued) -> int:
    """Re-align the unmapped mates ``idx`` of ``res_un`` near their
    anchors in ``res_an``; fill accepted placements in-place (``res_un``
    is already a private copy).  Returns the number rescued."""
    import jax.numpy as jnp

    from . import wf_backend as wfb

    rl = cfg.read_len
    G = len(ref)
    # sentinel padding: candidate windows near the reference edges clip
    # into never-matching bases instead of wrapping or crashing
    pad = np.full(G + 2 * (rl + 2 * cfg.eth), 4, dtype=np.uint8)
    off0 = rl + 2 * cfg.eth
    pad[off0 : off0 + G] = ref

    an_strand = _strands(res_an)
    n_rescued = 0
    s1_rows, win_rows, meta = [], [], []
    for i in idx:
        sa = int(an_strand[i])
        starts = _rescue_candidates(int(res_an.position[i]), sa,
                                    window, rl, max_windows)
        # a placement must fit wholly inside the reference: a start
        # hanging off either edge would score against sentinel padding
        # and then emit a coordinate that disagrees with the alignment
        starts = starts[(starts >= 0) & (starts <= G - rl)][:max_windows]
        if not len(starts):
            continue
        # FR: the rescued mate sits on the opposite strand of its anchor;
        # the engine's convention is "revcomp encoding aligned here"
        mate_strand = 1 - sa
        aligned = revcomp(reads_un[i]) if mate_strand else reads_un[i]
        for p in starts:
            w0 = int(p) + off0 - cfg.eth
            win_rows.append(pad[w0 : w0 + rl + 2 * cfg.eth])
            s1_rows.append(aligned)
            meta.append((i, int(p), mate_strand))
    if not s1_rows:
        return 0
    # pad the stacked sweep to a pow-2 bucket: the banded WF is jitted
    # per static shape, and the rescue workload varies every chunk — the
    # bucket makes shapes repeat so streams hit the compile cache
    # instead of re-tracing per chunk (same convention as the engine's
    # capacity buckets)
    from .compaction import bucket_capacity
    n_rows = len(s1_rows)
    cap = bucket_capacity(n_rows, align=128, cap_max=n_rows)
    s1_arr = np.zeros((cap, rl), dtype=np.uint8)
    win_arr = np.full((cap, rl + 2 * cfg.eth), 4, dtype=np.uint8)
    s1_arr[:n_rows] = np.stack(s1_rows)
    win_arr[:n_rows] = np.stack(win_rows)
    dist, _ = wfb.affine_wf_dist(jnp.asarray(s1_arr), jnp.asarray(win_arr),
                                 eth=cfg.eth, sat=cfg.sat_affine,
                                 backend="jnp")
    dist = np.asarray(dist)[:n_rows]
    best: dict[int, tuple[int, int, int]] = {}
    for (i, p, ms), d in zip(meta, dist):
        d = int(d)
        if d <= max_dist and (i not in best or d < best[i][0]
                              or (d == best[i][0] and p < best[i][1])):
            best[i] = (d, p, ms)
    for i, (d, p, ms) in best.items():
        res_un.position[i] = p
        res_un.distance[i] = d
        res_un.mapped[i] = True
        if res_un.strand is not None:
            res_un.strand[i] = ms
        if res_un.distance2 is not None:
            # a rescue sweep sees one window, not the genome: no runner-up
            # evidence, so the gap term must not claim uniqueness
            res_un.distance2[i] = d
        rescued[i] = True
        n_rescued += 1
    return n_rescued


def compute_mapq(distance, distance2, mapped, *, sat: int,
                 proper=None, mate_mapped=None) -> np.ndarray:
    """Calibrated 0..``MAPQ_MAX`` mapping quality per read.

    Base score is the best-vs-second-best affine distance gap
    (``distance2 - distance``; a unique locus has ``distance2 == sat``
    and earns the full gap), discounted by the winner's own distance.
    Pair concordance then adjusts: proper pairs gain ``_PROPER_BONUS``,
    discordant both-mapped pairs are halved, a lone mapped mate keeps
    its solo score.  Unmapped reads are 0.
    """
    d1 = np.asarray(distance, dtype=np.int64)
    mapped = np.asarray(mapped, dtype=bool)
    if distance2 is None:  # no runner-up accounting on this path: assume a
        d2 = d1 + 3        # modest gap rather than claiming uniqueness
    else:
        d2 = np.asarray(distance2, dtype=np.int64)
    gap = np.clip(d2 - d1, 0, sat)
    mapq = np.clip(_GAP_SCALE * gap - d1, 0, MAPQ_MAX)
    if proper is not None and mate_mapped is not None:
        proper = np.asarray(proper, dtype=bool)
        discordant = ~proper & np.asarray(mate_mapped, dtype=bool)
        mapq = np.where(proper, np.minimum(mapq + _PROPER_BONUS, MAPQ_MAX),
                        mapq)
        mapq = np.where(discordant, mapq // 2, mapq)
    return np.where(mapped, mapq, 0).astype(np.int32)


def _same_contig(pos1, pos2, contig_starts) -> np.ndarray:
    """True where both (global, flat) positions fall inside the same
    contig of a multi-contig reference.  ``contig_starts`` are the
    contigs' global offsets, sorted ascending (``Contig.offset``)."""
    starts = np.asarray(contig_starts)
    if starts.size <= 1:
        return np.ones(len(pos1), dtype=bool)
    c1 = np.searchsorted(starts, pos1, side="right")
    c2 = np.searchsorted(starts, pos2, side="right")
    return c1 == c2


def resolve_pairs(res1: MappingResult, res2: MappingResult, *,
                  cfg: MapperConfig, tracker: InsertSizeTracker | None = None,
                  ref: np.ndarray | None = None,
                  reads1: np.ndarray | None = None,
                  reads2: np.ndarray | None = None,
                  contig_starts=None,
                  rescue_max_dist: int | None = None,
                  rescue_max_windows: int = 512) -> PairResolution:
    """Resolve one batch of mate results into pairs.

    ``res1``/``res2`` are the per-mate halves of a stacked batch
    (``Mapper.map_pairs``), in global flat-reference coordinates.  The
    ``tracker`` carries insert-size state across batches of a stream
    (pass the same instance to every call); this batch's own concordant
    inserts are observed *before* the window is applied, so the first
    batch bootstraps itself.  ``ref`` (the flat uint8 reference) plus
    ``reads1``/``reads2`` (the as-sequenced base codes) enable mate
    rescue; without them rescue is skipped.  ``contig_starts`` (the
    contigs' global offsets on a multi-contig reference) excludes
    cross-contig mates from FR concordance — a chimeric pair must never
    earn 0x2 or feed the insert tracker, even during the permissive
    bootstrap window.  Returns a ``PairResolution``; the inputs are not
    mutated.
    """
    n = len(res1.position)
    if len(res2.position) != n:
        raise ValueError(f"mate result batches must align pairwise: "
                         f"{n} vs {len(res2.position)}")
    tracker = tracker if tracker is not None else InsertSizeTracker()
    res1, res2 = _copy_result(res1), _copy_result(res2)
    m1, m2 = np.asarray(res1.mapped, bool), np.asarray(res2.mapped, bool)
    s1, s2 = _strands(res1), _strands(res2)

    def _concordant(mapped_both):
        fr, ins = _fr_geometry(res1.position, s1, res2.position, s2,
                               cfg.read_len)
        fr &= mapped_both
        if contig_starts is not None:
            fr &= _same_contig(res1.position, res2.position, contig_starts)
        return fr, ins

    both = m1 & m2
    fr, insert = _concordant(both)
    tracker.update(insert[fr])  # observe before judging: running median

    n_rescued = 0
    rescued1 = np.zeros(n, dtype=bool)
    rescued2 = np.zeros(n, dtype=bool)
    win = (tracker.rescue_window() if ref is not None
           and reads1 is not None and reads2 is not None else None)
    if win is not None:
        max_dist = cfg.eth if rescue_max_dist is None else rescue_max_dist
        # quarantined reads (resilience layer: block failed after retries)
        # carry synthesized unmapped rows — their bases never went through
        # the engine, so they must neither anchor a rescue nor be rescued
        f1 = res1.failed if res1.failed is not None else np.zeros(n, bool)
        f2 = res2.failed if res2.failed is not None else np.zeros(n, bool)
        only1 = np.flatnonzero(m1 & ~m2 & ~f1 & ~f2)
        only2 = np.flatnonzero(m2 & ~m1 & ~f1 & ~f2)
        n_rescued += _rescue(res2, res1, only1, np.asarray(reads2),
                             ref, cfg, win, max_dist, rescue_max_windows,
                             rescued2)
        n_rescued += _rescue(res1, res2, only2, np.asarray(reads1),
                             ref, cfg, win, max_dist, rescue_max_windows,
                             rescued1)
        if n_rescued:  # rescued placements can complete proper pairs
            m1 = np.asarray(res1.mapped, bool)
            m2 = np.asarray(res2.mapped, bool)
            both = m1 & m2
            s1, s2 = _strands(res1), _strands(res2)
            fr, insert = _concordant(both)

    lo, hi = tracker.window()
    proper = fr & (insert >= lo) & (insert <= hi)
    insert = np.where(fr, insert, 0).astype(np.int32)

    mapq1 = compute_mapq(res1.distance, res1.distance2, m1,
                         sat=cfg.sat_affine, proper=proper, mate_mapped=m2)
    mapq2 = compute_mapq(res2.distance, res2.distance2, m2,
                         sat=cfg.sat_affine, proper=proper, mate_mapped=m1)
    # a rescued mate exists only because its anchor placed it: its
    # confidence cannot exceed the anchor's
    mapq2 = np.where(rescued2, np.minimum(np.minimum(mapq1, _RESCUE_CAP),
                                          mapq2), mapq2)
    mapq1 = np.where(rescued1, np.minimum(np.minimum(mapq2, _RESCUE_CAP),
                                          mapq1), mapq1)

    stats = dict(n_pairs=n, n_both_mapped=int(both.sum()),
                 n_proper=int(proper.sum()), n_rescued=n_rescued,
                 n_discordant=int((both & ~proper).sum()),
                 insert_median=tracker.median,
                 insert_window=(lo, hi))
    return PairResolution(res1=res1, res2=res2, proper=proper,
                          mapq1=mapq1, mapq2=mapq2,
                          rescued1=rescued1, rescued2=rescued2,
                          insert=insert, stats=stats)
