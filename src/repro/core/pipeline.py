"""End-to-end read mapping (paper Secs. V-B .. V-E), single-shard version.

Stages (numbers = the circled steps of paper Fig. 6):
  (1)(2) seeding     — minimizer lookup, candidate PLs       (seeding.py)
  (3)    linear WF   — banded distance for every candidate   (filtering.py)
  (4)    min extract — best PL per (read, minimizer)
  (5)(6) affine WF   — alignment + traceback for the winners (affine_wf.py)
  (7)    reduce      — best PL per read across minimizers

Two execution engines share these semantics bit-for-bit:

``engine="padded"`` — the fully-jit reference: one compiled program that
runs the linear WF over every slot of the static ``(R, M, P)`` candidate
tensor (invalid ones included) and the affine WF over every ``(R, M)``
winner, direction planes and all.

``engine="compacted"`` (default) — the candidate-compacted engine that
mirrors DART-PIM's actual dataflow: seeding output is flattened and
compacted to valid-only candidates in a static power-of-two, lane-aligned
capacity bucket (``repro.core.compaction``); the linear WF runs on just
those instances; the filter threshold is applied *before* the affine stage,
which then runs a distance-only pass on the compacted survivors; the
dirs-emitting affine pass + traceback run solely on the one winner per
read.  Capacities are chosen host-side from the measured counts, so jit
recompiles are bounded by the number of distinct bucket sizes.  Large read
batches stream through in ``chunk_reads``-sized chunks instead of
materializing one giant window tensor.

Both engines run their WF inner loops on the backend selected by
``MapperConfig.wf_backend``: the pure-jnp reference or the Pallas kernels
of ``repro.kernels`` (interpret mode on CPU, compiled on TPU).

The distributed version in ``repro.core.distributed`` wraps the same
stages with an all_to_all seeding exchange over the device mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import affine_wf
from . import wf_backend as wfb
from .compaction import bucket_capacity, compact_indices, scatter_to
from .filtering import gather_windows, linear_wf_filter
from .index import GenomeIndex
from .linear_wf import banded_wf
from .seeding import SeedParams, seed_reads


@dataclasses.dataclass(frozen=True)
class MapperConfig:
    read_len: int = 150
    k: int = 12
    w: int = 30
    eth: int = 6            # band half-width (linear + affine) — Table III
    sat_affine: int = 32    # affine value saturation (5-bit cells) — Table III
    max_minis: int = 16
    max_pls: int = 32       # linear WF buffer rows per crossbar
    filter_threshold: int = 6
    max_ops: int | None = None
    engine: str = "compacted"     # "compacted" | "padded"
    wf_backend: str = "jnp"       # "jnp" | "pallas"  (see core.wf_backend)
    lin_block_r: int = 512        # linear kernel lanes; linear bucket align
    aff_block_r: int = 256        # affine kernel lanes; affine bucket align
    chunk_reads: int | None = None  # stream reads in chunks of this size

    @property
    def seed_params(self) -> SeedParams:
        return SeedParams(k=self.k, w=self.w, max_minis=self.max_minis,
                          max_pls=self.max_pls)


@dataclasses.dataclass
class MappingResult:
    position: np.ndarray   # (R,) int32 best mapping position (-1 if unmapped)
    distance: np.ndarray   # (R,) int32 affine WF distance
    mapped: np.ndarray     # (R,) bool
    ops: np.ndarray        # (R, max_ops) traceback op codes (END-aligned)
    op_count: np.ndarray   # (R,) int32
    linear_dist: np.ndarray  # (R, M, P) all candidate linear distances
    n_candidates: np.ndarray  # (R,) number of valid PLs seeded
    stats: dict | None = None  # compacted engine: instance-count accounting


@partial(jax.jit, static_argnames=("cfg",))
def map_reads_jax(uniq_kmers, offsets, positions, segments, reads,
                  cfg: MapperConfig):
    """The padded-reference jit pipeline.  Index arrays are device arrays;
    reads (R, rl).  Every (R, M, P) slot is executed, valid or not."""
    R = reads.shape[0]
    seeds = seed_reads(uniq_kmers, offsets, reads, cfg.seed_params)
    occ_idx, occ_valid = seeds["occ_idx"], seeds["occ_valid"]
    mini_pos = seeds["mini_pos"]  # (R, M)

    # (3) linear WF over every candidate
    windows = gather_windows(segments, occ_idx, mini_pos[..., None],
                             read_len=cfg.read_len, k=cfg.k, eth=cfg.eth)
    lin_end, _ = linear_wf_filter(reads, windows, occ_valid, eth=cfg.eth,
                                  backend=cfg.wf_backend,
                                  block_r=cfg.lin_block_r)

    # (4) min extraction per (read, minimizer); filter threshold
    best_pl = jnp.argmin(lin_end, axis=-1)                       # (R, M)
    best_lin = jnp.take_along_axis(lin_end, best_pl[..., None],
                                   -1)[..., 0]                   # (R, M)
    pass_filter = best_lin <= cfg.filter_threshold

    # (5)+(6) affine WF on the per-minimizer winners
    sel_win = jnp.take_along_axis(
        windows, best_pl[..., None, None], axis=2)[:, :, 0]      # (R, M, wlen)
    s1 = jnp.broadcast_to(reads[:, None, :],
                          (R, cfg.max_minis, cfg.read_len))
    aff_end, _, dirs = wfb.affine_wf_dirs(s1, sel_win, eth=cfg.eth,
                                          sat=cfg.sat_affine,
                                          backend=cfg.wf_backend,
                                          block_r=cfg.aff_block_r)
    aff_end = jnp.where(pass_filter, aff_end, cfg.sat_affine)

    # (7) best minimizer per read — min distance, ties -> leftmost position
    # (deterministic across the single-shard and distributed mappers)
    cand_occ = jnp.take_along_axis(occ_idx,
                                   best_pl[..., None], axis=2)[:, :, 0]
    cand_pos = positions[cand_occ] - mini_pos                    # (R, M)
    best_aff = jnp.min(aff_end, axis=-1)
    mapped = best_aff < cfg.sat_affine
    is_best = aff_end == best_aff[:, None]
    pos_key = jnp.where(is_best & (cand_pos >= 0), cand_pos, 2 ** 30)
    position = jnp.min(pos_key, axis=-1)
    best_m = jnp.argmin(jnp.where(pos_key == position[:, None],
                                  jnp.arange(cfg.max_minis)[None, :],
                                  cfg.max_minis), axis=-1)
    position = jnp.where(mapped & (position < 2 ** 30), position, -1)

    # traceback for the winning instance only
    sel_dirs = jnp.take_along_axis(
        dirs, best_m[:, None, None, None], axis=1)[:, 0]         # (R, n, band)
    max_ops = cfg.max_ops or 2 * cfg.read_len + 2
    ops, op_count = affine_wf.traceback(sel_dirs, cfg.eth, max_ops)
    ops = jnp.where(mapped[:, None], ops, affine_wf.OP_NONE)
    op_count = jnp.where(mapped, op_count, 0)

    return dict(position=position, distance=best_aff, mapped=mapped, ops=ops,
                op_count=op_count, linear_dist=lin_end,
                n_candidates=jnp.sum(occ_valid, axis=(1, 2)))


# --------------------------------------------------------------------------
# Compacted execution engine
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "cap"))
def _linear_stage(segments, reads, occ_idx, occ_valid, mini_pos,
                  cfg: MapperConfig, cap: int):
    """(3)+(4): compact valid candidates -> linear WF on ``cap`` instances
    -> scatter distances back -> per-(read, minimizer) min + filter."""
    R = reads.shape[0]
    M, P = cfg.max_minis, cfg.max_pls
    N = R * M * P
    sat = cfg.eth + 1

    slots, slot_ok = compact_indices(occ_valid.reshape(-1), cap)
    r_idx = slots // (M * P)
    m_idx = (slots // P) % M
    occ = occ_idx.reshape(-1)[slots]
    mpos = mini_pos[r_idx, m_idx]

    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (cap, wlen)
    de, _ = wfb.linear_wf_dist(reads[r_idx], wins, eth=cfg.eth,
                               backend=cfg.wf_backend,
                               block_r=cfg.lin_block_r)
    de = jnp.where(slot_ok, de, sat).astype(jnp.int32)
    lin_end = scatter_to(N, slots, slot_ok, de,
                         jnp.int32(sat)).reshape(R, M, P)

    best_pl = jnp.argmin(lin_end, axis=-1)                       # (R, M)
    best_lin = jnp.take_along_axis(lin_end, best_pl[..., None],
                                   -1)[..., 0]                   # (R, M)
    pass_filter = best_lin <= cfg.filter_threshold
    return lin_end, best_pl, pass_filter, jnp.sum(occ_valid, axis=(1, 2))


@partial(jax.jit, static_argnames=("cfg", "cap"))
def _affine_stage(segments, positions, reads, occ_idx, mini_pos, best_pl,
                  pass_filter, cfg: MapperConfig, cap: int):
    """(5)+(7): distance-only affine WF on the compacted filter survivors,
    then the per-read winner reduce (identical tie-breaking to the padded
    engine: min distance, ties -> leftmost position)."""
    R = reads.shape[0]
    M = cfg.max_minis
    sat = cfg.sat_affine

    slots, slot_ok = compact_indices(pass_filter.reshape(-1), cap)
    r_idx = slots // M
    m_idx = slots % M
    pl = best_pl.reshape(-1)[slots]
    occ = occ_idx[r_idx, m_idx, pl]
    mpos = mini_pos[r_idx, m_idx]

    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (cap, wlen)
    ae, _ = wfb.affine_wf_dist(reads[r_idx], wins, eth=cfg.eth, sat=sat,
                               backend=cfg.wf_backend,
                               block_r=cfg.aff_block_r)
    ae = jnp.where(slot_ok, ae, sat).astype(jnp.int32)
    aff_end = scatter_to(R * M, slots, slot_ok, ae,
                         jnp.int32(sat)).reshape(R, M)

    cand_occ = jnp.take_along_axis(occ_idx,
                                   best_pl[..., None], axis=2)[:, :, 0]
    cand_pos = positions[cand_occ] - mini_pos                    # (R, M)
    best_aff = jnp.min(aff_end, axis=-1)
    mapped = best_aff < sat
    is_best = aff_end == best_aff[:, None]
    pos_key = jnp.where(is_best & (cand_pos >= 0), cand_pos, 2 ** 30)
    position = jnp.min(pos_key, axis=-1)
    best_m = jnp.argmin(jnp.where(pos_key == position[:, None],
                                  jnp.arange(M)[None, :], M), axis=-1)
    position = jnp.where(mapped & (position < 2 ** 30), position, -1)
    return best_aff, mapped, position, best_m


@partial(jax.jit, static_argnames=("cfg",))
def _traceback_stage(segments, reads, occ_idx, mini_pos, best_pl, best_m,
                     mapped, cfg: MapperConfig):
    """(6): dirs-emitting affine WF + traceback on the per-read winners only
    — R direction planes instead of (R, M, n*band)."""
    R = reads.shape[0]
    r = jnp.arange(R, dtype=jnp.int32)
    pl = best_pl[r, best_m]
    occ = occ_idx[r, best_m, pl]
    mpos = mini_pos[r, best_m]
    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (R, wlen)
    _, _, dirs = wfb.affine_wf_dirs(reads, wins, eth=cfg.eth,
                                    sat=cfg.sat_affine,
                                    backend=cfg.wf_backend,
                                    block_r=cfg.aff_block_r)
    max_ops = cfg.max_ops or 2 * cfg.read_len + 2
    ops, op_count = affine_wf.traceback(dirs, cfg.eth, max_ops)
    ops = jnp.where(mapped[:, None], ops, affine_wf.OP_NONE)
    op_count = jnp.where(mapped, op_count, 0)
    return ops, op_count


def _map_chunk_compacted(dev, reads: jnp.ndarray, cfg: MapperConfig,
                         n_real: int):
    """One chunk through the staged engine.  Host code between the jit
    stages measures candidate/survivor counts and picks static bucket
    capacities (``bucket_capacity``), so each jit sees a fixed shape.

    ``n_real`` is the unpadded read count: executed-instance stats count
    the whole (shape-static) chunk, but candidate/survivor accounting and
    the padded-equivalent baselines exclude the zero-padding reads so the
    reported pruning reflects the actual workload.
    """
    uniq_kmers, offsets, positions, segments = dev
    R = reads.shape[0]
    M, P = cfg.max_minis, cfg.max_pls

    seeds = seed_reads(uniq_kmers, offsets, reads, cfg.seed_params)
    occ_idx, occ_valid = seeds["occ_idx"], seeds["occ_valid"]
    mini_pos = seeds["mini_pos"]

    n_valid = int(jnp.sum(occ_valid))
    lin_cap = bucket_capacity(n_valid, align=cfg.lin_block_r,
                              cap_max=R * M * P)
    lin_end, best_pl, pass_filter, n_cand = _linear_stage(
        segments, reads, occ_idx, occ_valid, mini_pos, cfg, lin_cap)

    n_surv = int(jnp.sum(pass_filter))
    aff_cap = bucket_capacity(n_surv, align=cfg.aff_block_r, cap_max=R * M)
    best_aff, mapped, position, best_m = _affine_stage(
        segments, positions, reads, occ_idx, mini_pos, best_pl, pass_filter,
        cfg, aff_cap)

    ops, op_count = _traceback_stage(segments, reads, occ_idx, mini_pos,
                                     best_pl, best_m, mapped, cfg)

    if n_real == R:
        n_valid_real, n_surv_real = n_valid, n_surv
    else:
        n_valid_real = int(jnp.sum(occ_valid[:n_real]))
        n_surv_real = int(jnp.sum(pass_filter[:n_real]))
    stats = dict(candidates_valid=n_valid_real,
                 linear_instances=lin_cap,
                 padded_linear_instances=n_real * M * P,
                 survivors=n_surv_real,
                 affine_dist_instances=aff_cap,
                 padded_affine_instances=n_real * M,
                 affine_dirs_instances=n_real)
    out = dict(position=position, distance=best_aff, mapped=mapped, ops=ops,
               op_count=op_count, linear_dist=lin_end, n_candidates=n_cand)
    return out, stats


def _merge_stats(parts: list[dict]) -> dict:
    out = {k: sum(p[k] for p in parts) for k in parts[0]}
    out["pruning_ratio"] = (
        1.0 - out["survivors"] / max(out["candidates_valid"], 1))
    out["n_chunks"] = len(parts)
    return out


def map_reads(index: GenomeIndex, reads: np.ndarray,
              cfg: MapperConfig | None = None) -> MappingResult:
    """Host-friendly wrapper: numpy index + reads -> MappingResult.

    ``cfg.engine`` selects the padded reference or the candidate-compacted
    engine (default); both produce identical positions/distances.  The
    compacted engine streams ``cfg.chunk_reads``-sized read chunks and
    reports its instance accounting in ``MappingResult.stats``.
    """
    cfg = cfg or MapperConfig(read_len=index.read_len, k=index.k, w=index.w,
                              eth=index.eth)
    dev = (jnp.asarray(index.uniq_kmers), jnp.asarray(index.offsets),
           jnp.asarray(index.positions), jnp.asarray(index.segments))

    if cfg.engine == "padded":
        out = map_reads_jax(*dev, jnp.asarray(reads), cfg)
        parts, stats = [out], None
    elif cfg.engine == "compacted":
        R = len(reads)
        chunk = cfg.chunk_reads or max(R, 1)
        parts, stat_parts = [], []
        for c0 in range(0, R, chunk):
            sub = np.asarray(reads[c0 : c0 + chunk])
            pad = chunk - len(sub)
            if pad:  # keep the chunk shape static; trim the outputs below
                sub = np.concatenate(
                    [sub, np.zeros((pad, sub.shape[1]), sub.dtype)])
            out, st = _map_chunk_compacted(dev, jnp.asarray(sub), cfg,
                                           chunk - pad)
            if pad:
                out = {k: v[: chunk - pad] for k, v in out.items()}
            parts.append(out)
            stat_parts.append(st)
        stats = _merge_stats(stat_parts)
    else:
        raise ValueError(f"unknown engine {cfg.engine!r}")

    cat = (lambda k: np.asarray(parts[0][k]) if len(parts) == 1 else
           np.concatenate([np.asarray(p[k]) for p in parts]))
    return MappingResult(position=cat("position"), distance=cat("distance"),
                         mapped=cat("mapped"), ops=cat("ops"),
                         op_count=cat("op_count"),
                         linear_dist=cat("linear_dist"),
                         n_candidates=cat("n_candidates"), stats=stats)


def oracle_map(ref: np.ndarray, reads: np.ndarray, eth: int = 6,
               chunk: int = 4096):
    """Exhaustive banded-WF scan over every reference position (BWA-MEM
    stand-in ground truth for accuracy tests).  O(G * R) — small inputs only.

    Returns ``(best_p, best_d)``: per-read best position (ties -> leftmost)
    and its banded-WF distance, each of shape (R,).
    """
    rl = reads.shape[1]
    G = len(ref)
    pad = np.full(G + 2 * eth + rl, 4, dtype=np.uint8)
    pad[eth : eth + G] = ref
    n_pos = G - rl + 1
    starts = np.arange(n_pos)
    best_d = np.full(len(reads), 10 ** 9, dtype=np.int64)
    best_p = np.full(len(reads), -1, dtype=np.int64)
    win = rl + 2 * eth
    for c0 in range(0, n_pos, chunk):
        c1 = min(c0 + chunk, n_pos)
        idx = starts[c0:c1, None] + np.arange(win)[None, :]
        wins = jnp.asarray(pad[idx])  # (C, win)
        d_end, _ = banded_wf(jnp.asarray(reads)[:, None, :].repeat(c1 - c0, 1),
                             jnp.broadcast_to(wins[None], (len(reads), c1 - c0,
                                                           win)), eth=eth)
        d = np.asarray(d_end)
        for r in range(len(reads)):
            m = int(d[r].argmin())
            if d[r][m] < best_d[r]:
                best_d[r] = d[r][m]
                best_p[r] = c0 + m
    return best_p, best_d
