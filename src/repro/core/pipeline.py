"""End-to-end read mapping (paper Secs. V-B .. V-E), single-shard version.

Stages (numbers = the circled steps of paper Fig. 6):
  (1)(2) seeding     — minimizer lookup, candidate PLs       (seeding.py)
  (3)    linear WF   — banded distance for every candidate   (filtering.py)
  (4)    min extract — best PL per (read, minimizer)
  (5)(6) affine WF   — alignment + traceback for the winners (affine_wf.py)
  (7)    reduce      — best PL per read across minimizers

Two execution engines share these semantics bit-for-bit:

``engine="padded"`` — the fully-jit reference: one compiled program that
runs the linear WF over every slot of the static ``(R, M, P)`` candidate
tensor (invalid ones included) and the affine WF over every ``(R, M)``
winner, direction planes and all.

``engine="compacted"`` (default) — the candidate-compacted engine that
mirrors DART-PIM's actual dataflow: seeding output is flattened and
compacted to valid-only candidates in a static power-of-two, lane-aligned
capacity bucket (``repro.core.compaction``); the linear WF runs on just
those instances; the filter threshold is applied *before* the affine stage,
which then runs a distance-only pass on the compacted survivors; the
dirs-emitting affine pass + traceback run solely on the one winner per
read.  Capacities are chosen host-side from the measured counts, so jit
recompiles are bounded by the number of distinct bucket sizes.  Large read
batches stream through in ``chunk_reads``-sized chunks instead of
materializing one giant window tensor; with ``stream=True`` (default) the
chunks run on the async double-buffered engine of ``repro.core.streaming``
— chunk i+1's transfer+seeding and chunk i-1's result fetch overlap chunk
i's WF compute — while ``stream=False`` is the fully synchronous debug
path that records per-stage wall times in ``stats["stage_times_s"]``.
Both paths execute the same jitted stages with the same capacities and
are bit-identical.

Both engines run their WF inner loops on the backend selected by
``MapperConfig.wf_backend``: the pure-jnp reference or the Pallas kernels
of ``repro.kernels`` (interpret mode on CPU, compiled on TPU).

The distributed version in ``repro.core.distributed`` wraps the same
stages with an all_to_all seeding exchange over the device mesh.

Callers should not drive these stages directly: the public front-end is
the ``Mapper`` session of ``repro.core.mapper``, which owns device
placement, the plan cache, and topology selection (``map_reads`` below is
its deprecation shim).
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import affine_wf
from . import streaming
from . import wf_backend as wfb
from ..obs.tracing import annotate as _annotate
from .compaction import bucket_capacity, compact_indices, scatter_to
from .encoding import revcomp
from .filtering import collapse_candidates, gather_windows, linear_wf_filter
from .index import GenomeIndex, validate_geometry
from .linear_wf import banded_wf
from .seeding import SeedParams, seed_reads


@dataclasses.dataclass(frozen=True)
class MapperConfig:
    read_len: int = 150
    k: int = 12
    w: int = 30
    eth: int = 6            # band half-width (linear + affine) — Table III
    sat_affine: int = 32    # affine value saturation (5-bit cells) — Table III
    max_minis: int = 16
    max_pls: int = 32       # linear WF buffer rows per crossbar
    filter_threshold: int = 6
    max_ops: int | None = None
    engine: str = "compacted"     # "compacted" | "fused" | "padded"
    wf_backend: str = "jnp"       # "jnp" | "pallas"  (see core.wf_backend)
    cigar_mode: str = "eager"     # "eager" | "lazy" | "off": when the
    #                               dirs-emitting traceback pass runs.
    #                               eager = with the batch (default);
    #                               lazy  = deferred until the first
    #                               MappingResult.ops/op_count access;
    #                               off   = never (distance-only consumers;
    #                               SAM CIGARs degrade to "*")
    lin_block_r: int = 512        # linear kernel lanes; linear bucket align
    aff_block_r: int = 256        # affine kernel lanes; affine bucket align
    chunk_reads: int | None = None  # stream reads in chunks of this size
    both_strands: bool = False    # map forward + reverse-complement encodings
    #                               of every read; best (pos, dist, strand)
    #                               wins (see repro.core.mapper)
    stream: bool = True           # double-buffered chunk overlap (compacted
    #                               engine); False = fully synchronous debug
    #                               path with per-stage wall times in stats
    stage_b_survivor_frac: float = 0.5  # distributed stage-B: static affine
    #                               capacity as a fraction of bucket entries
    profile: bool = False         # streamed path: record per-stage
    #                               completion-time offsets into
    #                               stats["stage_times_s"] (the sync path
    #                               always records exclusive wall times)
    stage_b_adaptive: bool = False  # mesh: derive the stage-B survivor
    #                               capacity from the session's observed
    #                               survivor-fraction history instead of
    #                               the static stage_b_survivor_frac
    stage_b_quantile: float = 0.9   # rolling quantile of that history
    stage_b_history: int = 32       # history window (runs)

    ENGINES = ("compacted", "fused", "padded")
    WF_BACKENDS = ("jnp", "pallas")
    CIGAR_MODES = ("eager", "lazy", "off")

    def __post_init__(self):
        """Reject invalid configurations at construction time, with errors
        that name the field — instead of failing deep inside jit tracing
        (or worse, silently misaligning kernel lanes)."""
        validate_geometry(read_len=self.read_len, k=self.k, w=self.w,
                          eth=self.eth)
        if self.engine not in self.ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; expected one "
                             f"of {self.ENGINES}")
        if self.wf_backend not in self.WF_BACKENDS:
            raise ValueError(f"unknown wf_backend {self.wf_backend!r}; "
                             f"expected one of {self.WF_BACKENDS}")
        for name in ("lin_block_r", "aff_block_r"):
            v = getattr(self, name)
            if not (isinstance(v, int) and v >= 1 and (v & (v - 1)) == 0):
                raise ValueError(
                    f"{name}={v!r} must be a positive power of two: it is "
                    f"the Pallas kernel lane block and the bucket-capacity "
                    f"alignment (see repro.core.compaction)")
        if self.chunk_reads is not None and self.chunk_reads < 1:
            raise ValueError(f"chunk_reads={self.chunk_reads!r} must be "
                             f">= 1 (or None for unchunked)")
        if self.cigar_mode not in self.CIGAR_MODES:
            raise ValueError(f"unknown cigar_mode {self.cigar_mode!r}; "
                             f"expected one of {self.CIGAR_MODES}")
        if self.engine == "padded" and self.cigar_mode != "eager":
            raise ValueError(
                'engine="padded" is the fully-eager reference and only '
                f'supports cigar_mode="eager", got {self.cigar_mode!r}')
        if not 0.0 <= self.stage_b_quantile <= 1.0:
            raise ValueError(f"stage_b_quantile={self.stage_b_quantile!r} "
                             f"must be within [0, 1]")
        if self.stage_b_history < 1:
            raise ValueError(f"stage_b_history={self.stage_b_history!r} "
                             f"must be >= 1")

    @classmethod
    def from_index(cls, index, **overrides) -> "MapperConfig":
        """Config matching an index's geometry (``read_len``/``k``/``w``/
        ``eth``), with ``overrides`` applied on top.  Accepts a
        ``GenomeIndex`` or a ``distributed.ShardedIndex`` — the single
        place where index geometry flows into a config, so launchers
        cannot drift out of sync by hand-copying fields."""
        base = dict(read_len=index.read_len, k=index.k, w=index.w,
                    eth=index.eth)
        base.update(overrides)
        return cls(**base)

    @property
    def seed_params(self) -> SeedParams:
        return SeedParams(k=self.k, w=self.w, max_minis=self.max_minis,
                          max_pls=self.max_pls)


@dataclasses.dataclass
class MappingResult:
    """Unified mapping output across every execution path.

    The traceback/accounting fields are ``None`` on paths that do not
    produce them (the mesh topology's stage B computes distances and
    positions only — see ``repro.core.mapper``).  ``stats`` is a
    ``mapper.MapperStats`` on the compacted/mesh paths (dict-compatible
    for the legacy keys) and ``None`` on the padded reference engine.

    With ``cigar_mode="lazy"`` the ``ops``/``op_count`` fields start as
    ``None`` and a ``lazy_tb`` holder carries the per-read winner metadata;
    the first attribute access of either field dispatches the deferred
    on-device traceback and fills both in (reads that never ask for CIGARs
    never pay for them).
    """
    position: np.ndarray   # (R,) int best mapping position, -1 if unmapped
    #                      (int32 device-side up to 2^31-1 bases; int64 on
    #                      the host past that — see core.index.
    #                      device_position_dtype)
    distance: np.ndarray   # (R,) int32 affine WF distance
    mapped: np.ndarray     # (R,) bool
    distance2: np.ndarray | None = None  # (R,) int32 runner-up affine WF
    #                      distance at a *different* locus (beyond the band
    #                      half-width from the winner; ``sat_affine`` when no
    #                      competing locus exists) — the best-vs-second-best
    #                      gap that feeds the MAPQ model (repro.core.pairing)
    strand: np.ndarray | None = None  # (R,) int8 0=forward 1=reverse-
    #                      complement winner; None on single-strand runs
    ops: np.ndarray | None = None   # (R, max_ops) traceback ops (END-aligned)
    op_count: np.ndarray | None = None  # (R,) int32
    linear_dist: np.ndarray | None = None  # (R, M, P) candidate linear dists
    n_candidates: np.ndarray | None = None  # (R,) valid PLs seeded
    stats: object | None = None  # MapperStats (compacted/mesh) | None
    failed: np.ndarray | None = None  # (R,) bool quarantine mask set by the
    #                      resilience layer: True rows exhausted retry +
    #                      bisection and carry synthesized unmapped values
    #                      (position=-1, mapped=False); None on healthy runs
    lazy_tb: object | None = None  # LazyTraceback (cigar_mode="lazy") —
    #                      consumed (set back to None) on materialization

    def __getattribute__(self, name):
        if name in ("ops", "op_count"):
            lt = object.__getattribute__(self, "lazy_tb")
            if lt is not None:
                object.__setattr__(self, "lazy_tb", None)
                ops, cnt = lt.materialize()
                object.__setattr__(self, "ops", ops)
                object.__setattr__(self, "op_count", cnt)
        return object.__getattribute__(self, name)


@partial(jax.jit, static_argnames=("cfg",))
def map_reads_jax(uniq_kmers, offsets, positions, segments, reads,
                  cfg: MapperConfig):
    """The padded-reference jit pipeline.  Index arrays are device arrays;
    reads (R, rl).  Every (R, M, P) slot is executed, valid or not."""
    R = reads.shape[0]
    seeds = seed_reads(uniq_kmers, offsets, reads, cfg.seed_params)
    occ_idx, occ_valid = seeds["occ_idx"], seeds["occ_valid"]
    mini_pos = seeds["mini_pos"]  # (R, M)

    # (3) linear WF over every candidate
    windows = gather_windows(segments, occ_idx, mini_pos[..., None],
                             read_len=cfg.read_len, k=cfg.k, eth=cfg.eth)
    lin_end, _ = linear_wf_filter(reads, windows, occ_valid, eth=cfg.eth,
                                  backend=cfg.wf_backend,
                                  block_r=cfg.lin_block_r)

    # (4) min extraction per (read, minimizer); filter threshold
    best_pl, _, pass_filter = collapse_candidates(lin_end,
                                                  cfg.filter_threshold)

    # (5)+(6) affine WF on the per-minimizer winners
    sel_win = jnp.take_along_axis(
        windows, best_pl[..., None, None], axis=2)[:, :, 0]      # (R, M, wlen)
    s1 = jnp.broadcast_to(reads[:, None, :],
                          (R, cfg.max_minis, cfg.read_len))
    aff_end, _, dirs = wfb.affine_wf_dirs(s1, sel_win, eth=cfg.eth,
                                          sat=cfg.sat_affine,
                                          backend=cfg.wf_backend,
                                          block_r=cfg.aff_block_r)
    aff_end = jnp.where(pass_filter, aff_end, cfg.sat_affine)

    # (7) best minimizer per read — min distance, ties -> leftmost position
    # (deterministic across the single-shard and distributed mappers)
    cand_occ = jnp.take_along_axis(occ_idx,
                                   best_pl[..., None], axis=2)[:, :, 0]
    cand_pos, cand_ok = _cand_positions(positions, cand_occ, mini_pos)
    big = _pos_big(positions)
    best_aff = jnp.min(aff_end, axis=-1)
    mapped = best_aff < cfg.sat_affine
    is_best = aff_end == best_aff[:, None]
    pos_key = jnp.where(is_best & cand_ok, cand_pos, big)
    position = jnp.min(pos_key, axis=-1)
    best_m = jnp.argmin(jnp.where(pos_key == position[:, None],
                                  jnp.arange(cfg.max_minis)[None, :],
                                  cfg.max_minis), axis=-1)
    position = jnp.where(mapped & (position < big), position,
                         _pos_unmapped(positions))
    distance2 = _runner_up_distance(aff_end, cand_pos, cand_ok, position,
                                    cfg.eth, cfg.sat_affine)
    distance2 = _co_optimal_runner_up(lin_end, occ_idx, mini_pos, positions,
                                      position, best_m, best_aff,
                                      distance2, cfg)

    # traceback for the winning instance only
    sel_dirs = jnp.take_along_axis(
        dirs, best_m[:, None, None, None], axis=1)[:, 0]         # (R, n, band)
    max_ops = cfg.max_ops or 2 * cfg.read_len + 2
    ops, op_count = affine_wf.traceback(sel_dirs, cfg.eth, max_ops)
    ops = jnp.where(mapped[:, None], ops, affine_wf.OP_NONE)
    op_count = jnp.where(mapped, op_count, 0)

    return dict(position=position, distance=best_aff, distance2=distance2,
                mapped=mapped, ops=ops, op_count=op_count,
                linear_dist=lin_end,
                n_candidates=jnp.sum(occ_valid, axis=(1, 2)))


def _pos_big(positions):
    """Sentinel strictly above every real mapping position, in the
    positions dtype.  Replaces the old hardcoded ``2**30``, which real
    positions *reach* once the reference passes 2^30 bases — a mapped
    read there would have been reported unmapped."""
    return jnp.asarray(jnp.iinfo(positions.dtype).max, positions.dtype)


def _pos_unmapped(positions):
    """Device-side unmapped sentinel: -1 for signed position dtypes
    (the historical contract), the dtype max for unsigned ones (uint32
    arenas past 2^31 bases) — the host boundary rewrites it to -1."""
    if jnp.issubdtype(positions.dtype, jnp.unsignedinteger):
        return _pos_big(positions)
    return jnp.asarray(-1, positions.dtype)


def _cand_positions(positions, occ, mini_pos):
    """Candidate genome positions ``positions[occ] - mini_pos`` plus a
    validity mask, dtype-safe for signed and unsigned position arrays:
    an unsigned subtraction wraps instead of going negative, so
    validity is tested *before* the subtract (``p >= mini_pos``)."""
    p = positions[occ]
    mp = mini_pos.astype(positions.dtype)
    cp = p - mp
    if jnp.issubdtype(positions.dtype, jnp.unsignedinteger):
        ok = p >= mp
    else:
        ok = cp >= 0
    return cp, ok


def _absdiff(a, b):
    """|a - b| without a signed intermediate (unsigned-dtype-safe)."""
    return jnp.where(a > b, a - b, b - a)


def _runner_up_distance(aff_end, cand_pos, cand_ok, position, eth: int,
                        sat: int):
    """Best affine distance among candidates at a *different* locus than
    the winner (more than the band half-width away — candidates within
    ``eth`` of the winning position are the same alignment seeded from
    another minimizer, not a competitor).  ``sat`` when no competing
    locus exists; both engines share this so their ``distance2`` is
    bit-identical like the rest of the result."""
    far = _absdiff(cand_pos, position[:, None]) > eth
    key = jnp.where((aff_end < sat) & far & cand_ok, aff_end, sat)
    return jnp.min(key, axis=-1).astype(jnp.int32)


def _co_optimal_runner_up(lin_end, occ_idx, mini_pos, positions, position,
                          best_m, best_aff, distance2, cfg: MapperConfig):
    """Fold placement-level competitors into ``distance2``.

    The per-(read, minimizer) reduce collapses placements with
    ``argmin`` (ties -> lowest index), so a repeat copy whose placements
    share *all* the winner's minimizers never reaches the affine survey
    — an ambiguous read would look unique and earn maximal MAPQ.  The
    linear stage's full ``(R, M, P)`` distances still see every
    placement: any far-locus placement at most the filter threshold is a
    competitor, its affine distance estimated as the winner's plus its
    linear-distance excess (exact for exact repeat copies, where the
    excess is 0)."""
    eth, sat = cfg.eth, cfg.sat_affine
    sat_lin = jnp.int32(eth + 1)
    pos_all, _ = _cand_positions(positions, occ_idx,
                                 mini_pos[..., None])          # (R, M, P)
    far = _absdiff(pos_all, position[:, None, None]) > eth
    # min(thr, eth) keeps the linear sat value (= invalid/absent slots)
    # out even when the filter threshold is set above the band
    cand = far & (lin_end <= min(cfg.filter_threshold, eth))
    min_far = jnp.min(jnp.where(cand, lin_end, sat_lin), axis=(1, 2))
    lin_w_all = jnp.min(lin_end, axis=-1)                      # (R, M)
    lin_w = jnp.take_along_axis(lin_w_all, best_m[:, None], 1)[:, 0]
    est = jnp.minimum(best_aff + jnp.maximum(min_far - lin_w, 0), sat)
    return jnp.where(min_far < sat_lin,
                     jnp.minimum(distance2, est.astype(jnp.int32)),
                     distance2)


# --------------------------------------------------------------------------
# Compacted execution engine
# --------------------------------------------------------------------------

def _linear_stage_impl(segments, reads, occ_idx, occ_valid, mini_pos,
                       cfg: MapperConfig, cap: int):
    """(3)+(4): compact valid candidates -> linear WF on ``cap`` instances
    -> scatter distances back -> per-(read, minimizer) min + filter."""
    R = reads.shape[0]
    M, P = cfg.max_minis, cfg.max_pls
    N = R * M * P
    sat = cfg.eth + 1

    slots, slot_ok = compact_indices(occ_valid.reshape(-1), cap)
    r_idx = slots // (M * P)
    m_idx = (slots // P) % M
    occ = occ_idx.reshape(-1)[slots]
    mpos = mini_pos[r_idx, m_idx]

    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (cap, wlen)
    de, _ = wfb.linear_wf_dist(reads[r_idx], wins, eth=cfg.eth,
                               backend=cfg.wf_backend,
                               block_r=cfg.lin_block_r)
    de = jnp.where(slot_ok, de, sat).astype(jnp.int32)
    lin_end = scatter_to(N, slots, slot_ok, de,
                         jnp.int32(sat)).reshape(R, M, P)

    best_pl, _, pass_filter = collapse_candidates(lin_end,
                                                  cfg.filter_threshold)
    return lin_end, best_pl, pass_filter, jnp.sum(occ_valid, axis=(1, 2))


def _affine_stage_impl(segments, positions, reads, occ_idx, mini_pos, best_pl,
                       pass_filter, lin_end_full, cfg: MapperConfig,
                       cap: int):
    """(5)+(7): distance-only affine WF on the compacted filter survivors,
    then the per-read winner reduce (identical tie-breaking to the padded
    engine: min distance, ties -> leftmost position).  ``lin_end_full``
    is the linear stage's (R, M, P) distance tensor, surveyed for
    placement-level co-optimal competitors the per-minimizer collapse
    hides (see ``_co_optimal_runner_up``)."""
    R = reads.shape[0]
    M = cfg.max_minis
    sat = cfg.sat_affine

    slots, slot_ok = compact_indices(pass_filter.reshape(-1), cap)
    r_idx = slots // M
    m_idx = slots % M
    pl = best_pl.reshape(-1)[slots]
    occ = occ_idx[r_idx, m_idx, pl]
    mpos = mini_pos[r_idx, m_idx]

    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (cap, wlen)
    ae, _ = wfb.affine_wf_dist(reads[r_idx], wins, eth=cfg.eth, sat=sat,
                               backend=cfg.wf_backend,
                               block_r=cfg.aff_block_r)
    ae = jnp.where(slot_ok, ae, sat).astype(jnp.int32)
    aff_end = scatter_to(R * M, slots, slot_ok, ae,
                         jnp.int32(sat)).reshape(R, M)

    cand_occ = jnp.take_along_axis(occ_idx,
                                   best_pl[..., None], axis=2)[:, :, 0]
    cand_pos, cand_ok = _cand_positions(positions, cand_occ, mini_pos)
    big = _pos_big(positions)
    best_aff = jnp.min(aff_end, axis=-1)
    mapped = best_aff < sat
    is_best = aff_end == best_aff[:, None]
    pos_key = jnp.where(is_best & cand_ok, cand_pos, big)
    position = jnp.min(pos_key, axis=-1)
    best_m = jnp.argmin(jnp.where(pos_key == position[:, None],
                                  jnp.arange(M)[None, :], M), axis=-1)
    position = jnp.where(mapped & (position < big), position,
                         _pos_unmapped(positions))
    distance2 = _runner_up_distance(aff_end, cand_pos, cand_ok, position,
                                    cfg.eth, sat)
    distance2 = _co_optimal_runner_up(lin_end_full, occ_idx, mini_pos,
                                      positions, position, best_m,
                                      best_aff, distance2, cfg)
    # winner metadata (occurrence row + minimizer offset of the winning
    # instance): everything the traceback pass needs, so it no longer has
    # to re-derive the winner from the full (R, M, P) seeding tensors —
    # which is what lets the strand reduce and the lazy-CIGAR holder carry
    # two small vectors instead of the whole candidate state
    r = jnp.arange(R, dtype=jnp.int32)
    occ_w = cand_occ[r, best_m]
    mpos_w = mini_pos[r, best_m]
    return best_aff, mapped, position, best_m, distance2, occ_w, mpos_w


_linear_stage = partial(jax.jit, static_argnames=("cfg", "cap"))(
    _linear_stage_impl)
_affine_stage = partial(jax.jit, static_argnames=("cfg", "cap"))(
    _affine_stage_impl)


@functools.lru_cache(maxsize=2)
def _stage_jits(donate: bool):
    """Jitted (linear, affine) stages, optionally donating the one buffer
    each consumes exactly once (occ_valid / pass_filter) so streamed chunks
    reuse device allocations instead of growing the arena.  Donation is
    requested only on backends that implement it
    (``streaming.donatable_argnums``); everywhere else the module-level
    non-donating pair is returned so all paths share one executable cache.
    """
    lin_don = streaming.donatable_argnums(3) if donate else ()
    aff_don = streaming.donatable_argnums(6) if donate else ()
    if not lin_don and not aff_don:
        return _linear_stage, _affine_stage
    lin = jax.jit(_linear_stage_impl, static_argnames=("cfg", "cap"),
                  donate_argnums=lin_don)
    aff = jax.jit(_affine_stage_impl, static_argnames=("cfg", "cap"),
                  donate_argnums=aff_don)
    return lin, aff


def _winner_traceback(segments, reads, occ, mpos, mapped,
                      cfg: MapperConfig):
    """(6): fused affine WF + on-device banded traceback on the per-read
    winners only.  Takes the winner metadata the affine stage emits (one
    occurrence row + minimizer offset per read), so the END-aligned op
    rows and counts are the only O(max_ops) arrays that ever exist: on
    the pallas backend the (n, band) direction planes stay in VMEM
    scratch inside the kernel, on the jnp backend they fuse into one jit
    — neither ever crosses D2H."""
    wins = gather_windows(segments, occ, mpos, read_len=cfg.read_len,
                          k=cfg.k, eth=cfg.eth)                  # (R, wlen)
    max_ops = cfg.max_ops or 2 * cfg.read_len + 2
    _, _, ops, op_count = wfb.affine_traceback(
        reads, wins, eth=cfg.eth, sat=cfg.sat_affine, max_ops=max_ops,
        backend=cfg.wf_backend, block_r=cfg.aff_block_r)
    ops = jnp.where(mapped[:, None], ops, affine_wf.OP_NONE)
    op_count = jnp.where(mapped, op_count, 0)
    return ops, op_count


_traceback_stage = partial(jax.jit, static_argnames=("cfg",))(
    _winner_traceback)


def _strand_fold(distance, mapped, position, distance2, n_cand, occ_w,
                 mpos_w, reads, lin_end=None):
    """Device-side fwd-vs-rc winner fold (``mapper._reduce_strands``
    semantics, applied per chunk before anything is fetched): rows
    ``[0:n)`` are the forward encodings, ``[n:2n)`` the reverse
    complements of the same reads.  Lower affine distance wins; ties
    (including both-unmapped) keep forward, so single-strand workloads
    are bit-identical with or without ``both_strands``.  The runner-up
    becomes min(winner strand's second locus, loser strand's best) — an
    opposite-strand hit is a genuine competitor even at the same locus.
    """
    n = distance.shape[0] // 2
    rev = distance[n:] < distance[:n]

    def pick(a):
        return jnp.where(rev.reshape((-1,) + (1,) * (a.ndim - 1)),
                         a[n:], a[:n])

    lose_d1 = jnp.where(rev, distance[:n], distance[n:])
    out = dict(distance=pick(distance), mapped=pick(mapped),
               position=pick(position),
               distance2=jnp.minimum(pick(distance2),
                                     lose_d1).astype(jnp.int32),
               n_candidates=pick(n_cand), occ_w=pick(occ_w),
               mpos_w=pick(mpos_w), reads_w=pick(reads),
               strand=rev.astype(jnp.int8))
    if lin_end is not None:
        out["linear_dist"] = pick(lin_end)
    return out, rev


@partial(jax.jit, static_argnames=("cfg",))
def _strand_stage(distance, mapped, position, distance2, n_cand, occ_w,
                  mpos_w, reads, lin_end, n_real, cfg: MapperConfig):
    """Jitted strand reduce for the staged engine, plus the
    ``reverse_best`` count over the ``n_real`` non-padding reads."""
    out, rev = _strand_fold(distance, mapped, position, distance2, n_cand,
                            occ_w, mpos_w, reads, lin_end)
    n = distance.shape[0] // 2
    real = jnp.arange(n, dtype=jnp.int32) < n_real
    out["reverse_best"] = jnp.sum(rev & out["mapped"] & real)
    return out


def _fused_stage_impl(segments, positions, reads, occ_idx, occ_valid,
                      mini_pos, n_real, cfg: MapperConfig, lin_cap: int,
                      aff_cap: int):
    """The single-dispatch engine: seeding output -> compaction -> linear
    WF -> filter -> affine WF -> strand reduce -> traceback, one jit.

    The staged engine syncs the measured survivor count between the
    linear and affine stages to size the affine bucket; here the affine
    capacity is *bounded* host-side from the candidate count alone (each
    valid candidate contributes at most one surviving (read, minimizer)
    group, and a filter threshold above the linear band disables the
    filter entirely), so the whole back half of the pipeline dispatches
    without a second host sync.  The bound can only over-provision, never
    drop — results stay bit-identical to the staged engine; the trade is
    that the scattered (R, M, P) ``linear_dist`` tensor is not
    materialized for the host (``MappingResult.linear_dist`` is None).

    Per-read accounting (candidate/survivor/reverse-best counts) is
    reduced on device over the ``n_real`` non-padding rows and fetched as
    scalars with the results.
    """
    R = reads.shape[0]
    half = R // 2 if cfg.both_strands else R
    real = (jnp.arange(R, dtype=jnp.int32) % half) < n_real

    lin_end, best_pl, pass_filter, n_cand = _linear_stage_impl(
        segments, reads, occ_idx, occ_valid, mini_pos, cfg, lin_cap)
    (best_aff, mapped, position, best_m, distance2, occ_w,
     mpos_w) = _affine_stage_impl(segments, positions, reads, occ_idx,
                                  mini_pos, best_pl, pass_filter, lin_end,
                                  cfg, aff_cap)
    out = dict(survivors=jnp.sum(pass_filter & real[:, None]))
    reads_w = reads
    if cfg.both_strands:
        fold, rev = _strand_fold(best_aff, mapped, position, distance2,
                                 n_cand, occ_w, mpos_w, reads)
        best_aff, mapped, position = (fold["distance"], fold["mapped"],
                                      fold["position"])
        distance2, n_cand = fold["distance2"], fold["n_candidates"]
        occ_w, mpos_w, reads_w = fold["occ_w"], fold["mpos_w"], \
            fold["reads_w"]
        out["strand"] = fold["strand"]
        out["reverse_best"] = jnp.sum(rev & mapped & real[:half])
    out.update(position=position, distance=best_aff, distance2=distance2,
               mapped=mapped, n_candidates=n_cand)
    if cfg.cigar_mode == "eager":
        out["ops"], out["op_count"] = _winner_traceback(
            segments, reads_w, occ_w, mpos_w, mapped, cfg)
    elif cfg.cigar_mode == "lazy":
        out.update(_tb_reads=reads_w, _tb_occ=occ_w, _tb_mpos=mpos_w)
    return out


_fused_stage = partial(jax.jit,
                       static_argnames=("cfg", "lin_cap", "aff_cap"))(
    _fused_stage_impl)


def fused_affine_capacity(n_valid: int, R: int, cfg: MapperConfig) -> int:
    """Affine-survivor capacity for the fused engine, bounded without a
    post-filter sync: survivors are (read, minimizer) groups whose best
    linear distance clears the threshold, so there are at most
    ``min(n_valid, R*M)`` of them (empty groups scatter the linear sat
    value ``eth+1`` and cannot pass a threshold <= eth), and exactly
    ``R*M`` when the threshold disables the filter.  Never smaller than
    the true survivor count -> the fused engine never drops."""
    M = cfg.max_minis
    bound = R * M if cfg.filter_threshold > cfg.eth else min(n_valid, R * M)
    return bucket_capacity(bound, align=cfg.aff_block_r, cap_max=R * M)


class LazyTraceback:
    """Deferred winners-only traceback (``cigar_mode="lazy"``).

    Holds the per-read winner metadata fetched with the batch (read
    encoding, winning occurrence row, minimizer offset, mapped mask) plus
    the session's device-resident segments; ``materialize`` dispatches
    the same jitted traceback stage the eager mode runs.  Slicing and
    concatenation keep results lazy through ``mapper.split_result`` and
    the serving layer's per-request reassembly.
    """

    def __init__(self, segments, cfg: MapperConfig, reads, occ, mpos,
                 mapped):
        self.segments = segments        # device array, shared not copied
        self.cfg = cfg
        self.reads, self.occ, self.mpos = reads, occ, mpos
        self.mapped = mapped

    def __len__(self):
        return len(self.occ)

    def __getitem__(self, sl):
        return LazyTraceback(self.segments, self.cfg, self.reads[sl],
                             self.occ[sl], self.mpos[sl], self.mapped[sl])

    @classmethod
    def concat(cls, parts: list["LazyTraceback"]) -> "LazyTraceback":
        first = parts[0]
        if len(parts) == 1:
            return first
        return cls(first.segments, first.cfg,
                   np.concatenate([p.reads for p in parts]),
                   np.concatenate([p.occ for p in parts]),
                   np.concatenate([p.mpos for p in parts]),
                   np.concatenate([p.mapped for p in parts]))

    def materialize(self):
        ops, cnt = _traceback_stage(self.segments, jnp.asarray(self.reads),
                                    jnp.asarray(self.occ),
                                    jnp.asarray(self.mpos),
                                    jnp.asarray(self.mapped), self.cfg)
        # copies: np.asarray of a device buffer is a read-only view, and
        # materialized fields are caller-owned like their eager twins
        return np.array(ops), np.array(cnt)


class _ChunkPipeline:
    """Phase-split per-chunk execution for the streaming engine.

    Host code between the jit stages measures candidate/survivor counts and
    picks static bucket capacities (``bucket_capacity``), so each jit sees a
    fixed shape.  The phases map onto ``streaming.stream_map``'s schedule:

      phase1: host pad -> H2D transfer -> seeding dispatch
      phase2: capacity-count syncs -> linear/affine/traceback dispatch
      fetch:  device->host copies + padding trim (fetch thread)

    When a ``times`` dict is passed (the ``stream=False`` sync path), every
    phase blocks at its stage boundaries and records per-stage wall
    seconds; without it each stage is a non-blocking async enqueue.
    Candidate/survivor accounting and the padded-equivalent baselines
    exclude the zero-padding reads of a partial last chunk, so the
    reported pruning reflects the actual workload.
    """

    def __init__(self, dev, cfg: MapperConfig):
        self.dev = dev
        self.cfg = cfg
        self.lin_jit, self.aff_jit = _stage_jits(cfg.stream)

    def begin_run(self, items) -> None:
        """Hook called once with the full chunk list before streaming
        begins.  The flat pipeline has nothing to stage; the routed
        pipeline overrides this to start arena prefetch."""

    def phase1(self, item, times=None):
        sub, chunk = item
        n_real = len(sub)
        t0 = time.perf_counter()
        if n_real < chunk:  # keep the chunk shape static; trimmed in fetch
            sub = np.concatenate(
                [sub, np.zeros((chunk - n_real, sub.shape[1]), sub.dtype)])
        if self.cfg.both_strands:
            # rows [0:chunk) forward, [chunk:2*chunk) reverse complement:
            # each chunk carries both encodings of its own reads, so the
            # strand reduce happens on device before fetch (phase 2)
            sub = np.concatenate([sub, revcomp(sub)])
        t0 = streaming.timed(times, "host_prep", t0)
        reads = jnp.asarray(sub)
        if times is not None:
            reads.block_until_ready()
        t0 = streaming.timed(times, "h2d", t0)
        with _annotate("seed_dispatch"):
            seeds = seed_reads(self.dev[0], self.dev[1], reads,
                               self.cfg.seed_params)
        if times is not None:
            jax.block_until_ready(seeds)
        streaming.timed(times, "seed", t0)
        return reads, seeds, n_real

    def _real_count(self, arr, total: int, n_real: int, R: int):
        """Host count of True entries in ``arr``'s non-padding rows.
        ``total`` is the known full count; a partial chunk re-counts over
        the real slice of each strand half."""
        half = R // 2 if self.cfg.both_strands else R
        if (2 * n_real if self.cfg.both_strands else n_real) == R:
            return total
        c = jnp.sum(arr[:n_real])
        if self.cfg.both_strands:
            c = c + jnp.sum(arr[half : half + n_real])
        return int(c)

    def chunk_index(self, seeds):
        """Device ``(positions, segments)`` that this chunk's ``occ_idx``
        rows point into.  The flat pipeline has one session-lifetime
        pair; the shard-routed pipeline (``repro.index.residency``)
        overrides this to return the per-chunk arena snapshot its host
        seeding stage routed the occurrence rows against."""
        return self.dev[2], self.dev[3]

    def phase2(self, state, times=None):
        reads, seeds, n_real = state
        cfg = self.cfg
        positions, segments = self.chunk_index(seeds)
        R = reads.shape[0]          # rows: 2*chunk when both_strands
        M, P = cfg.max_minis, cfg.max_pls
        occ_idx, occ_valid = seeds["occ_idx"], seeds["occ_valid"]
        mini_pos = seeds["mini_pos"]
        rows_real = 2 * n_real if cfg.both_strands else n_real
        profile = cfg.profile and times is None  # streamed profiling

        # count syncs happen before the stage call so the donated buffers
        # (occ_valid / pass_filter) are never read after being consumed
        t0 = time.perf_counter()
        n_valid = int(seeds["n_valid"])
        lin_cap = bucket_capacity(n_valid, align=cfg.lin_block_r,
                                  cap_max=R * M * P)

        if cfg.engine == "fused":
            n_valid_real = self._real_count(occ_valid, n_valid, n_real, R)
            aff_cap = fused_affine_capacity(n_valid, R, cfg)
            with _annotate("fused_dispatch"):
                out = _fused_stage(segments, positions, reads, occ_idx,
                                   occ_valid, mini_pos, jnp.int32(n_real),
                                   cfg, lin_cap, aff_cap)
            if times is not None:
                out["position"].block_until_ready()
            streaming.timed(times, "fused", t0)
            stats = dict(candidates_valid=n_valid_real,
                         linear_instances=lin_cap,
                         padded_linear_instances=rows_real * M * P,
                         survivors=out.pop("survivors"),
                         affine_dist_instances=aff_cap,
                         padded_affine_instances=rows_real * M,
                         affine_dirs_instances=(
                             n_real if cfg.cigar_mode == "eager" else 0))
            if cfg.both_strands:
                stats["reverse_best"] = out.pop("reverse_best")
            if profile:
                out["_milestones"] = (("seed", mini_pos),
                                      ("fused", out["position"]))
            return out, stats, n_real

        n_valid_real = self._real_count(occ_valid, n_valid, n_real, R)
        with _annotate("linear_dispatch"):
            lin_end, best_pl, pass_filter, n_cand = self.lin_jit(
                segments, reads, occ_idx, occ_valid, mini_pos, cfg, lin_cap)
        if times is not None:
            pass_filter.block_until_ready()
        t0 = streaming.timed(times, "linear", t0)

        n_surv = int(jnp.sum(pass_filter))
        n_surv_real = self._real_count(pass_filter, n_surv, n_real, R)
        aff_cap = bucket_capacity(n_surv, align=cfg.aff_block_r,
                                  cap_max=R * M)
        with _annotate("affine_dispatch"):
            (best_aff, mapped, position, best_m, distance2, occ_w,
             mpos_w) = self.aff_jit(segments, positions, reads, occ_idx,
                                    mini_pos, best_pl, pass_filter, lin_end,
                                    cfg, aff_cap)
        reads_w, strand, reverse_best = reads, None, None
        if cfg.both_strands:
            fold = _strand_stage(best_aff, mapped, position, distance2,
                                 n_cand, occ_w, mpos_w, reads, lin_end,
                                 jnp.int32(n_real), cfg)
            best_aff, mapped, position = (fold["distance"], fold["mapped"],
                                          fold["position"])
            distance2, n_cand = fold["distance2"], fold["n_candidates"]
            occ_w, mpos_w, reads_w = (fold["occ_w"], fold["mpos_w"],
                                      fold["reads_w"])
            lin_end, strand = fold["linear_dist"], fold["strand"]
            reverse_best = fold["reverse_best"]
        if times is not None:
            position.block_until_ready()
        t0 = streaming.timed(times, "affine", t0)

        out = dict(position=position, distance=best_aff,
                   distance2=distance2, mapped=mapped, linear_dist=lin_end,
                   n_candidates=n_cand)
        if strand is not None:
            out["strand"] = strand
        tb_mark = position
        if cfg.cigar_mode == "eager":
            with _annotate("traceback_dispatch"):
                out["ops"], out["op_count"] = _traceback_stage(
                    segments, reads_w, occ_w, mpos_w, mapped, cfg)
            tb_mark = out["ops"]
            if times is not None:
                tb_mark.block_until_ready()
        elif cfg.cigar_mode == "lazy":
            out.update(_tb_reads=reads_w, _tb_occ=occ_w, _tb_mpos=mpos_w)
        streaming.timed(times, "traceback", t0)

        stats = dict(candidates_valid=n_valid_real,
                     linear_instances=lin_cap,
                     padded_linear_instances=rows_real * M * P,
                     survivors=n_surv_real,
                     affine_dist_instances=aff_cap,
                     padded_affine_instances=rows_real * M,
                     affine_dirs_instances=(
                         n_real if cfg.cigar_mode == "eager" else 0))
        if reverse_best is not None:
            stats["reverse_best"] = reverse_best
        if profile:
            out["_milestones"] = (("seed", mini_pos), ("linear", best_pl),
                                  ("affine", position),
                                  ("traceback", tb_mark))
        return out, stats, n_real

    def fetch(self, state, times=None):
        out, stats, n_real = state
        mil = out.pop("_milestones", None)
        t0 = time.perf_counter()
        if mil is not None:  # streamed profiling: completion-time offsets
            for name, arr in mil:
                arr.block_until_ready()
                t0 = streaming.timed(times, name, t0)
        host = {k: np.asarray(v)[:n_real] for k, v in out.items()}
        streaming.timed(times, "d2h", t0)
        stats = {k: (int(v) if isinstance(v, jax.Array) else v)
                 for k, v in stats.items()}
        return host, stats


def _merge_stats(parts: list[dict]) -> dict:
    out = {k: sum(p[k] for p in parts) for k in parts[0]}
    out["pruning_ratio"] = (
        1.0 - out["survivors"] / max(out["candidates_valid"], 1))
    out["n_chunks"] = len(parts)
    return out


def map_reads(index: GenomeIndex, reads: np.ndarray,
              cfg: MapperConfig | None = None) -> MappingResult:
    """Host-friendly wrapper: numpy index + reads -> MappingResult.

    .. deprecated::
        Use :class:`repro.core.mapper.Mapper` —
        ``Mapper(index, cfg).map(reads)`` is the bit-identical replacement
        and keeps the index placed on device across calls (this shim
        builds a fresh one-shot session each time).  See the README's
        migration table.
    """
    warnings.warn(
        "map_reads is deprecated; use repro.core.mapper.Mapper — "
        "Mapper(index, cfg).map(reads) is the bit-identical replacement "
        "(and reuses device placement across calls)",
        DeprecationWarning, stacklevel=2)
    from .mapper import Mapper
    return Mapper(index, cfg).map(reads)


def oracle_map(ref: np.ndarray, reads: np.ndarray, eth: int = 6,
               chunk: int = 4096):
    """Exhaustive banded-WF scan over every reference position (BWA-MEM
    stand-in ground truth for accuracy tests).  O(G * R) — small inputs only.

    Returns ``(best_p, best_d)``: per-read best position (ties -> leftmost)
    and its banded-WF distance, each of shape (R,).
    """
    rl = reads.shape[1]
    G = len(ref)
    pad = np.full(G + 2 * eth + rl, 4, dtype=np.uint8)
    pad[eth : eth + G] = ref
    n_pos = G - rl + 1
    starts = np.arange(n_pos)
    best_d = np.full(len(reads), 10 ** 9, dtype=np.int64)
    best_p = np.full(len(reads), -1, dtype=np.int64)
    win = rl + 2 * eth
    for c0 in range(0, n_pos, chunk):
        c1 = min(c0 + chunk, n_pos)
        idx = starts[c0:c1, None] + np.arange(win)[None, :]
        wins = jnp.asarray(pad[idx])  # (C, win)
        d_end, _ = banded_wf(jnp.asarray(reads)[:, None, :].repeat(c1 - c0, 1),
                             jnp.broadcast_to(wins[None], (len(reads), c1 - c0,
                                                           win)), eth=eth)
        d = np.asarray(d_end)
        for r in range(len(reads)):
            m = int(d[r].argmin())
            if d[r][m] < best_d[r]:
                best_d[r] = d[r][m]
                best_p[r] = c0 + m
    return best_p, best_d
