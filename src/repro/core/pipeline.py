"""End-to-end read mapping (paper Secs. V-B .. V-E), single-shard version.

Stages (numbers = the circled steps of paper Fig. 6):
  (1)(2) seeding     — minimizer lookup, candidate PLs       (seeding.py)
  (3)    linear WF   — banded distance for every candidate   (filtering.py)
  (4)    min extract — best PL per (read, minimizer)
  (5)(6) affine WF   — alignment + traceback for the winners (affine_wf.py)
  (7)    reduce      — best PL per read across minimizers

Everything is static-shape and jit-compiled; the distributed version in
``repro.core.distributed`` wraps the same stages with an all_to_all seeding
exchange over the device mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import affine_wf
from .filtering import gather_windows, linear_wf_filter
from .index import GenomeIndex
from .linear_wf import banded_wf
from .seeding import SeedParams, seed_reads


@dataclasses.dataclass(frozen=True)
class MapperConfig:
    read_len: int = 150
    k: int = 12
    w: int = 30
    eth: int = 6            # band half-width (linear + affine) — Table III
    sat_affine: int = 32    # affine value saturation (5-bit cells) — Table III
    max_minis: int = 16
    max_pls: int = 32       # linear WF buffer rows per crossbar
    filter_threshold: int = 6
    max_ops: int | None = None

    @property
    def seed_params(self) -> SeedParams:
        return SeedParams(k=self.k, w=self.w, max_minis=self.max_minis,
                          max_pls=self.max_pls)


@dataclasses.dataclass
class MappingResult:
    position: np.ndarray   # (R,) int32 best mapping position (-1 if unmapped)
    distance: np.ndarray   # (R,) int32 affine WF distance
    mapped: np.ndarray     # (R,) bool
    ops: np.ndarray        # (R, max_ops) traceback op codes (END-aligned)
    op_count: np.ndarray   # (R,) int32
    linear_dist: np.ndarray  # (R, M, P) all candidate linear distances
    n_candidates: np.ndarray  # (R,) number of valid PLs seeded


@partial(jax.jit, static_argnames=("cfg",))
def map_reads_jax(uniq_kmers, offsets, positions, segments, reads,
                  cfg: MapperConfig):
    """The jit pipeline. Index arrays are device arrays; reads (R, rl)."""
    R = reads.shape[0]
    seeds = seed_reads(uniq_kmers, offsets, reads, cfg.seed_params)
    occ_idx, occ_valid = seeds["occ_idx"], seeds["occ_valid"]
    mini_pos = seeds["mini_pos"]  # (R, M)

    # (3) linear WF over every candidate
    windows = gather_windows(segments, occ_idx, mini_pos[..., None],
                             read_len=cfg.read_len, k=cfg.k, eth=cfg.eth)
    lin_end, _ = linear_wf_filter(reads, windows, occ_valid, eth=cfg.eth)

    # (4) min extraction per (read, minimizer); filter threshold
    best_pl = jnp.argmin(lin_end, axis=-1)                       # (R, M)
    best_lin = jnp.take_along_axis(lin_end, best_pl[..., None],
                                   -1)[..., 0]                   # (R, M)
    pass_filter = best_lin <= cfg.filter_threshold

    # (5)+(6) affine WF on the per-minimizer winners
    sel_win = jnp.take_along_axis(
        windows, best_pl[..., None, None], axis=2)[:, :, 0]      # (R, M, wlen)
    s1 = jnp.broadcast_to(reads[:, None, :],
                          (R, cfg.max_minis, cfg.read_len))
    aff_end, _, dirs = affine_wf.banded_affine(s1, sel_win, eth=cfg.eth,
                                               sat=cfg.sat_affine)
    aff_end = jnp.where(pass_filter, aff_end, cfg.sat_affine)

    # (7) best minimizer per read — min distance, ties -> leftmost position
    # (deterministic across the single-shard and distributed mappers)
    cand_occ = jnp.take_along_axis(occ_idx,
                                   best_pl[..., None], axis=2)[:, :, 0]
    cand_pos = positions[cand_occ] - mini_pos                    # (R, M)
    best_aff = jnp.min(aff_end, axis=-1)
    mapped = best_aff < cfg.sat_affine
    is_best = aff_end == best_aff[:, None]
    pos_key = jnp.where(is_best & (cand_pos >= 0), cand_pos, 2 ** 30)
    position = jnp.min(pos_key, axis=-1)
    best_m = jnp.argmin(jnp.where(pos_key == position[:, None],
                                  jnp.arange(cfg.max_minis)[None, :],
                                  cfg.max_minis), axis=-1)
    position = jnp.where(mapped & (position < 2 ** 30), position, -1)

    # traceback for the winning instance only
    sel_dirs = jnp.take_along_axis(
        dirs, best_m[:, None, None, None], axis=1)[:, 0]         # (R, n, band)
    max_ops = cfg.max_ops or 2 * cfg.read_len + 2
    ops, op_count = affine_wf.traceback(sel_dirs, cfg.eth, max_ops)
    ops = jnp.where(mapped[:, None], ops, affine_wf.OP_NONE)
    op_count = jnp.where(mapped, op_count, 0)

    return dict(position=position, distance=best_aff, mapped=mapped, ops=ops,
                op_count=op_count, linear_dist=lin_end,
                n_candidates=jnp.sum(occ_valid, axis=(1, 2)))


def map_reads(index: GenomeIndex, reads: np.ndarray,
              cfg: MapperConfig | None = None) -> MappingResult:
    """Host-friendly wrapper: numpy index + reads -> MappingResult."""
    cfg = cfg or MapperConfig(read_len=index.read_len, k=index.k, w=index.w,
                              eth=index.eth)
    out = map_reads_jax(jnp.asarray(index.uniq_kmers),
                        jnp.asarray(index.offsets),
                        jnp.asarray(index.positions),
                        jnp.asarray(index.segments),
                        jnp.asarray(reads), cfg)
    return MappingResult(position=np.asarray(out["position"]),
                         distance=np.asarray(out["distance"]),
                         mapped=np.asarray(out["mapped"]),
                         ops=np.asarray(out["ops"]),
                         op_count=np.asarray(out["op_count"]),
                         linear_dist=np.asarray(out["linear_dist"]),
                         n_candidates=np.asarray(out["n_candidates"]))


def oracle_map(ref: np.ndarray, reads: np.ndarray, eth: int = 6,
               chunk: int = 4096) -> np.ndarray:
    """Exhaustive banded-WF scan over every reference position (BWA-MEM
    stand-in ground truth for accuracy tests).  O(G * R) — small inputs only.

    Returns (R,) best position per read (ties -> leftmost).
    """
    rl = reads.shape[1]
    G = len(ref)
    pad = np.full(G + 2 * eth + rl, 4, dtype=np.uint8)
    pad[eth : eth + G] = ref
    n_pos = G - rl + 1
    starts = np.arange(n_pos)
    best_d = np.full(len(reads), 10 ** 9, dtype=np.int64)
    best_p = np.full(len(reads), -1, dtype=np.int64)
    win = rl + 2 * eth
    for c0 in range(0, n_pos, chunk):
        c1 = min(c0 + chunk, n_pos)
        idx = starts[c0:c1, None] + np.arange(win)[None, :]
        wins = jnp.asarray(pad[idx])  # (C, win)
        d_end, _ = banded_wf(jnp.asarray(reads)[:, None, :].repeat(c1 - c0, 1),
                             jnp.broadcast_to(wins[None], (len(reads), c1 - c0,
                                                           win)), eth=eth)
        d = np.asarray(d_end)
        for r in range(len(reads)):
            m = int(d[r].argmin())
            if d[r][m] < best_d[r]:
                best_d[r] = d[r][m]
                best_p[r] = c0 + m
    return best_p, best_d
