"""Online seeding (paper Sec. V-C): route reads to their minimizers' data.

For each read we extract its unique minimizers (static-shape padded to
``max_minis``), look each up in the sorted index (binary search), and emit up
to ``max_pls`` potential locations per (read, minimizer).  In DART-PIM the
controller hierarchy physically routes the read to each matching crossbar's
Reads-FIFO; here the result is a static-shape candidate tensor that the
filtering stage consumes (and that ``repro.core.distributed`` routes across
the device mesh with one all_to_all).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .minimizers import unique_read_minimizers


@dataclasses.dataclass(frozen=True)
class SeedParams:
    k: int = 12
    w: int = 30
    max_minis: int = 16   # unique minimizers kept per read (Reads-FIFO width)
    max_pls: int = 32     # PLs per (read, minimizer) — linear WF buffer rows


@partial(jax.jit, static_argnames=("params",))
def seed_reads(uniq_kmers: jnp.ndarray, offsets: jnp.ndarray,
               reads: jnp.ndarray, params: SeedParams = SeedParams()):
    """Seed a batch of reads.

    Returns dict with, per read:
      mini_kmers  (R, M)      uint32  minimizer k-mer codes
      mini_pos    (R, M)      int32   minimizer start offset within the read
      mini_valid  (R, M)      bool    found in index & within max_minis
      occ_idx     (R, M, P)   int32   occurrence row into index.positions/segments
      occ_valid   (R, M, P)   bool
    where M = max_minis, P = max_pls; plus the batch scalar
      n_valid     ()          int32   total valid candidates
    folded into the same dispatch so the pipeline's bucket-capacity sync
    blocks on one ready scalar instead of launching a separate reduction.
    """
    M, P = params.max_minis, params.max_pls

    def per_read(read):
        kmers, pos, valid = unique_read_minimizers(
            read, k=params.k, w=params.w, max_uniq=M)
        idx = jnp.searchsorted(uniq_kmers, kmers)
        idx = jnp.minimum(idx, uniq_kmers.shape[0] - 1)
        found = (uniq_kmers[idx] == kmers) & valid
        start = offsets[idx]
        count = offsets[idx + 1] - start
        occ = start[:, None] + jnp.arange(P, dtype=jnp.int32)[None, :]
        occ_valid = (jnp.arange(P)[None, :] < count[:, None]) & found[:, None]
        occ = jnp.where(occ_valid, occ, 0)
        return dict(mini_kmers=kmers, mini_pos=pos, mini_valid=found,
                    occ_idx=occ, occ_valid=occ_valid)

    out = jax.vmap(per_read)(reads)
    out["n_valid"] = jnp.sum(out["occ_valid"]).astype(jnp.int32)
    return out


def seed_reads_routed(index, reads: np.ndarray, params: SeedParams, ensure):
    """Host-side seeding against a partitioned index — the shard-routed
    twin of :func:`seed_reads`.

    ``index`` is a ``repro.index.ShardedGenomeIndex`` (duck-typed: needs
    ``route(kmers)`` and ``parts[p].kmers/.offsets/.n_occurrences``).
    ``ensure(partition_ids)`` is the residency hook: it makes the listed
    partitions device-resident and returns ``{p: arena_base_row}`` —
    emitted ``occ_idx`` rows are *arena* rows (partition base + local CSR
    row), pointing into the device snapshot the caller pairs them with.

    Semantics match ``seed_reads`` exactly for every masked-visible
    value: the same minimizer extraction (bit-identical numpy port), the
    same per-kmer occurrence lists (each k-mer lives wholly in one
    partition), ``occ_idx`` zeroed where invalid, and ``n_valid``
    counted over the full padded batch.  Routing the lookup host-side is
    what lets the single-host topology know *which* partitions a chunk
    touches before any device dispatch.

    Returns ``(seeds, routed_per_part, found_per_part)`` — the numpy
    seeds dict plus per-partition routing/hit counts for
    ``MapperStats``.
    """
    from ..index.npscan import np_unique_read_minimizers  # lazy: no cycle

    M, P = params.max_minis, params.max_pls
    reads = np.asarray(reads)
    kmers, pos, valid = np_unique_read_minimizers(reads, params.k,
                                                  params.w, M)
    part = np.asarray(index.route(kmers))
    R = len(reads)
    n_parts = index.num_partitions
    routed = np.bincount(part[valid], minlength=n_parts).astype(np.int64)
    touched = [int(p) for p in np.nonzero(routed)[0]
               if index.parts[p].n_occurrences > 0]
    bases = ensure(touched)
    occ = np.zeros((R, M, P), dtype=np.int32)
    occ_valid = np.zeros((R, M, P), dtype=bool)
    mini_valid = np.zeros((R, M), dtype=bool)
    found_per_part = np.zeros(n_parts, dtype=np.int64)
    lanes = np.arange(P, dtype=np.int32)
    for p in touched:
        pk = index.parts[p]
        sel = (part == p) & valid
        if not sel.any():
            continue
        kk = kmers[sel]
        pk_kmers = np.asarray(pk.kmers)
        i = np.minimum(np.searchsorted(pk_kmers, kk), pk.n_kmers - 1)
        found = pk_kmers[i] == kk
        # CSR offsets may be int64 (format v2); keep the row arithmetic
        # int64 and narrow only the final arena rows, which are bounded
        # by the arena capacity (< 2^31 rows by construction)
        offs = np.asarray(pk.offsets)
        start = offs[i].astype(np.int64)
        count = offs[i + 1].astype(np.int64) - start
        rows = (np.int64(bases[p]) + start[:, None] + lanes[None, :])
        ov = (lanes[None, :] < count[:, None]) & found[:, None]
        occ[sel] = np.where(ov, rows, 0).astype(np.int32)
        occ_valid[sel] = ov
        mini_valid[sel] = found
        found_per_part[p] = int(found.sum())
    seeds = dict(mini_kmers=kmers, mini_pos=pos, mini_valid=mini_valid,
                 occ_idx=occ, occ_valid=occ_valid,
                 n_valid=int(occ_valid.sum()))
    return seeds, routed, found_per_part
