"""Online seeding (paper Sec. V-C): route reads to their minimizers' data.

For each read we extract its unique minimizers (static-shape padded to
``max_minis``), look each up in the sorted index (binary search), and emit up
to ``max_pls`` potential locations per (read, minimizer).  In DART-PIM the
controller hierarchy physically routes the read to each matching crossbar's
Reads-FIFO; here the result is a static-shape candidate tensor that the
filtering stage consumes (and that ``repro.core.distributed`` routes across
the device mesh with one all_to_all).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .minimizers import unique_read_minimizers


@dataclasses.dataclass(frozen=True)
class SeedParams:
    k: int = 12
    w: int = 30
    max_minis: int = 16   # unique minimizers kept per read (Reads-FIFO width)
    max_pls: int = 32     # PLs per (read, minimizer) — linear WF buffer rows


@partial(jax.jit, static_argnames=("params",))
def seed_reads(uniq_kmers: jnp.ndarray, offsets: jnp.ndarray,
               reads: jnp.ndarray, params: SeedParams = SeedParams()):
    """Seed a batch of reads.

    Returns dict with, per read:
      mini_kmers  (R, M)      uint32  minimizer k-mer codes
      mini_pos    (R, M)      int32   minimizer start offset within the read
      mini_valid  (R, M)      bool    found in index & within max_minis
      occ_idx     (R, M, P)   int32   occurrence row into index.positions/segments
      occ_valid   (R, M, P)   bool
    where M = max_minis, P = max_pls; plus the batch scalar
      n_valid     ()          int32   total valid candidates
    folded into the same dispatch so the pipeline's bucket-capacity sync
    blocks on one ready scalar instead of launching a separate reduction.
    """
    M, P = params.max_minis, params.max_pls

    def per_read(read):
        kmers, pos, valid = unique_read_minimizers(
            read, k=params.k, w=params.w, max_uniq=M)
        idx = jnp.searchsorted(uniq_kmers, kmers)
        idx = jnp.minimum(idx, uniq_kmers.shape[0] - 1)
        found = (uniq_kmers[idx] == kmers) & valid
        start = offsets[idx]
        count = offsets[idx + 1] - start
        occ = start[:, None] + jnp.arange(P, dtype=jnp.int32)[None, :]
        occ_valid = (jnp.arange(P)[None, :] < count[:, None]) & found[:, None]
        occ = jnp.where(occ_valid, occ, 0)
        return dict(mini_kmers=kmers, mini_pos=pos, mini_valid=found,
                    occ_idx=occ, occ_valid=occ_valid)

    out = jax.vmap(per_read)(reads)
    out["n_valid"] = jnp.sum(out["occ_valid"]).astype(jnp.int32)
    return out
