"""WF backend dispatch: ``"jnp"`` reference | ``"pallas"`` kernels.

One switch, threaded through ``MapperConfig`` into the filtering, pipeline
and distributed layers, selects the execution engine for every banded-WF
stage:

  * ``"jnp"``    — the pure-jnp batched references in ``repro.core``
    (always available, shape-polymorphic);
  * ``"pallas"`` — the lane-parallel kernels in ``repro.kernels``, in
    interpret mode on CPU (correctness of the exact TPU code) and compiled
    on TPU.  Inputs are flattened to one instance axis and handed to the
    (seq, instances)-transposed kernels; the ops wrappers pad to the kernel
    block size, so any instance count is accepted — the compacted pipeline
    picks lane-aligned capacities so that padding is a no-op.

All three entry points accept arbitrary leading batch dims like the jnp
references do.
"""
from __future__ import annotations

import jax.numpy as jnp

from .affine_wf import banded_affine, banded_affine_dist, traceback
from .linear_wf import banded_wf

BACKENDS = ("jnp", "pallas")


def _check(backend: str) -> None:
    if backend not in BACKENDS:
        raise ValueError(f"wf_backend must be one of {BACKENDS}, "
                         f"got {backend!r}")


def linear_wf_dist(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int,
                   backend: str = "jnp", block_r: int = 512):
    """Banded linear WF distances.  s1 (..., n), s2_window (..., n+2*eth) ->
    (dist_end, dist_min) int32 of shape (...)."""
    _check(backend)
    if backend == "jnp":
        return banded_wf(s1, s2_window, eth=eth)
    from repro.kernels import ops
    lead = s1.shape[:-1]
    de, dm = ops.linear_wf(s1.reshape(-1, s1.shape[-1]),
                           s2_window.reshape(-1, s2_window.shape[-1]),
                           eth=eth, block_r=block_r)
    return de.reshape(lead), dm.reshape(lead)


def affine_wf_dist(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int,
                   sat: int, backend: str = "jnp", block_r: int = 256):
    """Distance-only banded affine WF (no direction planes)."""
    _check(backend)
    if backend == "jnp":
        return banded_affine_dist(s1, s2_window, eth=eth, sat=sat)
    from repro.kernels import ops
    lead = s1.shape[:-1]
    de, dm = ops.affine_wf_dist(s1.reshape(-1, s1.shape[-1]),
                                s2_window.reshape(-1, s2_window.shape[-1]),
                                eth=eth, sat=sat, block_r=block_r)
    return de.reshape(lead), dm.reshape(lead)


def affine_wf_dirs(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int,
                   sat: int, backend: str = "jnp", block_r: int = 256):
    """Banded affine WF with packed direction planes (traceback pass).

    Returns (dist_end, dist_min, dirs (..., n, 2*eth+1) uint8)."""
    _check(backend)
    if backend == "jnp":
        return banded_affine(s1, s2_window, eth=eth, sat=sat)
    from repro.kernels import ops
    lead = s1.shape[:-1]
    n = s1.shape[-1]
    band = 2 * eth + 1
    de, dm, dirs = ops.affine_wf(s1.reshape(-1, n),
                                 s2_window.reshape(-1, s2_window.shape[-1]),
                                 eth=eth, sat=sat, block_r=block_r)
    return (de.reshape(lead), dm.reshape(lead),
            dirs.reshape(lead + (n, band)))


def affine_traceback(s1: jnp.ndarray, s2_window: jnp.ndarray, *, eth: int,
                     sat: int, max_ops: int, backend: str = "jnp",
                     block_r: int = 256):
    """Banded affine WF + traceback in one dispatch (the winners-only
    traceback pass).

    On the pallas backend this runs the *fused* kernel of
    ``repro.kernels.traceback`` — the (n, band) direction planes live only
    in VMEM scratch and never reach HBM; on the jnp backend the reference
    ``banded_affine`` + batched ``traceback`` walk run back to back.  Both
    produce bit-identical END-aligned ops.

    Returns (dist_end, dist_min, ops (..., max_ops) int32,
    op_count (...,) int32).
    """
    _check(backend)
    if backend == "jnp":
        de, dm, dirs = banded_affine(s1, s2_window, eth=eth, sat=sat)
        ops_, cnt = traceback(dirs, eth, max_ops)
        return de, dm, ops_, cnt
    from repro.kernels import ops
    lead = s1.shape[:-1]
    de, dm, ops_, cnt = ops.affine_traceback(
        s1.reshape(-1, s1.shape[-1]),
        s2_window.reshape(-1, s2_window.shape[-1]),
        eth=eth, sat=sat, max_ops=max_ops, block_r=block_r)
    return (de.reshape(lead), dm.reshape(lead),
            ops_.reshape(lead + (max_ops,)), cnt.reshape(lead))
