"""Minimizer computation (k=12, W=30) — the indexing/seeding substrate.

A window of W consecutive k-mers is represented by its *minimizer*: the k-mer
with the smallest hash value [Roberts et al. 2004].  DART-PIM assigns one
crossbar per reference minimizer; we assign one index shard per minimizer
hash bucket.  The hash is an invertible integer mix (minimap2-style) so that
minimizer choice is pseudo-random w.r.t. lexicographic order.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .encoding import kmer_codes


def hash32(x: jnp.ndarray) -> jnp.ndarray:
    """Invertible 32-bit integer mix (finalizer-style), uint32 -> uint32."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def sliding_min(values: jnp.ndarray, window: int) -> jnp.ndarray:
    """Sliding-window minimum along the last axis -> (..., L-window+1).

    Uses log2(window) doubling steps (jnp.minimum of shifted views), the
    TPU-friendly equivalent of lax.reduce_window for 1-D int data.
    """
    L = values.shape[-1]
    n = L - window + 1
    acc = values
    span = 1
    # doubling min: after the loop acc[i] = min(values[i : i+span]) for span>=window
    while span < window:
        step = min(span, window - span)
        acc = jnp.minimum(acc[..., : acc.shape[-1] - step], acc[..., step:])
        span += step
    return acc[..., :n]


def sliding_argmin(values: jnp.ndarray, window: int):
    """Sliding-window (min, leftmost argmin) via (value, index) pair doubling.

    Avoids 64-bit packed keys (x64 is disabled); ties break to the leftmost
    index, matching minimap2's minimizer convention.
    """
    L = values.shape[-1]
    n = L - window + 1
    idx = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), values.shape)
    val, pos = values, idx
    span = 1
    while span < window:
        step = min(span, window - span)
        a_v, a_p = val[..., : val.shape[-1] - step], pos[..., : pos.shape[-1] - step]
        b_v, b_p = val[..., step:], pos[..., step:]
        take_b = (b_v < a_v) | ((b_v == a_v) & (b_p < a_p))
        val = jnp.where(take_b, b_v, a_v)
        pos = jnp.where(take_b, b_p, a_p)
        span += step
    return val[..., :n], pos[..., :n]


@partial(jax.jit, static_argnames=("k", "w"))
def minimizers(seq: jnp.ndarray, k: int = 12, w: int = 30):
    """Window minimizers of ``seq``.

    Returns (min_hash, min_kmer, min_pos) each shaped (..., n_windows) where
    n_windows = L - (w + k - 1) + 1.  ``min_pos`` is the k-mer start position
    of the minimizer within ``seq``.
    """
    codes = kmer_codes(seq, k)  # (..., L-k+1)
    hashes = hash32(codes)
    n_win = codes.shape[-1] - w + 1
    minh, min_pos = sliding_argmin(hashes, w)  # (..., n_win) each
    min_kmer = jnp.take_along_axis(codes, min_pos, axis=-1)
    assert minh.shape[-1] == n_win
    return minh, min_kmer, min_pos


@partial(jax.jit, static_argnames=("k", "w", "max_uniq"))
def unique_read_minimizers(read: jnp.ndarray, k: int = 12, w: int = 30,
                           max_uniq: int = 24):
    """Unique minimizers of a single read, static-shape padded.

    Returns (kmers, positions, valid) each (max_uniq,). Deduplicates
    consecutive windows sharing the same minimizer position (the common
    case); fully general dedup via sort.
    """
    _, kmer, pos = minimizers(read, k=k, w=w)
    n_win = kmer.shape[-1]
    # Sort by (kmer, pos); mark first occurrence of each kmer.
    order = jnp.argsort(kmer, stable=True)
    ks = kmer[order]
    ps = pos[order]
    first = jnp.concatenate([jnp.array([True]), ks[1:] != ks[:-1]])
    # Compact the first-occurrence entries to the front.
    rank = jnp.cumsum(first) - 1  # target slot for each kept element
    slots = jnp.where(first, rank, n_win)  # discard -> overflow slot
    out_k = jnp.zeros((n_win + 1,), dtype=ks.dtype).at[slots].set(ks)
    out_p = jnp.zeros((n_win + 1,), dtype=ps.dtype).at[slots].set(ps)
    n_uniq = jnp.sum(first)
    valid = jnp.arange(max_uniq) < jnp.minimum(n_uniq, max_uniq)
    return out_k[:max_uniq], out_p[:max_uniq], valid
