"""Affine Wagner-Fischer with traceback (paper Sec. III-B, Eqs. 3-5).

Three banded matrices: D (edit distance), M1 (vertical gap = read char not in
reference, paper label "ins"), M2 (horizontal gap, "del").  Gap of length L
costs w_op + w_ex * L under Eqs. 4-5.

Band half-width vs. saturation: the paper quotes eth = 31 for affine WF with
5-bit cells.  31 is the value-saturation threshold (5-bit range); the band
GEOMETRY stays 2*6+1 = 13 diagonals — that is what fits the crossbar layout
(7 traceback rows x 1024 bits ~= 150 rows x 13 cells x 4 bits) and what the
linear-WF pre-filter (eth = 6) admits.  We therefore expose both: ``eth`` is
the band half-width, ``sat`` the saturation value (defaults: 6 and 32).  Direction bits (2 for D, 1 each for
M1/M2 = 4 bits/cell, paper Sec. IV-B) are emitted for every band cell so the
alignment is reconstructed without storing value matrices — DART-PIM keeps
them in 7 auxiliary crossbar rows; we pack them into one int8 plane per cell.

Direction encoding (packed byte = dD | dM1 << 2 | dM2 << 3):
  dD : 0 diag match, 1 diag substitution, 2 enter M1, 3 enter M2
  dM1: 0 extend (from M1[i-1,j]),  1 open (from D[i-1,j])
  dM2: 0 extend (from M2[i,j-1]),  1 open (from D[i,j-1])
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# traceback op codes
OP_MATCH, OP_SUB, OP_INS, OP_DEL, OP_NONE = 0, 1, 2, 3, 4
OP_CHARS = "=XIDP"

INF = 10 ** 6


def full_affine_numpy(s1, s2, w_sub=1, w_op=1, w_ex=1):
    """Unbanded Gotoh DP following paper Eqs. 3-5 exactly (oracle).

    Returns (D, M1, M2) int matrices of shape (n+1, m+1).
    """
    n, m = len(s1), len(s2)
    D = np.full((n + 1, m + 1), INF, dtype=np.int64)
    M1 = np.full((n + 1, m + 1), INF, dtype=np.int64)
    M2 = np.full((n + 1, m + 1), INF, dtype=np.int64)
    D[0, 0] = 0
    for i in range(1, n + 1):
        M1[i, 0] = min(M1[i - 1, 0] + w_ex, D[i - 1, 0] + w_op + w_ex)
        D[i, 0] = M1[i, 0]
    for j in range(1, m + 1):
        M2[0, j] = min(M2[0, j - 1] + w_ex, D[0, j - 1] + w_op + w_ex)
        D[0, j] = M2[0, j]
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            M1[i, j] = min(M1[i - 1, j] + w_ex, D[i - 1, j] + w_op + w_ex)
            M2[i, j] = min(M2[i, j - 1] + w_ex, D[i, j - 1] + w_op + w_ex)
            if s1[i - 1] == s2[j - 1]:
                D[i, j] = D[i - 1, j - 1]
            else:
                D[i, j] = min(M1[i, j], M2[i, j], D[i - 1, j - 1] + w_sub)
    return D, M1, M2


def banded_affine_numpy(s1, s2_window, eth=6, sat=32, w_sub=1, w_op=1,
                        w_ex=1):
    """Band-only oracle with saturation at eth+1. Mirrors the jnp/Pallas path.

    s2_window length = len(s1) + 2*eth; position p holds the reference base at
    (expected read start - eth + p).  Returns (D_band, dirs, dist) where
    D_band is the last band row and dirs is (n, 2*eth+1) packed direction
    bytes for rows 1..n.
    """
    n = len(s1)
    band = 2 * eth + 1
    D = np.full(band, sat, dtype=np.int32)
    M1 = np.full(band, sat, dtype=np.int32)
    M2 = np.full(band, sat, dtype=np.int32)
    # row 0: j = d - eth; D[0,j] = M2 chain = w_op + w_ex*j
    for d in range(eth, band):
        j = d - eth
        if j == 0:
            D[d] = 0
        else:
            D[d] = min(w_op + w_ex * j, sat)
            M2[d] = D[d]
    dirs = np.zeros((n, band), dtype=np.uint8)
    for i in range(1, n + 1):
        Dp, M1p, M2p = D.copy(), M1.copy(), M2.copy()
        D = np.full(band, sat, dtype=np.int32)
        M1 = np.full(band, sat, dtype=np.int32)
        M2 = np.full(band, sat, dtype=np.int32)
        for d in range(band):
            j = i + d - eth
            if j < 0:
                continue
            # vertical gap matrix M1 (prev row, same j -> band d+1)
            m1_ext = M1p[d + 1] + w_ex if d + 1 < band else INF
            m1_open = Dp[d + 1] + w_op + w_ex if d + 1 < band else INF
            M1[d] = min(m1_ext, m1_open, sat)
            d_m1 = 0 if m1_ext <= m1_open else 1
            # horizontal gap matrix M2 (same row, j-1 -> band d-1)
            m2_ext = M2[d - 1] + w_ex if d >= 1 else INF
            m2_open = D[d - 1] + w_op + w_ex if d >= 1 else INF
            M2[d] = min(m2_ext, m2_open, sat)
            d_m2 = 0 if m2_ext <= m2_open else 1
            if j == 0:
                D[d] = M1[d]
                d_d = 2
            else:
                diag = Dp[d]
                if s1[i - 1] == s2_window[i + d - 1]:
                    D[d] = min(diag, sat)
                    d_d = 0
                else:
                    opts = [(diag + w_sub, 1), (M1[d], 2), (M2[d], 3)]
                    val, d_d = min(opts, key=lambda t: t[0])
                    D[d] = min(val, sat)
            dirs[i - 1, d] = d_d | (d_m1 << 2) | (d_m2 << 3)
    return D, dirs, int(D[eth])


def traceback_numpy(dirs, eth, n):
    """Walk packed direction bits from (i=n, d=eth).  Returns op list."""
    ops = []
    i, d = n, eth
    state = 0  # 0=D, 1=M1, 2=M2
    while i > 0 or i + d - eth > 0:
        j = i + d - eth
        if i == 0:
            # top row: only horizontal gap back to (0,0)
            ops.append(OP_DEL)
            d -= 1
            continue
        if j == 0:
            ops.append(OP_INS)
            i -= 1
            d += 1
            continue
        byte = int(dirs[i - 1, d])
        dd, dm1, dm2 = byte & 0x3, (byte >> 2) & 0x1, (byte >> 3) & 0x1
        if state == 0:
            if dd == 0:
                ops.append(OP_MATCH); i -= 1
            elif dd == 1:
                ops.append(OP_SUB); i -= 1
            elif dd == 2:
                state = 1
            else:
                state = 2
        elif state == 1:  # M1: vertical move consumes read char
            ops.append(OP_INS)
            state = 0 if dm1 == 1 else 1
            i -= 1; d += 1
        else:  # M2: horizontal move consumes reference char
            ops.append(OP_DEL)
            state = 0 if dm2 == 1 else 2
            d -= 1
    ops.reverse()
    return ops


def alignment_cost(ops, w_sub=1, w_op=1, w_ex=1):
    """Cost of an op string under the paper's affine model (gap L: w_op+w_ex*L)."""
    cost, prev = 0, None
    for op in ops:
        if op == OP_SUB:
            cost += w_sub
        elif op in (OP_INS, OP_DEL):
            cost += w_ex + (w_op if op != prev else 0)
        prev = op
    return cost


@partial(jax.jit, static_argnames=("eth", "sat", "emit_dirs"))
def _banded_affine_impl(s1: jnp.ndarray, s2_window: jnp.ndarray, eth: int,
                        sat: int, emit_dirs: bool):
    """Shared affine band recurrence; ``emit_dirs`` statically selects
    whether the packed direction bytes are computed and stacked (the
    Pallas twin of this split is ``repro.kernels.affine_wf._row_step``)."""
    n = s1.shape[-1]
    band = 2 * eth + 1
    sat = jnp.int32(sat)
    d_idx = jnp.arange(band, dtype=jnp.int32)
    lead = s1.shape[:-1]

    j0 = d_idx - eth
    D0 = jnp.where(j0 < 0, sat, jnp.minimum(jnp.where(j0 == 0, 0, 1 + j0), sat))
    M0 = jnp.full((band,), sat, dtype=jnp.int32)
    M20 = jnp.where(j0 > 0, D0, sat)
    D0 = jnp.broadcast_to(D0, lead + (band,)).astype(jnp.int8)
    M0 = jnp.broadcast_to(M0, lead + (band,)).astype(jnp.int8)
    M20 = jnp.broadcast_to(M20, lead + (band,)).astype(jnp.int8)

    sat8 = sat.astype(jnp.int8)
    big = (sat + 40).astype(jnp.int8)  # stand-in for INF; raw values stay < 127

    def row(carry, i):
        Dp, M1p, M2p = carry
        j = i + d_idx - eth
        chars = jax.lax.dynamic_slice_in_dim(s2_window, i - 1, band, axis=-1)
        match = s1[..., i - 1][..., None] == chars

        # Direction decisions compare RAW (unclamped) candidates, exactly as
        # the numpy oracle does; stored values are clamped to sat afterwards.
        shift = lambda a: jnp.concatenate(
            [a[..., 1:], jnp.full_like(a[..., :1], big)], axis=-1)
        m1_ext = shift(M1p) + 1  # raw
        m1_open = shift(Dp) + 2  # raw
        M1n = jnp.minimum(jnp.minimum(m1_ext, m1_open), sat8).astype(jnp.int8)
        dM1 = (m1_open < m1_ext).astype(jnp.uint8)
        M1n = jnp.where(j >= 0, M1n, sat8).astype(jnp.int8)

        diag = Dp  # D[i-1, j-1]

        # Sequential in-row scan over the band: M2/D interdependence.
        def step(run, xs):
            d_left, m2_left = run  # stored D[i, j-1], M2[i, j-1] (or big)
            dg, m1n, dm1, mt, jj = xs
            m2_ext = m2_left + 1   # raw
            m2_open = d_left + 2   # raw
            m2n = jnp.minimum(jnp.minimum(m2_ext, m2_open), sat8)
            m2n = jnp.where(jj <= 0, sat8, m2n).astype(jnp.int8)
            sub_raw = dg + 1
            # D candidates (j >= 1): match -> diag; else min(sub, M1, M2)
            dmin = jnp.minimum(jnp.minimum(sub_raw, m1n), m2n)
            dval = jnp.where(mt, dg, jnp.minimum(dmin, sat8))
            # j == 0 column: D = M1; j < 0: saturated
            dval = jnp.where(jj == 0, m1n, dval)
            dval = jnp.where(jj < 0, sat8, dval).astype(jnp.int8)
            if not emit_dirs:
                return (dval, m2n), (dval, m1n, m2n)
            dm2 = (m2_open < m2_ext).astype(jnp.uint8)
            dd = jnp.where(
                mt, jnp.uint8(0),
                jnp.where(dmin == sub_raw, jnp.uint8(1),
                          jnp.where(dmin == m1n, jnp.uint8(2), jnp.uint8(3))))
            dd = jnp.where(jj == 0, jnp.uint8(2), dd)
            # j < 0 dirs zeroed (cells never reached in traceback)
            byte = (dd | (dm1 << 2) | (dm2 << 3)).astype(jnp.uint8)
            byte = jnp.where(jj < 0, jnp.uint8(0), byte)
            return (dval, m2n), (dval, m1n, m2n, byte)

        xs = (jnp.moveaxis(diag, -1, 0), jnp.moveaxis(M1n, -1, 0),
              jnp.moveaxis(dM1, -1, 0), jnp.moveaxis(match, -1, 0), j)
        init = (jnp.full(lead, big), jnp.full(lead, big))
        _, ys = jax.lax.scan(step, init, xs)
        Dn = jnp.moveaxis(ys[0], 0, -1)
        M1o = jnp.moveaxis(ys[1], 0, -1)
        M2n = jnp.moveaxis(ys[2], 0, -1)
        bytes_ = jnp.moveaxis(ys[3], 0, -1) if emit_dirs else None
        return (Dn, M1o, M2n), bytes_

    (Dl, _, _), dirs = jax.lax.scan(row, (D0, M0, M20), jnp.arange(1, n + 1))
    dist_end = Dl[..., eth].astype(jnp.int32)
    dist_min = jnp.min(Dl, axis=-1).astype(jnp.int32)
    if not emit_dirs:
        return dist_end, dist_min, None
    # scan stacks rows on axis 0 -> (n, ..., band); move to (..., n, band)
    return dist_end, dist_min, jnp.moveaxis(dirs, 0, -2)


@partial(jax.jit, static_argnames=("eth", "sat"))
def banded_affine(s1: jnp.ndarray, s2_window: jnp.ndarray, eth: int = 6,
                  sat: int = 32):
    """Batched banded affine WF.  s1: (..., n), s2_window: (..., n + 2*eth).

    Returns (dist_end, dist_min, dirs) with dirs (..., n, 2*eth+1) uint8
    packed direction bytes.  int8 value arithmetic saturated at ``sat``.
    """
    return _banded_affine_impl(s1, s2_window, eth, sat, emit_dirs=True)


@partial(jax.jit, static_argnames=("eth", "sat"))
def banded_affine_dist(s1: jnp.ndarray, s2_window: jnp.ndarray, eth: int = 6,
                       sat: int = 32):
    """Distance-only banded affine WF: ``banded_affine`` minus the direction
    planes.  Same recurrence, same saturation, but nothing O(n * band) is
    materialized — this is the distance-pass variant the compacted pipeline
    runs on every filter survivor, reserving the dirs-emitting pass for the
    one winner per read.

    s1: (..., n), s2_window: (..., n + 2*eth).  Returns (dist_end, dist_min).
    """
    de, dm, _ = _banded_affine_impl(s1, s2_window, eth, sat, emit_dirs=False)
    return de, dm


def traceback_step(i, d, state, byte, eth: int):
    """One fused-transition traceback step, shared by the jnp walk below
    and the Pallas kernel (``repro.kernels.traceback``).

    The oracle (``traceback_numpy``) spends an extra non-emitting
    iteration on every "enter M1/M2" transition (dd == 2/3) before the gap
    move reads the *same* cell's dm1/dm2 bit.  Fusing the transition with
    its gap move makes every step emit exactly one op, so a batch of
    walks stays in lockstep: step t IS op index t for every still-active
    lane, which is what lets the batched walk write one uniform output
    row per step instead of a per-lane scatter.

    All args are int32 arrays of one broadcastable shape (``byte`` is the
    packed direction byte at (i-1, d)).  Returns (op, ni, nd, ns, active);
    outputs for inactive lanes are unmasked — callers apply ``active``.
    """
    j = i + d - eth
    active = (i > 0) | (j > 0)
    dd, dm1, dm2 = byte & 3, (byte >> 2) & 1, (byte >> 3) & 1
    top = i == 0                      # top row: horizontal to (0,0)
    left = (j == 0) & ~top            # left col: vertical, state preserved
    in_d = (state == 0) & ~top & ~left
    # gap moves: an explicit M1/M2 step, or a D-cell transition (dd==2/3)
    # fused with the move it precedes — both consult this cell's dm1/dm2
    go_m1 = ((state == 1) & ~top & ~left) | (in_d & (dd == 2))
    go_m2 = ((state == 2) & ~top & ~left) | (in_d & (dd == 3))
    diag = in_d & (dd <= 1)
    vert = left | go_m1
    op = jnp.where(diag, jnp.where(dd == 0, OP_MATCH, OP_SUB),
                   jnp.where(vert, OP_INS, OP_DEL)).astype(jnp.int32)
    ni = jnp.where(diag | vert, i - 1, i)
    nd = jnp.where(vert, d + 1, jnp.where(top | go_m2, d - 1, d))
    ns = jnp.where(go_m1, jnp.where(dm1 == 1, 0, 1),
                   jnp.where(go_m2, jnp.where(dm2 == 1, 0, 2), state))
    return op, ni, nd, ns, active


@partial(jax.jit, static_argnames=("eth", "max_ops"))
def traceback(dirs: jnp.ndarray, eth: int, max_ops: int | None = None):
    """Batched traceback walk.  dirs: (..., n, band) -> ops (..., max_ops)
    filled from the END (left-padded with OP_NONE), plus op count.

    Every step emits exactly one op for every active lane
    (``traceback_step``), so the k-th op of each lane lands in the same
    output row ``(max_ops - 1 - k) % max_ops`` — one masked row update
    per step across the whole batch, no per-lane scatter.  A walk emits
    at most ``2n`` ops (each consumes a read and/or a window char), so
    the default ``max_ops = 2n + 2`` never truncates; smaller ``max_ops``
    wraps exactly like the pre-fused implementation's END-relative
    indexing did.
    """
    n, band = dirs.shape[-2], dirs.shape[-1]
    if max_ops is None:
        max_ops = 2 * n + 2
    lead = dirs.shape[:-2]
    flat = dirs.reshape((-1, n * band)).astype(jnp.int32)
    R = flat.shape[0]

    def cond(c):
        i, d, _, _, t, _ = c
        return ((i > 0) | (i + d - eth > 0)).any()

    def body(c):
        i, d, state, k, t, ops = c
        cell = jnp.maximum(i - 1, 0) * band + d
        byte = jnp.take_along_axis(flat, cell[:, None], axis=1)[:, 0]
        op, ni, nd, ns, active = traceback_step(i, d, state, byte, eth)
        ni = jnp.where(active, ni, i)
        nd = jnp.where(active, nd, d)
        ns = jnp.where(active, ns, state)
        row = jnp.remainder(max_ops - 1 - t, max_ops)
        cur = jax.lax.dynamic_slice_in_dim(ops, row, 1, axis=0)[0]
        ops = jax.lax.dynamic_update_slice_in_dim(
            ops, jnp.where(active, op, cur)[None], row, axis=0)
        return ni, nd, ns, k + active.astype(jnp.int32), t + 1, ops

    init = (jnp.full((R,), n, jnp.int32), jnp.full((R,), eth, jnp.int32),
            jnp.zeros((R,), jnp.int32), jnp.zeros((R,), jnp.int32),
            jnp.int32(0),
            jnp.full((max_ops, R), OP_NONE, dtype=jnp.int32))
    _, _, _, k, _, ops = jax.lax.while_loop(cond, body, init)
    return ops.T.reshape(lead + (max_ops,)), k.reshape(lead)
