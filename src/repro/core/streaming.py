"""Async double-buffered chunk streaming (throughput mode, paper Sec. V-C).

DART-PIM's controller hierarchy keeps every crossbar busy by refilling the
Reads-FIFOs while earlier batches compute: read routing, FIFO fill and WF
execution overlap instead of taking turns.  On the JAX side the same
overlap falls out of async dispatch — every jit call is a non-blocking
enqueue — *if* the host never stalls the queue.  The chunk loop here keeps
three chunks in flight:

  chunk i+1   host pad/encode + H2D transfer + seeding dispatch (phase 1)
  chunk i     capacity-count sync + WF stage dispatch         (phase 2)
  chunk i-1   device->host result fetch, on a fetch thread    (phase 3)

``stream_map`` runs that schedule.  The only host-blocking points are the
bucket-capacity count syncs of phase 2 and the D2H copies of phase 3; both
now overlap with the neighbouring chunks' device work instead of
serializing the pipeline.

``sync_map`` is the fully synchronous debugging path (``stream=False``):
it blocks at every stage boundary and records per-stage wall times, which
is what makes the double-buffering win *measurable* (see
``benchmarks/pipeline_bench.py --chunk-sweep``) — and what makes a failure
attributable to one stage instead of an async soup.

Both paths call the exact same jitted stages with the same static bucket
capacities, so their outputs are bit-identical (asserted in
``tests/test_streaming.py``).

The driver is the ``Mapper`` session of ``repro.core.mapper``: its
compacted-engine plans execute ``pipeline._ChunkPipeline`` phases through
``stream_map``/``sync_map``, and ``Mapper.map_async`` stacks a
caller-facing future on top of this chunk-level overlap.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError

import jax

from ..obs import registry as _metrics
from ..obs import tracing as _tracing

__all__ = ["stream_map", "sync_map", "donatable_argnums", "timed",
           "FetchStallError"]


class FetchStallError(RuntimeError):
    """The fetch thread exceeded the streaming watchdog (``watchdog_s``).

    A D2H copy that never completes — a wedged device queue, a deadlocked
    transfer — previously hung ``stream_map`` forever in the final
    ``f.result()``.  With a watchdog armed, the stall surfaces as this
    error instead, which the resilience layer treats like any other block
    failure (retry, then quarantine).

    Defined here (not in ``core.resilience``) so the streaming layer has
    no upward imports; ``resilience`` re-exports it as part of the error
    taxonomy.
    """


def donatable_argnums(*argnums: int) -> tuple[int, ...]:
    """``argnums`` where buffer donation is implemented, else ``()``.

    The streaming engine donates single-consumer chunk buffers into the WF
    stages (``jax.jit(..., donate_argnums=...)``) so each in-flight chunk
    reuses the previous chunk's device allocations instead of growing the
    arena.  The CPU backend does not implement donation and warns on every
    call, so donation is requested only where it exists.
    """
    return argnums if jax.default_backend() in ("tpu", "gpu") else ()


def timed(times: dict | None, key: str, t0: float) -> float:
    """Accumulate ``now - t0`` into ``times[key]``; returns a fresh t0.

    No-op (beyond the clock read) when ``times`` is None, so the phase
    functions can share one code path between the streamed and the
    synchronous engines.

    This is also the observability layer's stage hook: when the
    ``repro.obs`` tracer/registry are armed, the *same two clock reads*
    emit a span (with the calling thread's chunk context) and accrue the
    per-stage seconds counter — so the exported trace, the metrics
    snapshots and ``stage_times_s`` can never disagree on a duration.
    """
    t1 = time.perf_counter()
    if times is not None:
        times[key] = times.get(key, 0.0) + (t1 - t0)
        tr = _tracing.ACTIVE
        if tr is not None:
            tr.add(key, t0, t1)
        reg = _metrics.ACTIVE
        if reg is not None:
            reg.counter("repro_stage_seconds_total", stage=key).inc(t1 - t0)
    return t1


def stream_map(items: list, phase1, phase2, fetch,
               times: dict | None = None, *, injector=None,
               watchdog_s: float | None = None) -> list:
    """Double-buffered streaming execution over ``items`` (one per chunk).

    phase1(item)   -> state   : host prep + H2D + first async dispatch
    phase2(state)  -> outs    : count syncs + remaining stage dispatch
    fetch(outs)    -> result  : blocking device->host copy (fetch thread)

    phase1 of chunk i+1 is issued *before* phase2 of chunk i blocks on its
    capacity counts, so the next chunk's transfer+seeding are already in
    the device queue during the sync; fetches run on a worker thread so
    D2H copies of chunk i-1 overlap chunk i's compute.  Results come back
    in submission order.

    ``times``, when given (``MapperConfig.profile``), is handed to the
    ``fetch`` calls only — the dispatch phases stay non-blocking, and the
    fetch thread records per-stage *completion-time* offsets by blocking
    on the stage milestone arrays phase2 attached (the stage that the
    device queue is actually waiting on accrues the time).  It is only
    ever mutated from the single fetch worker, so no locking is needed.

    Fault tolerance: a fetch that fails used to surface only at the final
    ``f.result()`` drain — every later chunk was still dispatched and
    fetched first.  The dispatch loop now polls completed fetch futures
    and re-raises the first failure *promptly*, before dispatching more
    work.  ``watchdog_s`` bounds each fetch's wall time (a wedged fetch
    thread raises ``FetchStallError`` instead of hanging the caller
    forever) and ``injector`` is the chaos hook: each fetch first runs
    ``injector.sleep("fetch_stall")`` / ``injector.check("fetch_error")``
    on the fetch thread.  Both default off and add one branch per chunk.
    """
    n = len(items)
    if n == 0:
        return []

    if injector is None:
        run_fetch = fetch
    else:
        def run_fetch(outs, times_):
            injector.sleep("fetch_stall")
            injector.check("fetch_error")
            return fetch(outs, times_)

    # chunk attribution for span tracing: each phase call stamps the
    # in-flight chunk index on whichever thread runs it, so overlapping
    # chunks untangle in the exported trace
    tracing_on = _tracing.ACTIVE is not None

    def fetch_job(i, outs):
        if tracing_on:
            _tracing.set_ctx(chunk=i)
        return run_fetch(outs, times)

    reg = _metrics.ACTIVE
    if reg is not None:
        reg.counter("repro_chunks_total", mode="stream").inc(n)

    futs = [None] * n
    pool = ThreadPoolExecutor(max_workers=1,
                              thread_name_prefix="stream-fetch")
    try:
        if tracing_on:
            _tracing.set_ctx(chunk=0)
        state = phase1(items[0])
        for i in range(n):
            # prompt propagation: if an already-completed fetch failed,
            # raise now instead of dispatching the rest of the stream
            for f in futs[:i]:
                if f is not None and f.done():
                    f.result()
            if tracing_on:
                _tracing.set_ctx(chunk=i + 1)
            nxt = phase1(items[i + 1]) if i + 1 < n else None
            if tracing_on:
                _tracing.set_ctx(chunk=i)
            outs = phase2(state)
            futs[i] = pool.submit(fetch_job, i, outs)
            state = nxt
        out = []
        for i, f in enumerate(futs):
            try:
                out.append(f.result(timeout=watchdog_s))
            except FutureTimeoutError:
                # don't join the wedged worker — cancel what we can and
                # abandon the pool so the caller gets the error, not a
                # second hang in shutdown
                pool.shutdown(wait=False, cancel_futures=True)
                pool = None
                raise FetchStallError(
                    f"fetch of chunk {i}/{n} exceeded the streaming "
                    f"watchdog ({watchdog_s}s); device queue or fetch "
                    f"thread is stalled") from None
        return out
    finally:
        if pool is not None:
            pool.shutdown(wait=True)


def sync_map(items: list, phase1, phase2, fetch,
             times: dict | None = None) -> list:
    """Fully synchronous chunk execution (the ``stream=False`` debug path).

    Runs one chunk end-to-end at a time.  When the phase functions are
    handed a ``times`` dict they block at each stage boundary and record
    per-stage wall seconds into it (host_prep / h2d / seed / linear /
    affine / traceback / d2h).
    """
    reg = _metrics.ACTIVE
    if reg is not None:
        reg.counter("repro_chunks_total", mode="sync").inc(len(items))
    tracing_on = _tracing.ACTIVE is not None
    out = []
    for i, item in enumerate(items):
        if tracing_on:
            _tracing.set_ctx(chunk=i)
        out.append(fetch(phase2(phase1(item, times=times), times=times),
                         times=times))
    return out
