"""End-to-end driver: the distributed read-mapping SERVICE (the paper's
system kind) — batched requests against a sharded index on a device mesh,
through the unified ``Mapper`` session API.

    PYTHONPATH=src python examples/map_service.py [--shards 8 --batches 5]

Runs on virtual host devices (set before jax import), exercising the real
all_to_all seeding exchange, per-shard WF compute, and the result reduce —
the full DART-PIM dataflow of Fig. 6 at mesh scale.  Repeated same-size
batches hit the session plan cache (one compiled shard_map program),
which the closing line demonstrates.
"""
import argparse
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--shards", type=int, default=8)
ap.add_argument("--batches", type=int, default=5)
ap.add_argument("--batch-reads", type=int, default=64)
ap.add_argument("--genome", type=int, default=40_000)
args = ap.parse_args()
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.shards}")

import numpy as np  # noqa: E402
import jax  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.index import build_index  # noqa: E402
from repro.core.mapper import Mapper  # noqa: E402
from repro.data.genome import make_reference, sample_reads  # noqa: E402
from repro.launch.mesh import make_genomics_mesh  # noqa: E402


def main():
    mesh = make_genomics_mesh(args.shards)
    print(f"mesh: {mesh}")
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    mapper = Mapper(idx, topology="mesh", mesh=mesh)
    print(f"index sharded {args.shards} ways "
          f"({len(idx.uniq_kmers)} minimizers)")

    total, correct, dropped, t_total = 0, 0, 0, 0.0
    for b in range(args.batches):
        rs = sample_reads(ref, args.batch_reads, seed=100 + b)
        t0 = time.perf_counter()
        res = mapper.map(rs.reads)
        dt = time.perf_counter() - t0
        t_total += dt
        total += len(res.position)
        correct += int((np.abs(res.position - rs.true_pos) <= 6).sum())
        dropped += res.stats.dropped_send
        print(f"batch {b}: {len(res.position)} reads in {dt*1e3:.0f} ms "
              f"({len(res.position)/dt:.0f} reads/s), "
              f"dropped={res.stats.dropped_send}")
    print(f"\nservice accuracy: {correct/total:.3f} over {total} reads "
          f"({dropped} dropped); steady-state {total/t_total:.0f} reads/s "
          f"(CPU interpret scale)")
    print(f"plan cache: {mapper.plan_cache_hits} hits / "
          f"{mapper.plan_cache_misses} misses — warm batches reuse the "
          f"compiled shard_map program")


if __name__ == "__main__":
    main()
