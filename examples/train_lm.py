"""Train a reduced LM config for a few hundred steps with checkpointing and
a mid-run injected failure (the fault-tolerance path, end to end).

    PYTHONPATH=src python examples/train_lm.py [--arch smollm-135m --steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, reduced  # noqa: E402
from repro.train.trainer import FaultInjector, Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    tcfg = TrainerConfig(total_steps=args.steps, global_batch=8, seq_len=128,
                         ckpt_dir=args.ckpt, ckpt_every=100, log_every=25)
    trainer = Trainer(cfg, tcfg,
                      fault_injector=FaultInjector(fail_steps=(57,)))
    print(f"training reduced {args.arch} "
          f"({cfg.n_layers}L d={cfg.d_model}) for {args.steps} steps; "
          f"injected failure at step 57 (auto-retried); "
          f"checkpoints -> {args.ckpt}")
    trainer.run()
    for m in trainer.metrics_log:
        print(f"step {m['step']:>4}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  {m['step_time_s']*1e3:.0f} ms")
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    assert last["loss"] < first["loss"], "loss did not improve"
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} OK; "
          f"resume by re-running with the same --ckpt")


if __name__ == "__main__":
    main()
