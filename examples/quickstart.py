"""Quickstart: index a genome, open a Mapper session, map reads, print
alignments.

    python examples/quickstart.py [--genome 50000 --reads 32]
    (PYTHONPATH handled below)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.index import build_index
from repro.core.mapper import Mapper
from repro.data.genome import make_reference, sample_reads
from repro.io.cigar import cigar_from_ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--genome", type=int, default=50_000)
    ap.add_argument("--reads", type=int, default=32)
    args = ap.parse_args()

    print("== DART-PIM on JAX: quickstart ==")
    ref = make_reference(args.genome, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    print(f"reference: {len(ref)} bases; index: {len(idx.uniq_kmers)} "
          f"minimizers, {len(idx.positions)} occurrences, "
          f"segment length {idx.seg_len}")
    sb = idx.storage_bytes()
    print(f"storage blow-up (paper ~17x on HG38): {sb['blowup']:.1f}x")

    # the Mapper session owns device placement + the plan cache; inspect
    # the execution plan before running anything
    mapper = Mapper(idx)
    plan = mapper.plan(args.reads)
    print(f"\nplan: engine={plan.engine} chunks={plan.chunk_sizes} "
          f"(quantum {plan.chunk}), linear/affine instance ceilings "
          f"{plan.lin_cap_max}/{plan.aff_cap_max}")

    rs = sample_reads(ref, args.reads, seed=1)
    res = mapper.run(plan, rs.reads)
    acc = (np.abs(res.position - rs.true_pos) <= 6).mean()
    print(f"mapped {res.mapped.sum()}/{args.reads} reads; "
          f"accuracy(+-band) = {acc:.3f}")
    print(f"stats: {res.stats.candidates} candidates -> "
          f"{res.stats.survivors} survivors -> "
          f"{res.stats.affine_instances} affine instances\n")
    for i in range(min(5, args.reads)):
        print(f"read {i}: true={rs.true_pos[i]:>6} "
              f"mapped={res.position[i]:>6} dist={res.distance[i]} "
              f"cigar={cigar_from_ops(res.ops[i], res.op_count[i])}")


if __name__ == "__main__":
    main()
