"""Quickstart: index a genome, map reads, print alignments.

    python examples/quickstart.py   (PYTHONPATH handled below)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.affine_wf import OP_CHARS
from repro.core.index import build_index
from repro.core.pipeline import map_reads
from repro.data.genome import make_reference, sample_reads


def cigar(ops, counts):
    """Compact =/X/I/D run-length string from traceback op codes."""
    s, prev, run = [], None, 0
    for o in ops:
        if o == 4:
            continue
        c = OP_CHARS[int(o)]
        if c == prev:
            run += 1
        else:
            if prev is not None:
                s.append(f"{run}{prev}")
            prev, run = c, 1
    if prev is not None:
        s.append(f"{run}{prev}")
    return "".join(s)


def main():
    print("== DART-PIM on JAX: quickstart ==")
    ref = make_reference(50_000, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    print(f"reference: {len(ref)} bases; index: {len(idx.uniq_kmers)} "
          f"minimizers, {len(idx.positions)} occurrences, "
          f"segment length {idx.seg_len}")
    sb = idx.storage_bytes()
    print(f"storage blow-up (paper ~17x on HG38): {sb['blowup']:.1f}x")

    rs = sample_reads(ref, 32, seed=1)
    res = map_reads(idx, rs.reads)
    acc = (np.abs(res.position - rs.true_pos) <= 6).mean()
    print(f"\nmapped {res.mapped.sum()}/32 reads; "
          f"accuracy(+-band) = {acc:.3f}\n")
    for i in range(5):
        print(f"read {i}: true={rs.true_pos[i]:>6} "
              f"mapped={res.position[i]:>6} dist={res.distance[i]} "
              f"cigar={cigar(res.ops[i], res.op_count[i])}")


if __name__ == "__main__":
    main()
