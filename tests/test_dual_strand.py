"""Dual-strand mapping (`MapperConfig.both_strands`): reverse-complement
reads are recovered with correct strand bits on both topologies and via
the serving path, the strand reduce is deterministic (ties keep forward),
and forward-only behavior is unchanged."""
import numpy as np
import pytest

from repro.core.encoding import revcomp
from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.core.serving import BatcherConfig


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs_f = sample_reads(ref, 48, seed=13)                       # forward-only
    rs_b = sample_reads(ref, 48, seed=13, both_strands=True)    # same loci
    return idx, rs_f, rs_b


def _acc(res, rs, check_strand):
    ok = np.abs(res.position - rs.true_pos) <= 6
    if check_strand:
        ok &= res.strand == rs.strand
    return float(ok.mean())


def test_revcomp_involution():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 5, (6, 40)).astype(np.uint8)  # incl. sentinel 4
    np.testing.assert_array_equal(revcomp(revcomp(x)), x)
    np.testing.assert_array_equal(revcomp(np.array([0, 1, 2, 3, 4],
                                                   np.uint8)),
                                  [4, 0, 1, 2, 3])


def test_dual_strand_matches_forward_baseline(world):
    """Acceptance criterion: strand-aware accuracy on a both_strands set
    equals the forward-only baseline's accuracy on a forward-only set —
    reverse-strand reads are no longer unmapped."""
    idx, rs_f, rs_b = world
    base = Mapper(idx).map(rs_f.reads)
    assert base.strand is None  # single-strand runs carry no strand field
    dual = Mapper(idx, MapperConfig.from_index(
        idx, both_strands=True, chunk_reads=20)).map(rs_b.reads)
    assert rs_b.strand.sum() > 0  # the set really is mixed
    assert _acc(dual, rs_b, check_strand=True) == \
        _acc(base, rs_f, check_strand=False)
    # without dual-strand mapping the reverse half is lost
    fwd_only = Mapper(idx).map(rs_b.reads)
    assert _acc(fwd_only, rs_b, check_strand=False) < 0.7
    # stats re-expressed over real reads, with the reverse-winner count
    assert dual.stats.reads == 48
    assert dual.stats.reverse_best == int(
        (dual.strand & dual.mapped).sum())
    assert dual.stats["both_strands"] is True


def test_forward_reads_stay_forward_under_both_strands(world):
    """Ties (and forward-only workloads) keep the forward strand, so
    both_strands on a forward set reproduces the single-strand result."""
    idx, rs_f, _ = world
    single = Mapper(idx).map(rs_f.reads)
    dual = Mapper(idx,
                  MapperConfig.from_index(idx, both_strands=True)).map(
                      rs_f.reads)
    mapped = single.mapped
    assert (dual.strand[mapped] == 0).all()
    np.testing.assert_array_equal(dual.position[mapped],
                                  single.position[mapped])
    np.testing.assert_array_equal(dual.distance, single.distance)
    np.testing.assert_array_equal(dual.ops[mapped], single.ops[mapped])


def test_padded_engine_dual_strand_parity(world):
    idx, _, rs_b = world
    a = Mapper(idx, MapperConfig.from_index(
        idx, engine="padded", both_strands=True)).map(rs_b.reads)
    b = Mapper(idx, MapperConfig.from_index(
        idx, both_strands=True, chunk_reads=32)).map(rs_b.reads)
    for f in ("position", "distance", "mapped", "strand"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
    assert a.stats is None  # padded reference still reports no stats


def test_mesh_dual_strand(world):
    from repro.core.distributed import shard_index
    from repro.core.mapper import _flat_mesh
    idx, _, rs_b = world
    cfg = MapperConfig.from_index(idx, both_strands=True)
    single = Mapper(idx, cfg).map(rs_b.reads)
    mesh = Mapper(shard_index(idx, 1), cfg, topology="mesh",
                  mesh=_flat_mesh(1)).map(rs_b.reads)
    np.testing.assert_array_equal(mesh.position, single.position)
    np.testing.assert_array_equal(mesh.strand, single.strand)
    np.testing.assert_array_equal(mesh.distance, single.distance)
    assert mesh.ops is None  # stage B never tracebacks
    assert mesh.stats.reads == 48
    assert mesh.stats.reverse_best == single.stats.reverse_best


def test_service_carries_strand(world):
    idx, _, rs_b = world
    cfg = MapperConfig.from_index(idx, both_strands=True)
    svc = Mapper(idx, cfg).serve(BatcherConfig(bucket_min=16,
                                               bucket_max=64))
    direct = Mapper(idx, cfg).map(rs_b.reads)
    r0 = svc.submit(rs_b.reads[:30])
    r1 = svc.submit(rs_b.reads[30:])
    out = svc.flush()
    got = np.concatenate([out[r0].strand, out[r1].strand])
    np.testing.assert_array_equal(got, direct.strand)
    np.testing.assert_array_equal(
        np.concatenate([out[r0].position, out[r1].position]),
        direct.position)
    assert svc.totals["reverse_best"] == direct.stats.reverse_best
    assert svc.totals["reads"] == 48
