"""Shard-routed execution: ``ShardedGenomeIndex`` through the ``Mapper``.

The contract under test: plugging the partitioned index into either
topology changes *where* occurrence rows live (single: a budgeted LRU
device arena fed per chunk; mesh: partition i pre-placed on shard i)
but never changes a single mapped result — positions, distances,
strands, CIGARs all byte-match the flat-index session.  Plus the
residency mechanics (LRU eviction, compaction, budget errors), the
session validation errors, per-partition stats, and the mesh
plan-cache-hit-after-warm-up guarantee with zero runtime re-hashing.
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

from repro.core.index import build_index
from repro.core.mapper import Mapper, accumulate_partition_stats
from repro.core.pipeline import MapperConfig
from repro.data.genome import make_reference, sample_reads
from repro.index import shard_flat_index
from repro.index.residency import DeviceResidency
from repro.index.sharded import Partition

READ_LEN, K, W, ETH = 60, 10, 12, 4
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_RESULT_FIELDS = ("position", "distance", "distance2", "mapped", "strand",
                  "ops", "op_count", "linear_dist", "n_candidates")


@pytest.fixture(scope="module")
def world():
    ref = make_reference(20_000, seed=21, repeat_frac=0.02)
    flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH)
    sidx = shard_flat_index(flat, 4)
    rs = sample_reads(ref, 48, read_len=READ_LEN, seed=5,
                      both_strands=True)
    return ref, flat, sidx, rs


def _assert_same_results(a, b):
    for f in _RESULT_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        assert (va is None) == (vb is None), f
        if va is not None:
            assert np.array_equal(va, vb), f


def test_routed_single_matches_flat(world):
    ref, flat, sidx, rs = world
    cfg = MapperConfig.from_index(flat, chunk_reads=16, both_strands=True)
    res_flat = Mapper(flat, cfg).map(rs.reads)
    m = Mapper(sidx, cfg)
    res = m.map(rs.reads)
    _assert_same_results(res_flat, res)
    part = res.stats["partitions"]
    assert sum(part["minis_routed_per_partition"]) > 0
    assert part["partition_loads"] == 4
    assert part["arena_rows"] == sum(p.n_occurrences for p in sidx.parts)
    # accuracy sanity on top of equality
    mapped = res.mapped
    assert (np.abs(res.position[mapped] - rs.true_pos[mapped]) <= ETH).all()


def test_routed_single_under_budget_matches_flat(world):
    ref, flat, sidx, rs = world
    cfg = MapperConfig.from_index(flat, chunk_reads=16)
    res_flat = Mapper(flat, cfg).map(rs.reads)
    total = sum(p.n_occurrences for p in sidx.parts) * (sidx.seg_len + 4)
    m = Mapper(sidx, cfg, memory_budget_bytes=total)
    res = m.map(rs.reads)
    _assert_same_results(res_flat, res)
    part = res.stats["partitions"]
    assert part["h2d_bytes"] > 0
    # a second run reuses resident partitions: no new loads
    res2 = m.map(rs.reads)
    assert res2.stats["partitions"]["partition_loads"] == 0
    _assert_same_results(res_flat, res2)


def test_residency_lru_eviction_and_contents(world):
    _, _, sidx, _ = world
    rows = [p.n_occurrences for p in sidx.parts]
    row_b = sidx.seg_len + 4
    res = DeviceResidency(sidx, (max(rows) * 2 + max(rows) // 2) * row_b)
    for p in (0, 1, 2, 3, 0):
        res.ensure([p])
    assert res.evictions >= 2
    assert 0 in res.resident           # just touched — not evicted
    for p in res.resident:             # arena rows match partition data
        lo, nr = res._alloc[p]
        assert np.array_equal(np.asarray(res.segments_dev[lo:lo + nr]),
                              sidx.parts[p].read_segments())
        assert np.array_equal(np.asarray(res.positions_dev[lo:lo + nr]),
                              np.asarray(sidx.parts[p].positions))
    # pinned partitions of the current chunk are never victims
    need = res.resident[:1]
    res.ensure(need)
    assert need[0] in res.resident


def _synthetic_parts(sizes, seg_len):
    rng = np.random.default_rng(7)
    parts = []
    for i, n in enumerate(sizes):
        parts.append(Partition(
            kmers=np.arange(n, dtype=np.uint32),
            offsets=np.arange(n + 1, dtype=np.int32),
            positions=(1000 * (i + 1) + np.arange(n)).astype(np.int32),
            seg_len=seg_len,
            segments_raw=rng.integers(0, 4, (n, seg_len), dtype=np.uint8)))
    return parts


def test_compaction_relocates_pinned_and_bases_stay_authoritative():
    # Arena of 100 rows, partitions of 20/30/30/60 rows.  After
    # ensure([0, 1, 2]) packs the front, ensure([1, 3]) must evict 0
    # and 2, find free space fragmented ((0,20)+(50,50): 70 rows free
    # but no 60-row extent), compact — relocating still-resident pinned
    # partition 1 from row 20 to row 0 — and return partition 1's
    # *post-compaction* base, not the base it had when ensure() started.
    seg_len = 8
    parts = _synthetic_parts([20, 30, 30, 60], seg_len)
    idx = types.SimpleNamespace(parts=parts, seg_len=seg_len)
    res = DeviceResidency(idx, 100 * (seg_len + 4))
    assert res.ensure([0, 1, 2]) == {0: 0, 1: 20, 2: 50}
    bases = res.ensure([1, 3])
    assert res.evictions == 2 and res.compactions == 1
    assert res.resident == [1, 3]
    assert bases == {p: res._alloc[p][0] for p in bases}
    assert bases == {1: 0, 3: 30}
    # routed occ_idx rows are base + local CSR row: the arena contents
    # under every returned base must byte-match the source partition,
    # which is what keeps routed mappings identical to the flat index
    # across relocations.
    for p, base in bases.items():
        nr = parts[p].n_occurrences
        assert np.array_equal(np.asarray(res.segments_dev[base:base + nr]),
                              parts[p].read_segments())
        assert np.array_equal(np.asarray(res.positions_dev[base:base + nr]),
                              np.asarray(parts[p].positions))


def test_budget_too_small_errors(world):
    _, _, sidx, _ = world
    biggest = max(p.n_occurrences for p in sidx.parts)
    with pytest.raises(ValueError, match="largest partition"):
        DeviceResidency(sidx, (biggest - 1) * (sidx.seg_len + 4))
    cfg = MapperConfig.from_index(sidx)
    with pytest.raises(ValueError, match="memory_budget_bytes"):
        Mapper(sidx, cfg, memory_budget_bytes=16)


def test_mapper_session_validation(world):
    _, flat, sidx, _ = world
    with pytest.raises(ValueError, match='engine="padded"'):
        Mapper(sidx, MapperConfig.from_index(sidx, engine="padded"))
    with pytest.raises(ValueError, match='cigar_mode="lazy"'):
        Mapper(sidx, MapperConfig.from_index(sidx, cigar_mode="lazy"))
    with pytest.raises(ValueError, match="memory_budget_bytes only"):
        Mapper(flat, MapperConfig.from_index(flat),
               memory_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="4 partitions but the mesh has"):
        Mapper(sidx, MapperConfig.from_index(sidx), topology="mesh",
               n_shards=1)


def test_to_mesh_shards_matches_shard_index(world):
    from repro.core.distributed import shard_index
    _, flat, sidx, _ = world
    a = shard_index(flat, 4)
    b = sidx.to_mesh_shards()
    assert a.n_shards == b.n_shards and a.read_len == b.read_len
    for f in ("uniq_kmers", "offsets", "positions", "segments"):
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


def test_index_storage_and_stats_accumulation(world):
    _, flat, sidx, rs = world
    cfg = MapperConfig.from_index(flat, chunk_reads=16)
    m = Mapper(sidx, cfg)
    assert m.index_storage()["num_partitions"] == 4
    assert Mapper(flat, cfg).index_storage()["total_bytes"] > 0
    totals = {}
    for _ in range(2):
        accumulate_partition_stats(totals, m.map(rs.reads).stats)
    part = totals["partitions"]
    assert part["chunks_routed"] == 2 * -(-len(rs.reads) // 16)
    assert part["partition_loads"] == 4   # loaded once, reused after


MESH_SCRIPT = r"""
import numpy as np
from repro.core.index import build_index
from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.data.genome import make_reference, sample_reads
from repro.index import shard_flat_index

READ_LEN, K, W, ETH = 60, 10, 12, 4
ref = make_reference(20_000, seed=21, repeat_frac=0.02)
flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH)
sidx = shard_flat_index(flat, 4)
rs = sample_reads(ref, 48, read_len=READ_LEN, seed=5)
cfg = MapperConfig.from_index(flat)

res_flat = Mapper(flat, cfg, topology="mesh", n_shards=4).map(rs.reads)
m = Mapper(sidx, cfg, topology="mesh", n_shards=4)
res = m.map(rs.reads)
assert np.array_equal(res.position, res_flat.position)
assert np.array_equal(res.distance, res_flat.distance)
part = res.stats["partitions"]
assert part["num_partitions"] == 4
assert len(part["survivors_per_partition"]) == 4
assert part["occurrences_per_partition"] == \
    [p.n_occurrences for p in sidx.parts]

# pre-partitioned shards: repeated same-size batches hit the plan cache
# (no recompile, zero runtime re-hashing after placement)
res2 = m.map(rs.reads)
assert m.plan_cache_hits >= 1, (m.plan_cache_hits, m.plan_cache_misses)
assert m.plan_cache_misses == 1
assert np.array_equal(res2.position, res_flat.position)
print("MESH-OK")
"""


def test_mesh_prepartitioned(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "MESH-OK" in proc.stdout
