"""Streaming engine + serving batcher: the async double-buffered chunk
path must be bit-identical to the synchronous path and to the unchunked
run; the pow-2 request batcher must reassemble per-request results
exactly."""
import dataclasses

import numpy as np
import pytest

from repro.core.pipeline import MapperConfig, map_reads
from repro.core.serving import (BatcherConfig, MappingService, ReadBatcher,
                                pow2_buckets)

FIELDS = ("position", "distance", "mapped", "ops", "op_count",
          "linear_dist", "n_candidates")


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 40, seed=13)
    junk = np.random.default_rng(15).integers(0, 4, (8, 150)).astype(np.uint8)
    return idx, np.concatenate([rs.reads, junk])


def _assert_same(a, b):
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


def test_streamed_chunks_bit_identical_to_unchunked(world):
    idx, reads = world
    base = map_reads(idx, reads, MapperConfig(engine="compacted"))
    # 14 does not divide 48: exercises the padded partial last chunk
    streamed = map_reads(idx, reads, MapperConfig(engine="compacted",
                                                  chunk_reads=14))
    _assert_same(base, streamed)
    assert streamed.stats["n_chunks"] == 4
    assert streamed.stats["stream"] is True
    # padding reads are excluded from the workload accounting
    assert streamed.stats["candidates_valid"] == base.stats["candidates_valid"]
    assert streamed.stats["survivors"] == base.stats["survivors"]


def test_stream_true_false_bit_identical(world):
    idx, reads = world
    a = map_reads(idx, reads, MapperConfig(engine="compacted",
                                           chunk_reads=16, stream=True))
    b = map_reads(idx, reads, MapperConfig(engine="compacted",
                                           chunk_reads=16, stream=False))
    _assert_same(a, b)
    assert a.stats["stream"] is True and b.stats["stream"] is False
    # identical capacities -> identical executed-instance accounting
    for k in ("linear_instances", "affine_dist_instances", "survivors"):
        assert a.stats[k] == b.stats[k]


def test_sync_path_records_stage_times(world):
    idx, reads = world
    res = map_reads(idx, reads, MapperConfig(engine="compacted",
                                             chunk_reads=24, stream=False))
    times = res.stats["stage_times_s"]
    for key in ("host_prep", "h2d", "seed", "linear", "affine",
                "traceback", "d2h"):
        assert key in times and times[key] >= 0.0
    assert "stage_times_s" not in (map_reads(
        idx, reads[:16], MapperConfig(engine="compacted")).stats or {})


def test_streamed_pallas_matches_padded(world):
    idx, reads = world
    a = map_reads(idx, reads, MapperConfig(engine="padded"))
    b = map_reads(idx, reads, MapperConfig(engine="compacted",
                                           wf_backend="pallas",
                                           chunk_reads=16,
                                           lin_block_r=128, aff_block_r=64))
    _assert_same(a, b)


# ------------------------------------------------------------- batcher

def test_pow2_buckets_cover_and_shapes():
    for n in (1, 7, 64, 65, 129, 1000, 2048, 2900):
        buckets = pow2_buckets(n, lo=64, hi=1024)
        assert sum(buckets) >= n
        assert sum(buckets) - n < 1024          # residue pays < one bucket
        for b in buckets:
            assert 64 <= b <= 1024 and (b & (b - 1)) == 0
    assert pow2_buckets(0, lo=64, hi=1024) == []


def test_read_batcher_spans_and_accounting():
    bat = ReadBatcher(150, BatcherConfig(bucket_min=16, bucket_max=64))
    rng = np.random.default_rng(3)
    sizes = [5, 40, 23]
    rids = [bat.submit(rng.integers(0, 4, (n, 150)).astype(np.uint8))
            for n in sizes]
    assert bat.pending_reads == sum(sizes)
    reads, buckets, spans = bat.drain()
    assert len(reads) == sum(sizes)
    assert [spans[r][1] - spans[r][0] for r in rids] == sizes
    assert sum(buckets) >= len(reads)
    assert bat.pending_reads == 0 and bat.drain()[1] == []
    assert bat.stats["padded_reads"] == sum(buckets) - sum(sizes)


def test_mapping_service_matches_direct_map(world):
    idx, reads = world
    svc = MappingService(idx, MapperConfig(engine="compacted"),
                         BatcherConfig(bucket_min=8, bucket_max=32))
    requests = [reads[:10], reads[10:37], reads[37:]]
    rids = [svc.submit(r) for r in requests]
    results = svc.flush()
    assert set(results) == set(rids)
    for rid, req in zip(rids, requests):
        direct = map_reads(idx, req, MapperConfig(engine="compacted"))
        got = results[rid]
        np.testing.assert_array_equal(got.position, direct.position)
        np.testing.assert_array_equal(got.distance, direct.distance)
        np.testing.assert_array_equal(got.mapped, direct.mapped)
        np.testing.assert_array_equal(got.ops, direct.ops)
    # pow-2 coalescing kept the jit shapes bounded
    assert all(b in (8, 16, 32) for b in svc.batcher.stats["bucket_hist"])
    assert svc.flush() == {}
