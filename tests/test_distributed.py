"""Distributed read mapper + sharded LM steps on 8 virtual devices.

jax locks the device count at first init, so multi-device tests run in a
subprocess with XLA_FLAGS set (the dry-run itself uses 512 — see
repro/launch/dryrun.py; here 8 keeps test time sane).
"""
import os
import subprocess
import sys

import pytest

from conftest import JAX_PRE_05

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_MAPPER_SCRIPT = r"""
import jax, numpy as np
from repro.launch.mesh import make_genomics_mesh
mesh = make_genomics_mesh(8)
from repro.data.genome import make_reference, sample_reads
from repro.core.index import build_index
from repro.core.distributed import shard_index, distributed_map_reads
from repro.core.pipeline import MapperConfig, map_reads

ref = make_reference(20000, seed=0, repeat_frac=0.02)
idx = build_index(ref)
sidx = shard_index(idx, 8)
rs = sample_reads(ref, 64, seed=3)
cfg = MapperConfig(read_len=sidx.read_len, k=sidx.k, w=sidx.w, eth=sidx.eth,
                   aff_block_r=64)
pos, dist, dropped, stats = distributed_map_reads(mesh, sidx, rs.reads,
                                                  cfg=cfg, with_stats=True)
res = map_reads(idx, rs.reads)
assert (pos == res.position).all(), "distributed != single-shard positions"
assert (dist == res.distance).all()
assert dropped.sum() == 0
acc = (np.abs(pos - rs.true_pos) <= 6).mean()
assert acc > 0.95, acc

# stage B ran affine WF only on compacted filter survivors
assert stats["stage_b_affine_instances"] < stats["stage_b_entries"], stats
assert stats["stage_b_survivors"] <= stats["stage_b_affine_instances"]
assert stats["stage_b_affine_dropped"] == 0

# send-capacity overflow drops entries but never corrupts results
pos2, dist2, dropped2 = distributed_map_reads(mesh, sidx, rs.reads,
                                              send_cap=2)
assert dropped2.sum() > 0
mapped2 = pos2 >= 0
assert (np.abs(pos2[mapped2] - rs.true_pos[mapped2]) <= 6).mean() > 0.9

# survivor-capacity overflow: bounded affine work, sane subset results
cfg3 = MapperConfig(read_len=sidx.read_len, k=sidx.k, w=sidx.w, eth=sidx.eth,
                    stage_b_survivor_frac=0.001, aff_block_r=8)
pos3, dist3, drop3, st3 = distributed_map_reads(mesh, sidx, rs.reads,
                                                cfg=cfg3, with_stats=True)
assert st3["stage_b_affine_dropped"] > 0, st3
m3 = pos3 >= 0
assert m3.any()
assert (np.abs(pos3[m3] - rs.true_pos[m3]) <= 6).mean() > 0.9

# unified session API, mesh topology: bit-identical to the free function
from repro.core.mapper import Mapper
mapper = Mapper(sidx, cfg, topology="mesh", mesh=mesh)
mres = mapper.map(rs.reads)
assert (mres.position == pos).all() and (mres.distance == dist).all()
assert mres.stats["stage_b_survivors"] == stats["stage_b_survivors"]

# MappingService routed onto the mesh: repeated same-size buckets are
# pure plan-cache hits (no new executables after warm-up)
from repro.core.serving import BatcherConfig, MappingService
svc = MappingService(mapper, batcher=BatcherConfig(bucket_min=16,
                                                   bucket_max=32))
for _ in range(2):
    rids = [svc.submit(rs.reads[:40]), svc.submit(rs.reads[40:])]
    out = svc.flush()
    for rid, (lo, hi) in zip(rids, ((0, 40), (40, 64))):
        assert (np.abs(out[rid].position - res.position[lo:hi]) <= 0).all()
warm = mapper.plan_cache_misses
rids = [svc.submit(rs.reads[:40]), svc.submit(rs.reads[40:])]
svc.flush()
assert mapper.plan_cache_misses == warm, "same-size buckets recompiled"
assert mapper.plan_cache_hits > 0
print("DISTRIBUTED_MAPPER_OK")
"""

_LM_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
from repro.configs import ARCHS, reduced
from repro.models import lm, transformer
from repro.models.layers import Shardings
from repro.train.optimizer import adamw
import dataclasses

cfg = dataclasses.replace(reduced(ARCHS["olmo-1b"]), remat=True)
sh = Shardings(batch=("data",), model=("model",), fsdp=("data",),
               model_size=4)
key = jax.random.key(0)
params = transformer.init_params(cfg, key)
pspecs = transformer.param_specs(cfg, sh)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
params_sharded = jax.device_put(params, ns(pspecs))
opt = adamw(total_steps=4)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
with mesh:
    step = jax.jit(lm.make_train_step(cfg, opt, sh, num_microbatches=2))
    state = (params_sharded, opt.init(params_sharded), jnp.int32(0))
    state, metrics = step(state, batch)
    sharded_loss = float(metrics["loss"])

# unsharded single-device reference
step1 = jax.jit(lm.make_train_step(cfg, opt, num_microbatches=2))
state1 = (params, opt.init(params), jnp.int32(0))
state1, metrics1 = step1(state1, batch)
assert abs(sharded_loss - float(metrics1["loss"])) < 2e-2, (
    sharded_loss, float(metrics1["loss"]))
print("DISTRIBUTED_LM_OK")
"""


def _run(script):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_distributed_mapper_8dev():
    assert "DISTRIBUTED_MAPPER_OK" in _run(_MAPPER_SCRIPT)


@pytest.mark.slow
@pytest.mark.skipif(JAX_PRE_05, reason="jax<0.5: jax.sharding.AxisType and "
                    "the remat optimization_barrier differentiation rule "
                    "are missing (pre-existing seed failure on jax 0.4.37)")
def test_sharded_train_step_matches_unsharded():
    assert "DISTRIBUTED_LM_OK" in _run(_LM_SCRIPT)


_ELASTIC_SCRIPT = r"""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, reduced
from repro.models import lm, transformer
from repro.models.layers import Shardings
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw

cfg = reduced(ARCHS["olmo-1b"])
key = jax.random.key(0)
opt = adamw(warmup=0, total_steps=6)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}

def make(mesh_shape, axes):
    mesh = jax.make_mesh(mesh_shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
    sh = Shardings(batch=("data",), model=("model",), fsdp=("data",),
                   model_size=mesh.shape["model"])
    pspecs = transformer.param_specs(cfg, sh)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    return mesh, sh, pspecs, ns

with tempfile.TemporaryDirectory() as d:
    # train 2 steps on a (2, 4) mesh, checkpoint
    mesh, sh, pspecs, ns = make((2, 4), ("data", "model"))
    params = jax.device_put(transformer.init_params(cfg, key), ns(pspecs))
    state = (params, opt.init(params), jnp.int32(0))
    with mesh:
        step = jax.jit(lm.make_train_step(cfg, opt, sh))
        for _ in range(2):
            state, m = step(state, batch)
    ckpt.save(d, 2, state, extra={"next_step": 2})
    loss_a = None
    with mesh:
        state_a, m_a = step(state, batch)
        loss_a = float(m_a["loss"])

    # restart on a DIFFERENT mesh shape (node loss: 8 -> same 8 devices,
    # reshaped (4, 2)), restore, take the same step
    mesh2, sh2, pspecs2, ns2 = make((4, 2), ("data", "model"))
    params2 = jax.device_put(transformer.init_params(cfg, key), ns2(pspecs2))
    like = (params2, opt.init(params2), jnp.int32(0))
    shard_tree = (ns2(pspecs2), {"m": ns2(pspecs2), "v": ns2(pspecs2)},
                  NamedSharding(mesh2, P()))
    restored, extra = ckpt.restore(d, 2, like, sharding_tree=shard_tree)
    assert extra["next_step"] == 2
    with mesh2:
        step2 = jax.jit(lm.make_train_step(cfg, opt, sh2))
        state_b, m_b = step2(restored, batch)
    loss_b = float(m_b["loss"])
    assert abs(loss_a - loss_b) < 1e-3, (loss_a, loss_b)
print("ELASTIC_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(JAX_PRE_05, reason="jax<0.5: jax.sharding.AxisType is "
                    "missing (pre-existing seed failure on jax 0.4.37)")
def test_elastic_restore_across_mesh_shapes():
    """Checkpoint on a (2,4) mesh, restore + continue on (4,2): the step
    after restart produces the same loss as the uninterrupted run."""
    assert "ELASTIC_OK" in _run(_ELASTIC_SCRIPT)


_LONGCTX_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
from repro.configs import ARCHS, reduced
from repro.models import lm, transformer
from repro.models.layers import Shardings

# zamba-like reduced hybrid, batch=1, cache sequence sharded over data
cfg = reduced(ARCHS["zamba2-2.7b"])
sh = Shardings(batch=(), model=("model",), fsdp=(), model_size=2)
key = jax.random.key(0)
params = transformer.init_params(cfg, key)
pspecs = transformer.param_specs(cfg, sh)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
params_s = jax.device_put(params, ns(pspecs))
S = 64
cache = transformer.init_cache(cfg, 1, S)
cspecs = transformer.cache_specs(cfg, sh, seq_shard_axes=("data",))
cache_s = jax.device_put(cache, ns(cspecs))
toks = jax.random.randint(key, (1, 6), 0, cfg.vocab_size)
with mesh:
    serve = jax.jit(lm.make_serve_step(cfg, sh))
    c = cache_s
    for t in range(6):
        lg, c = serve(params_s, c, toks[:, t:t+1], jnp.int32(t))
# reference: unsharded decode
serve0 = jax.jit(lm.make_serve_step(cfg))
c0 = transformer.init_cache(cfg, 1, S)
for t in range(6):
    lg0, c0 = serve0(params, c0, toks[:, t:t+1], jnp.int32(t))
d = float(jnp.max(jnp.abs(lg.astype(jnp.float32) - lg0.astype(jnp.float32))))
assert d < 0.05, d
print("LONGCTX_OK")
"""


@pytest.mark.slow
@pytest.mark.skipif(JAX_PRE_05, reason="jax<0.5: jax.sharding.AxisType is "
                    "missing (pre-existing seed failure on jax 0.4.37)")
def test_seq_sharded_decode_matches_unsharded():
    """batch=1 decode with the KV cache sequence sharded over the data axis
    (the long_500k configuration) matches unsharded decode."""
    assert "LONGCTX_OK" in _run(_LONGCTX_SCRIPT)
