"""repro.io parsers: FASTA/FASTQ round-trips through the simulator's
writers, N -> sentinel handling, contig tables, chunked streaming, and
the fixed-read-length policy (skip short / truncate long, counted)."""
import io

import numpy as np
import pytest

from repro.core.index import SENTINEL
from repro.data.genome import (make_reference, sample_reads, write_fasta,
                               write_fastq)
from repro.io.fasta import ReferenceMap, load_reference, parse_fasta
from repro.io.fastq import FastqStream


# ------------------------------------------------------------------- FASTA

def test_fasta_roundtrip_multirecord_with_n():
    c1 = make_reference(500, seed=1)
    c1[100:107] = SENTINEL  # simulated N run survives the round trip
    c2 = make_reference(300, seed=2)
    buf = io.StringIO()
    write_fasta(buf, [("chr1", c1), ("chr2 description ignored", c2)],
                width=61)
    buf.seek(0)
    recs = list(parse_fasta(buf))
    assert [n for n, _ in recs] == ["chr1", "chr2"]
    np.testing.assert_array_equal(recs[0][1], c1)
    np.testing.assert_array_equal(recs[1][1], c2)


def test_fasta_lowercase_and_iupac_to_sentinel():
    buf = io.StringIO(">c\nacgtACGT\nNRYWn\n")
    (_, codes), = parse_fasta(buf)
    np.testing.assert_array_equal(codes[:8], [0, 1, 2, 3, 0, 1, 2, 3])
    assert (codes[8:] == SENTINEL).all()


def test_load_reference_spacer_and_locate():
    c1, c2 = make_reference(400, seed=3), make_reference(250, seed=4)
    buf = io.StringIO()
    write_fasta(buf, [("a", c1), ("b", c2)])
    buf.seek(0)
    ref, contigs = load_reference(buf, spacer=50)
    assert len(ref) == 400 + 50 + 250
    assert (ref[400:450] == SENTINEL).all()
    assert [c.offset for c in contigs] == [0, 450]
    rm = ReferenceMap(contigs)
    assert rm.locate(0) == (contigs[0], 0)
    assert rm.locate(399) == (contigs[0], 399)
    # positions inside the spacer clamp to the NEAREST contig edge:
    # just past contig a -> a's last base; just before b -> b's first
    assert rm.locate(420) == (contigs[0], 399)
    assert rm.locate(445) == (contigs[1], 0)
    assert rm.locate(450) == (contigs[1], 0)
    assert rm.locate(451) == (contigs[1], 1)


def test_fasta_errors():
    with pytest.raises(ValueError, match="before any"):
        list(parse_fasta(io.StringIO("ACGT\n")))
    with pytest.raises(ValueError, match="no records"):
        load_reference(io.StringIO(""), spacer=10)
    with pytest.raises(ValueError, match="no sequence"):
        load_reference(io.StringIO(">a\n>b\nACGT\n"), spacer=10)


# ------------------------------------------------------------------- FASTQ

def test_fastq_roundtrip_chunked():
    ref = make_reference(3000, seed=5)
    rs = sample_reads(ref, 24, read_len=80, seed=6, both_strands=True)
    names = [f"r{i}" for i in range(24)]
    buf = io.StringIO()
    write_fastq(buf, rs, names=names)
    buf.seek(0)
    stream = FastqStream(buf, chunk_reads=10)
    assert stream.read_len == 80  # inferred from the first record
    chunks = list(stream)
    assert [len(c) for c in chunks] == [10, 10, 4]
    np.testing.assert_array_equal(
        np.concatenate([c.reads for c in chunks]), rs.reads)
    np.testing.assert_array_equal(
        np.concatenate([c.quals for c in chunks]), rs.quals)
    assert [n for c in chunks for n in c.names] == names
    assert stream.n_reads == 24
    assert stream.n_skipped == 0 and stream.n_truncated == 0


def test_fastq_length_policy_counts():
    txt = ("@long\n" + "A" * 12 + "\n+\n" + "I" * 12 + "\n"
           "@short\nACG\n+\nIII\n"
           "@exact\n" + "C" * 8 + "\n+\n" + "#" * 8 + "\n")
    stream = FastqStream(io.StringIO(txt), read_len=8, chunk_reads=64)
    (chunk,) = list(stream)
    assert chunk.names == ["long", "exact"]
    assert stream.n_skipped == 1 and stream.n_truncated == 1
    assert chunk.reads.shape == (2, 8)
    np.testing.assert_array_equal(chunk.reads[1], np.full(8, 1))  # C
    assert chunk.quals[1].tobytes() == b"#" * 8


def test_fastq_n_bases_encode_to_a_but_seqs_keep_raw_text():
    stream = FastqStream(io.StringIO("@r\nANGN\n+\nIIII\n"), chunk_reads=4)
    (chunk,) = list(stream)
    np.testing.assert_array_equal(chunk.reads[0], [0, 0, 2, 0])
    assert chunk.seqs == ["ANGN"]  # raw text survives for SAM SEQ


def test_fastq_closes_owned_handle_on_early_break(tmp_path):
    p = tmp_path / "r.fq"
    p.write_text("".join(f"@r{i}\nACGT\n+\nIIII\n" for i in range(8)))
    stream = FastqStream(str(p), chunk_reads=2)
    it = iter(stream)
    next(it)
    it.close()  # abandon mid-file: generator finalization must close
    assert stream._f.closed


def test_fastq_malformed():
    with pytest.raises(ValueError, match="empty FASTQ"):
        FastqStream(io.StringIO(""))
    with pytest.raises(ValueError, match="'@' header"):
        list(FastqStream(io.StringIO("ACGT\n")))
    with pytest.raises(ValueError, match="separator"):
        list(FastqStream(io.StringIO("@r\nACGT\nACGT\nIIII\n")))
    with pytest.raises(ValueError, match="qualities"):
        list(FastqStream(io.StringIO("@r\nACGT\n+\nII\n")))


def test_simulator_forward_only_unchanged():
    """both_strands=False must keep the historical RNG stream: forward
    loci, reads, and error counts are bit-identical with the flag off and
    equal to the forward subset with it on."""
    ref = make_reference(2000, seed=7)
    a = sample_reads(ref, 16, read_len=60, seed=8)
    b = sample_reads(ref, 16, read_len=60, seed=8, both_strands=True)
    np.testing.assert_array_equal(a.true_pos, b.true_pos)
    assert (a.strand == 0).all() and b.strand.sum() > 0
    fwd = b.strand == 0
    np.testing.assert_array_equal(a.reads[fwd], b.reads[fwd])
    from repro.core.encoding import revcomp
    np.testing.assert_array_equal(a.reads[~fwd], revcomp(b.reads[~fwd]))
