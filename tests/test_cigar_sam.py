"""CIGAR rendering of END-aligned traceback ops and SAM structural
validity: hand-crafted edge alignments (leading/trailing indels,
adjacent I/D runs, all-match, the max_ops truncation path), a property
test that CIGAR lengths re-sum to the read length, and the
dependency-free SAM checker's own failure modes."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.affine_wf import OP_DEL, OP_INS, OP_MATCH, OP_NONE, OP_SUB
from repro.io.cigar import (cigar_from_ops, cigar_query_len, cigar_ref_len,
                            parse_cigar, trim_edge_deletions, unparse_cigar)
from repro.io.sam import sam_header, sam_record, validate_sam
from repro.io.fasta import Contig


def end_aligned(ops_list, max_ops):
    """Pack an op list the way affine_wf.traceback stores it: right-
    aligned in a fixed buffer, left-padded with OP_NONE."""
    arr = np.full(max_ops, OP_NONE, dtype=np.int32)
    if ops_list:
        arr[max_ops - len(ops_list):] = ops_list
    return arr, len(ops_list)


# ----------------------------------------------------------------- CIGAR

@pytest.mark.parametrize("ops,expect", [
    ([OP_MATCH] * 7, "7="),
    ([OP_INS, OP_INS] + [OP_MATCH] * 5, "2I5="),               # leading ins
    ([OP_DEL] + [OP_MATCH] * 4, "1D4="),                       # leading del
    ([OP_MATCH] * 4 + [OP_DEL, OP_DEL], "4=2D"),               # trailing del
    ([OP_MATCH, OP_INS, OP_INS, OP_DEL, OP_DEL, OP_DEL, OP_MATCH],
     "1=2I3D1="),                                              # adjacent I/D
    ([OP_SUB, OP_MATCH, OP_SUB], "1X1=1X"),
])
def test_cigar_hand_crafted(ops, expect):
    arr, k = end_aligned(ops, 32)
    assert cigar_from_ops(arr, k) == expect


def test_cigar_unmapped_and_truncation():
    arr, _ = end_aligned([OP_MATCH] * 4, 16)
    assert cigar_from_ops(arr, 0) == "*"          # unmapped
    # max_ops truncation: the walk was longer than the buffer, so the
    # stored ops are incomplete -> CIGAR unavailable, never a lying string
    assert cigar_from_ops(arr, 17) == "*"
    assert cigar_from_ops(arr, 16) == "*"         # padding inside the walk


def test_traceback_truncation_path_end_to_end():
    """A real traceback with max_ops smaller than the walk produces
    op_count > max_ops, which must render as '*'."""
    import jax.numpy as jnp
    from repro.core.affine_wf import banded_affine, traceback
    rng = np.random.default_rng(0)
    s1 = rng.integers(0, 4, 40).astype(np.uint8)
    win = np.full(40 + 12, 4, dtype=np.uint8)
    win[6 : 6 + 40] = s1
    _, _, dirs = banded_affine(jnp.asarray(s1), jnp.asarray(win), eth=6)
    ops, count = traceback(dirs[None], eth=6, max_ops=8)
    assert int(count[0]) == 40 > 8
    assert cigar_from_ops(np.asarray(ops[0]), int(count[0])) == "*"
    # and with a big enough buffer the same dirs give the full alignment
    ops2, count2 = traceback(dirs[None], eth=6, max_ops=82)
    assert cigar_from_ops(np.asarray(ops2[0]), int(count2[0])) == "40="


def test_trim_edge_deletions():
    parsed, shift = trim_edge_deletions(parse_cigar("2D3=1I2D"))
    assert unparse_cigar(parsed) == "3=1I" and shift == 2
    parsed, shift = trim_edge_deletions(parse_cigar("5="))
    assert unparse_cigar(parsed) == "5=" and shift == 0


def test_parse_cigar_rejects_garbage():
    for bad in ("abc", "3", "=3", "0M", "3=x"):
        with pytest.raises(ValueError):
            parse_cigar(bad)
    assert parse_cigar("*") == []


@settings(max_examples=60)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=48))
def test_cigar_lengths_resum_to_read_length(ops):
    """Property: for any op walk, the CIGAR's query length equals the
    number of read-consuming ops (= the read length the traceback walked)
    and the ref length equals the reference-consuming ops; round-trips
    through parse/unparse."""
    arr, k = end_aligned(ops, 64)
    cig = cigar_from_ops(arr, k)
    assert cigar_query_len(cig) == sum(
        1 for o in ops if o in (OP_MATCH, OP_SUB, OP_INS))
    assert cigar_ref_len(cig) == sum(
        1 for o in ops if o in (OP_MATCH, OP_SUB, OP_DEL))
    assert unparse_cigar(parse_cigar(cig)) == cig


# ------------------------------------------------------------------- SAM

def _doc(records):
    header = sam_header([Contig("chr1", 1000, 0)])
    return "\n".join(header + records) + "\n"


def test_validate_sam_accepts_wellformed():
    recs = [
        sam_record("r0", 0, "chr1", 5, 255, "4=", "ACGT", "IIII", nm=0),
        sam_record("r1", 16, "chr1", 9, 255, "2=1X1=", "ACGT", "IIII", nm=1),
        sam_record("r2", 4, "*", 0, 0, "*", "ACGT", "IIII"),
    ]
    st_ = validate_sam(_doc(recs), expect_reads=3)
    assert st_["n_mapped"] == 2 and st_["n_reverse"] == 1
    assert st_["contigs"] == {"chr1": 1000}


@pytest.mark.parametrize("rec,msg", [
    (["r", "0", "chr2", "5", "255", "4=", "*", "0", "0", "ACGT", "IIII"],
     "not in @SQ"),
    (["r", "0", "chr1", "0", "255", "4=", "*", "0", "0", "ACGT", "IIII"],
     "outside"),
    (["r", "4", "chr1", "5", "0", "*", "*", "0", "0", "ACGT", "IIII"],
     "unmapped record"),
    (["r", "0", "chr1", "5", "255", "3=", "*", "0", "0", "ACGT", "IIII"],
     "CIGAR consumes"),
    (["r", "0", "chr1", "5", "255", "1D4=", "*", "0", "0", "ACGT", "IIII"],
     "deletion"),
    (["r", "0", "chr1", "5", "255", "4=", "*", "0", "0", "ACGT", "III"],
     "length mismatch"),
    (["r", "0", "chr1", "5", "255", "4="], "columns"),
])
def test_validate_sam_catches_violations(rec, msg):
    with pytest.raises(AssertionError, match=msg):
        validate_sam(_doc(["\t".join(rec)]))


def test_emit_alignments_raw_seq_and_contig_shift():
    """Two review-found edges: (a) raw FASTQ text (N bases) must reach
    SEQ verbatim — the engine's N->A seeding codes must not; (b) the
    leading-deletion POS shift applies *before* contig lookup, so an
    alignment seeded in the inter-contig spacer lands on the contig of
    its first aligned base."""
    from repro.core.pipeline import MappingResult
    from repro.io.fasta import ReferenceMap
    from repro.io.sam import emit_alignments

    rm = ReferenceMap([Contig("c1", 100, 0), Contig("c2", 100, 110)])
    max_ops = 16
    ops = np.full((3, max_ops), OP_NONE, np.int32)
    ops[1, -6:] = [OP_DEL, OP_DEL] + [OP_MATCH] * 4  # leading 2D
    ops[2, -4:] = [OP_MATCH] * 4
    res = MappingResult(
        position=np.array([-1, 108, 5]),      # 108 = inside the spacer
        distance=np.array([32, 2, 0]),
        mapped=np.array([False, True, True]),
        strand=np.array([0, 0, 1], np.int8),
        ops=ops, op_count=np.array([0, 6, 4]))
    reads = np.zeros((3, 4), np.uint8)
    quals = np.tile(np.frombuffer(b"HIJK", np.uint8), (3, 1))
    recs = [r.split("\t") for r in emit_alignments(
        res, ["u", "m", "rev"], reads, quals, rm,
        seqs=["ANGN", "ACGT", "AANT"])]
    assert int(recs[0][1]) & 4 and recs[0][9] == "ANGN"  # N kept verbatim
    # 108 + 2 leading-D = 110 -> c2 local 0 -> POS 1, CIGAR trimmed
    assert recs[1][2] == "c2" and recs[1][3] == "1" and recs[1][5] == "4="
    # reverse strand: raw text revcomped (N self-complements), qual flipped
    assert recs[2][9] == "ANTT" and recs[2][10] == "KJIH"


def test_validate_sam_requires_header():
    with pytest.raises(AssertionError, match="@HD"):
        validate_sam("r\t4\t*\t0\t0\t*\t*\t0\t0\tA\tI\n")
    with pytest.raises(AssertionError, match="@SQ"):
        validate_sam("@HD\tVN:1.6\n")


# -------------------------------------------- validator tightening (PR 5)

def test_validate_sam_mapq_tightening():
    """Regression: the validator used to accept any 0..255 MAPQ on mapped
    records; with require_mapq it now rejects the 255 'unavailable'
    placeholder (and always rejects values past 255)."""
    ok = sam_record("r", 0, "chr1", 5, 60, "4=", "ACGT", "IIII")
    legacy = sam_record("r", 0, "chr1", 5, 255, "4=", "ACGT", "IIII")
    validate_sam(_doc([ok]), require_mapq=True)
    validate_sam(_doc([legacy]))  # single-end default: 255 still legal
    with pytest.raises(AssertionError, match="MAPQ 255"):
        validate_sam(_doc([legacy]), require_mapq=True)
    with pytest.raises(AssertionError, match="MAPQ"):
        validate_sam(_doc([sam_record("r", 0, "chr1", 5, 300, "4=",
                                      "ACGT", "IIII")]))
    # unmapped records keep MAPQ 0 regardless
    unm = sam_record("r", 4, "*", 0, 0, "*", "ACGT", "IIII")
    validate_sam(_doc([unm]), require_mapq=True)


def test_validate_sam_rnext_cross_checks():
    """Regression: RNEXT was previously unchecked — '=' with RNAME '*',
    unknown mate contigs, and PNEXT/TLEN on RNEXT '*' all slipped
    through."""
    with pytest.raises(AssertionError, match="RNAME is '\\*'"):
        validate_sam(_doc([sam_record("r", 4, "*", 0, 0, "*", "ACGT",
                                      "IIII", rnext="=", pnext=5)]))
    with pytest.raises(AssertionError, match="neither"):
        validate_sam(_doc([sam_record("r", 0, "chr1", 5, 60, "4=", "ACGT",
                                      "IIII", rnext="chrMissing")]))
    with pytest.raises(AssertionError, match="PNEXT/TLEN"):
        validate_sam(_doc([sam_record("r", 0, "chr1", 5, 60, "4=", "ACGT",
                                      "IIII", rnext="*", pnext=9)]))
    with pytest.raises(AssertionError, match="PNEXT"):
        validate_sam(_doc([sam_record("r", 0, "chr1", 5, 60, "4=", "ACGT",
                                      "IIII", rnext="=", pnext=5000)]))
    # and the well-formed spellings all pass
    validate_sam(_doc([sam_record("r", 0, "chr1", 5, 60, "4=", "ACGT",
                                  "IIII", rnext="=", pnext=9)]))


def test_validate_sam_paired_only_flags_need_0x1():
    with pytest.raises(AssertionError, match="without 0x1"):
        validate_sam(_doc([sam_record("r", 0x40, "chr1", 5, 60, "4=",
                                      "ACGT", "IIII")]))


def _pair(flag1, flag2, *, pos1=5, pos2=40, tlen1=75, tlen2=-75,
          rnext1="=", rnext2="=", pnext1=None, pnext2=None):
    r1 = sam_record("t", flag1, "chr1" if not flag1 & 0x4 else "*",
                    pos1 if not flag1 & 0x4 else 0,
                    60 if not flag1 & 0x4 else 0,
                    "4=" if not flag1 & 0x4 else "*", "ACGT", "IIII",
                    rnext=rnext1,
                    pnext=pnext1 if pnext1 is not None else pos2,
                    tlen=tlen1)
    r2 = sam_record("t", flag2, "chr1" if not flag2 & 0x4 else "*",
                    pos2 if not flag2 & 0x4 else 0,
                    60 if not flag2 & 0x4 else 0,
                    "4=" if not flag2 & 0x4 else "*", "ACGT", "IIII",
                    rnext=rnext2,
                    pnext=pnext2 if pnext2 is not None else pos1,
                    tlen=tlen2)
    return _doc([r1, r2])


def test_validate_sam_pair_consistency():
    st_ = validate_sam(_pair(0x63, 0x93))  # 99/147: proper FR pair
    assert st_["n_paired"] == 2 and st_["n_proper"] == 1
    # TLEN must be symmetric
    with pytest.raises(AssertionError, match="TLEN not symmetric"):
        validate_sam(_pair(0x63, 0x93, tlen2=75))
    # both mates claiming R1
    with pytest.raises(AssertionError, match="same mate slot"):
        validate_sam(_pair(0x63, 0x53))
    # 0x2 with an unmapped mate (0x8 missing on the mapped record)
    with pytest.raises(AssertionError, match="0x8 does not mirror|proper"):
        validate_sam(_pair(0x63, 0x97, rnext2="chr1", tlen1=0, tlen2=0))
    # 0x20 not mirroring the mate's 0x10
    with pytest.raises(AssertionError, match="0x20"):
        validate_sam(_pair(0x43, 0x93, tlen1=75))
    # PNEXT pointing away from the mate
    with pytest.raises(AssertionError, match="RNEXT/PNEXT"):
        validate_sam(_pair(0x63, 0x93, pnext1=7))
    # a lone paired record (mate record missing entirely)
    with pytest.raises(AssertionError, match="not 2"):
        validate_sam(_doc([sam_record("t", 0x63, "chr1", 5, 60, "4=",
                                      "ACGT", "IIII", rnext="=", pnext=40,
                                      tlen=75)]))
