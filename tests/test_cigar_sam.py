"""CIGAR rendering of END-aligned traceback ops and SAM structural
validity: hand-crafted edge alignments (leading/trailing indels,
adjacent I/D runs, all-match, the max_ops truncation path), a property
test that CIGAR lengths re-sum to the read length, and the
dependency-free SAM checker's own failure modes."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.affine_wf import OP_DEL, OP_INS, OP_MATCH, OP_NONE, OP_SUB
from repro.io.cigar import (cigar_from_ops, cigar_query_len, cigar_ref_len,
                            parse_cigar, trim_edge_deletions, unparse_cigar)
from repro.io.sam import sam_header, sam_record, validate_sam
from repro.io.fasta import Contig


def end_aligned(ops_list, max_ops):
    """Pack an op list the way affine_wf.traceback stores it: right-
    aligned in a fixed buffer, left-padded with OP_NONE."""
    arr = np.full(max_ops, OP_NONE, dtype=np.int32)
    if ops_list:
        arr[max_ops - len(ops_list):] = ops_list
    return arr, len(ops_list)


# ----------------------------------------------------------------- CIGAR

@pytest.mark.parametrize("ops,expect", [
    ([OP_MATCH] * 7, "7="),
    ([OP_INS, OP_INS] + [OP_MATCH] * 5, "2I5="),               # leading ins
    ([OP_DEL] + [OP_MATCH] * 4, "1D4="),                       # leading del
    ([OP_MATCH] * 4 + [OP_DEL, OP_DEL], "4=2D"),               # trailing del
    ([OP_MATCH, OP_INS, OP_INS, OP_DEL, OP_DEL, OP_DEL, OP_MATCH],
     "1=2I3D1="),                                              # adjacent I/D
    ([OP_SUB, OP_MATCH, OP_SUB], "1X1=1X"),
])
def test_cigar_hand_crafted(ops, expect):
    arr, k = end_aligned(ops, 32)
    assert cigar_from_ops(arr, k) == expect


def test_cigar_unmapped_and_truncation():
    arr, _ = end_aligned([OP_MATCH] * 4, 16)
    assert cigar_from_ops(arr, 0) == "*"          # unmapped
    # max_ops truncation: the walk was longer than the buffer, so the
    # stored ops are incomplete -> CIGAR unavailable, never a lying string
    assert cigar_from_ops(arr, 17) == "*"
    assert cigar_from_ops(arr, 16) == "*"         # padding inside the walk


def test_traceback_truncation_path_end_to_end():
    """A real traceback with max_ops smaller than the walk produces
    op_count > max_ops, which must render as '*'."""
    import jax.numpy as jnp
    from repro.core.affine_wf import banded_affine, traceback
    rng = np.random.default_rng(0)
    s1 = rng.integers(0, 4, 40).astype(np.uint8)
    win = np.full(40 + 12, 4, dtype=np.uint8)
    win[6 : 6 + 40] = s1
    _, _, dirs = banded_affine(jnp.asarray(s1), jnp.asarray(win), eth=6)
    ops, count = traceback(dirs[None], eth=6, max_ops=8)
    assert int(count[0]) == 40 > 8
    assert cigar_from_ops(np.asarray(ops[0]), int(count[0])) == "*"
    # and with a big enough buffer the same dirs give the full alignment
    ops2, count2 = traceback(dirs[None], eth=6, max_ops=82)
    assert cigar_from_ops(np.asarray(ops2[0]), int(count2[0])) == "40="


def test_trim_edge_deletions():
    parsed, shift = trim_edge_deletions(parse_cigar("2D3=1I2D"))
    assert unparse_cigar(parsed) == "3=1I" and shift == 2
    parsed, shift = trim_edge_deletions(parse_cigar("5="))
    assert unparse_cigar(parsed) == "5=" and shift == 0


def test_parse_cigar_rejects_garbage():
    for bad in ("abc", "3", "=3", "0M", "3=x"):
        with pytest.raises(ValueError):
            parse_cigar(bad)
    assert parse_cigar("*") == []


@settings(max_examples=60)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=48))
def test_cigar_lengths_resum_to_read_length(ops):
    """Property: for any op walk, the CIGAR's query length equals the
    number of read-consuming ops (= the read length the traceback walked)
    and the ref length equals the reference-consuming ops; round-trips
    through parse/unparse."""
    arr, k = end_aligned(ops, 64)
    cig = cigar_from_ops(arr, k)
    assert cigar_query_len(cig) == sum(
        1 for o in ops if o in (OP_MATCH, OP_SUB, OP_INS))
    assert cigar_ref_len(cig) == sum(
        1 for o in ops if o in (OP_MATCH, OP_SUB, OP_DEL))
    assert unparse_cigar(parse_cigar(cig)) == cig


# ------------------------------------------------------------------- SAM

def _doc(records):
    header = sam_header([Contig("chr1", 1000, 0)])
    return "\n".join(header + records) + "\n"


def test_validate_sam_accepts_wellformed():
    recs = [
        sam_record("r0", 0, "chr1", 5, 255, "4=", "ACGT", "IIII", nm=0),
        sam_record("r1", 16, "chr1", 9, 255, "2=1X1=", "ACGT", "IIII", nm=1),
        sam_record("r2", 4, "*", 0, 0, "*", "ACGT", "IIII"),
    ]
    st_ = validate_sam(_doc(recs), expect_reads=3)
    assert st_["n_mapped"] == 2 and st_["n_reverse"] == 1
    assert st_["contigs"] == {"chr1": 1000}


@pytest.mark.parametrize("rec,msg", [
    (["r", "0", "chr2", "5", "255", "4=", "*", "0", "0", "ACGT", "IIII"],
     "not in @SQ"),
    (["r", "0", "chr1", "0", "255", "4=", "*", "0", "0", "ACGT", "IIII"],
     "outside"),
    (["r", "4", "chr1", "5", "0", "*", "*", "0", "0", "ACGT", "IIII"],
     "unmapped record"),
    (["r", "0", "chr1", "5", "255", "3=", "*", "0", "0", "ACGT", "IIII"],
     "CIGAR consumes"),
    (["r", "0", "chr1", "5", "255", "1D4=", "*", "0", "0", "ACGT", "IIII"],
     "deletion"),
    (["r", "0", "chr1", "5", "255", "4=", "*", "0", "0", "ACGT", "III"],
     "length mismatch"),
    (["r", "0", "chr1", "5", "255", "4="], "columns"),
])
def test_validate_sam_catches_violations(rec, msg):
    with pytest.raises(AssertionError, match=msg):
        validate_sam(_doc(["\t".join(rec)]))


def test_emit_alignments_raw_seq_and_contig_shift():
    """Two review-found edges: (a) raw FASTQ text (N bases) must reach
    SEQ verbatim — the engine's N->A seeding codes must not; (b) the
    leading-deletion POS shift applies *before* contig lookup, so an
    alignment seeded in the inter-contig spacer lands on the contig of
    its first aligned base."""
    from repro.core.pipeline import MappingResult
    from repro.io.fasta import ReferenceMap
    from repro.io.sam import emit_alignments

    rm = ReferenceMap([Contig("c1", 100, 0), Contig("c2", 100, 110)])
    max_ops = 16
    ops = np.full((3, max_ops), OP_NONE, np.int32)
    ops[1, -6:] = [OP_DEL, OP_DEL] + [OP_MATCH] * 4  # leading 2D
    ops[2, -4:] = [OP_MATCH] * 4
    res = MappingResult(
        position=np.array([-1, 108, 5]),      # 108 = inside the spacer
        distance=np.array([32, 2, 0]),
        mapped=np.array([False, True, True]),
        strand=np.array([0, 0, 1], np.int8),
        ops=ops, op_count=np.array([0, 6, 4]))
    reads = np.zeros((3, 4), np.uint8)
    quals = np.tile(np.frombuffer(b"HIJK", np.uint8), (3, 1))
    recs = [r.split("\t") for r in emit_alignments(
        res, ["u", "m", "rev"], reads, quals, rm,
        seqs=["ANGN", "ACGT", "AANT"])]
    assert int(recs[0][1]) & 4 and recs[0][9] == "ANGN"  # N kept verbatim
    # 108 + 2 leading-D = 110 -> c2 local 0 -> POS 1, CIGAR trimmed
    assert recs[1][2] == "c2" and recs[1][3] == "1" and recs[1][5] == "4="
    # reverse strand: raw text revcomped (N self-complements), qual flipped
    assert recs[2][9] == "ANTT" and recs[2][10] == "KJIH"


def test_validate_sam_requires_header():
    with pytest.raises(AssertionError, match="@HD"):
        validate_sam("r\t4\t*\t0\t0\t*\t*\t0\t0\tA\tI\n")
    with pytest.raises(AssertionError, match="@SQ"):
        validate_sam("@HD\tVN:1.6\n")
