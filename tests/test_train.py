"""Training substrate: optimizer, checkpoint atomicity, fault tolerance,
microbatch equivalence, deterministic data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data.tokens import batch_for_step
from repro.models import lm, transformer
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.trainer import FaultInjector, Trainer, TrainerConfig

CFG = reduced(ARCHS["smollm-135m"])

# The trainer's remat path emits optimization_barrier, whose
# differentiation rule only exists in jax >= 0.5 — a pre-existing seed
# failure on this container's jax 0.4.37, gated as an explicit skip.
from conftest import JAX_PRE_05  # noqa: E402

SKIP_PRE_05 = pytest.mark.skipif(
    JAX_PRE_05,
    reason="jax<0.5: no differentiation rule for optimization_barrier "
           "(remat train step; pre-existing seed failure on jax 0.4.37)")


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(0, abs=1e-9)
    assert float(lr(55)) < float(lr(20))


def test_adamw_converges_quadratic():
    opt = adamw(lr=0.05, weight_decay=0.0, warmup=0, total_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for step in range(150):
        grads = {"w": 2 * (params["w"] - 1.0)}
        upd, state = opt.update(grads, state, params, jnp.int32(step))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=0.05)


@SKIP_PRE_05
def test_microbatch_equivalence():
    key = jax.random.key(0)
    params = transformer.init_params(CFG, key)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, CFG.vocab_size),
             "labels": jax.random.randint(key, (8, 32), 0, CFG.vocab_size)}
    opt = adamw(warmup=0, total_steps=4)
    s1 = (params, opt.init(params), jnp.int32(0))
    s2 = (params, opt.init(params), jnp.int32(0))
    t1 = jax.jit(lm.make_train_step(CFG, opt, num_microbatches=1))
    t4 = jax.jit(lm.make_train_step(CFG, opt, num_microbatches=4))
    (_, m1) = t1(s1, batch)[1], None
    s1n, m1 = t1(s1, batch)
    s4n, m4 = t4(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1n[0], s4n[0])
    assert max(jax.tree.leaves(d)) < 5e-2  # bf16 grads: small tolerance


def test_data_pipeline_deterministic_and_sharded():
    a1, b1 = batch_for_step(7, global_batch=8, seq_len=16, vocab_size=100,
                            seed=3)
    a2, b2 = batch_for_step(7, global_batch=8, seq_len=16, vocab_size=100,
                            seed=3)
    assert (a1 == a2).all() and (b1 == b2).all()
    # shards partition the global batch deterministically
    s0 = batch_for_step(7, global_batch=8, seq_len=16, vocab_size=100,
                        seed=3, shard_index=0, num_shards=2)[0]
    s1 = batch_for_step(7, global_batch=8, seq_len=16, vocab_size=100,
                        seed=3, shard_index=1, num_shards=2)[0]
    assert s0.shape == (4, 16)
    assert not (s0 == s1).all()


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, tree, extra={"next_step": step},
                  keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    restored, extra = ckpt.restore(str(tmp_path), 4, tree)
    assert extra["next_step"] == 4
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"a": jnp.ones((2, 3))}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.ones((3, 3))})


@SKIP_PRE_05
def test_trainer_fault_injection_and_resume(tmp_path):
    tc = TrainerConfig(total_steps=8, global_batch=4, seq_len=32,
                       ckpt_dir=str(tmp_path), ckpt_every=4, log_every=2,
                       seed=5)
    t = Trainer(CFG, tc, fault_injector=FaultInjector(fail_steps=(2, 5)))
    state = t.run()
    assert int(state[2]) == 8
    assert len(t.metrics_log) >= 2

    # uninterrupted run from scratch must produce the identical final loss
    t2 = Trainer(CFG, TrainerConfig(total_steps=8, global_batch=4, seq_len=32,
                                    log_every=2, seed=5))
    state2 = t2.run()
    l1 = [m["loss"] for m in t.metrics_log]
    l2 = [m["loss"] for m in t2.metrics_log]
    np.testing.assert_allclose(l1, l2, rtol=1e-5)

    # resume from checkpoint continues at the right step
    t3 = Trainer(CFG, TrainerConfig(total_steps=10, global_batch=4,
                                    seq_len=32, ckpt_dir=str(tmp_path),
                                    log_every=1, seed=5))
    t3.run()
    assert t3.metrics_log[0]["step"] == 8  # resumed, not restarted


def test_trainer_exhausted_retries_raises():
    tc = TrainerConfig(total_steps=4, global_batch=4, seq_len=32,
                       max_retries=1)
    fi = FaultInjector(fail_steps=(1,))
    fi.tripped = None  # force check to raise every attempt

    class AlwaysFail(FaultInjector):
        def check(self, step):
            if step == 1:
                raise RuntimeError("persistent failure")

    t = Trainer(CFG, tc, fault_injector=AlwaysFail())
    with pytest.raises(RuntimeError):
        t.run()


def test_adafactor_converges_and_state_small():
    from repro.train.optimizer import adafactor, adafactor_state_specs
    from jax.sharding import PartitionSpec as P
    opt = adafactor(lr=0.3, warmup=0, total_steps=300)
    params = {"w": jnp.full((8, 4), 3.0), "b": jnp.array([2.0])}
    state = opt.init(params)
    # factored state is O(rows+cols), not O(rows*cols)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state == (8 + 4) + (1 + 1)
    for step in range(200):
        grads = jax.tree.map(lambda p: 2 * (p - 1.0), params)
        upd, state = opt.update(grads, state, params, jnp.int32(step))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=0.05)
    # spec mapping drops the right axes
    specs = adafactor_state_specs({"w": P("data", "model"), "b": P(None)})
    assert specs["w"]["vr"] == P("data")
    assert specs["w"]["vc"] == P("model")


@SKIP_PRE_05
def test_train_step_with_adafactor():
    from repro.train.optimizer import adafactor
    opt = adafactor(warmup=0, total_steps=4)
    key = jax.random.key(0)
    params = transformer.init_params(CFG, key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, CFG.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, CFG.vocab_size)}
    ts = jax.jit(lm.make_train_step(CFG, opt, num_microbatches=2))
    state = (params, opt.init(params), jnp.int32(0))
    state, m = ts(state, batch)
    assert np.isfinite(float(m["loss"]))
