"""Ingestion hardening on truncated/corrupt real-world inputs: every
fault is exercised on both policies — ``on_error="strict"`` raises with
file:line context, ``on_error="permissive"`` quarantines the damaged
record(s) to the rejects sink, resynchronizes and keeps the healthy
stream flowing — plus the strict/permissive paths of the map_fastq CLI.
"""
import gzip
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.io.fasta import load_reference
from repro.io.fastq import FastqParseError, FastqStream, PairedFastqStream

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _fastq(records) -> str:
    return "".join(f"@{n}\n{s}\n+\n{q}\n" for n, s, q in records)


def _rec(name, seq="ACGTACGT"):
    return (name, seq, "I" * len(seq))


def _names(stream):
    return [n for chunk in stream for n in chunk.names]


def _pair_names(stream):
    return [(a, b) for c1, c2 in stream
            for a, b in zip(c1.names, c2.names)]


# ---------------------------------------------------------- single-end

def test_qual_len_mismatch_strict_has_file_line_context(tmp_path):
    p = tmp_path / "reads.fq"
    p.write_text(_fastq([_rec("r0"), ("r1", "ACGTACGT", "II")]))
    with pytest.raises(FastqParseError,
                       match=r"reads\.fq:5: malformed FASTQ record "
                             r"'@r1': 8 bases but 2 qualities") as ei:
        list(FastqStream(str(p), chunk_reads=4))
    assert ei.value.lineno == 5 and ei.value.slug == "qual_len_mismatch"


def test_permissive_quarantines_and_resyncs(tmp_path):
    p = tmp_path / "reads.fq"
    rej = tmp_path / "rej.fq"
    # a garbage run at a record boundary, then r1 with mismatched quals
    p.write_text(_fastq([_rec("r0")])
                 + "garbage\nmore garbage\n"
                 + "@r1\nACGTACGT\n+\nII\n"
                 + _fastq([_rec("r2")]))
    stream = FastqStream(str(p), chunk_reads=4, on_error="permissive",
                         rejects=str(rej))
    assert _names(stream) == ["r0", "r2"]
    assert stream.n_rejected == 2           # the garbage run + the record
    assert stream.reject_reasons["bad_header"] == 1
    assert stream.reject_reasons["qual_len_mismatch"] == 1
    assert "r1" in stream.rejected_names
    raw = rej.read_text()
    assert "@r1" in raw and "garbage" in raw  # raw lines preserved


def test_truncated_gzip_strict_and_permissive(tmp_path):
    full = tmp_path / "full.fastq.gz"
    with gzip.open(full, "wt") as f:
        f.write(_fastq([_rec(f"r{i}") for i in range(40)]))
    cut = tmp_path / "cut.fastq.gz"
    blob = full.read_bytes()
    cut.write_bytes(blob[: int(len(blob) * 0.6)])  # ends mid-member

    with pytest.raises(ValueError, match="truncated gzip FASTQ stream"):
        list(FastqStream(str(cut), chunk_reads=8))

    stream = FastqStream(str(cut), chunk_reads=8, on_error="permissive")
    names = _names(stream)
    assert names == [f"r{i}" for i in range(len(names))]  # prefix survives
    assert stream.reject_reasons == {"truncated_gzip": 1}


def test_empty_fastq_still_raises_even_permissive(tmp_path):
    p = tmp_path / "empty.fq"
    p.write_text("")
    with pytest.raises(ValueError, match="empty FASTQ: no records"):
        FastqStream(str(p), on_error="permissive")


# ---------------------------------------------------------- paired-end

def _write_pair(tmp_path, recs1, recs2):
    p1, p2 = tmp_path / "r1.fq", tmp_path / "r2.fq"
    p1.write_text(_fastq(recs1))
    p2.write_text(_fastq(recs2))
    return str(p1), str(p2)


def test_mate_desync_strict_raises(tmp_path):
    p1, p2 = _write_pair(tmp_path,
                         [_rec("a/1"), _rec("b/1"), _rec("c/1")],
                         [_rec("a/2"), _rec("c/2")])  # b/2 lost upstream
    with pytest.raises(ValueError, match="mate name mismatch: 'b/1' vs "
                                         "'c/2'"):
        _pair_names(PairedFastqStream(p1, p2, chunk_reads=4))


def test_mate_desync_permissive_repairs_midchunk(tmp_path):
    rej = tmp_path / "rej.fq"
    p1, p2 = _write_pair(
        tmp_path,
        [_rec("a/1"), _rec("b/1"), _rec("c/1"), _rec("d/1")],
        [_rec("a/2"), _rec("c/2"), _rec("d/2")])
    stream = PairedFastqStream(p1, p2, chunk_reads=4,
                               on_error="permissive", rejects=str(rej))
    # the lookahead re-pairs at c: only the orphaned b/1 is quarantined
    assert _pair_names(stream) == [("a", "a"), ("c", "c"), ("d", "d")]
    assert stream.reject_reasons == {"mate_desync": 1}
    assert stream.n_rejected == 1 and "b/1" in stream.rejected_names
    assert "@b/1" in rej.read_text()


def test_mate_desync_permissive_drops_both_when_unrepairable(tmp_path):
    p1, p2 = _write_pair(tmp_path,
                         [_rec("a/1"), _rec("b/1"), _rec("d/1")],
                         [_rec("a/2"), _rec("x/2"), _rec("d/2")])
    stream = PairedFastqStream(p1, p2, chunk_reads=4,
                               on_error="permissive")
    assert _pair_names(stream) == [("a", "a"), ("d", "d")]
    assert stream.reject_reasons == {"mate_desync": 1}
    assert stream.n_rejected == 1  # one pair-level quarantine (b + x)
    assert {"b/1", "x/2"} <= set(stream.rejected_names)


def test_unpaired_tail(tmp_path):
    p1, p2 = _write_pair(tmp_path,
                         [_rec("a/1"), _rec("b/1")], [_rec("a/2")])
    with pytest.raises(ValueError, match="unpaired FASTQ input: R2 ended"):
        _pair_names(PairedFastqStream(p1, p2, chunk_reads=4))
    stream = PairedFastqStream(p1, p2, chunk_reads=4,
                               on_error="permissive")
    assert _pair_names(stream) == [("a", "a")]
    assert stream.reject_reasons == {"unpaired_tail": 1}
    assert "b/1" in stream.rejected_names


def test_corrupt_record_inside_pair_stream(tmp_path):
    # R2's b-record is malformed: permissive rejects it at parse level,
    # then pair-level recovery quarantines the orphaned b/1
    p1, p2 = _write_pair(tmp_path,
                         [_rec("a/1"), _rec("b/1"), _rec("c/1")],
                         [_rec("a/2")])
    with open(p2, "a") as f:
        f.write("@b/2\nACGTACGT\n+\nII\n")  # bad quals; then c
        f.write(_fastq([_rec("c/2")]))
    stream = PairedFastqStream(p1, p2, chunk_reads=4,
                               on_error="permissive")
    assert _pair_names(stream) == [("a", "a"), ("c", "c")]
    assert stream.n_rejected == 2
    assert stream._s2.reject_reasons == {"qual_len_mismatch": 1}
    assert stream.reject_reasons == {"mate_desync": 1}


# --------------------------------------------------------------- FASTA

def test_fasta_all_sentinel_contig(tmp_path):
    p = tmp_path / "ref.fa"
    p.write_text(">good\nACGTACGTACGT\n>nrun\nNNNNNNNN\n>empty\n"
                 ">good2\nTTTTACGT\n")
    with pytest.raises(ValueError, match="FASTA contig 'nrun' has only "
                                         r"non-ACGT \(sentinel\) bases"):
        load_reference(str(p), spacer=4)
    rejected = []
    ref, contigs = load_reference(str(p), spacer=4, on_error="permissive",
                                  rejected=rejected)
    assert [c.name for c in contigs] == ["good", "good2"]
    assert rejected == [("nrun", "only non-ACGT (sentinel) bases"),
                        ("empty", "no sequence")]
    assert len(ref) == 12 + 4 + 8       # spacer only between kept contigs


def test_fasta_all_contigs_unusable_raises_even_permissive(tmp_path):
    p = tmp_path / "ref.fa"
    p.write_text(">n1\nNNNN\n>n2\nNN\n")
    with pytest.raises(ValueError, match="no records"):
        load_reference(str(p), spacer=4, on_error="permissive")


# ------------------------------------------------------------ CLI e2e

@pytest.fixture(scope="module")
def cli_world(tmp_path_factory):
    from repro.data.genome import make_reference, sample_reads, write_fasta
    d = tmp_path_factory.mktemp("cli")
    ref = make_reference(6_000, seed=5)
    rs = sample_reads(ref, 24, seed=7)
    fa = str(d / "ref.fa")
    write_fasta(fa, [("chr1", ref)])
    lines = []
    for i, row in enumerate(rs.reads):
        seq = "".join("ACGT"[b] for b in row)
        if i == 10:  # corrupt one record mid-file (quals too short)
            lines.append(f"@bad{i}\n{seq}\n+\nIII\n")
        else:
            lines.append(f"@r{i}\n{seq}\n+\n{'I' * len(seq)}\n")
    fq = str(d / "reads.fq")
    with open(fq, "w") as f:
        f.write("".join(lines))
    return d, fa, fq


def _run_cli(args):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.map_fastq", *args],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=300)


def test_cli_strict_fails_with_context_and_no_partial(cli_world):
    d, fa, fq = cli_world
    out = str(d / "strict.sam")
    p = _run_cli([fa, fq, "-o", out, "--chunk-reads", "16"])
    assert p.returncode != 0
    assert "reads.fq:" in p.stderr          # file:line context surfaced
    assert not os.path.exists(out)          # only .partial was written
    assert os.path.exists(out + ".partial")


def test_cli_permissive_quarantines_and_completes(cli_world):
    from repro.io.sam import validate_sam
    d, fa, fq = cli_world
    out, rej = str(d / "perm.sam"), str(d / "rej.fq")
    p = _run_cli([fa, fq, "-o", out, "--chunk-reads", "16",
                  "--on-error", "permissive", "--rejects", rej])
    assert p.returncode == 0, p.stderr[-2000:]
    assert "quarantined: 1 malformed record(s)" in p.stderr
    assert os.path.exists(out) and not os.path.exists(out + ".partial")
    text = open(out).read()
    validate_sam(text)
    qnames = {ln.split("\t")[0] for ln in text.splitlines()
              if ln and not ln.startswith("@")}
    assert qnames == {f"r{i}" for i in range(24) if i != 10}
    assert "@bad10" in open(rej).read()
