"""Cost-model reproduction of the paper's own numbers (Tables I/IV, Figs 9-10).

These are the validation points for the faithful reproduction: the analytic
model must land on the published values.
"""
import numpy as np
import pytest

from repro.core import costmodel as cm


def test_algorithm1_cell_cost_closed_form():
    # paper: 37b + 19 ops per linear WF cell; b=3 -> 130
    assert cm.linear_wf_cell_ops_closed(3) == 130
    assert cm.linear_wf_cell_ops_closed(8) == 315


def test_table_iv_linear_cycles_exact():
    lin = cm.linear_wf_cycles()
    assert lin["cells"] == 1950                     # 13 x 150
    assert lin["magic_cycles"] == 254_585           # paper Table IV
    assert lin["total_cycles"] == 258_620
    assert lin["energy_J"] == pytest.approx(45.9e-9, rel=0.01)


def test_table_iv_affine():
    aff = cm.affine_wf_cycles()
    assert aff["total_cycles"] == 1_308_699
    assert aff["energy_J"] == pytest.approx(229e-9, rel=0.01)


@pytest.mark.parametrize("max_reads,t_paper", [(12.5e3, 43.8), (50e3, 174.0)])
def test_execution_time_vs_paper(max_reads, t_paper):
    est = cm.dart_pim_system(max_reads=max_reads)
    assert est.exec_time_s == pytest.approx(t_paper, rel=0.05)


def test_energy_vs_paper_range():
    # paper: 20.8 kJ (12.5k) .. 34.9 kJ (50k)
    lo = cm.dart_pim_system(max_reads=12.5e3).energy_J
    hi = cm.dart_pim_system(max_reads=50e3).energy_J
    assert lo == pytest.approx(20.8e3, rel=0.10)
    assert hi == pytest.approx(34.9e3, rel=0.10)


def test_headline_speedups():
    st = cm.speedup_table(25e3)
    # paper Sec. VII-C: 227x / 5.7x / 334x / 257x vs minimap2 / Parabricks /
    # GenASM / SeGraM
    assert st["minimap2"]["speedup"] == pytest.approx(227, rel=0.05)
    assert st["parabricks"]["speedup"] == pytest.approx(5.7, rel=0.05)
    assert st["genasm"]["speedup"] == pytest.approx(334, rel=0.05)
    assert st["segram"]["speedup"] == pytest.approx(257, rel=0.05)


def test_energy_efficiency_vs_paper():
    st = cm.speedup_table(25e3)
    assert st["minimap2"]["energy_eff"] == pytest.approx(90.6, rel=0.10)
    assert st["segram"]["energy_eff"] == pytest.approx(20.7, rel=0.10)


def test_sw_vs_wf_latency_claim():
    # paper Sec. IV-B: linear WF ~2.8x lower latency than in-memory SW —
    # bit-width model gives 2.4x; the remainder comes from the two-row SW
    # layout, so assert the modelled range.
    r = cm.sw_vs_wf_latency_ratio()
    assert 2.0 < r < 3.0
    assert 2 * cm.linear_wf_cell_ops_closed(8) / (
        2 * cm.linear_wf_cell_ops_closed(3)) == pytest.approx(r)


def test_area_total():
    est = cm.dart_pim_system()
    assert est.area_mm2 == pytest.approx(8182, rel=0.01)  # paper: ~8170 mm^2


def test_full_system_simulation_caps():
    reads = np.array([30_000, 10_000, 50])
    pls = np.array([64, 200, 8])
    k_l, k_a, j_l, j_a = cm.full_system_simulation(reads, pls,
                                                   max_reads=25_000)
    assert k_l == 10_000 * 7          # bottleneck: 200 PLs -> 7 iterations
    assert j_a == 25_000 + 10_000 + 50
