"""The fused engine (seed -> filter -> linear -> affine -> strand-fold ->
traceback with no host sync between stages) must be bit-identical to the
staged compacted engine at every chunk boundary; ``cigar_mode`` lazy/off
must defer/skip traceback without changing any emitted SAM byte that does
not depend on it; and the adaptive stage-B survivor capacity must track
the session's observed survivor history."""
import numpy as np
import pytest

from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig, MappingResult
from repro.core.serving import MappingService

FIELDS = ("position", "distance", "mapped", "ops", "op_count",
          "n_candidates")


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=21, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 40, seed=23)
    junk = np.random.default_rng(25).integers(0, 4, (8, 150)).astype(np.uint8)
    return idx, np.concatenate([rs.reads, junk])


def _assert_same(a, b, fields=FIELDS):
    for f in fields:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


def _raw(res, field):
    """Field access that does NOT trip the lazy-materialization hook."""
    return object.__getattribute__(res, field)


# --------------------------------------------- fused vs staged identity

def test_fused_matches_staged_at_chunk_boundaries(world):
    """One device dispatch per chunk vs the two-sync staged engine, over
    dividing, non-dividing, and unchunked chunk shapes.  The fused path
    drops the intermediate linear distances (they never leave device)."""
    idx, reads = world
    ref = Mapper(idx, MapperConfig.from_index(idx)).map(reads)
    for chunk in (None, 16, 14):
        cfg = MapperConfig.from_index(idx, engine="fused",
                                      chunk_reads=chunk)
        res = Mapper(idx, cfg).map(reads)
        _assert_same(res, ref)
        assert _raw(res, "linear_dist") is None
        assert res.stats["survivors"] == ref.stats["survivors"]
        assert res.stats.engine == "fused"


def test_fused_pallas_backend_matches_jnp(world):
    idx, reads = world
    ref = Mapper(idx, MapperConfig.from_index(idx, engine="fused")).map(reads)
    cfg = MapperConfig.from_index(idx, engine="fused", wf_backend="pallas",
                                  lin_block_r=128, aff_block_r=64)
    _assert_same(Mapper(idx, cfg).map(reads), ref)


def test_fused_dual_strand_matches_padded(world):
    """Per-chunk strand stacking + the on-device strand fold vs the
    fully-eager padded reference, including the strand calls and the
    reverse-best accounting."""
    idx, reads = world
    pad = Mapper(idx, MapperConfig.from_index(
        idx, engine="padded", both_strands=True)).map(reads)
    for engine, chunk in (("fused", None), ("fused", 14),
                          ("compacted", 14)):
        cfg = MapperConfig.from_index(idx, engine=engine,
                                      both_strands=True, chunk_reads=chunk)
        res = Mapper(idx, cfg).map(reads)
        _assert_same(res, pad)
        np.testing.assert_array_equal(res.strand, pad.strand)
        assert res.stats["reverse_best"] == int(np.sum(
            (np.asarray(pad.strand) == 1) & np.asarray(pad.mapped)))


def test_fused_streamed_profile_stage_keys(world):
    idx, reads = world
    res = Mapper(idx, MapperConfig.from_index(
        idx, engine="fused", chunk_reads=16, profile=True)).map(reads)
    assert set(res.stats["stage_times_s"]) == {"seed", "fused", "d2h"}
    staged = Mapper(idx, MapperConfig.from_index(
        idx, chunk_reads=16, profile=True)).map(reads)
    assert set(staged.stats["stage_times_s"]) == \
        {"seed", "linear", "affine", "traceback", "d2h"}


# ------------------------------------------------------- cigar_mode

def test_lazy_cigar_defers_then_matches_eager(world):
    idx, reads = world
    eager = Mapper(idx, MapperConfig.from_index(idx)).map(reads)
    for engine in ("compacted", "fused"):
        cfg = MapperConfig.from_index(idx, engine=engine,
                                      cigar_mode="lazy", chunk_reads=14)
        res = Mapper(idx, cfg).map(reads)
        assert _raw(res, "ops") is None
        assert _raw(res, "lazy_tb") is not None
        assert res.stats["affine_dirs_instances"] == 0
        # first access materializes both fields, exactly once
        np.testing.assert_array_equal(res.ops, eager.ops)
        np.testing.assert_array_equal(res.op_count, eager.op_count)
        assert _raw(res, "lazy_tb") is None


def test_cigar_off_and_lazy_sam_output(world):
    """Same SAM records from eager and lazy (lazy materializes inside the
    writer); ``off`` degrades only the CIGAR/NM-bearing column to '*'
    semantics — positions, flags, SEQ stay identical."""
    from repro.io.fasta import Contig, ReferenceMap
    from repro.io.sam import emit_alignments
    idx, reads = world
    reads = reads[:24]
    rm = ReferenceMap([Contig("c1", 100_000, 0)])
    names = [f"r{i}" for i in range(len(reads))]
    quals = np.full(reads.shape, ord("I"), np.uint8)

    def sam(mode):
        cfg = MapperConfig.from_index(idx, engine="fused", cigar_mode=mode)
        res = Mapper(idx, cfg).map(reads)
        return [r.split("\t") for r in
                emit_alignments(res, names, reads, quals, rm)]

    eager, lazy, off = sam("eager"), sam("lazy"), sam("off")
    assert eager == lazy
    assert any(rec[5] not in ("*",) for rec in eager)  # real CIGARs exist
    for e, o in zip(eager, off):
        assert o[:3] == e[:3] and o[9] == e[9]
        assert o[5] == "*"
        if not int(o[1]) & 4:
            # without ops the leading-deletion POS shift cannot apply:
            # positions agree up to the band half-width
            assert abs(int(o[3]) - int(e[3])) <= 6


def test_lazy_survives_service_reassembly(world):
    """Request reassembly and pair splitting must slice the lazy holder,
    not materialize it; per-request CIGARs still match the eager service."""
    idx, reads = world

    def run(mode):
        svc = MappingService(Mapper(idx, MapperConfig.from_index(
            idx, cigar_mode=mode)))
        a = svc.submit(reads[:10])
        b = svc.submit(reads[10:27])
        return svc.flush(), a, b

    out_l, a, b = run("lazy")
    for rid in (a, b):
        assert _raw(out_l[rid], "ops") is None
        assert _raw(out_l[rid], "lazy_tb") is not None
    out_e, ae, be = run("eager")
    for rl, re_ in ((a, ae), (b, be)):
        np.testing.assert_array_equal(out_l[rl].ops, out_e[re_].ops)
        np.testing.assert_array_equal(out_l[rl].op_count,
                                      out_e[re_].op_count)


# ------------------------------------------- adaptive stage-B capacity

def test_stage_b_capacity_frac_override():
    from repro.core.distributed import stage_b_affine_capacity
    cfg = MapperConfig(stage_b_survivor_frac=0.5)
    base = stage_b_affine_capacity(4096, cfg)
    assert base == stage_b_affine_capacity(4096, cfg, frac=0.5)
    lo = stage_b_affine_capacity(4096, cfg, frac=0.1)
    hi = stage_b_affine_capacity(4096, cfg, frac=1.0)
    assert lo <= base <= hi
    assert hi <= 4096
    # alignment contract: capacities stay kernel-lane aligned (or the
    # full entry count when the fraction saturates)
    assert lo % cfg.aff_block_r == 0


def test_adaptive_capacity_tracks_survivor_history(world):
    from repro.core.distributed import shard_index
    from repro.core.mapper import _flat_mesh
    idx, reads = world
    mesh, sidx = _flat_mesh(1), shard_index(idx, 1)
    cfg = MapperConfig.from_index(idx, stage_b_adaptive=True,
                                  stage_b_quantile=0.9)
    m = Mapper(sidx, cfg, topology="mesh", mesh=mesh)
    assert m._stage_b_frac() is None          # no history yet -> static
    cap0 = m.plan(len(reads)).stage_b_affine_cap
    ref = m.map(reads)
    assert len(m._survivor_hist) == 1
    frac = m._stage_b_frac()
    assert frac is not None and 0.0 < frac <= 1.0
    plan1 = m.plan(len(reads))
    # the adaptively-derived capacity is part of the plan key, so a
    # changed capacity can never silently reuse a stale compiled program
    assert plan1.key[-1] == plan1.stage_b_affine_cap
    # low observed survivor rates shrink the provisioned capacity
    assert plan1.stage_b_affine_cap <= cap0
    res = m.run(plan1, reads)
    _assert_same(res, ref, fields=("position", "distance", "mapped"))
    assert res.stats["stage_b_affine_dropped"] == 0


def test_service_affine_drop_rate(world):
    idx, reads = world
    svc = MappingService(Mapper(idx, MapperConfig.from_index(idx)))
    svc.submit(reads)
    svc.flush()
    assert svc.affine_drop_rate == 0.0
    assert svc.totals["survivors"] > 0


# ------------------------------------------------------- config guards

def test_new_config_fields_validated():
    with pytest.raises(ValueError, match="cigar_mode"):
        MapperConfig(cigar_mode="sometimes")
    with pytest.raises(ValueError, match="padded"):
        MapperConfig(engine="padded", cigar_mode="lazy")
    with pytest.raises(ValueError, match="stage_b_quantile"):
        MapperConfig(stage_b_quantile=1.5)
    with pytest.raises(ValueError, match="stage_b_history"):
        MapperConfig(stage_b_history=0)
