"""Regenerate the golden paired-end SAM conformance file.

    PYTHONPATH=src python tests/make_golden.py

Only run this after a *deliberate* output-format or model change, and
review the diff of tests/golden/paired_small.sam like any other code
change — the golden test exists to make silent drift impossible.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # same fallback as tests/conftest.py
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))


def main():
    import test_pairing_properties as tpp

    text, pr, _ = tpp._paired_sam(tpp._world(), seed=779)
    out = os.path.join(tpp.GOLDEN_DIR, "paired_small.sam")
    os.makedirs(tpp.GOLDEN_DIR, exist_ok=True)
    with open(out, "w") as f:
        f.write(text)
    print(f"wrote {out}: {len(text.splitlines())} lines, "
          f"{pr.stats['n_proper']}/{pr.stats['n_pairs']} proper")


if __name__ == "__main__":
    main()
