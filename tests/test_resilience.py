"""Fault-tolerance policy layer (tier-1 units): config validation with
recoverable ValueErrors, deterministic fault injection, the degradation
ladder state machine, admission control / deadlines at the service, the
transactional flush, and retry/bisection quarantine on a real (small)
mapping world."""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig, map_reads
from repro.core.resilience import (AdmissionConfig, DegradeLadder,
                                   FaultInjector, InjectedFault,
                                   MappingError, ResilientMapper,
                                   RetryPolicy, ShedError)
from repro.core.serving import BatcherConfig, MappingService, ReadBatcher

# a no-wait policy for tests: failures must not sleep the suite
FAST = RetryPolicy(max_attempts=2, backoff_s=0.0, bisect_min=4,
                   degrade_after=1)


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 64, seed=13)
    return idx, rs.reads


# ------------------------------------------------------ config validation

def test_batcher_config_rejects_non_pow2():
    with pytest.raises(ValueError, match=r"bucket_min=48.*power"):
        BatcherConfig(bucket_min=48)
    with pytest.raises(ValueError, match=r"bucket_max=0.*power"):
        BatcherConfig(bucket_max=0)
    with pytest.raises(ValueError, match=r"bucket_min=128 must be <= "
                                         r"bucket_max=64"):
        BatcherConfig(bucket_min=128, bucket_max=64)


def test_read_batcher_submit_rejects_bad_shapes():
    bat = ReadBatcher(150)
    with pytest.raises(ValueError, match=r"expected \(n, 150\) reads, "
                                         r"got \(3, 100\)"):
        bat.submit(np.zeros((3, 100), np.uint8))
    with pytest.raises(ValueError, match=r"expected \(n, 150\)"):
        bat.submit(np.zeros(150, np.uint8))        # 1-D
    with pytest.raises(ValueError, match="empty read batch"):
        bat.submit(np.zeros((0, 150), np.uint8))
    assert bat.pending_reads == 0                  # nothing was enqueued


def test_policy_configs_validate():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="bisect_min"):
        RetryPolicy(bisect_min=0)
    with pytest.raises(ValueError, match="backoff_mult"):
        RetryPolicy(backoff_mult=0.5)
    with pytest.raises(ValueError, match="unknown admission policy"):
        AdmissionConfig(policy="drop")
    with pytest.raises(ValueError, match="max_pending_reads"):
        AdmissionConfig(max_pending_reads=0)
    with pytest.raises(ValueError, match="deadline_s"):
        AdmissionConfig(deadline_s=0.0)


# -------------------------------------------------------- fault injector

def test_injector_deterministic_per_site():
    a = FaultInjector(seed=7, rates={"bucket": 0.5, "fastq_record": 0.5})
    b = FaultInjector(seed=7, rates={"bucket": 0.5, "fastq_record": 0.5})
    seq_a = [a.fire("bucket") for _ in range(64)]
    # interleave another site: streams are independent, so "bucket"
    # must not be perturbed by "fastq_record" draws
    seq_b = []
    for _ in range(64):
        b.fire("fastq_record")
        seq_b.append(b.fire("bucket"))
    assert seq_a == seq_b
    assert any(seq_a) and not all(seq_a)
    assert a.fired["bucket"] == sum(seq_a)
    assert [FaultInjector(seed=8, rates={"bucket": 0.5}).fire("bucket")
            for _ in range(64)] != seq_a            # seed actually matters


def test_injector_from_spec():
    inj = FaultInjector.from_spec(
        "bucket=0.125,record=0.01,stall=1,stall_s=0.5,seed=3,"
        "poison=5;9,engines=fused;pallas")
    assert inj.seed == 3 and inj.stall_s == 0.5
    assert inj.rates == {"bucket": 0.125, "fastq_record": 0.01,
                         "fetch_stall": 1.0}
    assert inj.poison_rows == {5, 9}
    assert inj.fail_engines == {"fused", "pallas"}
    assert inj.armed
    assert not FaultInjector.from_spec("seed=3").armed
    with pytest.raises(ValueError, match="key=value"):
        FaultInjector.from_spec("bucket")


def test_injector_block_checks():
    inj = FaultInjector(poison_rows=[5])
    inj.check_block(6, 10, engine="compacted", backend="jnp")  # clean
    with pytest.raises(InjectedFault, match=r"poisoned read\(s\) \[5\]"):
        inj.check_block(0, 8, engine="compacted", backend="jnp")
    eng = FaultInjector(fail_engines=["fused"])
    eng.check_block(0, 8, engine="compacted", backend="jnp")   # clean
    with pytest.raises(InjectedFault, match="'fused' is marked failing"):
        eng.check_block(0, 8, engine="fused", backend="jnp")


# ------------------------------------------------------- degrade ladder

def test_degrade_ladder_rungs_and_stickiness():
    lad = DegradeLadder(MapperConfig(engine="fused", wf_backend="pallas"),
                        degrade_after=2)
    assert [(c.engine, c.wf_backend) for c in lad.rungs] == [
        ("fused", "pallas"), ("compacted", "pallas"), ("compacted", "jnp")]
    assert not lad.fail()                   # streak 1 < degrade_after
    lad.ok()                                # success resets the streak
    assert not lad.fail() and lad.fail()    # two consecutive -> degrade
    assert lad.level == 1 and lad.degraded
    lad.ok()
    assert lad.level == 1                   # sticky: ok() never climbs
    assert lad.fail() is False and lad.fail() is True
    assert lad.level == 2 and not lad.fail()  # bottom rung: nowhere to go
    assert lad.steps == 2
    assert "compacted/jnp" in lad.describe()


def test_degrade_ladder_trivial_for_base_config():
    lad = DegradeLadder(MapperConfig(engine="compacted", wf_backend="jnp"))
    assert len(lad.rungs) == 1
    assert not lad.fail() and not lad.degraded


# ---------------------------------------------- retry/bisect on a mapper

def test_resilient_map_clean_matches_plain(world):
    idx, reads = world
    cfg = MapperConfig(engine="compacted")
    res, mask, counters = ResilientMapper(Mapper(idx, cfg), FAST).map(reads)
    assert not mask.any() and res.failed is None
    base = map_reads(idx, reads, cfg)
    np.testing.assert_array_equal(res.position, base.position)
    np.testing.assert_array_equal(res.distance, base.distance)
    assert counters == dict(retries=0, failed_reads=0, failed_blocks=0,
                            degraded_steps=0)


def test_poisoned_row_quarantined_by_bisection(world):
    idx, reads = world
    cfg = MapperConfig(engine="compacted")
    inj = FaultInjector(poison_rows=[5])
    rm = ResilientMapper(Mapper(idx, cfg, injector=inj), FAST, injector=inj)
    res, mask, counters = rm.map(reads)
    # bisection narrows the failure to the bisect_min-sized block
    # holding row 5 (64 -> 32 -> 16 -> 8 -> rows [4, 8)), not the batch
    assert mask.sum() == FAST.bisect_min
    np.testing.assert_array_equal(np.flatnonzero(mask), np.arange(4, 8))
    assert res.failed is not None
    np.testing.assert_array_equal(res.failed, mask)
    # quarantined rows come back unmapped; healthy rows match plain
    base = map_reads(idx, reads, cfg)
    assert not res.mapped[mask].any()
    assert (res.position[mask] == -1).all()
    np.testing.assert_array_equal(res.position[~mask],
                                  base.position[~mask])
    np.testing.assert_array_equal(res.ops[~mask], base.ops[~mask])
    assert counters["failed_reads"] == FAST.bisect_min
    assert counters["failed_blocks"] == 1 and counters["retries"] > 0
    assert res.stats.failed_reads == FAST.bisect_min
    assert res.stats.extra["resilience"] == counters


def test_transient_fault_retried_away(world):
    idx, reads = world
    # rate 1.0 on the first draw only: fail once, then clean forever
    class OneShot(FaultInjector):
        def __init__(self):
            super().__init__(rates={"bucket": 1.0})
            self._shots = 1

        def fire(self, site):
            if site == "bucket" and self._shots > 0:
                self._shots -= 1
                return True
            return False

    rm = ResilientMapper(Mapper(idx, MapperConfig(engine="compacted")),
                         RetryPolicy(max_attempts=3, backoff_s=0.0),
                         injector=OneShot())
    res, mask, counters = rm.map(reads)
    assert not mask.any() and counters["retries"] == 1
    assert counters["failed_reads"] == 0


# ------------------------------------------------- service-level policy

def _service(idx, **kw):
    return MappingService(idx, MapperConfig(engine="compacted"),
                          BatcherConfig(bucket_min=8, bucket_max=32), **kw)


def test_admission_shed(world):
    idx, reads = world
    svc = _service(idx, admission=AdmissionConfig(max_pending_reads=16,
                                                  policy="shed"))
    svc.submit(reads[:10])
    with pytest.raises(ShedError, match="resubmit after a flush"):
        svc.submit(reads[10:20])
    assert svc.totals["shed_requests"] == 1
    # a single oversize request against an empty queue is still accepted
    svc.flush()
    rid = svc.submit(reads[:32])
    assert isinstance(svc.flush()[rid].position, np.ndarray)


def test_admission_block_drains_and_delivers_later(world):
    idx, reads = world
    svc = _service(idx, admission=AdmissionConfig(max_pending_reads=16,
                                                  policy="block"))
    r0 = svc.submit(reads[:10])
    r1 = svc.submit(reads[10:20])   # overflow -> synchronous drain of r0
    assert svc.batcher.pending_reads == 10
    out = svc.flush()               # delivers r0 (held) and r1 together
    assert set(out) == {r0, r1}
    assert svc.totals["shed_requests"] == 0


def test_deadline_expiry_resolves_to_error(world):
    idx, reads = world
    svc = _service(idx)
    r0 = svc.submit(reads[:8], deadline_s=0.01)
    r1 = svc.submit(reads[8:20])
    time.sleep(0.03)
    out = svc.flush()
    assert isinstance(out[r0], MappingError)
    assert out[r0].error_type == "deadline" and out[r0].n_reads == 8
    assert not out[r0].ok
    assert svc.totals["deadline_misses"] == 1
    # the live request still mapped, against the rebuilt batch
    np.testing.assert_array_equal(
        out[r1].position,
        map_reads(idx, reads[8:20], MapperConfig(engine="compacted"))
        .position)


def test_flush_transactional_on_internal_failure(world):
    idx, reads = world
    inj = FaultInjector(rates={"flush": 1.0})
    svc = _service(idx, injector=inj)
    rids = [svc.submit(reads[:10]), svc.submit(reads[10:20])]
    out = svc.flush()
    # every drained rid resolves exactly once, to a structured error
    assert sorted(out) == sorted(rids)
    for rid in rids:
        assert isinstance(out[rid], MappingError)
        assert out[rid].error_type == "internal"
        assert "InjectedFault" in out[rid].message
    assert svc.totals["failed_requests"] == 2
    assert svc.flush() == {}        # nothing stranded in pending state


def test_flush_partial_quarantine_per_request(world):
    idx, reads = world
    # poison one row of the first request; the second must be untouched
    inj = FaultInjector(poison_rows=[2])
    svc = _service(idx, retry=FAST, injector=inj)
    r0 = svc.submit(reads[:8])
    r1 = svc.submit(reads[8:20])
    out = svc.flush()
    assert out[r0].failed is not None and out[r0].failed.sum() > 0
    assert not out[r0].mapped[out[r0].failed].any()
    assert out[r1].failed is None or not out[r1].failed.any()
    np.testing.assert_array_equal(
        out[r1].position,
        map_reads(idx, reads[8:20], MapperConfig(engine="compacted"))
        .position)
    assert svc.totals["failed_reads"] > 0


def test_mapping_error_shape():
    e = MappingError("execution", "boom", n_reads=8, attempts=2)
    assert not e.ok and e.error_type == "execution"
    assert dataclasses.asdict(e)["n_reads"] == 8
