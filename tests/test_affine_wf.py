import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.affine_wf import (OP_DEL, OP_INS, OP_MATCH, OP_NONE, OP_SUB,
                                  alignment_cost, banded_affine,
                                  banded_affine_numpy, full_affine_numpy,
                                  traceback, traceback_numpy)


def _make_pair(r, n, eth, n_edits):
    s1 = r.integers(0, 4, n).astype(np.uint8)
    lst = list(np.concatenate([r.integers(0, 4, eth), s1,
                               r.integers(0, 4, eth)]))
    for _ in range(n_edits):
        p = int(r.integers(eth, eth + n - 2))
        t = int(r.integers(0, 3))
        if t == 0:
            lst[p] = int(r.integers(0, 4))
        elif t == 1:
            lst.insert(p, int(r.integers(0, 4)))
        else:
            del lst[p]
    win = np.array((lst + [0] * (n + 2 * eth))[: n + 2 * eth], dtype=np.uint8)
    return s1, win


@given(st.integers(0, 10 ** 6), st.integers(10, 50), st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_jnp_matches_numpy_including_directions(seed, n, edits):
    r = np.random.default_rng(seed)
    eth, sat = 6, 32
    s1, win = _make_pair(r, n, eth, edits)
    Db, dirs_np, d_np = banded_affine_numpy(s1, win, eth, sat)
    de, dm, dirs = banded_affine(jnp.array(s1), jnp.array(win), eth=eth,
                                 sat=sat)
    assert int(de) == d_np
    assert (np.array(dirs) == dirs_np).all()


@given(st.integers(0, 10 ** 6), st.integers(10, 40))
@settings(max_examples=25, deadline=None)
def test_band_matches_full_gotoh_in_band(seed, n):
    r = np.random.default_rng(seed)
    eth, sat = 8, 32
    s1, win = _make_pair(r, n, eth, int(r.integers(0, 3)))
    _, _, d_band = banded_affine_numpy(s1, win, eth, sat)
    D, _, _ = full_affine_numpy(s1, win[eth : eth + n])
    if D[n, n] <= eth:  # optimal path provably inside the band
        assert d_band == D[n, n]


@given(st.integers(0, 10 ** 6), st.integers(10, 50), st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_traceback_cost_equals_distance(seed, n, edits):
    """The reconstructed alignment's affine cost equals the DP distance —
    the traceback-validity property (paper contribution 4)."""
    r = np.random.default_rng(seed)
    eth, sat = 6, 32
    s1, win = _make_pair(r, n, eth, edits)
    _, dirs_np, d_np = banded_affine_numpy(s1, win, eth, sat)
    if d_np >= sat:
        return
    ops = traceback_numpy(dirs_np, eth, n)
    assert alignment_cost(ops) == d_np
    # ops consume exactly n read chars (match/sub/ins)
    consumed = sum(1 for o in ops if o in (OP_MATCH, OP_SUB, OP_INS))
    assert consumed == n
    # jax traceback agrees
    opsj, k = traceback(jnp.array(dirs_np)[None], eth)
    oj = [int(x) for x in np.array(opsj[0]) if x != OP_NONE]
    assert alignment_cost(oj) == d_np
    assert int(k[0]) == len(oj)


@given(st.integers(0, 10 ** 6), st.integers(10, 40))
@settings(max_examples=20, deadline=None)
def test_traceback_reconstructs_reference(seed, n):
    """Replaying ops against the read must regenerate the aligned reference
    span (match ops copy read chars; they must equal the window chars)."""
    r = np.random.default_rng(seed)
    eth, sat = 6, 32
    s1, win = _make_pair(r, n, eth, int(r.integers(0, 3)))
    _, dirs_np, d_np = banded_affine_numpy(s1, win, eth, sat)
    if d_np >= sat:
        return
    ops = traceback_numpy(dirs_np, eth, n)
    i = j = 0  # j indexes the diagonal-aligned window s2 = win[eth:]
    s2 = win[eth:]
    for op in ops:
        if op == OP_MATCH:
            assert s1[i] == s2[j], (i, j)
            i += 1
            j += 1
        elif op == OP_SUB:
            assert s1[i] != s2[j]
            i += 1
            j += 1
        elif op == OP_INS:
            i += 1
        elif op == OP_DEL:
            j += 1
    assert i == n and j == n


def test_affine_prefers_contiguous_gaps():
    """Affine model: a 2-insertion run + 2-deletion run (cost 3+3=6) must
    beat the 8-substitution positional alignment (cost 8) — checks the
    M1/M2 machinery is actually affine with gap runs, not char-by-char."""
    origin = np.array([0, 1, 2, 3] * 3, dtype=np.uint8)       # period 4
    # read: first 4 chars, insert [3,3], then origin[4:10] (drops the tail)
    s1 = np.concatenate([origin[:4], [3, 3], origin[4:10]]).astype(np.uint8)
    assert len(s1) == 12
    eth = 4
    win = np.concatenate([np.full(eth, 4), origin,
                          np.full(eth, 4)]).astype(np.uint8)
    _, dirs, d = banded_affine_numpy(s1, win, eth, 32)
    assert d == 6  # w_op + 2*w_ex twice, not 8 substitutions
    ops = traceback_numpy(dirs, eth, len(s1))
    assert alignment_cost(ops) == 6

    def runs_of(code):
        runs, prev = [], None
        for o in ops:
            if o == code:
                if prev == code:
                    runs[-1] += 1
                else:
                    runs.append(1)
            prev = o
        return runs

    assert 2 in runs_of(OP_INS)
    assert 2 in runs_of(OP_DEL)
