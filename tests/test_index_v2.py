"""Index format v2: int64-clean builds, v1 compat, prefetch residency.

The contracts under test:

* dtype selection — CSR offsets and occurrence positions are computed
  in int64 and narrowed to int32 exactly when they fit, on disk and at
  every reload;
* v1 <-> v2 round trip — a v1 build and a v2 build of the same FASTA
  reload from disk and map to byte-identical SAM (property-based over
  references, on both topologies);
* GRCh38-scale positions — an origin-shifted build whose occurrence
  positions straddle 2^31 builds, reloads, and maps to validated SAM
  with correct global coordinates, without a 3 Gb fixture;
* prefetch — background partition staging is bit-identical to
  synchronous loading (streamed, sync, budget-evicting), and a prefetch
  racing ``ensure()`` on the same partition loads it exactly once with
  exactly one allocation.
"""
import json
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import types
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index import build_index, device_position_dtype
from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.data.genome import make_reference, sample_reads, write_fasta
from repro.index import build_sharded_index, open_index, shard_flat_index
from repro.index import format as fmt
from repro.index.residency import DeviceResidency
from repro.index.sharded import Partition
from repro.io.sam import emit_alignments, sam_header, validate_sam

READ_LEN, K, W, ETH = 60, 10, 12, 4
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_RESULT_FIELDS = ("position", "distance", "distance2", "mapped", "strand",
                  "ops", "op_count", "linear_dist", "n_candidates")


# ------------------------------------------------------------ dtype rules

def test_csr_offsets_narrow_when_safe():
    small = fmt.csr_offsets(np.array([3, 0, 5], dtype=np.int64))
    assert small.dtype == np.int32
    assert small.tolist() == [0, 3, 3, 8]
    # totals past int32 stay int64 — the overflow satellite: cumsum in
    # int64 first, never a wrapped int32 intermediate
    big = fmt.csr_offsets(np.array([2**30, 2**30, 2**30], dtype=np.int64))
    assert big.dtype == np.int64
    assert big[-1] == 3 * 2**30
    edge = fmt.csr_offsets(np.array([fmt.INT32_MAX], dtype=np.int64))
    assert edge.dtype == np.int32


def test_position_dtype_rule():
    assert fmt.position_dtype(0) == np.int32
    assert fmt.position_dtype(fmt.INT32_MAX) == np.int32
    assert fmt.position_dtype(fmt.INT32_MAX + 1) == np.int64


def test_device_position_dtype_rule():
    assert device_position_dtype(1000) == np.int32
    # int32 max itself is the winner-reduce sentinel: a reference whose
    # last position equals it must step up a dtype
    assert device_position_dtype(2**31) != np.int32
    import jax
    if not jax.config.read("jax_enable_x64"):
        assert device_position_dtype(2**31 + 10) == np.uint32
        with pytest.raises(ValueError, match="JAX_ENABLE_X64"):
            device_position_dtype(2**32 + 10)


# ------------------------------------------------- v1 <-> v2 round trip

def _map_to_sam(idx, contigs, refmap, rs) -> str:
    cfg = MapperConfig.from_index(idx, chunk_reads=16, both_strands=True)
    res = Mapper(idx, cfg).map(rs.reads)
    names = [f"r{i}" for i in range(len(rs.reads))]
    lines = sam_header(contigs) + list(
        emit_alignments(res, names, rs.reads, rs.quals, refmap))
    return "\n".join(lines) + "\n"


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_v1_v2_roundtrip_sam_identical(seed):
    # tempfile, not a pytest fixture: the hypothesis runner calls the
    # test body once per drawn example with no fixture injection
    root = tempfile.mkdtemp(prefix="v1v2_")
    try:
        d = Path(root)
        rng = np.random.default_rng(seed)
        ref = make_reference(int(rng.integers(3000, 6000)), seed=seed,
                             repeat_frac=0.05)
        write_fasta(d / "ref.fa", [("chr1", ref)])
        i2 = build_sharded_index(d / "ref.fa", d / "v2", num_partitions=4,
                                 tile_bp=777, read_len=READ_LEN, k=K, w=W,
                                 eth=ETH)
        i1 = build_sharded_index(d / "ref.fa", d / "v1", num_partitions=4,
                                 tile_bp=777, read_len=READ_LEN, k=K, w=W,
                                 eth=ETH, format_version=1)
        m2 = json.load(open(d / "v2" / "manifest.json"))
        m1 = json.load(open(d / "v1" / "manifest.json"))
        assert m2["format"] == fmt.FORMAT_VERSION_V2
        assert m1["format"] == fmt.FORMAT_VERSION_V1
        assert "position_dtype" in m2 and "origin" in m2
        assert "position_dtype" not in m1 and "origin" not in m1
        # small builds choose compact dtypes automatically in both formats
        for idx in (i1, i2):
            for p in idx.parts:
                assert np.asarray(p.positions).dtype == np.int32
                assert np.asarray(p.offsets).dtype == np.int32
        # mmap reload -> byte-identical SAM
        r1, r2 = open_index(d / "v1"), open_index(d / "v2")
        rs = sample_reads(ref, 24, read_len=READ_LEN, seed=seed % 1000,
                          both_strands=True)
        sam1 = _map_to_sam(r1, r1.contigs, r1.reference_map(), rs)
        sam2 = _map_to_sam(r2, r2.contigs, r2.reference_map(), rs)
        assert sam1 == sam2
        validate_sam(sam1, expect_reads=len(rs.reads))
    finally:
        shutil.rmtree(root, ignore_errors=True)


MESH_V1V2_SCRIPT = r"""
import sys
import numpy as np
from repro.core.mapper import Mapper
from repro.core.pipeline import MapperConfig
from repro.data.genome import sample_reads
from repro.index import open_index
from repro.io.fasta import load_reference

v1_dir, v2_dir, fa = sys.argv[1], sys.argv[2], sys.argv[3]
i1, i2 = open_index(v1_dir), open_index(v2_dir)
ref, _ = load_reference(fa, spacer=60 + 2 * 4)
rs = sample_reads(ref, 24, read_len=60, seed=3)
out = []
for idx in (i1, i2):
    cfg = MapperConfig.from_index(idx)
    res = Mapper(idx, cfg, topology="mesh").map(rs.reads)
    out.append((res.position, res.distance, res.mapped))
for a, b in zip(out[0], out[1]):
    assert np.array_equal(a, b)
print("MESH-V1V2-OK")
"""


def test_v1_v2_mesh_identical(tmp_path):
    ref = make_reference(8000, seed=11, repeat_frac=0.02)
    write_fasta(tmp_path / "ref.fa", [("chr1", ref)])
    for ver, name in ((1, "v1"), (2, "v2")):
        build_sharded_index(tmp_path / "ref.fa", tmp_path / name,
                            num_partitions=4, tile_bp=2048,
                            read_len=READ_LEN, k=K, w=W, eth=ETH,
                            format_version=ver)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    proc = subprocess.run(
        [sys.executable, "-c", MESH_V1V2_SCRIPT, str(tmp_path / "v1"),
         str(tmp_path / "v2"), str(tmp_path / "ref.fa")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "MESH-V1V2-OK" in proc.stdout


# ------------------------------------- positions straddling 2^31 (tentpole)

ORIGIN = 2**31 - 1500   # occurrence positions straddle the int32 boundary


@pytest.fixture(scope="module")
def big_origin_index(tmp_path_factory):
    d = tmp_path_factory.mktemp("origin_idx")
    ref = make_reference(6000, seed=13, repeat_frac=0.02)
    write_fasta(d / "ref.fa", [("chrBig", ref)])
    build_sharded_index(d / "ref.fa", d / "idx", num_partitions=4,
                        tile_bp=1024, read_len=READ_LEN, k=K, w=W, eth=ETH,
                        origin=ORIGIN)
    return d, ref, open_index(d / "idx")


def test_origin_build_forces_int64(big_origin_index):
    d, ref, idx = big_origin_index
    man = json.load(open(d / "idx" / "manifest.json"))
    assert man["position_dtype"] == "int64"
    assert man["origin"] == ORIGIN
    assert man["ref_len"] == ORIGIN + len(ref)
    allpos = np.concatenate([np.asarray(p.positions) for p in idx.parts])
    assert allpos.dtype == np.int64
    assert allpos.min() < 2**31 <= allpos.max()
    # positions are origin + local: the same build at origin 0 must give
    # the exact same occurrence set, shifted
    build_sharded_index(d / "ref.fa", d / "idx0", num_partitions=4,
                        tile_bp=1024, read_len=READ_LEN, k=K, w=W, eth=ETH)
    idx0 = open_index(d / "idx0")
    for pa, pb in zip(idx.parts, idx0.parts):
        assert np.array_equal(np.asarray(pa.kmers), np.asarray(pb.kmers))
        assert np.array_equal(
            np.asarray(pa.positions),
            np.asarray(pb.positions).astype(np.int64) + ORIGIN)
        assert np.array_equal(pa.read_segments(), pb.read_segments())


def test_origin_index_maps_to_validated_sam(big_origin_index):
    d, ref, idx = big_origin_index
    rs = sample_reads(ref, 32, read_len=READ_LEN, seed=5,
                      both_strands=True)
    cfg = MapperConfig.from_index(idx, chunk_reads=16, both_strands=True)
    res = Mapper(idx, cfg).map(rs.reads)
    assert res.position.dtype == np.int64
    mapped = res.mapped
    assert mapped.mean() > 0.9
    assert (res.position[mapped] > 2**30).any()  # genuinely big coords
    want = ORIGIN + rs.true_pos.astype(np.int64)
    assert (np.abs(res.position[mapped] - want[mapped]) <= ETH).all()
    assert (res.position[~mapped] == -1).all()
    names = [f"r{i}" for i in range(len(rs.reads))]
    sam = "\n".join(sam_header(idx.contigs) + list(emit_alignments(
        res, names, rs.reads, rs.quals, idx.reference_map()))) + "\n"
    validate_sam(sam, expect_reads=len(rs.reads))


def test_origin_index_mesh_guard(big_origin_index):
    _, _, idx = big_origin_index
    with pytest.raises(ValueError, match="mesh shards hold int32"):
        idx.to_mesh_shards()


def test_v1_rejects_origin_and_load_rejects_v1_origin(tmp_path):
    ref = make_reference(2000, seed=3)
    write_fasta(tmp_path / "ref.fa", [("c", ref)])
    with pytest.raises(ValueError, match="format_version"):
        build_sharded_index(tmp_path / "ref.fa", tmp_path / "bad",
                            num_partitions=2, read_len=READ_LEN, k=K,
                            w=W, eth=ETH, origin=100, format_version=1)
    build_sharded_index(tmp_path / "ref.fa", tmp_path / "v1",
                        num_partitions=2, read_len=READ_LEN, k=K, w=W,
                        eth=ETH, format_version=1)
    man_path = tmp_path / "v1" / "manifest.json"
    man = json.load(open(man_path))
    man["origin"] = 100
    man_path.write_text(json.dumps(man))
    with pytest.raises(fmt.IndexFormatError, match="nonzero origin"):
        open_index(tmp_path / "v1")


# ------------------------------------------------------------- prefetch

@pytest.fixture(scope="module")
def routed_world():
    ref = make_reference(20_000, seed=21, repeat_frac=0.02)
    flat = build_index(ref, read_len=READ_LEN, k=K, w=W, eth=ETH)
    sidx = shard_flat_index(flat, 4)
    rs = sample_reads(ref, 48, read_len=READ_LEN, seed=5,
                      both_strands=True)
    return flat, sidx, rs


def _assert_same_results(a, b):
    for f in _RESULT_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        assert (va is None) == (vb is None), f
        if va is not None:
            assert np.array_equal(va, vb), f


def test_prefetch_bit_identical(routed_world):
    flat, sidx, rs = routed_world
    cfg = MapperConfig.from_index(flat, chunk_reads=16, both_strands=True)
    base = Mapper(sidx, cfg).map(rs.reads)
    pre = Mapper(sidx, cfg, prefetch=True)
    res = pre.map(rs.reads)
    _assert_same_results(base, res)
    part = res.stats["partitions"]
    assert part["prefetch_loads"] + part["prefetch_hits"] > 0
    # sync engine path: begin_run is a no-op, results still identical
    cfg_sync = MapperConfig.from_index(flat, chunk_reads=16,
                                       both_strands=True, stream=False)
    _assert_same_results(base,
                         Mapper(sidx, cfg_sync, prefetch=True).map(rs.reads))


def test_prefetch_under_budget_bit_identical(routed_world):
    # every chunk touches all four partitions, so the tightest budget a
    # run can complete under is the full pinned set — the budgeted-arena
    # prefetch path (alloc/gap search under the lock) with zero slack
    flat, sidx, rs = routed_world
    cfg = MapperConfig.from_index(flat, chunk_reads=16, both_strands=True)
    base = Mapper(sidx, cfg).map(rs.reads)
    total = sum(p.n_occurrences for p in sidx.parts) * (sidx.seg_len + 4)
    res = Mapper(sidx, cfg, memory_budget_bytes=total,
                 prefetch=True).map(rs.reads)
    _assert_same_results(base, res)


def test_prefetch_requires_routed_single(routed_world):
    flat, sidx, _ = routed_world
    with pytest.raises(ValueError, match="prefetch=True only"):
        Mapper(flat, MapperConfig.from_index(flat), prefetch=True)


def _synthetic_parts(sizes, seg_len):
    rng = np.random.default_rng(7)
    return [Partition(
        kmers=np.arange(n, dtype=np.uint32),
        offsets=np.arange(n + 1, dtype=np.int32),
        positions=(1000 * (i + 1) + np.arange(n)).astype(np.int32),
        seg_len=seg_len,
        segments_raw=rng.integers(0, 4, (n, seg_len), dtype=np.uint8))
        for i, n in enumerate(sizes)]


def test_prefetch_racing_ensure_loads_exactly_once():
    seg_len = 8
    parts = _synthetic_parts([10, 10, 10, 10], seg_len)
    idx = types.SimpleNamespace(parts=parts, seg_len=seg_len)
    res = DeviceResidency(idx)
    barrier = threading.Barrier(8)

    def hammer(i):
        barrier.wait()
        p = i % 4
        if i % 2:
            return res.prefetch([p])
        return res.ensure([p])

    with ThreadPoolExecutor(max_workers=8) as ex:
        outs = list(ex.map(hammer, range(8)))
    # exactly one load + one allocation per partition, no double-alloc
    assert res.loads == 4
    allocs = sorted(res._alloc.values())
    assert len(res._alloc) == 4
    for (lo_a, n_a), (lo_b, _) in zip(allocs, allocs[1:]):
        assert lo_a + n_a <= lo_b  # extents never overlap
    # every caller saw the same authoritative base per partition
    for out in outs:
        for p, base in out.items():
            assert res._alloc[p][0] == base
            nr = parts[p].n_occurrences
            assert np.array_equal(
                np.asarray(res.positions_dev[base:base + nr]),
                np.asarray(parts[p].positions))


def test_evict_error_accounts_for_freed_unpinned_rows():
    seg_len = 8
    parts = _synthetic_parts([60, 30], seg_len)
    idx = types.SimpleNamespace(parts=parts, seg_len=seg_len)
    res = DeviceResidency(idx, 70 * (seg_len + 4))
    with pytest.raises(ValueError) as ei:
        res.ensure([0, 1])
    msg = str(ei.value)
    assert "memory_budget_bytes" in msg
    assert "unpinned resident is already evicted" in msg
    assert "90 occurrence" in msg          # total pinned need
    assert "60 rows" in msg                # rows still held by the chunk


def test_prefetch_stats_reset_and_metrics(routed_world):
    flat, sidx, rs = routed_world
    from repro.obs import registry as _metrics
    cfg = MapperConfig.from_index(flat, chunk_reads=16)
    reg = _metrics.enable_metrics()
    try:
        m = Mapper(sidx, cfg, prefetch=True)
        res = m.map(rs.reads)
        part = res.stats["partitions"]
        loads = part["prefetch_loads"]
        assert loads > 0
        assert reg.counter(
            "repro_partition_prefetch_loads_total").value == loads
        # drain_stats reset the counters for the next run
        assert m.router.residency.prefetch_loads == 0
        res2 = m.map(rs.reads)
        part2 = res2.stats["partitions"]
        assert part2["partition_loads"] == 0      # all resident
        assert part2["prefetch_hits"] > 0         # staged parts were hit
    finally:
        _metrics.disable_metrics()
