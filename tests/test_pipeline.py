"""End-to-end read-mapping behaviour (paper Secs. V-B..V-E + VII-A)."""
import numpy as np
import pytest

from repro.core.index import build_index, minimizer_frequencies
from repro.core.pipeline import MapperConfig, map_reads, oracle_map
from repro.data.genome import make_reference, sample_reads


@pytest.fixture(scope="module")
def world():
    ref = make_reference(20_000, seed=0, repeat_frac=0.02)
    idx = build_index(ref)
    return ref, idx


def test_index_structure(world):
    ref, idx = world
    assert idx.seg_len == 2 * (150 + 6) - 12
    assert (np.diff(idx.offsets) >= 0).all()
    assert idx.offsets[-1] == len(idx.positions) == len(idx.segments)
    # each segment contains the reference bytes around its position
    pad = idx.pad
    for i in np.random.default_rng(0).choice(len(idx.positions), 16):
        p = idx.positions[i]
        lo, hi = max(0, p - pad), min(len(ref), p - pad + idx.seg_len)
        inner = idx.segments[i][lo - (p - pad) : hi - (p - pad)]
        assert (inner == ref[lo:hi]).all()
    # storage blow-up accounting is present (paper: ~17x on HG38)
    sb = idx.storage_bytes()
    assert sb["blowup"] > 1


def test_mapping_accuracy_clean_reads(world):
    ref, idx = world
    rs = sample_reads(ref, 48, sub_rate=0.0, ins_rate=0, del_rate=0, seed=1)
    res = map_reads(idx, rs.reads)
    assert res.mapped.all()
    assert (res.distance == 0).all()
    assert (res.position == rs.true_pos).mean() >= 0.95  # repeats may tie


def test_mapping_accuracy_noisy_reads(world):
    ref, idx = world
    rs = sample_reads(ref, 64, seed=3)
    res = map_reads(idx, rs.reads)
    assert res.mapped.mean() > 0.95
    close = np.abs(res.position - rs.true_pos) <= 6
    assert close.mean() > 0.95
    # reported distance bounded by simulated edit count (within band)
    ok = res.mapped & close
    assert (res.distance[ok] <= rs.n_errors[ok] + 6).all()


def test_filter_reduces_candidates(world):
    ref, idx = world
    rs = sample_reads(ref, 32, seed=5)
    res = map_reads(idx, rs.reads)
    sat = 6 + 1
    total = (res.linear_dist < 10**9).sum()
    passed = (res.linear_dist <= 6).sum()
    assert passed < total  # the filter actually discards PLs


def test_agrees_with_exhaustive_oracle():
    ref = make_reference(3_000, seed=2, repeat_frac=0.0)
    idx = build_index(ref)
    rs = sample_reads(ref, 12, seed=4)
    res = map_reads(idx, rs.reads)
    bp, bd = oracle_map(ref, rs.reads)
    ok = res.mapped
    # oracle distance can only be <= ours; when equal the position matches
    agree = (np.abs(res.position[ok] - bp[ok]) <= 6).mean()
    assert agree > 0.9


def test_minimizer_frequency_histogram(world):
    _, idx = world
    freqs = minimizer_frequencies(idx)
    assert freqs.sum() == len(idx.positions)
    assert (freqs >= 1).all()


def test_unmapped_random_reads(world):
    ref, idx = world
    rng = np.random.default_rng(9)
    junk = rng.integers(0, 4, (16, 150)).astype(np.uint8)
    res = map_reads(idx, junk)
    # random 150-mers should rarely align within 6 edits
    assert res.mapped.mean() <= 0.2


def test_low_th_split(world):
    from repro.core.index import low_th_split
    _, idx = world
    s = low_th_split(idx, low_th=3)
    assert 0 < s["rare_minimizer_fraction"] <= 1
    assert s["n_rare_minimizers"] <= s["n_minimizers"]
    # rare minimizers carry a small fraction of total PL work (the paper's
    # premise for offloading them: 0.16% of affine instances)
    assert s["rare_pl_fraction"] <= s["rare_minimizer_fraction"] + 0.5


def test_base_count_filter_is_sound(world):
    """Base-count histogram distance lower-bounds substitution-only edit
    distance -> the filter never discards a true sub-only match within
    threshold (the soundness property the paper's filter relies on)."""
    import jax.numpy as jnp
    from repro.core.filtering import base_count_filter
    ref, idx = world
    rng = np.random.default_rng(17)
    rl, eth = 150, 6
    reads, wins, true_d = [], [], []
    for _ in range(24):
        p = int(rng.integers(0, len(ref) - rl - 2 * eth))
        seg = ref[p : p + rl + 2 * eth].copy()
        read = seg[eth : eth + rl].copy()
        k = int(rng.integers(0, 6))
        for _ in range(k):
            q = int(rng.integers(0, rl))
            read[q] = (read[q] + int(rng.integers(1, 4))) % 4
        reads.append(read)
        wins.append(seg)
        true_d.append(k)
    reads = jnp.asarray(np.stack(reads))
    wins = jnp.asarray(np.stack(wins))[:, None, None, :]
    valid = jnp.ones((24, 1, 1), bool)
    keep, hist = base_count_filter(reads, wins, valid, threshold=6)
    hist = np.asarray(hist)[:, 0, 0]
    for h, d in zip(hist, true_d):
        assert h <= d  # lower bound
    kept = np.asarray(keep)[:, 0, 0]
    assert kept[np.array(true_d) <= 6].all()
