"""Property tests for the distributed seeding exchange primitives."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.distributed import _bucket_by_dst


@given(st.integers(0, 10 ** 6), st.integers(1, 8), st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_bucket_by_dst_invariants(seed, n_shards, cap):
    r = np.random.default_rng(seed)
    E = int(r.integers(1, 60))
    dst = jnp.asarray(r.integers(0, n_shards + 1, E), jnp.int32)  # +1=drop
    payload = {"x": jnp.asarray(r.integers(0, 1000, E), jnp.int32)}
    out, dropped = _bucket_by_dst(dst, payload, n_shards, cap)
    x = np.asarray(out["x"])
    valid = np.asarray(out["valid"])
    d = np.asarray(dst)
    # 1. conservation: valid slots + dropped == in-range entries
    n_in = int((d < n_shards).sum())
    assert int(valid.sum()) + int(dropped) == n_in
    # 2. no bucket exceeds capacity
    assert valid.sum(axis=1).max(initial=0) <= cap
    # 3. every valid payload value really was sent to that shard
    for s in range(n_shards):
        sent = sorted(np.asarray(payload["x"])[d == s][:cap].tolist())
        got = sorted(x[s][valid[s]].tolist())
        assert got == sent, (s, got, sent)
    # 4. dropped only when over capacity
    for s in range(n_shards):
        n_s = int((d == s).sum())
        assert valid[s].sum() == min(n_s, cap)
