"""End-to-end CLI smoke (the CI job's test): simulate a dual-strand read
set, write real FASTA/FASTQ files, run ``python -m repro.launch.map_fastq``
on both topologies as a subprocess, and validate the emitted SAM with the
dependency-free checker — header, mandatory columns, FLAG strand bits
against ground truth, CIGAR/SEQ consistency, and position accuracy."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.genome import (make_reference, sample_reads, write_fasta,
                               write_fastq)
from repro.io.cigar import cigar_query_len
from repro.io.sam import FLAG_REVERSE, FLAG_UNMAPPED, validate_sam

READ_LEN = 120
N_READS = 24


@pytest.fixture(scope="module")
def fastq_world(tmp_path_factory):
    d = tmp_path_factory.mktemp("map_fastq")
    c1 = make_reference(5_000, seed=0, repeat_frac=0.02)
    c2 = make_reference(3_000, seed=5, repeat_frac=0.0)
    c1[700:704] = 4  # an N run in the reference
    write_fasta(d / "ref.fa", [("chr1", c1), ("chr2", c2)])
    rs1 = sample_reads(c1, N_READS // 2, read_len=READ_LEN, seed=3,
                       both_strands=True)
    rs2 = sample_reads(c2, N_READS // 2, read_len=READ_LEN, seed=9,
                       both_strands=True)
    reads = np.concatenate([rs1.reads, rs2.reads])
    quals = np.concatenate([rs1.quals, rs2.quals])
    truth = [("chr1", int(p), int(s))
             for p, s in zip(rs1.true_pos, rs1.strand)]
    truth += [("chr2", int(p), int(s))
              for p, s in zip(rs2.true_pos, rs2.strand)]
    names = [f"read{i}" for i in range(N_READS)]
    write_fastq(d / "reads.fq", reads, quals, names)
    return d, dict(zip(names, truth))


def _run_map_fastq(d, out_name, *argv, chunk_reads=16):
    """Invoke the map_fastq CLI as a subprocess; argv follows the
    reference argument.  The single home for the env/subprocess
    boilerplate all the CLI tests share."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.map_fastq",
           str(d / "ref.fa"), *argv, "-o", str(d / out_name),
           "--chunk-reads", str(chunk_reads)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    return (d / out_name).read_text(), proc.stderr


def _run_cli(d, out_name, *extra):
    return _run_map_fastq(d, out_name, str(d / "reads.fq"), *extra)


def _check_sam(text, truth, *, expect_cigars):
    stats = validate_sam(text, expect_reads=N_READS)
    assert stats["contigs"] == {"chr1": 5000, "chr2": 3000}
    n_pos_strand_ok = 0
    for ln in text.splitlines():
        if ln.startswith("@"):
            continue
        f = ln.split("\t")
        qname, flag, rname, pos, cig, seq = (f[0], int(f[1]), f[2],
                                             int(f[3]), f[5], f[9])
        t_contig, t_pos, t_strand = truth[qname]
        if flag & FLAG_UNMAPPED:
            continue
        if expect_cigars:
            assert cig != "*"
            assert cigar_query_len(cig) == READ_LEN == len(seq)
        else:
            assert cig == "*"  # mesh stage B never tracebacks
        strand_bit = 1 if flag & FLAG_REVERSE else 0
        if (rname == t_contig and abs((pos - 1) - t_pos) <= 6
                and strand_bit == t_strand):
            n_pos_strand_ok += 1
    # strand-aware accuracy: position AND strand, against ground truth
    assert n_pos_strand_ok >= int(0.9 * N_READS), \
        f"only {n_pos_strand_ok}/{N_READS} correct (pos+strand)"
    assert stats["n_reverse"] > 0  # reverse-strand reads really mapped
    return stats


def test_map_fastq_single_topology(fastq_world):
    d, truth = fastq_world
    text, err = _run_cli(d, "single.sam")
    stats = _check_sam(text, truth, expect_cigars=True)
    assert stats["n_mapped"] >= int(0.9 * N_READS)
    assert "filter/affine [single]" in err


def test_map_fastq_mesh_topology(fastq_world):
    d, truth = fastq_world
    text, err = _run_cli(d, "mesh.sam", "--topology", "mesh",
                         "--shards", "2")
    _check_sam(text, truth, expect_cigars=False)
    assert "stage B [mesh]" in err


def test_map_fastq_single_strand_flag_drops_reverse(fastq_world):
    d, truth = fastq_world
    text, _ = _run_cli(d, "fwd.sam", "--single-strand")
    stats = validate_sam(text, expect_reads=N_READS)
    assert stats["n_reverse"] == 0
    n_rev_truth = sum(1 for _, _, s in truth.values() if s)
    assert stats["n_mapped"] <= N_READS - n_rev_truth + 2


def test_map_fastq_single_end_output_unchanged(fastq_world):
    """The single-end path must not drift under the paired-end feature:
    RNEXT/PNEXT/TLEN stay */0/0 and MAPQ stays the 255 placeholder."""
    d, _ = fastq_world
    text, _ = _run_cli(d, "single2.sam")
    for ln in text.splitlines():
        if ln.startswith("@"):
            continue
        f = ln.split("\t")
        assert f[6:9] == ["*", "0", "0"]
        assert f[4] == ("0" if int(f[1]) & FLAG_UNMAPPED else "255")
        assert not int(f[1]) & 0x1


# ----------------------------------------------------------- paired-end

N_PAIRS = 20


@pytest.fixture(scope="module")
def paired_world(tmp_path_factory):
    """Simulated gzip paired-end world over two contigs, with ground
    truth (positions, strands, insert sizes) for both mates."""
    from repro.data.genome import sample_pairs, write_fastq_pair

    d = tmp_path_factory.mktemp("map_fastq_paired")
    c1 = make_reference(6_000, seed=0, repeat_frac=0.0)
    c2 = make_reference(4_000, seed=5, repeat_frac=0.0)
    write_fasta(d / "ref.fa", [("chr1", c1), ("chr2", c2)])
    ps1 = sample_pairs(c1, N_PAIRS // 2, read_len=READ_LEN,
                       insert_mean=280, insert_sd=25, seed=3)
    ps2 = sample_pairs(c2, N_PAIRS // 2, read_len=READ_LEN,
                       insert_mean=280, insert_sd=25, seed=9)
    names = [f"p{i}" for i in range(N_PAIRS)]
    truth = {}
    for j, (contig, ps) in enumerate((("chr1", ps1), ("chr2", ps2))):
        for i in range(N_PAIRS // 2):
            truth[names[j * (N_PAIRS // 2) + i]] = (
                contig, int(ps.pos1[i]), int(ps.pos2[i]),
                int(ps.strand1[i]), int(ps.strand2[i]), int(ps.isize[i]))
    reads1 = np.concatenate([ps1.reads1, ps2.reads1])
    reads2 = np.concatenate([ps1.reads2, ps2.reads2])
    quals1 = np.concatenate([ps1.quals1, ps2.quals1])
    quals2 = np.concatenate([ps1.quals2, ps2.quals2])
    from repro.data.genome import write_fastq
    write_fastq(d / "r1.fastq.gz", reads1, quals1,
                [f"{n}/1" for n in names])
    write_fastq(d / "r2.fastq.gz", reads2, quals2,
                [f"{n}/2" for n in names])
    return d, truth


def _run_paired_cli(d, out_name, *extra):
    return _run_map_fastq(d, out_name, "--r1", str(d / "r1.fastq.gz"),
                          "--r2", str(d / "r2.fastq.gz"), *extra,
                          chunk_reads=10)


def _check_paired_sam(text, truth):
    """Extended-validator pass + proper-pair accuracy vs ground truth
    (position AND strand AND proper-pair for both mates)."""
    stats = validate_sam(text, expect_reads=2 * N_PAIRS, require_mapq=True)
    assert stats["n_paired"] == 2 * N_PAIRS
    recs = {}
    for ln in text.splitlines():
        if ln.startswith("@"):
            continue
        f = ln.split("\t")
        mate = 0 if int(f[1]) & 0x40 else 1
        recs[(f[0], mate)] = f
    n_ok = 0
    for name, (contig, p1, p2, s1, s2, isize) in truth.items():
        f1, f2 = recs[(name, 0)], recs[(name, 1)]
        fl1, fl2 = int(f1[1]), int(f2[1])
        ok = (not (fl1 & 0x4) and not (fl2 & 0x4)
              and f1[2] == f2[2] == contig
              and abs(int(f1[3]) - 1 - p1) <= 6
              and abs(int(f2[3]) - 1 - p2) <= 6
              and bool(fl1 & 0x10) == bool(s1)
              and bool(fl2 & 0x10) == bool(s2)
              and bool(fl1 & 0x2) and bool(fl2 & 0x2)
              and abs(abs(int(f1[8])) - isize) <= 6)
        n_ok += ok
    assert n_ok >= 0.97 * N_PAIRS, \
        f"only {n_ok}/{N_PAIRS} pairs correct (pos+strand+proper+TLEN)"
    return stats


@pytest.mark.parametrize("topo", ["single", "mesh"])
def test_map_fastq_paired_gz_topologies(paired_world, topo):
    d, truth = paired_world
    extra = () if topo == "single" else ("--topology", "mesh",
                                         "--shards", "2")
    text, err = _run_paired_cli(d, f"paired_{topo}.sam", *extra)
    stats = _check_paired_sam(text, truth)
    assert stats["n_proper"] >= int(0.97 * N_PAIRS)
    assert "pairing:" in err and "insert median" in err


def test_map_fastq_interleaved_matches_two_file(paired_world):
    """--interleaved over the same pairs produces the identical SAM body
    (modulo the @PG CL line, which records the command)."""
    import gzip as gz

    d, _ = paired_world

    def body(text):
        return [ln for ln in text.splitlines() if not ln.startswith("@PG")]

    inter = d / "inter.fastq.gz"
    with gz.open(d / "r1.fastq.gz", "rt") as f1, \
            gz.open(d / "r2.fastq.gz", "rt") as f2, \
            gz.open(inter, "wt") as out:
        while True:
            rec1 = [f1.readline() for _ in range(4)]
            rec2 = [f2.readline() for _ in range(4)]
            if not rec1[0]:
                break
            out.writelines(rec1 + rec2)
    two, _ = _run_paired_cli(d, "two.sam")
    inter_sam, _ = _run_map_fastq(d, "inter.sam", str(inter),
                                  "--interleaved", chunk_reads=10)
    assert body(inter_sam) == body(two)


# ------------------------------------------------------------ --index-dir

def _run_index_cli(d, out_name, *argv, chunk_reads=16):
    """map_fastq against a prebuilt --index-dir (no FASTA positional)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.map_fastq",
           "--index-dir", str(d / "idx"), str(d / "reads.fq"),
           *argv, "-o", str(d / out_name),
           "--chunk-reads", str(chunk_reads)]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    return (d / out_name).read_text(), proc.stderr


def _sam_body(text):
    # @PG carries the command line, which legitimately differs
    return [ln for ln in text.splitlines() if not ln.startswith("@PG")]


@pytest.fixture(scope="module")
def index_dir(fastq_world):
    d, _ = fastq_world
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.build_index",
           str(d / "ref.fa"), "-o", str(d / "idx"), "--partitions", "2",
           "--tile-bp", "1024", "--read-len", str(READ_LEN), "--verify"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "integrity check passed" in proc.stderr
    return d / "idx"


def test_index_dir_single_byte_identical(fastq_world, index_dir):
    """Golden e2e: mapping from the on-disk sharded index produces the
    byte-identical SAM to indexing the FASTA in memory (multi-contig,
    dual-strand), single topology."""
    d, truth = fastq_world
    mem, _ = _run_cli(d, "mem_single.sam")
    disk, err = _run_index_cli(d, "disk_single.sam")
    assert _sam_body(disk) == _sam_body(mem)
    _check_sam(disk, truth, expect_cigars=True)
    assert "partitions: routed" in err
    assert "index storage:" in err


def test_index_dir_single_budget_byte_identical(fastq_world, index_dir):
    d, _ = fastq_world
    mem, _ = _run_cli(d, "mem_single2.sam")
    disk, err = _run_index_cli(d, "disk_budget.sam",
                               "--index-budget-mb", "64")
    assert _sam_body(disk) == _sam_body(mem)


def test_index_dir_mesh_byte_identical(fastq_world, index_dir):
    """Mesh topology consumes the pre-partitioned index (partition i on
    shard i) and still byte-matches the in-memory mesh run."""
    d, truth = fastq_world
    mem, _ = _run_cli(d, "mem_mesh.sam", "--topology", "mesh",
                      "--shards", "2")
    disk, err = _run_index_cli(d, "disk_mesh.sam", "--topology", "mesh",
                               "--shards", "2")
    assert _sam_body(disk) == _sam_body(mem)
    _check_sam(disk, truth, expect_cigars=False)
    assert "partitions: 2 mesh-placed" in err


def test_index_dir_cli_validation(fastq_world, index_dir):
    d, _ = fastq_world
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))

    def run_cli(*argv):
        return subprocess.run(
            [sys.executable, "-m", "repro.launch.map_fastq", *argv],
            env=env, capture_output=True, text=True, timeout=600)

    p = run_cli(str(d / "ref.fa"), str(d / "reads.fq"),
                "--index-dir", str(d / "idx"))
    assert p.returncode != 0 and "not both" in p.stderr
    p = run_cli(str(d / "reads.fq"))  # looks like a reference, none given
    assert p.returncode != 0
    p = run_cli("--index-dir", str(d / "idx"), str(d / "reads.fq"),
                "--read-len", str(READ_LEN + 1))
    assert p.returncode != 0 and "conflicts with the index" in p.stderr
