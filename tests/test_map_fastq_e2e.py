"""End-to-end CLI smoke (the CI job's test): simulate a dual-strand read
set, write real FASTA/FASTQ files, run ``python -m repro.launch.map_fastq``
on both topologies as a subprocess, and validate the emitted SAM with the
dependency-free checker — header, mandatory columns, FLAG strand bits
against ground truth, CIGAR/SEQ consistency, and position accuracy."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.genome import (make_reference, sample_reads, write_fasta,
                               write_fastq)
from repro.io.cigar import cigar_query_len
from repro.io.sam import FLAG_REVERSE, FLAG_UNMAPPED, validate_sam

READ_LEN = 120
N_READS = 24


@pytest.fixture(scope="module")
def fastq_world(tmp_path_factory):
    d = tmp_path_factory.mktemp("map_fastq")
    c1 = make_reference(5_000, seed=0, repeat_frac=0.02)
    c2 = make_reference(3_000, seed=5, repeat_frac=0.0)
    c1[700:704] = 4  # an N run in the reference
    write_fasta(d / "ref.fa", [("chr1", c1), ("chr2", c2)])
    rs1 = sample_reads(c1, N_READS // 2, read_len=READ_LEN, seed=3,
                       both_strands=True)
    rs2 = sample_reads(c2, N_READS // 2, read_len=READ_LEN, seed=9,
                       both_strands=True)
    reads = np.concatenate([rs1.reads, rs2.reads])
    quals = np.concatenate([rs1.quals, rs2.quals])
    truth = [("chr1", int(p), int(s))
             for p, s in zip(rs1.true_pos, rs1.strand)]
    truth += [("chr2", int(p), int(s))
              for p, s in zip(rs2.true_pos, rs2.strand)]
    names = [f"read{i}" for i in range(N_READS)]
    write_fastq(d / "reads.fq", reads, quals, names)
    return d, dict(zip(names, truth))


def _run_cli(d, out_name, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.map_fastq",
           str(d / "ref.fa"), str(d / "reads.fq"), "-o",
           str(d / out_name), "--chunk-reads", "16", *extra]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    return (d / out_name).read_text(), proc.stderr


def _check_sam(text, truth, *, expect_cigars):
    stats = validate_sam(text, expect_reads=N_READS)
    assert stats["contigs"] == {"chr1": 5000, "chr2": 3000}
    n_pos_strand_ok = 0
    for ln in text.splitlines():
        if ln.startswith("@"):
            continue
        f = ln.split("\t")
        qname, flag, rname, pos, cig, seq = (f[0], int(f[1]), f[2],
                                             int(f[3]), f[5], f[9])
        t_contig, t_pos, t_strand = truth[qname]
        if flag & FLAG_UNMAPPED:
            continue
        if expect_cigars:
            assert cig != "*"
            assert cigar_query_len(cig) == READ_LEN == len(seq)
        else:
            assert cig == "*"  # mesh stage B never tracebacks
        strand_bit = 1 if flag & FLAG_REVERSE else 0
        if (rname == t_contig and abs((pos - 1) - t_pos) <= 6
                and strand_bit == t_strand):
            n_pos_strand_ok += 1
    # strand-aware accuracy: position AND strand, against ground truth
    assert n_pos_strand_ok >= int(0.9 * N_READS), \
        f"only {n_pos_strand_ok}/{N_READS} correct (pos+strand)"
    assert stats["n_reverse"] > 0  # reverse-strand reads really mapped
    return stats


def test_map_fastq_single_topology(fastq_world):
    d, truth = fastq_world
    text, err = _run_cli(d, "single.sam")
    stats = _check_sam(text, truth, expect_cigars=True)
    assert stats["n_mapped"] >= int(0.9 * N_READS)
    assert "filter/affine [single]" in err


def test_map_fastq_mesh_topology(fastq_world):
    d, truth = fastq_world
    text, err = _run_cli(d, "mesh.sam", "--topology", "mesh",
                         "--shards", "2")
    _check_sam(text, truth, expect_cigars=False)
    assert "stage B [mesh]" in err


def test_map_fastq_single_strand_flag_drops_reverse(fastq_world):
    d, truth = fastq_world
    text, _ = _run_cli(d, "fwd.sam", "--single-strand")
    stats = validate_sam(text, expect_reads=N_READS)
    assert stats["n_reverse"] == 0
    n_rev_truth = sum(1 for _, _, s in truth.values() if s)
    assert stats["n_mapped"] <= N_READS - n_rev_truth + 2
