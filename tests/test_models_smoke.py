"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, shape + finiteness assertions; decode where the family supports it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_applicable, input_specs, reduced
from repro.models import lm, transformer
from repro.train.optimizer import adamw

KEY = jax.random.key(0)


def _batch(cfg, B=2, S=32):
    if cfg.input_kind == "embeds":
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16) * 0.1,
                "labels": jnp.ones((B, S), jnp.int32)}
    return {"tokens": jnp.zeros((B, S), jnp.int32) + 3,
            "labels": jnp.ones((B, S), jnp.int32)}


# The reduced configs train with remat, whose optimization_barrier has no
# differentiation rule before jax 0.5 — a pre-existing seed failure on
# this container's jax 0.4.37, gated as an explicit skip.  The forward
# half stays live on old jax via test_forward_step_pre_jax05 below.
from conftest import JAX_PRE_05  # noqa: E402


@pytest.mark.skipif(JAX_PRE_05,
                    reason="jax<0.5: no differentiation rule for "
                           "optimization_barrier (remat train step; "
                           "pre-existing seed failure on jax 0.4.37)")
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = reduced(ARCHS[arch])
    params = transformer.init_params(cfg, KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, aux = transformer.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt = adamw(warmup=0, total_steps=4)
    step = jax.jit(lm.make_train_step(cfg, opt))
    state = (params, opt.init(params), jnp.int32(0))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         state[0], params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.skipif(not JAX_PRE_05,
                    reason="forward covered by test_forward_and_train_step "
                           "on jax>=0.5")
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_step_pre_jax05(arch):
    """Forward-pass half of the smoke test, kept live where the train
    step is version-gated (train needs jax>=0.5, forward does not)."""
    cfg = reduced(ARCHS[arch])
    params = transformer.init_params(cfg, KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, aux = transformer.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = reduced(ARCHS[arch])
    if not cfg.supports_decode:
        pytest.skip("encoder-only: no decode step by design")
    params = transformer.init_params(cfg, KEY)
    B, S = 2, 16
    cache = transformer.init_cache(cfg, B, S)
    serve = jax.jit(lm.make_serve_step(cfg))
    tok = (jax.random.normal(KEY, (B, 1, cfg.d_model), jnp.bfloat16) * 0.1
           if cfg.input_kind == "embeds"
           else jnp.ones((B, 1), jnp.int32))
    lg, cache = serve(params, cache, tok, jnp.int32(0))
    lg, cache = serve(params, cache, tok, jnp.int32(1))
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen3-0.6b", "falcon-mamba-7b",
                                  "zamba2-2.7b"])
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the full forward logits (token archs;
    MoE excluded — train-path capacity dropping differs by design)."""
    cfg = reduced(ARCHS[arch])
    params = transformer.init_params(cfg, KEY)
    B, T = 2, 6
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    full, _ = transformer.forward(params, {"tokens": toks}, cfg)
    serve = jax.jit(lm.make_serve_step(cfg))
    cache = transformer.init_cache(cfg, B, 8)
    for t in range(T):
        lg, cache = serve(params, cache, toks[:, t : t + 1], jnp.int32(t))
    tol = 0.05 if cfg.family in ("hybrid", "ssm") else 1e-3
    np.testing.assert_allclose(np.asarray(full[:, -1], np.float32),
                               np.asarray(lg, np.float32), atol=tol, rtol=tol)


def test_cell_applicability_matrix():
    """The 40-cell accounting: every cell is either runnable or has a
    documented skip reason."""
    n_run = n_skip = 0
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            if ok:
                n_run += 1
            else:
                n_skip += 1
                assert reason
    assert n_run + n_skip == 40
    # encoder skips 2 decode cells; 8 full-attention archs skip long_500k
    assert n_skip == 2 + 7  # hubert(decode_32k+long), 7 others long_500k


def test_input_specs_are_abstract():
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_count_sanity():
    # full configs should be in the advertised ballpark
    assert 2.0e9 < ARCHS["zamba2-2.7b"].n_params() < 3.6e9
    assert 0.9e9 < ARCHS["olmo-1b"].n_params() < 1.6e9
    assert 60e9 < ARCHS["qwen2-vl-72b"].n_params() < 85e9
    assert 6e9 < ARCHS["falcon-mamba-7b"].n_params() < 9e9
    assert 150e9 < ARCHS["qwen3-moe-235b-a22b"].n_params() < 300e9
    a22 = ARCHS["qwen3-moe-235b-a22b"].active_params()
    assert 15e9 < a22 < 30e9
