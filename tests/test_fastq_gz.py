"""gzip FASTQ ingestion: .fastq.gz parses bit-identically to the plain
file (same ReadChunks, same skip/truncate counters), the truncated-gzip
failure mode raises instead of silently ending the read set, and the
paired reader walks two gzip files / one interleaved file in lockstep
with per-pair length policy and mate-name checks."""
import gzip

import numpy as np
import pytest

from repro.data.genome import (make_reference, sample_pairs, sample_reads,
                               write_fastq, write_fastq_pair)
from repro.io.fastq import FastqStream, PairedFastqStream, mate_base_name


@pytest.fixture(scope="module")
def world(tmp_path_factory):
    d = tmp_path_factory.mktemp("fastq_gz")
    ref = make_reference(4000, seed=21)
    rs = sample_reads(ref, 33, read_len=90, seed=22, both_strands=True)
    names = [f"r{i}" for i in range(33)]
    write_fastq(d / "reads.fq", rs, names=names)
    write_fastq(d / "reads.fastq.gz", rs, names=names)
    return d, ref


def _drain(stream):
    chunks = list(stream)
    return (np.concatenate([c.reads for c in chunks]),
            np.concatenate([c.quals for c in chunks]),
            [n for c in chunks for n in c.names],
            [s for c in chunks for s in c.seqs])


def test_gzip_parses_bit_identical_to_plain(world):
    d, _ = world
    plain = FastqStream(str(d / "reads.fq"), chunk_reads=10)
    gz = FastqStream(str(d / "reads.fastq.gz"), chunk_reads=10)
    assert gz.read_len == plain.read_len == 90
    pr, pq, pn, ps = _drain(plain)
    gr, gq, gn, gs = _drain(gz)
    np.testing.assert_array_equal(pr, gr)
    np.testing.assert_array_equal(pq, gq)
    assert pn == gn and ps == gs
    assert (gz.n_reads, gz.n_skipped, gz.n_truncated) == \
        (plain.n_reads, plain.n_skipped, plain.n_truncated) == (33, 0, 0)


def test_gzip_length_policy_counters_match_plain(tmp_path):
    txt = ("@long\n" + "A" * 12 + "\n+\n" + "I" * 12 + "\n"
           "@short\nACG\n+\nIII\n"
           "@exact\n" + "C" * 8 + "\n+\n" + "#" * 8 + "\n")
    (tmp_path / "p.fq").write_text(txt)
    with gzip.open(tmp_path / "p.fastq.gz", "wt") as f:
        f.write(txt)
    out = []
    for name in ("p.fq", "p.fastq.gz"):
        s = FastqStream(str(tmp_path / name), read_len=8, chunk_reads=64)
        (chunk,) = list(s)
        out.append((chunk.names, chunk.reads.tobytes(),
                    s.n_reads, s.n_skipped, s.n_truncated))
    assert out[0] == out[1]
    assert out[0][3] == 1 and out[0][4] == 1  # skip short, truncate long


def test_truncated_gzip_stream_raises(tmp_path):
    ref = make_reference(3000, seed=5)
    rs = sample_reads(ref, 64, read_len=80, seed=6)
    write_fastq(tmp_path / "full.fastq.gz", rs)
    blob = (tmp_path / "full.fastq.gz").read_bytes()
    (tmp_path / "cut.fastq.gz").write_bytes(blob[: len(blob) // 2])
    stream = FastqStream(str(tmp_path / "cut.fastq.gz"), chunk_reads=16)
    with pytest.raises((ValueError, EOFError), match="truncated|Compressed"):
        for _ in stream:
            pass
    # and the records seen before the cut never silently count as a
    # complete read set
    assert stream.n_reads < 64


def test_misnamed_gz_fails_fast(tmp_path):
    """A gzip blob without the .gz suffix must error in the parser, not
    stream compressed framing as bases."""
    ref = make_reference(1000, seed=7)
    rs = sample_reads(ref, 4, read_len=50, seed=8)
    write_fastq(tmp_path / "x.fastq.gz", rs)
    renamed = tmp_path / "x.fastq"
    renamed.write_bytes((tmp_path / "x.fastq.gz").read_bytes())
    with pytest.raises((ValueError, UnicodeDecodeError)):
        list(FastqStream(str(renamed)))


# ---------------------------------------------------------------- paired

def test_mate_base_name():
    assert mate_base_name("p7/1") == mate_base_name("p7/2") == "p7"
    assert mate_base_name("plain") == "plain"
    assert mate_base_name("x/12") == "x/12"  # only a trailing 1 or 2
    # SRA spot names use '.N' for DIFFERENT templates — never stripped
    # (conflating 'SRR123.1' and 'SRR123.2' would merge two spots)
    assert mate_base_name("SRR123.1") == "SRR123.1"
    assert mate_base_name("SRR123_2") == "SRR123_2"


@pytest.fixture(scope="module")
def paired_world(tmp_path_factory):
    d = tmp_path_factory.mktemp("paired_gz")
    ref = make_reference(8000, seed=31)
    ps = sample_pairs(ref, 21, read_len=80, insert_mean=220, insert_sd=20,
                      seed=32)
    write_fastq_pair(str(d / "r1.fastq.gz"), str(d / "r2.fastq.gz"), ps)
    write_fastq_pair(None, None, ps,
                     interleaved_path=str(d / "inter.fastq.gz"))
    return d, ps


def test_paired_two_file_gz_roundtrip(paired_world):
    d, ps = paired_world
    stream = PairedFastqStream(str(d / "r1.fastq.gz"),
                               str(d / "r2.fastq.gz"), chunk_reads=8)
    assert stream.read_len == 80
    pairs = list(stream)
    assert [len(c1) for c1, _ in pairs] == [8, 8, 5]
    for c1, c2 in pairs:
        assert c1.names == c2.names  # shared template QNAMEs
    np.testing.assert_array_equal(
        np.concatenate([c1.reads for c1, _ in pairs]), ps.reads1)
    np.testing.assert_array_equal(
        np.concatenate([c2.reads for _, c2 in pairs]), ps.reads2)
    np.testing.assert_array_equal(
        np.concatenate([c2.quals for _, c2 in pairs]), ps.quals2)
    assert stream.n_pairs == 21 and stream.n_skipped == 0


def test_paired_interleaved_matches_two_file(paired_world):
    d, ps = paired_world
    two = PairedFastqStream(str(d / "r1.fastq.gz"), str(d / "r2.fastq.gz"),
                            chunk_reads=64)
    inter = PairedFastqStream(str(d / "inter.fastq.gz"), interleaved=True,
                              chunk_reads=64)
    (t1, t2), = list(two)
    (i1, i2), = list(inter)
    assert t1.names == i1.names
    np.testing.assert_array_equal(t1.reads, i1.reads)
    np.testing.assert_array_equal(t2.reads, i2.reads)
    np.testing.assert_array_equal(t2.quals, i2.quals)


def test_paired_skips_whole_pair_when_one_mate_short(tmp_path):
    r1 = "@a/1\n" + "A" * 8 + "\n+\n" + "I" * 8 + "\n" \
         "@b/1\n" + "C" * 8 + "\n+\n" + "I" * 8 + "\n"
    r2 = "@a/2\nACG\n+\nIII\n" \
         "@b/2\n" + "G" * 10 + "\n+\n" + "I" * 10 + "\n"
    (tmp_path / "r1.fq").write_text(r1)
    (tmp_path / "r2.fq").write_text(r2)
    stream = PairedFastqStream(str(tmp_path / "r1.fq"),
                               str(tmp_path / "r2.fq"), read_len=8)
    (c1, c2), = list(stream)
    # pair a dropped entirely (short R2), pair b kept (R2 truncated)
    assert c1.names == c2.names == ["b"]
    assert stream.n_skipped == 1 and stream.n_truncated == 1
    assert c1.reads.shape == c2.reads.shape == (1, 8)


def test_paired_name_mismatch_and_desync(tmp_path):
    (tmp_path / "r1.fq").write_text("@a/1\nACGT\n+\nIIII\n")
    (tmp_path / "r2.fq").write_text("@zz/2\nACGT\n+\nIIII\n")
    with pytest.raises(ValueError, match="mate name mismatch"):
        list(PairedFastqStream(str(tmp_path / "r1.fq"),
                               str(tmp_path / "r2.fq")))
    (tmp_path / "r1b.fq").write_text("@a/1\nACGT\n+\nIIII\n"
                                     "@b/1\nACGT\n+\nIIII\n")
    (tmp_path / "r2b.fq").write_text("@a/2\nACGT\n+\nIIII\n")
    with pytest.raises(ValueError, match="unpaired FASTQ"):
        list(PairedFastqStream(str(tmp_path / "r1b.fq"),
                               str(tmp_path / "r2b.fq")))
    with pytest.raises(ValueError, match="r2 must be None"):
        PairedFastqStream("x.fq", "y.fq", interleaved=True)
