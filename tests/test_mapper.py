"""Unified ``Mapper`` session API: parity with the deprecated free
functions (which must warn), the plan/run layer and its cache counters,
mesh topology in-process (1-shard mesh), and ``MappingService`` request
reassembly (out-of-order drains, partial buckets, bucket-spanning
requests)."""
import warnings

import numpy as np
import pytest

from repro.core.mapper import Mapper, MapperStats, MappingPlan
from repro.core.pipeline import MapperConfig, map_reads
from repro.core.serving import BatcherConfig, MappingService

FIELDS = ("position", "distance", "mapped", "ops", "op_count",
          "linear_dist", "n_candidates")


@pytest.fixture(scope="module")
def world():
    from repro.core.index import build_index
    from repro.data.genome import make_reference, sample_reads
    ref = make_reference(8_000, seed=11, repeat_frac=0.03)
    idx = build_index(ref)
    rs = sample_reads(ref, 40, seed=13)
    junk = np.random.default_rng(15).integers(0, 4, (8, 150)).astype(np.uint8)
    return idx, np.concatenate([rs.reads, junk])


@pytest.fixture(scope="module")
def mesh1(world):
    """In-process 1-shard mesh + sharded index (no subprocess needed)."""
    from repro.core.distributed import shard_index
    from repro.core.mapper import _flat_mesh
    idx, _ = world
    return _flat_mesh(1), shard_index(idx, 1)


def _assert_same(a, b, fields=FIELDS):
    for f in fields:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


# ------------------------------------------------------------------ parity

def test_map_matches_deprecated_map_reads(world):
    idx, reads = world
    res = Mapper(idx).map(reads)
    with pytest.warns(DeprecationWarning, match="Mapper"):
        old = map_reads(idx, reads)
    _assert_same(res, old)
    # unified stats carry the legacy accounting keys
    assert res.stats["survivors"] == old.stats["survivors"]
    assert res.stats.survivors == res.stats["survivors"]


def test_deprecation_warnings_point_at_caller(world, mesh1):
    """The shims' DeprecationWarnings must carry a stacklevel that blames
    the *calling* code (this file), not the shim module — that is what
    makes `python -W error::DeprecationWarning` output actionable."""
    idx, reads = world
    mesh, sidx = mesh1
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        map_reads(idx, reads[:8])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert dep and dep[0].filename == __file__

    from repro.core.distributed import distributed_map_reads
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        distributed_map_reads(mesh, sidx, reads[:8])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert dep and dep[0].filename == __file__


def test_map_matches_padded_reference(world):
    idx, reads = world
    a = Mapper(idx, MapperConfig.from_index(idx, engine="padded")).map(reads)
    b = Mapper(idx, MapperConfig.from_index(idx, chunk_reads=14)).map(reads)
    _assert_same(a, b)
    assert a.stats is None  # padded reference: no instance accounting
    assert b.stats.extra["n_chunks"] == 4


def test_map_async_matches_map(world):
    idx, reads = world
    with Mapper(idx) as mapper:
        sync = mapper.map(reads)
        futs = [mapper.map_async(reads[:16]), mapper.map_async(reads)]
        _assert_same(futs[1].result(), sync)
        np.testing.assert_array_equal(futs[0].result().position,
                                      sync.position[:16])


# ---------------------------------------------------------------- planning

def test_plan_is_inspectable_before_execution(world):
    idx, reads = world
    mapper = Mapper(idx)
    plan = mapper.plan(reads, chunk=14)
    assert isinstance(plan, MappingPlan)
    assert plan.chunk_sizes == (14, 14, 14, 6)
    assert plan.lin_cap_max == 14 * mapper.cfg.max_minis * mapper.cfg.max_pls
    assert mapper.plan_cache_misses == 0  # planning dispatches nothing
    res = mapper.run(plan, reads)
    assert res.stats.extra["n_chunks"] == 4


def test_plan_cache_hits_on_repeat(world):
    idx, reads = world
    mapper = Mapper(idx)
    mapper.map(reads)
    assert (mapper.plan_cache_hits, mapper.plan_cache_misses) == (0, 1)
    res = mapper.map(reads)
    assert (mapper.plan_cache_hits, mapper.plan_cache_misses) == (1, 1)
    # the stats snapshot carries the session counters
    assert res.stats.plan_cache_hits == 1
    # a different chunking is a different plan key
    mapper.run(mapper.plan(reads, chunk=16), reads)
    assert mapper.plan_cache_misses == 2


def test_unknown_topology_rejected(world):
    idx, _ = world
    with pytest.raises(ValueError, match="topology"):
        Mapper(idx, topology="ring")


# ---------------------------------------------------------------- validation

def test_mapper_config_rejects_bad_values_at_construction():
    with pytest.raises(ValueError, match="engine"):
        MapperConfig(engine="nope")
    with pytest.raises(ValueError, match="wf_backend"):
        MapperConfig(wf_backend="cuda")
    with pytest.raises(ValueError, match="lin_block_r"):
        MapperConfig(lin_block_r=3)
    with pytest.raises(ValueError, match="aff_block_r"):
        MapperConfig(aff_block_r=0)
    with pytest.raises(ValueError, match="chunk_reads"):
        MapperConfig(chunk_reads=0)


def test_mapper_config_from_index(world):
    idx, _ = world
    cfg = MapperConfig.from_index(idx)
    assert (cfg.read_len, cfg.k, cfg.w, cfg.eth) == \
        (idx.read_len, idx.k, idx.w, idx.eth)
    cfg2 = MapperConfig.from_index(idx, wf_backend="pallas", eth=4)
    assert cfg2.wf_backend == "pallas" and cfg2.eth == 4
    # works for sharded indexes too (same geometry fields)
    from repro.core.distributed import shard_index
    assert MapperConfig.from_index(shard_index(idx, 2)) == cfg


# ------------------------------------------------------------ mesh topology

def test_mesh_topology_matches_deprecated_distributed(world, mesh1):
    from repro.core.distributed import distributed_map_reads
    idx, reads = world
    mesh, sidx = mesh1
    res = Mapper(sidx, topology="mesh", mesh=mesh).map(reads)
    with pytest.warns(DeprecationWarning, match="mesh"):
        pos, dist, dropped, st = distributed_map_reads(
            mesh, sidx, reads, with_stats=True)
    np.testing.assert_array_equal(res.position, pos)
    np.testing.assert_array_equal(res.distance, dist)
    assert res.ops is None and res.linear_dist is None
    assert isinstance(res.stats, MapperStats)
    for k in st:
        assert res.stats[k] == st[k], k
    assert res.stats.dropped_send == int(np.asarray(dropped).sum())


def test_mesh_topology_matches_single_shard(world, mesh1):
    idx, reads = world
    mesh, sidx = mesh1
    single = Mapper(idx).map(reads)
    meshed = Mapper(idx, topology="mesh", mesh=mesh).map(reads)
    np.testing.assert_array_equal(meshed.position, single.position)
    np.testing.assert_array_equal(meshed.distance, single.distance)


def test_mesh_pads_to_shard_multiple(world, mesh1):
    idx, reads = world
    mesh, sidx = mesh1
    mapper = Mapper(sidx, topology="mesh", mesh=mesh)
    plan = mapper.plan(64)
    assert plan.padded_reads == 64
    sub = mapper.run(plan, reads[:37])  # short batch through a 64-plan
    full = mapper.map(reads[:37])
    assert len(sub.position) == 37
    np.testing.assert_array_equal(sub.position, full.position)


def test_mesh_rejects_mismatched_shards(world, mesh1):
    from repro.core.distributed import shard_index
    idx, _ = world
    mesh, _ = mesh1
    with pytest.raises(ValueError, match="shards"):
        Mapper(shard_index(idx, 2), topology="mesh", mesh=mesh)


# ------------------------------------------------- service reassembly

def test_service_out_of_order_drains(world):
    """Interleaved submit/flush cycles: every id resolves exactly once, in
    the flush that drained it, with results matching a direct map."""
    idx, reads = world
    mapper = Mapper(idx)
    svc = MappingService(mapper,
                         batcher=BatcherConfig(bucket_min=8, bucket_max=32))
    r0 = svc.submit(reads[:7])
    out0 = svc.flush()
    assert set(out0) == {r0}
    r1 = svc.submit(reads[7:20])
    r2 = svc.submit(reads[20:25])
    out1 = svc.flush()
    assert set(out1) == {r1, r2}
    direct = mapper.map(reads[7:20])
    np.testing.assert_array_equal(out1[r1].position, direct.position)
    np.testing.assert_array_equal(out1[r1].ops, direct.ops)
    np.testing.assert_array_equal(out0[r0].position,
                                  mapper.map(reads[:7]).position)
    assert svc.flush() == {}


def test_service_partial_final_bucket(world):
    """A drain that only part-fills its last pow-2 bucket still returns
    exact per-request results (padding trimmed)."""
    idx, reads = world
    svc = MappingService(Mapper(idx),
                         batcher=BatcherConfig(bucket_min=8, bucket_max=32))
    sizes = [9, 3]  # 12 reads -> one padded 16-bucket
    rids = [svc.submit(reads[:9]), svc.submit(reads[9:12])]
    out = svc.flush()
    assert svc.batcher.stats["padded_reads"] == 4
    lo = 0
    for rid, n in zip(rids, sizes):
        direct = Mapper(idx).map(reads[lo : lo + n])
        np.testing.assert_array_equal(out[rid].position, direct.position)
        np.testing.assert_array_equal(out[rid].distance, direct.distance)
        assert len(out[rid].position) == n
        lo += n


def test_service_request_split_across_buckets(world):
    """One request larger than bucket_max spans two pow-2 buckets and is
    reassembled to a single per-request MappingResult."""
    idx, reads = world
    svc = MappingService(Mapper(idx),
                         batcher=BatcherConfig(bucket_min=8, bucket_max=16))
    rid = svc.submit(reads[:24])  # -> buckets [16, 8]
    out = svc.flush()
    assert sorted(svc.batcher.stats["bucket_hist"]) == [8, 16]
    direct = Mapper(idx).map(reads[:24])
    _assert_same(out[rid], direct)


def test_service_totals_accumulate(world):
    idx, reads = world
    svc = MappingService(Mapper(idx),
                         batcher=BatcherConfig(bucket_min=8, bucket_max=32))
    svc.submit(reads[:20])
    svc.flush()
    assert svc.totals["reads"] == 20
    assert 0 < svc.totals["survivors"] <= svc.totals["candidates"]
    svc.submit(reads[20:])
    svc.flush()
    assert svc.totals["reads"] == len(reads)


def test_service_on_mesh_reassembles_and_caches_plans(world, mesh1):
    """The ISSUE acceptance path: MappingService routed through
    Mapper(topology="mesh") — per-request results match the single-shard
    mapper, and repeated same-size buckets are pure plan-cache hits
    (zero new executables => zero recompiles after warm-up)."""
    idx, reads = world
    mesh, sidx = mesh1
    mapper = Mapper(sidx, topology="mesh", mesh=mesh)
    svc = MappingService(mapper,
                         batcher=BatcherConfig(bucket_min=8, bucket_max=16))
    single = Mapper(idx)

    def roundtrip():
        rids = [svc.submit(reads[:24]), svc.submit(reads[24:31])]
        out = svc.flush()
        spans = [(0, 24), (24, 31)]
        for rid, (lo, hi) in zip(rids, spans):
            ref = single.map(reads[lo:hi])
            np.testing.assert_array_equal(out[rid].position, ref.position)
            np.testing.assert_array_equal(out[rid].distance, ref.distance)
            assert out[rid].ops is None  # mesh path: no traceback

    roundtrip()  # warm-up: compiles one executable per bucket size
    warm_misses = mapper.plan_cache_misses
    hits0 = mapper.plan_cache_hits
    for _ in range(3):
        roundtrip()
    assert mapper.plan_cache_misses == warm_misses  # no recompiles
    assert mapper.plan_cache_hits > hits0
    assert svc.totals["reads"] == 4 * 31
