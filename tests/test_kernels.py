"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

rng = np.random.default_rng(11)


def _pair_batch(R, n, eth, near=True):
    s1 = rng.integers(0, 4, (R, n)).astype(np.uint8)
    s2 = rng.integers(0, 4, (R, n + 2 * eth)).astype(np.uint8)
    if near:
        s2[: R // 2, eth : eth + n] = s1[: R // 2]
        for r in range(R // 2):
            for _ in range(int(rng.integers(0, 4))):
                s2[r, eth + int(rng.integers(0, n))] = rng.integers(0, 4)
    return s1, s2


@pytest.mark.parametrize("R,n,eth,block_r", [
    (33, 24, 6, 32),
    (64, 40, 6, 64),
    (128, 50, 4, 128),
    (16, 30, 8, 16),
])
def test_linear_wf_kernel_sweep(R, n, eth, block_r):
    s1, s2 = _pair_batch(R, n, eth)
    de, dm = ops.linear_wf(jnp.array(s1), jnp.array(s2), eth=eth,
                           block_r=block_r)
    r = ref.linear_wf_ref(jnp.array(s1).T, jnp.array(s2).T, eth=eth)
    np.testing.assert_array_equal(np.array(de), np.array(r[0]))
    np.testing.assert_array_equal(np.array(dm), np.array(r[1]))


@pytest.mark.parametrize("R,n,eth,sat,block_r", [
    (17, 24, 6, 32, 32),
    (32, 40, 4, 16, 32),
    (64, 30, 6, 32, 64),
])
def test_affine_wf_kernel_sweep(R, n, eth, sat, block_r):
    s1, s2 = _pair_batch(R, n, eth)
    de, dm, dirs = ops.affine_wf(jnp.array(s1), jnp.array(s2), eth=eth,
                                 sat=sat, block_r=block_r)
    rd, rdirs = ref.affine_wf_ref(jnp.array(s1).T, jnp.array(s2).T,
                                  eth=eth, sat=sat)
    band = 2 * eth + 1
    np.testing.assert_array_equal(np.array(de), np.array(rd[0]))
    np.testing.assert_array_equal(np.array(dm), np.array(rd[1]))
    np.testing.assert_array_equal(
        np.array(dirs), np.array(rdirs).T.reshape(R, n, band))


@pytest.mark.parametrize("R,L,k,w,block_r", [
    (8, 150, 12, 30, 8),
    (33, 100, 12, 30, 64),
    (16, 80, 8, 16, 16),
])
def test_minimizer_kernel_sweep(R, L, k, w, block_r):
    seqs = rng.integers(0, 4, (R, L)).astype(np.uint8)
    mh, mp = ops.minimizer_scan(jnp.array(seqs), k=k, w=w, block_r=block_r)
    rh, rp = ref.minimizer_ref(jnp.array(seqs).T, k=k, w=w)
    np.testing.assert_array_equal(np.array(mh), np.array(rh).T)
    np.testing.assert_array_equal(np.array(mp), np.array(rp).T)


def test_kernel_padding_path():
    """R not divisible by block_r exercises the pad/unpad wrapper."""
    s1, s2 = _pair_batch(21, 24, 6)
    de, _ = ops.linear_wf(jnp.array(s1), jnp.array(s2), eth=6, block_r=64)
    r = ref.linear_wf_ref(jnp.array(s1).T, jnp.array(s2).T, eth=6)
    np.testing.assert_array_equal(np.array(de), np.array(r[0]))


@pytest.mark.parametrize("B,S,H,KV,hd,causal,qc,kc", [
    (2, 128, 4, 2, 32, True, 64, 64),
    (1, 256, 8, 8, 16, True, 64, 128),
    (2, 128, 6, 2, 32, False, 32, 64),
    (1, 64, 4, 1, 64, True, 64, 32),
])
def test_flash_attention_kernel_sweep(B, S, H, KV, hd, causal, qc, kc):
    r = np.random.default_rng(5)
    q = jnp.asarray(r.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KV, hd)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-3, rtol=2e-3)
