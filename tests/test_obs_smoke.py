"""Observability CLI smoke (the ``obs-smoke`` CI job's test): run
``map_fastq --trace-out --metrics-out --log-json`` as a subprocess on
both topologies on a tiny genome, then validate the exported Chrome
trace with the dependency-free checker (B/E balance, numeric pid/tid/
ts/dur) and the metrics JSONL against the checked-in schema at
``schemas/metrics_snapshot.schema.json``."""
import json
import os
import subprocess
import sys

import pytest

from repro.data.genome import (make_reference, sample_reads, write_fasta,
                               write_fastq)
from repro.obs.validate import (load_json, validate_chrome_trace,
                                validate_jsonl)

READ_LEN = 120
N_READS = 24
SCHEMA = os.path.join(os.path.dirname(__file__), "..", "schemas",
                      "metrics_snapshot.schema.json")


@pytest.fixture(scope="module")
def fastq_world(tmp_path_factory):
    d = tmp_path_factory.mktemp("obs_smoke")
    ref = make_reference(5_000, seed=0, repeat_frac=0.02)
    write_fasta(d / "ref.fa", [("chr1", ref)])
    rs = sample_reads(ref, N_READS, read_len=READ_LEN, seed=3,
                      both_strands=True)
    write_fastq(d / "reads.fq", rs.reads, rs.quals,
                [f"read{i}" for i in range(N_READS)])
    return d


def _run_map_fastq(d, *argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.map_fastq",
           str(d / "ref.fa"), str(d / "reads.fq"), *argv,
           "--chunk-reads", "16"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    return proc.stderr


def _json_lines(text):
    out = []
    for ln in text.splitlines():
        try:
            out.append(json.loads(ln))
        except json.JSONDecodeError:
            continue
    return out


@pytest.mark.parametrize("topology", ["single", "mesh"])
def test_trace_and_metrics_exports(fastq_world, topology):
    d = fastq_world
    tag = topology
    extra = ["--topology", "mesh", "--shards", "1"] \
        if topology == "mesh" else []
    stderr = _run_map_fastq(
        d, "-o", str(d / f"out_{tag}.sam"),
        "--trace-out", str(d / f"trace_{tag}.json"),
        "--metrics-out", str(d / f"metrics_{tag}.jsonl"),
        "--log-json", *extra)

    # Chrome trace: loads, validates, and holds the chunk lifecycle
    trace = load_json(d / f"trace_{tag}.json")
    assert validate_chrome_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    expected = ({"dispatch", "d2h"} if topology == "mesh"
                else {"seed", "d2h"})
    assert expected <= names, names
    assert "ingest" in names and "sam_emit" in names
    # spans carry chunk attribution for the viewer
    assert any(e.get("args", {}).get("chunk") is not None
               for e in trace["traceEvents"] if e["ph"] == "X")

    # metrics JSONL: every snapshot line matches the checked-in schema,
    # and counters end up covering the run accounting
    schema = load_json(SCHEMA)
    assert validate_jsonl(d / f"metrics_{tag}.jsonl", schema) == []
    last = [json.loads(ln) for ln in
            open(d / f"metrics_{tag}.jsonl") if ln.strip()][-1]
    counters = last["counters"]
    # dual-strand mesh runs count each strand encoding as a mapped row,
    # so the counter is >= the FASTQ read count on that topology
    assert counters[f'repro_reads_total{{topology="{tag}"}}'] >= N_READS
    assert any(k.startswith("repro_stage_seconds_total") for k in counters)

    # --log-json: launcher progress is one JSON object per line (other
    # stderr writers — jax/absl warnings — may interleave; skip them)
    events = [obj.get("event") for obj in _json_lines(stderr)]
    assert "start" in events and "done" in events and "chunk" in events


def test_trace_durations_match_metrics_counters(fastq_world):
    """The CLI-level acceptance property: the exported trace's summed
    per-stage durations equal the ``repro_stage_seconds_total`` counters
    in the final metrics snapshot — both accrue from the same
    ``streaming.timed`` clock reads (and the counters are
    ``stage_times_s`` by the same construction)."""
    d = fastq_world
    _run_map_fastq(
        d, "-o", str(d / "out_agree.sam"),
        "--trace-out", str(d / "trace_agree.json"),
        "--metrics-out", str(d / "metrics_agree.jsonl"))
    last = [json.loads(ln) for ln in
            open(d / "metrics_agree.jsonl") if ln.strip()][-1]
    st = {k.split('stage="')[1].rstrip('"}'): v
          for k, v in last["counters"].items()
          if k.startswith("repro_stage_seconds_total")}
    assert st, "no per-stage counters in the final snapshot"
    totals = {}
    for e in load_json(d / "trace_agree.json")["traceEvents"]:
        if e["ph"] == "X":
            totals[e["name"]] = totals.get(e["name"], 0.0) + e["dur"] / 1e6
    for k, v in st.items():
        assert totals[k] == pytest.approx(v, rel=1e-6, abs=1e-7), k


def test_build_index_exports(fastq_world, tmp_path):
    d = fastq_world
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src") +
                         os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.build_index",
           str(d / "ref.fa"), "-o", str(tmp_path / "ref.idx"),
           "--partitions", "2", "--read-len", str(READ_LEN),
           "--trace-out", str(tmp_path / "trace.json"),
           "--metrics-out", str(tmp_path / "metrics.jsonl"),
           "--log-json"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    trace = load_json(tmp_path / "trace.json")
    assert validate_chrome_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"index_scan", "index_partition"} <= names
    assert validate_jsonl(tmp_path / "metrics.jsonl",
                          load_json(SCHEMA)) == []
    events = [o.get("event") for o in _json_lines(proc.stderr)]
    assert "done" in events and "progress" in events
