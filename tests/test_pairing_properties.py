"""Property-based paired-end conformance: for simulated paired sets and
for adversarial synthetic mate results, every emitted pair satisfies the
FLAG algebra (0x40 xor 0x80, mate bits mirror each other, 0x2 implies
both mapped), TLEN(R1) == -TLEN(R2), and CIGAR query-lengths re-sum to
the read length; plus a byte-exact golden-file SAM conformance test
(tests/golden/) and unit coverage of the MAPQ model, the insert-size
tracker, mate rescue, and the paired serving path."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.index import build_index
from repro.core.mapper import Mapper, split_result
from repro.core.pairing import (MAPQ_MAX, InsertSizeTracker, compute_mapq,
                                resolve_pairs)
from repro.core.pipeline import MapperConfig, MappingResult
from repro.data.genome import make_reference, sample_pairs
from repro.io.cigar import cigar_query_len
from repro.io.fasta import Contig, ReferenceMap
from repro.io.sam import (FLAG_MATE_REVERSE, FLAG_MATE_UNMAPPED,
                          FLAG_PAIRED, FLAG_PROPER, FLAG_READ1, FLAG_READ2,
                          FLAG_REVERSE, FLAG_UNMAPPED,
                          emit_paired_alignments, sam_header, validate_sam)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
READ_LEN = 100
N_PAIRS = 16

_WORLD = None


def _world():
    """Module-cached mapping world (plain function, not a fixture, so the
    hypothesis @given tests can reach it too — the vendored stub cannot
    inject pytest fixtures)."""
    global _WORLD
    if _WORLD is None:
        ref = make_reference(12_000, seed=40, repeat_frac=0.0)
        idx = build_index(ref, read_len=READ_LEN)
        cfg = MapperConfig.from_index(idx, both_strands=True)
        _WORLD = ref, idx, cfg, Mapper(idx, cfg)
    return _WORLD


@pytest.fixture(scope="module")
def world():
    return _world()


def _contigs(ref):
    return [Contig("chrT", len(ref), 0)]


def _paired_sam(world, seed: int, n_pairs: int = N_PAIRS):
    """Simulate -> map (one stacked batch) -> resolve -> emit; returns
    (sam_text, PairResolution, PairedReadSet)."""
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, n_pairs, read_len=READ_LEN, insert_mean=300,
                      insert_sd=30, seed=seed, unmappable_frac=0.15)
    res1, res2 = mapper.map_pairs(ps.reads1, ps.reads2)
    pr = resolve_pairs(res1, res2, cfg=cfg, ref=ref,
                       reads1=ps.reads1, reads2=ps.reads2)
    names = [f"p{seed}_{i}" for i in range(n_pairs)]
    recs = list(emit_paired_alignments(
        pr, names, ps.reads1, ps.quals1, ps.reads2, ps.quals2,
        ReferenceMap(_contigs(ref))))
    text = "\n".join(sam_header(_contigs(ref)) + recs) + "\n"
    return text, pr, ps


def _flag_algebra(records):
    """The pair-FLAG invariants, asserted record-by-record (independent
    of validate_sam, which is itself under test here)."""
    by_name: dict[str, list] = {}
    for ln in records:
        f = ln.split("\t")
        by_name.setdefault(f[0], []).append(f)
    for qname, pair in by_name.items():
        assert len(pair) == 2, qname
        fl = [int(f[1]) for f in pair]
        assert all(x & FLAG_PAIRED for x in fl)
        # exactly one R1 and one R2, each with exactly one of 0x40/0x80
        assert all(bool(x & FLAG_READ1) != bool(x & FLAG_READ2) for x in fl)
        assert bool(fl[0] & FLAG_READ1) != bool(fl[1] & FLAG_READ1)
        for me, other in ((0, 1), (1, 0)):
            # mate bits mirror the mate's own state
            assert bool(fl[me] & FLAG_MATE_UNMAPPED) == \
                bool(fl[other] & FLAG_UNMAPPED)
            if not fl[other] & FLAG_UNMAPPED:
                assert bool(fl[me] & FLAG_MATE_REVERSE) == \
                    bool(fl[other] & FLAG_REVERSE)
        # 0x2 implies both mapped, and is set on both or neither
        assert bool(fl[0] & FLAG_PROPER) == bool(fl[1] & FLAG_PROPER)
        if fl[0] & FLAG_PROPER:
            assert not any(x & FLAG_UNMAPPED for x in fl)
            assert not any(x & FLAG_MATE_UNMAPPED for x in fl)
        # TLEN symmetry
        assert int(pair[0][8]) == -int(pair[1][8]), qname
        # CIGAR query length re-sums to the read length
        for f in pair:
            assert len(f[9]) == READ_LEN
            if f[5] != "*":
                assert cigar_query_len(f[5]) == READ_LEN


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_property_simulated_pairs_conform(seed):
    text, _, _ = _paired_sam(_world(), seed)
    records = [ln for ln in text.splitlines() if not ln.startswith("@")]
    assert len(records) == 2 * N_PAIRS
    _flag_algebra(records)
    stats = validate_sam(text, expect_reads=2 * N_PAIRS, require_mapq=True)
    assert stats["n_paired"] == 2 * N_PAIRS


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=12))
def test_property_synthetic_states_conform(states):
    """Adversarial host-side states the simulator rarely produces: every
    combination of (mate1 mapped, mate2 mapped, per-mate strands)
    including both-unmapped, same-strand discordant, and far-apart
    loci."""
    n = len(states)
    rng = np.random.default_rng(sum(states) + 7 * n)
    sat = 32

    def mk(mapped, strand):
        pos = np.where(mapped, rng.integers(0, 900, n), -1).astype(np.int64)
        return MappingResult(
            position=pos,
            distance=np.where(mapped, rng.integers(0, 6, n), sat),
            distance2=np.full(n, sat, dtype=np.int64),
            mapped=np.asarray(mapped, bool),
            strand=np.asarray(strand, np.int8))

    m1 = np.array([bool(s & 1) for s in states])
    m2 = np.array([bool(s & 2) for s in states])
    s1 = np.array([int(bool(s & 4)) for s in states], np.int8)
    s2 = np.array([int(bool(s & 8)) for s in states], np.int8)
    res1, res2 = mk(m1, s1), mk(m2, s2)
    cfg = MapperConfig(read_len=20)
    pr = resolve_pairs(res1, res2, cfg=cfg)
    rm = ReferenceMap([Contig("c", 1000, 0)])
    reads = np.zeros((n, 20), np.uint8)
    quals = np.full((n, 20), ord("I"), np.uint8)
    names = [f"s{i}" for i in range(n)]
    recs = list(emit_paired_alignments(pr, names, reads, quals, reads,
                                       quals, rm))
    assert len(recs) == 2 * n

    def check(records):
        by = {}
        for ln in records:
            f = ln.split("\t")
            by.setdefault(f[0], []).append(f)
        for pair in by.values():
            fl = [int(f[1]) for f in pair]
            assert all(x & FLAG_PAIRED for x in fl)
            assert bool(fl[0] & FLAG_READ1) != bool(fl[1] & FLAG_READ1)
            assert int(pair[0][8]) == -int(pair[1][8])
            for me, other in ((0, 1), (1, 0)):
                assert bool(fl[me] & FLAG_MATE_UNMAPPED) == \
                    bool(fl[other] & FLAG_UNMAPPED)
    check(recs)
    text = "\n".join(sam_header([Contig("c", 1000, 0)]) + recs) + "\n"
    validate_sam(text, expect_reads=2 * n, require_mapq=True)


# ------------------------------------------------------------- golden file

def test_golden_paired_sam_conformance(world):
    """Byte-exact conformance against the checked-in golden SAM.  If a
    deliberate behavior change moves the output, regenerate with:
    PYTHONPATH=src python tests/make_golden.py"""
    text, _, _ = _paired_sam(world, seed=779)
    golden_path = os.path.join(GOLDEN_DIR, "paired_small.sam")
    with open(golden_path) as f:
        golden = f.read()
    assert text == golden, (
        "paired SAM output drifted from tests/golden/paired_small.sam; "
        "if intentional, regenerate via tests/make_golden.py")


# ----------------------------------------------------- accuracy + rescue

def test_proper_pair_accuracy_vs_ground_truth(world):
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, 64, read_len=READ_LEN, insert_mean=300,
                      insert_sd=30, seed=51)
    res1, res2 = mapper.map_pairs(ps.reads1, ps.reads2)
    pr = resolve_pairs(res1, res2, cfg=cfg, ref=ref,
                       reads1=ps.reads1, reads2=ps.reads2)
    ok = ((np.abs(pr.res1.position - ps.pos1) <= 6)
          & (np.abs(pr.res2.position - ps.pos2) <= 6)
          & (pr.res1.strand == ps.strand1)
          & (pr.res2.strand == ps.strand2) & pr.proper)
    assert ok.mean() >= 0.97, pr.stats
    # observed fragment length recovers the simulator's ground truth
    close = np.abs(pr.insert[pr.proper]
                   - ps.isize[pr.proper]) <= 6
    assert close.mean() >= 0.9


def test_mate_rescue_recovers_killed_mate(world):
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, 32, read_len=READ_LEN, insert_mean=300,
                      insert_sd=30, seed=52)
    res1, res2 = mapper.map_pairs(ps.reads1, ps.reads2)
    kill = np.flatnonzero(res2.mapped)[:6]
    res2.mapped[kill] = False
    res2.position[kill] = -1
    pr = resolve_pairs(res1, res2, cfg=cfg, ref=ref,
                       reads1=ps.reads1, reads2=ps.reads2)
    assert pr.stats["n_rescued"] == len(kill)
    assert pr.rescued2[kill].all()
    np.testing.assert_array_equal(pr.res2.strand[kill], ps.strand2[kill])
    assert (np.abs(pr.res2.position[kill] - ps.pos2[kill]) <= 2).all()
    # rescued mates are capped: never more confident than their anchor
    assert (pr.mapq2[kill] <= np.minimum(pr.mapq1[kill], 17)).all()


def test_rescue_rejects_junk_mate(world):
    """A genuinely unmappable mate (random sequence) must NOT be rescued
    into a fake placement."""
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, 16, read_len=READ_LEN, insert_mean=300,
                      insert_sd=30, seed=53, unmappable_frac=1.0)
    res1, res2 = mapper.map_pairs(ps.reads1, ps.reads2)
    assert res2.mapped.sum() == 0
    pr = resolve_pairs(res1, res2, cfg=cfg, ref=ref,
                       reads1=ps.reads1, reads2=ps.reads2)
    assert pr.stats["n_rescued"] == 0
    assert not pr.res2.mapped.any() and not pr.proper.any()
    assert (pr.mapq2 == 0).all()


# ------------------------------------------------------------- unit layer

def test_cross_contig_mates_never_proper():
    """Regression (review-found): in flat concatenated coordinates, R1 at
    the end of one contig and R2 at the start of the next sit a
    spacer-width apart — inside any permissive insert window — but a
    chimeric pair must never earn 0x2 nor feed the insert tracker."""
    sat = 32
    # contigs: [0, 1000) and [1200, 2200) with a 200-base spacer
    contig_starts = [0, 1200]
    res1 = MappingResult(position=np.array([950]),
                         distance=np.array([0]),
                         distance2=np.array([sat]),
                         mapped=np.array([True]),
                         strand=np.array([0], np.int8))
    res2 = MappingResult(position=np.array([1210]),
                         distance=np.array([0]),
                         distance2=np.array([sat]),
                         mapped=np.array([True]),
                         strand=np.array([1], np.int8))
    cfg = MapperConfig(read_len=100)
    tr = InsertSizeTracker()
    pr = resolve_pairs(res1, res2, cfg=cfg, tracker=tr,
                       contig_starts=contig_starts)
    assert not pr.proper[0]
    assert tr.n_observed == 0  # the pseudo-insert never enters the median
    # same geometry on a single contig IS concordant (sanity check)
    pr2 = resolve_pairs(res1, res2, cfg=cfg, contig_starts=[0])
    assert pr2.proper[0]
    # and the emitted records carry no 0x2 but still point at the mate
    rm = ReferenceMap([Contig("cA", 1000, 0), Contig("cB", 1000, 1200)])
    reads = np.zeros((1, 100), np.uint8)
    quals = np.full((1, 100), ord("I"), np.uint8)
    r1, r2 = list(emit_paired_alignments(pr, ["x"], reads, quals, reads,
                                         quals, rm))
    f1, f2 = r1.split("\t"), r2.split("\t")
    assert not int(f1[1]) & FLAG_PROPER and not int(f2[1]) & FLAG_PROPER
    assert f1[2] == "cA" and f1[6] == "cB" and int(f1[8]) == 0
    assert f2[2] == "cB" and f2[6] == "cA" and int(f2[8]) == 0


def test_exact_repeat_read_gets_zero_gap_distance2():
    """Regression (review-found): a read from an exact two-copy repeat
    shares ALL its minimizers between the copies, so the per-minimizer
    argmin collapse hides the second copy from the affine survey — the
    linear-stage co-optimality fold must still report distance2 ==
    distance (no gap, MAPQ ~0) instead of claiming uniqueness."""
    rng = np.random.default_rng(9)
    ref = rng.integers(0, 4, 6000).astype(np.uint8)
    ref[4000:4400] = ref[1000:1400]  # exact 400-base duplicate
    idx = build_index(ref, read_len=120)
    read = ref[1100:1220][None, :]  # read wholly inside the repeat
    uniq = ref[300:420][None, :]    # control: unique locus
    for engine in ("compacted", "padded"):
        cfg = MapperConfig.from_index(idx, engine=engine)
        res = Mapper(idx, cfg).map(np.concatenate([read, uniq]))
        assert res.mapped.all()
        assert res.distance2[0] == res.distance[0], engine  # ambiguous
        assert res.distance2[1] == cfg.sat_affine, engine   # unique
        q = compute_mapq(res.distance, res.distance2, res.mapped,
                         sat=cfg.sat_affine)
        assert q[0] == 0 and q[1] == MAPQ_MAX, engine
    # mesh path (1-shard in-process mesh): same calibration
    from repro.core.mapper import make_mesh_compat
    from repro.core.distributed import AXIS
    mesh = make_mesh_compat((1,), (AXIS,))
    mres = Mapper(idx, MapperConfig.from_index(idx), topology="mesh",
                  mesh=mesh).map(np.concatenate([read, uniq]))
    assert mres.mapped.all()
    assert mres.distance2[0] == mres.distance[0]
    assert mres.distance2[1] == MapperConfig.from_index(idx).sat_affine


def test_insert_tracker_window():
    tr = InsertSizeTracker(min_samples=8)
    assert tr.window() == tr.default_window  # bootstrap: permissive
    rng = np.random.default_rng(0)
    tr.update(rng.normal(350, 30, 256).astype(int))
    lo, hi = tr.window()
    assert lo < 350 < hi and 330 < tr.median < 370
    assert hi - lo < 2 * 350  # and it actually narrowed
    tr2 = InsertSizeTracker(max_samples=64)
    tr2.update(np.full(200, 100))
    assert len(tr2._samples) == 64 and tr2.n_observed == 200
    lo2, hi2 = tr2.window()
    assert lo2 < 100 < hi2  # zero-MAD library keeps a floored window


def test_compute_mapq_calibration():
    sat = 32
    d1 = np.array([0, 0, 0, 3, 0])
    d2 = np.array([sat, 0, 2, sat, sat])
    mapped = np.array([True, True, True, True, False])
    proper = np.array([True, False, False, False, False])
    mate = np.array([True, True, True, False, True])
    q = compute_mapq(d1, d2, mapped, sat=sat, proper=proper,
                     mate_mapped=mate)
    assert q[0] == MAPQ_MAX                   # unique + proper: top score
    assert q[1] == 0                          # exact co-optimal: no trust
    assert 0 < q[2] < q[0]                    # small gap, discordant: mid
    assert q[3] > 0                           # lone mate keeps solo score
    assert q[4] == 0                          # unmapped: always 0
    assert (q <= MAPQ_MAX).all() and (q >= 0).all()


def test_split_result_roundtrip(world):
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, 8, read_len=READ_LEN, seed=54)
    stacked = mapper.map(np.concatenate([ps.reads1, ps.reads2]))
    r1, r2 = split_result(stacked, 8)
    np.testing.assert_array_equal(r1.position, stacked.position[:8])
    np.testing.assert_array_equal(r2.position, stacked.position[8:])
    np.testing.assert_array_equal(r2.distance2, stacked.distance2[8:])
    assert r1.stats is stacked.stats and r2.stats is stacked.stats
    # and map_pairs is exactly this stack+split
    m1, m2 = mapper.map_pairs(ps.reads1, ps.reads2)
    np.testing.assert_array_equal(m1.position, r1.position)
    np.testing.assert_array_equal(m2.position, r2.position)


def test_service_submit_paired(world):
    ref, idx, cfg, mapper = world
    ps = sample_pairs(ref, 9, read_len=READ_LEN, seed=55)
    svc = mapper.serve()
    rid_single = svc.submit(ps.reads1[:3])
    rid_pair = svc.submit_paired(ps.reads1, ps.reads2)
    out = svc.flush()
    assert isinstance(out[rid_pair], tuple)
    r1, r2 = out[rid_pair]
    assert len(r1.position) == len(r2.position) == 9
    direct1, direct2 = mapper.map_pairs(ps.reads1, ps.reads2)
    np.testing.assert_array_equal(r1.position, direct1.position)
    np.testing.assert_array_equal(r2.position, direct2.position)
    np.testing.assert_array_equal(r2.distance2, direct2.distance2)
    assert not isinstance(out[rid_single], tuple)  # single stays single
