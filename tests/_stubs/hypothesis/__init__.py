"""Minimal deterministic stand-in for ``hypothesis``.

Loaded by ``tests/conftest.py`` only when the real package is not
installed (some CI/container images lack it; ``pip install -r
requirements-dev.txt`` gets the real thing).  Implements just the subset
this suite uses — ``@given`` / ``@settings`` with ``st.integers`` and
``st.lists`` — by drawing ``max_examples`` pseudo-random examples from a
per-test seeded RNG, so runs are reproducible but carry none of
hypothesis' shrinking or database machinery.
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import random

__version__ = "0.0.0-stub"

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


class strategies:  # noqa: N801 — mirrors the real module-as-namespace use
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int | None = None) -> _Strategy:
        hi = (min_size + 16) if max_size is None else max_size

        def draw(rng):
            return [elements._draw(rng) for _ in range(rng.randint(min_size,
                                                                   hi))]
        return _Strategy(draw)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Records max_examples on the decorated function (order-agnostic
    w.r.t. @given: the runner checks both the wrapper and the inner fn)."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            key = f"{fn.__module__}.{fn.__qualname__}".encode()
            rng = random.Random(int(hashlib.sha256(key).hexdigest()[:12], 16))
            for _ in range(n):
                drawn = [s._draw(rng) for s in strats]
                fn(*args, *drawn, **kwargs)
        # hide the wrapped signature, else pytest mistakes the strategy
        # parameters for fixtures
        run.__dict__.pop("__wrapped__", None)
        run.__signature__ = inspect.Signature()
        return run
    return deco
